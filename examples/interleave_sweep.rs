//! Multi-device exploration: sweep expander count x interleave
//! granularity and watch traffic spread across the cards — the
//! system-level question a fleet architect asks before buying N small
//! expanders vs one big one. Doubles as the multi-device config schema
//! walkthrough:
//!
//! ```toml
//! [cxl]
//! devices = 4                  # one host bridge + root port + PCIe
//!                              # bus + link + media per card
//! interleave_ways = 0          # 0 = auto (all cards, one window)
//! interleave_granularity = 1024
//! interleave_arith = "modulo"  # or "xor"
//!
//! [cxl.dev2]                   # per-card overrides
//! size = 8 GiB
//! link_width = 4
//! latency_class = "far"
//! ```
//!
//! Each interleave set publishes one CEDT CFMWS window and onlines as
//! one zNUMA node; per-device fill counters come back in
//! `RunSummary::cxl_dev_fills` (and `cxl.devN.*` in the stat dump).
//!
//! Run: `cargo run --release --example interleave_sweep`

use cxlramsim::config::SimConfig;
use cxlramsim::coordinator::run_sweep;
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::util::bench::Table;
use cxlramsim::workloads::{Stream, StreamKernel};

#[derive(Clone)]
struct Point {
    devices: usize,
    granularity: u64,
}

fn main() -> anyhow::Result<()> {
    cxlramsim::util::logger::init();
    let mut points = Vec::new();
    for devices in [1usize, 2, 4] {
        for granularity in [256u64, 1024, 4096] {
            points.push(Point { devices, granularity });
        }
    }

    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8);
    let rows = run_sweep(points, threads, |p: Point| {
        let mut cfg = SimConfig::default();
        cfg.cores = 1;
        cfg.sys_mem_size = 256 << 20;
        cfg.cxl.mem_size = 256 << 20; // per device
        cfg.cxl.devices = p.devices;
        cfg.cxl.interleave_granularity = p.granularity;
        let mut m = Machine::new(cfg.clone()).expect("machine");
        m.boot(ProgModel::Znuma).expect("boot");
        let wl = Stream::for_wss(StreamKernel::Triad, cfg.l2.size, 6);
        // Everything on the expander set: node 1 is the interleaved
        // zNUMA node covering all devices.
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![1] },
        )
        .expect("attach");
        let s = m.run(None);
        let total: u64 = s.cxl_dev_fills.iter().sum();
        let spread = s
            .cxl_dev_fills
            .iter()
            .map(|&f| format!("{:.0}%", 100.0 * f as f64 / total.max(1) as f64))
            .collect::<Vec<_>>()
            .join("/");
        vec![
            p.devices.to_string(),
            p.granularity.to_string(),
            format!("{:.2}", s.bandwidth_gbps),
            format!("{:.0}", s.avg_lat_cxl_ns),
            total.to_string(),
            spread,
        ]
    });

    let mut t = Table::new(
        "STREAM triad on N interleaved expanders",
        &["devices", "gran B", "GB/s", "CXL lat ns", "CXL fills", "spread"],
    );
    for r in rows {
        t.row(&r);
    }
    t.print();
    println!(
        "\nspread = share of line fills served by each device; an even\n\
         split means the window's interleave decode engaged every card."
    );
    Ok(())
}
