//! Closed-loop elastic pooling: a telemetry-driven Fabric-Manager
//! **policy** (`[fm] policy = "capacity_rebalance"`) migrates logical
//! devices toward demand — with ZERO hand-written `[fm] events`.
//!
//! This is the scenario class a scripted schedule cannot express: the
//! FM does not know *when* (or whether) a host will need memory; it
//! finds out by sampling per-host/per-LD stats every `[fm] epoch` and
//! reacts, with hysteresis (min-residency, per-host cooldown, refusal
//! back-off) keeping the loop stable. Because the sampling epochs are
//! ordinary entries in the machine's unified `(tick, seq)` queue and
//! every input is deterministic machine state, the whole closed loop
//! is bitwise reproducible.
//!
//! Timeline:
//!   * boot        — one 2-LD MLD behind a switch; the FM binds BOTH
//!     LDs to host 0; host 1 boots with the windows published but
//!     offline (its hot-plug pool).
//!   * t = 0       — host 0 streams on node 1 (LD 0), leaving LD 1
//!     idle. Host 1 starts a working set that *prefers* node 2 — while
//!     that node is offline every page it touches spills to DRAM,
//!     which shows up as `host1.sys.numa_fallback_allocs` pressure.
//!   * each epoch  — the FM differentiates the pressure counters. Once
//!     LD 1's min-residency expires it decides, on its own, to move
//!     dev0.ld1 to host 1: POLICY_DECISION + UNBIND_REQUEST Event-Log
//!     records, guest offline, UNBIND_LD / BIND_LD, guest hot-add —
//!     the identical path a scripted rebind takes.
//!   * afterwards  — host 1's faults land on its preferred CXL node;
//!     the pressure signal dies out and the loop goes quiet (no
//!     ping-pong).
//!
//! Run: `cargo run --release --example policy_sweep`

use cxlramsim::config::{
    CxlDevOverride, FmPolicyConfig, FmPolicyKind, LdRef, SimConfig,
};
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::util::bench::Table;
use cxlramsim::workloads::{Stream, StreamKernel};

fn policy_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.hosts = 2;
    cfg.cores = 2;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 512 << 20; // 2 x 256 MiB LD slices
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides =
        vec![CxlDevOverride { lds: Some(2), ..Default::default() }];
    // FM boot binding: host 0 starts with both logical devices.
    cfg.host_lds = vec![
        vec![LdRef { dev: 0, ld: 0 }, LdRef { dev: 0, ld: 1 }],
        vec![],
    ];
    // The whole point: no [fm] events — just a policy.
    cfg.fm_policy =
        Some(FmPolicyConfig::new(FmPolicyKind::CapacityRebalance));
    cfg
}

struct RunOut {
    ticks: u64,
    epochs: u64,
    decisions: u64,
    holds: u64,
    fallback1: u64,
    host1_ld1_reads: u64,
    rebinds: u64,
    dmesg: Vec<String>,
    stats_text: String,
}

fn run_once() -> RunOut {
    let cfg = policy_cfg();
    assert!(cfg.fm_events.is_empty(), "closed loop: no scripted events");
    let mut m = Machine::new(cfg).expect("machine");
    m.boot(ProgModel::Znuma).expect("boot");
    // Host 0: pinned to its first LD's node — LD 1 stays idle, so the
    // policy has donor capacity to work with.
    let wl0 = Stream::for_wss(StreamKernel::Triad, m.cfg.l2.size, 2);
    m.attach_workloads_to(
        0,
        vec![Box::new(wl0)],
        &MemPolicy::Bind { nodes: vec![1] },
    )
    .expect("attach host 0");
    // Host 1: a growing working set that PREFERS node 2. While the
    // node is offline the allocator spills to DRAM — the demand signal
    // the capacity_rebalance policy watches.
    let wl1 = Stream::for_wss(StreamKernel::Triad, m.cfg.l2.size, 4);
    m.attach_workloads_to(
        1,
        vec![Box::new(wl1)],
        &MemPolicy::Preferred { node: 2 },
    )
    .expect("attach host 1");
    let s = m.run(None);
    m.verify().expect("verify");

    let d = m.dump_stats();
    let get = |k: &str| d.get(k).unwrap_or(0.0) as u64;
    let mut dmesg = Vec::new();
    for h in 0..2 {
        let g = m.hosts[h].guest.as_ref().expect("guest");
        for line in &g.boot_log {
            if line.contains("hot-remove")
                || line.contains("hot-add")
                || line.contains("policy decision")
            {
                dmesg.push(format!("[host{h}] {line}"));
            }
        }
    }
    RunOut {
        ticks: s.ticks,
        epochs: get("fm.policy.epochs"),
        decisions: get("fm.policy.decisions"),
        holds: get("fm.policy.holds"),
        fallback1: get("host1.sys.numa_fallback_allocs"),
        host1_ld1_reads: get("cxl.dev0.ld1.host1_reads"),
        rebinds: get("cxl.dev0.ld1.rebinds"),
        dmesg,
        stats_text: d.to_text(),
    }
}

fn main() -> anyhow::Result<()> {
    cxlramsim::util::logger::init();

    let a = run_once();

    println!("guest kernel log (policy + hot-plug lines):");
    for line in &a.dmesg {
        println!("  {line}");
    }

    let mut t = Table::new(
        "LOAD-DRIVEN FM POLICY: capacity follows demand, no scripts",
        &["metric", "value"],
    );
    t.row(&["run length (ticks)".into(), a.ticks.to_string()]);
    t.row(&["policy epochs sampled".into(), a.epochs.to_string()]);
    t.row(&["moves decided".into(), a.decisions.to_string()]);
    t.row(&[
        "moves held by hysteresis".into(),
        a.holds.to_string(),
    ]);
    t.row(&[
        "host1 pages spilled pre-move".into(),
        a.fallback1.to_string(),
    ]);
    t.row(&[
        "host1 reads served by dev0.ld1 (post-move)".into(),
        a.host1_ld1_reads.to_string(),
    ]);
    t.row(&["cxl.dev0.ld1.rebinds".into(), a.rebinds.to_string()]);
    t.print();

    // The closed loop is an event-queue program like everything else:
    // repeat the run and every sampled epoch, decision and stat lands
    // identically.
    let b = run_once();
    let identical = a.stats_text == b.stats_text && a.ticks == b.ticks;
    println!(
        "\nbitwise deterministic across two runs: {}",
        if identical { "yes" } else { "NO (bug!)" }
    );
    assert!(identical, "policy run must be bit-deterministic");
    assert!(
        a.rebinds >= 1 && a.decisions >= 1,
        "the FM must migrate >= 1 LD toward the loaded host on its own"
    );
    assert!(
        a.host1_ld1_reads > 0,
        "host 1 must observe its new capacity mid-run"
    );
    println!(
        "the FM noticed host 1 spilling {} pages off its preferred \
         node, waited out LD 1's residency ({} epochs held), and moved \
         it over — {} line fills later host 1 runs on CXL it was never \
         scripted to receive.",
        a.fallback1, a.holds, a.host1_ld1_reads
    );
    Ok(())
}
