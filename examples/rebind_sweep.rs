//! Elastic pooling: the Fabric Manager re-binds a logical device
//! between two running hosts — host 0 *shrinks* while host 1 *grows*,
//! mid-workload, through the unmodified enumeration/driver path.
//!
//! This is the scenario class (elastic memory for LLM serving, the
//! CXL-ClusterSim motivation) a static-binding simulator cannot
//! express: capacity follows demand across hosts at runtime, and the
//! whole run stays bitwise-deterministic because FM actions are just
//! events in the machine's unified `(tick, seq)` queue.
//!
//! Timeline:
//!   * boot      — one 2-LD MLD behind a switch; the FM binds BOTH LDs
//!     to host 0 (zNUMA nodes 1 and 2); host 1 boots with the same two
//!     windows published but offline — its hot-plug pool.
//!   * t = 0     — host 0 streams on node 1 (LD 0); host 1 streams with
//!     `--preferred 2`, which falls back to DRAM while node 2 is
//!     offline.
//!   * t = 50 us — FM `UNBIND_LD` dev0.ld1: host 0's guest gets the
//!     Event-Log doorbell, offlines node 2 (it is idle — hot-remove
//!     refuses busy nodes), uncommits the HDM decoder pair, releases
//!     the LD.
//!   * t = 55 us — FM `BIND_LD` dev0.ld1 -> host 1: host 1's guest
//!     commits the spare window's decoders, `cxl create-region`s it and
//!     onlines node 2; from here its page faults land on CXL.
//!
//! Run: `cargo run --release --example rebind_sweep`

use cxlramsim::config::{CxlDevOverride, FmEventDef, LdRef, SimConfig};
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::util::bench::Table;
use cxlramsim::workloads::{Stream, StreamKernel};

fn rebind_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.hosts = 2;
    cfg.cores = 2;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 512 << 20; // 2 x 256 MiB LD slices
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides =
        vec![CxlDevOverride { lds: Some(2), ..Default::default() }];
    // FM boot binding: host 0 starts with both logical devices.
    cfg.host_lds = vec![
        vec![LdRef { dev: 0, ld: 0 }, LdRef { dev: 0, ld: 1 }],
        vec![],
    ];
    cfg.fm_events = vec![
        FmEventDef::parse("@50us unbind dev0.ld1").expect("event"),
        FmEventDef::parse("@55us bind dev0.ld1 host1").expect("event"),
    ];
    cfg
}

struct RunOut {
    ticks: u64,
    host1_ld1_reads: u64,
    offline0: u64,
    online1: u64,
    rebinds: u64,
    dmesg: Vec<String>,
    stats_text: String,
}

fn run_once() -> RunOut {
    let mut m = Machine::new(rebind_cfg()).expect("machine");
    m.boot(ProgModel::Znuma).expect("boot");
    // Host 0: pinned to its first LD's node — node 2 stays idle so the
    // hot-remove can take it cleanly mid-run.
    let wl0 = Stream::for_wss(StreamKernel::Triad, m.cfg.l2.size, 2);
    m.attach_workloads_to(
        0,
        vec![Box::new(wl0)],
        &MemPolicy::Bind { nodes: vec![1] },
    )
    .expect("attach host 0");
    // Host 1: prefers node 2 — DRAM fallback while it is offline, CXL
    // as soon as the hot-add lands.
    let wl1 = Stream::for_wss(StreamKernel::Triad, m.cfg.l2.size, 4);
    m.attach_workloads_to(
        1,
        vec![Box::new(wl1)],
        &MemPolicy::Preferred { node: 2 },
    )
    .expect("attach host 1");
    let s = m.run(None);
    m.verify().expect("verify");

    let d = m.dump_stats();
    let get = |k: &str| d.get(k).unwrap_or(0.0) as u64;
    let mut dmesg = Vec::new();
    for h in 0..2 {
        let g = m.hosts[h].guest.as_ref().expect("guest");
        for line in &g.boot_log {
            if line.contains("hot-remove")
                || line.contains("hot-add")
                || line.contains("reserved for hot-plug")
            {
                dmesg.push(format!("[host{h}] {line}"));
            }
        }
    }
    RunOut {
        ticks: s.ticks,
        host1_ld1_reads: get("cxl.dev0.ld1.host1_reads"),
        offline0: get("host0.sys.mem_offline_events"),
        online1: get("host1.sys.mem_online_events"),
        rebinds: get("cxl.dev0.ld1.rebinds"),
        dmesg,
        stats_text: d.to_text(),
    }
}

fn main() -> anyhow::Result<()> {
    cxlramsim::util::logger::init();

    let a = run_once();

    println!("guest kernel log (hot-plug lines):");
    for line in &a.dmesg {
        println!("  {line}");
    }

    let mut t = Table::new(
        "FM-DRIVEN LD RE-BIND: host 0 shrinks, host 1 grows mid-run",
        &["metric", "value"],
    );
    t.row(&["run length (ticks)".into(), a.ticks.to_string()]);
    t.row(&[
        "host1 reads served by dev0.ld1 (post-rebind)".into(),
        a.host1_ld1_reads.to_string(),
    ]);
    t.row(&["host0 mem_offline_events".into(), a.offline0.to_string()]);
    t.row(&["host1 mem_online_events".into(), a.online1.to_string()]);
    t.row(&["cxl.dev0.ld1.rebinds".into(), a.rebinds.to_string()]);
    t.print();

    // The run is an event-queue program: repeat it and the FM actions
    // land on the same ticks, in the same order, with the same stats.
    let b = run_once();
    let identical = a.stats_text == b.stats_text && a.ticks == b.ticks;
    println!(
        "\nbitwise deterministic across two runs: {}",
        if identical { "yes" } else { "NO (bug!)" }
    );
    assert!(identical, "rebind run must be bit-deterministic");
    assert!(a.rebinds == 1 && a.offline0 == 1 && a.online1 == 1);
    assert!(
        a.host1_ld1_reads > 0,
        "host 1 must observe its new capacity mid-run"
    );
    println!(
        "host 1 gained 256 MiB of CXL-backed zNUMA capacity mid-run \
         ({} line fills served by the re-bound LD) while host 0 shrank \
         by the same amount — all through GET_EVENT_RECORDS, HDM \
         decoder re-commits and cxl-cli onlining, no simulator hooks.",
        a.host1_ld1_reads
    );
    Ok(())
}
