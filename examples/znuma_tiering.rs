//! Programming-model demo (paper §IV): the same KV-cache-shaped
//! workload under three memory-exposure strategies:
//!   1. zNUMA + explicit tiering (hot keys bound to DRAM, cold to CXL),
//!   2. zNUMA + naive bind-everything-to-CXL,
//!   3. Flat mode (CXL merged with system RAM, first-touch spill).
//!
//! Shows why the zNUMA programming model the paper champions matters:
//! the OS-visible node boundary is what lets software tier at all.
//!
//! Run: `cargo run --release --example znuma_tiering`

use cxlramsim::config::SimConfig;
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::util::bench::Table;
use cxlramsim::workloads::TieredKv;

fn run(
    label: &str,
    model: ProgModel,
    hot: MemPolicy,
    cold: MemPolicy,
    t: &mut Table,
) -> anyhow::Result<()> {
    let mut cfg = SimConfig::default();
    cfg.cores = 1;
    let mut m = Machine::new(cfg.clone())?;
    m.boot(model)?;
    let mut kv = TieredKv::new(8192, 256, 30_000, cfg.seed);
    kv.hot_policy = hot;
    kv.cold_policy = cold;
    m.attach_workloads(vec![Box::new(kv)], &MemPolicy::Local { home: 0 })?;
    let s = m.run(None);
    t.row(&[
        label.to_string(),
        format!("{:.2}", s.bandwidth_gbps),
        format!("{:.3}", s.seconds * 1e3),
        s.dram_accesses.to_string(),
        s.cxl_accesses.to_string(),
        format!("{:.0}", s.avg_lat_cxl_ns),
    ]);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    cxlramsim::util::logger::init();
    let mut t = Table::new(
        "Tiered KV (80% hot hits) under three programming models",
        &["model", "GB/s", "ms", "DRAM fills", "CXL fills", "CXL lat ns"],
    );

    run(
        "znuma+tiering (hot->DRAM)",
        ProgModel::Znuma,
        MemPolicy::Bind { nodes: vec![0] },
        MemPolicy::Bind { nodes: vec![1] },
        &mut t,
    )?;
    run(
        "znuma, all-on-CXL",
        ProgModel::Znuma,
        MemPolicy::Bind { nodes: vec![1] },
        MemPolicy::Bind { nodes: vec![1] },
        &mut t,
    )?;
    // Flat mode: no node boundary — the workload cannot express
    // tiering; everything is "local" and spills by first touch.
    run(
        "flat mode (no tiering)",
        ProgModel::Flat,
        MemPolicy::Local { home: 0 },
        MemPolicy::Local { home: 0 },
        &mut t,
    )?;
    t.print();
    println!(
        "\nTiering on the zNUMA boundary keeps the hot set on DRAM; flat \
         mode loses the distinction, bind-to-CXL pays full link latency."
    );
    Ok(())
}
