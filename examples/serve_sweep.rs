//! LLM serving fleet on a memory expander: what does it cost, in
//! request-latency percentiles, to back the warm KV-cache tier with
//! CXL instead of DRAM?
//!
//! One host runs the `serve` workload twice over the *identical*
//! request stream (same seed, same Zipf mix, same admission/eviction
//! sequence — the op streams are bit-identical):
//!
//!   * DRAM-only   — both KV tiers bound to the DRAM node (the
//!     "just buy more DRAM" baseline).
//!   * DRAM + CXL  — the hot tier stays in DRAM, the warm tier (where
//!     evicted-but-still-popular contexts park) moves to the CXL
//!     zNUMA node, i.e. the capacity actually available in practice.
//!
//! Because only the page placement differs, the p99 delta isolates the
//! expander's contribution to tail latency: every warm-tier hit
//! streams its KV slot across the I/O bus instead of the memory bus.
//!
//! Run: `cargo run --release --example serve_sweep`

use cxlramsim::config::SimConfig;
use cxlramsim::guestos::ProgModel;
use cxlramsim::system::Machine;
use cxlramsim::util::bench::Table;
use cxlramsim::workloads::{Serve, ServeConfig, Workload};

fn machine_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.hosts = 1;
    cfg.cores = 1;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 256 << 20;
    cfg
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        users: 256,
        zipf_s: 1.1,
        requests: 1500,
        kv_block: 1024,
        context_blocks: 4, // 4 KiB of KV state per context
        dram_slots: 32,    // hot tier: 32 resident contexts
        cxl_slots: 256,    // warm tier: everyone else's parked KV
        decode_work: 64,
    }
}

struct RunOut {
    p50: u64,
    p95: u64,
    p99: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    stats_text: String,
}

/// One serving run; `cxl_warm` picks where the warm tier's pages live.
fn run_once(cxl_warm: bool) -> RunOut {
    let mut m = Machine::new(machine_cfg()).expect("machine");
    m.boot(ProgModel::Znuma).expect("boot");
    let (hot, cold) =
        m.hosts[0].guest.as_ref().expect("guest").alloc.tier_policies();
    let cold = if cxl_warm { cold } else { hot.clone() };
    let wl: Box<dyn Workload> =
        Box::new(Serve::new(serve_cfg(), hot.clone(), cold, 42));
    m.attach_workloads_to(0, vec![wl], &hot).expect("attach");
    m.run(None);
    let d = m.dump_stats();
    let get = |k: &str| d.get(k).unwrap_or(0.0) as u64;
    RunOut {
        p50: get("serve.p50_ns"),
        p95: get("serve.p95_ns"),
        p99: get("serve.p99_ns"),
        hits: get("serve.tier_hits"),
        misses: get("serve.tier_misses"),
        evictions: get("serve.evictions"),
        stats_text: d.to_text(),
    }
}

fn main() -> anyhow::Result<()> {
    cxlramsim::util::logger::init();

    let dram = run_once(false);
    let cxl = run_once(true);

    let mut t = Table::new(
        "SERVING-FLEET TIER MIX: request latency, DRAM-only vs DRAM+CXL",
        &["metric", "dram-only", "dram+cxl"],
    );
    t.row(&["p50 (ns)".into(), dram.p50.to_string(), cxl.p50.to_string()]);
    t.row(&["p95 (ns)".into(), dram.p95.to_string(), cxl.p95.to_string()]);
    t.row(&["p99 (ns)".into(), dram.p99.to_string(), cxl.p99.to_string()]);
    t.row(&[
        "warm/hot tier hits".into(),
        dram.hits.to_string(),
        cxl.hits.to_string(),
    ]);
    t.row(&[
        "tier misses (KV recompute)".into(),
        dram.misses.to_string(),
        cxl.misses.to_string(),
    ]);
    t.row(&[
        "hot-tier evictions".into(),
        dram.evictions.to_string(),
        cxl.evictions.to_string(),
    ]);
    t.print();

    // Same seed, same Zipf draws: the *request streams* are identical,
    // so the cache behaviour (hits/misses/evictions) must match
    // exactly — only the timing may differ.
    assert_eq!(dram.hits, cxl.hits, "identical streams, identical hits");
    assert_eq!(dram.misses, cxl.misses);
    assert_eq!(dram.evictions, cxl.evictions);
    assert!(dram.evictions > 0, "config must actually churn the hot tier");

    // The expander is farther away: parking the warm tier there cannot
    // make the tail faster.
    assert!(
        cxl.p99 >= dram.p99,
        "CXL-backed warm tier p99 ({}) beat DRAM ({})?",
        cxl.p99,
        dram.p99
    );
    let delta_pct = if dram.p99 > 0 {
        (cxl.p99 as f64 - dram.p99 as f64) / dram.p99 as f64 * 100.0
    } else {
        0.0
    };
    println!(
        "\np99 delta (dram+cxl vs dram-only): +{} ns ({:+.1}%)",
        cxl.p99 - dram.p99,
        delta_pct
    );

    // And the whole serving loop is bit-deterministic.
    let again = run_once(true);
    assert_eq!(
        cxl.stats_text, again.stats_text,
        "serve run must be bit-deterministic"
    );
    println!("bitwise deterministic across two runs: yes");
    Ok(())
}
