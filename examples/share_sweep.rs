//! Sharing vs. migration: the same producer/consumer working set served
//! two ways —
//!
//!   (a) **shared LD** (CXL 3.x): one logical device is mapped into
//!       BOTH hosts at once (`[cxl.dev0] shared_lds = [0]`). Writes
//!       take device-side ownership (M2S MemInv RFO); the expander's
//!       snoop filter back-invalidates (S2M BISnp) every other sharer's
//!       cached copy, and dirty data rides the BIRsp ack home. Capacity
//!       never moves — coherence traffic does.
//!
//!   (b) **FM page migration**: the classic CXL 2.x answer. The LD is
//!       private; when the consumer needs the data the Fabric Manager
//!       UNBINDs it from the producer and BINDs it to the consumer —
//!       guest offline, decoder uncommit, hot-add on the other side.
//!       Capacity moves — no coherence traffic exists.
//!
//! Both runs print the interesting tradeoff: BI-rate vs. rebind count,
//! plus the consumer-side CXL round-trip p99. And both are ordinary
//! event-queue programs, so each is bit-identical when repeated — run
//! (a) is additionally repeated at `threads = 4, commit_lanes = 4` to
//! show the back-invalidate path holds the determinism contract too.
//!
//! Run: `cargo run --release --example share_sweep`

use cxlramsim::config::{CxlDevOverride, FmEventDef, LdRef, SimConfig};
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::util::bench::Table;
use cxlramsim::workloads::{Stream, StreamKernel};

/// (a) One 256 MiB LD, declared shared, listed by both hosts: a single
/// zNUMA node (node 1) that is the SAME physical media on both.
fn shared_cfg(threads: usize, lanes: usize) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.hosts = 2;
    cfg.cores = 2;
    cfg.threads = threads;
    cfg.commit_lanes = lanes;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 256 << 20;
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides = vec![CxlDevOverride {
        lds: Some(1),
        shared_lds: Some(vec![0]),
        ..Default::default()
    }];
    cfg.host_lds = vec![
        vec![LdRef { dev: 0, ld: 0 }],
        vec![LdRef { dev: 0, ld: 0 }],
    ];
    cfg
}

/// (b) Two private LDs; the producer starts with both and the FM
/// migrates LD 1 to the consumer mid-run (rebind_sweep's shape).
fn migrate_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.hosts = 2;
    cfg.cores = 2;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 512 << 20; // 2 x 256 MiB LD slices
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides =
        vec![CxlDevOverride { lds: Some(2), ..Default::default() }];
    cfg.host_lds = vec![
        vec![LdRef { dev: 0, ld: 0 }, LdRef { dev: 0, ld: 1 }],
        vec![],
    ];
    cfg.fm_events = vec![
        FmEventDef::parse("@50us unbind dev0.ld1").expect("event"),
        FmEventDef::parse("@55us bind dev0.ld1 host1").expect("event"),
    ];
    cfg
}

struct RunOut {
    ticks: u64,
    bi_sent: u64,
    bi_dirty_wb: u64,
    bi_inval_h0: u64,
    bi_inval_h1: u64,
    rebinds: u64,
    consumer_p99: u64,
    stats_text: String,
}

fn run(cfg: SimConfig, producer_node: u64, consumer_node: u64) -> RunOut {
    let mut m = Machine::new(cfg).expect("machine");
    m.boot(ProgModel::Znuma).expect("boot");
    // Producer (host 0): a read-write kernel pinned to the CXL node —
    // every store to a shared line is an RFO the snoop filter sees.
    let wl0 = Stream::for_wss(StreamKernel::Triad, m.cfg.l2.size, 2);
    m.attach_workloads_to(
        0,
        vec![Box::new(wl0)],
        &MemPolicy::Bind { nodes: vec![producer_node] },
    )
    .expect("attach producer");
    // Consumer (host 1): walks the same node. Under (a) its cached
    // copies of producer-written lines are back-invalidated; under (b)
    // the node is offline until the FM migrates the LD over.
    let wl1 = Stream::for_wss(StreamKernel::Triad, m.cfg.l2.size, 2);
    m.attach_workloads_to(
        1,
        vec![Box::new(wl1)],
        &MemPolicy::Preferred { node: consumer_node },
    )
    .expect("attach consumer");
    let s = m.run(None);
    m.verify().expect("verify");

    let d = m.dump_stats();
    let get = |k: &str| d.get(k).unwrap_or(0.0) as u64;
    RunOut {
        ticks: s.ticks,
        bi_sent: get("cxl.dev0.ld0.bi_sent"),
        bi_dirty_wb: get("cxl.dev0.ld0.bi_dirty_wb"),
        bi_inval_h0: get("host0.sys.bi_invalidations"),
        bi_inval_h1: get("host1.sys.bi_invalidations"),
        rebinds: get("cxl.dev0.ld0.rebinds") + get("cxl.dev0.ld1.rebinds"),
        consumer_p99: get("host1.cxl.rc.round_trip.p99"),
        stats_text: d.to_text(),
    }
}

fn main() -> anyhow::Result<()> {
    cxlramsim::util::logger::init();

    // (a) shared LD — serial baseline, then the parallel/sharded rerun.
    let a = run(shared_cfg(1, 1), 1, 1);
    let a2 = run(shared_cfg(1, 1), 1, 1);
    let a4 = run(shared_cfg(4, 4), 1, 1);
    // (b) FM migration — repeated once for the same determinism check.
    let b = run(migrate_cfg(), 1, 2);
    let b2 = run(migrate_cfg(), 1, 2);

    let mut t = Table::new(
        "SHARED LD (back-invalidate) vs FM PAGE MIGRATION (rebind)",
        &["metric", "(a) shared LD", "(b) migration"],
    );
    t.row(&[
        "run length (ticks)".into(),
        a.ticks.to_string(),
        b.ticks.to_string(),
    ]);
    t.row(&[
        "device BISnp sent (dev0.ld0.bi_sent)".into(),
        a.bi_sent.to_string(),
        b.bi_sent.to_string(),
    ]);
    t.row(&[
        "dirty lines recovered via BIRsp".into(),
        a.bi_dirty_wb.to_string(),
        b.bi_dirty_wb.to_string(),
    ]);
    t.row(&[
        "host cache invalidations (h0+h1)".into(),
        (a.bi_inval_h0 + a.bi_inval_h1).to_string(),
        (b.bi_inval_h0 + b.bi_inval_h1).to_string(),
    ]);
    t.row(&[
        "LD rebinds".into(),
        a.rebinds.to_string(),
        b.rebinds.to_string(),
    ]);
    t.row(&[
        "consumer CXL round-trip p99 (ticks)".into(),
        a.consumer_p99.to_string(),
        b.consumer_p99.to_string(),
    ]);
    t.print();

    // Determinism: repeat runs are bitwise identical, and for (a) the
    // parallel + sharded-lane engine reproduces the serial run exactly
    // even with BISnp/BIRsp traffic crossing host domains.
    let a_repeat = a.stats_text == a2.stats_text && a.ticks == a2.ticks;
    let a_parallel = a.stats_text == a4.stats_text && a.ticks == a4.ticks;
    let b_repeat = b.stats_text == b2.stats_text && b.ticks == b2.ticks;
    println!(
        "\nshared run repeat-identical: {} | threads=4/lanes=4 \
         identical: {} | migration repeat-identical: {}",
        if a_repeat { "yes" } else { "NO (bug!)" },
        if a_parallel { "yes" } else { "NO (bug!)" },
        if b_repeat { "yes" } else { "NO (bug!)" },
    );
    assert!(a_repeat, "shared-LD run must be bit-deterministic");
    assert!(
        a_parallel,
        "shared-LD run must be bit-identical under threads=4, lanes=4"
    );
    assert!(b_repeat, "migration run must be bit-deterministic");
    assert!(
        a.bi_sent > 0 && a.bi_inval_h0 + a.bi_inval_h1 > 0,
        "sharing must generate back-invalidate traffic"
    );
    assert!(a.rebinds == 0, "sharing needs no rebinds");
    assert!(b.rebinds >= 1, "migration must rebind the LD");
    assert!(b.bi_sent == 0, "private LDs must never snoop");
    println!(
        "same working set, two fabrics: sharing kept both hosts live on \
         one LD at the cost of {} back-invalidates ({} dirty lines \
         pulled home); migration kept the fabric snoop-free at the cost \
         of {} rebind(s) and a mid-run hot-plug.",
        a.bi_sent, a.bi_dirty_wb, b.rebinds
    );
    Ok(())
}
