//! Fig.-5-style exploration: STREAM triad across working-set sizes and
//! OS page-interleave ratios, for both CPU models — the paper's §IV
//! characterization, as a library consumer would script it.
//!
//! Run: `cargo run --release --example stream_sweep`

use cxlramsim::config::{CpuModel, SimConfig};
use cxlramsim::coordinator::run_sweep;
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::util::bench::Table;
use cxlramsim::workloads::{Stream, StreamKernel};

#[derive(Clone)]
struct Point {
    cpu: CpuModel,
    wss_mult: u64,
    ratio_label: &'static str,
    weights: Vec<(u32, u32)>,
}

fn main() -> anyhow::Result<()> {
    cxlramsim::util::logger::init();
    let ratios: [(&'static str, Vec<(u32, u32)>); 3] = [
        ("100:0", vec![(0, 1)]),
        ("50:50", vec![(0, 1), (1, 1)]),
        ("0:100", vec![(1, 1)]),
    ];
    let mut points = Vec::new();
    for cpu in [CpuModel::InOrder, CpuModel::OutOfOrder] {
        for wss in [2u64, 4, 8] {
            for (label, w) in &ratios {
                points.push(Point {
                    cpu,
                    wss_mult: wss,
                    ratio_label: label,
                    weights: w.clone(),
                });
            }
        }
    }

    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8);
    let rows = run_sweep(points, threads, |p: Point| {
        let mut cfg = SimConfig::default();
        cfg.cpu_model = p.cpu;
        cfg.cores = 1;
        let mut m = Machine::new(cfg.clone()).expect("machine");
        m.boot(ProgModel::Znuma).expect("boot");
        let wl = Stream::for_wss(StreamKernel::Triad, cfg.l2.size, p.wss_mult);
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Interleave { weights: p.weights.clone() },
        )
        .expect("attach");
        let s = m.run(None);
        vec![
            match p.cpu {
                CpuModel::InOrder => "Timing".to_string(),
                CpuModel::OutOfOrder => "O3".to_string(),
            },
            p.wss_mult.to_string(),
            p.ratio_label.to_string(),
            format!("{:.4}", s.l2_miss_rate),
            format!("{:.2}", s.bandwidth_gbps),
            format!("{:.0}", s.avg_lat_cxl_ns),
        ]
    });

    let mut t = Table::new(
        "STREAM triad: WSS x interleave x CPU model",
        &["cpu", "wss(xL2)", "DRAM:CXL", "LLC miss", "GB/s", "CXL lat ns"],
    );
    for r in rows {
        t.row(&r);
    }
    t.print();
    Ok(())
}
