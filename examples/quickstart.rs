//! End-to-end driver (DESIGN.md §5, recorded in EXPERIMENTS.md):
//!
//! Build the full topology (4-core O3, two-level MESI, DRAM + CXL
//! expander behind the root complex on the IOBus), boot the modeled
//! guest (BIOS -> ACPI -> PCIe enumeration -> CXL driver -> cxl-cli
//! region -> zNUMA node), then run STREAM at 4x L2 under an OS-managed
//! 1:1 interleave and report per-kernel bandwidth, LLC miss rate, CXL
//! link traffic and M2S/S2M packet counts — with functional
//! verification of the STREAM results.
//!
//! Run: `cargo run --release --example quickstart`

use cxlramsim::config::SimConfig;
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::util::bench::Table;
use cxlramsim::workloads::{Stream, StreamKernel};

fn main() -> anyhow::Result<()> {
    cxlramsim::util::logger::init();
    let cfg = SimConfig::default();
    println!("== CXLRAMSim quickstart ==");
    println!(
        "{} cores ({}), L1 {} KiB, L2 {} MiB, DRAM {} GiB, CXL {} GiB\n",
        cfg.cores,
        cfg.cpu_model.name(),
        cfg.l1.size >> 10,
        cfg.l2.size >> 20,
        cfg.sys_mem_size >> 30,
        cfg.cxl.mem_size >> 30
    );

    // --- boot -----------------------------------------------------------
    let mut probe = Machine::new(cfg.clone())?;
    probe.boot(ProgModel::Znuma)?;
    for line in &probe.guest.as_ref().unwrap().boot_log {
        println!("[guest] {line}");
    }
    println!();

    // --- STREAM at 4x L2, interleave 1:1 DRAM:CXL -------------------------
    let policy = MemPolicy::Interleave { weights: vec![(0, 1), (1, 1)] };
    let mut t = Table::new(
        "STREAM @ 4xL2, interleave 1:1 (DRAM:CXL)",
        &[
            "kernel", "GB/s", "L1 miss", "LLC miss", "DRAM fills",
            "CXL fills", "M2S req", "S2M DRS", "verified",
        ],
    );
    for kernel in StreamKernel::all() {
        let mut m = Machine::new(cfg.clone())?;
        m.boot(ProgModel::Znuma)?;
        let wl = Stream::for_wss(kernel, cfg.l2.size, 4);
        m.attach_workloads(vec![Box::new(wl)], &policy)?;
        let s = m.run(None);
        let verified = m.verify().is_ok();
        t.row(&[
            kernel.name().to_string(),
            format!("{:.2}", s.bandwidth_gbps),
            format!("{:.4}", s.l1_miss_rate),
            format!("{:.4}", s.l2_miss_rate),
            s.dram_accesses.to_string(),
            s.cxl_accesses.to_string(),
            s.m2s_req.to_string(),
            s.s2m_drs.to_string(),
            if verified { "OK" } else { "FAIL" }.to_string(),
        ]);
        assert!(verified, "functional verification failed");
    }
    t.print();
    println!(
        "\nAll four kernels verified functionally; CXL traffic crossed the \
         modeled M2S/S2M transaction layer."
    );
    Ok(())
}
