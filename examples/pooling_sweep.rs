//! Multi-host MLD pooling: one 4-LD expander behind a CXL switch,
//! its logical devices parceled out to simulated hosts by the fabric
//! manager — the scenario that separates a cluster-grade simulator
//! from a single-node one.
//!
//! The sweep compares the same per-host STREAM workload:
//!   * **1 host, solo** — host 0 alone hammers its LD through the
//!     switch (private upstream link, private media);
//!   * **2 hosts, pooled** — host 1 concurrently hammers *its* LD of
//!     the SAME device: both streams now share the switch upstream
//!     link's wire + M2S credits and the MLD's media banks, and host
//!     0's finish time stretches accordingly.
//!
//! Config walkthrough:
//!
//! ```toml
//! [system]
//! hosts = 2                     # per-host stacks over one fabric
//!
//! [cxl]
//! devices = 1
//! switches = 1
//!
//! [cxl.dev0]
//! size = 1 GiB
//! lds = 4                       # MLD: four pooled logical devices
//!
//! [host.0]
//! lds = ["dev0.ld0", "dev0.ld2"]  # FM binding (BIND_LD per entry)
//! [host.1]
//! lds = ["dev0.ld1", "dev0.ld3"]
//! ```
//!
//! Per-host traffic lands in `cxl.devN.ldK.host{H}_reads`; the shared
//! upstream port in `cxl.sw0.us_link.*`; per-host machine stats under
//! `host{H}.*`.
//!
//! Run: `cargo run --release --example pooling_sweep`

use cxlramsim::config::{CxlDevOverride, SimConfig};
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::util::bench::Table;
use cxlramsim::workloads::{Stream, StreamKernel};

fn pooled_cfg(hosts: usize) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.hosts = hosts;
    cfg.cores = 2;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 1 << 30; // 4 x 256 MiB LD slices
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides =
        vec![CxlDevOverride { lds: Some(4), ..Default::default() }];
    cfg
}

/// Run `active_hosts` concurrent per-host streams; returns
/// (host-0 finish ticks, per-host LD reads, upstream credit stalls).
fn run(hosts: usize, active_hosts: usize) -> (u64, Vec<u64>, f64) {
    let mut m = Machine::new(pooled_cfg(hosts)).expect("machine");
    m.boot(ProgModel::Znuma).expect("boot");
    for h in 0..active_hosts {
        // Each host binds to its first zNUMA node = its first LD.
        let wl = Stream::for_wss(
            StreamKernel::Triad,
            m.cfg.l2.size,
            4,
        );
        m.attach_workloads_to(
            h,
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![1] },
        )
        .expect("attach");
    }
    m.run(None);
    let host0_ticks = m.hosts[0].finished_at();
    let d = m.dump_stats();
    let per_host: Vec<u64> = (0..hosts)
        .map(|h| {
            (0..4)
                .map(|ld| {
                    d.get(&format!("cxl.dev0.ld{ld}.host{h}_reads"))
                        .unwrap_or(0.0) as u64
                })
                .sum()
        })
        .collect();
    let stalls = d.get("cxl.sw0.us_link.credit_stalls").unwrap_or(0.0);
    (host0_ticks, per_host, stalls)
}

fn main() -> anyhow::Result<()> {
    cxlramsim::util::logger::init();

    let (solo_ticks, solo_reads, solo_stalls) = run(1, 1);
    let (pooled_ticks, pooled_reads, pooled_stalls) = run(2, 2);

    let mut t = Table::new(
        "STREAM triad on one pooled 4-LD MLD behind a switch",
        &[
            "scenario",
            "host0 ticks",
            "host0 LD reads",
            "peer LD reads",
            "us credit stalls",
        ],
    );
    t.row(&[
        "1 host (solo)".into(),
        solo_ticks.to_string(),
        solo_reads[0].to_string(),
        "-".into(),
        format!("{solo_stalls:.0}"),
    ]);
    t.row(&[
        "2 hosts (pooled)".into(),
        pooled_ticks.to_string(),
        pooled_reads[0].to_string(),
        pooled_reads[1].to_string(),
        format!("{pooled_stalls:.0}"),
    ]);
    t.print();

    let slowdown = pooled_ticks as f64 / solo_ticks.max(1) as f64;
    println!(
        "\nhost 0 runs {slowdown:.2}x longer when host 1 shares the \
         MLD: both streams fund the same switch upstream link (wire + \
         credits) and the same media banks, even though each touches \
         only its own LD. That cross-host interference is the pooling \
         cost the host/fabric split makes measurable."
    );
    Ok(())
}
