//! Switch fan-out exploration: the same endpoints, direct-attached vs
//! behind one CXL switch — the question a pooling architect asks before
//! hanging N expanders off a single root port.
//!
//! Direct attach gives every card its own root-port link (private
//! bandwidth + private M2S credit pool). Behind a switch, all cards
//! share the *upstream* link's wire and credits, so concurrent streams
//! contend: bandwidth drops, credit stalls appear, and every access
//! pays the extra hop (`us link + fwd_lat_ns`). Config walkthrough:
//!
//! ```toml
//! [cxl]
//! devices = 4
//! switches = 1               # 0 = direct attach
//!
//! [cxl.switch0]
//! fanout = 4                 # downstream ports
//! link_lat_ns = 20.0         # upstream link (shared by all 4)
//! link_bw_gbps = 32.0
//! fwd_lat_ns = 25.0          # store-and-forward per hop
//!
//! [cxl.dev3]
//! lds = 2                    # MLD: two LDs -> two zNUMA nodes
//! ```
//!
//! Upstream-port stats land in `cxl.swN.us_link.*`; per-LD traffic in
//! `cxl.devN.ldK.*`.
//!
//! Run: `cargo run --release --example switch_sweep`

use cxlramsim::config::SimConfig;
use cxlramsim::coordinator::run_sweep;
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::util::bench::Table;
use cxlramsim::workloads::{Stream, StreamKernel};

#[derive(Clone)]
struct Point {
    devices: usize,
    switched: bool,
}

fn main() -> anyhow::Result<()> {
    cxlramsim::util::logger::init();
    let mut points = Vec::new();
    for devices in [2usize, 4] {
        for switched in [false, true] {
            points.push(Point { devices, switched });
        }
    }

    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8);
    let rows = run_sweep(points, threads, |p: Point| {
        let mut cfg = SimConfig::default();
        cfg.cores = p.devices;
        cfg.sys_mem_size = 256 << 20;
        cfg.cxl.mem_size = 256 << 20; // per device
        cfg.cxl.devices = p.devices;
        cfg.cxl.interleave_ways = 1; // one window per endpoint
        if p.switched {
            cfg.cxl.switches = 1; // default fanout covers all devices
        }
        let mut m = Machine::new(cfg.clone()).expect("machine");
        m.boot(ProgModel::Znuma).expect("boot");
        // One stream per endpoint, each bound to its own zNUMA node:
        // direct attach runs them on private links; switched funnels
        // everything through the shared upstream port.
        let wls: Vec<Box<dyn cxlramsim::workloads::Workload>> = (0
            ..p.devices)
            .map(|_| {
                Box::new(Stream::for_wss(
                    StreamKernel::Triad,
                    cfg.l2.size,
                    4,
                )) as Box<dyn cxlramsim::workloads::Workload>
            })
            .collect();
        let policies: Vec<u32> = (1..=p.devices as u32).collect();
        // attach_workloads takes one shared policy; emulate per-core
        // binding by interleaving with equal weights across all nodes —
        // every node (device) still sees an even share of the traffic.
        let weights: Vec<(u32, u32)> =
            policies.iter().map(|&n| (n, 1)).collect();
        m.attach_workloads(wls, &MemPolicy::Interleave { weights })
            .expect("attach");
        let s = m.run(None);
        let d = m.dump_stats();
        let stalls = if p.switched {
            d.get("cxl.sw0.us_link.credit_stalls").unwrap_or(0.0)
        } else {
            (0..p.devices)
                .map(|i| {
                    d.get(&format!("cxl.link{i}.credit_stalls"))
                        .unwrap_or(0.0)
                })
                .sum()
        };
        vec![
            p.devices.to_string(),
            if p.switched { "1 switch".into() } else { "direct".into() },
            format!("{:.2}", s.bandwidth_gbps),
            format!("{:.0}", s.avg_lat_cxl_ns),
            s.cxl_accesses.to_string(),
            format!("{stalls:.0}"),
        ]
    });

    let mut t = Table::new(
        "STREAM triad x N endpoints: direct attach vs switch fan-out",
        &[
            "endpoints",
            "topology",
            "GB/s",
            "CXL lat ns",
            "CXL fills",
            "credit stalls",
        ],
    );
    for r in rows {
        t.row(&r);
    }
    t.print();
    println!(
        "\nBehind the switch every endpoint shares one upstream link \
         (wire + M2S credits),\nso concurrent streams stall on credits \
         and pay the forwarding hop — the contention\ndisappears when \
         the same cards are direct-attached."
    );
    Ok(())
}
