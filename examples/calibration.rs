//! Latency-bandwidth calibration walkthrough (paper §III-B.2 / §V):
//! "measure" three synthetic vendor cards, fit the differentiable link
//! model to each via the AOT-compiled fwd+grad artifact, and show the
//! calibrated simulator knobs. Requires `make artifacts`.
//!
//! Run: `cargo run --release --example calibration`

use cxlramsim::calibrate::{hwref, Fitter};
use cxlramsim::config::SimConfig;
use cxlramsim::runtime::XlaRuntime;
use cxlramsim::util::bench::Table;

fn main() -> anyhow::Result<()> {
    cxlramsim::util::logger::init();
    let rt = XlaRuntime::load(std::path::Path::new("artifacts"))?;
    println!(
        "PJRT platform: {} (artifacts: window={}, calib_points={})\n",
        rt.platform(),
        rt.manifest.window,
        rt.manifest.calib_points
    );

    let cfg = SimConfig::default();
    let fitter = Fitter::default();
    let mut t = Table::new(
        "Per-vendor link calibration (fit vs synthetic silicon)",
        &[
            "card", "idle ns (true)", "sat GB/s (true)", "iters",
            "rms ns", "fit pkt ns", "fit bw GB/s",
        ],
    );
    for (i, card) in hwref::CARDS.iter().enumerate() {
        let loads =
            hwref::load_grid(rt.manifest.calib_points, card.sat_bw_gbps);
        let meas = hwref::measure(card, &loads, 0.02, 42 + i as u64);
        let report =
            fitter.fit(&rt, Fitter::seed_from(&cfg.cxl), &loads, &meas)?;
        let mut cal = cfg.cxl.clone();
        Fitter::apply(&report.fitted, &mut cal);
        t.row(&[
            card.name.to_string(),
            format!("{:.0}", card.idle_lat_ns),
            format!("{:.0}", card.sat_bw_gbps),
            report.iterations.to_string(),
            format!("{:.2}", report.rms_ns),
            format!("{:.1}", cal.pkt_lat_ns),
            format!("{:.1}", cal.link_bw_gbps),
        ]);
        // The fitted curve must reproduce the measurement well.
        assert!(
            report.rms_ns < 25.0,
            "{}: rms {} ns too high",
            card.name,
            report.rms_ns
        );
    }
    t.print();
    println!("\nFitted parameters feed straight back into [cxl.*] config keys.");
    Ok(())
}
