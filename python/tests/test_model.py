"""L2 graphs: two-level warming composition + calibration step."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest  # noqa: F401  (fixtures/marks)

from _hypothesis_compat import given, settings, st

from compile import model
from compile.kernels.ref import (calib_loss_ref, latency_curve_ref,
                                 two_level_ref)


def small_states(S1=8, W1=2, S2=16, W2=4):
    z1 = np.zeros((S1, W1), np.int32)
    z2 = np.zeros((S2, W2), np.int32)
    return ((z1, z1, z1, z1), (z2, z2, z2, z2))


def run_warm(addrs, wr, t0, l1, l2):
    out = model.cache_warm(
        jnp.asarray(addrs, jnp.int32), jnp.asarray(wr, jnp.int32),
        jnp.asarray([t0], jnp.int32),
        *[jnp.asarray(x) for x in l1], *[jnp.asarray(x) for x in l2],
    )
    return [np.asarray(o) for o in out]


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 64))
def test_two_level_matches_ref(seed, n):
    rng = np.random.default_rng(seed)
    addrs = rng.integers(0, 128, n).astype(np.int32)
    wr = rng.integers(0, 2, n).astype(np.int32)
    l1, l2 = small_states()
    out = run_warm(addrs, wr, 7, l1, l2)
    rh1, rh2, rl1, rl2 = two_level_ref(addrs, wr, [7], l1, l2)
    np.testing.assert_array_equal(out[0], rh1, "hit1")
    np.testing.assert_array_equal(out[1], rh2, "hit2")
    for o, r, n_ in zip(out[2:6], rl1, ["t", "v", "d", "l"]):
        np.testing.assert_array_equal(o, r, f"l1.{n_}")
    for o, r, n_ in zip(out[6:10], rl2, ["t", "v", "d", "l"]):
        np.testing.assert_array_equal(o, r, f"l2.{n_}")


def test_l2_sees_only_l1_misses():
    l1, l2 = small_states()
    # Same address twice: second L1-hits, so L2 sees exactly one access.
    out = run_warm([5, 5], [0, 0], 0, l1, l2)
    hit1, hit2 = out[0], out[1]
    assert list(hit1) == [0, 1]
    assert hit2[0] == 0  # L2 cold miss
    assert hit2[1] == -1  # masked: L1 hit never reaches L2


def test_inclusion_after_warming():
    rng = np.random.default_rng(0)
    l1, l2 = small_states()
    addrs = rng.integers(0, 64, 200).astype(np.int32)
    out = run_warm(addrs, np.zeros(200, np.int32), 0, l1, l2)
    l1_tags, l1_valid = out[2], out[3]
    l2_tags, l2_valid = out[6], out[7]
    S1, S2 = l1_tags.shape[0], l2_tags.shape[0]
    resident_l2 = {
        int(l2_tags[s, w]) * S2 + s
        for s in range(S2)
        for w in range(l2_tags.shape[1])
        if l2_valid[s, w]
    }
    for s in range(S1):
        for w in range(l1_tags.shape[1]):
            if l1_valid[s, w]:
                line = int(l1_tags[s, w]) * S1 + s
                assert line in resident_l2, f"L1 line {line} not in L2"


def test_calib_step_matches_ref_loss_and_descends():
    p = jnp.array([50.0, 10.0, 80.0, 20.0, 10.0], jnp.float32)
    loads = np.linspace(0.5, 25.0, model.CALIB_POINTS).astype(np.float32)
    target = latency_curve_ref(
        np.array([80.0, 25.0, 110.0, 28.0, 40.0]), loads
    )
    lr = jnp.array([1e-2, 1e-2, 1e-2, 1e-2, 1e-3], jnp.float32)
    p1, loss1 = model.calib_step(p, jnp.asarray(loads), jnp.asarray(target), lr)
    ref_loss = calib_loss_ref(np.asarray(p), loads, target)
    assert abs(float(loss1[0]) - ref_loss) / ref_loss < 1e-4
    _, loss2 = model.calib_step(
        p1, jnp.asarray(loads), jnp.asarray(target), lr
    )
    assert float(loss2[0]) < float(loss1[0])


def test_calib_grad_matches_finite_difference():
    loads = jnp.linspace(0.5, 20.0, model.CALIB_POINTS)
    target = jnp.full((model.CALIB_POINTS,), 200.0)
    p = jnp.array([50.0, 10.0, 80.0, 25.0, 10.0], jnp.float32)
    g = jax.grad(model.calib_loss)(p, loads, target)
    eps = 1e-2
    for i in range(5):
        dp = jnp.zeros(5).at[i].set(eps)
        fd = (model.calib_loss(p + dp, loads, target)
              - model.calib_loss(p - dp, loads, target)) / (2 * eps)
        assert abs(float(g[i]) - float(fd)) < max(1e-2, abs(float(fd)) * 0.05)


def test_lat_bw_sweep_shape():
    p = jnp.array([80.0, 25.0, 110.0, 28.0, 40.0], jnp.float32)
    loads = jnp.linspace(0.1, 30.0, model.SWEEP_POINTS)
    (lat,) = model.lat_bw_sweep(p, loads)
    assert lat.shape == (model.SWEEP_POINTS,)
