"""Import hypothesis when available; otherwise provide no-op stand-ins.

With the real package absent, only @given-based property tests are
skipped — deterministic tests in the same module still run (a
module-level importorskip would silently drop those too).
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategies:
        """Any strategy constructor returns a placeholder."""

        def __getattr__(self, _name):
            def _strategy(*_a, **_k):
                return None

            return _strategy

    st = _Strategies()

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        def deco(fn):
            return fn

        return deco
