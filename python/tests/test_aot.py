"""AOT pipeline: lowering produces valid HLO text + manifest."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def lowered_texts():
    return {name: aot.to_hlo_text(fn()) for name, fn in aot.ARTIFACTS.items()}


def test_all_artifacts_lower(lowered_texts):
    assert set(lowered_texts) == {"cache_warm", "calib_step", "lat_bw_sweep"}
    for name, text in lowered_texts.items():
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_cache_warm_signature_shapes(lowered_texts):
    t = lowered_texts["cache_warm"]
    assert f"s32[{model.WINDOW}]" in t
    assert f"s32[{model.L1_SETS},{model.L1_WAYS}]" in t
    assert f"s32[{model.L2_SETS},{model.L2_WAYS}]" in t


def test_calib_step_is_differentiable_graph(lowered_texts):
    # The fused fwd+grad step must reference the 5-param vector.
    t = lowered_texts["calib_step"]
    assert "f32[5]" in t
    assert f"f32[{model.CALIB_POINTS}]" in t


def test_main_writes_files_and_manifest(tmp_path, monkeypatch):
    out = tmp_path / "arts"
    monkeypatch.setattr(
        "sys.argv", ["aot", "--out", str(out)]
    )
    aot.main()
    man = json.loads((out / "manifest.json").read_text())
    assert man["format"] == "hlo-text"
    assert man["window"] == model.WINDOW
    for name, meta in man["artifacts"].items():
        p = out / meta["file"]
        assert p.exists(), name
        assert p.stat().st_size == meta["bytes"]
    assert len(man["artifacts"]) == 3
    assert os.listdir(out)  # non-empty
