"""Latency-model Pallas kernel vs the numpy oracle and the jnp twin."""

import numpy as np
import jax.numpy as jnp
import pytest  # noqa: F401  (fixtures/marks)

from _hypothesis_compat import given, settings, st

from compile import model
from compile.kernels.latency_model import latency_curve
from compile.kernels.ref import latency_curve_ref


def params_strategy():
    f = lambda lo, hi: st.floats(lo, hi, allow_nan=False)  # noqa: E731
    return st.tuples(f(1, 300), f(1, 100), f(10, 300), f(4, 64), f(1, 100))


@settings(max_examples=25, deadline=None)
@given(p=params_strategy(), seed=st.integers(0, 10**6))
def test_kernel_matches_ref(p, seed):
    rng = np.random.default_rng(seed)
    params = np.array(p, np.float32)
    loads = rng.uniform(0.05, p[3] * 1.5, 256).astype(np.float32)
    k = np.asarray(latency_curve(jnp.asarray(params), jnp.asarray(loads)))
    r = latency_curve_ref(params, loads)
    np.testing.assert_allclose(k, r, rtol=2e-5, atol=1e-2)


@settings(max_examples=10, deadline=None)
@given(p=params_strategy())
def test_kernel_matches_jnp_twin(p):
    """The grad-capable jnp twin must be numerically identical to the
    Pallas kernel — the calibration path depends on it."""
    params = np.array(p, np.float32)
    loads = np.linspace(0.1, p[3] * 1.3, 256).astype(np.float32)
    k = np.asarray(latency_curve(jnp.asarray(params), jnp.asarray(loads)))
    j = np.asarray(model._curve_jnp(jnp.asarray(params), jnp.asarray(loads)))
    np.testing.assert_allclose(k, j, rtol=1e-6, atol=1e-3)


def test_monotone_in_load():
    params = jnp.array([80.0, 25.0, 110.0, 28.0, 40.0], jnp.float32)
    loads = jnp.linspace(0.1, 27.0, 256)
    lat = np.asarray(latency_curve(params, loads))
    assert np.all(np.diff(lat) >= -1e-3)


def test_block_divisibility_enforced():
    params = jnp.zeros(5, jnp.float32)
    with pytest.raises(ValueError):
        latency_curve(params, jnp.zeros(100, jnp.float32))


def test_unloaded_latency_is_fixed_costs():
    params = np.array([80.0, 25.0, 110.0, 28.0, 40.0], np.float32)
    loads = np.full(256, 0.01, np.float32)
    lat = np.asarray(latency_curve(jnp.asarray(params), jnp.asarray(loads)))
    # base + 2*pkt + media = 240, queue term ~ 0 at tiny load.
    assert abs(lat[0] - 240.0) < 1.0
