"""Pallas cache-probe kernel vs the pure-python oracle.

The CORE correctness signal for the fast-forward path: every divergence
here would silently corrupt the Rust coordinator's warmed cache state.
"""

import numpy as np
import jax.numpy as jnp
import pytest  # noqa: F401  (fixtures/marks)

from _hypothesis_compat import given, settings, st

from compile.kernels.cache_probe import cache_probe
from compile.kernels.ref import cache_probe_ref


def run_both(addrs, wr, mask, t0, S, W, tags=None, valid=None, dirty=None,
             lru=None):
    z = np.zeros((S, W), np.int32)
    tags = z if tags is None else tags
    valid = z if valid is None else valid
    dirty = z if dirty is None else dirty
    lru = z if lru is None else lru
    args = [np.asarray(a, np.int32) for a in
            (addrs, wr, mask, t0, tags, valid, dirty, lru)]
    out = cache_probe(*[jnp.asarray(a) for a in args])
    ref = cache_probe_ref(*args)
    return [np.asarray(o) for o in out], list(ref)


def assert_match(out, ref, msg=""):
    names = ["hit", "wb", "tags", "valid", "dirty", "lru"]
    for o, r, n in zip(out, ref, names):
        np.testing.assert_array_equal(o, r, err_msg=f"{msg}: {n}")


def test_cold_miss_then_hit():
    out, ref = run_both([5, 5], [0, 0], [1, 1], [10], 4, 2)
    assert_match(out, ref)
    assert out[0][0] == 0 and out[0][1] == 1


def test_mask_skips_accesses():
    out, ref = run_both([1, 1, 1], [0, 0, 0], [1, 0, 1], [0], 4, 2)
    assert_match(out, ref)
    assert out[0][1] == -1  # skipped


def test_write_allocate_sets_dirty():
    out, ref = run_both([3], [1], [1], [0], 4, 2)
    assert_match(out, ref)
    s, tag = 3 % 4, 3 // 4
    assert out[4][s].max() == 1  # dirty bit somewhere in the set
    assert tag in out[2][s]


def test_dirty_eviction_reports_writeback():
    # 2-way set; three distinct tags to set 0 with writes.
    S, W = 4, 2
    addrs = [0, 4, 8]  # all map to set 0, tags 0,1,2
    out, ref = run_both(addrs, [1, 1, 1], [1, 1, 1], [0], S, W)
    assert_match(out, ref)
    assert out[1][2] == 0, "third access must evict dirty line addr 0"


def test_lru_order_respected():
    S, W = 2, 2
    # Set 0: fill tags 0,1 (addrs 0, 2), touch 0 again, then addr 4
    # (tag 2) must evict tag 1 (addr 2).
    addrs = [0, 2, 0, 4, 2]
    out, ref = run_both(addrs, [0] * 5, [1] * 5, [0], S, W)
    assert_match(out, ref)
    assert out[0][4] == 0, "addr 2 must have been evicted"


def test_t0_continuation_across_windows():
    S, W = 2, 2
    # Window 1 establishes LRU order; window 2 continues with larger t0.
    out1, ref1 = run_both([0, 2], [0, 0], [1, 1], [0], S, W)
    assert_match(out1, ref1)
    out2, ref2 = run_both(
        [4], [0], [1], [100], S, W,
        tags=out1[2], valid=out1[3], dirty=out1[4], lru=out1[5],
    )
    assert_match(out2, ref2)
    # tag for addr 0 (LRU) was evicted; addr 2 still resident.
    out3, _ = run_both(
        [2], [0], [1], [200], S, W,
        tags=out2[2], valid=out2[3], dirty=out2[4], lru=out2[5],
    )
    assert out3[0][0] == 1


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 96),
    s_log=st.integers(1, 4),
    w=st.integers(1, 8),
    addr_space=st.integers(8, 512),
)
def test_random_streams_match_ref(seed, n, s_log, w, addr_space):
    rng = np.random.default_rng(seed)
    S = 1 << s_log
    addrs = rng.integers(0, addr_space, n)
    wr = rng.integers(0, 2, n)
    mask = rng.integers(0, 2, n)
    out, ref = run_both(addrs, wr, mask, [seed % 1000], S, w)
    assert_match(out, ref, f"seed={seed} S={S} W={w}")


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_invariants_hold(seed):
    """Structural invariants independent of the oracle."""
    rng = np.random.default_rng(seed)
    S, W, n = 8, 4, 128
    addrs = rng.integers(0, 256, n)
    wr = rng.integers(0, 2, n)
    out, _ = run_both(addrs, wr, np.ones(n, np.int64), [1], S, W)
    hit, wb, tags, valid, dirty, lru = out
    # Every processed access is hit or miss.
    assert set(np.unique(hit)).issubset({0, 1})
    # Dirty implies valid.
    assert np.all(valid[dirty == 1] == 1)
    # No duplicate tags within a set among valid ways.
    for s in range(S):
        vt = tags[s][valid[s] == 1]
        assert len(set(vt.tolist())) == len(vt)
    # A resident line's tag re-probes as a hit.
    for s in range(S):
        for wy in range(W):
            if valid[s, wy]:
                addr = tags[s, wy] * S + s
                out2, _ = run_both(
                    [addr], [0], [1], [10**6], S, W,
                    tags=tags, valid=valid, dirty=dirty, lru=lru,
                )
                assert out2[0][0] == 1
                return  # one probe suffices per example
