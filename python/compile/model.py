"""Layer-2 JAX compute graphs for CXLRAMSim (build-time only).

Two graphs are AOT-lowered to HLO text by aot.py and executed from the
Rust coordinator via PJRT; Python never runs on the simulation path.

  cache_warm   -- functional fast-forward of a two-level cache hierarchy
                  over a window of accesses (calls the L1 Pallas kernel
                  per level). The gem5 analogue is functional warming:
                  CXLRAMSim-rs uses it to warm caches through OS boot and
                  array-init phases before switching to the detailed
                  event-driven model.
  calib_step   -- one fused fwd+grad+SGD step of the differentiable CXL
                  latency-bandwidth model against measured points (the
                  paper's user-facing latency calibration mechanism).
  lat_bw_sweep -- batched evaluation of the latency curve (the L1 Pallas
                  latency kernel) for characterisation benches.

Geometry constants here are the single source of truth; aot.py writes
them into artifacts/manifest.json, and the Rust runtime validates its
config against that manifest before using an artifact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import cache_probe, latency_curve

# --- Default AOT geometry (matches rust/src/config defaults; Table I) ----
WINDOW = 4096          # accesses per fast-forward window
L1_SETS, L1_WAYS = 64, 8      # 32 KiB / 64 B lines
L2_SETS, L2_WAYS = 1024, 16   # 1 MiB / 64 B lines
CALIB_POINTS = 32      # measured (load, latency) pairs per calib step
SWEEP_POINTS = 256     # loads per characterisation sweep


def cache_warm(addrs, is_write, t0,
               l1_tags, l1_valid, l1_dirty, l1_lru,
               l2_tags, l2_valid, l2_dirty, l2_lru):
    """Two-level functional warming for one window.

    L2 sees exactly the L1 misses (warming models the allocation path;
    writeback traffic does not change L2 *presence* under inclusive
    write-allocate assumptions -- DESIGN.md S20).

    Returns a flat tuple:
      (hit1[N], hit2[N],
       l1_tags', l1_valid', l1_dirty', l1_lru',
       l2_tags', l2_valid', l2_dirty', l2_lru')
    """
    n = addrs.shape[0]
    ones = jnp.ones((n,), jnp.int32)
    hit1, _wb1, l1t, l1v, l1d, l1l = cache_probe(
        addrs, is_write, ones, t0, l1_tags, l1_valid, l1_dirty, l1_lru
    )
    mask2 = (hit1 == 0).astype(jnp.int32)
    hit2, _wb2, l2t, l2v, l2d, l2l = cache_probe(
        addrs, is_write, mask2, t0, l2_tags, l2_valid, l2_dirty, l2_lru
    )
    return (hit1, hit2, l1t, l1v, l1d, l1l, l2t, l2v, l2d, l2l)


def _curve_jnp(params, loads):
    """Differentiable twin of kernels.latency_model._lat_kernel.

    The Pallas kernel is used on the (grad-free) sweep path; the calib
    path needs jax.grad, so the identical formula is expressed in plain
    jnp here. test_latency_model.py asserts the two match to fp32.
    """
    base, pkt, media, bw, k = (params[i] for i in range(5))
    headroom = jax.nn.softplus(bw - loads) + 1e-3
    return base + 2.0 * pkt + media + k * loads / headroom


def calib_loss(params, loads, lat_meas):
    pred = _curve_jnp(params, loads)
    return jnp.mean((pred - lat_meas) ** 2)


def calib_step(params, loads, lat_meas, lr):
    """One sign-SGD step on the latency-model parameters.

    Sign-SGD (p <- p - lr * sign(grad)) instead of raw SGD: the loss
    landscape is badly scaled (the queueing term's gradient w.r.t. `bw`
    explodes near the knee and vanishes far from it, and raw SGD
    reliably diverges with bw -> 1e6). Sign steps are scale-free; the
    Rust fitter owns the per-parameter step sizes and their decay
    schedule, so convergence radius shrinks geometrically.

    Args:
      params:   f32[5] (base, pkt, media, bw, k).
      loads:    f32[CALIB_POINTS] offered loads (GB/s).
      lat_meas: f32[CALIB_POINTS] measured latencies (ns).
      lr:       f32[5] per-parameter step sizes (ns / GB/s units).

    Returns (params', loss[1]).
    """
    loss, grad = jax.value_and_grad(calib_loss)(params, loads, lat_meas)
    new_params = params - lr * jnp.sign(grad)
    return new_params, loss.reshape((1,))


def lat_bw_sweep(params, loads):
    """Latency curve over a load sweep, via the Pallas kernel."""
    return (latency_curve(params, loads),)
