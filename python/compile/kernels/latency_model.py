"""Layer-1 Pallas kernel: batched CXL latency-bandwidth curve evaluation.

CXLRAMSim exposes the latencies of CXL packetization/de-packetization, the
CXL buses and the device media at the configuration level so users can
calibrate them against real hardware (paper SIII-B.2, SV). The loaded
latency of a CXL.mem link is modeled as a smooth queueing curve:

    lat(load) = base + 2*pkt + media + k * load / softplus(bw - load)

where
    base   -- root-complex + IOBus traversal (ns)
    pkt    -- one packetization *or* de-packetization step (ns); the
              factor 2 accounts for M2S packetize + S2M de-packetize
    media  -- device-side media (DRAM on the expander) latency (ns)
    bw     -- link saturation bandwidth (GB/s)
    k      -- queueing sensitivity (ns * GB/s)

The kernel evaluates the curve for a batch of offered loads; it is the
inner loop of both the calibration fitter and the latency/bandwidth
characterisation bench (E4). Element-wise VPU work, tiled by BlockSpec.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Parameter vector layout (f32[5]):
P_BASE, P_PKT, P_MEDIA, P_BW, P_K = range(5)


def _lat_kernel(params_ref, loads_ref, out_ref):
    base = params_ref[P_BASE]
    pkt = params_ref[P_PKT]
    media = params_ref[P_MEDIA]
    bw = params_ref[P_BW]
    k = params_ref[P_K]
    loads = loads_ref[...]
    headroom = jax.nn.softplus(bw - loads) + 1e-3
    out_ref[...] = base + 2.0 * pkt + media + k * loads / headroom


def latency_curve(params, loads, *, interpret=True, block=256):
    """Evaluate the loaded-latency curve.

    Args:
      params: f32[5] -- (base, pkt, media, bw, k).
      loads:  f32[M] offered loads in GB/s; M must be a multiple of
              `block` (pad with zeros otherwise).

    Returns:
      f32[M] latency in ns.
    """
    m = loads.shape[0]
    if m % block != 0:
        raise ValueError(f"loads length {m} not a multiple of block {block}")
    grid = (m // block,)
    return pl.pallas_call(
        _lat_kernel,
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((5,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=interpret,
    )(params.astype(jnp.float32), loads.astype(jnp.float32))
