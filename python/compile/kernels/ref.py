"""Pure-python/numpy correctness oracles for the Pallas kernels.

These are deliberately written in the most obvious possible style (dicts
and loops) so they can serve as ground truth for both the Pallas kernels
(pytest/hypothesis, build time) and the Rust detailed cache model
(golden-trace files, see rust/tests/).
"""

from __future__ import annotations

import numpy as np

INT32_MIN_SENTINEL = -0x7FFFFFFF


def cache_probe_ref(addrs, is_write, mask, t0, tags, valid, dirty, lru):
    """Reference set-associative probe/update. Mirrors cache_probe().

    All arrays numpy int32; state arrays are copied, not mutated.
    Returns (hit, wb, tags, valid, dirty, lru).
    """
    tags = np.array(tags, dtype=np.int64).copy()
    valid = np.array(valid, dtype=np.int64).copy()
    dirty = np.array(dirty, dtype=np.int64).copy()
    lru = np.array(lru, dtype=np.int64).copy()
    num_sets, num_ways = tags.shape
    n = len(addrs)
    hit_out = np.full(n, -1, dtype=np.int64)
    wb_out = np.full(n, -1, dtype=np.int64)
    t0 = int(np.asarray(t0).reshape(-1)[0])

    for i in range(n):
        if mask[i] == 0:
            continue
        addr = int(addrs[i])
        s = addr % num_sets
        tag = addr // num_sets
        now = t0 + i

        hit_way = None
        for w in range(num_ways):
            if valid[s, w] == 1 and tags[s, w] == tag:
                hit_way = w
                break

        if hit_way is not None:
            hit_out[i] = 1
            lru[s, hit_way] = now
            if is_write[i]:
                dirty[s, hit_way] = 1
        else:
            hit_out[i] = 0
            # victim: first invalid way, else min-LRU (ties -> lowest way)
            eff = [
                lru[s, w] if valid[s, w] == 1 else INT32_MIN_SENTINEL
                for w in range(num_ways)
            ]
            victim = int(np.argmin(eff))
            if valid[s, victim] == 1 and dirty[s, victim] == 1:
                wb_out[i] = tags[s, victim] * num_sets + s
            tags[s, victim] = tag
            valid[s, victim] = 1
            dirty[s, victim] = 1 if is_write[i] else 0
            lru[s, victim] = now

    to32 = lambda a: a.astype(np.int32)  # noqa: E731
    return (to32(hit_out), to32(wb_out), to32(tags), to32(valid),
            to32(dirty), to32(lru))


def two_level_ref(addrs, is_write, t0, l1_state, l2_state):
    """Reference for the composed L1->L2 warming model (model.cache_warm).

    l1_state/l2_state: tuples (tags, valid, dirty, lru).
    Returns (hit1, hit2, l1_state', l2_state').
    L2 sees exactly the L1 misses (no writeback traffic -- documented
    simplification of the warming path, DESIGN.md S20).
    """
    n = len(addrs)
    ones = np.ones(n, dtype=np.int32)
    hit1, _, *l1p = cache_probe_ref(addrs, is_write, ones, t0, *l1_state)
    mask2 = (hit1 == 0).astype(np.int32)
    hit2, _, *l2p = cache_probe_ref(addrs, is_write, mask2, t0, *l2_state)
    return hit1, hit2, tuple(l1p), tuple(l2p)


def latency_curve_ref(params, loads):
    """Reference loaded-latency curve. Mirrors latency_curve()."""
    params = np.asarray(params, dtype=np.float64)
    loads = np.asarray(loads, dtype=np.float64)
    base, pkt, media, bw, k = params
    x = bw - loads
    # float64 softplus matching jax.nn.softplus, then the +1e-3 floor
    headroom = np.logaddexp(0.0, x) + 1e-3
    return (base + 2.0 * pkt + media + k * loads / headroom).astype(
        np.float32
    )


def calib_loss_ref(params, loads, lat_meas):
    """Reference MSE loss for the calibration objective."""
    pred = latency_curve_ref(params, loads).astype(np.float64)
    return float(np.mean((pred - np.asarray(lat_meas, np.float64)) ** 2))
