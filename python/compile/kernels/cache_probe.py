"""Layer-1 Pallas kernel: vectorised set-associative cache probe/update.

This is the compute hot-spot of CXLRAMSim's *functional fast-forward*
("cache warming") path: given a window of N memory accesses it probes and
updates one cache level's tag/LRU/dirty state and reports, per access,
hit/miss plus any dirty victim line.

Design notes (DESIGN.md §Hardware-Adaptation):
  * The tag state (sets x ways) is the VMEM-resident operand; for the
    default L2 geometry (1024 sets x 16 ways x 4 state words) it is
    256 KiB -- VMEM-resident on a real TPU. BlockSpec keeps the whole
    state in one block; the access stream is streamed through.
  * The per-access associative search is a masked vector compare across
    the ways dimension (VPU work, no MXU), so a window is processed with
    a sequential fori_loop over accesses but full vectorisation over ways.
  * The kernel MUST be lowered with interpret=True in this environment:
    the CPU PJRT plugin cannot execute Mosaic custom-calls.

State encoding (all int32):
  tags[s, w]   -- tag value stored in way w of set s
  valid[s, w]  -- 0/1
  dirty[s, w]  -- 0/1
  lru[s, w]    -- last-use timestamp; larger == more recently used

Per-access outputs (int32):
  hit[i]   -- 1 hit, 0 miss, -1 access skipped (mask[i] == 0)
  wb[i]    -- line address of a dirty victim evicted by access i, else -1

Addresses are *line* addresses (byte address >> log2(line)); int32 line
addresses cover a 128 GiB physical space at 64 B lines.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _probe_body(i, refs, num_sets):
    """One access: probe, update LRU/dirty, evict+install on miss."""
    (addr_ref, wr_ref, mask_ref, t0_ref,
     tags_ref, valid_ref, dirty_ref, lru_ref, hit_ref, wb_ref) = refs

    addr = addr_ref[i]
    is_wr = wr_ref[i]
    act = mask_ref[i]

    set_idx = jax.lax.rem(addr, num_sets)
    tag = jax.lax.div(addr, num_sets)
    now = t0_ref[0] + i  # monotonic recency stamp within the window

    row_tags = pl.load(tags_ref, (pl.dslice(set_idx, 1), slice(None)))[0]
    row_valid = pl.load(valid_ref, (pl.dslice(set_idx, 1), slice(None)))[0]
    row_dirty = pl.load(dirty_ref, (pl.dslice(set_idx, 1), slice(None)))[0]
    row_lru = pl.load(lru_ref, (pl.dslice(set_idx, 1), slice(None)))[0]

    hit_vec = (row_tags == tag) & (row_valid == 1)
    is_hit = jnp.any(hit_vec)

    # Victim selection: any invalid way first, else true-LRU (min stamp).
    # Invalid ways are forced to stamp INT32_MIN so argmin picks them.
    eff_lru = jnp.where(row_valid == 1, row_lru, jnp.int32(-0x7FFFFFFF))
    victim_way = jnp.argmin(eff_lru).astype(jnp.int32)
    hit_way = jnp.argmax(hit_vec).astype(jnp.int32)
    way = jnp.where(is_hit, hit_way, victim_way)
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, row_tags.shape, 0) == way
    )

    victim_valid = row_valid[victim_way] == 1
    victim_dirty = row_dirty[victim_way] == 1
    victim_line = row_tags[victim_way] * num_sets + set_idx
    wb_line = jnp.where(
        (~is_hit) & victim_valid & victim_dirty, victim_line, jnp.int32(-1)
    )

    new_tags = jnp.where(onehot, jnp.where(is_hit, row_tags, tag), row_tags)
    new_valid = jnp.where(onehot, jnp.int32(1), row_valid)
    # On a miss the installed line is dirty iff the access is a write
    # (write-allocate); on a write hit the way turns dirty.
    new_dirty = jnp.where(
        onehot,
        jnp.where(is_hit, row_dirty | is_wr, is_wr),
        row_dirty,
    )
    new_lru = jnp.where(onehot, now, row_lru)

    keep = act == 1
    sel = lambda n, o: jnp.where(keep, n, o)[None]  # noqa: E731
    pl.store(tags_ref, (pl.dslice(set_idx, 1), slice(None)),
             sel(new_tags, row_tags))
    pl.store(valid_ref, (pl.dslice(set_idx, 1), slice(None)),
             sel(new_valid, row_valid))
    pl.store(dirty_ref, (pl.dslice(set_idx, 1), slice(None)),
             sel(new_dirty, row_dirty))
    pl.store(lru_ref, (pl.dslice(set_idx, 1), slice(None)),
             sel(new_lru, row_lru))

    hit_out = jnp.where(keep, is_hit.astype(jnp.int32), jnp.int32(-1))
    wb_out = jnp.where(keep, wb_line, jnp.int32(-1))
    pl.store(hit_ref, (pl.dslice(i, 1),), hit_out[None])
    pl.store(wb_ref, (pl.dslice(i, 1),), wb_out[None])


def _cache_kernel(addr_ref, wr_ref, mask_ref, t0_ref,
                  tags_in, valid_in, dirty_in, lru_in,
                  hit_ref, wb_ref,
                  tags_ref, valid_ref, dirty_ref, lru_ref,
                  *, num_sets):
    # Copy state in -> out, then update in place on the outputs.
    tags_ref[...] = tags_in[...]
    valid_ref[...] = valid_in[...]
    dirty_ref[...] = dirty_in[...]
    lru_ref[...] = lru_in[...]

    n = addr_ref.shape[0]
    refs = (addr_ref, wr_ref, mask_ref, t0_ref,
            tags_ref, valid_ref, dirty_ref, lru_ref, hit_ref, wb_ref)

    # The refs are closed over, NOT threaded through the loop carry:
    # jax's scan/fori state-discharge supports refs as loop *consts*
    # only — a ref in the carry trips its discharge assertion.
    def body(i, carry):
        _probe_body(i, refs, num_sets=num_sets)
        return carry

    jax.lax.fori_loop(0, n, body, jnp.int32(0))


def cache_probe(addrs, is_write, mask, t0, tags, valid, dirty, lru,
                *, interpret=True):
    """Probe/update one cache level for a window of accesses.

    Args:
      addrs:    int32[N] line addresses.
      is_write: int32[N] 0/1.
      mask:     int32[N] 1 = process access, 0 = skip.
      t0:       int32[1] recency stamp base for this window.
      tags, valid, dirty, lru: int32[S, W] state.

    Returns:
      (hit[N], wb[N], tags', valid', dirty', lru') -- all int32.
    """
    n = addrs.shape[0]
    num_sets, num_ways = tags.shape
    i32 = jnp.int32
    out_shape = (
        jax.ShapeDtypeStruct((n,), i32),
        jax.ShapeDtypeStruct((n,), i32),
        jax.ShapeDtypeStruct((num_sets, num_ways), i32),
        jax.ShapeDtypeStruct((num_sets, num_ways), i32),
        jax.ShapeDtypeStruct((num_sets, num_ways), i32),
        jax.ShapeDtypeStruct((num_sets, num_ways), i32),
    )
    kern = functools.partial(_cache_kernel, num_sets=num_sets)
    return pl.pallas_call(kern, out_shape=out_shape, interpret=interpret)(
        addrs.astype(i32), is_write.astype(i32), mask.astype(i32),
        t0.astype(i32), tags, valid, dirty, lru,
    )
