from .cache_probe import cache_probe  # noqa: F401
from .latency_model import latency_curve  # noqa: F401
