"""AOT pipeline: lower the L2 graphs to HLO *text* artifacts.

HLO text (NOT ``lowered.compile().serialize()`` and NOT serialized
HloModuleProto) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 (the version behind the
published ``xla`` rust crate) rejects; the HLO text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_cache_warm():
    n = model.WINDOW
    l1 = _i32(model.L1_SETS, model.L1_WAYS)
    l2 = _i32(model.L2_SETS, model.L2_WAYS)
    return jax.jit(model.cache_warm).lower(
        _i32(n), _i32(n), _i32(1), l1, l1, l1, l1, l2, l2, l2, l2
    )


def lower_calib_step():
    m = model.CALIB_POINTS
    return jax.jit(model.calib_step).lower(_f32(5), _f32(m), _f32(m),
                                           _f32(5))


def lower_lat_bw_sweep():
    return jax.jit(model.lat_bw_sweep).lower(_f32(5),
                                             _f32(model.SWEEP_POINTS))


ARTIFACTS = {
    "cache_warm": lower_cache_warm,
    "calib_step": lower_calib_step,
    "lat_bw_sweep": lower_lat_bw_sweep,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="output directory for *.hlo.txt + manifest.json")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    entries = {}
    for name, lower in ARTIFACTS.items():
        text = to_hlo_text(lower())
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entries[name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")

    manifest = {
        "format": "hlo-text",
        "window": model.WINDOW,
        "l1_sets": model.L1_SETS,
        "l1_ways": model.L1_WAYS,
        "l2_sets": model.L2_SETS,
        "l2_ways": model.L2_WAYS,
        "calib_points": model.CALIB_POINTS,
        "sweep_points": model.SWEEP_POINTS,
        "artifacts": entries,
    }
    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
        f.write("\n")
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
