//! E5 — programming models over the expander (paper §IV): zNUMA with
//! explicit tiering / naive placement vs Flat memory mode, on the
//! KV-cache-shaped workload, plus a footprint-exceeds-DRAM case that
//! only works because the expander is onlined.

use cxlramsim::config::SimConfig;
use cxlramsim::coordinator::run_sweep;
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::util::bench::Table;
use cxlramsim::workloads::{Stream, StreamKernel, TieredKv};

#[derive(Clone, Copy, PartialEq)]
enum Scheme {
    ZnumaTiered,
    ZnumaAllCxl,
    Flat,
}

fn main() {
    let schemes = [
        (Scheme::ZnumaTiered, "znuma hot->DRAM cold->CXL"),
        (Scheme::ZnumaAllCxl, "znuma all->CXL"),
        (Scheme::Flat, "flat (first-touch spill)"),
    ];
    let points: Vec<Scheme> = schemes.iter().map(|(s, _)| *s).collect();
    let rows = run_sweep(points, 3, |s: Scheme| {
        let mut cfg = SimConfig::default();
        cfg.cores = 1;
        let model = if s == Scheme::Flat {
            ProgModel::Flat
        } else {
            ProgModel::Znuma
        };
        let mut m = Machine::new(cfg.clone()).unwrap();
        m.boot(model).unwrap();
        let mut kv = TieredKv::new(8192, 256, 40_000, cfg.seed);
        match s {
            Scheme::ZnumaTiered => {
                kv.hot_policy = MemPolicy::Bind { nodes: vec![0] };
                kv.cold_policy = MemPolicy::Bind { nodes: vec![1] };
            }
            Scheme::ZnumaAllCxl => {
                kv.hot_policy = MemPolicy::Bind { nodes: vec![1] };
                kv.cold_policy = MemPolicy::Bind { nodes: vec![1] };
            }
            Scheme::Flat => {
                kv.hot_policy = MemPolicy::Local { home: 0 };
                kv.cold_policy = MemPolicy::Local { home: 0 };
            }
        }
        let mut boxed: Vec<Box<dyn cxlramsim::workloads::Workload>> =
            vec![Box::new(kv)];
        m.attach_workloads(boxed.drain(..).collect(), &MemPolicy::Local { home: 0 })
            .unwrap();
        let s = m.run(None);
        (s.seconds * 1e3, s.bandwidth_gbps, s.dram_accesses, s.cxl_accesses)
    });

    let mut t = Table::new(
        "Programming models — tiered KV, 80% hot hits",
        &["scheme", "ms", "GB/s", "DRAM fills", "CXL fills"],
    );
    for ((_, label), (ms, bw, d, c)) in schemes.iter().zip(&rows) {
        t.row(&[
            label.to_string(),
            format!("{ms:.3}"),
            format!("{bw:.2}"),
            d.to_string(),
            c.to_string(),
        ]);
    }
    t.print();

    let tiered = rows[0];
    let all_cxl = rows[1];
    assert!(
        tiered.0 < all_cxl.0,
        "tiering must beat all-on-CXL ({:.2} vs {:.2} ms)",
        tiered.0,
        all_cxl.0
    );

    // --- capacity case: WSS > system DRAM requires the expander ----------
    let mut cfg = SimConfig::default();
    cfg.cores = 1;
    cfg.sys_mem_size = 64 << 20; // tiny DRAM
    cfg.cxl.mem_size = 1 << 30;
    let mut m = Machine::new(cfg.clone()).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    // 3 arrays x ~43 MiB > 64 MiB DRAM: needs CXL to fit.
    let wl = Stream::new(StreamKernel::Copy, (128 << 20) / 24, 1);
    m.attach_workloads(
        vec![Box::new(wl)],
        &MemPolicy::Local { home: 0 }, // spills DRAM -> CXL
    )
    .unwrap();
    let s = m.run(None);
    m.verify().expect("capacity-spill stream verification");
    assert!(
        s.cxl_accesses > 0,
        "footprint beyond DRAM must spill onto the expander"
    );
    println!(
        "\nprogmodel_znuma_flat: capacity case spilled {} fills to CXL \
         with functional verification OK",
        s.cxl_accesses
    );
}
