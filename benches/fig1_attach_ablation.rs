//! E3 — the Fig.-1 ablation: architecturally correct **IOBus attach**
//! (CXLRAMSim, Fig. 1B) vs the **membus attach** shortcut of
//! CXL-DMSim/SimCXL (Fig. 1A), identical in every other parameter.
//!
//! Expected shape: at low intensity the two roughly agree (the fixed
//! protocol costs dominate and the baseline folds them into a
//! constant), but under load the membus model *underestimates* latency
//! because it has no flit serialization, no credit back-pressure and no
//! IOBus occupancy — the modeling error the paper calls out.

use cxlramsim::config::{CxlAttach, SimConfig};
use cxlramsim::coordinator::run_sweep;
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::util::bench::Table;
use cxlramsim::workloads::{PointerChase, RandomAccess, Stream, StreamKernel, Workload};

#[derive(Clone, Copy, PartialEq)]
enum Wl {
    Chase,
    Stream,
    Random,
}

#[derive(Clone)]
struct Point {
    attach: CxlAttach,
    wl: Wl,
}

fn make_wl(wl: Wl, cfg: &SimConfig) -> Box<dyn Workload> {
    match wl {
        // Dependent loads: unloaded latency probe.
        Wl::Chase => Box::new(PointerChase::new(32 * 1024, 30_000, cfg.seed)),
        // Sequential bandwidth under load.
        Wl::Stream => {
            Box::new(Stream::for_wss(StreamKernel::Copy, cfg.l2.size, 8))
        }
        // Random loaded traffic with writes.
        Wl::Random => {
            Box::new(RandomAccess::new(16 << 20, 60_000, 0.3, cfg.seed))
        }
    }
}

fn main() {
    let mut points = Vec::new();
    for wl in [Wl::Chase, Wl::Stream, Wl::Random] {
        for attach in [CxlAttach::IoBus, CxlAttach::MemBus] {
            points.push(Point { attach, wl });
        }
    }
    let rows = run_sweep(points.clone(), 6, |p: Point| {
        let mut cfg = SimConfig::default();
        cfg.cores = 1;
        cfg.cxl.attach = p.attach;
        if p.wl == Wl::Chase {
            // Dependent loads are an *idle latency* probe only when the
            // core cannot overlap them.
            cfg.cpu_model = cxlramsim::config::CpuModel::InOrder;
        }
        let mut m = Machine::new(cfg.clone()).unwrap();
        m.boot(ProgModel::Znuma).unwrap();
        m.attach_workloads(
            vec![make_wl(p.wl, &cfg)],
            &MemPolicy::Bind { nodes: vec![1] }, // all traffic on CXL
        )
        .unwrap();
        let s = m.run(None);
        (s.seconds * 1e3, s.bandwidth_gbps, s.m2s_req + s.m2s_rwd,
         s.cxl_accesses)
    });

    let mut t = Table::new(
        "Fig. 1 ablation — IOBus (CXLRAMSim) vs membus (DMSim-style)",
        &["workload", "attach", "ms", "GB/s", "M2S pkts", "CXL fills"],
    );
    let name = |w: Wl| match w {
        Wl::Chase => "chase (idle lat)",
        Wl::Stream => "stream copy 8xL2",
        Wl::Random => "random 30% wr",
    };
    for (p, (ms, bw, pkts, fills)) in points.iter().zip(&rows) {
        t.row(&[
            name(p.wl).to_string(),
            match p.attach {
                CxlAttach::IoBus => "IOBus".into(),
                CxlAttach::MemBus => "membus".to_string(),
            },
            format!("{ms:.3}"),
            format!("{bw:.2}"),
            pkts.to_string(),
            fills.to_string(),
        ]);
    }
    t.print();

    // Shape assertions.
    let get = |wl: Wl, attach: CxlAttach| {
        points
            .iter()
            .zip(&rows)
            .find(|(p, _)| p.wl == wl && p.attach == attach)
            .map(|(_, r)| *r)
            .unwrap()
    };
    // 1. The baseline never emits CXL.mem packets.
    for wl in [Wl::Chase, Wl::Stream, Wl::Random] {
        assert_eq!(get(wl, CxlAttach::MemBus).2, 0);
        assert!(get(wl, CxlAttach::IoBus).2 > 0);
    }
    // 2. Idle latency (chase) roughly agrees: < 15% apart.
    let (io_ms, _, _, _) = get(Wl::Chase, CxlAttach::IoBus);
    let (mb_ms, _, _, _) = get(Wl::Chase, CxlAttach::MemBus);
    let idle_gap = (io_ms - mb_ms).abs() / mb_ms;
    assert!(idle_gap < 0.15, "idle gap {idle_gap:.3} too large");
    // 3. Under load the baseline is optimistic (higher bandwidth).
    let (_, io_bw, _, _) = get(Wl::Stream, CxlAttach::IoBus);
    let (_, mb_bw, _, _) = get(Wl::Stream, CxlAttach::MemBus);
    assert!(
        mb_bw >= io_bw,
        "membus attach must be optimistic under load \
         (membus {mb_bw:.2} vs iobus {io_bw:.2})"
    );
    println!(
        "\nfig1_attach_ablation: idle gap {:.1}%, loaded optimism {:.1}% — \
         the membus shortcut matches idle latency but hides loaded effects",
        idle_gap * 100.0,
        (mb_bw / io_bw - 1.0) * 100.0
    );
}
