//! E2 — regenerate Fig. 5: LLC (L2) miss rate for the STREAM
//! micro-benchmark under the Timing (in-order) and O3 CPU models, with
//! working sets of 2/4/6/8x the L2 size and OS page-interleave ratios
//! swept across DRAM:CXL = 100:0 .. 0:100 (paper §IV).
//!
//! Two prefetcher regimes are reported:
//!  * pf=off — the paper's gem5-classic-caches setting: at WSS >= 2xL2
//!    pure streaming defeats LRU entirely, so the LLC *demand* miss
//!    rate sits at ~1.0 independent of the interleave ratio; the ratio
//!    shows up purely as bandwidth (the CXL path is slower).
//!  * pf=on — with an L2 stride prefetcher the demand miss rate
//!    collapses and the latency interaction appears through prefetch
//!    timeliness (the cache-pollution/latency effect the abstract
//!    highlights), while the bandwidth ordering is preserved.

use cxlramsim::config::{CpuModel, SimConfig};
use cxlramsim::coordinator::run_sweep;
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::util::bench::Table;
use cxlramsim::workloads::{Stream, StreamKernel};

#[derive(Clone)]
struct Point {
    cpu: CpuModel,
    pf: bool,
    wss: u64,
    label: &'static str,
    weights: Vec<(u32, u32)>,
}

struct Row {
    cpu: &'static str,
    pf: bool,
    wss: u64,
    label: &'static str,
    llc_miss: f64,
    l1_miss: f64,
    bw: f64,
    cxl_share: f64,
}

fn main() {
    let quick = std::env::var("CXLRAMSIM_BENCH_QUICK").is_ok();
    let ratios: [(&'static str, Vec<(u32, u32)>); 5] = [
        ("100:0", vec![(0, 1)]),
        ("75:25", vec![(0, 3), (1, 1)]),
        ("50:50", vec![(0, 1), (1, 1)]),
        ("25:75", vec![(0, 1), (1, 3)]),
        ("0:100", vec![(1, 1)]),
    ];
    let wss_list: &[u64] = if quick { &[2, 8] } else { &[2, 4, 6, 8] };
    let mut points = Vec::new();
    for pf in [false, true] {
        for cpu in [CpuModel::InOrder, CpuModel::OutOfOrder] {
            for &wss in wss_list {
                for (label, w) in &ratios {
                    points.push(Point {
                        cpu,
                        pf,
                        wss,
                        label,
                        weights: w.clone(),
                    });
                }
            }
        }
    }
    let threads = std::thread::available_parallelism()
        .map(|n| n.get().min(10))
        .unwrap_or(4);
    let rows: Vec<Row> = run_sweep(points, threads, |p: Point| {
        let mut cfg = SimConfig::default();
        cfg.cpu_model = p.cpu;
        cfg.cores = 1;
        cfg.l2.prefetch = p.pf;
        let mut m = Machine::new(cfg.clone()).unwrap();
        m.boot(ProgModel::Znuma).unwrap();
        let wl = Stream::for_wss(StreamKernel::Triad, cfg.l2.size, p.wss);
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Interleave { weights: p.weights.clone() },
        )
        .unwrap();
        let s = m.run(None);
        m.verify().expect("stream verification");
        Row {
            cpu: match p.cpu {
                CpuModel::InOrder => "Timing",
                CpuModel::OutOfOrder => "O3",
            },
            pf: p.pf,
            wss: p.wss,
            label: p.label,
            llc_miss: s.l2_miss_rate,
            l1_miss: s.l1_miss_rate,
            bw: s.bandwidth_gbps,
            cxl_share: s.cxl_accesses as f64
                / (s.cxl_accesses + s.dram_accesses).max(1) as f64,
        }
    });

    let mut t = Table::new(
        "Fig. 5 — STREAM triad LLC miss rate (Timing + O3, pf off/on)",
        &[
            "cpu", "pf", "wss(xL2)", "DRAM:CXL", "LLC miss", "L1 miss",
            "GB/s", "CXL share",
        ],
    );
    let mut jsonl = String::new();
    for r in &rows {
        t.row(&[
            r.cpu.to_string(),
            if r.pf { "on" } else { "off" }.to_string(),
            r.wss.to_string(),
            r.label.to_string(),
            format!("{:.4}", r.llc_miss),
            format!("{:.4}", r.l1_miss),
            format!("{:.2}", r.bw),
            format!("{:.2}", r.cxl_share),
        ]);
        jsonl.push_str(&format!(
            "{{\"cpu\":\"{}\",\"pf\":{},\"wss\":{},\"ratio\":\"{}\",\
             \"llc_miss\":{:.4},\"l1_miss\":{:.4},\"gbps\":{:.3},\
             \"cxl_share\":{:.3}}}\n",
            r.cpu, r.pf, r.wss, r.label, r.llc_miss, r.l1_miss, r.bw,
            r.cxl_share
        ));
    }
    t.print();
    let _ = std::fs::create_dir_all("target/bench-results");
    let _ = std::fs::write("target/bench-results/fig5.jsonl", jsonl);

    // --- shape assertions (the paper's qualitative claims) -----------------
    let at = |cpu: &str, pf: bool, wss: u64, label: &str| {
        rows.iter()
            .find(|r| {
                r.cpu == cpu && r.pf == pf && r.wss == wss && r.label == label
            })
            .unwrap()
    };
    let wss_hi = *wss_list.last().unwrap();
    for cpu in ["Timing", "O3"] {
        for pf in [false, true] {
            // All-DRAM strictly outperforms all-CXL; ordering monotone.
            let bws: Vec<f64> = ratios
                .iter()
                .map(|(l, _)| at(cpu, pf, wss_hi, l).bw)
                .collect();
            for w in bws.windows(2) {
                assert!(
                    w[0] >= w[1] * 0.98,
                    "{cpu}/pf={pf}: bandwidth must degrade with CXL share \
                     ({bws:?})"
                );
            }
            assert!(
                bws[0] > bws[4] * 2.0,
                "{cpu}/pf={pf}: all-DRAM must clearly beat all-CXL ({bws:?})"
            );
        }
        // pf=off: capacity-dominated demand misses, ratio-independent.
        let m_dram = at(cpu, false, wss_hi, "100:0").llc_miss;
        let m_cxl = at(cpu, false, wss_hi, "0:100").llc_miss;
        assert!(m_dram > 0.95 && m_cxl > 0.95, "{cpu}: streaming at 8xL2 \
                 with no prefetch must defeat LRU ({m_dram}, {m_cxl})");
        // pf=on: stride prefetching collapses demand misses.
        let p_dram = at(cpu, true, wss_hi, "100:0").llc_miss;
        assert!(
            p_dram < 0.2,
            "{cpu}: prefetcher must cover streaming ({p_dram})"
        );
    }
    // CPU-model contrast (Fig. 5 plots both): the in-order core's one
    // outstanding access makes L1 unit-stride reuse visible (~12.5%
    // miss), while O3's run-ahead turns reuse into MSHR merges.
    let t_l1 = at("Timing", true, wss_hi, "50:50").l1_miss;
    let o_l1 = at("O3", true, wss_hi, "50:50").l1_miss;
    assert!(
        t_l1 < 0.3 && o_l1 > 0.5,
        "CPU models must differ in L1 behaviour (Timing {t_l1}, O3 {o_l1})"
    );
    println!("\nfig5_stream_missrate: shape assertions hold");
}
