//! E6 — the unmodified-guest boot flow, timed end-to-end:
//! BIOS build -> ACPI parse (incl. AML) -> PCIe enumeration (ECAM) ->
//! CXL driver bind (DVSEC walk + mailbox IDENTIFY + HDM commit) ->
//! cxl-cli create-region -> zNUMA node online.
//!
//! Asserts every stage's observable outcome and measures wall-clock for
//! the whole flow (this is simulator hosting cost, not simulated time).

use cxlramsim::config::SimConfig;
use cxlramsim::guestos::ProgModel;
use cxlramsim::system::Machine;
use cxlramsim::util::bench::BenchRunner;

fn main() {
    let mut r = BenchRunner::new("boot_online");

    // Timed: full machine construction + boot.
    r.bench("machine_new+boot", || {
        let mut m = Machine::new(SimConfig::default()).unwrap();
        m.boot(ProgModel::Znuma).unwrap();
        std::hint::black_box(&m.guest);
    });

    r.bench("machine_new_only", || {
        let m = Machine::new(SimConfig::default()).unwrap();
        std::hint::black_box(&m.bios);
    });

    // Verified: the flow's outcomes.
    let mut m = Machine::new(SimConfig::default()).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let g = m.guest.as_ref().unwrap();

    assert_eq!(g.acpi.cpu_apic_ids.len(), 4, "MADT CPUs");
    assert_eq!(g.acpi.chbs.len(), 1, "CEDT CHBS");
    assert_eq!(g.acpi.cfmws.len(), 1, "CEDT CFMWS");
    assert_eq!(g.pci_devs.len(), 3, "host bridge + root port + endpoint");
    let md = g.memdevs.first().expect("CXL memdev bound");
    assert_eq!(md.capacity, SimConfig::default().cxl.mem_size);
    assert_eq!(g.znuma_node(), Some(1), "zNUMA node onlined");
    assert!(!g.alloc.nodes[1].has_cpus, "node 1 is CPU-less");
    assert!(m.rc.routes(md.hpa_base), "RC routes the HDM window");
    assert!(
        m.fabric.devices[0].component.decoder_committed(0),
        "endpoint decoder committed"
    );
    assert!(
        m.hb_components[0].decoder_committed(0),
        "host-bridge decoder committed"
    );
    assert!(
        m.fabric.devices[0].mailbox.commands_executed >= 2,
        "IDENTIFY + health"
    );

    // Flat mode boots too.
    let mut mf = Machine::new(SimConfig::default()).unwrap();
    mf.boot(ProgModel::Flat).unwrap();
    assert!(mf.guest.as_ref().unwrap().znuma_node().is_none());

    r.finish();
    println!("\nboot_online: all boot-flow invariants verified");
}
