//! E4 — latency-bandwidth characterization + calibration (paper
//! §III-B.2/§V): fit the differentiable link model to three synthetic
//! vendor cards via the AOT fwd+grad artifact, then cross-check the
//! *simulator's own* loaded-latency curve against the fitted model.
//! Requires `make artifacts`.

use cxlramsim::calibrate::{hwref, Fitter};
use cxlramsim::config::SimConfig;
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::runtime::XlaRuntime;
use cxlramsim::system::Machine;
use cxlramsim::util::bench::Table;
use cxlramsim::workloads::RandomAccess;

fn main() {
    let Ok(rt) = XlaRuntime::load(std::path::Path::new("artifacts")) else {
        println!("calib_latency_bw: artifacts/ missing — run `make artifacts`");
        return;
    };
    let cfg = SimConfig::default();
    let fitter = Fitter::default();

    // --- per-vendor fits ----------------------------------------------------
    let mut t = Table::new(
        "Calibration: fit vs synthetic vendor silicon",
        &["card", "init loss", "final loss", "iters", "rms ns"],
    );
    for (i, card) in hwref::CARDS.iter().enumerate() {
        let loads =
            hwref::load_grid(rt.manifest.calib_points, card.sat_bw_gbps);
        let meas = hwref::measure(card, &loads, 0.02, 100 + i as u64);
        let r = fitter
            .fit(&rt, Fitter::seed_from(&cfg.cxl), &loads, &meas)
            .expect("fit");
        assert!(
            r.final_loss < r.initial_loss / 50.0,
            "{}: did not converge",
            card.name
        );
        t.row(&[
            card.name.to_string(),
            format!("{:.1}", r.initial_loss),
            format!("{:.3}", r.final_loss),
            r.iterations.to_string(),
            format!("{:.2}", r.rms_ns),
        ]);
    }
    t.print();

    // --- simulator loaded-latency curve (characterization series) ---------
    // Vary offered load by inserting compute gaps between random CXL
    // accesses; measure end-to-end CXL fill latency from the RC's
    // round-trip histogram.
    let mut t2 = Table::new(
        "Simulator loaded-latency (random reads on CXL, O3, 1 core)",
        &["gap cycles", "offered GB/s", "avg RT ns", "link util proxy"],
    );
    let mut series = Vec::new();
    for gap in [400u64, 200, 100, 50, 20, 0] {
        let mut c = cfg.clone();
        c.cores = 1;
        let mut m = Machine::new(c.clone()).unwrap();
        m.boot(ProgModel::Znuma).unwrap();
        let mut wl = RandomAccess::new(32 << 20, 30_000, 0.0, 7);
        wl.gap_cycles = gap;
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![1] },
        )
        .unwrap();
        let s = m.run(None);
        let rt_ns = m.rc.stats.round_trip.stats.mean() / 1000.0;
        let offered = s.bytes_moved as f64 / s.seconds / 1e9;
        series.push((offered, rt_ns));
        t2.row(&[
            gap.to_string(),
            format!("{offered:.2}"),
            format!("{rt_ns:.0}"),
            format!("{:.3}", s.cxl_accesses as f64 / s.seconds / 1e9),
        ]);
    }
    t2.print();

    // Shape: latency grows with offered load.
    let lo = series.first().unwrap();
    let hi = series.last().unwrap();
    assert!(hi.0 > lo.0, "offered load must rise as gaps shrink");
    assert!(
        hi.1 > lo.1,
        "loaded latency must exceed unloaded ({:.0} vs {:.0} ns)",
        hi.1,
        lo.1
    );
    println!(
        "\ncalib_latency_bw: unloaded {:.0} ns -> loaded {:.0} ns at \
         {:.1} GB/s offered",
        lo.1, hi.1, hi.0
    );
}
