//! E1 — regenerate paper Table I from the live config schema, and
//! verify the shipped default honors every row.

use cxlramsim::config::{CpuModel, SimConfig};
use cxlramsim::util::bench::Table;

fn main() {
    let cfg = SimConfig::default();
    let mut t = Table::new(
        "TABLE I — SIMULATION CONFIGURATION",
        &["Component", "Specification"],
    );
    for (k, v) in cfg.table1_rows() {
        t.row(&[k, v]);
    }
    t.print();

    // Assertions: the config system really exposes what the table says.
    assert!(CpuModel::parse("inorder").is_ok());
    assert!(CpuModel::parse("o3").is_ok());
    assert!(cfg.cores <= 4, "paper evaluates up to 4 cores");
    // "Configurable (Unbounded)": a 64 GiB system + 128 GiB expander
    // must validate.
    let big = SimConfig {
        sys_mem_size: 64 << 30,
        ..SimConfig::default()
    };
    big.validate().expect("64 GiB system memory");
    let mut huge = SimConfig::default();
    huge.cxl.mem_size = 128 << 30;
    huge.validate().expect("128 GiB CXL expander");
    println!("\ntable1_config: all Table-I claims verified against the schema");
}
