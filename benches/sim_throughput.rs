//! Simulator-hosting throughput: events/second on the standard
//! 4-device STREAM configuration — the number that tracks whether the
//! event loop is getting faster or slower across PRs.
//!
//! Non-gating: CI runs it with `CXLRAMSIM_BENCH_QUICK=1` and uploads
//! `BENCH_sim_throughput.json` (written to the repo root) as an
//! artifact, so the perf trajectory is recorded without failing builds
//! on noisy runners.
//!
//! Run: `cargo bench --bench sim_throughput`

use cxlramsim::config::SimConfig;
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::util::bench::BenchRunner;
use cxlramsim::workloads::{Stream, StreamKernel};

fn standard_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.cores = 4;
    cfg.sys_mem_size = 512 << 20;
    cfg.cxl.devices = 4;
    cfg.cxl.mem_size = 512 << 20;
    cfg
}

/// Build + boot the standard machine with 4 STREAM triad cores
/// attached, split across DRAM and the 4-way interleaved CXL window —
/// everything up to (but not including) the event loop.
fn build_attached() -> Machine {
    let cfg = standard_cfg();
    let mut m = Machine::new(cfg.clone()).expect("machine");
    m.boot(ProgModel::Znuma).expect("boot");
    let wls: Vec<Box<dyn cxlramsim::workloads::Workload>> = (0..4)
        .map(|_| {
            Box::new(Stream::for_wss(StreamKernel::Triad, cfg.l2.size, 4))
                as Box<dyn cxlramsim::workloads::Workload>
        })
        .collect();
    m.attach_workloads(
        wls,
        &MemPolicy::Interleave { weights: vec![(0, 1), (1, 1)] },
    )
    .expect("attach");
    m
}

/// One end-to-end iteration. Returns (events, ticks).
fn run_once() -> (u64, u64) {
    let s = build_attached().run(None);
    (s.events, s.ticks)
}

/// Measure ONLY the event loop (`Machine::run`): boot/attach happen
/// outside the timed region, so the headline metric tracks the loop
/// and not ACPI-table construction cost. Returns (events, ticks,
/// median loop ns over `samples` runs).
fn measure_loop(samples: usize) -> (u64, u64, f64) {
    let mut per_run = Vec::with_capacity(samples);
    let mut events = 0;
    let mut ticks = 0;
    for _ in 0..samples {
        let mut m = build_attached();
        let t = std::time::Instant::now();
        let s = m.run(None);
        per_run.push(t.elapsed().as_nanos() as f64);
        events = s.events;
        ticks = s.ticks;
    }
    per_run.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (events, ticks, per_run[per_run.len() / 2])
}

fn main() {
    let quick = std::env::var("CXLRAMSIM_BENCH_QUICK").is_ok();
    let mut r = BenchRunner::new("sim_throughput");

    // Event-loop-only timing: the perf-trajectory headline.
    let (events, ticks, loop_ns) = measure_loop(if quick { 3 } else { 7 });
    assert!(events > 0 && ticks > 0);
    let events_per_sec = events as f64 * 1e9 / loop_ns;
    let sim_ns = ticks as f64 / 1000.0; // ticks are ps
    println!(
        "sim_throughput: {events} events in {:.1} ms -> {:.0} events/s \
         (host/sim time ratio {:.0}x, loop only)",
        loop_ns / 1e6,
        events_per_sec,
        loop_ns / sim_ns
    );

    // End-to-end (new + boot + attach + run) for context.
    let s = r.bench("stream4x_4dev_end_to_end", || {
        std::hint::black_box(run_once());
    });
    r.finish();

    // The perf-trajectory artifact, at the repo root where the driver
    // (and CI artifact upload) expects BENCH_*.json files.
    let json = format!(
        "{{\"bench\":\"sim_throughput\",\"config\":\"stream-triad x4 \
         cores, 4 devices, 4-way interleave\",\"events\":{events},\
         \"sim_ticks\":{ticks},\"loop_median_ns\":{loop_ns:.1},\
         \"events_per_sec\":{events_per_sec:.1},\
         \"end_to_end_median_ns\":{:.1},\"end_to_end_p90_ns\":{:.1}}}\n",
        s.median_ns, s.p90_ns
    );
    if let Err(e) = std::fs::write("BENCH_sim_throughput.json", &json) {
        eprintln!("sim_throughput: could not write BENCH file: {e}");
    } else {
        println!("wrote BENCH_sim_throughput.json");
    }
}
