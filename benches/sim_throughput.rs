//! Simulator-hosting throughput: events/second on the standard
//! 4-device STREAM configuration — the number that tracks whether the
//! event loop is getting faster or slower across PRs — plus the
//! 16-host rack thread-scaling axis (`rack16`) and the fabric-heavy
//! commit-lane axis (`rack16_fabric`, threads x `[sim] commit_lanes`).
//!
//! Non-gating: CI runs it with `CXLRAMSIM_BENCH_QUICK=1` and uploads
//! `BENCH_sim_throughput.json` (written to the repo root) as an
//! artifact, so the perf trajectory is recorded without failing builds
//! on noisy runners.
//!
//! Run: `cargo bench --bench sim_throughput`

use cxlramsim::config::{CxlDevOverride, LdRef, SimConfig};
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::util::bench::BenchRunner;
use cxlramsim::workloads::{Stream, StreamKernel};

fn standard_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.cores = 4;
    cfg.sys_mem_size = 512 << 20;
    cfg.cxl.devices = 4;
    cfg.cxl.mem_size = 512 << 20;
    cfg
}

/// Build + boot the standard machine with 4 STREAM triad cores
/// attached, split across DRAM and the 4-way interleaved CXL window —
/// everything up to (but not including) the event loop.
fn build_attached() -> Machine {
    let cfg = standard_cfg();
    let mut m = Machine::new(cfg.clone()).expect("machine");
    m.boot(ProgModel::Znuma).expect("boot");
    let wls: Vec<Box<dyn cxlramsim::workloads::Workload>> = (0..4)
        .map(|_| {
            Box::new(Stream::for_wss(StreamKernel::Triad, cfg.l2.size, 4))
                as Box<dyn cxlramsim::workloads::Workload>
        })
        .collect();
    m.attach_workloads(
        wls,
        &MemPolicy::Interleave { weights: vec![(0, 1), (1, 1)] },
    )
    .expect("attach");
    m
}

/// One end-to-end iteration. Returns (events, ticks).
fn run_once() -> (u64, u64) {
    let s = build_attached().run(None);
    (s.events, s.ticks)
}

/// Measure ONLY the event loop (`Machine::run`): boot/attach happen
/// outside the timed region, so the headline metric tracks the loop
/// and not ACPI-table construction cost. Returns (events, ticks,
/// median loop ns over `samples` runs).
fn measure_loop(samples: usize) -> (u64, u64, f64) {
    let mut per_run = Vec::with_capacity(samples);
    let mut events = 0;
    let mut ticks = 0;
    for _ in 0..samples {
        let mut m = build_attached();
        let t = std::time::Instant::now();
        let s = m.run(None);
        per_run.push(t.elapsed().as_nanos() as f64);
        events = s.events;
        ticks = s.ticks;
    }
    per_run.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (events, ticks, per_run[per_run.len() / 2])
}

/// The 16-host rack from the parallel-determinism harness: four 4-LD
/// MLDs behind two switches, one LD (and one STREAM core) per host.
fn rack_cfg(threads: usize) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.hosts = 16;
    cfg.cores = 1;
    cfg.threads = threads;
    cfg.sys_mem_size = 128 << 20;
    cfg.cxl.devices = 4;
    cfg.cxl.mem_size = 1 << 30; // 4 x 256 MiB LD slices per device
    cfg.cxl.switches = 2;
    cfg.cxl.dev_overrides =
        vec![CxlDevOverride { lds: Some(4), ..Default::default() }; 4];
    cfg.host_lds = (0..16)
        .map(|h| vec![LdRef { dev: h / 4, ld: (h % 4) as u16 }])
        .collect();
    cfg
}

fn build_rack(threads: usize, n: u64) -> Machine {
    let mut m = Machine::new(rack_cfg(threads)).expect("rack machine");
    m.boot(ProgModel::Znuma).expect("rack boot");
    for h in 0..16 {
        let kernel = [
            StreamKernel::Copy,
            StreamKernel::Scale,
            StreamKernel::Add,
            StreamKernel::Triad,
        ][h % 4];
        m.attach_workloads_to(
            h,
            vec![Box::new(Stream::new(kernel, n, 1))],
            &MemPolicy::Interleave { weights: vec![(0, 1), (1, 1)] },
        )
        .expect("rack attach");
    }
    m
}

/// Median event-loop time for the 16-host rack at a thread count.
/// Returns (events, median loop ns).
fn measure_rack(threads: usize, n: u64, samples: usize) -> (u64, f64) {
    let mut per_run = Vec::with_capacity(samples);
    let mut events = 0;
    for _ in 0..samples {
        let mut m = build_rack(threads, n);
        let t = std::time::Instant::now();
        let s = m.run(None);
        per_run.push(t.elapsed().as_nanos() as f64);
        events = s.events;
    }
    per_run.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (events, per_run[per_run.len() / 2])
}

/// The fabric-heavy rack for the commit-lane axis: 16 hosts over eight
/// 2-LD devices behind two switches (two switch-credit-disjoint lane
/// groups), every host pinned all-CXL so the commit phase dominates.
fn rack_fabric_cfg(threads: usize, lanes: usize) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.hosts = 16;
    cfg.cores = 1;
    cfg.threads = threads;
    cfg.commit_lanes = lanes;
    cfg.sys_mem_size = 128 << 20;
    cfg.cxl.devices = 8;
    cfg.cxl.mem_size = 512 << 20; // 2 x 256 MiB LD slices per device
    cfg.cxl.switches = 2;
    cfg.cxl.dev_overrides =
        vec![CxlDevOverride { lds: Some(2), ..Default::default() }; 8];
    cfg.host_lds = (0..16)
        .map(|h| vec![LdRef { dev: h / 2, ld: (h % 2) as u16 }])
        .collect();
    cfg
}

fn build_rack_fabric(threads: usize, lanes: usize, n: u64) -> Machine {
    let mut m = Machine::new(rack_fabric_cfg(threads, lanes))
        .expect("rack_fabric machine");
    m.boot(ProgModel::Znuma).expect("rack_fabric boot");
    for h in 0..16 {
        let kernel = [StreamKernel::Copy, StreamKernel::Triad][h % 2];
        m.attach_workloads_to(
            h,
            vec![Box::new(Stream::new(kernel, n, 1))],
            // All-CXL: every access crosses the fabric.
            &MemPolicy::Bind { nodes: vec![1] },
        )
        .expect("rack_fabric attach");
    }
    m
}

/// Median event-loop time for the fabric-heavy rack at one
/// `(threads, commit_lanes)` point. Returns (events, median loop ns).
fn measure_rack_fabric(
    threads: usize,
    lanes: usize,
    n: u64,
    samples: usize,
) -> (u64, f64) {
    let mut per_run = Vec::with_capacity(samples);
    let mut events = 0;
    for _ in 0..samples {
        let mut m = build_rack_fabric(threads, lanes, n);
        let t = std::time::Instant::now();
        let s = m.run(None);
        per_run.push(t.elapsed().as_nanos() as f64);
        events = s.events;
    }
    per_run.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (events, per_run[per_run.len() / 2])
}

fn main() {
    let quick = std::env::var("CXLRAMSIM_BENCH_QUICK").is_ok();
    let mut r = BenchRunner::new("sim_throughput");

    // Event-loop-only timing: the perf-trajectory headline.
    let (events, ticks, loop_ns) = measure_loop(if quick { 3 } else { 7 });
    assert!(events > 0 && ticks > 0);
    let events_per_sec = events as f64 * 1e9 / loop_ns;
    let sim_ns = ticks as f64 / 1000.0; // ticks are ps
    println!(
        "sim_throughput: {events} events in {:.1} ms -> {:.0} events/s \
         (host/sim time ratio {:.0}x, loop only)",
        loop_ns / 1e6,
        events_per_sec,
        loop_ns / sim_ns
    );

    // The rack-scale scaling axis: 16 hosts, threads 1/2/4/8. Same
    // workload at every point (bit-identical results by the
    // determinism contract), so events/sec differences are pure
    // event-loop scaling.
    let rack_n: u64 = if quick { 8192 } else { 32768 };
    let rack_samples = if quick { 1 } else { 3 };
    let mut rack_points = Vec::new();
    let mut rack_serial_eps = 0.0;
    for threads in [1usize, 2, 4, 8] {
        let (ev, ns) = measure_rack(threads, rack_n, rack_samples);
        let eps = ev as f64 * 1e9 / ns;
        if threads == 1 {
            rack_serial_eps = eps;
        }
        println!(
            "sim_throughput[rack16 t={threads}]: {ev} events in \
             {:.1} ms -> {:.0} events/s ({:.2}x vs serial)",
            ns / 1e6,
            eps,
            eps / rack_serial_eps.max(1.0)
        );
        rack_points.push(format!(
            "{{\"threads\":{threads},\"events\":{ev},\
             \"loop_median_ns\":{ns:.1},\"events_per_sec\":{eps:.1}}}"
        ));
    }

    // The commit-lane axis: the fabric-heavy rack at threads 1/2/4/8,
    // each with the commit phase on the main thread (lanes = 1) and
    // sharded (lanes = auto). Identical results at every point; the
    // delta is pure commit-phase scaling.
    let ngroups = Machine::new(rack_fabric_cfg(1, 1))
        .expect("rack_fabric machine")
        .fabric
        .lane_ranges()
        .len();
    let mut fabric_points = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        let mut lane1_eps = 0.0;
        for lanes_req in [1usize, 0] {
            let (ev, ns) =
                measure_rack_fabric(threads, lanes_req, rack_n, rack_samples);
            let eps = ev as f64 * 1e9 / ns;
            // Resolve "auto" (0) the way the machine does, so the JSON
            // carries concrete lane counts.
            let lanes = if lanes_req == 0 { threads } else { lanes_req }
                .min(ngroups)
                .max(1);
            if lanes_req == 1 {
                lane1_eps = eps;
            }
            println!(
                "sim_throughput[rack16_fabric t={threads} l={lanes}]: \
                 {ev} events in {:.1} ms -> {:.0} events/s \
                 ({:.2}x vs lanes=1)",
                ns / 1e6,
                eps,
                eps / lane1_eps.max(1.0)
            );
            fabric_points.push(format!(
                "{{\"threads\":{threads},\"lanes\":{lanes},\
                 \"events\":{ev},\"loop_median_ns\":{ns:.1},\
                 \"events_per_sec\":{eps:.1}}}"
            ));
        }
    }

    // End-to-end (new + boot + attach + run) for context.
    let s = r.bench("stream4x_4dev_end_to_end", || {
        std::hint::black_box(run_once());
    });
    r.finish();

    // The perf-trajectory artifact, at the repo root where the driver
    // (and CI artifact upload) expects BENCH_*.json files.
    let json = format!(
        "{{\"bench\":\"sim_throughput\",\"config\":\"stream-triad x4 \
         cores, 4 devices, 4-way interleave\",\"events\":{events},\
         \"sim_ticks\":{ticks},\"loop_median_ns\":{loop_ns:.1},\
         \"events_per_sec\":{events_per_sec:.1},\
         \"end_to_end_median_ns\":{:.1},\"end_to_end_p90_ns\":{:.1},\
         \"rack16\":[{}],\"rack16_fabric\":[{}]}}\n",
        s.median_ns,
        s.p90_ns,
        rack_points.join(","),
        fabric_points.join(",")
    );
    if let Err(e) = std::fs::write("BENCH_sim_throughput.json", &json) {
        eprintln!("sim_throughput: could not write BENCH file: {e}");
    } else {
        println!("wrote BENCH_sim_throughput.json");
    }
}
