//! Trace-replay throughput + fidelity: the checked-in mini trace
//! (`benches/data/serve_mini.cxlt`, a hand-sized serving-shaped event
//! stream) replayed end to end, and a live `serve` run captured and
//! replayed in-process to confirm the replay path reproduces the live
//! machine stats bit-for-bit.
//!
//! Non-gating: CI runs it with `CXLRAMSIM_BENCH_QUICK=1` and uploads
//! `BENCH_serve_replay.json` (written to the repo root) as an
//! artifact alongside the sim_throughput trajectory.
//!
//! Run: `cargo bench --bench serve_replay`

use cxlramsim::config::SimConfig;
use cxlramsim::coordinator::attach_replay;
use cxlramsim::guestos::ProgModel;
use cxlramsim::system::Machine;
use cxlramsim::trace::{EventTrace, Recorder};
use cxlramsim::util::bench::BenchRunner;
use cxlramsim::workloads::{Serve, ServeConfig, Workload};

const MINI_TRACE: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/benches/data/serve_mini.cxlt");

/// Single host, DRAM + one expander: node 0 (DRAM) backs the trace's
/// `local` arena, node 1 (CXL) its `bind:1` arena.
fn replay_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.hosts = 1;
    cfg.cores = 1;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 256 << 20;
    cfg
}

fn replay_once(cfg: &SimConfig, t: &EventTrace) -> cxlramsim::stats::StatDump {
    let mut m = Machine::new(cfg.clone()).expect("machine");
    m.boot(ProgModel::Znuma).expect("boot");
    attach_replay(&mut m, t).expect("attach replay");
    m.run(None);
    m.dump_stats()
}

fn main() {
    let cfg = replay_cfg();
    let t = EventTrace::load(std::path::Path::new(MINI_TRACE))
        .expect("checked-in mini trace must load");
    println!(
        "serve_mini.cxlt: {} vmas, {} inits, {} events",
        t.vmas.len(),
        t.inits.len(),
        t.len()
    );

    // Fidelity first: two replays of the same trace are bit-identical
    // and stream every recorded op.
    let a = replay_once(&cfg, &t);
    let b = replay_once(&cfg, &t);
    assert_eq!(
        a.to_text(),
        b.to_text(),
        "trace replay must be bit-deterministic"
    );
    assert_eq!(
        a.get("trace.replay_ops"),
        Some(t.len() as f64),
        "every recorded op must be replayed"
    );

    // Then the throughput headline.
    let mut r = BenchRunner::new("serve_replay");
    let s = r.bench("mini_trace_end_to_end", || {
        std::hint::black_box(replay_once(&cfg, &t));
    });
    let events_per_sec = t.len() as f64 * 1e9 / s.median_ns;

    // Capture-side check: record a live serve run, replay the capture,
    // and require the machine-side stats to match exactly (the live
    // run additionally reports `serve.*`, the replay `trace.*`).
    let scfg = ServeConfig {
        users: 64,
        zipf_s: 1.1,
        requests: 60,
        kv_block: 256,
        context_blocks: 2,
        dram_slots: 8,
        cxl_slots: 16,
        decode_work: 16,
    };
    let rec = Recorder::new();
    let mut m = Machine::new(cfg.clone()).expect("machine");
    m.boot(ProgModel::Znuma).expect("boot");
    let (hot, cold) =
        m.hosts[0].guest.as_ref().expect("guest").alloc.tier_policies();
    let wl: Box<dyn Workload> = Box::new(Serve::new(scfg, hot.clone(), cold, 7));
    m.attach_workloads_to(0, vec![rec.wrap(0, 0, wl)], &hot)
        .expect("attach");
    m.run(None);
    let live = m.dump_stats();
    let captured = rec.take();
    let replayed = replay_once(&cfg, &captured);
    let machine_only = |d: &cxlramsim::stats::StatDump| -> Vec<(String, f64)> {
        d.entries
            .iter()
            .filter(|(k, _)| {
                !k.starts_with("serve.") && !k.starts_with("trace.")
            })
            .cloned()
            .collect()
    };
    assert_eq!(
        machine_only(&live),
        machine_only(&replayed),
        "replaying a captured serve run must reproduce the live stats"
    );
    println!(
        "capture fidelity: {} captured events replayed, machine stats \
         identical to the live run",
        captured.len()
    );
    r.finish();

    let json = format!(
        "{{\"bench\":\"serve_replay\",\"config\":\"serve_mini.cxlt, 1 \
         host, dram+cxl\",\"mini_events\":{},\"replay_median_ns\":{:.1},\
         \"replay_events_per_sec\":{events_per_sec:.1},\
         \"capture_replay_match\":1}}\n",
        t.len(),
        s.median_ns
    );
    if let Err(e) = std::fs::write("BENCH_serve_replay.json", &json) {
        eprintln!("serve_replay: could not write BENCH file: {e}");
    } else {
        println!("wrote BENCH_serve_replay.json");
    }
}
