//! E7 — hybrid fast-forward (our L1/L2 contribution, the gem5
//! functional-warming analogue): run STREAM's array-init phase either
//!   (a) fully event-driven ("detailed init"), or
//!   (b) through the AOT-compiled Pallas cache model, importing the
//!       warmed tag state into the detailed caches ("fast-forward"),
//! then measure the same timed region. Reports host wall-clock speedup
//! of the warming phase and the agreement of the measured-region stats.
//! Requires `make artifacts`.

use std::time::Instant;

use cxlramsim::config::SimConfig;
use cxlramsim::coordinator::{capture_init_trace, warm_machine, WithTimedInit};
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::runtime::XlaRuntime;
use cxlramsim::system::Machine;
use cxlramsim::util::bench::Table;
use cxlramsim::workloads::{Stream, StreamKernel};

fn main() {
    let Ok(rt) = XlaRuntime::load(std::path::Path::new("artifacts")) else {
        println!("warm_fastforward: artifacts/ missing — run `make artifacts`");
        return;
    };
    let mut cfg = SimConfig::default();
    cfg.cores = 1;
    let policy = MemPolicy::Interleave { weights: vec![(0, 1), (1, 1)] };
    let n = (cfg.l2.size * 2) / 24; // 2x L2 working set

    // --- (a) detailed init: everything event-driven -----------------------
    let t0 = Instant::now();
    let mut md = Machine::new(cfg.clone()).unwrap();
    md.boot(ProgModel::Znuma).unwrap();
    let wl = WithTimedInit::new(Stream::new(StreamKernel::Triad, n, 1));
    md.attach_workloads(vec![Box::new(wl)], &policy).unwrap();
    let sd = md.run(None);
    let detailed_wall = t0.elapsed();
    md.verify().expect("detailed verify");

    // --- (b) fast-forward: warm via the XLA artifact ----------------------
    let t1 = Instant::now();
    let mut mf = Machine::new(cfg.clone()).unwrap();
    mf.boot(ProgModel::Znuma).unwrap();
    let wl = Stream::new(StreamKernel::Triad, n, 1); // functional init
    mf.attach_workloads(vec![Box::new(wl)], &policy).unwrap();
    let trace = capture_init_trace(&mut mf, 0).unwrap();
    let warm = warm_machine(&mut mf, &rt, 0, &trace).unwrap();
    let warm_wall = t1.elapsed();
    let sf = mf.run(None);
    mf.verify().expect("fastforward verify");

    let mut t = Table::new(
        "Fast-forward warming vs detailed init (STREAM triad, 2xL2)",
        &["mode", "host ms (init)", "sim ms (total)", "LLC miss", "L2 occ"],
    );
    t.row(&[
        "detailed".into(),
        format!("{:.1}", detailed_wall.as_secs_f64() * 1e3),
        format!("{:.3}", sd.seconds * 1e3),
        format!("{:.4}", sd.l2_miss_rate),
        "-".into(),
    ]);
    t.row(&[
        "fast-forward".into(),
        format!("{:.1}", warm_wall.as_secs_f64() * 1e3),
        format!("{:.3}", sf.seconds * 1e3),
        format!("{:.4}", sf.l2_miss_rate),
        format!("{}/{}", warm.l2_occupancy, rt.manifest.l2_sets * rt.manifest.l2_ways),
    ]);
    t.print();

    // The warmed state must be meaningful: L2 substantially occupied.
    assert!(
        warm.l2_occupancy > rt.manifest.l2_sets * rt.manifest.l2_ways / 4,
        "warming left L2 mostly cold ({})",
        warm.l2_occupancy
    );
    // Warm start must lower the measured region's LLC miss rate vs the
    // detailed run seen end-to-end (which includes the init's cold
    // misses) — the whole point of warming.
    assert!(
        sf.l2_miss_rate <= sd.l2_miss_rate + 0.02,
        "fast-forwarded run should not miss more ({:.4} vs {:.4})",
        sf.l2_miss_rate,
        sd.l2_miss_rate
    );
    println!(
        "\nwarm_fastforward: warmed {} accesses in {} windows \
         ({} L1-hit, {} L2-hit), L2 occupancy {}",
        warm.accesses, warm.windows, warm.l1_hits, warm.l2_hits,
        warm.l2_occupancy
    );
}
