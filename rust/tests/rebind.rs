//! Runtime FM-driven LD re-binding: end-to-end hot remove/add through
//! the unmodified driver path, golden bitwise determinism with an
//! `[fm] events` schedule, the busy-node refusal path, and a property
//! test that unbind-then-bind round-trips ownership with no leaked
//! in-flight requests.

use cxlramsim::config::{
    CxlDevOverride, FmEventDef, FmOp, LdRef, SimConfig,
};
use cxlramsim::cxl::mailbox::UNBOUND;
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::util::prop::check;
use cxlramsim::util::rng::Rng;
use cxlramsim::workloads::{Stream, StreamKernel};

/// Two hosts over one switched 2-LD MLD; host 0 boots owning both LDs,
/// host 1 starts with an empty pool (its windows published offline).
fn rebind_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.hosts = 2;
    // One core per host: every core carries a workload, so the no-leak
    // checks (`done`, outstanding == 0) apply to all of them.
    cfg.cores = 1;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 512 << 20; // 2 x 256 MiB LD slices
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides =
        vec![CxlDevOverride { lds: Some(2), ..Default::default() }];
    cfg.host_lds = vec![
        vec![LdRef { dev: 0, ld: 0 }, LdRef { dev: 0, ld: 1 }],
        vec![],
    ];
    cfg.seed = 7;
    cfg
}

fn with_rebind_schedule(mut cfg: SimConfig) -> SimConfig {
    cfg.fm_events = vec![
        FmEventDef::parse("@20us unbind dev0.ld1").unwrap(),
        FmEventDef::parse("@25us bind dev0.ld1 host1").unwrap(),
    ];
    cfg.validate().unwrap();
    cfg
}

#[test]
fn hotplug_layout_reserves_spare_windows() {
    // With a schedule, each host's firmware publishes BOTH windows;
    // the non-owner keeps them offline as its hot-add pool.
    let mut m =
        Machine::new(with_rebind_schedule(rebind_cfg())).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let g0 = m.hosts[0].guest.as_ref().unwrap();
    assert_eq!(g0.memdevs.len(), 2, "host 0 owns both LDs");
    assert!(g0.spares.is_empty());
    assert_eq!(g0.cxl_nodes, vec![1, 2]);
    let g1 = m.hosts[1].guest.as_ref().unwrap();
    assert!(g1.memdevs.is_empty(), "host 1 owns nothing at boot");
    assert_eq!(g1.spares.len(), 2, "both windows reserved for hot-plug");
    assert!(g1.cxl_nodes.is_empty());
    // The spare nodes exist (SRAT hotplug domains) but are offline.
    assert!(!g1.alloc.nodes[1].online && !g1.alloc.nodes[2].online);
    // Without a schedule, the legacy layout publishes nothing to the
    // non-owner.
    let mut m = Machine::new(rebind_cfg()).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let g1 = m.hosts[1].guest.as_ref().unwrap();
    assert!(g1.memdevs.is_empty() && g1.spares.is_empty());
}

fn attach_rebind_workloads(m: &mut Machine) {
    // Host 0 streams on its first LD's node; node 2 stays idle so the
    // hot-remove finds it free.
    let wl0 = Stream::new(StreamKernel::Copy, 8192, 1);
    m.attach_workloads_to(
        0,
        vec![Box::new(wl0)],
        &MemPolicy::Bind { nodes: vec![1] },
    )
    .unwrap();
    // Host 1 prefers the node that onlines mid-run: DRAM fallback
    // before the hot-add, CXL after.
    let wl1 = Stream::new(StreamKernel::Triad, 32768, 1);
    m.attach_workloads_to(
        1,
        vec![Box::new(wl1)],
        &MemPolicy::Preferred { node: 2 },
    )
    .unwrap();
}

#[test]
fn runtime_rebind_moves_ld_between_running_hosts() {
    let mut m =
        Machine::new(with_rebind_schedule(rebind_cfg())).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    assert_eq!(
        m.fabric.devices[0].mailbox.state.ld_owner,
        vec![0, 0],
        "boot binding: host 0 holds both LDs"
    );
    attach_rebind_workloads(&mut m);
    let s = m.run(None);
    assert!(s.ticks > 0);
    m.verify().unwrap();

    // Ownership moved through the mailbox.
    assert_eq!(m.fabric.devices[0].mailbox.state.ld_owner, vec![0, 1]);

    // Host 0 shrank: LD 1's window is gone from guest and routing.
    let g0 = m.hosts[0].guest.as_ref().unwrap();
    assert_eq!(g0.memdevs.len(), 1);
    assert_eq!(g0.memdevs[0].ld, 0);
    assert_eq!(g0.spares.len(), 1, "released window became a spare");
    assert!(!g0.alloc.nodes[2].online, "node 2 offlined on host 0");
    assert!(g0
        .boot_log
        .iter()
        .any(|l| l.contains("memory hot-remove")));

    // Host 1 grew: LD 1 bound, node onlined, pages landed on it.
    let g1 = m.hosts[1].guest.as_ref().unwrap();
    assert_eq!(g1.memdevs.len(), 1);
    assert_eq!(g1.memdevs[0].ld, 1);
    assert_eq!(g1.spares.len(), 1, "LD 0's window is still foreign");
    assert!(g1.alloc.nodes[2].online, "node 2 onlined on host 1");
    assert!(g1.boot_log.iter().any(|l| l.contains("memory hot-add")));

    let d = m.dump_stats();
    assert!(
        d.get("cxl.dev0.ld1.host1_reads").unwrap_or(0.0) > 0.0,
        "host 1's workload must observe the new capacity mid-run"
    );
    assert_eq!(d.get("cxl.dev0.ld1.rebinds"), Some(1.0));
    assert_eq!(d.get("cxl.dev0.ld0.rebinds"), Some(0.0));
    assert_eq!(d.get("host0.sys.mem_offline_events"), Some(1.0));
    assert_eq!(d.get("host0.sys.mem_online_events"), Some(0.0));
    assert_eq!(d.get("host1.sys.mem_online_events"), Some(1.0));
    assert_eq!(d.get("host0.sys.mem_offline_refused"), Some(0.0));

    // No leaked requests anywhere.
    for h in 0..2 {
        for (i, c) in m.hosts[h].cores.iter().enumerate() {
            assert!(c.done, "host {h} core {i} never finished");
            assert_eq!(c.outstanding(), 0, "host {h} core {i} leaked");
        }
    }
}

#[test]
fn rebind_runs_are_bitwise_deterministic() {
    let go = || {
        let mut m =
            Machine::new(with_rebind_schedule(rebind_cfg())).unwrap();
        m.boot(ProgModel::Znuma).unwrap();
        attach_rebind_workloads(&mut m);
        let s = m.run(None);
        m.verify().unwrap();
        (s.ticks, s.events, s.cxl_accesses, m.dump_stats().to_text())
    };
    let a = go();
    let b = go();
    assert_eq!(a.0, b.0, "ticks diverged");
    assert_eq!(a.1, b.1, "event counts diverged");
    assert_eq!(a.2, b.2, "cxl accesses diverged");
    assert_eq!(a.3, b.3, "full stat dump diverged");
    assert!(a.3.contains("cxl.dev0.ld1.rebinds"));
}

#[test]
fn busy_node_refuses_hot_remove_and_keeps_ownership() {
    // Host 0's workload lives ON the departing LD's node: the guest
    // must refuse the offline (pages in use, no-migration model), the
    // LD stays bound and the dependent bind fails harmlessly.
    let mut cfg = rebind_cfg();
    cfg.fm_events = vec![
        FmEventDef::parse("@20us unbind dev0.ld1").unwrap(),
        FmEventDef::parse("@25us bind dev0.ld1 host1").unwrap(),
    ];
    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let wl0 = Stream::new(StreamKernel::Triad, 16384, 1);
    m.attach_workloads_to(
        0,
        vec![Box::new(wl0)],
        &MemPolicy::Bind { nodes: vec![2] }, // node 2 = LD 1's window
    )
    .unwrap();
    let s = m.run(None);
    assert!(s.ticks > 0);
    m.verify().unwrap();
    // Ownership unchanged; the workload was never disturbed.
    assert_eq!(m.fabric.devices[0].mailbox.state.ld_owner, vec![0, 0]);
    let d = m.dump_stats();
    assert_eq!(d.get("host0.sys.mem_offline_refused"), Some(1.0));
    assert_eq!(d.get("host0.sys.mem_offline_events"), Some(0.0));
    assert_eq!(d.get("cxl.dev0.ld1.rebinds"), Some(0.0));
    let g0 = m.hosts[0].guest.as_ref().unwrap();
    assert!(g0.alloc.nodes[2].online, "refused node must stay online");
    assert_eq!(g0.memdevs.len(), 2);
}

/// Unbind-then-bind round-trips LD ownership under random schedules,
/// with no leaked in-flight requests: after the run the device's owner
/// table equals a replay of the schedule, re-bind counters match, and
/// every core retired every request it issued.
#[test]
fn prop_unbind_bind_roundtrip_no_leaked_requests() {
    check(
        "fm-rebind-roundtrip",
        12,
        |r: &mut Rng| {
            let cycles = r.range(1, 4); // 1..=3 re-bind cycles
            let mut t_ns = 5_000 + r.below(20_000);
            let mut evs: Vec<(u64, u64)> = Vec::new(); // (t_ns, target)
            for _ in 0..cycles {
                let target = r.below(2);
                evs.push((t_ns, target));
                t_ns += 2_000 + r.below(30_000);
            }
            evs
        },
        |evs| {
            if evs.is_empty() {
                return Ok(()); // shrinker artifact: nothing to test
            }
            let mut cfg = rebind_cfg();
            // Each cycle: unbind dev0.ld1 from whoever holds it, then
            // bind it to the cycle's target host 1 us later.
            for &(t_ns, target) in evs {
                cfg.fm_events.push(FmEventDef {
                    at_ns: t_ns as f64,
                    op: FmOp::Unbind { ld: LdRef { dev: 0, ld: 1 } },
                });
                cfg.fm_events.push(FmEventDef {
                    at_ns: (t_ns + 1_000) as f64,
                    op: FmOp::Bind {
                        ld: LdRef { dev: 0, ld: 1 },
                        host: target as usize,
                    },
                });
            }
            // Generated inputs are valid by construction; the shrinker
            // may produce overlapping times that no longer replay —
            // those are vacuously fine, not property failures.
            if cfg.validate().is_err() {
                return Ok(());
            }
            let expected_owner = evs.last().unwrap().1 as u16;

            let mut m = Machine::new(cfg).map_err(|e| e.to_string())?;
            m.boot(ProgModel::Znuma).map_err(|e| e.to_string())?;
            // Traffic avoids the re-bound LD so every remove is clean:
            // host 0 on its LD-0 node, host 1 on DRAM.
            let wl0 = Stream::new(StreamKernel::Copy, 4096, 1);
            m.attach_workloads_to(
                0,
                vec![Box::new(wl0)],
                &MemPolicy::Bind { nodes: vec![1] },
            )
            .map_err(|e| e.to_string())?;
            let wl1 = Stream::new(StreamKernel::Copy, 4096, 1);
            m.attach_workloads_to(
                1,
                vec![Box::new(wl1)],
                &MemPolicy::Bind { nodes: vec![0] },
            )
            .map_err(|e| e.to_string())?;
            m.run(None);
            m.verify()?;

            let owners =
                &m.fabric.devices[0].mailbox.state.ld_owner;
            if owners[0] != 0 {
                return Err(format!("ld0 moved: {owners:?}"));
            }
            if owners[1] == UNBOUND || owners[1] != expected_owner {
                return Err(format!(
                    "ld1 owner {:?} != expected {expected_owner}",
                    owners[1]
                ));
            }
            let d = m.dump_stats();
            let cycles = evs.len() as f64;
            if d.get("cxl.dev0.ld1.rebinds") != Some(cycles) {
                return Err("rebind counter mismatch".into());
            }
            let offline = d
                .get("host0.sys.mem_offline_events")
                .unwrap_or(0.0)
                + d.get("host1.sys.mem_offline_events").unwrap_or(0.0);
            let online = d
                .get("host0.sys.mem_online_events")
                .unwrap_or(0.0)
                + d.get("host1.sys.mem_online_events").unwrap_or(0.0);
            if offline != cycles || online != cycles {
                return Err(format!(
                    "hot-plug event counts {offline}/{online} != \
                     {cycles}"
                ));
            }
            for h in 0..2 {
                for (i, c) in m.hosts[h].cores.iter().enumerate() {
                    if !c.done || c.outstanding() != 0 {
                        return Err(format!(
                            "host {h} core {i} leaked requests"
                        ));
                    }
                    let issued =
                        c.stats.loads.get() + c.stats.stores.get();
                    if issued != c.stats.mem_latency.count() {
                        return Err(format!(
                            "host {h} core {i}: {issued} issued vs {} \
                             completed",
                            c.stats.mem_latency.count()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}
