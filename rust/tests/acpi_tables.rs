//! ACPI publication integration tests (in the spirit of aero's
//! `machine_acpi_publication`): read RSDP/XSDT/CEDT/SRAT straight out
//! of guest physical memory after machine construction and verify
//! signatures, header lengths and checksums for 1-, 2- and 4-device
//! configurations. Nothing here uses the builder's return values beyond
//! the fixed RSDP scan region — everything is discovered from bytes,
//! like a real kernel.

use cxlramsim::bios::layout;
use cxlramsim::config::SimConfig;
use cxlramsim::mem::PhysMem;
use cxlramsim::system::Machine;

fn checksum_ok(bytes: &[u8]) -> bool {
    bytes.iter().fold(0u8, |a, b| a.wrapping_add(*b)) == 0
}

/// Scan the BIOS window for the RSDP, validating both checksums.
fn find_rsdp(mem: &PhysMem) -> u64 {
    let base = layout::RSDP_ADDR & !0xFFFF;
    for off in (0..0x2_0000u64).step_by(16) {
        let mut sig = [0u8; 8];
        mem.read(base + off, &mut sig);
        if &sig != b"RSD PTR " {
            continue;
        }
        let addr = base + off;
        let mut rsdp = vec![0u8; 36];
        mem.read(addr, &mut rsdp);
        assert!(checksum_ok(&rsdp[..20]), "RSDP v1 checksum");
        assert!(checksum_ok(&rsdp), "RSDP extended checksum");
        return addr;
    }
    panic!("RSDP not found in BIOS scan window");
}

/// Read one SDT: signature, length sanity, checksum.
fn read_sdt(mem: &PhysMem, addr: u64) -> (String, Vec<u8>) {
    let len = mem.read_u32(addr + 4) as usize;
    assert!((36..1 << 20).contains(&len), "SDT length {len} at {addr:#x}");
    let mut t = vec![0u8; len];
    mem.read(addr, &mut t);
    assert!(
        checksum_ok(&t),
        "checksum failed for {:?} at {addr:#x}",
        &t[0..4]
    );
    (String::from_utf8_lossy(&t[0..4]).into_owned(), t)
}

fn machine(devices: usize) -> Machine {
    let mut cfg = SimConfig::default();
    cfg.cxl.devices = devices;
    cfg.cxl.mem_size = 512 << 20;
    cfg.sys_mem_size = 512 << 20;
    Machine::new(cfg).unwrap()
}

fn walk(devices: usize) {
    let m = machine(devices);
    let rsdp_addr = find_rsdp(&m.mem);
    let mut rsdp = vec![0u8; 36];
    m.mem.read(rsdp_addr, &mut rsdp);
    let xsdt_addr = u64::from_le_bytes(rsdp[24..32].try_into().unwrap());
    let (sig, xsdt) = read_sdt(&m.mem, xsdt_addr);
    assert_eq!(sig, "XSDT");

    let mut seen = Vec::new();
    let mut srat = None;
    let mut cedt = None;
    for chunk in xsdt[36..].chunks_exact(8) {
        let addr = u64::from_le_bytes(chunk.try_into().unwrap());
        let (sig, table) = read_sdt(&m.mem, addr);
        match sig.as_str() {
            "SRAT" => srat = Some(table.clone()),
            "CEDT" => cedt = Some(table.clone()),
            _ => {}
        }
        seen.push(sig);
    }
    for want in ["FACP", "APIC", "MCFG", "SRAT", "CEDT", "HMAT"] {
        assert!(seen.contains(&want.to_string()), "missing {want}: {seen:?}");
    }

    // CEDT: one CHBS per device, ENIW matches the auto interleave.
    let cedt = cedt.unwrap();
    let mut i = 36;
    let mut chbs = 0;
    let mut cfmws = 0;
    while i + 4 <= cedt.len() {
        let len = u16::from_le_bytes(cedt[i + 2..i + 4].try_into().unwrap())
            as usize;
        assert!(len >= 4 && i + len <= cedt.len(), "CEDT record length");
        match cedt[i] {
            0 => {
                assert_eq!(len, 32, "CHBS record length");
                chbs += 1;
            }
            1 => {
                let eniw = cedt[i + 24] as usize;
                assert_eq!(1 << eniw, devices, "full-width auto interleave");
                assert_eq!(len, 36 + 4 * devices, "CFMWS record length");
                cfmws += 1;
            }
            _ => panic!("unknown CEDT record {}", cedt[i]),
        }
        i += len;
    }
    assert_eq!(chbs, devices);
    assert_eq!(cfmws, 1, "power-of-two counts form one interleave set");

    // SRAT: processor entries + DRAM domain + one hotplug CXL domain.
    let srat = srat.unwrap();
    let mut i = 36 + 12;
    let mut mem_domains = Vec::new();
    while i + 2 <= srat.len() {
        let len = srat[i + 1] as usize;
        assert!(len >= 2 && i + len <= srat.len());
        if srat[i] == 1 {
            let dom = u32::from_le_bytes(
                srat[i + 2..i + 6].try_into().unwrap(),
            );
            let flags = u32::from_le_bytes(
                srat[i + 28..i + 32].try_into().unwrap(),
            );
            mem_domains.push((dom, flags));
        }
        i += len;
    }
    assert_eq!(mem_domains.len(), 2);
    assert_eq!(mem_domains[0], (0, 1), "DRAM domain enabled");
    assert_eq!(mem_domains[1].0, 1, "CXL set domain");
    assert_eq!(mem_domains[1].1 & 0b11, 0b11, "enabled + hotplug");
}

#[test]
fn acpi_tables_valid_one_device() {
    walk(1);
}

#[test]
fn acpi_tables_valid_two_devices() {
    walk(2);
}

#[test]
fn acpi_tables_valid_four_devices() {
    walk(4);
}

#[test]
fn acpi_tables_valid_after_boot_too() {
    // Booting must not corrupt the published tables (the guest only
    // reads them; decoders live in MMIO, not in the ACPI pool).
    let mut m = machine(2);
    m.boot(cxlramsim::guestos::ProgModel::Znuma).unwrap();
    let rsdp_addr = find_rsdp(&m.mem);
    let mut rsdp = vec![0u8; 36];
    m.mem.read(rsdp_addr, &mut rsdp);
    let xsdt_addr = u64::from_le_bytes(rsdp[24..32].try_into().unwrap());
    let (sig, xsdt) = read_sdt(&m.mem, xsdt_addr);
    assert_eq!(sig, "XSDT");
    for chunk in xsdt[36..].chunks_exact(8) {
        let addr = u64::from_le_bytes(chunk.try_into().unwrap());
        read_sdt(&m.mem, addr); // signature + checksum assertions inside
    }
}
