//! ACPI publication integration tests (in the spirit of aero's
//! `machine_acpi_publication`): read RSDP/XSDT/CEDT/SRAT straight out
//! of guest physical memory after machine construction and verify
//! signatures, header lengths and checksums for 1-, 2- and 4-device
//! configurations. Nothing here uses the builder's return values beyond
//! the fixed RSDP scan region — everything is discovered from bytes,
//! like a real kernel.

use cxlramsim::bios::layout;
use cxlramsim::config::SimConfig;
use cxlramsim::mem::PhysMem;
use cxlramsim::system::Machine;

fn checksum_ok(bytes: &[u8]) -> bool {
    bytes.iter().fold(0u8, |a, b| a.wrapping_add(*b)) == 0
}

/// Scan the BIOS window for the RSDP, validating both checksums.
fn find_rsdp(mem: &PhysMem) -> u64 {
    let base = layout::RSDP_ADDR & !0xFFFF;
    for off in (0..0x2_0000u64).step_by(16) {
        let mut sig = [0u8; 8];
        mem.read(base + off, &mut sig);
        if &sig != b"RSD PTR " {
            continue;
        }
        let addr = base + off;
        let mut rsdp = vec![0u8; 36];
        mem.read(addr, &mut rsdp);
        assert!(checksum_ok(&rsdp[..20]), "RSDP v1 checksum");
        assert!(checksum_ok(&rsdp), "RSDP extended checksum");
        return addr;
    }
    panic!("RSDP not found in BIOS scan window");
}

/// Read one SDT: signature, length sanity, checksum.
fn read_sdt(mem: &PhysMem, addr: u64) -> (String, Vec<u8>) {
    let len = mem.read_u32(addr + 4) as usize;
    assert!((36..1 << 20).contains(&len), "SDT length {len} at {addr:#x}");
    let mut t = vec![0u8; len];
    mem.read(addr, &mut t);
    assert!(
        checksum_ok(&t),
        "checksum failed for {:?} at {addr:#x}",
        &t[0..4]
    );
    (String::from_utf8_lossy(&t[0..4]).into_owned(), t)
}

fn machine(devices: usize) -> Machine {
    let mut cfg = SimConfig::default();
    cfg.cxl.devices = devices;
    cfg.cxl.mem_size = 512 << 20;
    cfg.sys_mem_size = 512 << 20;
    Machine::new(cfg).unwrap()
}

fn walk(devices: usize) {
    let m = machine(devices);
    let rsdp_addr = find_rsdp(&m.mem);
    let mut rsdp = vec![0u8; 36];
    m.mem.read(rsdp_addr, &mut rsdp);
    let xsdt_addr = u64::from_le_bytes(rsdp[24..32].try_into().unwrap());
    let (sig, xsdt) = read_sdt(&m.mem, xsdt_addr);
    assert_eq!(sig, "XSDT");

    let mut seen = Vec::new();
    let mut srat = None;
    let mut cedt = None;
    for chunk in xsdt[36..].chunks_exact(8) {
        let addr = u64::from_le_bytes(chunk.try_into().unwrap());
        let (sig, table) = read_sdt(&m.mem, addr);
        match sig.as_str() {
            "SRAT" => srat = Some(table.clone()),
            "CEDT" => cedt = Some(table.clone()),
            _ => {}
        }
        seen.push(sig);
    }
    for want in ["FACP", "APIC", "MCFG", "SRAT", "CEDT", "HMAT"] {
        assert!(seen.contains(&want.to_string()), "missing {want}: {seen:?}");
    }

    // CEDT: one CHBS per device, ENIW matches the auto interleave.
    let cedt = cedt.unwrap();
    let mut i = 36;
    let mut chbs = 0;
    let mut cfmws = 0;
    while i + 4 <= cedt.len() {
        let len = u16::from_le_bytes(cedt[i + 2..i + 4].try_into().unwrap())
            as usize;
        assert!(len >= 4 && i + len <= cedt.len(), "CEDT record length");
        match cedt[i] {
            0 => {
                assert_eq!(len, 32, "CHBS record length");
                chbs += 1;
            }
            1 => {
                let eniw = cedt[i + 24] as usize;
                assert_eq!(1 << eniw, devices, "full-width auto interleave");
                assert_eq!(len, 36 + 4 * devices, "CFMWS record length");
                cfmws += 1;
            }
            _ => panic!("unknown CEDT record {}", cedt[i]),
        }
        i += len;
    }
    assert_eq!(chbs, devices);
    assert_eq!(cfmws, 1, "power-of-two counts form one interleave set");

    // SRAT: processor entries + DRAM domain + one hotplug CXL domain.
    let srat = srat.unwrap();
    let mut i = 36 + 12;
    let mut mem_domains = Vec::new();
    while i + 2 <= srat.len() {
        let len = srat[i + 1] as usize;
        assert!(len >= 2 && i + len <= srat.len());
        if srat[i] == 1 {
            let dom = u32::from_le_bytes(
                srat[i + 2..i + 6].try_into().unwrap(),
            );
            let flags = u32::from_le_bytes(
                srat[i + 28..i + 32].try_into().unwrap(),
            );
            mem_domains.push((dom, flags));
        }
        i += len;
    }
    assert_eq!(mem_domains.len(), 2);
    assert_eq!(mem_domains[0], (0, 1), "DRAM domain enabled");
    assert_eq!(mem_domains[1].0, 1, "CXL set domain");
    assert_eq!(mem_domains[1].1 & 0b11, 0b11, "enabled + hotplug");
}

#[test]
fn acpi_tables_valid_one_device() {
    walk(1);
}

#[test]
fn acpi_tables_valid_two_devices() {
    walk(2);
}

#[test]
fn acpi_tables_valid_four_devices() {
    walk(4);
}

/// Parse the CEDT into (CHBS count, per-CFMWS target lists) and the
/// SRAT into (domain, flags) memory entries — shared by the switched
/// and MLD walks below.
fn cedt_srat(m: &Machine) -> (usize, Vec<Vec<u32>>, Vec<(u32, u32)>) {
    let rsdp_addr = find_rsdp(&m.mem);
    let mut rsdp = vec![0u8; 36];
    m.mem.read(rsdp_addr, &mut rsdp);
    let xsdt_addr = u64::from_le_bytes(rsdp[24..32].try_into().unwrap());
    let (_, xsdt) = read_sdt(&m.mem, xsdt_addr);
    let mut chbs = 0usize;
    let mut cfmws_targets = Vec::new();
    let mut mem_domains = Vec::new();
    for chunk in xsdt[36..].chunks_exact(8) {
        let addr = u64::from_le_bytes(chunk.try_into().unwrap());
        let (sig, t) = read_sdt(&m.mem, addr);
        if sig == "CEDT" {
            let mut i = 36;
            while i + 4 <= t.len() {
                let len = u16::from_le_bytes(
                    t[i + 2..i + 4].try_into().unwrap(),
                ) as usize;
                match t[i] {
                    0 => chbs += 1,
                    1 => {
                        let eniw = t[i + 24] as usize;
                        let targets: Vec<u32> = (0..1usize << eniw)
                            .map(|k| {
                                u32::from_le_bytes(
                                    t[i + 36 + 4 * k..i + 40 + 4 * k]
                                        .try_into()
                                        .unwrap(),
                                )
                            })
                            .collect();
                        cfmws_targets.push(targets);
                    }
                    _ => panic!("unknown CEDT record {}", t[i]),
                }
                i += len;
            }
        }
        if sig == "SRAT" {
            let mut i = 36 + 12;
            while i + 2 <= t.len() {
                let len = t[i + 1] as usize;
                if t[i] == 1 {
                    mem_domains.push((
                        u32::from_le_bytes(
                            t[i + 2..i + 6].try_into().unwrap(),
                        ),
                        u32::from_le_bytes(
                            t[i + 28..i + 32].try_into().unwrap(),
                        ),
                    ));
                }
                i += len;
            }
        }
    }
    (chbs, cfmws_targets, mem_domains)
}

#[test]
fn acpi_tables_switched_one_bridge_four_windows() {
    // 1 switch x 4 endpoints: one root port / CHBS, four 1-way CFMWS
    // windows all targeting it, and four hotplug SRAT domains.
    let mut cfg = SimConfig::default();
    cfg.cxl.devices = 4;
    cfg.cxl.switches = 1;
    cfg.cxl.mem_size = 512 << 20;
    cfg.sys_mem_size = 512 << 20;
    let m = Machine::new(cfg).unwrap();
    let (chbs, cfmws, mem_domains) = cedt_srat(&m);
    assert_eq!(chbs, 1, "one host bridge for the switch's root port");
    assert_eq!(cfmws.len(), 4, "one window per endpoint");
    for t in &cfmws {
        assert_eq!(t, &vec![7u32], "every window targets bridge UID 7");
    }
    assert_eq!(mem_domains.len(), 5, "DRAM + 4 zNUMA domains");
    for (dom, flags) in &mem_domains[1..] {
        assert!(*dom >= 1 && *dom <= 4);
        assert_eq!(flags & 0b11, 0b11, "enabled + hotplug");
    }
}

#[test]
fn acpi_tables_two_way_window_behind_one_switch() {
    // PR-3 lifts the switched 1-way restriction: a 2-way interleave set
    // under ONE switch publishes a single CFMWS whose two target slots
    // both name that switch's host bridge, one hotplug SRAT domain, and
    // boots into one interleaved zNUMA node covering both endpoints.
    let mut cfg = SimConfig::default();
    cfg.cxl.devices = 2;
    cfg.cxl.switches = 1;
    cfg.cxl.interleave_ways = 2;
    cfg.cxl.mem_size = 512 << 20;
    cfg.sys_mem_size = 512 << 20;
    let mut m = Machine::new(cfg).unwrap();
    let (chbs, cfmws, mem_domains) = cedt_srat(&m);
    assert_eq!(chbs, 1, "one host bridge for the switch's root port");
    assert_eq!(cfmws.len(), 1, "one window for the whole set");
    assert_eq!(
        cfmws[0],
        vec![7u32, 7u32],
        "both target slots name bridge UID 7"
    );
    assert_eq!(mem_domains.len(), 2, "DRAM + one interleaved domain");
    assert_eq!(mem_domains[1].1 & 0b11, 0b11, "enabled + hotplug");

    // The unmodified guest walk consumes it: one node, both devices.
    m.boot(cxlramsim::guestos::ProgModel::Znuma).unwrap();
    let g = m.guest.as_ref().unwrap();
    assert_eq!(g.cxl_nodes, vec![1]);
    assert_eq!(g.alloc.nodes[1].size, 1 << 30, "2 x 512 MiB combined");
    assert_eq!(g.memdevs.len(), 2);
    assert_eq!(g.memdevs[0].window_ways, 2);
    assert_eq!(
        (g.memdevs[0].position, g.memdevs[1].position),
        (0, 1),
        "slots claimed in BDF order"
    );
    assert_eq!(g.memdevs[0].hpa_base, g.memdevs[1].hpa_base);
}

#[test]
fn acpi_tables_mld_per_ld_windows() {
    // One MLD with lds = 2: two CFMWS windows targeting the same
    // bridge, two hotplug SRAT domains.
    let mut cfg = SimConfig::default();
    cfg.cxl.interleave_ways = 1;
    cfg.cxl.mem_size = 512 << 20;
    cfg.sys_mem_size = 512 << 20;
    cfg.cxl.dev_overrides = vec![cxlramsim::config::CxlDevOverride {
        lds: Some(2),
        ..Default::default()
    }];
    let m = Machine::new(cfg).unwrap();
    let (chbs, cfmws, mem_domains) = cedt_srat(&m);
    assert_eq!(chbs, 1);
    assert_eq!(cfmws.len(), 2, "one window per logical device");
    assert_eq!(cfmws[0], cfmws[1], "both slices target the same bridge");
    assert_eq!(mem_domains.len(), 3, "DRAM + one domain per LD");
}

#[test]
fn switched_boot_discovers_two_level_hierarchy() {
    // The guest's flat scan must see the root port -> upstream bridge
    // -> downstream bridge chain above every endpoint (depth 3), and
    // online one zNUMA node per endpoint.
    let mut cfg = SimConfig::default();
    cfg.cxl.devices = 4;
    cfg.cxl.switches = 1;
    cfg.cxl.mem_size = 512 << 20;
    cfg.sys_mem_size = 512 << 20;
    let mut m = Machine::new(cfg).unwrap();
    m.boot(cxlramsim::guestos::ProgModel::Znuma).unwrap();
    let g = m.guest.as_ref().unwrap();
    // 1 HB + 1 RP + 1 USP + 4 DSP + 4 EP.
    assert_eq!(g.pci_devs.len(), 11);
    let eps: Vec<_> = g
        .pci_devs
        .iter()
        .filter(|d| d.class[0] == 0x05 && d.class[1] == 0x02)
        .collect();
    assert_eq!(eps.len(), 4);
    for ep in &eps {
        let depth = g
            .pci_devs
            .iter()
            .filter(|b| {
                b.is_bridge
                    && ep.bdf.bus >= b.secondary_bus
                    && ep.bdf.bus <= b.subordinate_bus
            })
            .count();
        assert_eq!(depth, 3, "RP + USP + DSP above endpoint {}", ep.bdf);
    }
    assert_eq!(g.cxl_nodes, vec![1, 2, 3, 4]);
    assert_eq!(g.memdevs.len(), 4);
    assert!(g.memdevs.iter().all(|md| md.hb_uid == 7));
}

#[test]
fn acpi_tables_valid_after_boot_too() {
    // Booting must not corrupt the published tables (the guest only
    // reads them; decoders live in MMIO, not in the ACPI pool).
    let mut m = machine(2);
    m.boot(cxlramsim::guestos::ProgModel::Znuma).unwrap();
    let rsdp_addr = find_rsdp(&m.mem);
    let mut rsdp = vec![0u8; 36];
    m.mem.read(rsdp_addr, &mut rsdp);
    let xsdt_addr = u64::from_le_bytes(rsdp[24..32].try_into().unwrap());
    let (sig, xsdt) = read_sdt(&m.mem, xsdt_addr);
    assert_eq!(sig, "XSDT");
    for chunk in xsdt[36..].chunks_exact(8) {
        let addr = u64::from_le_bytes(chunk.try_into().unwrap());
        read_sdt(&m.mem, addr); // signature + checksum assertions inside
    }
}
