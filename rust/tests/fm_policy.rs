//! Telemetry-driven FM policies (`[fm] policy`): closed-loop elastic
//! pooling with ZERO hand-written `[fm] events`. The FM samples
//! per-host/per-LD load each epoch and moves logical devices toward
//! demand through the same quiesce → doorbell → hot-remove/add flow the
//! scripted path uses — bit-deterministically.

use cxlramsim::config::{
    CxlDevOverride, FmPolicyConfig, FmPolicyKind, LdRef, SimConfig,
};
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::workloads::{Stream, StreamKernel};

/// Two hosts over one switched 2-LD MLD, host 0 booting with both LDs
/// — the rebind.rs topology, but with a policy instead of a schedule.
fn policy_cfg(kind: FmPolicyKind) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.hosts = 2;
    cfg.cores = 1;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 512 << 20; // 2 x 256 MiB LD slices
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides =
        vec![CxlDevOverride { lds: Some(2), ..Default::default() }];
    cfg.host_lds = vec![
        vec![LdRef { dev: 0, ld: 0 }, LdRef { dev: 0, ld: 1 }],
        vec![],
    ];
    cfg.fm_policy = Some(FmPolicyConfig::new(kind));
    cfg.seed = 7;
    cfg.validate().unwrap();
    cfg
}

/// Host 0 streams on its first LD (node 1, keeping LD 1 idle); host 1
/// prefers the offline node 2, so every page it touches spills to DRAM
/// — the capacity-pressure signal the policy feeds on.
fn attach_capacity_workloads(m: &mut Machine) {
    let wl0 = Stream::new(StreamKernel::Copy, 8192, 1);
    m.attach_workloads_to(
        0,
        vec![Box::new(wl0)],
        &MemPolicy::Bind { nodes: vec![1] },
    )
    .unwrap();
    let wl1 = Stream::new(StreamKernel::Triad, 32768, 1);
    m.attach_workloads_to(
        1,
        vec![Box::new(wl1)],
        &MemPolicy::Preferred { node: 2 },
    )
    .unwrap();
}

#[test]
fn capacity_policy_migrates_idle_ld_toward_pressure() {
    let cfg = policy_cfg(FmPolicyKind::CapacityRebalance);
    assert!(cfg.fm_events.is_empty(), "no hand-written schedule");
    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    assert_eq!(
        m.fabric.devices[0].mailbox.state.ld_owner,
        vec![0, 0],
        "boot binding: host 0 holds both LDs"
    );
    attach_capacity_workloads(&mut m);
    let s = m.run(None);
    assert!(s.ticks > 0);
    m.verify().unwrap();

    // The FM decided the move on its own: LD 1 now belongs to host 1.
    assert_eq!(m.fabric.devices[0].mailbox.state.ld_owner, vec![0, 1]);

    let d = m.dump_stats();
    assert!(d.get("fm.policy.epochs").unwrap() > 0.0);
    assert_eq!(d.get("fm.policy.decisions"), Some(1.0));
    assert_eq!(d.get("fm.policy.refusals"), Some(0.0));
    assert!(
        d.get("fm.policy.holds").unwrap() >= 1.0,
        "min-residency must hold the first pressured epochs back"
    );
    assert_eq!(d.get("cxl.dev0.ld1.rebinds"), Some(1.0));
    assert_eq!(d.get("cxl.dev0.ld0.rebinds"), Some(0.0));
    assert_eq!(d.get("host0.sys.mem_offline_events"), Some(1.0));
    assert_eq!(d.get("host1.sys.mem_online_events"), Some(1.0));
    assert!(
        d.get("host1.sys.numa_fallback_allocs").unwrap() > 0.0,
        "the pressure signal itself must be dumped"
    );
    assert!(
        d.get("cxl.dev0.ld1.host1_reads").unwrap_or(0.0) > 0.0,
        "host 1 must observe its new capacity mid-run"
    );

    // The decision trail went through the Event Log: the losing guest
    // drained a POLICY_DECISION record ahead of the unbind request.
    let g0 = m.hosts[0].guest.as_ref().unwrap();
    assert!(g0.boot_log.iter().any(|l| l.contains("fm policy decision")));
    assert!(g0.boot_log.iter().any(|l| l.contains("memory hot-remove")));
    let g1 = m.hosts[1].guest.as_ref().unwrap();
    assert!(g1.boot_log.iter().any(|l| l.contains("memory hot-add")));

    // No leaked requests anywhere.
    for h in 0..2 {
        for (i, c) in m.hosts[h].cores.iter().enumerate() {
            assert!(c.done, "host {h} core {i} never finished");
            assert_eq!(c.outstanding(), 0, "host {h} core {i} leaked");
        }
    }
}

#[test]
fn policy_runs_are_bitwise_deterministic() {
    // Golden determinism for the closed loop, mirroring
    // rebind_runs_are_bitwise_deterministic: same config twice ->
    // identical tick count, event count and FULL stat dump.
    let go = || {
        let mut m =
            Machine::new(policy_cfg(FmPolicyKind::CapacityRebalance))
                .unwrap();
        m.boot(ProgModel::Znuma).unwrap();
        attach_capacity_workloads(&mut m);
        let s = m.run(None);
        m.verify().unwrap();
        (s.ticks, s.events, s.cxl_accesses, m.dump_stats().to_text())
    };
    let a = go();
    let b = go();
    assert_eq!(a.0, b.0, "ticks diverged");
    assert_eq!(a.1, b.1, "event counts diverged");
    assert_eq!(a.2, b.2, "cxl accesses diverged");
    assert_eq!(a.3, b.3, "full stat dump diverged");
    assert!(a.3.contains("fm.policy.decisions"));
}

#[test]
fn bandwidth_policy_spreads_idle_capacity_toward_traffic() {
    // Each host boots with one LD; host 0 runs on DRAM (its LD 0 stays
    // idle) while host 1 hammers its LD 1 — the bandwidth-fairness
    // policy hands host 0's idle LD to the traffic-heavy host.
    let mut cfg = policy_cfg(FmPolicyKind::BandwidthFairness);
    cfg.host_lds = vec![
        vec![LdRef { dev: 0, ld: 0 }],
        vec![LdRef { dev: 0, ld: 1 }],
    ];
    cfg.validate().unwrap();
    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let wl0 = Stream::new(StreamKernel::Copy, 8192, 1);
    m.attach_workloads_to(
        0,
        vec![Box::new(wl0)],
        &MemPolicy::Bind { nodes: vec![0] }, // DRAM only
    )
    .unwrap();
    let wl1 = Stream::new(StreamKernel::Triad, 32768, 1);
    m.attach_workloads_to(
        1,
        vec![Box::new(wl1)],
        &MemPolicy::Bind { nodes: vec![2] }, // its own LD 1 node
    )
    .unwrap();
    let s = m.run(None);
    assert!(s.ticks > 0);
    m.verify().unwrap();
    assert_eq!(
        m.fabric.devices[0].mailbox.state.ld_owner,
        vec![1, 1],
        "idle LD 0 must migrate to the traffic-heavy host"
    );
    let d = m.dump_stats();
    assert_eq!(d.get("cxl.dev0.ld0.rebinds"), Some(1.0));
    assert!(d.get("fm.policy.decisions").unwrap() >= 1.0);
}

#[test]
fn busy_lds_are_never_stolen() {
    // Host 1 is pressured, but host 0 has pages resident on BOTH its
    // LD nodes: the policy must leave ownership alone (idle-LD filter)
    // rather than trigger guest refusals.
    let mut m =
        Machine::new(policy_cfg(FmPolicyKind::CapacityRebalance))
            .unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    // Host 0 interleaves over BOTH its LD nodes — nothing is idle.
    let wl0 = Stream::new(StreamKernel::Copy, 16384, 1);
    m.attach_workloads_to(
        0,
        vec![Box::new(wl0)],
        &MemPolicy::Interleave { weights: vec![(1, 1), (2, 1)] },
    )
    .unwrap();
    let wl1 = Stream::new(StreamKernel::Triad, 16384, 1);
    m.attach_workloads_to(
        1,
        vec![Box::new(wl1)],
        &MemPolicy::Preferred { node: 2 },
    )
    .unwrap();
    m.run(None);
    m.verify().unwrap();
    assert_eq!(
        m.fabric.devices[0].mailbox.state.ld_owner,
        vec![0, 0],
        "busy LDs must stay put"
    );
    let d = m.dump_stats();
    assert_eq!(d.get("fm.policy.decisions"), Some(0.0));
    assert_eq!(d.get("fm.policy.refusals"), Some(0.0));
    assert_eq!(d.get("host0.sys.mem_offline_events"), Some(0.0));
}
