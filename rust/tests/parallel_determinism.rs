//! The bit-determinism harness for the rack-scale parallel event loop.
//!
//! The contract under test (see `system::machine` module docs): for any
//! topology, workload mix, and FM schedule/policy, a run at `[sim]
//! threads = N` is *byte-identical* to the serial `threads = 1` run —
//! same `RunSummary`, same full stat dump, same event count. The epoch
//! structure is a function of queue state alone, never of thread
//! scheduling, so the only thing threads may change is wall-clock time.
//!
//! Alongside the equivalence property this file pins down the safety
//! side of the conservative horizon:
//!
//! * the lookahead is never zero and never exceeds the true minimum
//!   round-trip to any LD the host can reach;
//! * an FM re-bind that changes a host's reachable set re-derives the
//!   horizon (gaining a lower-latency path shrinks it);
//! * a deliberately *wrong* (too large) horizon is caught by the
//!   debug assertion ("scheduling into the past") rather than silently
//!   corrupting event order — on the serial path and through the
//!   worker-panic relay of the threaded path alike.

use cxlramsim::config::{
    CxlDevOverride, FmEventDef, FmPolicyConfig, FmPolicyKind, LdRef,
    SimConfig,
};
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::sim::{ns_to_ticks, Tick};
use cxlramsim::system::{Machine, RunSummary};
use cxlramsim::util::rng::Rng;
use cxlramsim::workloads::{
    PointerChase, RandomAccess, Serve, ServeConfig, Stream, StreamKernel,
    TieredKv, Workload,
};

/// Boot `cfg` at the given thread count, attach workloads, run to
/// completion and return the full stat dump plus the run summary.
fn run_once(
    cfg: &SimConfig,
    threads: usize,
    attach: impl Fn(&mut Machine),
) -> (String, RunSummary) {
    run_with(cfg, threads, cfg.commit_lanes, attach)
}

/// Like [`run_once`] but also pinning `[sim] commit_lanes` (`0` =
/// auto), for the `(threads, lanes)` invariance sweeps.
fn run_with(
    cfg: &SimConfig,
    threads: usize,
    lanes: usize,
    attach: impl Fn(&mut Machine),
) -> (String, RunSummary) {
    let mut cfg = cfg.clone();
    cfg.threads = threads;
    cfg.commit_lanes = lanes;
    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    attach(&mut m);
    let s = m.run(None);
    m.verify().unwrap();
    (m.dump_stats().to_text(), s)
}

/// Field-by-field `RunSummary` equality (floats compared by bits: the
/// contract is bit-determinism, not approximate agreement).
fn assert_summaries_eq(a: &RunSummary, b: &RunSummary, what: &str) {
    assert_eq!(a.ticks, b.ticks, "{what}: ticks");
    assert_eq!(a.events, b.events, "{what}: events");
    assert_eq!(a.bytes_moved, b.bytes_moved, "{what}: bytes_moved");
    assert_eq!(a.dram_accesses, b.dram_accesses, "{what}: dram_accesses");
    assert_eq!(a.cxl_accesses, b.cxl_accesses, "{what}: cxl_accesses");
    assert_eq!(a.cxl_dev_fills, b.cxl_dev_fills, "{what}: cxl_dev_fills");
    assert_eq!(a.m2s_req, b.m2s_req, "{what}: m2s_req");
    assert_eq!(a.m2s_rwd, b.m2s_rwd, "{what}: m2s_rwd");
    assert_eq!(a.s2m_ndr, b.s2m_ndr, "{what}: s2m_ndr");
    assert_eq!(a.s2m_drs, b.s2m_drs, "{what}: s2m_drs");
    assert_eq!(a.s2m_bisnp, b.s2m_bisnp, "{what}: s2m_bisnp");
    assert_eq!(a.m2s_birsp, b.m2s_birsp, "{what}: m2s_birsp");
    for (x, y, f) in [
        (a.seconds, b.seconds, "seconds"),
        (a.bandwidth_gbps, b.bandwidth_gbps, "bandwidth_gbps"),
        (a.l1_miss_rate, b.l1_miss_rate, "l1_miss_rate"),
        (a.l2_miss_rate, b.l2_miss_rate, "l2_miss_rate"),
        (a.avg_lat_dram_ns, b.avg_lat_dram_ns, "avg_lat_dram_ns"),
        (a.avg_lat_cxl_ns, b.avg_lat_cxl_ns, "avg_lat_cxl_ns"),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: {f}");
    }
}

/// FNV-1a over the stat dump text — the in-process "golden digest".
fn fnv64(text: &str) -> u64 {
    text.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x1_0000_01b3)
    })
}

/// True minimum round-trip through the fabric for device `dev` — the
/// upper bound a host's lookahead horizon must never exceed.
fn dev_round_trip_ticks(cfg: &SimConfig, dev: usize) -> Tick {
    ns_to_ticks(
        2.0 * (cfg.cxl.pkt_lat_ns + cfg.cxl.depkt_lat_ns)
            + 2.0 * cfg.cxl.path_lat_ns(dev),
    )
}

// ---------------------------------------------------------------------------
// Property sweep: random topologies x workload mixes, threads 1 vs N.
// ---------------------------------------------------------------------------

#[test]
fn random_topologies_are_thread_count_invariant() {
    let mut rng = Rng::new(0x7ac4_5ca1e);
    for case in 0..4u32 {
        let hosts = rng.range(2, 4) as usize;
        let devices = rng.range(1, 2) as usize;
        let lds = rng.range(1, 2) as usize;
        let mut cfg = SimConfig::default();
        cfg.hosts = hosts;
        cfg.cores = rng.range(1, 2) as usize;
        cfg.sys_mem_size = 128 << 20;
        cfg.cxl.devices = devices;
        cfg.cxl.mem_size = (lds as u64) * (256 << 20);
        cfg.cxl.switches = usize::from(rng.chance(0.5));
        // One window per LD: direct-attach auto would interleave a
        // power-of-two device count into a single set, which cannot be
        // dealt out via [host.N] lds (and MLDs require 1-way anyway).
        cfg.cxl.interleave_ways = 1;
        cfg.cxl.dev_overrides = vec![
            CxlDevOverride { lds: Some(lds), ..Default::default() };
            devices
        ];
        // Deal the LDs round-robin; hosts past the LD supply run
        // DRAM-only, which the equivalence must hold for too.
        cfg.host_lds = vec![Vec::new(); hosts];
        for i in 0..devices * lds {
            cfg.host_lds[i % hosts]
                .push(LdRef { dev: i / lds, ld: (i % lds) as u16 });
        }
        // Half the topologies promote dev0.ld0 to a shared LD (CXL 3.x
        // back-invalidate coherence) mapped into every host: the BI
        // fan-out + uncredited BIRsp path must hold the same
        // equivalence as private pooling.
        if rng.chance(0.5) {
            cfg.cxl.dev_overrides[0].shared_lds = Some(vec![0]);
            let shared = LdRef { dev: 0, ld: 0 };
            for lds in &mut cfg.host_lds {
                if !lds.contains(&shared) {
                    lds.push(shared);
                }
            }
        }
        cfg.seed = rng.next_u64();
        cfg.validate().unwrap();

        let kinds: Vec<u64> = (0..hosts).map(|_| rng.below(3)).collect();
        let seeds: Vec<u64> = (0..hosts).map(|_| rng.next_u64()).collect();
        let threads = rng.range(2, 5) as usize;

        let attach = |m: &mut Machine| {
            for h in 0..m.hosts.len() {
                let wl: Box<dyn Workload> = match kinds[h] {
                    0 => Box::new(Stream::new(StreamKernel::Triad, 4096, 1)),
                    1 => Box::new(RandomAccess::new(
                        1 << 20,
                        2000,
                        0.25,
                        seeds[h],
                    )),
                    _ => Box::new(PointerChase::new(1024, 3000, seeds[h])),
                };
                let policy = if m.cfg.host_lds[h].is_empty() {
                    MemPolicy::Local { home: 0 }
                } else {
                    MemPolicy::Interleave { weights: vec![(0, 1), (1, 1)] }
                };
                m.attach_workloads_to(h, vec![wl], &policy).unwrap();
            }
        };

        let (t1, s1) = run_once(&cfg, 1, attach);
        let (tn, sn) = run_once(&cfg, threads, attach);
        assert_eq!(
            t1, tn,
            "case {case}: stat dump diverged between threads=1 and \
             threads={threads} (hosts={hosts} devices={devices} lds={lds})"
        );
        assert_summaries_eq(&s1, &sn, &format!("case {case}"));
        assert!(s1.events > 0, "case {case}: nothing ran");
    }
}

/// Serve (the latency-percentile workload) over the 2-host switched
/// MLD: per-request samples and `extra_stats` percentile merging must
/// not depend on which worker ran which host.
#[test]
fn serve_fleet_is_thread_count_invariant() {
    let mut cfg = SimConfig::default();
    cfg.hosts = 2;
    cfg.cores = 2;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 512 << 20;
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides =
        vec![CxlDevOverride { lds: Some(2), ..Default::default() }];
    cfg.host_lds = vec![
        vec![LdRef { dev: 0, ld: 0 }],
        vec![LdRef { dev: 0, ld: 1 }],
    ];
    cfg.validate().unwrap();

    let attach = |m: &mut Machine| {
        for h in 0..m.hosts.len() {
            let (hot, cold) =
                m.hosts[h].guest.as_ref().unwrap().alloc.tier_policies();
            let seed = m
                .cfg
                .seed
                .wrapping_add((h as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let sc = ServeConfig {
                users: 64,
                zipf_s: 1.1,
                requests: 60,
                kv_block: 256,
                context_blocks: 2,
                dram_slots: 8,
                cxl_slots: 16,
                decode_work: 16,
            };
            let wl: Box<dyn Workload> =
                Box::new(Serve::new(sc, hot, cold, seed));
            m.attach_workloads_to(
                h,
                vec![wl],
                &MemPolicy::Local { home: 0 },
            )
            .unwrap();
        }
    };

    let (t1, s1) = run_once(&cfg, 1, attach);
    let (t4, s4) = run_once(&cfg, 4, attach);
    assert_eq!(t1, t4, "serve stat dump diverged at threads=4");
    assert_summaries_eq(&s1, &s4, "serve");
    assert!(t1.contains("serve."), "percentile stats missing from dump");
}

/// Tiered-KV pins its own hot/cold tier arenas; the hot/cold split must
/// survive the threaded path bit-exactly.
#[test]
fn tiered_kv_is_thread_count_invariant() {
    let mut cfg = SimConfig::default();
    cfg.hosts = 2;
    cfg.cores = 1;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 512 << 20;
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides =
        vec![CxlDevOverride { lds: Some(2), ..Default::default() }];
    cfg.host_lds = vec![
        vec![LdRef { dev: 0, ld: 0 }],
        vec![LdRef { dev: 0, ld: 1 }],
    ];
    cfg.seed = 11;
    cfg.validate().unwrap();

    let attach = |m: &mut Machine| {
        for h in 0..m.hosts.len() {
            let wl: Box<dyn Workload> = Box::new(TieredKv::new(
                512,
                128,
                1500,
                m.cfg.seed.wrapping_add(h as u64),
            ));
            m.attach_workloads_to(
                h,
                vec![wl],
                &MemPolicy::Local { home: 0 },
            )
            .unwrap();
        }
    };

    let (t1, s1) = run_once(&cfg, 1, attach);
    let (t3, s3) = run_once(&cfg, 3, attach);
    assert_eq!(t1, t3, "tiered-kv stat dump diverged at threads=3");
    assert_summaries_eq(&s1, &s3, "tiered-kv");
}

/// The closed-loop `[fm] policy` path: machine-level telemetry epochs,
/// quiesce negotiations, and mid-run re-binds must make the same
/// decisions at every thread count.
#[test]
fn fm_policy_run_is_thread_count_invariant() {
    let mut cfg = SimConfig::default();
    cfg.hosts = 2;
    cfg.cores = 1;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 512 << 20;
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides =
        vec![CxlDevOverride { lds: Some(2), ..Default::default() }];
    cfg.host_lds = vec![
        vec![LdRef { dev: 0, ld: 0 }, LdRef { dev: 0, ld: 1 }],
        vec![],
    ];
    cfg.fm_policy =
        Some(FmPolicyConfig::new(FmPolicyKind::CapacityRebalance));
    cfg.seed = 7;
    cfg.validate().unwrap();

    let attach = |m: &mut Machine| {
        let wl0 = Stream::new(StreamKernel::Copy, 8192, 1);
        m.attach_workloads_to(
            0,
            vec![Box::new(wl0)],
            &MemPolicy::Bind { nodes: vec![1] },
        )
        .unwrap();
        let wl1 = Stream::new(StreamKernel::Triad, 32768, 1);
        m.attach_workloads_to(
            1,
            vec![Box::new(wl1)],
            &MemPolicy::Preferred { node: 2 },
        )
        .unwrap();
    };

    let (t1, s1) = run_once(&cfg, 1, attach);
    let (t2, s2) = run_once(&cfg, 2, attach);
    assert_eq!(t1, t2, "[fm] policy stat dump diverged at threads=2");
    assert_summaries_eq(&s1, &s2, "fm-policy");
    // The policy actually acted in both runs (identical decisions).
    assert!(t1.contains("fm.policy.decisions"));
}

// ---------------------------------------------------------------------------
// The 16-host rack golden: one digest at every thread count.
// ---------------------------------------------------------------------------

/// Sixteen hosts over four 4-LD MLDs behind two switches (the rack from
/// the issue title). The serial run's dump digest is the golden value;
/// threads ∈ {2, 4, 8} and a repeated threads=8 run must all reproduce
/// it bit-for-bit.
#[test]
fn sixteen_host_rack_golden_digest() {
    let mut cfg = SimConfig::default();
    cfg.hosts = 16;
    cfg.cores = 1;
    cfg.sys_mem_size = 128 << 20;
    cfg.cxl.devices = 4;
    cfg.cxl.mem_size = 1 << 30; // 4 x 256 MiB LD slices per device
    cfg.cxl.switches = 2;
    cfg.cxl.dev_overrides = vec![
        CxlDevOverride { lds: Some(4), ..Default::default() };
        4
    ];
    cfg.host_lds = (0..16)
        .map(|h| vec![LdRef { dev: h / 4, ld: (h % 4) as u16 }])
        .collect();
    cfg.seed = 42;
    cfg.validate().unwrap();

    let attach = |m: &mut Machine| {
        for h in 0..m.hosts.len() {
            let kernel = [
                StreamKernel::Copy,
                StreamKernel::Scale,
                StreamKernel::Add,
                StreamKernel::Triad,
            ][h % 4];
            let wl: Box<dyn Workload> =
                Box::new(Stream::new(kernel, 2048, 1));
            m.attach_workloads_to(
                h,
                vec![wl],
                &MemPolicy::Bind { nodes: vec![1] },
            )
            .unwrap();
        }
    };

    let (golden_text, golden_sum) = run_once(&cfg, 1, attach);
    let golden = fnv64(&golden_text);
    assert!(golden_sum.cxl_accesses > 0, "rack never touched the fabric");
    assert!(
        golden_text.contains("sim.par.epochs"),
        "parallel-loop stats missing from the dump"
    );

    for threads in [2usize, 4, 8, 8] {
        let (text, sum) = run_once(&cfg, threads, attach);
        assert_eq!(
            fnv64(&text),
            golden,
            "16-host digest diverged at threads={threads}"
        );
        assert_eq!(text, golden_text);
        assert_summaries_eq(
            &sum,
            &golden_sum,
            &format!("rack threads={threads}"),
        );
    }
}

/// The BI-heavy variant of the rack golden: sixteen hosts in four
/// 4-host sharing groups, each group hammering one shared LD. Every
/// store is an RFO through the device snoop filter and every epoch
/// carries BISnp/BIRsp traffic across host domains — the cross-host
/// event flow the BI horizon cap exists to order. The serial digest is
/// golden; threads ∈ {2, 4, 8} and a repeated threads=8 run (auto
/// lanes) must reproduce it bit-for-bit.
#[test]
fn sixteen_host_bi_heavy_rack_golden_digest() {
    let mut cfg = SimConfig::default();
    cfg.hosts = 16;
    cfg.cores = 1;
    cfg.sys_mem_size = 128 << 20;
    cfg.cxl.devices = 4;
    cfg.cxl.mem_size = 256 << 20;
    cfg.cxl.switches = 2;
    cfg.cxl.interleave_ways = 1;
    cfg.cxl.dev_overrides = vec![
        CxlDevOverride {
            lds: Some(1),
            shared_lds: Some(vec![0]),
            ..Default::default()
        };
        4
    ];
    // Hosts 4d..4d+3 share device d's only LD.
    cfg.host_lds = (0..16)
        .map(|h| vec![LdRef { dev: h / 4, ld: 0 }])
        .collect();
    cfg.seed = 4242;
    cfg.validate().unwrap();

    let attach = |m: &mut Machine| {
        for h in 0..m.hosts.len() {
            let kernel = [
                StreamKernel::Copy,
                StreamKernel::Scale,
                StreamKernel::Add,
                StreamKernel::Triad,
            ][h % 4];
            // Same small footprint per group member: the four sharers
            // collide on the same lines continuously.
            let wl: Box<dyn Workload> =
                Box::new(Stream::new(kernel, 2048, 1));
            m.attach_workloads_to(
                h,
                vec![wl],
                &MemPolicy::Bind { nodes: vec![1] },
            )
            .unwrap();
        }
    };

    let (golden_text, golden_sum) = run_with(&cfg, 1, 1, attach);
    let golden = fnv64(&golden_text);
    assert!(
        golden_sum.s2m_bisnp > 0,
        "BI-heavy rack never back-invalidated"
    );
    assert_eq!(
        golden_sum.s2m_bisnp, golden_sum.m2s_birsp,
        "every BISnp must be acked by run end"
    );

    for threads in [2usize, 4, 8, 8] {
        let (text, sum) = run_with(&cfg, threads, 0, attach);
        assert_eq!(
            fnv64(&text),
            golden,
            "BI-heavy 16-host digest diverged at threads={threads}"
        );
        assert_eq!(text, golden_text);
        assert_summaries_eq(
            &sum,
            &golden_sum,
            &format!("bi-rack threads={threads}"),
        );
        assert_eq!(sum.s2m_bisnp, golden_sum.s2m_bisnp);
        assert_eq!(sum.m2s_birsp, golden_sum.m2s_birsp);
    }
}

// ---------------------------------------------------------------------------
// Lookahead-horizon safety.
// ---------------------------------------------------------------------------

/// The horizon is never zero, and never exceeds the true minimum
/// round-trip latency of any LD the host can reach; hosts with no
/// bound LD advance unthrottled (`Tick::MAX`).
#[test]
fn lookahead_is_positive_and_bounded_by_reachable_latency() {
    // Direct-attach, switched, and a mixed set where host 1 is LD-less.
    for switches in [0usize, 1] {
        let mut cfg = SimConfig::default();
        cfg.hosts = 3;
        cfg.cores = 1;
        cfg.sys_mem_size = 128 << 20;
        cfg.cxl.devices = 2;
        cfg.cxl.mem_size = 256 << 20;
        cfg.cxl.switches = switches;
        // Per-device windows even on the direct-attach arm (auto would
        // fold two devices into one interleave set).
        cfg.cxl.interleave_ways = 1;
        cfg.host_lds = vec![
            vec![LdRef { dev: 0, ld: 0 }],
            vec![],
            vec![LdRef { dev: 1, ld: 0 }],
        ];
        cfg.validate().unwrap();
        let mut m = Machine::new(cfg.clone()).unwrap();
        m.boot(ProgModel::Znuma).unwrap();
        for h in 0..3 {
            m.hosts[h].recompute_lookahead();
            let la = m.hosts[h].lookahead();
            assert!(la >= 1, "switches={switches} host{h}: zero horizon");
            if cfg.host_lds[h].is_empty() {
                assert_eq!(
                    la,
                    Tick::MAX,
                    "switches={switches} host{h}: LD-less host throttled"
                );
            } else {
                let bound =
                    dev_round_trip_ticks(&cfg, cfg.host_lds[h][0].dev);
                assert!(
                    la <= bound,
                    "switches={switches} host{h}: horizon {la} exceeds \
                     true min round-trip {bound}"
                );
                assert!(
                    la >= bound.saturating_sub(1000).max(1),
                    "switches={switches} host{h}: horizon {la} gives \
                     away more than the rounding margin below {bound}"
                );
            }
        }
    }
}

/// An FM re-bind changes the reachable set, and the next section runs
/// with a re-derived horizon: host 0 starts behind the slow expander
/// only, gains the fast one mid-run, and its horizon shrinks to the
/// fast round-trip; host 1 loses its only LD and becomes unthrottled.
#[test]
fn lookahead_rederives_after_fm_rebind() {
    let mut cfg = SimConfig::default();
    cfg.hosts = 2;
    cfg.cores = 1;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.devices = 2;
    cfg.cxl.mem_size = 256 << 20;
    cfg.cxl.switches = 1;
    // dev 0 keeps the default (fast) link; dev 1 sits on a much slower
    // downstream link, so the two round-trips are ~320 ns apart.
    cfg.cxl.dev_overrides = vec![
        CxlDevOverride::default(),
        CxlDevOverride { link_lat_ns: Some(180.0), ..Default::default() },
    ];
    cfg.host_lds = vec![
        vec![LdRef { dev: 1, ld: 0 }],
        vec![LdRef { dev: 0, ld: 0 }],
    ];
    cfg.fm_events = vec![
        FmEventDef::parse("@20us unbind dev0.ld0").unwrap(),
        FmEventDef::parse("@25us bind dev0.ld0 host0").unwrap(),
    ];
    cfg.seed = 7;
    cfg.validate().unwrap();

    let slow = dev_round_trip_ticks(&cfg, 1);
    let fast = dev_round_trip_ticks(&cfg, 0);
    assert!(fast + 100_000 < slow, "topology must separate the paths");

    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    m.hosts[0].recompute_lookahead();
    let before = m.hosts[0].lookahead();
    assert!(before <= slow && before > fast, "boot horizon on slow path");

    // Host 0 streams on its slow LD well past the 25 us re-bind; host 1
    // stays idle so the unbind quiesces immediately.
    let wl = Stream::new(StreamKernel::Triad, 32768, 1);
    m.attach_workloads_to(
        0,
        vec![Box::new(wl)],
        &MemPolicy::Bind { nodes: vec![1] },
    )
    .unwrap();
    let s = m.run(None);
    assert!(s.ticks > ns_to_ticks(25_000.0), "run ended before the bind");

    let after = m.hosts[0].lookahead();
    assert!(
        after < before,
        "gaining the fast path must shrink the horizon \
         ({before} -> {after})"
    );
    assert!(after <= fast && after >= fast.saturating_sub(1000).max(1));
    assert_eq!(
        m.hosts[1].lookahead(),
        Tick::MAX,
        "host 1 lost its only LD and must run unthrottled"
    );
}

/// A deliberately-wrong horizon must be *caught*, not absorbed: pin the
/// horizon far past the true round-trip, let the host race ahead
/// through a long DRAM stretch while a CXL fill is still in flight, and
/// the commit lands in the host's past — the event queue's debug
/// assertion fires.
#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "scheduling into the past")]
fn forced_stale_horizon_is_caught_serial() {
    let mut cfg = forced_horizon_cfg(1);
    cfg.threads = 1;
    run_forced_horizon(cfg);
}

/// Same trap on the threaded path: the worker's panic must relay
/// through the epoch barrier to the caller with its message intact
/// (not deadlock the section).
#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "scheduling into the past")]
fn forced_stale_horizon_is_caught_across_worker_threads() {
    let mut cfg = forced_horizon_cfg(2);
    cfg.threads = 2;
    run_forced_horizon(cfg);
}

#[cfg(debug_assertions)]
fn forced_horizon_cfg(hosts: usize) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.hosts = hosts;
    cfg.cores = 1;
    // A deep LSQ so a whole CXL page's misses stay outstanding while
    // the core streams on through the DRAM stretch behind them.
    cfg.lsq_entries = 256;
    cfg.sys_mem_size = 128 << 20;
    cfg.cxl.devices = 1;
    cfg.cxl.mem_size = 256 << 20;
    let mut host_lds = vec![vec![LdRef { dev: 0, ld: 0 }]];
    host_lds.resize(hosts, Vec::new());
    cfg.host_lds = host_lds;
    cfg.validate().unwrap();
    cfg
}

#[cfg(debug_assertions)]
fn run_forced_horizon(cfg: SimConfig) {
    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    // 16 DRAM pages per CXL page: after each burst of CXL misses the
    // host has microseconds of purely local work to race ahead into.
    m.attach_workloads_to(
        0,
        vec![Box::new(Stream::new(StreamKernel::Copy, 32768, 1))],
        &MemPolicy::Interleave { weights: vec![(0, 16), (1, 1)] },
    )
    .unwrap();
    for h in 1..m.hosts.len() {
        m.attach_workloads_to(
            h,
            vec![Box::new(Stream::new(StreamKernel::Triad, 4096, 1))],
            &MemPolicy::Local { home: 0 },
        )
        .unwrap();
    }
    // Pin host 0's horizon far past the true round-trip: the
    // self-throttle is gone, so a fill must eventually commit behind
    // the host's local clock.
    m.hosts[0].force_lookahead(Some(Tick::MAX));
    m.run(None);
}

// ---------------------------------------------------------------------------
// Stats-merge hardening.
// ---------------------------------------------------------------------------

/// `Workload::extra_stats` percentiles come out of `Samples`, which
/// must be insensitive to the order values were recorded in — the
/// order hosts retire requests is an execution detail.
#[test]
fn sample_percentiles_are_insertion_order_invariant() {
    use cxlramsim::stats::Samples;
    let vals: Vec<u64> = (0..997u64)
        .map(|i| i.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 40)
        .collect();
    let mut fwd = Samples::default();
    fwd.extend(&vals);
    let mut rev = Samples::default();
    let mut shuffled = vals.clone();
    shuffled.reverse();
    rev.extend(&shuffled);
    let mut rng = Rng::new(3);
    rng.shuffle(&mut shuffled);
    let mut perm = Samples::default();
    for v in &shuffled {
        perm.add(*v);
    }
    for p in [0.5, 0.9, 0.99, 1.0] {
        assert_eq!(fwd.percentile(p), rev.percentile(p), "p={p} reversed");
        assert_eq!(fwd.percentile(p), perm.percentile(p), "p={p} shuffled");
    }
    assert_eq!(fwd.mean().to_bits(), rev.mean().to_bits());
}

/// The dump walks hosts in index order regardless of which worker
/// finished last, so two identical runs at different thread counts
/// produce the same *ordering* of per-host keys, not just the same
/// values.
#[test]
fn stat_dump_key_order_is_execution_order_independent() {
    let mut cfg = SimConfig::default();
    cfg.hosts = 4;
    cfg.cores = 1;
    cfg.sys_mem_size = 128 << 20;
    cfg.cxl.mem_size = 1 << 30; // four 256 MiB LD slices
    cfg.cxl.dev_overrides =
        vec![CxlDevOverride { lds: Some(4), ..Default::default() }];
    cfg.host_lds = (0..4)
        .map(|h| vec![LdRef { dev: 0, ld: h as u16 }])
        .collect();
    cfg.validate().unwrap();

    let attach = |m: &mut Machine| {
        for h in 0..m.hosts.len() {
            // Wildly uneven work so worker completion order differs
            // from host index order.
            let n = [16384u64, 512, 8192, 1024][h];
            let wl: Box<dyn Workload> =
                Box::new(Stream::new(StreamKernel::Copy, n, 1));
            m.attach_workloads_to(
                h,
                vec![wl],
                &MemPolicy::Bind { nodes: vec![1] },
            )
            .unwrap();
        }
    };

    let (t1, _) = run_once(&cfg, 1, attach);
    let (t4, _) = run_once(&cfg, 4, attach);
    let keys = |t: &str| {
        t.lines()
            .filter_map(|l| l.split_whitespace().next().map(String::from))
            .collect::<Vec<_>>()
    };
    assert_eq!(keys(&t1), keys(&t4), "per-host key order diverged");
    assert_eq!(t1, t4);
}

// ---------------------------------------------------------------------------
// Sharded-commit lanes: (threads x commit_lanes) invariance.
// ---------------------------------------------------------------------------

/// A fabric-heavy rack (every access is CXL) where eight single-LD
/// devices sit behind two switches — two switch-credit-disjoint lane
/// groups. Every `(threads, commit_lanes)` combination, including
/// `auto`, must reproduce the `threads = 1, lanes = 1` run
/// byte-for-byte.
#[test]
fn fabric_heavy_lane_count_invariance() {
    let mut cfg = SimConfig::default();
    cfg.hosts = 8;
    cfg.cores = 1;
    cfg.sys_mem_size = 128 << 20;
    cfg.cxl.devices = 8;
    cfg.cxl.mem_size = 256 << 20;
    cfg.cxl.switches = 2;
    cfg.cxl.interleave_ways = 1;
    cfg.host_lds =
        (0..8).map(|h| vec![LdRef { dev: h, ld: 0 }]).collect();
    cfg.seed = 23;
    cfg.validate().unwrap();

    let attach = |m: &mut Machine| {
        for h in 0..m.hosts.len() {
            let kernel =
                [StreamKernel::Copy, StreamKernel::Triad][h % 2];
            let wl: Box<dyn Workload> =
                Box::new(Stream::new(kernel, 4096, 1));
            // Bind to the zNUMA node: all traffic crosses the fabric.
            m.attach_workloads_to(
                h,
                vec![wl],
                &MemPolicy::Bind { nodes: vec![1] },
            )
            .unwrap();
        }
    };

    let (golden_text, golden_sum) = run_with(&cfg, 1, 1, attach);
    assert!(golden_sum.cxl_accesses > 0, "rack never touched the fabric");
    // 0 = auto (lanes follow the thread count).
    for (threads, lanes) in [(1, 2), (1, 0), (4, 1), (4, 2), (4, 0)] {
        let (text, sum) = run_with(&cfg, threads, lanes, attach);
        assert_eq!(
            fnv64(&text),
            fnv64(&golden_text),
            "digest diverged at threads={threads} lanes={lanes}"
        );
        assert_eq!(text, golden_text);
        assert_summaries_eq(
            &sum,
            &golden_sum,
            &format!("threads={threads} lanes={lanes}"),
        );
    }
}

/// The 32-host scale-up of the rack golden: eight 4-LD MLDs behind two
/// switches, every host pinned all-CXL. One serial digest; threads
/// ∈ {2, 4, 8} with auto lanes must reproduce it bit-for-bit.
#[test]
fn thirty_two_host_fabric_heavy_golden_digest() {
    let mut cfg = SimConfig::default();
    cfg.hosts = 32;
    cfg.cores = 1;
    cfg.sys_mem_size = 128 << 20;
    cfg.cxl.devices = 8;
    cfg.cxl.mem_size = 1 << 30; // 4 x 256 MiB LD slices per device
    cfg.cxl.switches = 2;
    cfg.cxl.dev_overrides = vec![
        CxlDevOverride { lds: Some(4), ..Default::default() };
        8
    ];
    cfg.host_lds = (0..32)
        .map(|h| vec![LdRef { dev: h / 4, ld: (h % 4) as u16 }])
        .collect();
    cfg.seed = 1234;
    cfg.validate().unwrap();

    let attach = |m: &mut Machine| {
        for h in 0..m.hosts.len() {
            let kernel = [
                StreamKernel::Copy,
                StreamKernel::Scale,
                StreamKernel::Add,
                StreamKernel::Triad,
            ][h % 4];
            let wl: Box<dyn Workload> =
                Box::new(Stream::new(kernel, 1024, 1));
            m.attach_workloads_to(
                h,
                vec![wl],
                &MemPolicy::Bind { nodes: vec![1] },
            )
            .unwrap();
        }
    };

    let (golden_text, golden_sum) = run_with(&cfg, 1, 1, attach);
    let golden = fnv64(&golden_text);
    assert!(golden_sum.cxl_accesses > 0, "rack never touched the fabric");

    for threads in [2usize, 4, 8] {
        let (text, sum) = run_with(&cfg, threads, 0, attach);
        assert_eq!(
            fnv64(&text),
            golden,
            "32-host digest diverged at threads={threads} lanes=auto"
        );
        assert_eq!(text, golden_text);
        assert_summaries_eq(
            &sum,
            &golden_sum,
            &format!("rack32 threads={threads}"),
        );
    }
}

/// Shared-upstream-switch credit contention: with a single M2S credit
/// per pool, four hosts hammering two devices behind each switch are
/// continuously in the retry path — the exact accounting the
/// switch-group lane rule exists to serialize. Every lane/thread combo
/// must agree bit-for-bit, and the runs must actually exercise credit
/// stalls on the shared upstream links.
#[test]
fn shared_upstream_credit_contention_is_lane_invariant() {
    let mut cfg = SimConfig::default();
    cfg.hosts = 4;
    cfg.cores = 1;
    cfg.sys_mem_size = 128 << 20;
    cfg.cxl.devices = 4;
    cfg.cxl.mem_size = 256 << 20;
    cfg.cxl.switches = 2;
    cfg.cxl.interleave_ways = 1;
    cfg.cxl.credits = 1;
    cfg.host_lds =
        (0..4).map(|h| vec![LdRef { dev: h, ld: 0 }]).collect();
    cfg.seed = 5;
    cfg.validate().unwrap();

    let attach = |m: &mut Machine| {
        for h in 0..m.hosts.len() {
            let wl: Box<dyn Workload> =
                Box::new(Stream::new(StreamKernel::Copy, 4096, 1));
            m.attach_workloads_to(
                h,
                vec![wl],
                &MemPolicy::Bind { nodes: vec![1] },
            )
            .unwrap();
        }
    };

    let (golden_text, golden_sum) = run_with(&cfg, 1, 1, attach);
    let stalls: f64 = golden_text
        .lines()
        .filter(|l| {
            l.starts_with("cxl.sw") && l.contains(".credit_stalls")
        })
        .filter_map(|l| l.split_whitespace().last()?.parse().ok())
        .sum();
    assert!(
        stalls > 0.0,
        "contention case never stalled on a shared upstream credit"
    );
    for (threads, lanes) in [(1, 2), (1, 0), (4, 1), (4, 2), (4, 0)] {
        let (text, sum) = run_with(&cfg, threads, lanes, attach);
        assert_eq!(
            text, golden_text,
            "credit-contention dump diverged at threads={threads} \
             lanes={lanes}"
        );
        assert_summaries_eq(
            &sum,
            &golden_sum,
            &format!("contention threads={threads} lanes={lanes}"),
        );
    }
}
