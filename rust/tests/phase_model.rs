//! Exhaustive-interleaving model check of the sharded section loop's
//! phase machine (`system::machine::run_section_sharded`).
//!
//! The real loop coordinates one main thread and N pool workers with a
//! phase word plus a start/end barrier pair:
//!
//! ```text
//! main:    store(phase); start.wait(); end.wait();   // per round
//!          store(STOP);  start.wait();               // shutdown
//! worker:  loop { start.wait();
//!                 if phase == STOP { break }
//!                 act(phase); end.wait(); }
//! ```
//!
//! loom isn't vendored in this tree, so this file carries its own tiny
//! model checker: every thread is a step function over an explicit
//! shared state, and a DFS with memoized states enumerates EVERY
//! interleaving of the atomic steps. Three properties are proved over
//! the full space, for 1-3 workers over a drain/commit/drain round
//! schedule:
//!
//! 1. **No deadlock** — from every reachable state some thread can
//!    step until all have terminated.
//! 2. **Phase coherence** — each worker observes exactly the phase
//!    sequence the main thread published, in order. (This is the
//!    correctness core: a worker committing lanes during a drain round
//!    would race the host borrows.)
//! 3. **Termination** — every interleaving reaches the all-done state.
//!
//! Two deliberately broken protocol variants prove the checker has
//! teeth: publishing the phase *after* the start barrier admits an
//! interleaving where a worker acts on a stale phase, and parking the
//! main thread on the end barrier after STOP (workers exit without
//! arriving) deadlocks — the model must catch both.

use std::collections::HashSet;

const DRAIN: u8 = 0;
const COMMIT: u8 = 1;
const STOP: u8 = 2;

/// A cyclic barrier for `n` parties, modeled with an arrival count and
/// a generation counter: the n-th arrival flips the generation and
/// resets the count; a parked thread may pass once the generation moved
/// beyond its ticket.
#[derive(Clone, PartialEq, Eq, Hash)]
struct Bar {
    arrived: usize,
    generation: u32,
}

impl Bar {
    fn new() -> Self {
        Bar { arrived: 0, generation: 0 }
    }

    /// Arrive; returns the generation ticket to park on.
    fn arrive(&mut self, parties: usize) -> u32 {
        let ticket = self.generation;
        self.arrived += 1;
        if self.arrived == parties {
            self.arrived = 0;
            self.generation += 1;
        }
        ticket
    }

    fn released(&self, ticket: u32) -> bool {
        self.generation > ticket
    }
}

/// Main-thread program counter.
#[derive(Clone, PartialEq, Eq, Hash)]
enum MainPc {
    /// Publish `schedule[round]` (or STOP past the end).
    Publish { round: usize },
    StartArrive { round: usize },
    StartPark { round: usize, ticket: u32 },
    EndArrive { round: usize },
    EndPark { round: usize, ticket: u32 },
    /// Broken-variant order: start barrier first, publish after.
    LatePublishArrive { round: usize },
    LatePublishPark { round: usize, ticket: u32 },
    /// Broken-variant shutdown: park on `end` after STOP.
    StopEndArrive,
    StopEndPark { ticket: u32 },
    Done,
}

/// Worker program counter.
#[derive(Clone, PartialEq, Eq, Hash)]
enum WorkerPc {
    StartArrive,
    StartPark { ticket: u32 },
    /// Read the phase word (the atomic load after the start release).
    ReadPhase,
    EndArrive,
    EndPark { ticket: u32 },
    Done,
}

#[derive(Clone, PartialEq, Eq, Hash)]
struct State {
    phase: u8,
    start: Bar,
    end: Bar,
    main: MainPc,
    workers: Vec<WorkerPc>,
    /// Phase values each worker observed, in order — the property.
    observed: Vec<Vec<u8>>,
}

/// Protocol variants under test.
#[derive(Clone, Copy, PartialEq)]
enum Variant {
    /// The shipped protocol: publish happens-before the start release.
    Correct,
    /// Publish *after* the start barrier — workers race the store.
    PublishAfterStart,
    /// Main parks on `end` after publishing STOP; workers never arrive.
    StopWaitsOnEnd,
}

/// Every outcome the DFS can observe; the assertions pick over these.
#[derive(Default)]
struct Outcomes {
    deadlocks: usize,
    incoherent: usize,
    terminal: usize,
}

struct Model<'a> {
    schedule: &'a [u8],
    nworkers: usize,
    variant: Variant,
}

impl Model<'_> {
    fn parties(&self) -> usize {
        self.nworkers + 1
    }

    fn initial(&self) -> State {
        let main = match self.variant {
            Variant::PublishAfterStart => {
                MainPc::LatePublishArrive { round: 0 }
            }
            _ => MainPc::Publish { round: 0 },
        };
        State {
            // The phase word starts as DRAIN in the real loop too; the
            // broken variant leans on exactly that stale value.
            phase: DRAIN,
            start: Bar::new(),
            end: Bar::new(),
            main,
            workers: vec![WorkerPc::StartArrive; self.nworkers],
            observed: vec![Vec::new(); self.nworkers],
        }
    }

    /// All successor states: one atomic step of any runnable thread.
    fn steps(&self, s: &State) -> Vec<State> {
        let mut next = Vec::new();
        let parties = self.parties();
        // Main thread.
        match &s.main {
            MainPc::Publish { round } => {
                let mut t = s.clone();
                if *round < self.schedule.len() {
                    t.phase = self.schedule[*round];
                    t.main = MainPc::StartArrive { round: *round };
                } else {
                    t.phase = STOP;
                    t.main = match self.variant {
                        Variant::StopWaitsOnEnd => MainPc::StopEndArrive,
                        _ => MainPc::StartArrive { round: *round },
                    };
                }
                next.push(t);
            }
            MainPc::StartArrive { round } => {
                let mut t = s.clone();
                let ticket = t.start.arrive(parties);
                t.main = MainPc::StartPark { round: *round, ticket };
                next.push(t);
            }
            MainPc::StartPark { round, ticket }
                if s.start.released(*ticket) =>
            {
                let mut t = s.clone();
                t.main = if *round < self.schedule.len() {
                    MainPc::EndArrive { round: *round }
                } else {
                    // STOP published: the real main thread returns from
                    // the section after this start release.
                    MainPc::Done
                };
                next.push(t);
            }
            MainPc::EndArrive { round } => {
                let mut t = s.clone();
                let ticket = t.end.arrive(parties);
                t.main = MainPc::EndPark { round: *round, ticket };
                next.push(t);
            }
            MainPc::EndPark { round, ticket }
                if s.end.released(*ticket) =>
            {
                let mut t = s.clone();
                t.main = match self.variant {
                    Variant::PublishAfterStart => {
                        MainPc::LatePublishArrive { round: round + 1 }
                    }
                    _ => MainPc::Publish { round: round + 1 },
                };
                next.push(t);
            }
            MainPc::LatePublishArrive { round } => {
                let mut t = s.clone();
                let ticket = t.start.arrive(parties);
                t.main =
                    MainPc::LatePublishPark { round: *round, ticket };
                next.push(t);
            }
            MainPc::LatePublishPark { round, ticket }
                if s.start.released(*ticket) =>
            {
                // Store AFTER the release: some worker may already have
                // loaded the stale word.
                let mut t = s.clone();
                if *round < self.schedule.len() {
                    t.phase = self.schedule[*round];
                    t.main = MainPc::EndArrive { round: *round };
                } else {
                    t.phase = STOP;
                    t.main = MainPc::Done;
                }
                next.push(t);
            }
            MainPc::StopEndArrive => {
                let mut t = s.clone();
                let ticket = t.end.arrive(parties);
                t.main = MainPc::StopEndPark { ticket };
                next.push(t);
            }
            MainPc::StopEndPark { ticket } if s.end.released(*ticket) => {
                let mut t = s.clone();
                t.main = MainPc::Done;
                next.push(t);
            }
            _ => {}
        }
        // Workers.
        for w in 0..self.nworkers {
            match &s.workers[w] {
                WorkerPc::StartArrive => {
                    let mut t = s.clone();
                    let ticket = t.start.arrive(parties);
                    t.workers[w] = WorkerPc::StartPark { ticket };
                    next.push(t);
                }
                WorkerPc::StartPark { ticket }
                    if s.start.released(*ticket) =>
                {
                    let mut t = s.clone();
                    t.workers[w] = WorkerPc::ReadPhase;
                    next.push(t);
                }
                WorkerPc::ReadPhase => {
                    let mut t = s.clone();
                    if s.phase == STOP {
                        t.workers[w] = WorkerPc::Done;
                    } else {
                        t.observed[w].push(s.phase);
                        t.workers[w] = WorkerPc::EndArrive;
                    }
                    next.push(t);
                }
                WorkerPc::EndArrive => {
                    let mut t = s.clone();
                    let ticket = t.end.arrive(parties);
                    t.workers[w] = WorkerPc::EndPark { ticket };
                    next.push(t);
                }
                WorkerPc::EndPark { ticket }
                    if s.end.released(*ticket) =>
                {
                    let mut t = s.clone();
                    t.workers[w] = WorkerPc::StartArrive;
                    next.push(t);
                }
                _ => {}
            }
        }
        next
    }

    fn all_done(&self, s: &State) -> bool {
        s.main == MainPc::Done
            && s.workers.iter().all(|w| *w == WorkerPc::Done)
    }

    /// DFS over every interleaving, memoizing visited states.
    fn explore(&self) -> Outcomes {
        let mut out = Outcomes::default();
        let mut seen: HashSet<State> = HashSet::new();
        let mut stack = vec![self.initial()];
        while let Some(s) = stack.pop() {
            if !seen.insert(s.clone()) {
                continue;
            }
            if self.all_done(&s) {
                out.terminal += 1;
                let coherent = s
                    .observed
                    .iter()
                    .all(|o| o.as_slice() == self.schedule);
                if !coherent {
                    out.incoherent += 1;
                }
                continue;
            }
            let succ = self.steps(&s);
            if succ.is_empty() {
                out.deadlocks += 1;
                continue;
            }
            stack.extend(succ);
        }
        assert!(
            seen.len() < 2_000_000,
            "state space blow-up: tighten the model"
        );
        out
    }
}

/// The shipped protocol, over every interleaving, 1-3 workers: no
/// deadlock, no stale phase observation, guaranteed termination.
#[test]
fn shipped_phase_protocol_is_deadlock_free_and_coherent() {
    // Drain/commit alternation exactly as the sharded loop issues it
    // (a drain phase, then commit waves, then the next drain).
    let schedule = [DRAIN, COMMIT, COMMIT, DRAIN];
    for nworkers in 1..=3 {
        let m = Model {
            schedule: &schedule,
            nworkers,
            variant: Variant::Correct,
        };
        let out = m.explore();
        assert_eq!(
            out.deadlocks, 0,
            "{nworkers} workers: interleaving deadlocked"
        );
        assert_eq!(
            out.incoherent, 0,
            "{nworkers} workers: a worker saw a stale phase"
        );
        assert!(out.terminal > 0, "no interleaving terminated");
    }
}

/// Publishing the phase after the start release must admit at least one
/// interleaving where a worker acts on the previous round's phase — the
/// model checker proves the store-before-barrier order is load-bearing.
#[test]
fn late_phase_publish_is_caught_as_incoherent() {
    // Starts with a COMMIT round: a worker that outruns the late store
    // sees the initial DRAIN word.
    let schedule = [COMMIT, DRAIN];
    let m = Model {
        schedule: &schedule,
        nworkers: 2,
        variant: Variant::PublishAfterStart,
    };
    let out = m.explore();
    assert!(
        out.incoherent > 0,
        "the checker must find a stale-phase interleaving"
    );
}

/// Parking the main thread on the end barrier after STOP deadlocks:
/// workers exit at the phase check and never arrive. The real shutdown
/// (STOP + start release only) is the fix this proves necessary.
#[test]
fn stop_through_end_barrier_is_caught_as_deadlock() {
    let schedule = [DRAIN];
    let m = Model {
        schedule: &schedule,
        nworkers: 2,
        variant: Variant::StopWaitsOnEnd,
    };
    let out = m.explore();
    assert!(
        out.deadlocks > 0,
        "the checker must find the shutdown deadlock"
    );
}
