//! Mutation tests for the runtime protocol-invariant checker
//! (`[sim] check`, `sim::invariants`).
//!
//! The checker's value is falsifiable: a clean run must report zero
//! violations at every `(threads, commit_lanes)` pair, and each seeded
//! corruption — a leaked credit, a reordered commit, a desynced snoop
//! filter — must fire exactly the rule written for it. The fault hooks
//! (`Machine::debug_*`) only exist under the `check` feature, which is
//! why this whole file is feature-gated.
#![cfg(feature = "check")]

use cxlramsim::config::{CxlDevOverride, LdRef, SimConfig};
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::util::rng::Rng;
use cxlramsim::workloads::{RandomAccess, Stream, StreamKernel, Workload};

/// A single-host machine with the checker armed.
fn checked_cfg() -> SimConfig {
    let mut c = SimConfig::default();
    c.cores = 2;
    c.sys_mem_size = 256 << 20;
    c.cxl.mem_size = 256 << 20;
    c.check = true;
    c
}

/// Two hosts sharing one LD (the sharing.rs topology) with the checker
/// armed — exercises BI traffic, so SF-1/SF-2 have state to audit.
fn checked_shared_cfg() -> SimConfig {
    let mut cfg = checked_cfg();
    cfg.hosts = 2;
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides = vec![CxlDevOverride {
        lds: Some(1),
        shared_lds: Some(vec![0]),
        ..Default::default()
    }];
    cfg.host_lds = vec![
        vec![LdRef { dev: 0, ld: 0 }],
        vec![LdRef { dev: 0, ld: 0 }],
    ];
    cfg.seed = 99;
    cfg
}

fn attach_stream(m: &mut Machine, hosts: usize) {
    for h in 0..hosts {
        let wl = Stream::for_wss(StreamKernel::Triad, m.cfg.l2.size, 2);
        m.attach_workloads_to(
            h,
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![1] },
        )
        .unwrap();
    }
}

fn booted(mut cfg: SimConfig, threads: usize, lanes: usize) -> Machine {
    cfg.threads = threads;
    cfg.commit_lanes = lanes;
    let hosts = cfg.hosts;
    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    attach_stream(&mut m, hosts);
    m
}

// ---------------------------------------------------------------------------
// Clean runs: zero violations, every scheduler mode, goldens unchanged.
// ---------------------------------------------------------------------------

/// The acceptance gate: with the checker armed, a clean shared-LD run
/// reports zero violations at every `(threads, commit_lanes)` pair AND
/// leaves the deterministic stat dump bit-identical to the unchecked
/// run — auditing must observe, never perturb.
#[test]
fn clean_runs_have_zero_violations_at_every_schedule() {
    let mut unchecked = checked_shared_cfg();
    unchecked.check = false;
    let mut m = booted(unchecked, 1, 1);
    m.run(None);
    let golden = m.dump_stats().to_text();

    // 0 = auto lanes.
    for (threads, lanes) in [(1, 1), (1, 4), (4, 0), (4, 4)] {
        let mut m = booted(checked_shared_cfg(), threads, lanes);
        m.run(None);
        m.verify().unwrap();
        let ck = m.checker().expect("[sim] check = true arms the checker");
        assert_eq!(
            ck.total_violations(),
            0,
            "threads={threads} lanes={lanes}: {}",
            ck.report()
        );
        assert!(ck.epochs() > 0, "audits must actually have run");
        assert!(ck.rules_evaluated() > 0);
        assert_eq!(
            m.dump_stats().to_text(),
            golden,
            "threads={threads} lanes={lanes}: checking changed the run"
        );
    }
}

/// The checker stats ride in the full dump only: the deterministic
/// dump must not grow mode-dependent keys (audit cadence differs per
/// scheduler), and an unchecked run must not grow them at all.
#[test]
fn check_stats_surface_only_in_full_dump_when_armed() {
    let mut m = booted(checked_cfg(), 1, 1);
    m.run(None);
    let det = m.dump_stats();
    let full = m.dump_stats_full();
    for key in ["check.epochs", "check.violations", "check.rules_evaluated"]
    {
        assert!(full.get(key).is_some(), "full dump must carry {key}");
        assert!(det.get(key).is_none(), "det dump must not carry {key}");
    }
    assert_eq!(full.get("check.violations"), Some(0.0));
    assert!(full.get("check.epochs").unwrap() > 0.0);

    let mut plain = checked_cfg();
    plain.check = false;
    let mut m = booted(plain, 1, 1);
    m.run(None);
    assert!(
        !m.dump_stats_full().to_text().contains("check."),
        "unchecked runs must not emit checker keys"
    );
}

// ---------------------------------------------------------------------------
// Mutations: each seeded fault fires exactly the rule written for it.
// ---------------------------------------------------------------------------

/// Leak a credit after a clean run: the issued pool grows without a
/// matching free/in-flight entry, so the next audit must fire CR-1 (and
/// only a conservation rule — commit order and the snoop filter are
/// untouched).
#[test]
fn leaked_credit_trips_cr1() {
    let mut m = booted(checked_cfg(), 1, 1);
    m.run(None);
    assert_eq!(m.checker().unwrap().total_violations(), 0);
    m.debug_leak_credit(0);
    m.check_now();
    let rules = m.check_violation_rules();
    assert!(
        rules.contains(&"CR-1"),
        "leaked credit must break conservation, got {rules:?}"
    );
    assert!(
        rules.iter().all(|r| *r == "CR-1"),
        "a leaked credit is purely a CR-1 fault, got {rules:?}"
    );
}

/// Arm the commit-reorder fault before the run: the order audit holds
/// one key back a slot, so the stream of committed `(tick, host, seq)`
/// keys is no longer monotone and EQ-2 must fire — in every scheduler
/// mode, since all of them feed the same audit.
#[test]
fn reordered_commit_trips_eq2() {
    // Serial commit path.
    let mut m = booted(checked_cfg(), 1, 1);
    m.debug_reorder_commit();
    m.run(None);
    let rules = m.check_violation_rules();
    assert!(
        rules.contains(&"EQ-2"),
        "serial: reordered commit must trip EQ-2, got {rules:?}"
    );
    // Threaded commit path feeds the same audit from its distributor.
    let mut m = booted(checked_shared_cfg(), 2, 1);
    m.debug_reorder_commit();
    m.run(None);
    let rules = m.check_violation_rules();
    assert!(
        rules.contains(&"EQ-2"),
        "threaded: reordered commit must trip EQ-2, got {rules:?}"
    );
}

/// Wipe the shared device's snoop filter after a contended run: hosts
/// still claim ownership the directory no longer remembers, so the
/// quiesce audit must fire SF-1.
#[test]
fn desynced_sharer_trips_sf1() {
    let mut m = booted(checked_shared_cfg(), 1, 1);
    m.run(None);
    assert_eq!(m.checker().unwrap().total_violations(), 0);
    assert!(
        m.fabric.devices[0]
            .snoop_entries()
            .any(|(_, sl)| sl.owner.is_some()),
        "precondition: the run must end with host-owned lines, or the \
         desync has nothing to contradict"
    );
    m.debug_desync_sharer(0);
    m.check_now();
    let rules = m.check_violation_rules();
    assert!(
        rules.contains(&"SF-1"),
        "cleared snoop filter under live owners must trip SF-1, \
         got {rules:?}"
    );
}

// ---------------------------------------------------------------------------
// Random topologies: the checker holds across the config space.
// ---------------------------------------------------------------------------

/// 100 randomly drawn topologies (hosts x devices x switches x
/// interleave x sharing x scheduler mode x workload), each run under
/// the checker: zero violations everywhere. This is the sweep that
/// makes the invariants *laws of the simulator*, not properties of one
/// lucky config.
#[test]
fn random_topologies_run_clean_under_check() {
    let mut rng = Rng::new(0xc4ec_4e55);
    for case in 0..100u32 {
        let mut cfg = checked_cfg();
        cfg.seed = rng.next_u64();
        cfg.cores = 1 + rng.below(2) as usize;
        let shared = rng.below(3) == 0;
        if shared {
            cfg = checked_shared_cfg();
            cfg.seed = rng.next_u64();
            cfg.hosts = 2 + rng.below(2) as usize;
            cfg.host_lds = (0..cfg.hosts)
                .map(|_| vec![LdRef { dev: 0, ld: 0 }])
                .collect();
        } else {
            cfg.hosts = 1 + rng.below(2) as usize;
            if cfg.hosts == 2 {
                // Round-robin LD assignment hands window i to host
                // i % hosts: two pooled hosts need one window each.
                cfg.cxl.devices = 2;
                cfg.cxl.switches = rng.below(2) as usize;
            } else {
                cfg.cxl.devices = 1 + rng.below(2) as usize;
                if cfg.cxl.devices == 2 {
                    cfg.cxl.interleave_ways =
                        if rng.below(2) == 0 { 0 } else { 2 };
                    cfg.cxl.switches = rng.below(2) as usize;
                }
            }
        }
        cfg.threads = 1 + rng.below(4) as usize;
        cfg.commit_lanes = rng.below(3) as usize; // 0 = auto
        let hosts = cfg.hosts;
        let mut m = Machine::new(cfg).unwrap();
        m.boot(ProgModel::Znuma).unwrap();
        for h in 0..hosts {
            let wl: Box<dyn Workload> = match rng.below(3) {
                0 => Box::new(Stream::new(StreamKernel::Triad, 8192, 1)),
                1 => Box::new(Stream::new(StreamKernel::Copy, 8192, 1)),
                _ => Box::new(RandomAccess::new(
                    1 << 20,
                    2000,
                    0.5,
                    rng.next_u64(),
                )),
            };
            m.attach_workloads_to(
                h,
                vec![wl],
                &MemPolicy::Bind { nodes: vec![1] },
            )
            .unwrap();
        }
        m.run(None);
        let ck = m.checker().unwrap();
        assert_eq!(
            ck.total_violations(),
            0,
            "case {case}: {}",
            ck.report()
        );
        assert!(ck.rules_evaluated() > 0, "case {case}: no audits ran");
    }
}
