//! Integration: the unmodified-guest boot contract, end to end.

use cxlramsim::bios;
use cxlramsim::config::SimConfig;
use cxlramsim::guestos::{self, ProgModel};
use cxlramsim::mem::PhysMem;
use cxlramsim::system::Machine;

#[test]
fn full_boot_produces_znuma_node() {
    let mut m = Machine::new(SimConfig::default()).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let g = m.guest.as_ref().unwrap();
    assert_eq!(g.znuma_node(), Some(1));
    let n1 = &g.alloc.nodes[1];
    assert!(n1.online && !n1.has_cpus);
    assert_eq!(n1.base, m.bios.cxl_window_base);
    assert_eq!(n1.size, SimConfig::default().cxl.mem_size);
}

#[test]
fn boot_log_records_every_stage() {
    let mut m = Machine::new(SimConfig::default()).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let log = m.guest.as_ref().unwrap().boot_log.join("\n");
    for needle in ["acpi:", "numa:", "pci:", "cxl: mem0 bound", "zNUMA"] {
        assert!(log.contains(needle), "boot log missing '{needle}':\n{log}");
    }
}

#[test]
fn guest_discovers_only_what_bios_described() {
    let cfg = SimConfig { cores: 2, ..SimConfig::default() };
    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let g = m.guest.as_ref().unwrap();
    assert_eq!(g.acpi.cpu_apic_ids, vec![0, 1]);
    // Exactly one memdev-class function.
    let memdevs = g
        .pci_devs
        .iter()
        .filter(|d| d.class[0] == 0x05 && d.class[1] == 0x02)
        .count();
    assert_eq!(memdevs, 1);
}

#[test]
fn bars_land_inside_the_dsdt_window() {
    let mut m = Machine::new(SimConfig::default()).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let g = m.guest.as_ref().unwrap();
    let ep = g
        .pci_devs
        .iter()
        .find(|d| d.class[0] == 0x05 && d.class[1] == 0x02)
        .unwrap();
    assert_eq!(ep.bars.len(), 2);
    for bar in &ep.bars {
        assert!(bar.base >= bios::layout::MMIO_BASE + bios::layout::CHBS_SIZE);
        assert!(
            bar.base + bar.size
                <= bios::layout::MMIO_BASE + bios::layout::MMIO_SIZE
        );
        assert_eq!(bar.base % bar.size.max(4096), 0, "BAR alignment");
    }
}

#[test]
fn hdm_decoders_committed_on_both_ends() {
    let mut m = Machine::new(SimConfig::default()).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    assert!(m.fabric.devices[0].component.decoder_committed(0));
    assert!(m.hb_components[0].decoder_committed(0));
    let (base, size) = m.fabric.devices[0].component.decoder_range(0);
    assert_eq!(base, m.bios.cxl_window_base);
    assert_eq!(size, SimConfig::default().cxl.mem_size);
    // End-to-end HPA->DPA translation works at the window edges.
    assert_eq!(m.fabric.devices[0].hpa_to_dpa(base), 0);
    assert_eq!(m.fabric.devices[0].hpa_to_dpa(base + size - 64), size - 64);
}

#[test]
fn four_device_boot_enumerates_every_endpoint() {
    let mut cfg = SimConfig::default();
    cfg.cxl.devices = 4;
    cfg.cxl.mem_size = 512 << 20;
    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let g = m.guest.as_ref().unwrap();
    // 1 host bridge + 4 root ports + 4 endpoints.
    assert_eq!(g.pci_devs.len(), 9);
    let memdev_bdfs: Vec<String> = g
        .memdevs
        .iter()
        .map(|m| m.bdf.to_string())
        .collect();
    assert_eq!(memdev_bdfs.len(), 4);
    // Distinct BDFs, one per bus.
    let mut uniq = memdev_bdfs.clone();
    uniq.dedup();
    assert_eq!(uniq.len(), 4, "{memdev_bdfs:?}");
    // All four decoders committed over the same 4-way window.
    let window = g.memdevs[0].hpa_base;
    for (i, md) in g.memdevs.iter().enumerate() {
        assert_eq!(md.hpa_base, window);
        assert_eq!(md.window_ways, 4);
        assert_eq!(md.position, i);
        assert!(m.fabric.devices[i].component.decoder_committed(0));
        assert!(m.hb_components[i].decoder_committed(0));
    }
    // One interleaved zNUMA node covering the whole set.
    assert_eq!(g.cxl_nodes, vec![1]);
    assert_eq!(g.alloc.nodes[1].size, 2 << 30);
}

#[test]
fn flat_mode_merges_capacity_instead_of_znuma() {
    let mut m = Machine::new(SimConfig::default()).unwrap();
    m.boot(ProgModel::Flat).unwrap();
    let g = m.guest.as_ref().unwrap();
    assert_eq!(g.znuma_node(), None);
    // The flat extent exists and is online.
    let extra: u64 = g.alloc.nodes.iter().skip(2).map(|n| n.size).sum();
    let n1 = &g.alloc.nodes[1];
    // Node 1 (SRAT-declared, hotplug) stays offline in flat mode; the
    // extent was added as a new node with CPU affinity.
    assert!(!n1.online);
    assert_eq!(extra, SimConfig::default().cxl.mem_size);
}

#[test]
fn corrupted_acpi_fails_boot_loudly() {
    // Build a machine, corrupt the XSDT in guest-visible memory, and
    // check the guest refuses to boot rather than limping on.
    let cfg = SimConfig::default();
    let mut mem = PhysMem::new();
    let info = cxlramsim::bios::build(&cfg, &mut mem);
    // Corrupt one byte of every table in the pool; at least one parse
    // must fail (checksums catch it).
    let mut failures = 0;
    for off in (0..(info.tables_end - bios::layout::ACPI_POOL)).step_by(64) {
        let a = bios::layout::ACPI_POOL + off;
        let orig = mem.read_u32(a);
        mem.write_u32(a, orig ^ 0x5A);
        if guestos::acpi_parse::parse(&mem, 0xE0000 & !0xFFFF).is_err() {
            failures += 1;
        }
        mem.write_u32(a, orig);
    }
    assert!(failures > 0, "checksum corruption never detected");
}

#[test]
fn shipped_default_config_matches_schema_defaults() {
    // configs/default.toml documents every knob; it must parse and
    // reproduce the built-in defaults exactly so docs never drift.
    let text = std::fs::read_to_string("configs/default.toml").unwrap();
    let from_file = SimConfig::from_toml(&text, &[]).unwrap();
    let builtin = SimConfig::default();
    assert_eq!(from_file.cores, builtin.cores);
    assert_eq!(from_file.cpu_model, builtin.cpu_model);
    assert_eq!(from_file.l1.size, builtin.l1.size);
    assert_eq!(from_file.l2.size, builtin.l2.size);
    assert_eq!(from_file.l2.pf_degree, builtin.l2.pf_degree);
    assert_eq!(from_file.l2.prefetch, builtin.l2.prefetch);
    assert_eq!(from_file.sys_mem_size, builtin.sys_mem_size);
    assert_eq!(from_file.cxl.mem_size, builtin.cxl.mem_size);
    assert_eq!(from_file.cxl.pkt_lat_ns, builtin.cxl.pkt_lat_ns);
    assert_eq!(from_file.cxl.link_bw_gbps, builtin.cxl.link_bw_gbps);
    assert_eq!(from_file.cxl.credits, builtin.cxl.credits);
    assert_eq!(from_file.cxl.attach, builtin.cxl.attach);
    // And it boots.
    let mut m = Machine::new(from_file).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    assert_eq!(m.guest.as_ref().unwrap().znuma_node(), Some(1));
}

#[test]
fn cxl_cli_surface_reports_every_device() {
    let mut cfg = SimConfig::default();
    cfg.cxl.devices = 2;
    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let mds = m.guest.as_ref().unwrap().memdevs.clone();
    let mut world = m.mmio_world(0);
    for (i, md) in mds.iter().enumerate() {
        let listing =
            cxlramsim::guestos::cxlcli::cxl_list(&mut world, md, i)
                .unwrap();
        assert!(listing.contains(&format!("\"memdev\":\"mem{i}\"")));
        assert!(listing.contains("4294967296"));
        assert!(listing.contains(&format!("\"position\":{i}")));
    }
}
