//! Cross-host consistency harness for shared logical devices (CXL 3.x
//! back-invalidate coherence).
//!
//! Two layers of litmus:
//!
//! * **Device-level** — drive `CxlDevice::handle_m2s` directly with the
//!   classic litmus shapes (message passing, store buffer) and check
//!   the snoop filter's answers: who gets a BISnp, whether the dirty
//!   line is pulled home, and that a read which raced a foreign owner
//!   STALLS until the BI round trip completes — the structural reason a
//!   stale value can never be returned.
//! * **Machine-level** — boot two (and more) hosts onto one shared LD,
//!   run real workloads through caches/RC/links/switch, and gate the
//!   end-to-end counters against each other: every BISnp the device
//!   sent was delivered to a host cache, acked, and (for owned lines)
//!   carried the dirty data home. The whole exchange must be
//!   bit-identical at every `(threads, commit_lanes)` pair.
//!
//! The simulator models timing + coherence metadata, not data values,
//! so "every read returns the last globally committed write" is pinned
//! through the snoop filter's `version` counter (ground truth bumped on
//! each ownership grant) plus a reference model in the property test.

use cxlramsim::config::{CxlDevOverride, LdRef, SimConfig};
use cxlramsim::cxl::device::{BiRequest, CxlDevice, SnoopLine};
use cxlramsim::cxl::mem_proto::{self, CxlMemPacket};
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::sim::{MemCmd, Packet};
use cxlramsim::system::Machine;
use cxlramsim::util::rng::Rng;
use cxlramsim::workloads::{RandomAccess, Stream, StreamKernel, Workload};

// ---------------------------------------------------------------------------
// Device-level litmus: the snoop filter as an SC referee.
// ---------------------------------------------------------------------------

/// A 1-LD shared expander with two sharer hosts mapped at distinct HPA
/// bases onto the same DPA slice (what the machine's boot path commits).
fn shared_device() -> CxlDevice {
    let mut cfg = SimConfig::default().cxl;
    cfg.dev_overrides = vec![CxlDevOverride {
        lds: Some(1),
        shared_lds: Some(vec![0]),
        ..Default::default()
    }];
    let mut d = CxlDevice::new(&cfg, 7);
    d.configure_sharing(&[0], 2, 0);
    d.component.program_decoder_at(0, 4 << 30, 2 << 30, 0);
    d.component.program_decoder_at(1, 8 << 30, 2 << 30, 0);
    d.component
        .write32(cxlramsim::cxl::regs::comp::HDM_GLOBAL_CTRL, 0b10);
    d
}

/// Host `h`'s HPA for shared-line index `line` (64 B lines).
fn hpa(h: u8, line: u64) -> u64 {
    (if h == 0 { 4u64 << 30 } else { 8u64 << 30 }) + line * 64
}

fn rd(addr: u64) -> CxlMemPacket {
    mem_proto::packetize(&Packet::new(1, MemCmd::ReadReq, addr, 64, 0, 0), 1)
        .unwrap()
}

fn wb(addr: u64) -> CxlMemPacket {
    mem_proto::packetize(
        &Packet::new(1, MemCmd::WritebackDirty, addr, 64, 0, 0),
        1,
    )
    .unwrap()
}

fn rfo(addr: u64) -> CxlMemPacket {
    mem_proto::packetize_rfo(
        &Packet::new(1, MemCmd::WriteReq, addr, 64, 0, 0),
        1,
    )
}

/// Message passing: P0 writes data (line 0) then flag (line 1); P1
/// spins on the flag, then reads the data. Forbidden outcome: P1 sees
/// the new flag but stale data. Structurally: once host 0 owns both
/// lines, host 1's read of EITHER snoops the dirty copy home and stalls
/// behind the BI round trip — there is no interleaving in which the
/// data read is served from pre-write media after the flag read saw the
/// committed flag.
#[test]
fn litmus_message_passing_pulls_dirty_data_home() {
    let mut d = shared_device();
    // P0: w(data)=1; w(flag)=1 — two ownership grants.
    d.handle_m2s(0, &rfo(hpa(0, 0)), 0);
    d.handle_m2s(0, &rfo(hpa(0, 1)), 0);
    assert!(d.take_pending_bi().is_empty(), "no sharers yet: no BI");
    assert_eq!(d.snoop_line(0).version, 1);
    assert_eq!(d.snoop_line(64).version, 1);

    // P1: r(flag) — the flag's dirty copy must come home first.
    let (_, t_flag) = d.handle_m2s(1000, &rd(hpa(1, 1)), 1);
    assert_eq!(
        d.take_pending_bi(),
        vec![BiRequest { host: 0, dpa: 64, expect_dirty: true }]
    );
    // P1: r(data) — same for the data line. Seeing the flag cannot
    // outrun the data: both reads independently stall on the owner.
    let (_, t_data) = d.handle_m2s(1000, &rd(hpa(1, 0)), 1);
    assert_eq!(
        d.take_pending_bi(),
        vec![BiRequest { host: 0, dpa: 0, expect_dirty: true }]
    );
    // Both dirty lines land before the fills are served.
    let done_flag = d.handle_bi_rsp(1100, 64, true);
    let done_data = d.handle_bi_rsp(1100, 0, true);
    assert!(done_flag > 1100 && done_data > 1100, "dirty WB takes media time");
    assert_eq!(d.stats.ld_bi_dirty_wb[0].get(), 2);

    // An uncontended read of an idle line for comparison: the snooped
    // reads stalled a full BI round trip beyond it.
    let (_, t_idle) = d.handle_m2s(1000, &rd(hpa(1, 9)), 1);
    assert!(t_flag > t_idle && t_data > t_idle, "snooped reads must stall");

    // Final filter state: host 1 shares both lines, nobody owns them.
    for dpa in [0u64, 64] {
        let line = d.snoop_line(dpa);
        assert_eq!(line.owner, None);
        assert_eq!(line.sharers, 0b10);
        assert_eq!(line.version, 1, "reads never mint versions");
    }
}

/// Store buffer: P0 w(x)=1; r(y) || P1 w(y)=1; r(x). Under SC at least
/// one read sees the other's write. Structurally: the snoop filter
/// serializes the two RFOs (each a committed write), so whichever read
/// runs second finds a foreign owner, snoops the dirty line home, and
/// is served post-write media — `r(x)=0 && r(y)=0` is unreachable.
#[test]
fn litmus_store_buffer_serializes_ownership() {
    let mut d = shared_device();
    d.handle_m2s(0, &rfo(hpa(0, 0)), 0); // P0: w(x)
    d.handle_m2s(0, &rfo(hpa(1, 1)), 1); // P1: w(y)
    assert!(d.take_pending_bi().is_empty());
    assert_eq!(d.snoop_line(0).owner, Some(0));
    assert_eq!(d.snoop_line(64).owner, Some(1));

    // P0: r(y) — y's committed write comes home before the fill.
    d.handle_m2s(2000, &rd(hpa(0, 1)), 0);
    assert_eq!(
        d.take_pending_bi(),
        vec![BiRequest { host: 1, dpa: 64, expect_dirty: true }]
    );
    d.handle_bi_rsp(2100, 64, true);
    // P1: r(x) — symmetric.
    d.handle_m2s(2000, &rd(hpa(1, 0)), 1);
    assert_eq!(
        d.take_pending_bi(),
        vec![BiRequest { host: 0, dpa: 0, expect_dirty: true }]
    );
    d.handle_bi_rsp(2100, 0, true);

    // Both committed writes survived (versions intact), both lines now
    // shared by their reader, and both dirty copies were written back.
    assert_eq!(d.snoop_line(0).version, 1);
    assert_eq!(d.snoop_line(64).version, 1);
    assert_eq!(d.stats.ld_bi_dirty_wb[0].get(), 2);
    assert_eq!(d.stats.ld_bi_acks[0].get(), 2);
}

/// Dirty-writeback-on-BI: a clean sharer acks without data; an owner
/// acks with the line, and the media write is visible in the BIRsp
/// completion time.
#[test]
fn litmus_bi_ack_carries_data_only_when_owned() {
    let mut d = shared_device();
    // Clean sharer case: host 0 reads, host 1 RFOs — BI expects clean.
    d.handle_m2s(0, &rd(hpa(0, 0)), 0);
    d.take_pending_bi();
    d.handle_m2s(0, &rfo(hpa(1, 0)), 1);
    assert_eq!(
        d.take_pending_bi(),
        vec![BiRequest { host: 0, dpa: 0, expect_dirty: false }]
    );
    let done_clean = d.handle_bi_rsp(500, 0, false);
    assert_eq!(d.stats.ld_bi_dirty_wb[0].get(), 0, "clean ack: no WB");

    // Owner case: host 1 owns line 1; host 0's read snoops it dirty.
    d.handle_m2s(0, &rfo(hpa(1, 1)), 1);
    d.take_pending_bi();
    d.handle_m2s(0, &rd(hpa(0, 1)), 0);
    assert_eq!(
        d.take_pending_bi(),
        vec![BiRequest { host: 1, dpa: 64, expect_dirty: true }]
    );
    let done_dirty = d.handle_bi_rsp(500, 64, true);
    assert_eq!(d.stats.ld_bi_dirty_wb[0].get(), 1);
    assert!(
        done_dirty > done_clean,
        "the dirty ack pays the media write the clean ack skips"
    );
}

// ---------------------------------------------------------------------------
// Random-op property test: snoop filter vs. a reference MESI model.
// ---------------------------------------------------------------------------

/// Reference state of one line: the last committed write's version,
/// which host holds it Modified, and who may hold clean copies.
#[derive(Clone, Copy, Default)]
struct RefLine {
    version: u64,
    owner: Option<u8>,
    sharers: u64,
}

/// Drive random {read, rfo, writeback} ops from random hosts through
/// the device and mirror them in the reference model. After every op
/// the snoop filter must agree with the model exactly — which is
/// precisely the "every read observes the last globally committed
/// write" claim: a read either finds media current (no foreign owner)
/// or snoops the owner's dirty line home before being served.
#[test]
fn property_random_ops_track_reference_model() {
    let mut rng = Rng::new(0xb1_c0_17e5);
    let mut d = shared_device();
    const LINES: u64 = 8;
    let mut model = [RefLine::default(); LINES as usize];

    for step in 0..4000u32 {
        let h = rng.below(2) as u8;
        let line = rng.below(LINES);
        let dpa = line * 64;
        let m = &mut model[line as usize];
        match rng.below(3) {
            0 => {
                // Read: a foreign owner is snooped home (dirty).
                let (_, _) = d.handle_m2s(0, &rd(hpa(h, line)), h);
                let bi = d.take_pending_bi();
                match m.owner {
                    Some(o) if o != h => {
                        assert_eq!(
                            bi,
                            vec![BiRequest {
                                host: o,
                                dpa,
                                expect_dirty: true
                            }],
                            "step {step}: read must snoop the owner"
                        );
                        d.handle_bi_rsp(0, dpa, true);
                        m.sharers &= !(1u64 << o);
                        m.owner = None;
                    }
                    _ => assert!(
                        bi.is_empty(),
                        "step {step}: clean read must not snoop"
                    ),
                }
                m.sharers |= 1 << h;
            }
            1 => {
                // RFO: every other copy is invalidated; the grant is
                // the next globally committed write.
                let (_, _) = d.handle_m2s(0, &rfo(hpa(h, line)), h);
                let bi = d.take_pending_bi();
                let mut expect = m.sharers;
                if let Some(o) = m.owner {
                    expect |= 1 << o;
                }
                expect &= !(1u64 << h);
                let got: u64 =
                    bi.iter().fold(0, |acc, b| acc | 1 << b.host);
                assert_eq!(
                    got, expect,
                    "step {step}: RFO must BI exactly the stale copies"
                );
                for b in &bi {
                    assert_eq!(b.dpa, dpa);
                    assert_eq!(
                        b.expect_dirty,
                        m.owner == Some(b.host),
                        "step {step}: only the owner returns data"
                    );
                    d.handle_bi_rsp(0, dpa, b.expect_dirty);
                }
                m.version += 1;
                m.owner = Some(h);
                m.sharers = 1 << h;
            }
            _ => {
                // Writeback: the writer drops its copy; media becomes
                // current without any BI.
                let (_, _) = d.handle_m2s(0, &wb(hpa(h, line)), h);
                assert!(
                    d.take_pending_bi().is_empty(),
                    "step {step}: writeback must not snoop"
                );
                m.sharers &= !(1u64 << h);
                if m.owner == Some(h) {
                    m.owner = None;
                }
            }
        }
        let got = d.snoop_line(dpa);
        let want = SnoopLine {
            sharers: m.sharers,
            owner: m.owner,
            version: m.version,
        };
        assert_eq!(got, want, "step {step}: filter diverged from model");
    }
    // The walk really exercised the machinery.
    assert!(d.stats.ld_bi_sent[0].get() > 100);
    assert_eq!(
        d.stats.ld_bi_sent[0].get(),
        d.stats.ld_bi_acks[0].get(),
        "every snoop acked"
    );
}

// ---------------------------------------------------------------------------
// Machine-level: the full stack, bit-identical at every (threads, lanes).
// ---------------------------------------------------------------------------

/// Two hosts sharing one 256 MiB LD behind a switch.
fn shared_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.hosts = 2;
    cfg.cores = 2;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 256 << 20;
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides = vec![CxlDevOverride {
        lds: Some(1),
        shared_lds: Some(vec![0]),
        ..Default::default()
    }];
    cfg.host_lds = vec![
        vec![LdRef { dev: 0, ld: 0 }],
        vec![LdRef { dev: 0, ld: 0 }],
    ];
    cfg.seed = 99;
    cfg
}

fn run_shared(
    cfg: &SimConfig,
    threads: usize,
    lanes: usize,
    attach: impl Fn(&mut Machine),
) -> (String, Machine) {
    let mut cfg = cfg.clone();
    cfg.threads = threads;
    cfg.commit_lanes = lanes;
    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    attach(&mut m);
    m.run(None);
    m.verify().unwrap();
    (m.dump_stats().to_text(), m)
}

fn attach_producer_consumer(m: &mut Machine) {
    // Producer: read-write kernel on the shared node — every store is
    // an RFO through the snoop filter.
    let wl0 = Stream::for_wss(StreamKernel::Triad, m.cfg.l2.size, 2);
    m.attach_workloads_to(
        0,
        vec![Box::new(wl0)],
        &MemPolicy::Bind { nodes: vec![1] },
    )
    .unwrap();
    // Consumer: walks the same (overlapping) region of the same node.
    let wl1 = Stream::for_wss(StreamKernel::Triad, m.cfg.l2.size, 2);
    m.attach_workloads_to(
        1,
        vec![Box::new(wl1)],
        &MemPolicy::Bind { nodes: vec![1] },
    )
    .unwrap();
}

/// End-to-end message passing: producer stores are RFOs, consumer
/// caches get back-invalidated, dirty lines ride BIRsp acks home, and
/// the counters reconcile exactly across the whole fabric.
#[test]
fn shared_ld_counters_reconcile_end_to_end() {
    let cfg = shared_cfg();
    let (text, m) = run_shared(&cfg, 1, 1, attach_producer_consumer);
    let d = m.dump_stats();
    let get = |k: &str| d.get(k).unwrap_or(0.0) as u64;

    let bi_sent = get("cxl.dev0.ld0.bi_sent");
    let bi_acks = get("cxl.dev0.ld0.bi_acks");
    let bi_dirty = get("cxl.dev0.ld0.bi_dirty_wb");
    let inv0 = get("host0.sys.bi_invalidations");
    let inv1 = get("host1.sys.bi_invalidations");
    assert!(bi_sent > 0, "contended sharing must generate BISnps");
    assert_eq!(
        bi_sent,
        inv0 + inv1,
        "every BISnp sent must invalidate exactly one host cache"
    );
    assert_eq!(bi_sent, bi_acks, "every BISnp must be acked");
    assert!(bi_dirty > 0, "producer-owned lines must come home dirty");
    assert!(bi_dirty <= bi_acks);
    assert!(inv0 > 0 && inv1 > 0, "contention runs both directions");
    assert_eq!(get("cxl.dev0.ld0.sharers"), 2);

    let s = m.summary();
    assert_eq!(s.s2m_bisnp, bi_sent, "leaf links carry every BISnp");
    assert_eq!(s.m2s_birsp, bi_acks, "leaf links carry every BIRsp");
    assert!(text.contains("cxl.dev0.ld0.bi_sent"));

    // No line is left exclusively owned with foreign sharers, and no
    // sharer bit names a host outside the topology (filter sanity over
    // the touched working set).
    let dev = &m.fabric.devices[0];
    for line in 0..(16u64 << 20) / 64 {
        let sl = dev.snoop_line(line * 64);
        assert_eq!(sl.sharers & !0b11, 0, "ghost sharer on line {line}");
        if let Some(o) = sl.owner {
            assert_eq!(
                sl.sharers & !(1u64 << o),
                0,
                "line {line}: owner {o} coexists with foreign sharers"
            );
        }
    }
}

/// The acceptance gate: a 2-host shared-LD run is bit-identical across
/// threads x commit_lanes — BISnp/BIRsp traffic included — and repeat
/// runs reproduce the golden digest.
#[test]
fn shared_ld_golden_digest_across_threads_and_lanes() {
    let cfg = shared_cfg();
    let (golden, m) = run_shared(&cfg, 1, 1, attach_producer_consumer);
    assert!(
        m.summary().s2m_bisnp > 0,
        "golden run must exercise back-invalidates"
    );
    // 0 = auto lanes.
    for (threads, lanes) in [(1, 1), (1, 4), (4, 0), (4, 4)] {
        let (text, _) =
            run_shared(&cfg, threads, lanes, attach_producer_consumer);
        assert_eq!(
            text, golden,
            "shared-LD dump diverged at threads={threads} lanes={lanes}"
        );
    }
}

/// Random-op machine property: mixed random workloads over the shared
/// node must produce identical dumps at every (threads, lanes) — the
/// BI exchange is part of the deterministic event order, so identical
/// dumps mean every read observed the same committed-write history.
#[test]
fn random_shared_workloads_are_schedule_invariant() {
    let mut rng = Rng::new(0x5eed_5a1e);
    for case in 0..3u32 {
        let mut cfg = shared_cfg();
        cfg.seed = rng.next_u64();
        let seeds = [rng.next_u64(), rng.next_u64()];
        let kinds = [rng.below(2), rng.below(2)];
        let attach = |m: &mut Machine| {
            for h in 0..2usize {
                let wl: Box<dyn Workload> = match kinds[h] {
                    0 => Box::new(Stream::new(
                        StreamKernel::Triad,
                        16384,
                        1,
                    )),
                    _ => Box::new(RandomAccess::new(
                        1 << 20,
                        3000,
                        0.5,
                        seeds[h],
                    )),
                };
                m.attach_workloads_to(
                    h,
                    vec![wl],
                    &MemPolicy::Bind { nodes: vec![1] },
                )
                .unwrap();
            }
        };
        let (golden, gm) = run_shared(&cfg, 1, 1, attach);
        for (threads, lanes) in [(1, 4), (4, 0), (4, 4)] {
            let (text, _) = run_shared(&cfg, threads, lanes, attach);
            assert_eq!(
                text, golden,
                "case {case}: diverged at threads={threads} lanes={lanes}"
            );
        }
        // Both hosts really hit the shared LD.
        let d = gm.dump_stats();
        assert!(d.get("cxl.dev0.ld0.host0_reads").unwrap_or(0.0) > 0.0);
        assert!(d.get("cxl.dev0.ld0.host1_reads").unwrap_or(0.0) > 0.0);
    }
}

/// Three sharers: BI fan-out hits every stale copy exactly once and the
/// per-host invalidation counters sum to the device's send count.
#[test]
fn three_sharer_fanout_reconciles() {
    let mut cfg = shared_cfg();
    cfg.hosts = 3;
    cfg.host_lds = vec![
        vec![LdRef { dev: 0, ld: 0 }],
        vec![LdRef { dev: 0, ld: 0 }],
        vec![LdRef { dev: 0, ld: 0 }],
    ];
    let attach = |m: &mut Machine| {
        for h in 0..3usize {
            let wl: Box<dyn Workload> =
                Box::new(Stream::new(StreamKernel::Triad, 8192, 1));
            m.attach_workloads_to(
                h,
                vec![wl],
                &MemPolicy::Bind { nodes: vec![1] },
            )
            .unwrap();
        }
    };
    let (golden, m) = run_shared(&cfg, 1, 1, attach);
    let d = m.dump_stats();
    let get = |k: &str| d.get(k).unwrap_or(0.0) as u64;
    let bi_sent = get("cxl.dev0.ld0.bi_sent");
    assert!(bi_sent > 0);
    assert_eq!(
        bi_sent,
        get("host0.sys.bi_invalidations")
            + get("host1.sys.bi_invalidations")
            + get("host2.sys.bi_invalidations")
    );
    assert_eq!(get("cxl.dev0.ld0.sharers"), 3);
    let (t4, _) = run_shared(&cfg, 4, 4, attach);
    assert_eq!(t4, golden, "3-sharer run diverged at threads=4 lanes=4");
}
