//! Cross-layer consistency: the Rust detailed cache model vs the
//! AOT-compiled Pallas kernel (L3 vs L1), through the PJRT runtime.
//!
//! These tests are skipped (pass vacuously, with a notice) when
//! `artifacts/` has not been built.

use std::path::Path;

use cxlramsim::cache::CacheArray;
use cxlramsim::config::SimConfig;
use cxlramsim::coordinator::{capture_init_trace, warm_machine};
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::runtime::{CacheState, XlaRuntime};
use cxlramsim::system::Machine;
use cxlramsim::util::rng::Rng;
use cxlramsim::workloads::{Stream, StreamKernel};

fn runtime() -> Option<XlaRuntime> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("cross_layer: artifacts/ not built — skipping");
        return None;
    }
    // Also skip (not fail) when the runtime can't come up — e.g. the
    // crate was built without the `xla` feature, where load() reports
    // the stub error even with artifacts present.
    match XlaRuntime::load(dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("cross_layer: runtime unavailable ({e}) — skipping");
            None
        }
    }
}

/// Drive the detailed CacheArray and the Pallas kernel with the same
/// single-level access stream; final tag state must agree exactly.
#[test]
fn detailed_and_kernel_agree_on_final_state() {
    let Some(rt) = runtime() else { return };
    let cfg = SimConfig::default();
    let man = &rt.manifest;

    let mut rust_l1 = CacheArray::new(&cfg.l1);
    // L2 "sink" kernel state stays cold by masking: use a stream that
    // always L1-misses? Simpler: compare the *L1* state after a stream
    // where L2 effects don't feed back into L1 (they don't: L1 state
    // evolves only on probe/fill).
    let l1 = CacheState::cold(man.l1_sets, man.l1_ways);
    let l2 = CacheState::cold(man.l2_sets, man.l2_ways);

    let mut rng = Rng::new(42);
    let n = 1024;
    let addrs: Vec<i32> =
        (0..n).map(|_| rng.below(4096) as i32).collect();
    let writes: Vec<i32> =
        (0..n).map(|_| rng.chance(0.3) as i32).collect();

    // Kernel side (one window is enough: n <= window).
    let r = rt.cache_warm(&addrs, &writes, 1, &l1, &l2).unwrap();

    // Rust side: probe + fill on miss, write-allocate (same policy).
    for (&a, &w) in addrs.iter().zip(&writes) {
        let pa = (a as u64) * cfg.l1.line;
        let is_w = w == 1;
        let pr = rust_l1.probe(pa, is_w);
        if pr.access == cxlramsim::cache::Access::Miss {
            let st = if is_w {
                cxlramsim::cache::MesiState::Modified
            } else {
                cxlramsim::cache::MesiState::Exclusive
            };
            rust_l1.fill(pa, st);
        } else if pr.needs_upgrade {
            rust_l1.finish_upgrade(pa);
        }
    }

    // Compare resident sets + dirty bits (LRU stamps differ in value
    // but induce the same order, checked via victim agreement below).
    let (tags, valid, dirty, _lru) = rust_l1.export_state();
    assert_eq!(valid, r.l1.valid, "valid maps diverge");
    for i in 0..tags.len() {
        if valid[i] == 1 {
            assert_eq!(tags[i], r.l1.tags[i], "tag diverges at {i}");
            assert_eq!(dirty[i], r.l1.dirty[i], "dirty diverges at {i}");
        }
    }

    // Victim agreement: import kernel state into a fresh array and
    // evict from every set — both must choose the same victim.
    let mut imported = CacheArray::new(&cfg.l1);
    imported.import_state(&r.l1.tags, &r.l1.valid, &r.l1.dirty, &r.l1.lru);
    for set in 0..man.l1_sets {
        // Address mapping to this set with a brand-new tag.
        let line = (10_000 * man.l1_sets + set) as u64;
        let pa = line * cfg.l1.line;
        let va = rust_l1.fill(pa, cxlramsim::cache::MesiState::Exclusive);
        let vb = imported.fill(pa, cxlramsim::cache::MesiState::Exclusive);
        assert_eq!(va, vb, "victim choice diverges in set {set}");
    }
}

/// Warming a machine through the runtime then running the measured
/// region must (a) keep functional correctness and (b) start warm.
#[test]
fn warmed_machine_starts_hot_and_verifies() {
    let Some(rt) = runtime() else { return };
    let mut cfg = SimConfig::default();
    cfg.cores = 1;
    let mut m = Machine::new(cfg.clone()).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    // WSS well under L2: after warming, the measured pass re-hits L2.
    let wl = Stream::new(StreamKernel::Copy, 8192, 1);
    m.attach_workloads(
        vec![Box::new(wl)],
        &MemPolicy::Bind { nodes: vec![0] },
    )
    .unwrap();
    let trace = capture_init_trace(&mut m, 0).unwrap();
    // Copy initializes only its source array `a`; the destination `c`
    // first-touch faults during the timed run (see Stream::init_data).
    assert_eq!(trace.len(), 8192, "init touches the source array");
    let warm = warm_machine(&mut m, &rt, 0, &trace).unwrap();
    assert!(warm.l2_occupancy > 0);

    let before_l2_miss = m.l2.stats.misses.get();
    let s = m.run(None);
    m.verify().unwrap();
    let run_misses = m.l2.stats.misses.get() - before_l2_miss;
    // The warmed source array (64 KiB, fits the 1 MiB L2) re-hits;
    // only the cold destination lines may miss, so misses stay well
    // under the all-cold level (every line of both arrays missing).
    let run_accesses = run_misses + m.l2.stats.hits.get();
    assert!(
        (run_misses as f64) < 0.6 * run_accesses as f64,
        "warm start should hit L2 on the warmed source: \
         {run_misses}/{run_accesses}"
    );
    assert!(s.ticks > 0);
}

/// Geometry mismatch must be rejected loudly, not silently mis-warm.
#[test]
fn geometry_mismatch_is_an_error() {
    let Some(rt) = runtime() else { return };
    let mut cfg = SimConfig::default();
    cfg.l2.size = 2 << 20; // 2 MiB != artifact geometry
    cfg.cores = 1;
    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let wl = Stream::new(StreamKernel::Copy, 256, 1);
    m.attach_workloads(
        vec![Box::new(wl)],
        &MemPolicy::Bind { nodes: vec![0] },
    )
    .unwrap();
    let trace = capture_init_trace(&mut m, 0).unwrap();
    let err = warm_machine(&mut m, &rt, 0, &trace).unwrap_err();
    assert!(err.to_string().contains("geometry"), "{err}");
}
