//! Multi-host MLD pooling: FM-API bind/unbind properties, 2-host boot
//! isolation, and the 2-host golden bitwise-determinism run.

use cxlramsim::config::{CxlDevOverride, LdRef, SimConfig, MAX_HOSTS};
use cxlramsim::cxl::mailbox::{opcode, retcode, Mailbox, MemdevState,
                              UNBOUND};
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::util::prop::check;
use cxlramsim::util::rng::Rng;
use cxlramsim::workloads::{Stream, StreamKernel};

// ---- FM bind/unbind state machine --------------------------------------

/// Random bind/unbind sequences against the mailbox surface must keep
/// LD↔host ownership exclusive (a bound LD can't be re-bound until
/// unbound) and exactly mirror a reference model.
#[test]
fn prop_bind_unbind_exclusive_under_random_sequences() {
    const LDS: usize = 4;
    check(
        "fm-bind-exclusive",
        200,
        |r: &mut Rng| {
            (0..r.range(1, 60))
                .map(|_| (r.below(LDS as u64 + 2), r.below(6)))
                .collect::<Vec<(u64, u64)>>()
        },
        |ops| {
            let mut mb = Mailbox::new(MemdevState::new_mld(
                (LDS as u64) * (256 << 20),
                1,
                LDS as u16,
            ));
            let mut model: Vec<Option<u16>> = vec![None; LDS];
            for &(ld, action) in ops {
                if action < 4 {
                    // BIND_LD ld -> host `action`.
                    let host = action as u16;
                    let mut p = [0u8; 4];
                    p[0..2].copy_from_slice(&(ld as u16).to_le_bytes());
                    p[2..4].copy_from_slice(&host.to_le_bytes());
                    let (code, _) = mb.run_command(opcode::BIND_LD, &p);
                    let expect = if ld >= LDS as u64 {
                        retcode::INVALID_INPUT
                    } else if model[ld as usize].is_some() {
                        retcode::BUSY // exclusivity
                    } else {
                        model[ld as usize] = Some(host);
                        retcode::SUCCESS
                    };
                    if code != expect {
                        return Err(format!(
                            "bind(ld={ld}, host={host}): code {code:#x}, \
                             expected {expect:#x}"
                        ));
                    }
                } else {
                    // UNBIND_LD ld.
                    let p = (ld as u16).to_le_bytes();
                    let (code, _) = mb.run_command(opcode::UNBIND_LD, &p);
                    let expect = if ld >= LDS as u64
                        || model[ld as usize].is_none()
                    {
                        retcode::INVALID_INPUT
                    } else {
                        model[ld as usize] = None;
                        retcode::SUCCESS
                    };
                    if code != expect {
                        return Err(format!(
                            "unbind(ld={ld}): code {code:#x}, expected \
                             {expect:#x}"
                        ));
                    }
                }
                // Device state must mirror the model after every op.
                let device: Vec<Option<u16>> = mb
                    .state
                    .ld_owner
                    .iter()
                    .map(|&o| if o == UNBOUND { None } else { Some(o) })
                    .collect();
                if device != model {
                    return Err(format!(
                        "state diverged: device {device:?} vs model \
                         {model:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Config-driven FM binding is total: after machine construction every
/// logical device of every expander has exactly the owner the window
/// assignment dictates.
#[test]
fn config_binding_is_total_and_matches_assignment() {
    let mut cfg = SimConfig::default();
    cfg.hosts = 2;
    cfg.cores = 1;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 1 << 30;
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides =
        vec![CxlDevOverride { lds: Some(4), ..Default::default() }];
    let hosts = cfg.window_hosts();
    assert_eq!(hosts, vec![0, 1, 0, 1], "round-robin over 4 LD windows");
    let m = Machine::new(cfg).unwrap();
    let owners = &m.fabric.devices[0].mailbox.state.ld_owner;
    assert_eq!(owners.len(), 4);
    for (ld, &owner) in owners.iter().enumerate() {
        assert_ne!(owner, UNBOUND, "ld{ld} unbound — binding not total");
        assert_eq!(owner as usize, hosts[ld]);
        assert!((owner as usize) < MAX_HOSTS);
    }
}

// ---- 2-host boot isolation ---------------------------------------------

fn pooled_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.hosts = 2;
    cfg.cores = 2;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 1 << 30; // 4 x 256 MiB LD slices
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides =
        vec![CxlDevOverride { lds: Some(4), ..Default::default() }];
    cfg.seed = 13;
    cfg
}

#[test]
fn two_host_boot_onlines_exactly_its_bound_lds() {
    let mut m = Machine::new(pooled_cfg()).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    // Round-robin: host 0 owns LDs {0, 2}, host 1 owns {1, 3}.
    for (h, want_lds) in [(0usize, vec![0u16, 2]), (1, vec![1, 3])] {
        let g = m.hosts[h].guest.as_ref().unwrap();
        let got: Vec<u16> = g.memdevs.iter().map(|md| md.ld).collect();
        assert_eq!(got, want_lds, "host {h} bound the wrong LDs");
        assert!(g.memdevs.iter().all(|md| md.lds == 4));
        assert_eq!(g.cxl_nodes, vec![1, 2], "two zNUMA nodes per host");
        assert!(g.alloc.nodes[1].online && !g.alloc.nodes[1].has_cpus);
        assert_eq!(g.alloc.nodes[1].size, 256 << 20);
        // The guest knows which host it is (driver used it for the
        // FM-API allocation query).
        assert_eq!(g.host as usize, h);
    }
    // Every host's windows are disjoint from every other's — the
    // property that keeps the shared device's decoders unambiguous.
    let mut spans: Vec<(u64, u64)> = m
        .hosts
        .iter()
        .flat_map(|h| h.bios.cxl_windows.iter().copied())
        .collect();
    spans.sort_unstable();
    for pair in spans.windows(2) {
        assert!(
            pair[0].0 + pair[0].1 <= pair[1].0,
            "windows overlap: {pair:?}"
        );
    }
}

#[test]
fn explicit_ld_assignment_reaches_guests() {
    let mut cfg = pooled_cfg();
    // Invert the default round-robin via explicit lists.
    cfg.host_lds = vec![
        vec![
            LdRef { dev: 0, ld: 1 },
            LdRef { dev: 0, ld: 3 },
        ],
        vec![
            LdRef { dev: 0, ld: 0 },
            LdRef { dev: 0, ld: 2 },
        ],
    ];
    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let lds_of = |h: usize| -> Vec<u16> {
        m.hosts[h]
            .guest
            .as_ref()
            .unwrap()
            .memdevs
            .iter()
            .map(|md| md.ld)
            .collect()
    };
    assert_eq!(lds_of(0), vec![1, 3]);
    assert_eq!(lds_of(1), vec![0, 2]);
}

// ---- 2-host golden determinism -----------------------------------------

fn run_two_host_pooled() -> (u64, u64, u64, u64, Vec<u64>, String) {
    let mut m = Machine::new(pooled_cfg()).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    for h in 0..2 {
        let a = Stream::new(StreamKernel::Triad, 8192, 1);
        let b = Stream::new(StreamKernel::Copy, 4096, 1);
        m.attach_workloads_to(
            h,
            vec![Box::new(a), Box::new(b)],
            &MemPolicy::Interleave { weights: vec![(1, 1), (2, 1)] },
        )
        .unwrap();
    }
    let s = m.run(None);
    m.verify().unwrap();
    (
        s.ticks,
        s.events,
        s.dram_accesses,
        s.cxl_accesses,
        s.cxl_dev_fills.clone(),
        m.dump_stats().to_text(),
    )
}

#[test]
fn golden_two_host_runs_are_bitwise_identical() {
    let a = run_two_host_pooled();
    let b = run_two_host_pooled();
    assert_eq!(a.0, b.0, "ticks diverged");
    assert_eq!(a.1, b.1, "event counts diverged");
    assert_eq!(a.2, b.2, "dram accesses diverged");
    assert_eq!(a.3, b.3, "cxl accesses diverged");
    assert_eq!(a.4, b.4, "per-device fills diverged");
    assert_eq!(a.5, b.5, "full stat dump diverged");
    // Both hosts really drove the shared device.
    assert!(a.3 > 0);
    assert!(a.5.contains("cxl.dev0.ld0.host0_reads"));
    assert!(a.5.contains("cxl.dev0.ld1.host1_reads"));
    assert!(a.5.contains("host0.l2.hits"));
    assert!(a.5.contains("host1.l2.hits"));
}
