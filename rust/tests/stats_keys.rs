//! docs/STATS.md coverage gate: every stat key a fully-loaded run
//! emits must match a documented pattern.
//!
//! The run below deliberately lights up every emitter: two hosts over
//! a switched 2-LD MLD (host prefixes, switch + link + per-LD + host-
//! attribution keys), DRAM+CXL interleaved traffic (both memory
//! classes, writebacks), the default L2 prefetcher, and a runtime FM
//! re-bind (rebinds + hot-plug event counters). Emitted keys are
//! normalized (indices -> `{N}`-style placeholders, `host{H}.` prefix
//! stripped) and looked up in the set of backtick patterns parsed out
//! of docs/STATS.md.

use cxlramsim::config::{
    CxlDevOverride, FmEventDef, FmPolicyConfig, FmPolicyKind, LdRef,
    SimConfig,
};
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::stats::StatDump;
use cxlramsim::system::Machine;
use cxlramsim::trace::Recorder;
use cxlramsim::workloads::{Serve, ServeConfig, Stream, StreamKernel};

/// Expand one-level `{a,b,c}` alternation groups in a documented
/// pattern (placeholders like `{N}` contain no comma and are left
/// alone). `dram.latency_ticks.{count,mean,p50,p99}` -> four patterns.
fn expand(pattern: &str, out: &mut Vec<String>) {
    let Some(open) = pattern.find('{') else {
        out.push(pattern.to_string());
        return;
    };
    let Some(close) = pattern[open..].find('}').map(|i| i + open) else {
        out.push(pattern.to_string());
        return;
    };
    let inner = &pattern[open + 1..close];
    if !inner.contains(',') {
        // A placeholder — skip past it and keep expanding the tail.
        let mut tails = Vec::new();
        expand(&pattern[close + 1..], &mut tails);
        for t in tails {
            out.push(format!("{}{t}", &pattern[..close + 1]));
        }
        return;
    }
    for alt in inner.split(',') {
        let candidate =
            format!("{}{}{}", &pattern[..open], alt, &pattern[close + 1..]);
        expand(&candidate, out);
    }
}

/// Every backtick span in STATS.md that looks like a stat-key pattern.
fn documented_patterns(md: &str) -> std::collections::BTreeSet<String> {
    let mut set = std::collections::BTreeSet::new();
    for raw in md.split('`').skip(1).step_by(2) {
        if raw.contains(' ') || !raw.contains('.') {
            continue; // prose code span, not a key pattern
        }
        let mut expanded = Vec::new();
        expand(raw, &mut expanded);
        set.extend(expanded);
    }
    set
}

/// Normalize an emitted key to its documented pattern: strip a
/// `host{H}.` prefix, replace per-instance indices with placeholders.
fn all_digits(s: &str) -> bool {
    !s.is_empty() && s.chars().all(|c| c.is_ascii_digit())
}

fn normalize(key: &str) -> String {
    let all: Vec<&str> = key.split('.').collect();
    let segs: &[&str] = match all[0].strip_prefix("host") {
        Some(rest) if all_digits(rest) => &all[1..],
        _ => &all[..],
    };
    let mut out: Vec<String> = Vec::new();
    let mut prev = "";
    for &s in segs {
        let digits_after = |pre: &str| {
            s.strip_prefix(pre).is_some_and(all_digits)
        };
        let mapped = if digits_after("core") {
            "core{C}".to_string()
        } else if digits_after("dev") {
            "dev{N}".to_string()
        } else if digits_after("ld") {
            "ld{K}".to_string()
        } else if digits_after("sw") {
            "sw{M}".to_string()
        } else if digits_after("link") {
            "link{N}".to_string()
        } else if prev == "l1" && all_digits(s) {
            "{C}".to_string()
        } else if let Some((head, tail)) = s.split_once('_') {
            // host attribution suffixes: host0_reads -> host{H}_reads
            match head.strip_prefix("host") {
                Some(idx) if all_digits(idx) => {
                    format!("host{{H}}_{tail}")
                }
                _ => s.to_string(),
            }
        } else {
            s.to_string()
        };
        out.push(mapped);
        prev = s;
    }
    out.join(".")
}

#[test]
fn every_emitted_stat_key_is_documented() {
    let md = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/docs/STATS.md"
    ))
    .expect("docs/STATS.md must exist");
    let documented = documented_patterns(&md);
    assert!(
        documented.len() > 40,
        "suspiciously few documented patterns: {}",
        documented.len()
    );

    let mut cfg = SimConfig::default();
    cfg.hosts = 2;
    cfg.cores = 2;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 512 << 20;
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides =
        vec![CxlDevOverride { lds: Some(2), ..Default::default() }];
    cfg.host_lds = vec![
        vec![LdRef { dev: 0, ld: 0 }, LdRef { dev: 0, ld: 1 }],
        vec![],
    ];
    cfg.fm_events = vec![
        FmEventDef::parse("@20us unbind dev0.ld1").unwrap(),
        FmEventDef::parse("@25us bind dev0.ld1 host1").unwrap(),
    ];
    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    // DRAM + CXL mix on host 0 (writebacks both ways), hot-added CXL
    // traffic on host 1.
    let wl0 = Stream::new(StreamKernel::Triad, 8192, 1);
    m.attach_workloads_to(
        0,
        vec![Box::new(wl0)],
        &MemPolicy::Interleave { weights: vec![(0, 1), (1, 1)] },
    )
    .unwrap();
    let wl1 = Stream::new(StreamKernel::Triad, 16384, 1);
    m.attach_workloads_to(
        1,
        vec![Box::new(wl1)],
        &MemPolicy::Preferred { node: 2 },
    )
    .unwrap();
    m.run(None);
    m.verify().unwrap();

    let d = m.dump_stats();
    assert!(d.entries.len() > 100, "run did not light up the emitters");
    // The interesting families really are present in this run.
    for probe in [
        "sim.par.epochs",
        "sim.par.barrier_waits",
        "sim.par.horizon_ns_min",
        "host0.l2.pf.issued",
        "host1.sys.mem_online_events",
        "cxl.sw0.us_link.credit_wait.p99",
        "cxl.dev0.ld1.host1_reads",
        "cxl.dev0.ld1.rebinds",
        "cxl.dev0.media.latency_ticks.p50",
    ] {
        assert!(d.get(probe).is_some(), "expected emitter missing: {probe}");
    }

    assert_documented(&d, &documented);
}

/// Every emitted key must normalize to a documented pattern.
fn assert_documented(
    d: &StatDump,
    documented: &std::collections::BTreeSet<String>,
) {
    let mut undocumented = Vec::new();
    for (key, _) in &d.entries {
        let pat = normalize(key);
        if !documented.contains(&pat) {
            undocumented.push(format!("{key}  (pattern {pat})"));
        }
    }
    assert!(
        undocumented.is_empty(),
        "stat keys emitted but not documented in docs/STATS.md:\n  {}",
        undocumented.join("\n  ")
    );
}

#[test]
fn policy_run_stat_keys_are_documented() {
    // The `[fm] policy` closed loop emits the fm.policy.* family (and
    // exercises occupancy_wait on contended links); its dump must also
    // be fully covered by docs/STATS.md.
    let md = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/docs/STATS.md"
    ))
    .expect("docs/STATS.md must exist");
    let documented = documented_patterns(&md);

    let mut cfg = SimConfig::default();
    cfg.hosts = 2;
    cfg.cores = 1;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 512 << 20;
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides =
        vec![CxlDevOverride { lds: Some(2), ..Default::default() }];
    cfg.host_lds = vec![
        vec![LdRef { dev: 0, ld: 0 }, LdRef { dev: 0, ld: 1 }],
        vec![],
    ];
    cfg.fm_policy =
        Some(FmPolicyConfig::new(FmPolicyKind::CapacityRebalance));
    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let wl0 = Stream::new(StreamKernel::Copy, 8192, 1);
    m.attach_workloads_to(
        0,
        vec![Box::new(wl0)],
        &MemPolicy::Bind { nodes: vec![1] },
    )
    .unwrap();
    let wl1 = Stream::new(StreamKernel::Triad, 16384, 1);
    m.attach_workloads_to(
        1,
        vec![Box::new(wl1)],
        &MemPolicy::Preferred { node: 2 },
    )
    .unwrap();
    m.run(None);
    m.verify().unwrap();

    let d = m.dump_stats();
    for probe in [
        "fm.policy.epochs",
        "fm.policy.decisions",
        "fm.policy.holds",
        "host1.sys.numa_fallback_allocs",
        "cxl.sw0.us_link.occupancy_wait.count",
        "cxl.link0.occupancy_wait.p99",
    ] {
        assert!(d.get(probe).is_some(), "expected emitter missing: {probe}");
    }
    assert!(d.get("fm.policy.epochs").unwrap() > 0.0);
    assert_documented(&d, &documented);
}

#[test]
fn serve_and_replay_stat_keys_are_documented() {
    // The serving workload (`serve.*` family incl. latency percentiles)
    // and trace replay (`trace.*` family) are the newest emitters; both
    // dumps must be fully covered by docs/STATS.md.
    let md = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/docs/STATS.md"
    ))
    .expect("docs/STATS.md must exist");
    let documented = documented_patterns(&md);

    let mut cfg = SimConfig::default();
    cfg.hosts = 2;
    cfg.cores = 1;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 512 << 20;
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides =
        vec![CxlDevOverride { lds: Some(2), ..Default::default() }];
    cfg.host_lds = vec![
        vec![LdRef { dev: 0, ld: 0 }],
        vec![LdRef { dev: 0, ld: 1 }],
    ];
    let scfg = ServeConfig {
        users: 64,
        zipf_s: 1.1,
        requests: 40,
        kv_block: 256,
        context_blocks: 2,
        dram_slots: 8,
        cxl_slots: 16,
        decode_work: 16,
    };
    let mut m = Machine::new(cfg.clone()).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let rec = Recorder::new();
    for h in 0..2 {
        let (hot, cold) =
            m.hosts[h].guest.as_ref().unwrap().alloc.tier_policies();
        let wl = Box::new(Serve::new(scfg.clone(), hot, cold, 7 + h as u64));
        m.attach_workloads_to(
            h,
            vec![rec.wrap(h, 0, wl)],
            &MemPolicy::Local { home: 0 },
        )
        .unwrap();
    }
    m.run(None);
    let d = m.dump_stats();
    for probe in [
        "host0.serve.requests",
        "host0.serve.tier_hits",
        "host1.serve.tier_misses",
        "host1.serve.evictions",
        "host0.serve.p50_ns",
        "host1.serve.p99_ns",
    ] {
        assert!(d.get(probe).is_some(), "expected emitter missing: {probe}");
    }
    assert_documented(&d, &documented);

    // Replay the captured trace: the `trace.*` family must be
    // documented too.
    let t = rec.take();
    let mut m2 = Machine::new(cfg).unwrap();
    m2.boot(ProgModel::Znuma).unwrap();
    cxlramsim::coordinator::attach_replay(&mut m2, &t).unwrap();
    m2.run(None);
    let d2 = m2.dump_stats();
    for probe in ["host0.trace.replay_ops", "host1.trace.replay_vmas"] {
        assert!(
            d2.get(probe).is_some(),
            "expected emitter missing: {probe}"
        );
    }
    assert_documented(&d2, &documented);
}

#[test]
fn sharing_run_stat_keys_are_documented() {
    // A shared-LD (CXL 3.x back-invalidate) run lights up the sharing
    // emitters: per-LD snoop-filter counters on the device, the
    // BISnp/BIRsp channel counters on every link block, the host-side
    // invalidation counter, and — with `[fm] policy` configured — the
    // differentiated BI-rate signal. All of it must be covered by
    // docs/STATS.md.
    let md = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/docs/STATS.md"
    ))
    .expect("docs/STATS.md must exist");
    let documented = documented_patterns(&md);

    let mut cfg = SimConfig::default();
    cfg.hosts = 2;
    cfg.cores = 2;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 256 << 20;
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides = vec![CxlDevOverride {
        lds: Some(1),
        shared_lds: Some(vec![0]),
        ..Default::default()
    }];
    cfg.host_lds = vec![
        vec![LdRef { dev: 0, ld: 0 }],
        vec![LdRef { dev: 0, ld: 0 }],
    ];
    cfg.fm_policy =
        Some(FmPolicyConfig::new(FmPolicyKind::CapacityRebalance));
    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    // Both hosts hammer the same shared node: stores RFO through the
    // snoop filter, peer copies get back-invalidated.
    for h in 0..2 {
        let wl = Stream::new(StreamKernel::Triad, 16384, 1);
        m.attach_workloads_to(
            h,
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![1] },
        )
        .unwrap();
    }
    m.run(None);
    m.verify().unwrap();

    let d = m.dump_stats();
    for probe in [
        "cxl.dev0.ld0.sharers",
        "cxl.dev0.ld0.bi_sent",
        "cxl.dev0.ld0.bi_acks",
        "cxl.dev0.ld0.bi_dirty_wb",
        "host0.sys.bi_invalidations",
        "host1.sys.bi_invalidations",
        "cxl.link0.s2m_bisnp",
        "cxl.link0.m2s_birsp",
        "cxl.sw0.us_link.s2m_bisnp",
        "fm.policy.bi_rate_last",
    ] {
        assert!(d.get(probe).is_some(), "expected emitter missing: {probe}");
    }
    assert!(
        d.get("cxl.dev0.ld0.bi_sent").unwrap() > 0.0,
        "sharing run generated no back-invalidates"
    );
    assert_eq!(d.get("cxl.dev0.ld0.sharers"), Some(2.0));
    assert_documented(&d, &documented);
}

#[test]
fn wall_clock_keys_live_outside_the_deterministic_dump() {
    // The sim.par.*_ns phase timers measure *host* wall-clock, so they
    // differ run-to-run: they must never appear in `dump_stats` (the
    // dump golden digests and the determinism harness compare) — only
    // in `dump_stats_full`, where they are documented keys like any
    // other.
    let md = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/docs/STATS.md"
    ))
    .expect("docs/STATS.md must exist");
    let documented = documented_patterns(&md);

    let mut cfg = SimConfig::default();
    cfg.cores = 1;
    cfg.sys_mem_size = 128 << 20;
    cfg.cxl.mem_size = 256 << 20;
    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let wl = Stream::new(StreamKernel::Copy, 4096, 1);
    m.attach_workloads_to(
        0,
        vec![Box::new(wl)],
        &MemPolicy::Interleave { weights: vec![(0, 1), (1, 1)] },
    )
    .unwrap();
    m.run(None);
    m.verify().unwrap();

    let det = m.dump_stats();
    let full = m.dump_stats_full();
    for probe in
        ["sim.par.drain_ns", "sim.par.commit_ns", "sim.par.merge_ns"]
    {
        assert!(
            det.get(probe).is_none(),
            "wall-clock key {probe} leaked into the deterministic dump"
        );
        assert!(
            full.get(probe).is_some(),
            "wall-clock key {probe} missing from the full dump"
        );
    }
    // The run did real work, so at least one phase accumulated time.
    let spent: f64 = ["sim.par.drain_ns", "sim.par.commit_ns"]
        .iter()
        .map(|k| full.get(k).unwrap())
        .sum();
    assert!(spent > 0.0, "phase timers never accumulated");
    // The full dump is the deterministic dump plus the timer keys, and
    // every key in it (timers included) is documented.
    assert_eq!(full.entries.len(), det.entries.len() + 3);
    assert_documented(&full, &documented);
}

#[test]
fn normalize_maps_representative_keys() {
    assert_eq!(normalize("host1.core0.loads"), "core{C}.loads");
    assert_eq!(normalize("host0.l1.3.miss_rate"), "l1.{C}.miss_rate");
    assert_eq!(normalize("l2.pf.useful"), "l2.pf.useful");
    assert_eq!(
        normalize("cxl.dev2.ld1.host3_writes"),
        "cxl.dev{N}.ld{K}.host{H}_writes"
    );
    assert_eq!(
        normalize("cxl.sw1.us_link.credit_wait.p99"),
        "cxl.sw{M}.us_link.credit_wait.p99"
    );
    assert_eq!(normalize("cxl.link0.flits"), "cxl.link{N}.flits");
    assert_eq!(normalize("sys.events"), "sys.events");
    assert_eq!(
        normalize("host0.cxl.dev0.fills"),
        "cxl.dev{N}.fills"
    );
}
