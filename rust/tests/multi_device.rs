//! Multi-device interleaving properties + the determinism golden test.
//!
//! Property tests ride on the in-tree mini framework
//! (`cxlramsim::util::prop`): the event queue's equal-tick FIFO
//! contract and the interleave decoder's totality/balance, which the
//! whole multi-device memory path rests on.

use cxlramsim::config::SimConfig;
use cxlramsim::cxl::HdmWindow;
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::sim::EventQueue;
use cxlramsim::system::Machine;
use cxlramsim::util::prop::check;
use cxlramsim::util::rng::Rng;
use cxlramsim::workloads::{Stream, StreamKernel};

// ---- event queue: deterministic tie-breaking ---------------------------

#[test]
fn prop_eventq_equal_ticks_fire_in_insertion_order() {
    check(
        "eventq-insertion-order",
        300,
        |r: &mut Rng| {
            // Many collisions: ticks drawn from a tiny range.
            (0..r.range(2, 80)).map(|_| r.below(8)).collect::<Vec<u64>>()
        },
        |ticks| {
            let mut q = EventQueue::new();
            for (i, &t) in ticks.iter().enumerate() {
                q.schedule_at(t, i);
            }
            let mut prev: Option<(u64, usize)> = None;
            while let Some((t, idx)) = q.pop() {
                if let Some((pt, pidx)) = prev {
                    if t < pt {
                        return Err(format!("tick regressed {pt} -> {t}"));
                    }
                    if t == pt && idx < pidx {
                        return Err(format!(
                            "equal tick {t}: event {idx} fired after {pidx}"
                        ));
                    }
                }
                prev = Some((t, idx));
            }
            Ok(())
        },
    );
}

// ---- interleave decoder ------------------------------------------------

fn window(ways: usize, granularity: u64, xor: bool) -> HdmWindow {
    HdmWindow {
        base: 4 << 30,
        size: 4 << 30,
        granularity,
        targets: (0..ways).collect::<Vec<_>>().into(),
        xor,
        dpa_base: 0,
    }
}

#[test]
fn prop_every_line_maps_to_exactly_one_device() {
    check(
        "interleave-total",
        400,
        |r: &mut Rng| {
            let ways = 1usize << r.range(0, 4); // 1, 2, 4, 8
            let gran = 256u64 << r.range(0, 5); // 256 .. 4096
            let addr_off = r.below(4 << 30) & !63;
            (ways, (gran, (addr_off, r.chance(0.5))))
        },
        |&(ways, (gran, (off, xor)))| {
            // Shrinking may propose out-of-domain shapes; skip them.
            if ways == 0 || !ways.is_power_of_two() || ways > 16 {
                return Ok(());
            }
            if gran < 256 || !gran.is_power_of_two() {
                return Ok(());
            }
            let w = window(ways, gran, xor);
            let addr = w.base + off;
            let slot = w.slot(addr);
            if slot >= ways {
                return Err(format!("slot {slot} out of range ({ways})"));
            }
            // The whole cache line lands on the same device (the config
            // layer guarantees granularity >= line size).
            let slot_end = w.slot(addr + 63);
            if slot_end != slot {
                return Err(format!(
                    "line straddles devices: {slot} vs {slot_end}"
                ));
            }
            // DPA stays inside this device's share of the window.
            let dpa = w.dpa(addr);
            if dpa >= w.size / ways as u64 {
                return Err(format!("dpa {dpa:#x} exceeds device share"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_uniform_addresses_balance_within_one_percent() {
    for &xor in &[false, true] {
        for &ways in &[2usize, 4] {
            let w = window(ways, 1024, xor);
            let mut counts = vec![0u64; ways];
            let mut rng = Rng::new(0xD1CE + ways as u64);
            let samples = 400_000;
            for _ in 0..samples {
                let addr = w.base + (rng.below(w.size) & !63);
                counts[w.slot(addr)] += 1;
            }
            let expect = samples as f64 / ways as f64;
            for (i, &c) in counts.iter().enumerate() {
                let dev = (c as f64 - expect).abs() / expect;
                assert!(
                    dev < 0.01,
                    "ways={ways} xor={xor} dev{i}: {c} vs {expect} \
                     ({:.3}% off)",
                    dev * 100.0
                );
            }
        }
    }
}

#[test]
fn exhaustive_sweep_is_perfectly_balanced() {
    // Every granule over a full ways-group cycle: exact equality, for
    // both arithmetics.
    for &xor in &[false, true] {
        let w = window(4, 256, xor);
        let mut counts = [0u64; 4];
        for g in 0..4096u64 {
            counts[w.slot(w.base + g * 256)] += 1;
        }
        assert!(counts.iter().all(|&c| c == 1024), "{xor}: {counts:?}");
    }
}

// ---- determinism golden test -------------------------------------------

fn run_two_device_stream() -> (u64, u64, u64, u64, Vec<u64>, String) {
    let mut cfg = SimConfig::default();
    cfg.cores = 2;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 256 << 20;
    cfg.cxl.devices = 2;
    cfg.seed = 7;
    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let wl = Stream::new(StreamKernel::Triad, 8192, 1);
    m.attach_workloads(
        vec![Box::new(wl)],
        &MemPolicy::Interleave { weights: vec![(0, 1), (1, 1)] },
    )
    .unwrap();
    let s = m.run(None);
    m.verify().unwrap();
    (
        s.ticks,
        s.events,
        s.dram_accesses,
        s.cxl_accesses,
        s.cxl_dev_fills.clone(),
        m.dump_stats().to_text(),
    )
}

#[test]
fn golden_two_device_runs_are_bitwise_identical() {
    let a = run_two_device_stream();
    let b = run_two_device_stream();
    assert_eq!(a.0, b.0, "ticks diverged");
    assert_eq!(a.1, b.1, "event counts diverged");
    assert_eq!(a.2, b.2, "dram accesses diverged");
    assert_eq!(a.3, b.3, "cxl accesses diverged");
    assert_eq!(a.4, b.4, "per-device fills diverged");
    assert_eq!(a.5, b.5, "full stat dump diverged");
    // And the interleave actually engaged: both devices served fills.
    assert!(a.4.iter().all(|&f| f > 0), "fills {:?}", a.4);
}

// ---- switched topology + MLD pooling -----------------------------------

/// The acceptance scenario: 1 switch x fanout 4, with one MLD exposing
/// 2 LDs — boots through the unmodified guest path and onlines
/// fanout + 1 zNUMA nodes (the per-LD nodes included).
fn switched_mld_machine() -> Machine {
    let mut cfg = SimConfig::default();
    cfg.cores = 2;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 512 << 20;
    cfg.cxl.devices = 4;
    cfg.cxl.switches = 1;
    cfg.seed = 11;
    // Device 3 is an MLD pooling two logical devices.
    cfg.cxl.dev_overrides = vec![
        Default::default(),
        Default::default(),
        Default::default(),
        cxlramsim::config::CxlDevOverride {
            lds: Some(2),
            ..Default::default()
        },
    ];
    let mut m = Machine::new(cfg).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    m
}

#[test]
fn switched_mld_onlines_fanout_plus_one_nodes() {
    let m = switched_mld_machine();
    let g = m.guest.as_ref().unwrap();
    // fanout = 4 endpoints, one of which splits into 2 LDs: 5 nodes.
    assert_eq!(g.cxl_nodes, vec![1, 2, 3, 4, 5]);
    assert_eq!(g.memdevs.len(), 5, "one memdev per logical device");
    // The two LD memdevs share a BDF but map distinct windows.
    let mld: Vec<_> =
        g.memdevs.iter().filter(|md| md.lds == 2).collect();
    assert_eq!(mld.len(), 2);
    assert_eq!(mld[0].bdf, mld[1].bdf);
    assert_ne!(mld[0].hpa_base, mld[1].hpa_base);
    assert_eq!(mld[0].capacity, 256 << 20, "512 MiB splits per LD");
    // All endpoints hang off the single switch's host bridge.
    assert!(g.memdevs.iter().all(|md| md.hb_uid == 7));
}

fn run_switched_mld_stream() -> (u64, u64, u64, Vec<u64>, String) {
    let mut m = switched_mld_machine();
    let a = Stream::new(StreamKernel::Triad, 8192, 1);
    let b = Stream::new(StreamKernel::Copy, 8192, 1);
    // Spread across an SLD node (2) and both MLD LD nodes (4, 5).
    m.attach_workloads(
        vec![Box::new(a), Box::new(b)],
        &MemPolicy::Interleave { weights: vec![(2, 1), (4, 1), (5, 1)] },
    )
    .unwrap();
    let s = m.run(None);
    m.verify().unwrap();
    (
        s.ticks,
        s.events,
        s.cxl_accesses,
        s.cxl_dev_fills.clone(),
        m.dump_stats().to_text(),
    )
}

#[test]
fn golden_switched_mld_runs_are_bitwise_identical() {
    let a = run_switched_mld_stream();
    let b = run_switched_mld_stream();
    assert_eq!(a.0, b.0, "ticks diverged");
    assert_eq!(a.1, b.1, "event counts diverged");
    assert_eq!(a.2, b.2, "cxl accesses diverged");
    assert_eq!(a.3, b.3, "per-device fills diverged");
    assert_eq!(a.4, b.4, "full stat dump diverged");
    // The switch and both MLD LDs actually saw traffic.
    assert!(a.4.contains("cxl.sw0.us_link.flits"));
    assert!(a.3[1] > 0 && a.3[3] > 0, "fills {:?}", a.3);
}

#[test]
fn switched_mld_reports_switch_and_ld_stats() {
    let r = run_switched_mld_stream();
    let dump = &r.4;
    for key in [
        "cxl.sw0.us_link.flits",
        "cxl.sw0.m2s_forwarded",
        "cxl.dev3.ld0.reads",
        "cxl.dev3.ld1.reads",
    ] {
        assert!(dump.contains(key), "stat dump missing {key}");
    }
}

#[test]
fn upstream_contention_slows_switched_attach() {
    // Two endpoints streaming concurrently: behind one switch they
    // share the upstream link; direct-attached they do not. Same
    // workload, measurably more ticks when switched.
    let run = |switched: bool| {
        let mut cfg = SimConfig::default();
        cfg.cores = 2;
        cfg.sys_mem_size = 256 << 20;
        cfg.cxl.mem_size = 256 << 20;
        cfg.cxl.devices = 2;
        cfg.cxl.interleave_ways = 1;
        if switched {
            cfg.cxl.switches = 1;
        }
        let mut m = Machine::new(cfg).unwrap();
        m.boot(ProgModel::Znuma).unwrap();
        let a = Stream::new(StreamKernel::Triad, 16384, 1);
        let b = Stream::new(StreamKernel::Triad, 16384, 1);
        m.attach_workloads(
            vec![Box::new(a), Box::new(b)],
            &MemPolicy::Interleave { weights: vec![(1, 1), (2, 1)] },
        )
        .unwrap();
        m.run(None).ticks
    };
    let direct = run(false);
    let switched = run(true);
    assert!(
        switched > direct * 105 / 100,
        "shared upstream link must cost time: direct {direct} vs \
         switched {switched}"
    );
}
