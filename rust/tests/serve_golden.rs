//! Serve-workload golden runs: bit-determinism of the serving fleet on
//! a 2-host switched MLD, and trace capture/replay reproducing the
//! live run's stats exactly.

use cxlramsim::config::{CxlDevOverride, LdRef, SimConfig};
use cxlramsim::coordinator::attach_replay;
use cxlramsim::guestos::ProgModel;
use cxlramsim::system::Machine;
use cxlramsim::trace::{EventTrace, Recorder};
use cxlramsim::workloads::{Serve, ServeConfig, Workload};

/// Two hosts over one switched 2-LD MLD expander, one LD each: both
/// hosts see a DRAM node and a CXL zNUMA node, so serve's tier split
/// is real on both.
fn mld_config() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.hosts = 2;
    cfg.cores = 2;
    cfg.sys_mem_size = 256 << 20;
    cfg.cxl.mem_size = 512 << 20;
    cfg.cxl.switches = 1;
    cfg.cxl.dev_overrides =
        vec![CxlDevOverride { lds: Some(2), ..Default::default() }];
    cfg.host_lds = vec![
        vec![LdRef { dev: 0, ld: 0 }],
        vec![LdRef { dev: 0, ld: 1 }],
    ];
    cfg
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        users: 64,
        zipf_s: 1.1,
        requests: 60,
        kv_block: 256,
        context_blocks: 2,
        dram_slots: 8,
        cxl_slots: 16,
        decode_work: 16,
    }
}

/// Boot `cfg`, attach one serve workload per host (tier policies from
/// each host's booted NUMA topology), optionally teeing into a
/// recorder, run to completion and return the machine.
fn run_serve(cfg: &SimConfig, recorder: Option<&Recorder>) -> Machine {
    let mut m = Machine::new(cfg.clone()).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    for h in 0..m.hosts.len() {
        let (hot, cold) = m.hosts[h]
            .guest
            .as_ref()
            .unwrap()
            .alloc
            .tier_policies();
        let seed = cfg
            .seed
            .wrapping_add((h as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let wl: Box<dyn Workload> =
            Box::new(Serve::new(serve_cfg(), hot, cold, seed));
        let wl = match recorder {
            Some(rec) => rec.wrap(h, 0, wl),
            None => wl,
        };
        m.attach_workloads_to(h, vec![wl], &hot_default()).unwrap();
    }
    m.run(None);
    m
}

/// Attach-time default policy (serve overrides it with its own tier
/// arenas, so any valid policy works here).
fn hot_default() -> cxlramsim::guestos::MemPolicy {
    cxlramsim::guestos::MemPolicy::Local { home: 0 }
}

#[test]
fn serve_two_host_mld_is_bit_deterministic() {
    let cfg = mld_config();
    let a = run_serve(&cfg, None).dump_stats().to_text();
    let b = run_serve(&cfg, None).dump_stats().to_text();
    assert_eq!(a, b, "same seed must give the identical stats dump");
    // The serving stats actually showed up on both hosts.
    for probe in [
        "host0.serve.requests",
        "host1.serve.requests",
        "host0.serve.p99_ns",
        "host0.serve.tier_hits",
        "host1.serve.evictions",
    ] {
        assert!(a.contains(probe), "{probe} missing from dump:\n{a}");
    }
}

#[test]
fn serve_seed_changes_the_run() {
    let cfg = mld_config();
    let mut cfg2 = mld_config();
    cfg2.seed = 99;
    let a = run_serve(&cfg, None).dump_stats().to_text();
    let b = run_serve(&cfg2, None).dump_stats().to_text();
    assert_ne!(a, b, "different seeds must differ (sanity check)");
}

/// Stat keys that describe the workload itself rather than the
/// machine: the live run emits `serve.*`, the replay run `trace.*`.
/// Everything else must match exactly between the two.
fn machine_keys(dump: &cxlramsim::stats::StatDump) -> Vec<(String, f64)> {
    dump.entries
        .iter()
        .filter(|(k, _)| {
            let tail = k
                .split_once('.')
                .map(|(head, tail)| {
                    if head.starts_with("host")
                        && head[4..].chars().all(|c| c.is_ascii_digit())
                    {
                        tail
                    } else {
                        k.as_str()
                    }
                })
                .unwrap_or(k.as_str());
            !tail.starts_with("serve.") && !tail.starts_with("trace.")
        })
        .cloned()
        .collect()
}

#[test]
fn captured_serve_trace_replays_bit_identically() {
    let cfg = mld_config();
    // Live run, teeing every (host, core) stream into one trace.
    let rec = Recorder::new();
    let live = run_serve(&cfg, Some(&rec));
    let live_dump = live.dump_stats();
    let t = rec.take();
    assert!(!t.is_empty(), "recorder captured nothing");
    assert_eq!(t.hosts(), vec![0, 1]);

    // The recorded wrapper must not have perturbed the run: a bare
    // live run's machine stats match the recorded one's exactly.
    let bare_dump = run_serve(&cfg, None).dump_stats();
    assert_eq!(
        machine_keys(&bare_dump),
        machine_keys(&live_dump),
        "recording changed the simulation"
    );

    // Byte round-trip through the on-disk format.
    let t = EventTrace::from_bytes(&t.to_bytes()).unwrap();

    // Replay into a fresh machine under the same config.
    let mut m = Machine::new(cfg.clone()).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    attach_replay(&mut m, &t).unwrap();
    m.run(None);
    let replay_dump = m.dump_stats();

    // Bit-identical machine behaviour: every per-tier read/write
    // counter, latency percentile and link stat matches the live run.
    assert_eq!(
        machine_keys(&live_dump),
        machine_keys(&replay_dump),
        "replay diverged from the live run"
    );
    // And the replay bookkeeping is visible.
    let ops: f64 = t.len() as f64;
    let replayed = replay_dump.get("host0.trace.replay_ops").unwrap()
        + replay_dump.get("host1.trace.replay_ops").unwrap();
    assert_eq!(replayed, ops, "not every recorded op was replayed");
}
