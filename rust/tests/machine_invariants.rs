//! Property-based integration tests on the full machine: coherence
//! SWMR, request conservation, directory consistency, determinism.

use cxlramsim::cache::coherence::swmr_holds;
use cxlramsim::config::{CpuModel, SimConfig};
use cxlramsim::guestos::{MemPolicy, ProgModel};
use cxlramsim::system::Machine;
use cxlramsim::util::prop::check;
use cxlramsim::util::rng::Rng;
use cxlramsim::workloads::{RandomAccess, Stream, StreamKernel};

fn small_cfg(cores: usize, cpu: CpuModel) -> SimConfig {
    let mut c = SimConfig::default();
    c.cores = cores;
    c.cpu_model = cpu;
    c.sys_mem_size = 256 << 20;
    c.cxl.mem_size = 256 << 20;
    c
}

/// Run a random multi-core workload mix; return the machine for
/// post-mortem invariant checks.
fn run_random(seed: u64, cores: usize, cpu: CpuModel) -> Machine {
    let mut m = Machine::new(small_cfg(cores, cpu)).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let mut wls: Vec<Box<dyn cxlramsim::workloads::Workload>> = Vec::new();
    for i in 0..cores {
        // Overlapping footprints across cores exercise coherence.
        wls.push(Box::new(RandomAccess::new(
            1 << 20,
            2000,
            0.4,
            seed + i as u64, // different streams, same VMA sizes
        )));
    }
    m.attach_workloads(
        wls,
        &MemPolicy::Interleave { weights: vec![(0, 1), (1, 1)] },
    )
    .unwrap();
    m.run(None);
    m
}

#[test]
fn prop_swmr_holds_after_random_runs() {
    check(
        "machine-swmr",
        6,
        |r: &mut Rng| r.below(1_000_000),
        |&seed| {
            let m = run_random(seed, 4, CpuModel::OutOfOrder);
            // Collect per-line states across all L1s.
            let mut by_line: std::collections::HashMap<u64, Vec<_>> =
                Default::default();
            for l1 in &m.l1s {
                for (line, st) in l1.valid_lines() {
                    by_line.entry(line).or_default().push(st);
                }
            }
            for (line, states) in by_line {
                if !swmr_holds(&states) {
                    return Err(format!(
                        "SWMR violated on line {line:#x}: {states:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_all_requests_complete() {
    check(
        "machine-conservation",
        6,
        |r: &mut Rng| r.below(1_000_000),
        |&seed| {
            let m = run_random(seed, 2, CpuModel::OutOfOrder);
            for (i, c) in m.cores.iter().enumerate() {
                if !c.done {
                    return Err(format!("core {i} never finished"));
                }
                if c.outstanding() != 0 {
                    return Err(format!(
                        "core {i} leaked {} in-flight requests",
                        c.outstanding()
                    ));
                }
                let issued = c.stats.loads.get() + c.stats.stores.get();
                let completed = c.stats.mem_latency.count();
                if issued != completed {
                    return Err(format!(
                        "core {i}: {issued} issued vs {completed} completed"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_deterministic_for_seed() {
    check(
        "machine-determinism",
        3,
        |r: &mut Rng| r.below(1_000_000),
        |&seed| {
            let digest = |m: &Machine| {
                let s = m.summary();
                (
                    s.ticks,
                    s.events,
                    s.dram_accesses,
                    s.cxl_accesses,
                    s.m2s_req,
                    m.l2.stats.misses.get(),
                )
            };
            let a = digest(&run_random(seed, 2, CpuModel::OutOfOrder));
            let b = digest(&run_random(seed, 2, CpuModel::OutOfOrder));
            if a != b {
                return Err(format!("nondeterminism: {a:?} vs {b:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn inclusive_hierarchy_no_l1_orphans() {
    let m = run_random(99, 4, CpuModel::OutOfOrder);
    // Every valid L1 line must also be valid in L2 (inclusive).
    let l2_lines: std::collections::HashSet<u64> =
        m.l2.valid_lines().into_iter().map(|(l, _)| l).collect();
    // L1 and L2 have different set counts but line addresses are global.
    for (i, l1) in m.l1s.iter().enumerate() {
        for (line, _) in l1.valid_lines() {
            assert!(
                l2_lines.contains(&line),
                "L1.{i} line {line:#x} not in L2 (inclusion broken)"
            );
        }
    }
}

#[test]
fn true_sharing_invalidates_peer_copies() {
    // Two cores ping-pong the same VMA: writes must invalidate the
    // peer's Shared copies (observable as invalidations + upgrades).
    let mut m = Machine::new(small_cfg(2, CpuModel::InOrder)).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let a = RandomAccess::new(64 << 10, 3000, 0.5, 5);
    let b = RandomAccess::new(64 << 10, 3000, 0.5, 5); // same seed: same VAs
    m.attach_workloads(
        vec![Box::new(a), Box::new(b)],
        &MemPolicy::Bind { nodes: vec![0] },
    )
    .unwrap();
    m.run(None);
    // NOTE: separate address spaces -> no physical sharing; this checks
    // the machinery is at least alive on shared L2 lines via directory.
    let invals: u64 = m.stats.coherence_invals.get();
    let _ = invals; // may be zero with private spaces — assert machinery:
    assert!(m.dir.tracked_lines() > 0 || invals == 0);
}

#[test]
fn stream_multicore_verifies_on_cxl() {
    let mut m = Machine::new(small_cfg(4, CpuModel::OutOfOrder)).unwrap();
    m.boot(ProgModel::Znuma).unwrap();
    let wls: Vec<Box<dyn cxlramsim::workloads::Workload>> = (0..4)
        .map(|_| {
            Box::new(Stream::new(StreamKernel::Triad, 4096, 1))
                as Box<dyn cxlramsim::workloads::Workload>
        })
        .collect();
    m.attach_workloads(wls, &MemPolicy::Bind { nodes: vec![1] }).unwrap();
    let s = m.run(None);
    assert!(s.cxl_accesses > 0);
    m.verify().unwrap();
    // All 4 cores contributed CXL traffic through one shared link.
    assert!(s.m2s_req > 1000);
}
