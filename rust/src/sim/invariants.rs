//! Runtime CXL protocol-invariant checker (`[sim] check` / `--check`).
//!
//! The machine's golden digests prove determinism by example; this
//! module enforces the *conservation laws* behind them on a live run.
//! Off by default (zero cost for benches); when `[sim] check = true`
//! the machine audits after each epoch commit wave and once more at
//! quiesce, recording structured [`InvariantViolation`]s and a
//! `check.{epochs,violations,rules_evaluated}` stat surface. A clean
//! run must produce zero violations at any `(threads, commit_lanes)`.
//!
//! Rule catalog (ids appear in reports, docs/ARCHITECTURE.md and the
//! mutation tests):
//!
//! | id    | law                                                       |
//! |-------|-----------------------------------------------------------|
//! | CR-1  | per-pool credit conservation: free + in-flight +          |
//! |       | placeholders == issued, every epoch                       |
//! | CR-2  | no `Tick::MAX` credit placeholders once drained (every    |
//! |       | send eventually retired)                                  |
//! | EQ-1  | per-host clock monotone: `queue_now` never regresses and  |
//! |       | the next event is never behind the clock                  |
//! | EQ-2  | global commit order: within a wave, `(tick, host, seq)`   |
//! |       | strictly increasing; across waves the tick floor never    |
//! |       | regresses (a later wave may legally start at the same     |
//! |       | tick with a smaller host id)                              |
//! | WIN-1 | HDM/CFMWS windows: per-host HPA ranges disjoint; two      |
//! |       | hosts' windows covering the same device DPA only for a    |
//! |       | shared LD                                                 |
//! | SF-1  | snoop-filter soundness at quiesce: a host's owned shared  |
//! |       | lines and the device directory's owner entries agree      |
//! |       | exactly, both directions                                  |
//! | SF-2  | BI accounting at quiesce: every BISnp sent was acked      |
//! |       | (`bi_sent == bi_acks`), none still queued                 |
//! | RT-1  | no orphaned MSHRs at quiesce: `l2_pending`, outboxes and  |
//! |       | the global pending map all empty                          |
//!
//! The checker never panics mid-run: violations are recorded so a
//! broken run still produces its full report. The machine decides at
//! end of run whether to fail (it does, loudly, unless a fault hook
//! marked the checker tolerant — the mutation tests in
//! `rust/tests/invariants.rs` seed corruption on purpose).

use std::fmt;

use crate::cxl::mem_proto::DATA_BYTES;
use crate::cxl::Fabric;
use crate::sim::Tick;
use crate::system::host::Host;

/// Cap on *recorded* violations: a conservation bug trips every epoch,
/// and the report only needs the first screenful. The running count
/// (`check.violations`) keeps the true total.
const MAX_RECORDED: usize = 256;

/// One broken invariant, with enough context to find the state that
/// broke it.
#[derive(Clone, Debug)]
pub struct InvariantViolation {
    /// Rule id from the module-level catalog (e.g. `"CR-1"`).
    pub rule: &'static str,
    /// Simulated tick of the audit that caught it.
    pub tick: Tick,
    /// Host involved, when the rule is host-scoped.
    pub host: Option<usize>,
    /// Device involved, when the rule is device-scoped.
    pub device: Option<usize>,
    /// Narrative: what equation failed, with the numbers.
    pub what: String,
}

impl fmt::Display for InvariantViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] t={}", self.rule, self.tick)?;
        if let Some(h) = self.host {
            write!(f, " host{h}")?;
        }
        if let Some(d) = self.device {
            write!(f, " dev{d}")?;
        }
        write!(f, ": {}", self.what)
    }
}

/// Streaming audit of the global commit order (rule EQ-2). The commit
/// paths feed every `(tick, host, seq)` key they pop from the pending
/// map through [`CommitOrderAudit::note`]; wave boundaries (each
/// `commit_pending` call / sharded wave) reset the within-wave cursor
/// via [`CommitOrderAudit::begin_wave`] while ratcheting the tick
/// floor — entries committed in a later wave may start at the same
/// tick as the previous wave's limit (with any host id), but never at
/// an earlier tick.
#[derive(Debug, Default)]
pub struct CommitOrderAudit {
    /// Largest key committed in the current wave.
    last: Option<(Tick, u8, u64)>,
    /// Largest tick of any completed wave.
    floor: Tick,
    /// EQ-2 violations awaiting pickup by the checker's next audit.
    pending: Vec<InvariantViolation>,
    /// Fault hook: hold the next key and emit it after its successor.
    #[cfg(feature = "check")]
    fault_armed: bool,
    #[cfg(feature = "check")]
    held: Option<(Tick, u8, u64)>,
}

impl CommitOrderAudit {
    /// A new commit wave begins: within-wave ordering restarts, the
    /// cross-wave tick floor ratchets up.
    pub fn begin_wave(&mut self) {
        if let Some((t, _, _)) = self.last {
            self.floor = self.floor.max(t);
        }
        self.last = None;
    }

    /// Observe the next key popped from the pending map, in commit
    /// order.
    pub fn note(&mut self, key: (Tick, u8, u64)) {
        #[cfg(feature = "check")]
        if self.fault_armed {
            match self.held.take() {
                None => {
                    self.held = Some(key);
                    return;
                }
                Some(h) => {
                    self.fault_armed = false;
                    self.observe(key);
                    self.observe(h);
                    return;
                }
            }
        }
        self.observe(key);
    }

    fn observe(&mut self, key: (Tick, u8, u64)) {
        if key.0 < self.floor {
            self.pending.push(InvariantViolation {
                rule: "EQ-2",
                tick: key.0,
                host: Some(key.1 as usize),
                device: None,
                what: format!(
                    "commit key {key:?} regresses behind the completed-\
                     wave tick floor {}",
                    self.floor
                ),
            });
        }
        if let Some(last) = self.last {
            if key <= last {
                self.pending.push(InvariantViolation {
                    rule: "EQ-2",
                    tick: key.0,
                    host: Some(key.1 as usize),
                    device: None,
                    what: format!(
                        "commit key {key:?} not strictly after {last:?} \
                         within one wave"
                    ),
                });
            }
        }
        self.last = Some(match self.last {
            Some(l) if l > key => l,
            _ => key,
        });
    }

    /// Arm the EQ-2 mutation fault: the next committed key is held
    /// back and delivered after its successor, exactly the reordering
    /// the rule exists to catch.
    #[cfg(feature = "check")]
    pub fn arm_reorder_fault(&mut self) {
        self.fault_armed = true;
    }
}

/// The runtime invariant engine. Owned by `system::Machine` when
/// `[sim] check` is on; all audits are driven from the machine's
/// single-threaded sections (never from commit-lane workers), so the
/// checker needs no synchronization.
#[derive(Debug, Default)]
pub struct InvariantChecker {
    /// EQ-2 streaming audit, fed by the commit paths.
    pub order: CommitOrderAudit,
    violations: Vec<InvariantViolation>,
    total_violations: u64,
    epochs: u64,
    rules_evaluated: u64,
    /// Per-host high-water mark of `queue_now` (EQ-1).
    watermarks: Vec<Tick>,
    /// Set by the fault hooks: a seeded corruption is *supposed* to
    /// violate rules, so the end-of-run audit reports instead of
    /// failing the run.
    tolerant: bool,
}

impl InvariantChecker {
    pub fn new(nhosts: usize) -> Self {
        InvariantChecker {
            watermarks: vec![0; nhosts],
            ..Default::default()
        }
    }

    fn push(&mut self, v: InvariantViolation) {
        self.total_violations += 1;
        if self.violations.len() < MAX_RECORDED {
            self.violations.push(v);
        }
    }

    /// Per-epoch audit: credit conservation (CR-1), host clock
    /// monotonicity (EQ-1) and any commit-order violations the
    /// streaming EQ-2 audit collected since the last call. `now` is
    /// the epoch's commit limit.
    pub fn audit_epoch(
        &mut self,
        now: Tick,
        hosts: &[Host],
        fabric: &Fabric,
    ) {
        self.epochs += 1;
        self.rules_evaluated += 3;
        for (label, link) in fabric.pools() {
            let (total, free, inflight, placeholders) =
                link.credit_audit();
            if free + inflight + placeholders != total {
                self.push(InvariantViolation {
                    rule: "CR-1",
                    tick: now,
                    host: None,
                    device: None,
                    what: format!(
                        "credit pool {label}: issued {total} != free \
                         {free} + in-flight {inflight} + placeholders \
                         {placeholders}"
                    ),
                });
            }
        }
        for (h, host) in hosts.iter().enumerate() {
            let qnow = host.queue_now();
            if qnow < self.watermarks[h] {
                self.push(InvariantViolation {
                    rule: "EQ-1",
                    tick: now,
                    host: Some(h),
                    device: None,
                    what: format!(
                        "queue_now {qnow} regressed below watermark {}",
                        self.watermarks[h]
                    ),
                });
            } else {
                self.watermarks[h] = qnow;
            }
            if let Some(next) = host.next_event_tick() {
                if next < qnow {
                    self.push(InvariantViolation {
                        rule: "EQ-1",
                        tick: now,
                        host: Some(h),
                        device: None,
                        what: format!(
                            "next event at {next} is behind the host \
                             clock {qnow}"
                        ),
                    });
                }
            }
        }
        let order_violations = std::mem::take(&mut self.order.pending);
        for v in order_violations {
            self.push(v);
        }
    }

    /// Window audit (WIN-1), run after every FM rebind wave and at
    /// quiesce: per-host HPA disjointness, and cross-host DPA overlap
    /// on one device only where the FM actually bound a shared LD.
    pub fn audit_windows(
        &mut self,
        now: Tick,
        hosts: &[Host],
        fabric: &Fabric,
    ) {
        self.rules_evaluated += 1;
        for (h, host) in hosts.iter().enumerate() {
            let mut spans: Vec<(u64, u64)> = host
                .rc
                .windows()
                .iter()
                .map(|w| (w.base, w.size))
                .collect();
            spans.sort_unstable();
            for pair in spans.windows(2) {
                if pair[0].0 + pair[0].1 > pair[1].0 {
                    self.push(InvariantViolation {
                        rule: "WIN-1",
                        tick: now,
                        host: Some(h),
                        device: None,
                        what: format!(
                            "HPA windows overlap: [{:#x}, {:#x}) and \
                             [{:#x}, {:#x})",
                            pair[0].0,
                            pair[0].0 + pair[0].1,
                            pair[1].0,
                            pair[1].0 + pair[1].1
                        ),
                    });
                }
            }
        }
        // Cross-host: which DPA span of which device does each window
        // reach? For an N-way window each target device sees size/N
        // bytes starting at the window's DPA base.
        let mut per_dev: Vec<Vec<(usize, u64, u64)>> =
            vec![Vec::new(); fabric.ndev()];
        for (h, host) in hosts.iter().enumerate() {
            for w in host.rc.windows() {
                let ways = w.targets.len().max(1) as u64;
                let span = w.size / ways;
                for &t in w.targets.iter() {
                    if t < per_dev.len() {
                        per_dev[t].push((
                            h,
                            w.dpa_base,
                            w.dpa_base + span,
                        ));
                    }
                }
            }
        }
        for (d, spans) in per_dev.iter().enumerate() {
            for i in 0..spans.len() {
                for j in i + 1..spans.len() {
                    let (ha, lo_a, hi_a) = spans[i];
                    let (hb, lo_b, hi_b) = spans[j];
                    if ha == hb || lo_a >= hi_b || lo_b >= hi_a {
                        continue;
                    }
                    let ld =
                        fabric.devices[d].ld_of_dpa(lo_a.max(lo_b));
                    if !fabric.devices[d].is_shared_ld(ld) {
                        self.push(InvariantViolation {
                            rule: "WIN-1",
                            tick: now,
                            host: Some(ha),
                            device: Some(d),
                            what: format!(
                                "hosts {ha} and {hb} both map DPA \
                                 [{:#x}, {:#x}) of unshared ld{ld}",
                                lo_a.max(lo_b),
                                hi_a.min(hi_b)
                            ),
                        });
                    }
                }
            }
        }
    }

    /// Quiesce audit (CR-2, SF-1, SF-2, RT-1). Only meaningful once
    /// the run actually drained — a `max_ticks` truncation legally
    /// leaves work in flight, so the final-state rules are skipped
    /// (and not counted as evaluated) when anything is still pending.
    pub fn audit_quiesce(
        &mut self,
        now: Tick,
        hosts: &[Host],
        fabric: &Fabric,
        pending_inflight: usize,
    ) {
        let drained = pending_inflight == 0
            && hosts.iter().all(|h| h.next_event_tick().is_none());
        if !drained {
            return;
        }
        self.rules_evaluated += 4;
        // CR-2: every consumed credit was retired.
        for (label, link) in fabric.pools() {
            let (_, _, _, placeholders) = link.credit_audit();
            if placeholders > 0 {
                self.push(InvariantViolation {
                    rule: "CR-2",
                    tick: now,
                    host: None,
                    device: None,
                    what: format!(
                        "credit pool {label}: {placeholders} \
                         Tick::MAX placeholder(s) never retired"
                    ),
                });
            }
        }
        // RT-1: no orphaned MSHRs or undrained outboxes.
        for (h, host) in hosts.iter().enumerate() {
            if host.inflight_fetches() > 0 {
                self.push(InvariantViolation {
                    rule: "RT-1",
                    tick: now,
                    host: Some(h),
                    device: None,
                    what: format!(
                        "{} l2_pending MSHR(s) orphaned at quiesce",
                        host.inflight_fetches()
                    ),
                });
            }
            if host.outbox_len() > 0 {
                self.push(InvariantViolation {
                    rule: "RT-1",
                    tick: now,
                    host: Some(h),
                    device: None,
                    what: format!(
                        "{} outbox entr(ies) never drained",
                        host.outbox_len()
                    ),
                });
            }
        }
        // SF-1, host -> device: every line a host believes it owns
        // must be owned by that host in the device directory.
        let mut host_owned: Vec<(usize, u64, usize)> = Vec::new();
        for (h, host) in hosts.iter().enumerate() {
            for line in host.owned_shared_lines() {
                match host.rc.route_dpa(line) {
                    Some((dev, dpa)) => {
                        let sl = fabric.devices[dev].snoop_line(dpa);
                        if sl.owner != Some(h as u8) {
                            self.push(InvariantViolation {
                                rule: "SF-1",
                                tick: now,
                                host: Some(h),
                                device: Some(dev),
                                what: format!(
                                    "host owns line {line:#x} (dpa \
                                     {dpa:#x}) but the snoop filter \
                                     says owner = {:?}",
                                    sl.owner
                                ),
                            });
                        } else {
                            host_owned.push((
                                dev,
                                dpa / DATA_BYTES,
                                h,
                            ));
                        }
                    }
                    None => self.push(InvariantViolation {
                        rule: "SF-1",
                        tick: now,
                        host: Some(h),
                        device: None,
                        what: format!(
                            "owned line {line:#x} routes to no window"
                        ),
                    }),
                }
            }
        }
        // SF-1, device -> host: every exclusive entry in a directory
        // must be claimed by that host.
        host_owned.sort_unstable();
        for (d, dev) in fabric.devices.iter().enumerate() {
            for (line_dpa, sl) in dev.snoop_entries() {
                let Some(o) = sl.owner else { continue };
                let key = (d, line_dpa / DATA_BYTES, o as usize);
                if host_owned.binary_search(&key).is_err() {
                    self.push(InvariantViolation {
                        rule: "SF-1",
                        tick: now,
                        host: Some(o as usize),
                        device: Some(d),
                        what: format!(
                            "snoop filter grants dpa {line_dpa:#x} \
                             exclusively to host{o}, which claims no \
                             such line"
                        ),
                    });
                }
            }
        }
        // SF-2: BI bookkeeping closed out.
        for (d, dev) in fabric.devices.iter().enumerate() {
            if dev.pending_bi_len() > 0 {
                self.push(InvariantViolation {
                    rule: "SF-2",
                    tick: now,
                    host: None,
                    device: Some(d),
                    what: format!(
                        "{} BISnp(s) still queued at quiesce",
                        dev.pending_bi_len()
                    ),
                });
            }
            let sent: u64 =
                dev.stats.ld_bi_sent.iter().map(|c| c.get()).sum();
            let acks: u64 =
                dev.stats.ld_bi_acks.iter().map(|c| c.get()).sum();
            if sent != acks {
                self.push(InvariantViolation {
                    rule: "SF-2",
                    tick: now,
                    host: None,
                    device: Some(d),
                    what: format!(
                        "bi_sent {sent} != bi_acks {acks} at quiesce"
                    ),
                });
            }
        }
    }

    /// Mark seeded-fault mode: the end-of-run audit reports violations
    /// without failing the run (mutation tests inspect them instead).
    #[cfg(feature = "check")]
    pub fn set_tolerant(&mut self) {
        self.tolerant = true;
    }

    pub fn tolerant(&self) -> bool {
        self.tolerant
    }

    /// Audits performed (stat `check.epochs`).
    pub fn epochs(&self) -> u64 {
        self.epochs
    }

    /// Rule-group evaluations across all audits
    /// (stat `check.rules_evaluated`).
    pub fn rules_evaluated(&self) -> u64 {
        self.rules_evaluated
    }

    /// Total violations observed, including any past the recording cap
    /// (stat `check.violations`).
    pub fn total_violations(&self) -> u64 {
        self.total_violations
    }

    /// The recorded violations (first [`MAX_RECORDED`]), audit order.
    pub fn violations(&self) -> &[InvariantViolation] {
        &self.violations
    }

    /// Multi-line report for the end-of-run failure path.
    pub fn report(&self) -> String {
        let mut s = format!(
            "invariant checker: {} violation(s) over {} epoch(s)\n",
            self.total_violations, self.epochs
        );
        for v in &self.violations {
            s.push_str(&format!("  {v}\n"));
        }
        if self.total_violations > self.violations.len() as u64 {
            s.push_str(&format!(
                "  ... and {} more (recording capped)\n",
                self.total_violations - self.violations.len() as u64
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(t: Tick, h: u8, s: u64) -> (Tick, u8, u64) {
        (t, h, s)
    }

    #[test]
    fn commit_order_accepts_strictly_increasing_waves() {
        let mut a = CommitOrderAudit::default();
        a.begin_wave();
        a.note(k(10, 0, 1));
        a.note(k(10, 1, 0));
        a.note(k(12, 0, 2));
        a.begin_wave();
        // Same tick as the previous wave's limit, smaller host: legal.
        a.note(k(12, 0, 3));
        a.note(k(20, 2, 0));
        assert!(a.pending.is_empty(), "{:?}", a.pending);
    }

    #[test]
    fn commit_order_rejects_within_wave_regression() {
        let mut a = CommitOrderAudit::default();
        a.begin_wave();
        a.note(k(10, 1, 0));
        a.note(k(10, 0, 0)); // smaller host at same tick, same wave
        assert_eq!(a.pending.len(), 1);
        assert_eq!(a.pending[0].rule, "EQ-2");
    }

    #[test]
    fn commit_order_rejects_cross_wave_tick_regression() {
        let mut a = CommitOrderAudit::default();
        a.begin_wave();
        a.note(k(100, 0, 0));
        a.begin_wave();
        a.note(k(99, 0, 1));
        assert_eq!(a.pending.len(), 1);
        assert!(a.pending[0].what.contains("floor"));
    }

    #[test]
    fn duplicate_key_is_a_violation() {
        let mut a = CommitOrderAudit::default();
        a.begin_wave();
        a.note(k(5, 0, 0));
        a.note(k(5, 0, 0));
        assert_eq!(a.pending.len(), 1, "strictly-increasing means no dup");
    }

    #[test]
    fn checker_caps_recording_but_counts_all() {
        let mut c = InvariantChecker::new(1);
        for i in 0..(MAX_RECORDED as u64 + 10) {
            c.push(InvariantViolation {
                rule: "CR-1",
                tick: i,
                host: None,
                device: None,
                what: "x".into(),
            });
        }
        assert_eq!(c.total_violations(), MAX_RECORDED as u64 + 10);
        assert_eq!(c.violations().len(), MAX_RECORDED);
        assert!(c.report().contains("more (recording capped)"));
    }

    #[test]
    fn violation_display_has_rule_site_and_narrative() {
        let v = InvariantViolation {
            rule: "SF-1",
            tick: 42,
            host: Some(3),
            device: Some(1),
            what: "owner mismatch".into(),
        };
        let s = v.to_string();
        assert!(s.contains("[SF-1]"));
        assert!(s.contains("t=42"));
        assert!(s.contains("host3"));
        assert!(s.contains("dev1"));
        assert!(s.contains("owner mismatch"));
    }

    #[cfg(feature = "check")]
    #[test]
    fn reorder_fault_trips_eq2() {
        let mut a = CommitOrderAudit::default();
        a.arm_reorder_fault();
        a.begin_wave();
        a.note(k(10, 0, 0)); // held
        a.note(k(11, 0, 1)); // delivered first, then the held key
        assert!(
            a.pending.iter().any(|v| v.rule == "EQ-2"),
            "{:?}",
            a.pending
        );
    }
}
