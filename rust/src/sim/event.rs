//! Deterministic event queue.
//!
//! Generic over the machine's event type `E`. Ordering: (tick, seq) where
//! seq is the global insertion counter — equal-tick events fire in the
//! order they were scheduled, which makes whole-machine runs
//! bit-reproducible (a property the determinism tests assert).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::Tick;

#[derive(Debug)]
pub struct Scheduled<E> {
    pub tick: Tick,
    pub seq: u64,
    pub ev: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, o: &Self) -> bool {
        self.tick == o.tick && self.seq == o.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, o: &Self) -> Ordering {
        // Reverse for min-heap behaviour inside BinaryHeap (max-heap).
        (o.tick, o.seq).cmp(&(self.tick, self.seq))
    }
}

#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    seq: u64,
    now: Tick,
    processed: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0, processed: 0 }
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now(&self) -> Tick {
        self.now
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `ev` at absolute tick `at` (>= now).
    pub fn schedule_at(&mut self, at: Tick, ev: E) {
        debug_assert!(at >= self.now, "scheduling into the past");
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { tick: at.max(self.now), seq, ev });
    }

    /// Schedule `ev` after `delay` ticks.
    pub fn schedule(&mut self, delay: Tick, ev: E) {
        self.schedule_at(self.now + delay, ev);
    }

    /// Pop the next event, advancing `now`.
    pub fn pop(&mut self) -> Option<(Tick, E)> {
        let s = self.heap.pop()?;
        self.now = s.tick;
        self.processed += 1;
        Some((s.tick, s.ev))
    }

    /// Peek at the next event time.
    pub fn next_tick(&self) -> Option<Tick> {
        self.heap.peek().map(|s| s.tick)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn fifo_order_for_equal_ticks() {
        let mut q = EventQueue::new();
        q.schedule_at(10, "a");
        q.schedule_at(10, "b");
        q.schedule_at(5, "c");
        q.schedule_at(10, "d");
        let order: Vec<_> = std::iter::from_fn(|| q.pop())
            .map(|(_, e)| e)
            .collect();
        assert_eq!(order, vec!["c", "a", "b", "d"]);
    }

    #[test]
    fn now_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule_at(3, 0);
        q.schedule_at(1, 1);
        q.schedule_at(2, 2);
        let mut last = 0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
        }
        assert_eq!(q.now(), 3);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn schedule_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(100, 1);
        q.pop();
        q.schedule(50, 2);
        assert_eq!(q.pop(), Some((150, 2)));
    }

    #[test]
    fn property_pops_sorted_stable() {
        check(
            "eventq-sorted",
            200,
            |r: &mut Rng| {
                (0..r.range(1, 60))
                    .map(|_| r.below(100))
                    .collect::<Vec<u64>>()
            },
            |ticks| {
                let mut q = EventQueue::new();
                for (i, &t) in ticks.iter().enumerate() {
                    q.schedule_at(t, i);
                }
                let mut prev: Option<(Tick, usize)> = None;
                while let Some((t, idx)) = q.pop() {
                    if ticks[idx] != t {
                        return Err("tick mangled".into());
                    }
                    if let Some((pt, pidx)) = prev {
                        if t < pt {
                            return Err("out of order".into());
                        }
                        if t == pt && idx < pidx {
                            return Err("unstable for equal ticks".into());
                        }
                    }
                    prev = Some((t, idx));
                }
                Ok(())
            },
        );
    }
}
