//! Memory packets — the request/response currency of the machine.
//!
//! One [`Packet`] represents a line-granular memory transaction as it
//! moves CPU -> L1 -> L2 -> (DRAM | IOBus -> CXL). Timing annotations
//! accumulate on the packet so end-to-end latency histograms can be
//! split by memory class (system DRAM vs CXL).

use super::Tick;

pub type ReqId = u64;

/// Command, deliberately close to gem5's MemCmd vocabulary.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemCmd {
    ReadReq,
    ReadResp,
    WriteReq,
    WriteResp,
    /// Write-back of a dirty line from a cache to the next level.
    WritebackDirty,
    /// Coherence: invalidate a line in a peer cache (directory-issued).
    InvalidateReq,
    InvalidateResp,
    /// Coherence: upgrade S -> M without data transfer.
    UpgradeReq,
    UpgradeResp,
}

impl MemCmd {
    pub fn is_read(&self) -> bool {
        matches!(self, MemCmd::ReadReq | MemCmd::ReadResp)
    }
    pub fn is_write(&self) -> bool {
        matches!(
            self,
            MemCmd::WriteReq | MemCmd::WriteResp | MemCmd::WritebackDirty
        )
    }
    pub fn is_request(&self) -> bool {
        matches!(
            self,
            MemCmd::ReadReq
                | MemCmd::WriteReq
                | MemCmd::WritebackDirty
                | MemCmd::InvalidateReq
                | MemCmd::UpgradeReq
        )
    }
    pub fn response(&self) -> Option<MemCmd> {
        match self {
            MemCmd::ReadReq => Some(MemCmd::ReadResp),
            MemCmd::WriteReq => Some(MemCmd::WriteResp),
            MemCmd::InvalidateReq => Some(MemCmd::InvalidateResp),
            MemCmd::UpgradeReq => Some(MemCmd::UpgradeResp),
            MemCmd::WritebackDirty => None, // posted
            _ => None,
        }
    }
}

/// Which physical memory class a physical address belongs to.
/// Determined by the system address map / HDM decoders.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemClass {
    SysDram,
    CxlExpander,
    Mmio,
}

#[derive(Clone, Debug)]
pub struct Packet {
    pub id: ReqId,
    pub cmd: MemCmd,
    /// Physical byte address (line-aligned for cache traffic).
    pub addr: u64,
    pub size: u32,
    /// Issuing core (coherence needs the origin).
    pub core: u8,
    /// Tick at which the CPU issued the original request.
    pub issued_at: Tick,
    /// Filled by the address map when the packet is routed.
    pub class: MemClass,
}

impl Packet {
    pub fn new(
        id: ReqId,
        cmd: MemCmd,
        addr: u64,
        size: u32,
        core: u8,
        issued_at: Tick,
    ) -> Self {
        Packet { id, cmd, addr, size, core, issued_at, class: MemClass::SysDram }
    }

    /// Line address for a given line size.
    #[inline]
    pub fn line_addr(&self, line: u64) -> u64 {
        self.addr & !(line - 1)
    }

    /// Turn a request into its response in place.
    pub fn make_response(&mut self) {
        if let Some(r) = self.cmd.response() {
            self.cmd = r;
        } else {
            panic!("no response form for {:?}", self.cmd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cmd_classification() {
        assert!(MemCmd::ReadReq.is_read());
        assert!(MemCmd::ReadReq.is_request());
        assert!(!MemCmd::ReadResp.is_request());
        assert!(MemCmd::WritebackDirty.is_write());
        assert_eq!(MemCmd::WriteReq.response(), Some(MemCmd::WriteResp));
        assert_eq!(MemCmd::WritebackDirty.response(), None);
    }

    #[test]
    fn line_alignment() {
        let p = Packet::new(1, MemCmd::ReadReq, 0x12345, 8, 0, 0);
        assert_eq!(p.line_addr(64), 0x12340);
        assert_eq!(p.line_addr(4096), 0x12000);
    }

    #[test]
    fn response_conversion() {
        let mut p = Packet::new(1, MemCmd::ReadReq, 0x1000, 64, 0, 5);
        p.make_response();
        assert_eq!(p.cmd, MemCmd::ReadResp);
    }

    #[test]
    #[should_panic(expected = "no response form")]
    fn writeback_has_no_response() {
        let mut p = Packet::new(1, MemCmd::WritebackDirty, 0, 64, 0, 0);
        p.make_response();
    }
}
