//! Discrete-event simulation core (the gem5-engine substitute).
//!
//! * [`Tick`] — picosecond time base (1 tick = 1 ps, like gem5).
//! * [`EventQueue`] — deterministic priority queue; ties break by
//!   insertion order so runs are bit-reproducible.
//! * [`packet`] — memory request/response representation shared by the
//!   caches, buses, DRAM and the CXL transaction layer.

pub mod event;
pub mod invariants;
pub mod packet;

pub use event::{EventQueue, Scheduled};
pub use packet::{MemCmd, Packet, ReqId};

/// Simulation time in picoseconds.
pub type Tick = u64;

/// Convert nanoseconds (f64 config values) to ticks.
#[inline]
pub fn ns_to_ticks(ns: f64) -> Tick {
    (ns * 1000.0).round() as Tick
}

/// Convert ticks back to nanoseconds.
#[inline]
pub fn ticks_to_ns(t: Tick) -> f64 {
    t as f64 / 1000.0
}

/// Serialization delay of `bytes` over a link of `gbps` GB/s, in ticks.
/// (1 GB/s == 1 byte/ns.)
#[inline]
pub fn ser_ticks(bytes: u64, gbps: f64) -> Tick {
    if gbps <= 0.0 {
        return 0;
    }
    ns_to_ticks(bytes as f64 / gbps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tick_conversions_roundtrip() {
        assert_eq!(ns_to_ticks(1.0), 1000);
        assert_eq!(ns_to_ticks(0.5), 500);
        assert!((ticks_to_ns(2500) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn serialization_delay() {
        // 64 B at 32 GB/s = 2 ns = 2000 ticks.
        assert_eq!(ser_ticks(64, 32.0), 2000);
        assert_eq!(ser_ticks(64, 0.0), 0);
    }
}
