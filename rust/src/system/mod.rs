//! The full-system machine: topology construction, boot, and the
//! event-driven memory system (Fig. 1B).
//!
//! Timing methodology (DESIGN.md §S20): components keep *stateful
//! occupancy* (bus layers, DRAM banks, link flits, credits), so a miss's
//! end-to-end latency is composed synchronously at miss time by walking
//! the path CPU -> L1 -> (dir) -> L2 -> {membus -> DRAM | membus ->
//! IOBus -> RC -> link -> device}; only genuinely asynchronous points
//! (responses, credit stalls, DRAM-queue-full retries) become events.
//! This is the classic latency-composition DES style: contention and
//! queueing are modeled by the components' occupancy state, event count
//! stays proportional to misses, and runs are bit-deterministic.

pub mod machine;
pub mod mmio;

pub use machine::{Machine, RunSummary};
pub use mmio::MmioWorld;
