//! The full-system machine: per-host stacks over a shared CXL fabric,
//! boot, and the event-driven memory system (Fig. 1B).
//!
//! Since the multi-host split, [`machine::Machine`] is a thin shell:
//! it owns `hosts` [`host::Host`] instances (cores, caches, directory,
//! buses, DRAM, BIOS image, guest OS, root complex) plus one shared
//! [`crate::cxl::Fabric`] (devices, switches, links, FM LD ownership)
//! and a single unified event queue whose events are tagged by host —
//! `(tick, seq)` ordering is global, so runs stay bit-deterministic.
//!
//! Timing methodology (DESIGN.md §S20): components keep *stateful
//! occupancy* (bus layers, DRAM banks, link flits, credits), so a miss's
//! end-to-end latency is composed synchronously at miss time by walking
//! the path CPU -> L1 -> (dir) -> L2 -> {membus -> DRAM | membus ->
//! IOBus -> RC -> fabric -> device}; only genuinely asynchronous points
//! (responses, credit stalls, DRAM-queue-full retries, MSHR-full parks)
//! become events. This is the classic latency-composition DES style:
//! contention and queueing are modeled by the components' occupancy
//! state — shared fabric state is exactly how cross-host contention
//! shows up — event count stays proportional to misses, and runs are
//! bit-deterministic.

pub mod host;
pub mod machine;
pub mod mmio;

pub use host::{Host, MachineStats};
pub use machine::{Machine, RunSummary};
pub use mmio::MmioWorld;
