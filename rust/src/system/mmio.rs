//! MMIO routing: the machine's physical-address decode for device
//! registers, exposed to the guest as [`crate::guestos::Platform`].
//!
//! Routes:
//!   * ECAM window -> per-function config spaces,
//!   * CHBS block  -> host-bridge (RC) component registers,
//!   * endpoint BARs (after assignment) -> device component / mailbox
//!     blocks.

use crate::cxl::regs::ComponentRegs;
use crate::cxl::CxlDevice;
use crate::guestos::Platform;
use crate::pcie::{Bdf, Ecam};

pub struct MmioWorld<'a> {
    pub ecam: &'a mut Ecam,
    pub cxl_dev: &'a mut CxlDevice,
    pub hb_component: &'a mut ComponentRegs,
    pub chbs_base: u64,
    pub chbs_size: u64,
    pub ep_bdf: Bdf,
}

impl<'a> MmioWorld<'a> {
    /// Resolve the endpoint's currently-programmed BARs (the guest may
    /// have just written them through ECAM).
    fn ep_bar(&self, idx: usize) -> Option<(u64, u64)> {
        let cfg = self.ecam.function(self.ep_bdf)?;
        let base = cfg.bar_addr(idx)?;
        Some((base, cfg.bar_size(idx)))
    }

    /// Route an address: 0 = ECAM, 1 = CHBS, 2 = BAR0 (component),
    /// 3 = BAR2 (device block).
    fn route(&self, addr: u64) -> Option<(u8, u64)> {
        if self.ecam.contains(addr) {
            return Some((0, addr));
        }
        if addr >= self.chbs_base && addr < self.chbs_base + self.chbs_size {
            return Some((1, addr - self.chbs_base));
        }
        if let Some((b, s)) = self.ep_bar(0) {
            if addr >= b && addr < b + s {
                return Some((2, addr - b));
            }
        }
        if let Some((b, s)) = self.ep_bar(2) {
            if addr >= b && addr < b + s {
                return Some((3, addr - b));
            }
        }
        None
    }
}

impl<'a> Platform for MmioWorld<'a> {
    fn mmio_read32(&mut self, addr: u64) -> u32 {
        match self.route(addr) {
            Some((0, a)) => self.ecam.mmio_read32(a),
            Some((1, off)) => self.hb_component.read32(off),
            Some((2, off)) => self.cxl_dev.mmio_read(0, off) as u32,
            Some((3, off)) => {
                // 32-bit view of the 64-bit device registers.
                let v = self.cxl_dev.mmio_read(2, off & !7);
                (v >> ((addr & 4) * 8)) as u32
            }
            _ => 0xFFFF_FFFF,
        }
    }

    fn mmio_write32(&mut self, addr: u64, v: u32) {
        match self.route(addr) {
            Some((0, a)) => self.ecam.mmio_write32(a, v),
            Some((1, off)) => self.hb_component.write32(off, v),
            Some((2, off)) => self.cxl_dev.mmio_write(0, off, v as u64),
            Some((3, off)) => {
                let old = self.cxl_dev.mmio_read(2, off & !7);
                let sh = (addr & 4) * 8;
                let nv =
                    (old & !(0xFFFF_FFFFu64 << sh)) | ((v as u64) << sh);
                self.cxl_dev.mmio_write(2, off & !7, nv);
            }
            _ => {}
        }
    }

    fn mmio_read64(&mut self, addr: u64) -> u64 {
        match self.route(addr) {
            Some((3, off)) => self.cxl_dev.mmio_read(2, off),
            _ => {
                let lo = self.mmio_read32(addr) as u64;
                let hi = self.mmio_read32(addr + 4) as u64;
                lo | (hi << 32)
            }
        }
    }

    fn mmio_write64(&mut self, addr: u64, v: u64) {
        match self.route(addr) {
            Some((3, off)) => self.cxl_dev.mmio_write(2, off, v),
            _ => {
                self.mmio_write32(addr, v as u32);
                self.mmio_write32(addr + 4, (v >> 32) as u32);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bios::layout;
    use crate::config::SimConfig;
    use crate::cxl::regs::dev;
    use crate::pcie;

    fn world() -> (Ecam, CxlDevice, ComponentRegs, Bdf) {
        let cfg = SimConfig::default();
        let mut ecam = Ecam::new(layout::ECAM_BASE, layout::ECAM_BUSES);
        let (_, _, ep) = pcie::build_topology(&mut ecam);
        // Endpoint BARs: BAR0 = 64 KiB component, BAR2 = 4 KiB device.
        let epc = ecam.function_mut(ep).unwrap();
        epc.add_bar64(0, 1 << 16);
        epc.add_bar64(2, 1 << 12);
        epc.assign_bar(0, 0xF010_0000);
        epc.assign_bar(2, 0xF012_0000);
        let dev = CxlDevice::new(&cfg.cxl, 42);
        let hb = ComponentRegs::new(1);
        (ecam, dev, hb, ep)
    }

    #[test]
    fn routes_all_four_surfaces() {
        let (mut ecam, mut dev, mut hb, ep) = world();
        let mut w = MmioWorld {
            ecam: &mut ecam,
            cxl_dev: &mut dev,
            hb_component: &mut hb,
            chbs_base: layout::CHBS_BASE,
            chbs_size: layout::CHBS_SIZE,
            ep_bdf: ep,
        };
        // ECAM: endpoint vendor id.
        let vid = w.mmio_read32(layout::ECAM_BASE + ep.ecam_offset());
        assert_eq!(vid & 0xFFFF, pcie::ids::VENDOR_CXL_DEV as u32);
        // CHBS: capability header.
        assert_eq!(w.mmio_read32(layout::CHBS_BASE) & 0xFFFF, 0x0001);
        // BAR0: component header.
        assert_eq!(w.mmio_read32(0xF010_0000) & 0xFFFF, 0x0001);
        // BAR2: mailbox caps (64-bit reg).
        assert_eq!(w.mmio_read64(0xF012_0000 + dev::MB_CAPS), 9);
        // Unmapped floats high.
        assert_eq!(w.mmio_read32(0x1234_5678), 0xFFFF_FFFF);
    }

    #[test]
    fn split_32bit_access_to_64bit_regs() {
        let (mut ecam, mut dev, mut hb, ep) = world();
        let mut w = MmioWorld {
            ecam: &mut ecam,
            cxl_dev: &mut dev,
            hb_component: &mut hb,
            chbs_base: layout::CHBS_BASE,
            chbs_size: layout::CHBS_SIZE,
            ep_bdf: ep,
        };
        let cmd = 0xF012_0000 + dev::MB_CMD;
        w.mmio_write32(cmd, 0x4000);
        w.mmio_write32(cmd + 4, 0x1);
        assert_eq!(w.mmio_read64(cmd), 0x1_0000_4000);
        assert_eq!(w.mmio_read32(cmd + 4), 1);
    }
}
