//! MMIO routing: the machine's physical-address decode for device
//! registers, exposed to the guest as [`crate::guestos::Platform`].
//!
//! Routes:
//!   * ECAM window -> per-function config spaces,
//!   * CHBS blocks -> per-host-bridge (RC) component registers
//!     (`chbs_base + i * chbs_stride` for host bridge `i`),
//!   * endpoint BARs (after assignment) -> that device's component /
//!     mailbox blocks.

use crate::cxl::regs::ComponentRegs;
use crate::cxl::CxlDevice;
use crate::guestos::Platform;
use crate::pcie::{Bdf, Ecam};

pub struct MmioWorld<'a> {
    pub ecam: &'a mut Ecam,
    /// One device model per endpoint, same order as `ep_bdfs`.
    pub cxl_devs: &'a mut [CxlDevice],
    /// One host-bridge component block per device.
    pub hb_components: &'a mut [ComponentRegs],
    pub chbs_base: u64,
    /// Stride between consecutive CHBS blocks (= block size).
    pub chbs_stride: u64,
    pub ep_bdfs: &'a [Bdf],
}

/// A decoded MMIO target.
enum Route {
    Ecam(u64),
    /// (host bridge index, offset)
    Chbs(usize, u64),
    /// (device index, offset) into BAR0 = component registers.
    Bar0(usize, u64),
    /// (device index, offset) into BAR2 = device/mailbox registers.
    Bar2(usize, u64),
}

impl<'a> MmioWorld<'a> {
    /// Resolve endpoint `i`'s currently-programmed BAR (the guest may
    /// have just written it through ECAM).
    fn ep_bar(&self, i: usize, idx: usize) -> Option<(u64, u64)> {
        let cfg = self.ecam.function(self.ep_bdfs[i])?;
        let base = cfg.bar_addr(idx)?;
        Some((base, cfg.bar_size(idx)))
    }

    fn route(&self, addr: u64) -> Option<Route> {
        if self.ecam.contains(addr) {
            return Some(Route::Ecam(addr));
        }
        let n = self.hb_components.len();
        let chbs_end = self.chbs_base + self.chbs_stride * n as u64;
        if addr >= self.chbs_base && addr < chbs_end {
            let off = addr - self.chbs_base;
            return Some(Route::Chbs(
                (off / self.chbs_stride) as usize,
                off % self.chbs_stride,
            ));
        }
        for i in 0..self.ep_bdfs.len() {
            if let Some((b, s)) = self.ep_bar(i, 0) {
                if addr >= b && addr < b + s {
                    return Some(Route::Bar0(i, addr - b));
                }
            }
            if let Some((b, s)) = self.ep_bar(i, 2) {
                if addr >= b && addr < b + s {
                    return Some(Route::Bar2(i, addr - b));
                }
            }
        }
        None
    }
}

impl<'a> Platform for MmioWorld<'a> {
    fn mmio_read32(&mut self, addr: u64) -> u32 {
        match self.route(addr) {
            Some(Route::Ecam(a)) => self.ecam.mmio_read32(a),
            Some(Route::Chbs(i, off)) => self.hb_components[i].read32(off),
            Some(Route::Bar0(i, off)) => {
                self.cxl_devs[i].mmio_read(0, off) as u32
            }
            Some(Route::Bar2(i, off)) => {
                // 32-bit view of the 64-bit device registers.
                let v = self.cxl_devs[i].mmio_read(2, off & !7);
                (v >> ((addr & 4) * 8)) as u32
            }
            None => 0xFFFF_FFFF,
        }
    }

    fn mmio_write32(&mut self, addr: u64, v: u32) {
        match self.route(addr) {
            Some(Route::Ecam(a)) => self.ecam.mmio_write32(a, v),
            Some(Route::Chbs(i, off)) => {
                self.hb_components[i].write32(off, v)
            }
            Some(Route::Bar0(i, off)) => {
                self.cxl_devs[i].mmio_write(0, off, v as u64)
            }
            Some(Route::Bar2(i, off)) => {
                let old = self.cxl_devs[i].mmio_read(2, off & !7);
                let sh = (addr & 4) * 8;
                let nv =
                    (old & !(0xFFFF_FFFFu64 << sh)) | ((v as u64) << sh);
                self.cxl_devs[i].mmio_write(2, off & !7, nv);
            }
            None => {}
        }
    }

    fn mmio_read64(&mut self, addr: u64) -> u64 {
        match self.route(addr) {
            Some(Route::Bar2(i, off)) => self.cxl_devs[i].mmio_read(2, off),
            _ => {
                let lo = self.mmio_read32(addr) as u64;
                let hi = self.mmio_read32(addr + 4) as u64;
                lo | (hi << 32)
            }
        }
    }

    fn mmio_write64(&mut self, addr: u64, v: u64) {
        match self.route(addr) {
            Some(Route::Bar2(i, off)) => {
                self.cxl_devs[i].mmio_write(2, off, v)
            }
            _ => {
                self.mmio_write32(addr, v as u32);
                self.mmio_write32(addr + 4, (v >> 32) as u32);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bios::layout;
    use crate::config::SimConfig;
    use crate::cxl::regs::dev;
    use crate::pcie;

    fn world() -> (Ecam, Vec<CxlDevice>, Vec<ComponentRegs>, Vec<Bdf>) {
        let cfg = SimConfig::default();
        let mut ecam = Ecam::new(layout::ECAM_BASE, layout::ECAM_BUSES);
        let (_, _, ep) = pcie::build_topology(&mut ecam);
        // Endpoint BARs: BAR0 = 64 KiB component, BAR2 = 4 KiB device.
        let epc = ecam.function_mut(ep).unwrap();
        epc.add_bar64(0, 1 << 16);
        epc.add_bar64(2, 1 << 12);
        epc.assign_bar(0, 0xF010_0000);
        epc.assign_bar(2, 0xF012_0000);
        let devs = vec![CxlDevice::new(&cfg.cxl, 42)];
        let hbs = vec![ComponentRegs::new(1)];
        (ecam, devs, hbs, vec![ep])
    }

    #[test]
    fn routes_all_four_surfaces() {
        let (mut ecam, mut devs, mut hbs, eps) = world();
        let mut w = MmioWorld {
            ecam: &mut ecam,
            cxl_devs: &mut devs,
            hb_components: &mut hbs,
            chbs_base: layout::CHBS_BASE,
            chbs_stride: layout::CHBS_SIZE,
            ep_bdfs: &eps,
        };
        // ECAM: endpoint vendor id.
        let vid = w.mmio_read32(layout::ECAM_BASE + eps[0].ecam_offset());
        assert_eq!(vid & 0xFFFF, pcie::ids::VENDOR_CXL_DEV as u32);
        // CHBS: capability header.
        assert_eq!(w.mmio_read32(layout::CHBS_BASE) & 0xFFFF, 0x0001);
        // BAR0: component header.
        assert_eq!(w.mmio_read32(0xF010_0000) & 0xFFFF, 0x0001);
        // BAR2: mailbox caps (64-bit reg).
        assert_eq!(w.mmio_read64(0xF012_0000 + dev::MB_CAPS), 9);
        // Unmapped floats high.
        assert_eq!(w.mmio_read32(0x1234_5678), 0xFFFF_FFFF);
    }

    #[test]
    fn split_32bit_access_to_64bit_regs() {
        let (mut ecam, mut devs, mut hbs, eps) = world();
        let mut w = MmioWorld {
            ecam: &mut ecam,
            cxl_devs: &mut devs,
            hb_components: &mut hbs,
            chbs_base: layout::CHBS_BASE,
            chbs_stride: layout::CHBS_SIZE,
            ep_bdfs: &eps,
        };
        let cmd = 0xF012_0000 + dev::MB_CMD;
        w.mmio_write32(cmd, 0x4000);
        w.mmio_write32(cmd + 4, 0x1);
        assert_eq!(w.mmio_read64(cmd), 0x1_0000_4000);
        assert_eq!(w.mmio_read32(cmd + 4), 1);
    }

    #[test]
    fn second_device_surfaces_route_independently() {
        let cfg = SimConfig::default();
        let mut ecam = Ecam::new(layout::ECAM_BASE, layout::ECAM_BUSES);
        let (_, _, eps) = pcie::build_topology_n(&mut ecam, 2);
        for (i, ep) in eps.iter().enumerate() {
            let epc = ecam.function_mut(*ep).unwrap();
            epc.add_bar64(0, 1 << 16);
            epc.add_bar64(2, 1 << 12);
            epc.assign_bar(0, 0xF020_0000 + (i as u64) * 0x4_0000);
            epc.assign_bar(2, 0xF022_0000 + (i as u64) * 0x4_0000);
        }
        let mut devs =
            vec![CxlDevice::new(&cfg.cxl, 1), CxlDevice::new(&cfg.cxl, 2)];
        let mut hbs = vec![ComponentRegs::new(1), ComponentRegs::new(1)];
        let mut w = MmioWorld {
            ecam: &mut ecam,
            cxl_devs: &mut devs,
            hb_components: &mut hbs,
            chbs_base: layout::CHBS_BASE,
            chbs_stride: layout::CHBS_SIZE,
            ep_bdfs: &eps,
        };
        // Both CHBS blocks answer with the capability header.
        assert_eq!(w.mmio_read32(layout::chbs_base(0)) & 0xFFFF, 0x0001);
        assert_eq!(w.mmio_read32(layout::chbs_base(1)) & 0xFFFF, 0x0001);
        // A doorbell ring on device 1's mailbox leaves device 0 idle.
        let mb1 = 0xF022_0000 + 0x4_0000;
        w.mmio_write64(mb1 + dev::MB_CMD, 0x4000);
        w.mmio_write64(mb1 + dev::MB_CTRL, 1);
        drop(w);
        assert_eq!(devs[1].mailbox.commands_executed, 1);
        assert_eq!(devs[0].mailbox.commands_executed, 0);
    }
}
