//! The machine shell: `hosts` [`Host`] stacks over one shared
//! [`Fabric`], driven by the rack-scale conservative-parallel event
//! loop.
//!
//! # Parallel determinism contract
//!
//! Every host owns its own event queue and drains it independently —
//! on a worker thread when `[sim] threads > 1`, inline otherwise. The
//! fabric is the only shared timing state, and it only ever mutates in
//! one canonical order: fabric-crossing requests are committed from a
//! global `(entry tick, host id, per-host seq)` map. Hosts
//! self-throttle to their lookahead horizon (the minimum fixed
//! round-trip to any reachable device — see
//! [`Host::recompute_lookahead`]), so no host ever runs past a tick at
//! which a fabric response could still land. The commit window is
//! bounded the same way from the machine side: an entry at tick `t`
//! commits only once every host has drained past `t - d_min` (no new
//! request can enter the fabric at or before `t` any more) and no
//! already-committed response could schedule new fabric entries before
//! `t`. Because both the epoch structure and the commit order are pure
//! functions of queue state — never of thread scheduling — a
//! `threads = N` run is bit-identical to a serial one: same stats,
//! same guest memory images, same event counts.
//!
//! ## Sharded commit lanes (`[sim] commit_lanes`)
//!
//! The commit phase itself shards across worker threads without
//! weakening that contract, under three lane-partitioning rules:
//!
//! 1. **Device-disjointness.** Pending entries partition by routed
//!    target device (fixed at enqueue time), and each lane owns a
//!    `&mut`-disjoint slice of the fabric interior
//!    ([`Fabric::lane_views`]) — two lanes can never touch the same
//!    link, switch, or device state.
//! 2. **Switch-group serialization.** Devices behind one switch share
//!    its upstream credit pool, so [`Fabric::lane_ranges`] folds a
//!    switch's whole span into a single lane: shared-credit accounting
//!    (availability probes, stall notes, retirements) is always
//!    serialized inside one lane, in canonical order.
//! 3. **Canonical merge order.** A wave hands each lane its entries in
//!    global `(tick, host, seq)` order restricted to that lane's
//!    devices; waves are sized (`min(window, t0 + d_min)`) so no
//!    same-wave delivery can tighten the window into the wave. Lane
//!    outputs — responses, deferred retries, window bounds — merge
//!    back on the main thread sorted by the same global key, which
//!    reproduces the serial delivery order exactly. Every
//!    `(threads, commit_lanes)` combination is therefore bit-identical
//!    to serial, enforced by `rust/tests/parallel_determinism.rs`.
//!
//! Machine-level events (scripted FM actions, policy epochs, deferred
//! policy moves) live in the machine's own small queue. They cut the
//! run into *sections*: all host work strictly before a machine event's
//! tick settles first (the epoch loop runs to a fixpoint), then the
//! machine event executes on fully-quiesced state, then the next
//! section starts with freshly derived horizons (an FM re-bind changes
//! the reachable-device set, hence the lookahead).
//!
//! For the (default) single-host case, `Machine` derefs to host 0:
//! `m.guest`, `m.l1s`, `m.rc`, … read exactly as they did before the
//! host/fabric split. Multi-host code addresses `m.hosts[h]` and
//! `m.fabric` explicitly.
//!
//! A `[fm] events` schedule adds `MEv::Fm` entries: at their simulated
//! timestamps the fabric manager re-binds logical devices between
//! running hosts (quiesce -> Event-Log doorbell -> guest
//! offline/online through the unmodified driver path -> mailbox
//! `UNBIND_LD`/`BIND_LD` -> RC routing update). An `[fm] policy`
//! closes the loop instead: `MEv::FmEpoch` entries fire on a fixed
//! cadence, the [`crate::cxl::fm_policy::FmPolicyEngine`]
//! differentiates per-host / per-LD load and decides moves itself
//! (deferred moves re-probe as `MEv::FmMove`). Either way the actions
//! run between sections, on settled state — policy-driven runs stay
//! bit-deterministic at every thread count.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::bios;
use crate::config::{FmOp, InterleaveArith, LdRef, SimConfig};
use crate::cxl::fm_policy::{FmPolicyEngine, HostLoad, LdState};
use crate::cxl::mailbox::{event, retcode, EventRecord, UNBOUND};
use crate::cxl::mem_proto;
use crate::cxl::{CreditAvail, Fabric, FabricLane, HdmWindow};
use crate::guestos::{GuestOs, MemChange, MemPolicy, ProgModel};
use crate::sim::invariants::{CommitOrderAudit, InvariantChecker};
use crate::sim::{ns_to_ticks, ticks_to_ns, EventQueue, Tick};
use crate::stats::StatDump;
use crate::workloads::Workload;

use super::host::{Ev, FabricReq, Host};
use super::mmio::MmioWorld;

pub use super::host::MachineStats;

/// End-of-run digest used by benches and examples. For multi-host
/// machines the core-side numbers aggregate over all hosts and the
/// link-side numbers are fabric totals.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub ticks: Tick,
    pub seconds: f64,
    pub bytes_moved: u64,
    pub bandwidth_gbps: f64,
    pub l1_miss_rate: f64,
    pub l2_miss_rate: f64,
    pub dram_accesses: u64,
    pub cxl_accesses: u64,
    /// Line fills per expander device (summed over hosts).
    pub cxl_dev_fills: Vec<u64>,
    pub avg_lat_dram_ns: f64,
    pub avg_lat_cxl_ns: f64,
    pub m2s_req: u64,
    pub m2s_rwd: u64,
    pub s2m_ndr: u64,
    pub s2m_drs: u64,
    /// Back-invalidate snoops (S2M BISnp) across all leaf links.
    pub s2m_bisnp: u64,
    /// Back-invalidate acks (M2S BIRsp) across all leaf links.
    pub m2s_birsp: u64,
    pub events: u64,
}

/// Machine-level events: fabric-manager actions and policy epochs.
/// They span hosts, so they live in the machine's own queue and bound
/// the host sections — a machine event at tick `T` runs after every
/// host event strictly before `T` and before any host event at `T`.
#[derive(Debug)]
enum MEv {
    /// Scheduled `[fm] events` entry (index into `cfg.fm_events`).
    Fm(u32),
    /// `[fm] policy` sampling epoch.
    FmEpoch,
    /// A quiesce-deferred policy move re-probing.
    FmMove { dev: u8, ld: u8, from: u8, to: u8 },
}

pub struct Machine {
    pub cfg: SimConfig,
    /// The per-host stacks, index = host id.
    pub hosts: Vec<Host>,
    /// The shared CXL tree all hosts' root ports lead into.
    pub fabric: Fabric,
    /// Machine-level events only (FM actions, policy epochs); host
    /// events live in each host's own queue.
    mq: EventQueue<MEv>,
    /// Fabric-crossing requests awaiting commit, in the canonical
    /// global `(entry tick, host id, per-host seq)` order.
    pending: BTreeMap<(Tick, u8, u64), FabricReq>,
    /// RC-side packetization cost (ticks) — commit-phase timing.
    pkt_ticks: Tick,
    /// RC-side de-packetization cost (ticks).
    depkt_ticks: Tick,
    /// Fixed protocol adder per device (MemBus-baseline media timing).
    dev_fixed_ticks: Vec<Tick>,
    /// Minimum host-side delay between an event at `t` and any fabric
    /// entry it can cause: one membus hop is always in the way and
    /// `Bus::transfer` costs at least `1 + lat` ticks.
    d_min: Tick,
    /// Epochs run by the section scheduler (thread-count-invariant).
    par_epochs: u64,
    /// Cross-host synchronization points: epochs in which two or more
    /// hosts made progress, weighted by how many did. Identical at
    /// every thread count — it measures available parallelism, not
    /// achieved parallelism.
    par_barrier_waits: u64,
    /// Smallest finite lookahead horizon seen at any section start
    /// (`Tick::MAX` if no host ever had a reachable device).
    par_horizon_min: Tick,
    /// The `[fm] events` schedule has been injected into the queue
    /// (first `run` call only).
    fm_scheduled: bool,
    /// Logical devices whose most recent FM unbind the owning guest
    /// refused (pages in use). A scheduled bind finding the LD still
    /// owned retries while its unbind is merely quiescing, but gives
    /// up once the unbind was refused — refusal is terminal for the
    /// run, so retrying would never terminate.
    fm_refused: std::collections::BTreeSet<(usize, u16)>,
    /// Telemetry-driven FM policy engine (`[fm] policy`): samples
    /// per-host/per-LD load on `MEv::FmEpoch` ticks and decides
    /// UNBIND/BIND moves, executed through the same flow as scripted
    /// `MEv::Fm` events. `None` without a policy.
    fm_policy: Option<FmPolicyEngine>,
    /// Policy moves currently parked in quiesce deferral (an
    /// `MEv::FmMove` re-probe chain is in flight for each). Epochs skip
    /// re-deciding these so one real quiesce wait spawns one chain —
    /// not one per epoch — keeping `fm.policy.deferrals` /
    /// `sys.fm_quiesce_retries` honest.
    fm_moves_parked: std::collections::BTreeSet<(usize, u16)>,
    /// `cfg.window_keys()` snapshot (fixed after validation), so the
    /// per-epoch telemetry sweep and `def_window` lookups don't
    /// rebuild the key list on every call.
    window_keys: Vec<LdRef>,
    /// `cfg.cxl.window_defs()` snapshot (fixed after validation):
    /// boot-time and hot-add window mirrors look defs up here instead
    /// of rebuilding the list per call.
    win_defs: Vec<crate::config::CxlWindowDef>,
    /// Shared target lists, aligned with `win_defs` — mirroring a
    /// window into a host's RC clones an `Arc`, not a `Vec`.
    win_targets: Vec<Arc<[usize]>>,
    /// Commit-lane partition of the fabric ([`Fabric::lane_ranges`]) —
    /// fixed at build time (FM re-binds move LD ownership, never the
    /// device/switch topology).
    lane_ranges: Vec<(usize, usize)>,
    /// Device index -> lane group ([`Fabric::lane_of_dev`]) snapshot,
    /// so the wave distributor can route entries while lane views hold
    /// `&mut` borrows of the fabric interior.
    lane_of_dev: Vec<usize>,
    /// Reusable per-host response inboxes: the commit phase pushes
    /// fills in, the next epoch's drain consumes them in place — one
    /// allocation per host for the whole run, not one per epoch.
    inboxes: Vec<Vec<(Tick, Ev)>>,
    /// Reusable oldest-pending-entry scratch (per host).
    scratch_oldest: Vec<Tick>,
    /// Reusable epoch-cap scratch (per host).
    scratch_caps: Vec<Tick>,
    /// Reusable canonical-merge buffer for sharded-commit lane outputs:
    /// `(pop key + delivery sub-index, target host, delivery tick,
    /// event)`. One committed entry can deliver to several hosts (a
    /// shared-LD RFO back-invalidates every other sharer before the
    /// requester's fill), so the sub-index keeps equal pop keys in the
    /// emission order the serial path uses, and the target host rides
    /// explicitly instead of in the key.
    merge_buf: Vec<((Tick, u8, u64, u32), u8, Tick, Ev)>,
    /// Per host: the other hosts it shares at least one BI-coherent
    /// window with (empty everywhere without shared LDs).
    bi_peers: Vec<Vec<usize>>,
    /// Any host has a nonempty `bi_peers` entry.
    has_bi: bool,
    /// Lower bound on how far ahead of its triggering commit a BISnp
    /// can land at a sharer host (RC packetize + depacketize, >= 1
    /// tick): the epoch cap for a sharer must stay within this horizon
    /// of its peers' oldest undrained work, or a back-invalidation
    /// could arrive in the host's past.
    bi_horizon: Tick,
    /// Wall-clock spent draining hosts (ns) — see
    /// [`Machine::dump_stats_full`]. Not deterministic; never part of
    /// golden digests.
    wall_drain_ns: u64,
    /// Wall-clock spent committing fabric entries (ns).
    wall_commit_ns: u64,
    /// Wall-clock spent merging outboxes/lane outputs back (ns).
    wall_merge_ns: u64,
    /// Runtime protocol-invariant engine (`[sim] check` / `--check`).
    /// `None` (the default) costs nothing on the hot paths; when armed,
    /// the section loops feed it commit keys and audit after each
    /// settle, and `run` fails loudly on any recorded violation.
    checker: Option<InvariantChecker>,
}

/// Re-probe interval while an FM unbind waits for in-flight requests to
/// the departing window to drain (ns).
const FM_QUIESCE_RETRY_NS: f64 = 500.0;

/// Single-host ergonomics: the overwhelmingly common `hosts = 1` case
/// reads as it did before the host/fabric split (`m.guest`, `m.l1s`,
/// `m.rc`, …). Multi-host code must address `m.hosts[h]` explicitly.
impl std::ops::Deref for Machine {
    type Target = Host;
    fn deref(&self) -> &Host {
        &self.hosts[0]
    }
}

impl std::ops::DerefMut for Machine {
    fn deref_mut(&mut self) -> &mut Host {
        &mut self.hosts[0]
    }
}

/// Per-host mailbox slots the parallel section loop trades through:
/// main thread fills `cap`/`inbox`, the owning worker fills the rest.
#[derive(Default)]
struct EpochSlot {
    cap: Tick,
    inbox: Vec<(Tick, Ev)>,
    processed: u64,
    outbox: Vec<(Tick, u64, FabricReq)>,
    next_tick: Option<Tick>,
}

/// Worker-pool phase word for the sharded section loop: what the next
/// `start`-barrier release asks the workers to do.
const PHASE_DRAIN: u8 = 0;
const PHASE_COMMIT: u8 = 1;
const PHASE_STOP: u8 = 2;

/// One commit lane's mailbox for the sharded commit phase: the lane's
/// `&mut`-disjoint fabric view plus the wave working state the main
/// thread fills (`input`, `wave_hi`) and the owning worker fills back
/// (`out`, `deferred`, `handled`, `w_min`).
struct LaneSlot<'a> {
    lane: FabricLane<'a>,
    /// This wave's entries for this lane's devices, in global
    /// `(tick, host, seq)` order (the distributor pops the pending map
    /// in key order).
    input: Vec<((Tick, u8, u64), FabricReq)>,
    /// Wave-local working set: input entries plus credit-race retries
    /// whose retry key still falls inside the wave.
    local: BTreeMap<(Tick, u8, u64), FabricReq>,
    /// Deliveries for the canonical merge: `(pop key + sub-index,
    /// target host, delivery tick, event)` — see
    /// [`Machine`]'s `merge_buf` for the key shape rationale.
    out: Vec<((Tick, u8, u64, u32), u8, Tick, Ev)>,
    /// Retries that left the wave — returned to the global pending map.
    deferred: Vec<((Tick, u8, u64), FabricReq)>,
    /// Exclusive upper tick bound of this wave.
    wave_hi: Tick,
    /// Entries popped this wave (commits + retries), the progress
    /// signal summed by the main thread.
    handled: u64,
    /// Tightest `done + d_min` window bound among this wave's
    /// deliveries (`Tick::MAX` if none).
    w_min: Tick,
}

/// Commit one wave of one lane's entries against its fabric slice —
/// the sharded twin of [`commit_pending`]'s dispatch arms, byte-for-
/// byte the same timing math. Entries (and any same-wave retries)
/// process in `(tick, host, seq)` order; every popped key's tick is in
/// `[t0, wave_hi)`, and since a delivery retires at `done > t0` its
/// window contribution `done + d_min >= wave_hi` — no same-wave
/// delivery can invalidate the wave, which is what makes per-lane
/// processing exactly equivalent to the serial global pop loop.
fn commit_lane_wave(
    sl: &mut LaneSlot<'_>,
    pkt_ticks: Tick,
    depkt_ticks: Tick,
    dev_fixed_ticks: &[Tick],
    d_min: Tick,
    line: u64,
) {
    sl.handled = 0;
    sl.w_min = Tick::MAX;
    if sl.input.is_empty() {
        return;
    }
    let mut handled = 0u64;
    let mut w_min = Tick::MAX;
    let wave_hi = sl.wave_hi;
    let LaneSlot { lane, input, local, out, deferred, .. } = sl;
    local.extend(input.drain(..));
    while let Some((&(t, _, _), _)) = local.first_key_value() {
        if t >= wave_hi {
            break;
        }
        let ((t, h, seq), req) = local.pop_first().unwrap();
        handled += 1;
        match req {
            FabricReq::Fetch { dev, pkt, core, line_pa, issued_at } => {
                let after_pkt = t + pkt_ticks;
                let retry = {
                    let link = lane.credit_link(dev);
                    match link.credit_available_at(after_pkt) {
                        CreditAvail::Now => None,
                        CreditAvail::RetiresAt(rt) => {
                            link.note_credit_stall(after_pkt, rt);
                            Some(rt)
                        }
                        CreditAvail::Unknown => {
                            let rt = link.reprobe_at(after_pkt);
                            link.note_credit_stall(after_pkt, rt);
                            Some(rt)
                        }
                    }
                };
                if let Some(rt) = retry {
                    local.insert(
                        (rt.max(t + 1), h, seq),
                        FabricReq::Fetch {
                            dev,
                            pkt,
                            core,
                            line_pa,
                            issued_at,
                        },
                    );
                    continue;
                }
                let arrival = lane.send_m2s(after_pkt, &pkt, dev);
                let (resp, ready) =
                    lane.device_mut(dev).handle_m2s(arrival, &pkt, h);
                // Device-side coherence: the snoop filter may have
                // queued back-invalidations to other sharer hosts.
                // Emit them before the requester's fill, in filter
                // order, each under this pop key with a rising
                // sub-index — byte-identical to the serial push order.
                let mut sub = 0u32;
                for bi in lane.device_mut(dev).take_pending_bi() {
                    let snp =
                        mem_proto::make_bi_snoop(bi.dpa, pkt.tag, pkt.req_id);
                    let at_host = lane.send_s2m(arrival, &snp, dev);
                    let deliver = at_host + depkt_ticks;
                    out.push((
                        (t, h, seq, sub),
                        bi.host,
                        deliver,
                        Ev::BiInv { dev, dpa: bi.dpa },
                    ));
                    sub += 1;
                    w_min = w_min.min(deliver.saturating_add(d_min));
                }
                let rc_arrival = lane.send_s2m(ready, &resp, dev);
                let done = rc_arrival + depkt_ticks;
                lane.retire(dev, done);
                out.push((
                    (t, h, seq, sub),
                    h,
                    done,
                    Ev::CxlFill { core, line_pa, issued_at },
                ));
                w_min = w_min.min(done.saturating_add(d_min));
            }
            FabricReq::Writeback { dev, pkt } => {
                let after_pkt = t + pkt_ticks;
                let ok = {
                    let link = lane.credit_link(dev);
                    match link.credit_available_at(after_pkt) {
                        CreditAvail::Now => true,
                        CreditAvail::RetiresAt(rt) => {
                            link.note_credit_stall(after_pkt, rt);
                            false
                        }
                        CreditAvail::Unknown => {
                            let rt = link.reprobe_at(after_pkt);
                            link.note_credit_stall(after_pkt, rt);
                            false
                        }
                    }
                };
                // Credit exhaustion drops the posted write from the
                // timing model (data is already functionally in
                // physmem) — same semantics as the serial path.
                if ok {
                    let arrival = lane.send_m2s(after_pkt, &pkt, dev);
                    let (resp, ready) =
                        lane.device_mut(dev).handle_m2s(arrival, &pkt, h);
                    let rc_arrival = lane.send_s2m(ready, &resp, dev);
                    let done = rc_arrival + depkt_ticks;
                    lane.retire(dev, done);
                }
            }
            FabricReq::MediaFetch { dev, dpa, core, line_pa } => {
                let done = lane.device_mut(dev).media.access(
                    t + dev_fixed_ticks[dev],
                    dpa,
                    line,
                    false,
                );
                out.push((
                    (t, h, seq, 0),
                    h,
                    done,
                    Ev::CxlFill { core, line_pa, issued_at: t },
                ));
                w_min = w_min.min(done.saturating_add(d_min));
            }
            FabricReq::MediaWriteback { dev, dpa } => {
                lane.device_mut(dev).media.access(t, dpa, line, true);
            }
            FabricReq::BiRsp { dev, pkt, dpa, dirty } => {
                // Uncredited BI channel: never probes the M2S credit
                // pool (a BIRsp blocking on credits its own sender
                // holds would deadlock the fabric) and delivers no
                // host event — the device absorbs the ack.
                let after_pkt = t + pkt_ticks;
                let at_dev = lane.send_birsp(after_pkt, &pkt, dev);
                let _ = lane.device_mut(dev).handle_bi_rsp(at_dev, dpa, dirty);
            }
        }
    }
    // Retries that escaped the wave go back to the global pending map.
    deferred.extend(std::mem::take(local));
    sl.handled = handled;
    sl.w_min = w_min;
}

/// Commit pending fabric requests against the shared fabric in global
/// `(tick, host, seq)` order — the single place fabric state mutates.
///
/// An entry at tick `t` commits while `t <= limit` (the section bound)
/// and `t < w`, where `w` starts at the barrier
/// `min over hosts (next local event tick + d_min)` — no un-drained
/// host event can emit a new fabric entry before `w` — and tightens to
/// `min(w, done + d_min)` on every response delivered at `done`: the
/// delivered fill may itself trigger emissions from `done + d_min` on,
/// which must order ahead of any later pending entry. Entries that
/// lose their credit race re-enter the map at the retry tick under the
/// same `(host, seq)`, exactly as the old inline path re-scheduled
/// them. Returns the number of entries handled (commits + retries —
/// the section loop's progress signal, identical at every thread
/// count).
#[allow(clippy::too_many_arguments)]
fn commit_pending(
    fabric: &mut Fabric,
    pending: &mut BTreeMap<(Tick, u8, u64), FabricReq>,
    inboxes: &mut [Vec<(Tick, Ev)>],
    limit: Tick,
    barrier: Tick,
    pkt_ticks: Tick,
    depkt_ticks: Tick,
    dev_fixed_ticks: &[Tick],
    d_min: Tick,
    line: u64,
    mut order: Option<&mut CommitOrderAudit>,
) -> u64 {
    let mut handled = 0u64;
    let mut w = barrier;
    loop {
        let Some((&(t, _, _), _)) = pending.first_key_value() else {
            break;
        };
        if t > limit || t >= w {
            break;
        }
        let ((t, h, seq), req) = pending.pop_first().unwrap();
        if let Some(o) = order.as_deref_mut() {
            o.note((t, h, seq));
        }
        handled += 1;
        match req {
            FabricReq::Fetch { dev, pkt, core, line_pa, issued_at } => {
                let after_pkt = t + pkt_ticks;
                let retry = {
                    let link = fabric.credit_link(dev);
                    match link.credit_available_at(after_pkt) {
                        CreditAvail::Now => None,
                        CreditAvail::RetiresAt(rt) => {
                            link.note_credit_stall(after_pkt, rt);
                            Some(rt)
                        }
                        CreditAvail::Unknown => {
                            let rt = link.reprobe_at(after_pkt);
                            link.note_credit_stall(after_pkt, rt);
                            Some(rt)
                        }
                    }
                };
                if let Some(rt) = retry {
                    pending.insert(
                        (rt.max(t + 1), h, seq),
                        FabricReq::Fetch {
                            dev,
                            pkt,
                            core,
                            line_pa,
                            issued_at,
                        },
                    );
                    continue;
                }
                let arrival = fabric.send_m2s(after_pkt, &pkt, dev);
                let (resp, ready) =
                    fabric.devices[dev].handle_m2s(arrival, &pkt, h);
                // Device-side coherence: deliver any queued
                // back-invalidations to the other sharer hosts before
                // the requester's fill (the sharded path reproduces
                // this order through its merge sub-index).
                for bi in fabric.devices[dev].take_pending_bi() {
                    let snp =
                        mem_proto::make_bi_snoop(bi.dpa, pkt.tag, pkt.req_id);
                    let at_host = fabric.send_s2m(arrival, &snp, dev);
                    let deliver = at_host + depkt_ticks;
                    inboxes[bi.host as usize]
                        .push((deliver, Ev::BiInv { dev, dpa: bi.dpa }));
                    w = w.min(deliver.saturating_add(d_min));
                }
                let rc_arrival = fabric.send_s2m(ready, &resp, dev);
                let done = rc_arrival + depkt_ticks;
                fabric.retire(dev, done);
                inboxes[h as usize]
                    .push((done, Ev::CxlFill { core, line_pa, issued_at }));
                w = w.min(done.saturating_add(d_min));
            }
            FabricReq::Writeback { dev, pkt } => {
                let after_pkt = t + pkt_ticks;
                let ok = {
                    let link = fabric.credit_link(dev);
                    match link.credit_available_at(after_pkt) {
                        CreditAvail::Now => true,
                        CreditAvail::RetiresAt(rt) => {
                            link.note_credit_stall(after_pkt, rt);
                            false
                        }
                        CreditAvail::Unknown => {
                            let rt = link.reprobe_at(after_pkt);
                            link.note_credit_stall(after_pkt, rt);
                            false
                        }
                    }
                };
                // Credit exhaustion drops the posted write from the
                // timing model (data is already functionally in
                // physmem) — the old inline path's semantics.
                if ok {
                    let arrival = fabric.send_m2s(after_pkt, &pkt, dev);
                    let (resp, ready) =
                        fabric.devices[dev].handle_m2s(arrival, &pkt, h);
                    let rc_arrival = fabric.send_s2m(ready, &resp, dev);
                    let done = rc_arrival + depkt_ticks;
                    fabric.retire(dev, done);
                }
            }
            FabricReq::MediaFetch { dev, dpa, core, line_pa } => {
                let done = fabric.devices[dev].media.access(
                    t + dev_fixed_ticks[dev],
                    dpa,
                    line,
                    false,
                );
                inboxes[h as usize]
                    .push((done, Ev::CxlFill { core, line_pa, issued_at: t }));
                w = w.min(done.saturating_add(d_min));
            }
            FabricReq::MediaWriteback { dev, dpa } => {
                fabric.devices[dev].media.access(t, dpa, line, true);
            }
            FabricReq::BiRsp { dev, pkt, dpa, dirty } => {
                // Uncredited BI channel: no credit probe (a BIRsp
                // blocking on credits its own sender holds would
                // deadlock), no host-side delivery — the device
                // absorbs the ack and unblocks nothing host-visible.
                let after_pkt = t + pkt_ticks;
                let at_dev = fabric.send_birsp(after_pkt, &pkt, dev);
                let _ =
                    fabric.devices[dev].handle_bi_rsp(at_dev, dpa, dirty);
            }
        }
    }
    handled
}

impl Machine {
    /// Build the hardware: the shared fabric with its FM LD bindings,
    /// then one host stack per `cfg.hosts` — each with BIOS tables in
    /// its own memory describing its windows (only the bound ones, or
    /// all of them when an `[fm] events` schedule enables hot-plug), at
    /// host physical bases disjoint from every other host's.
    pub fn new(cfg: SimConfig) -> Result<Self> {
        cfg.validate()?;
        let mut fabric = Fabric::new(&cfg.cxl);
        let window_sharers = cfg.window_sharers();
        fabric.bind_from_config(&cfg.cxl, &window_sharers)?;
        let win_defs = cfg.cxl.window_defs();
        let pkt_ticks = ns_to_ticks(cfg.cxl.pkt_lat_ns);
        let depkt_ticks = ns_to_ticks(cfg.cxl.depkt_lat_ns);
        let dev_fixed_ticks: Vec<Tick> = (0..cfg.cxl.devices)
            .map(|i| {
                ns_to_ticks(
                    2.0 * (cfg.cxl.pkt_lat_ns + cfg.cxl.depkt_lat_ns)
                        + 2.0 * cfg.cxl.path_lat_ns(i),
                )
            })
            .collect();
        let d_min = ns_to_ticks(cfg.membus_lat_ns) + 1;
        // Arm the snoop filter on every device exposing a shared LD,
        // sizing its decoder file for the per-sharer HDM commits the
        // guest drivers will make (one slot per window per sharer).
        // The BI round-trip floor mirrors the MemBus-baseline fixed
        // adder: the snoop must cross the same wire the data does.
        for d in 0..cfg.cxl.devices {
            let mut shared: Vec<u16> = Vec::new();
            let mut decoders = 0usize;
            for (def, sharers) in win_defs.iter().zip(&window_sharers) {
                for &t in &def.targets {
                    if t == d {
                        decoders += sharers.len().max(1);
                    }
                }
                if def.targets.len() == 1
                    && def.targets[0] == d
                    && sharers.len() > 1
                {
                    shared.push(def.ld);
                }
            }
            if !shared.is_empty() {
                let bi_rt = dev_fixed_ticks[d] + d_min;
                fabric.devices[d].configure_sharing(&shared, decoders, bi_rt);
            }
        }
        // Which hosts can back-invalidate which: co-sharers of any
        // BI-coherent window. The epoch schedulers use this to keep a
        // sharer's cap within `bi_horizon` of its peers' frontiers.
        let mut bi_peers: Vec<Vec<usize>> = vec![Vec::new(); cfg.hosts];
        for sharers in &window_sharers {
            if sharers.len() < 2 {
                continue;
            }
            for &a in sharers {
                for &b in sharers {
                    if a != b && !bi_peers[a].contains(&b) {
                        bi_peers[a].push(b);
                    }
                }
            }
        }
        for p in &mut bi_peers {
            p.sort_unstable();
        }
        let has_bi = bi_peers.iter().any(|p| !p.is_empty());
        // `max(1)`: a zero horizon (degenerate zero-latency protocol
        // config) would let mutual caps livelock at `floor - 1`.
        let bi_horizon = (pkt_ticks + depkt_ticks).max(1);
        let mut hosts = Vec::with_capacity(cfg.hosts);
        let mut next_base = bios::cxl_window_base(cfg.sys_mem_size);
        for h in 0..cfg.hosts {
            let host = Host::new(&cfg, h as u8, next_base, &window_sharers)?;
            next_base = host.bios.next_free_base;
            hosts.push(host);
        }
        let fm_policy = cfg
            .fm_policy
            .as_ref()
            .map(|p| FmPolicyEngine::new(p, cfg.hosts));
        let window_keys = cfg.window_keys();
        let win_targets: Vec<Arc<[usize]>> =
            win_defs.iter().map(|d| d.targets.clone().into()).collect();
        let lane_ranges = fabric.lane_ranges();
        let lane_of_dev = fabric.lane_of_dev(&lane_ranges);
        let nh = hosts.len();
        let checker = if cfg.check {
            Some(InvariantChecker::new(nh))
        } else {
            None
        };
        Ok(Machine {
            cfg,
            hosts,
            fabric,
            mq: EventQueue::new(),
            pending: BTreeMap::new(),
            pkt_ticks,
            depkt_ticks,
            dev_fixed_ticks,
            d_min,
            par_epochs: 0,
            par_barrier_waits: 0,
            par_horizon_min: Tick::MAX,
            fm_scheduled: false,
            fm_refused: Default::default(),
            fm_policy,
            fm_moves_parked: Default::default(),
            window_keys,
            win_defs,
            win_targets,
            lane_ranges,
            lane_of_dev,
            inboxes: (0..nh).map(|_| Vec::new()).collect(),
            scratch_oldest: Vec::new(),
            scratch_caps: Vec::new(),
            merge_buf: Vec::new(),
            bi_peers,
            has_bi,
            bi_horizon,
            wall_drain_ns: 0,
            wall_commit_ns: 0,
            wall_merge_ns: 0,
            checker,
        })
    }

    /// The MMIO surface host `h`'s guest drives: its own ECAM and
    /// host-bridge blocks, the shared endpoint register blocks.
    pub fn mmio_world(&mut self, h: usize) -> MmioWorld<'_> {
        let host = &mut self.hosts[h];
        MmioWorld {
            ecam: &mut host.ecam,
            cxl_devs: &mut self.fabric.devices,
            hb_components: &mut host.hb_components,
            chbs_base: bios::layout::CHBS_BASE,
            chbs_stride: bios::layout::CHBS_SIZE,
            ep_bdfs: &host.ep_bdfs,
        }
    }

    /// Boot every host's guest: ACPI parse, enumeration, CXL bind (only
    /// the LDs the FM assigned to each host), onlining.
    pub fn boot(&mut self, model: ProgModel) -> Result<()> {
        for h in 0..self.hosts.len() {
            self.boot_host(h, model)
                .with_context(|| format!("host {h} boot failed"))?;
        }
        Ok(())
    }

    fn boot_host(&mut self, h: usize, model: ProgModel) -> Result<()> {
        let page_size = self.cfg.page_size;
        let host = &mut self.hosts[h];
        let mut world = MmioWorld {
            ecam: &mut host.ecam,
            cxl_devs: &mut self.fabric.devices,
            hb_components: &mut host.hb_components,
            chbs_base: bios::layout::CHBS_BASE,
            chbs_stride: bios::layout::CHBS_SIZE,
            ep_bdfs: &host.ep_bdfs,
        };
        let guest =
            GuestOs::boot(&mut world, &host.mem, page_size, model, h as u16)
                .context("guest boot failed")?;
        // Mirror the committed host-bridge decoders into this host's
        // RC interleave decoder: one window per published definition
        // (interleave set or MLD slice), carrying the member devices in
        // CFMWS slot order, provided every member's *bridge* actually
        // committed the range (routing is by hierarchy: device ->
        // bridge).
        let xor = self.cfg.cxl.interleave_arith == InterleaveArith::Xor;
        let published: Vec<(usize, (u64, u64))> = host
            .bios
            .cxl_window_defs
            .iter()
            .copied()
            .zip(host.bios.cxl_windows.iter().copied())
            .collect();
        for (def_idx, (base, size)) in published {
            let def = &self.win_defs[def_idx];
            let all_committed = def.targets.iter().all(|&i| {
                host.hb_components[self.cfg.cxl.bridge_of(i)]
                    .committed_ranges()
                    .iter()
                    .any(|&(b, s)| b == base && s == size)
            });
            if all_committed {
                host.rc.add_window(HdmWindow {
                    base,
                    size,
                    granularity: self.cfg.cxl.interleave_granularity,
                    targets: self.win_targets[def_idx].clone(),
                    xor,
                    // 1-way LD slices relocate densely by slice size.
                    dpa_base: def.ld as u64 * size,
                });
            }
        }
        host.guest = Some(guest);
        Ok(())
    }

    /// Attach workloads to host 0 (the single-host entry point).
    pub fn attach_workloads(
        &mut self,
        wls: Vec<Box<dyn Workload>>,
        policy: &MemPolicy,
    ) -> Result<()> {
        self.attach_workloads_to(0, wls, policy)
    }

    /// Attach one workload per core on host `h` and run the functional
    /// init phase (untimed).
    pub fn attach_workloads_to(
        &mut self,
        h: usize,
        wls: Vec<Box<dyn Workload>>,
        policy: &MemPolicy,
    ) -> Result<()> {
        let host = self.hosts.get_mut(h).context("no such host")?;
        host.attach_workloads(wls, policy)
    }

    // ---- the event loop ---------------------------------------------------

    /// Run until all attached workloads (on every host) finish, or
    /// `max_ticks`. FM events from the `[fm] events` schedule fire at
    /// their simulated timestamps, between fully-settled host sections.
    pub fn run(&mut self, max_ticks: Option<Tick>) -> RunSummary {
        if !self.fm_scheduled {
            self.fm_scheduled = true;
            for i in self.cfg.fm_events_in_time_order() {
                let at = ns_to_ticks(self.cfg.fm_events[i].at_ns)
                    .max(self.mq.now());
                self.mq.schedule_at(at, MEv::Fm(i as u32));
            }
            // A policy samples on its own epoch cadence; arm the first
            // tick only if some workload is actually going to run
            // (epochs re-arm themselves until every host drains).
            if let Some(eng) = &self.fm_policy {
                if self.hosts.iter().any(|h| !h.all_done()) {
                    let at = self.mq.now() + eng.epoch_ticks();
                    self.mq.schedule_at(at, MEv::FmEpoch);
                }
            }
        }
        loop {
            // Hosts run strictly up to the next machine event's tick
            // (machine events at `T` precede host events at `T`).
            let host_limit = match self.mq.next_tick() {
                Some(0) => None, // machine event before any host work
                Some(mt) => Some(mt - 1),
                None => Some(Tick::MAX),
            };
            let host_limit = host_limit.map(|l| match max_ticks {
                Some(m) => l.min(m),
                None => l,
            });
            if let Some(l) = host_limit {
                self.run_section(l);
            }
            match self.mq.next_tick() {
                Some(t) if max_ticks.map_or(true, |m| t <= m) => {
                    let (t, mev) = self.mq.pop().unwrap();
                    crate::util::logger::set_tick(t);
                    match mev {
                        MEv::Fm(idx) => self.handle_fm_event(idx as usize, t),
                        MEv::FmEpoch => self.handle_policy_epoch(t),
                        MEv::FmMove { dev, ld, from, to } => {
                            let Some(mut eng) = self.fm_policy.take() else {
                                continue;
                            };
                            self.execute_policy_move(
                                &mut eng,
                                LdRef { dev: dev as usize, ld: ld as u16 },
                                from as usize,
                                to as usize,
                                t,
                            );
                            self.fm_policy = Some(eng);
                        }
                    }
                    // FM actions are the only thing that rewires HDM
                    // windows mid-run; re-check disjointness after each
                    // (WIN-1).
                    if let Some(ck) = self.checker.as_mut() {
                        ck.audit_windows(t, &self.hosts, &self.fabric);
                    }
                }
                // No machine event within bounds: the section above
                // already settled every host up to the limit.
                _ => break,
            }
        }
        if self.checker.is_some() {
            self.audit_final();
            let ck = self.checker.as_ref().unwrap();
            if ck.total_violations() > 0 && !ck.tolerant() {
                panic!("{}", ck.report());
            }
        }
        self.summary()
    }

    /// End-of-run audit pass: one last epoch audit (drains any EQ-2
    /// findings the order audit still holds), the window check, and the
    /// quiesce-only rules (CR-2 / SF-1 / SF-2 / RT-1).
    fn audit_final(&mut self) {
        let now = self
            .hosts
            .iter()
            .map(|h| h.queue_now())
            .max()
            .unwrap_or(0);
        if let Some(ck) = self.checker.as_mut() {
            ck.audit_epoch(now, &self.hosts, &self.fabric);
            ck.audit_windows(now, &self.hosts, &self.fabric);
            ck.audit_quiesce(
                now,
                &self.hosts,
                &self.fabric,
                self.pending.len(),
            );
        }
    }

    /// Run every host to a settled fixpoint at `limit` — no local event
    /// at or before `limit` left, no committable fabric entry left.
    /// Serial and parallel paths run the *identical* epoch algorithm;
    /// the thread count only changes who executes each host's drain.
    fn run_section(&mut self, limit: Tick) {
        // FM re-binds between sections change window routing; horizons
        // are a function of the bound topology, so re-derive them.
        for h in &mut self.hosts {
            h.recompute_lookahead();
        }
        if let Some(min_la) = self
            .hosts
            .iter()
            .map(|h| h.lookahead())
            .filter(|&l| l != Tick::MAX)
            .min()
        {
            self.par_horizon_min = self.par_horizon_min.min(min_la);
        }
        let nthreads = self.cfg.threads.min(self.hosts.len()).max(1);
        let lane_workers = self.commit_lane_workers();
        if lane_workers > 1 {
            self.run_section_sharded(limit, nthreads, lane_workers);
        } else if nthreads > 1 {
            self.run_section_parallel(limit, nthreads);
        } else {
            self.run_section_serial(limit);
        }
    }

    /// Resolved commit-lane worker count: `[sim] commit_lanes`
    /// (`0 = auto` follows `[sim] threads`), clamped to the number of
    /// switch-credit-disjoint lane groups the topology actually has.
    /// 1 means the commit phase stays on the main thread.
    fn commit_lane_workers(&self) -> usize {
        let req = if self.cfg.commit_lanes == 0 {
            self.cfg.threads
        } else {
            self.cfg.commit_lanes
        };
        req.min(self.lane_ranges.len()).max(1)
    }

    /// Per-host epoch caps into the reused scratch arrays: a host may
    /// drain up to `limit`, but not past
    /// `oldest pending entry + its lookahead - 1` — its oldest
    /// uncommitted fabric request could produce a response as early as
    /// `entry + lookahead`.
    fn epoch_caps_into(&mut self, limit: Tick) {
        let nh = self.hosts.len();
        self.scratch_oldest.clear();
        self.scratch_oldest.resize(nh, Tick::MAX);
        for &(t, h, _) in self.pending.keys() {
            let h = h as usize;
            if t < self.scratch_oldest[h] {
                self.scratch_oldest[h] = t;
            }
        }
        self.scratch_caps.clear();
        for (h, host) in self.hosts.iter().enumerate() {
            self.scratch_caps.push(
                limit.min(
                    self.scratch_oldest[h]
                        .saturating_add(host.lookahead())
                        .saturating_sub(1),
                ),
            );
        }
        // Back-invalidate horizon: a sharer host must not drain past
        // `peer frontier + bi_horizon - 1` — a peer's undrained work can
        // commit an RFO whose BISnp lands at this host as early as
        // `frontier + bi_horizon`. The frontier counts the peer's
        // uncommitted fabric entries, its next queued event AND its
        // undelivered inbox (a fill still in the inbox can trigger the
        // emission that snoops us).
        if self.has_bi {
            for h in 0..self.hosts.len() {
                let mut floor = Tick::MAX;
                for &p in &self.bi_peers[h] {
                    let inbox_min = self.inboxes[p]
                        .iter()
                        .map(|e| e.0)
                        .min()
                        .unwrap_or(Tick::MAX);
                    let f = self.scratch_oldest[p]
                        .min(
                            self.hosts[p]
                                .next_event_tick()
                                .unwrap_or(Tick::MAX),
                        )
                        .min(inbox_min);
                    floor = floor.min(f);
                }
                if floor != Tick::MAX {
                    let bi_cap = floor
                        .saturating_add(self.bi_horizon)
                        .saturating_sub(1);
                    if bi_cap < self.scratch_caps[h] {
                        self.scratch_caps[h] = bi_cap;
                    }
                }
            }
        }
    }

    /// The commit barrier for this epoch: no host can emit a new fabric
    /// entry before its next local event plus the minimum host-side
    /// path (`d_min`), so everything in the pending map earlier than
    /// this is globally final.
    fn commit_barrier(&self) -> Tick {
        self.hosts
            .iter()
            .filter_map(|h| h.next_event_tick())
            .map(|t| t.saturating_add(self.d_min))
            .min()
            .unwrap_or(Tick::MAX)
    }

    fn run_section_serial(&mut self, limit: Tick) {
        let nh = self.hosts.len();
        loop {
            let t0 = Instant::now();
            self.epoch_caps_into(limit);
            let mut processed = 0u64;
            let mut active = 0u32;
            for h in 0..nh {
                let cap = self.scratch_caps[h];
                let n =
                    self.hosts[h].epoch_step(cap, &mut self.inboxes[h]);
                processed += n;
                if n > 0 {
                    active += 1;
                }
            }
            let t1 = Instant::now();
            for h in 0..nh {
                let (host, pending) =
                    (&mut self.hosts[h], &mut self.pending);
                for (at, seq, req) in host.outbox_mut().drain(..) {
                    pending.insert((at, h as u8, seq), req);
                }
            }
            let barrier = self.commit_barrier();
            let t2 = Instant::now();
            if let Some(ck) = self.checker.as_mut() {
                ck.order.begin_wave();
            }
            let committed = commit_pending(
                &mut self.fabric,
                &mut self.pending,
                &mut self.inboxes,
                limit,
                barrier,
                self.pkt_ticks,
                self.depkt_ticks,
                &self.dev_fixed_ticks,
                self.d_min,
                self.cfg.l1.line,
                self.checker.as_mut().map(|c| &mut c.order),
            );
            let t3 = Instant::now();
            self.wall_drain_ns += (t1 - t0).as_nanos() as u64;
            self.wall_merge_ns += (t2 - t1).as_nanos() as u64;
            self.wall_commit_ns += (t3 - t2).as_nanos() as u64;
            self.par_epochs += 1;
            if active >= 2 {
                self.par_barrier_waits += active as u64;
            }
            if let Some(ck) = self.checker.as_mut() {
                ck.audit_epoch(limit, &self.hosts, &self.fabric);
            }
            if processed == 0 && committed == 0 {
                break;
            }
        }
    }

    fn run_section_parallel(&mut self, limit: Tick, nthreads: usize) {
        let nh = self.hosts.len();
        let chunk = nh.div_ceil(nthreads);
        let nworkers = nh.div_ceil(chunk);

        // `next_tick` starts live (not `None`): the first epoch's BI
        // floor must see each host's real frontier, exactly as the
        // serial path's live `next_event_tick()` call does.
        let slots: Vec<Mutex<EpochSlot>> = (0..nh)
            .map(|h| {
                let mut sl = EpochSlot::default();
                sl.next_tick = self.hosts[h].next_event_tick();
                Mutex::new(sl)
            })
            .collect();
        let start = Barrier::new(nworkers + 1);
        let end = Barrier::new(nworkers + 1);
        let stop = AtomicBool::new(false);
        let panicked: Mutex<Option<Box<dyn std::any::Any + Send>>> =
            Mutex::new(None);

        // Split-borrow self: workers own disjoint host chunks, the main
        // thread keeps the fabric, the pending map and the commit-order
        // audit (EQ-2 keys are only ever noted from the main thread).
        let hosts = &mut self.hosts;
        let fabric = &mut self.fabric;
        let pending = &mut self.pending;
        let inboxes = &mut self.inboxes;
        let scratch_oldest = &mut self.scratch_oldest;
        let mut order = self.checker.as_mut().map(|c| &mut c.order);
        let lookaheads: Vec<Tick> =
            hosts.iter().map(|h| h.lookahead()).collect();
        let pkt_ticks = self.pkt_ticks;
        let depkt_ticks = self.depkt_ticks;
        let dev_fixed = &self.dev_fixed_ticks;
        let d_min = self.d_min;
        let line = self.cfg.l1.line;
        let bi_peers = &self.bi_peers;
        let has_bi = self.has_bi;
        let bi_horizon = self.bi_horizon;
        let mut bi_floors = vec![Tick::MAX; nh];

        let mut epochs = 0u64;
        let mut barrier_waits = 0u64;
        let mut drain_ns = 0u64;
        let mut commit_ns = 0u64;
        let mut merge_ns = 0u64;

        std::thread::scope(|s| {
            for (wi, hchunk) in hosts.chunks_mut(chunk).enumerate() {
                let base = wi * chunk;
                let slots = &slots;
                let start = &start;
                let end = &end;
                let stop = &stop;
                let panicked = &panicked;
                s.spawn(move || loop {
                    start.wait();
                    if stop.load(Ordering::Acquire) {
                        break;
                    }
                    let res = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| {
                            for (i, host) in hchunk.iter_mut().enumerate() {
                                let mut sl =
                                    slots[base + i].lock().unwrap();
                                let cap = sl.cap;
                                let n = host.epoch_step(cap, &mut sl.inbox);
                                sl.processed = n;
                                // Trade buffers: the slot's outbox was
                                // drained by the main thread, so the
                                // host re-fills a recycled allocation.
                                std::mem::swap(
                                    &mut sl.outbox,
                                    host.outbox_mut(),
                                );
                                sl.next_tick = host.next_event_tick();
                            }
                        }),
                    );
                    if let Err(p) = res {
                        *panicked.lock().unwrap() = Some(p);
                    }
                    end.wait();
                });
            }

            let mut tp = Instant::now();
            loop {
                // Caps from the pending map — identical computation to
                // the serial path's `epoch_caps_into`.
                scratch_oldest.clear();
                scratch_oldest.resize(nh, Tick::MAX);
                for &(t, h, _) in pending.keys() {
                    let h = h as usize;
                    if t < scratch_oldest[h] {
                        scratch_oldest[h] = t;
                    }
                }
                // Per-host frontiers for the BI horizon clamp — the
                // slot's `next_tick` equals what a live
                // `next_event_tick()` would return here (host queues
                // only move during drains), so this matches the serial
                // computation bit for bit.
                if has_bi {
                    for h in 0..nh {
                        let nt = slots[h]
                            .lock()
                            .unwrap()
                            .next_tick
                            .unwrap_or(Tick::MAX);
                        let inbox_min = inboxes[h]
                            .iter()
                            .map(|e| e.0)
                            .min()
                            .unwrap_or(Tick::MAX);
                        bi_floors[h] =
                            scratch_oldest[h].min(nt).min(inbox_min);
                    }
                }
                for h in 0..nh {
                    let mut sl = slots[h].lock().unwrap();
                    let mut cap = limit.min(
                        scratch_oldest[h]
                            .saturating_add(lookaheads[h])
                            .saturating_sub(1),
                    );
                    if has_bi {
                        let mut floor = Tick::MAX;
                        for &p in &bi_peers[h] {
                            floor = floor.min(bi_floors[p]);
                        }
                        if floor != Tick::MAX {
                            cap = cap.min(
                                floor
                                    .saturating_add(bi_horizon)
                                    .saturating_sub(1),
                            );
                        }
                    }
                    sl.cap = cap;
                    // Filled inbox in, drained (recycled) buffer back.
                    std::mem::swap(&mut sl.inbox, &mut inboxes[h]);
                }
                start.wait();
                end.wait();
                if panicked.lock().unwrap().is_some() {
                    let p = panicked.lock().unwrap().take().unwrap();
                    stop.store(true, Ordering::Release);
                    start.wait();
                    std::panic::resume_unwind(p);
                }
                let now = Instant::now();
                drain_ns += (now - tp).as_nanos() as u64;
                tp = now;
                let mut processed = 0u64;
                let mut active = 0u32;
                let mut barrier = Tick::MAX;
                for h in 0..nh {
                    let mut sl = slots[h].lock().unwrap();
                    processed += sl.processed;
                    if sl.processed > 0 {
                        active += 1;
                    }
                    for (at, seq, req) in sl.outbox.drain(..) {
                        pending.insert((at, h as u8, seq), req);
                    }
                    if let Some(t) = sl.next_tick {
                        barrier = barrier.min(t.saturating_add(d_min));
                    }
                }
                let now = Instant::now();
                merge_ns += (now - tp).as_nanos() as u64;
                tp = now;
                if let Some(o) = order.as_deref_mut() {
                    o.begin_wave();
                }
                let committed = commit_pending(
                    fabric,
                    pending,
                    inboxes,
                    limit,
                    barrier,
                    pkt_ticks,
                    depkt_ticks,
                    dev_fixed,
                    d_min,
                    line,
                    order.as_deref_mut(),
                );
                let now = Instant::now();
                commit_ns += (now - tp).as_nanos() as u64;
                tp = now;
                epochs += 1;
                if active >= 2 {
                    barrier_waits += active as u64;
                }
                if processed == 0 && committed == 0 {
                    stop.store(true, Ordering::Release);
                    start.wait();
                    break;
                }
            }
        });

        self.par_epochs += epochs;
        self.par_barrier_waits += barrier_waits;
        self.wall_drain_ns += drain_ns;
        self.wall_commit_ns += commit_ns;
        self.wall_merge_ns += merge_ns;
        // Audit once per settled section (not per epoch — the workers
        // hold the host borrows between barriers). The checked laws are
        // invariants of the queue state, so a coarser cadence changes
        // `check.epochs`, never whether a violation is caught by the
        // end of the run.
        if let Some(ck) = self.checker.as_mut() {
            ck.audit_epoch(limit, &self.hosts, &self.fabric);
        }
    }

    /// The sharded section loop: host drains on the worker pool (as in
    /// [`Machine::run_section_parallel`]) AND the commit phase sharded
    /// across the same pool as per-device commit lanes. Each epoch's
    /// commit runs as a sequence of *waves*: the main thread pops every
    /// pending entry below `min(window, limit + 1, t0 + d_min)` and
    /// deals it to its device's lane, the pool commits all lanes
    /// concurrently against `&mut`-disjoint fabric views, and the lane
    /// outputs merge back in global `(tick, host, seq)` order — see the
    /// module-level lane-partitioning rules. Bit-identical to the
    /// serial commit loop for every `(threads, commit_lanes)` pair.
    fn run_section_sharded(
        &mut self,
        limit: Tick,
        nthreads: usize,
        lane_workers: usize,
    ) {
        let nh = self.hosts.len();
        let chunk = nh.div_ceil(nthreads);
        let nworkers = nh.div_ceil(chunk).max(lane_workers);

        // `next_tick` starts live for the first epoch's BI floor, as in
        // the unsharded parallel path.
        let slots: Vec<Mutex<EpochSlot>> = (0..nh)
            .map(|h| {
                let mut sl = EpochSlot::default();
                sl.next_tick = self.hosts[h].next_event_tick();
                Mutex::new(sl)
            })
            .collect();
        let start = Barrier::new(nworkers + 1);
        let end = Barrier::new(nworkers + 1);
        let phase = AtomicU8::new(PHASE_DRAIN);
        let panicked: Mutex<Option<Box<dyn std::any::Any + Send>>> =
            Mutex::new(None);

        let hosts = &mut self.hosts;
        let fabric = &mut self.fabric;
        let pending = &mut self.pending;
        let inboxes = &mut self.inboxes;
        let merge_buf = &mut self.merge_buf;
        let scratch_oldest = &mut self.scratch_oldest;
        let lane_of_dev = &self.lane_of_dev;
        // EQ-2 keys are noted at the wave distributor (main thread) —
        // the one place global commit order exists in this path.
        let mut order = self.checker.as_mut().map(|c| &mut c.order);
        let lookaheads: Vec<Tick> =
            hosts.iter().map(|h| h.lookahead()).collect();
        let pkt_ticks = self.pkt_ticks;
        let depkt_ticks = self.depkt_ticks;
        let dev_fixed = &self.dev_fixed_ticks;
        let d_min = self.d_min;
        let line = self.cfg.l1.line;
        let bi_peers = &self.bi_peers;
        let has_bi = self.has_bi;
        let bi_horizon = self.bi_horizon;
        let mut bi_floors = vec![Tick::MAX; nh];

        // One lane slot per switch-credit-disjoint device group; the
        // views hold `&mut` borrows of the fabric interior for the
        // whole section, so the main thread routes entries via the
        // `lane_of_dev` snapshot only.
        let lane_slots: Vec<Mutex<LaneSlot<'_>>> = fabric
            .lane_views(&self.lane_ranges)
            .into_iter()
            .map(|lane| {
                Mutex::new(LaneSlot {
                    lane,
                    input: Vec::new(),
                    local: BTreeMap::new(),
                    out: Vec::new(),
                    deferred: Vec::new(),
                    wave_hi: 0,
                    handled: 0,
                    w_min: Tick::MAX,
                })
            })
            .collect();

        let mut epochs = 0u64;
        let mut barrier_waits = 0u64;
        let mut drain_ns = 0u64;
        let mut commit_ns = 0u64;
        let mut merge_ns = 0u64;

        std::thread::scope(|s| {
            // Every worker gets a (possibly empty) host chunk for the
            // drain phases plus a strided set of lane groups for the
            // commit waves.
            let mut chunks: Vec<&mut [Host]> =
                hosts.chunks_mut(chunk).collect();
            chunks.resize_with(nworkers, Default::default);
            for (wi, hchunk) in chunks.into_iter().enumerate() {
                let base = wi * chunk;
                let slots = &slots;
                let lane_slots = &lane_slots;
                let start = &start;
                let end = &end;
                let phase = &phase;
                let panicked = &panicked;
                s.spawn(move || loop {
                    start.wait();
                    let ph = phase.load(Ordering::Acquire);
                    if ph == PHASE_STOP {
                        break;
                    }
                    let res = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| match ph {
                            PHASE_DRAIN => {
                                for (i, host) in
                                    hchunk.iter_mut().enumerate()
                                {
                                    let mut sl =
                                        slots[base + i].lock().unwrap();
                                    let cap = sl.cap;
                                    let n =
                                        host.epoch_step(cap, &mut sl.inbox);
                                    sl.processed = n;
                                    std::mem::swap(
                                        &mut sl.outbox,
                                        host.outbox_mut(),
                                    );
                                    sl.next_tick = host.next_event_tick();
                                }
                            }
                            _ => {
                                // PHASE_COMMIT: commit this worker's
                                // lanes (static stride assignment; the
                                // canonical merge makes the mapping
                                // result-irrelevant).
                                if wi < lane_workers {
                                    let mut g = wi;
                                    while g < lane_slots.len() {
                                        let mut sl =
                                            lane_slots[g].lock().unwrap();
                                        commit_lane_wave(
                                            &mut sl,
                                            pkt_ticks,
                                            depkt_ticks,
                                            dev_fixed,
                                            d_min,
                                            line,
                                        );
                                        g += lane_workers;
                                    }
                                }
                            }
                        }),
                    );
                    if let Err(p) = res {
                        *panicked.lock().unwrap() = Some(p);
                    }
                    end.wait();
                });
            }

            // Phase barrier + panic relay, shared by both phases.
            let run_phase = |ph: u8| {
                phase.store(ph, Ordering::Release);
                start.wait();
                end.wait();
                if panicked.lock().unwrap().is_some() {
                    let p = panicked.lock().unwrap().take().unwrap();
                    phase.store(PHASE_STOP, Ordering::Release);
                    start.wait();
                    std::panic::resume_unwind(p);
                }
            };

            let mut tp = Instant::now();
            loop {
                // ---- drain phase (same structure as the unsharded
                // parallel path) ----
                scratch_oldest.clear();
                scratch_oldest.resize(nh, Tick::MAX);
                for &(t, h, _) in pending.keys() {
                    let h = h as usize;
                    if t < scratch_oldest[h] {
                        scratch_oldest[h] = t;
                    }
                }
                // BI horizon clamp — same computation as the serial
                // `epoch_caps_into`, frontiers read from the slots.
                if has_bi {
                    for h in 0..nh {
                        let nt = slots[h]
                            .lock()
                            .unwrap()
                            .next_tick
                            .unwrap_or(Tick::MAX);
                        let inbox_min = inboxes[h]
                            .iter()
                            .map(|e| e.0)
                            .min()
                            .unwrap_or(Tick::MAX);
                        bi_floors[h] =
                            scratch_oldest[h].min(nt).min(inbox_min);
                    }
                }
                for h in 0..nh {
                    let mut sl = slots[h].lock().unwrap();
                    let mut cap = limit.min(
                        scratch_oldest[h]
                            .saturating_add(lookaheads[h])
                            .saturating_sub(1),
                    );
                    if has_bi {
                        let mut floor = Tick::MAX;
                        for &p in &bi_peers[h] {
                            floor = floor.min(bi_floors[p]);
                        }
                        if floor != Tick::MAX {
                            cap = cap.min(
                                floor
                                    .saturating_add(bi_horizon)
                                    .saturating_sub(1),
                            );
                        }
                    }
                    sl.cap = cap;
                    std::mem::swap(&mut sl.inbox, &mut inboxes[h]);
                }
                run_phase(PHASE_DRAIN);
                let now = Instant::now();
                drain_ns += (now - tp).as_nanos() as u64;
                tp = now;
                let mut processed = 0u64;
                let mut active = 0u32;
                let mut barrier = Tick::MAX;
                for h in 0..nh {
                    let mut sl = slots[h].lock().unwrap();
                    processed += sl.processed;
                    if sl.processed > 0 {
                        active += 1;
                    }
                    for (at, seq, req) in sl.outbox.drain(..) {
                        pending.insert((at, h as u8, seq), req);
                    }
                    if let Some(t) = sl.next_tick {
                        barrier = barrier.min(t.saturating_add(d_min));
                    }
                }
                let now = Instant::now();
                merge_ns += (now - tp).as_nanos() as u64;
                tp = now;

                // ---- commit phase: waves over the lane pool ----
                let mut committed = 0u64;
                let mut w = barrier;
                loop {
                    let Some((&(t0, _, _), _)) = pending.first_key_value()
                    else {
                        break;
                    };
                    if t0 > limit || t0 >= w {
                        break;
                    }
                    // Entries in [t0, wave_hi) are final: no same-wave
                    // delivery can tighten the window below wave_hi
                    // (done > t0 implies done + d_min >= wave_hi).
                    let wave_hi = w
                        .min(limit.saturating_add(1))
                        .min(t0.saturating_add(d_min));
                    // Lane-deferred retries always re-enter the map at
                    // or past `wave_hi`, while every key dealt below is
                    // under it — so the audit's cross-wave tick floor
                    // holds even when a retry escapes its wave.
                    if let Some(o) = order.as_deref_mut() {
                        o.begin_wave();
                    }
                    while let Some((&(t, _, _), _)) =
                        pending.first_key_value()
                    {
                        if t >= wave_hi {
                            break;
                        }
                        let (k, req) = pending.pop_first().unwrap();
                        if let Some(o) = order.as_deref_mut() {
                            o.note(k);
                        }
                        let mut sl =
                            lane_slots[lane_of_dev[req.dev()]]
                                .lock()
                                .unwrap();
                        sl.wave_hi = wave_hi;
                        sl.input.push((k, req));
                    }
                    run_phase(PHASE_COMMIT);
                    let now = Instant::now();
                    commit_ns += (now - tp).as_nanos() as u64;
                    tp = now;
                    // Canonical merge: lane outputs sorted by global
                    // key reproduce the serial delivery order.
                    merge_buf.clear();
                    for slm in &lane_slots {
                        let mut sl = slm.lock().unwrap();
                        committed += sl.handled;
                        w = w.min(sl.w_min);
                        merge_buf.append(&mut sl.out);
                        for (k, req) in sl.deferred.drain(..) {
                            pending.insert(k, req);
                        }
                    }
                    merge_buf.sort_unstable_by_key(|&(k, _, _, _)| k);
                    for (_, target, done, ev) in merge_buf.drain(..) {
                        inboxes[target as usize].push((done, ev));
                    }
                    let now = Instant::now();
                    merge_ns += (now - tp).as_nanos() as u64;
                    tp = now;
                }

                epochs += 1;
                if active >= 2 {
                    barrier_waits += active as u64;
                }
                if processed == 0 && committed == 0 {
                    phase.store(PHASE_STOP, Ordering::Release);
                    start.wait();
                    break;
                }
            }
        });

        self.par_epochs += epochs;
        self.par_barrier_waits += barrier_waits;
        self.wall_drain_ns += drain_ns;
        self.wall_commit_ns += commit_ns;
        self.wall_merge_ns += merge_ns;
        // Per-section audit cadence, as in the unsharded parallel path.
        // The lane views hold `&mut` borrows of the fabric interior;
        // end them before the audit reborrows the fabric shared.
        drop(lane_slots);
        if let Some(ck) = self.checker.as_mut() {
            ck.audit_epoch(limit, &self.hosts, &self.fabric);
        }
    }

    /// Events dispatched machine-wide: every host's local queue plus
    /// the machine queue.
    fn events_total(&self) -> u64 {
        self.hosts.iter().map(|h| h.events_processed()).sum::<u64>()
            + self.mq.processed()
    }

    // ---- runtime fabric-manager actions -----------------------------------

    /// The window-definition index of logical device `r`, and the
    /// host-physical window host `h`'s firmware published for it
    /// (present for every def in the hot-plug layout).
    fn def_window(&self, h: usize, r: LdRef) -> Option<(usize, u64, u64)> {
        let def_idx = self.window_keys.iter().position(|k| *k == r)?;
        let bios = &self.hosts[h].bios;
        let pos =
            bios.cxl_window_defs.iter().position(|&d| d == def_idx)?;
        let (base, size) = bios.cxl_windows[pos];
        Some((def_idx, base, size))
    }

    /// Execute scheduled FM action `idx` at tick `t`: the full
    /// cross-layer hot add / remove flow. Unbind sequencing is
    /// quiesce -> Event-Log doorbell -> guest offline -> FM UNBIND_LD
    /// -> host routing teardown; bind is FM BIND_LD -> Event-Log
    /// doorbell -> guest hot-add -> host routing mirror. All through
    /// the same mailbox/decoder surfaces the boot path uses.
    fn handle_fm_event(&mut self, idx: usize, t: Tick) {
        let op = self.cfg.fm_events[idx].op;
        match op {
            FmOp::Unbind { ld } => {
                let owner = self.fabric.ld_owner(ld.dev, ld.ld);
                if owner == UNBOUND {
                    log::warn!("fm: unbind of unbound {ld} — skipped");
                    return;
                }
                let h = owner as usize;
                let Some((_, base, size)) = self.def_window(h, ld) else {
                    log::warn!(
                        "fm: host{h} has no window for {ld} — skipped"
                    );
                    return;
                };
                // Quiesce: let packets to the departing window complete
                // before the surprise-remove doorbell rings; re-probe on
                // a fixed deterministic cadence.
                if self.hosts[h].has_inflight_in(base, size) {
                    self.hosts[h].stats.fm_quiesce_retries.inc();
                    let at = t + ns_to_ticks(FM_QUIESCE_RETRY_NS);
                    self.mq.schedule_at(at, MEv::Fm(idx as u32));
                    return;
                }
                self.fabric.post_fm_event(
                    ld.dev,
                    EventRecord {
                        host: owner,
                        ld: ld.ld,
                        action: event::UNBIND_REQUEST,
                    },
                );
                if self.unbind_flow(ld, h, base) {
                    self.fm_refused.remove(&(ld.dev, ld.ld));
                    log::info!("fm: {ld} unbound from host{h}");
                } else {
                    // The guest refused (pages in use): ownership is
                    // unchanged and the LD stays online — exactly what
                    // a failed `daxctl offline-memory` leaves behind.
                    self.fm_refused.insert((ld.dev, ld.ld));
                    log::warn!("fm: host{h} refused to release {ld}");
                }
            }
            FmOp::Bind { ld, host } => {
                let code = self.fabric.fm_bind(ld.dev, ld.ld, host as u16);
                if code == retcode::BUSY
                    && !self.fm_refused.contains(&(ld.dev, ld.ld))
                {
                    // Still owned, but only because the scheduled
                    // unbind ahead of us is itself parked in quiesce
                    // retries — follow it on the same cadence rather
                    // than dropping a validated bind on the floor.
                    let at = t + ns_to_ticks(FM_QUIESCE_RETRY_NS);
                    self.mq.schedule_at(at, MEv::Fm(idx as u32));
                    return;
                }
                if code != retcode::SUCCESS {
                    // Terminal: the unbind this bind depends on was
                    // refused (pages in use), so the LD keeps its
                    // owner for the rest of the run.
                    log::warn!(
                        "fm: BIND_LD {ld} -> host{host} failed \
                         ({code:#x}) — skipped"
                    );
                    return;
                }
                self.bind_flow(ld, host);
                log::info!("fm: {ld} bound to host{host}");
            }
        }
    }

    /// Shared unbind flow, used by scripted events and policy moves
    /// alike (the UNBIND_REQUEST doorbell record is already posted):
    /// notify the owning guest, and if it offlined the window, drive
    /// the mailbox `UNBIND_LD`, drop the RC routing window and count
    /// the hot-remove. Returns whether the LD was actually released —
    /// `false` means the guest refused (pages in use,
    /// `sys.mem_offline_refused`) and ownership is unchanged.
    fn unbind_flow(&mut self, r: LdRef, from: usize, base: u64) -> bool {
        let changes = self.notify_host(from);
        let offlined = changes.iter().any(
            |c| matches!(c, MemChange::Offlined { base: b, .. } if *b == base),
        );
        if !offlined {
            self.hosts[from].stats.mem_offline_refused.inc();
            return false;
        }
        let code = self.fabric.fm_unbind(r.dev, r.ld);
        debug_assert_eq!(code, retcode::SUCCESS);
        self.hosts[from].rc.remove_window(base);
        self.hosts[from].stats.mem_offline_events.inc();
        true
    }

    /// Shared bind flow, used by scripted events and policy moves
    /// alike (the mailbox `BIND_LD` already succeeded): count the
    /// re-bind, ring the gaining host's Event-Log doorbell, and mirror
    /// every window its guest onlines into its RC decoder.
    fn bind_flow(&mut self, r: LdRef, to: usize) {
        self.fabric.devices[r.dev].note_rebind(r.ld as usize);
        self.fabric.post_fm_event(
            r.dev,
            EventRecord {
                host: to as u16,
                ld: r.ld,
                action: event::LD_BOUND,
            },
        );
        let changes = self.notify_host(to);
        for c in changes {
            if let MemChange::Onlined { base, size, .. } = c {
                self.mirror_rc_window(to, r, base, size);
                self.hosts[to].stats.mem_online_events.inc();
            }
        }
    }

    /// One `[fm] policy` sampling epoch at tick `t`: read every host's
    /// and LD's load, let the engine decide at most one move, execute
    /// it through the scripted path's quiesce/doorbell flow, and re-arm
    /// the next epoch while any workload still runs (so the queue can
    /// drain once every host finishes).
    fn handle_policy_epoch(&mut self, t: Tick) {
        let Some(mut eng) = self.fm_policy.take() else { return };
        let (hosts, lds) = self.sample_telemetry();
        if let Some(mv) = eng.epoch(t, &hosts, &lds) {
            // A move already parked in quiesce deferral keeps its one
            // re-probe chain; spawning another per epoch would only
            // multiply the deferral counters.
            if !self.fm_moves_parked.contains(&(mv.ld.dev, mv.ld.ld)) {
                self.execute_policy_move(
                    &mut eng, mv.ld, mv.from, mv.to, t,
                );
            }
        }
        let next = t + eng.epoch_ticks();
        self.fm_policy = Some(eng);
        if self.hosts.iter().any(|h| !h.all_done()) {
            self.mq.schedule_at(next, MEv::FmEpoch);
        }
    }

    /// Sample the telemetry the policy engine consumes — the same
    /// deterministic machine state the `host{H}.sys.*` and
    /// `cxl.devN.ldK.*` stat keys report: per-host cumulative load
    /// counters, and per-LD ownership + pages resident on the owning
    /// guest's zNUMA node.
    fn sample_telemetry(&self) -> (Vec<HostLoad>, Vec<LdState>) {
        let hosts: Vec<HostLoad> = self
            .hosts
            .iter()
            .map(|h| HostLoad {
                fallback_allocs: h
                    .guest
                    .as_ref()
                    .map(|g| g.alloc.fallback_allocs)
                    .unwrap_or(0),
                cxl_traffic: h.stats.cxl_reads.get()
                    + h.stats.writebacks_cxl.get(),
            })
            .collect();
        let lds: Vec<LdState> = self
            .window_keys
            .iter()
            .map(|&r| {
                let owner = self.fabric.ld_owner(r.dev, r.ld);
                let resident_pages = if owner != UNBOUND
                    && (owner as usize) < self.hosts.len()
                {
                    let h = owner as usize;
                    self.def_window(h, r)
                        .and_then(|(_, base, _)| {
                            let g = self.hosts[h].guest.as_ref()?;
                            let node = g.alloc.node_of_addr(base)?;
                            Some(g.alloc.pages_in_use(node))
                        })
                        .unwrap_or(0)
                } else {
                    0
                };
                let dev = &self.fabric.devices[r.dev];
                LdState {
                    ld: r,
                    owner,
                    resident_pages,
                    sharers: dev.mailbox.state.sharer_count(r.ld) as u16,
                    bi_sent: dev
                        .stats
                        .ld_bi_sent
                        .get(r.ld as usize)
                        .map(|c| c.get())
                        .unwrap_or(0),
                }
            })
            .collect();
        (hosts, lds)
    }

    /// Execute (or defer) one policy-decided move (`r`: host `from` ->
    /// host `to`) at tick `t`: the same cross-layer flow as a scripted
    /// unbind + bind pair, prefixed with a `POLICY_DECISION` Event-Log
    /// record so the decision trail is drainable via
    /// `GET_EVENT_RECORDS` like the actions themselves. Ownership is
    /// re-read and compared against the decided donor, so a
    /// quiesce-deferred move that the world outran (the LD already
    /// moved elsewhere) is dropped as stale instead of yanking it from
    /// its new owner behind the hysteresis gates' back.
    fn execute_policy_move(
        &mut self,
        eng: &mut FmPolicyEngine,
        r: LdRef,
        from: usize,
        to: usize,
        t: Tick,
    ) {
        // Whatever happens below, this attempt owns the LD's (single)
        // re-probe chain until it either parks again or resolves.
        self.fm_moves_parked.remove(&(r.dev, r.ld));
        let owner = self.fabric.ld_owner(r.dev, r.ld);
        if owner as usize != from
            || from == to
            || to >= self.hosts.len()
        {
            return; // stale decision (ownership moved while deferred)
        }
        let Some((_, base, size)) = self.def_window(from, r) else {
            log::warn!("fm-policy: host{from} has no window for {r}");
            return;
        };
        // Quiesce exactly like the scripted path: in-flight fetches to
        // the departing window drain first, re-probed on the same
        // fixed deterministic cadence.
        if self.hosts[from].has_inflight_in(base, size) {
            self.hosts[from].stats.fm_quiesce_retries.inc();
            eng.note_deferred();
            self.fm_moves_parked.insert((r.dev, r.ld));
            let at = t + ns_to_ticks(FM_QUIESCE_RETRY_NS);
            self.mq.schedule_at(
                at,
                MEv::FmMove {
                    dev: r.dev as u8,
                    ld: r.ld as u8,
                    from: from as u8,
                    to: to as u8,
                },
            );
            return;
        }
        // Decision log, then the unbind doorbell: the owning guest
        // drains both records in one GET_EVENT_RECORDS pass.
        self.fabric.post_fm_event(
            r.dev,
            EventRecord {
                host: owner,
                ld: r.ld,
                action: event::POLICY_DECISION,
            },
        );
        self.fabric.post_fm_event(
            r.dev,
            EventRecord {
                host: owner,
                ld: r.ld,
                action: event::UNBIND_REQUEST,
            },
        );
        if !self.unbind_flow(r, from, base) {
            // Pages in use: the guest kept the node. Back off
            // exponentially before asking for this LD again.
            eng.note_refused(r, t);
            log::warn!("fm-policy: host{from} refused to release {r}");
            return;
        }
        let code = self.fabric.fm_bind(r.dev, r.ld, to as u16);
        debug_assert_eq!(code, retcode::SUCCESS);
        self.bind_flow(r, to);
        eng.note_moved(r, from, to, t);
        log::info!("fm-policy: moved {r} host{from} -> host{to}");
    }

    /// Ring host `h`'s event doorbell: run the guest's FM-event handler
    /// against the real MMIO world and return the topology changes it
    /// made (empty if the host never booted or handling failed).
    fn notify_host(&mut self, h: usize) -> Vec<MemChange> {
        let Some(mut guest) = self.hosts[h].guest.take() else {
            log::warn!("fm: host{h} has no booted guest to notify");
            return Vec::new();
        };
        let res = {
            let host = &mut self.hosts[h];
            let mut world = MmioWorld {
                ecam: &mut host.ecam,
                cxl_devs: &mut self.fabric.devices,
                hb_components: &mut host.hb_components,
                chbs_base: bios::layout::CHBS_BASE,
                chbs_stride: bios::layout::CHBS_SIZE,
                ep_bdfs: &host.ep_bdfs,
            };
            guest.handle_fm_events(&mut world)
        };
        self.hosts[h].guest = Some(guest);
        match res {
            Ok(changes) => changes,
            Err(e) => {
                log::warn!("fm: host{h} event handling failed: {e}");
                Vec::new()
            }
        }
    }

    /// Mirror a hot-added window into host `h`'s RC interleave decoder
    /// — the runtime twin of the boot-time mirror in `boot_host`.
    fn mirror_rc_window(&mut self, h: usize, r: LdRef, base: u64, size: u64) {
        let Some(i) = self
            .win_defs
            .iter()
            .position(|d| d.targets[0] == r.dev && d.ld == r.ld)
        else {
            return;
        };
        // Pull the cached pieces into locals before borrowing the host.
        let targets = self.win_targets[i].clone();
        let ld = self.win_defs[i].ld;
        let xor = self.cfg.cxl.interleave_arith == InterleaveArith::Xor;
        self.hosts[h].rc.add_window(HdmWindow {
            base,
            size,
            granularity: self.cfg.cxl.interleave_granularity,
            targets,
            xor,
            dpa_base: ld as u64 * size,
        });
    }

    pub fn summary(&self) -> RunSummary {
        // Wall tick = the last core to finish anywhere (the queues may
        // still drain trailing prefetch fills past that point).
        let finished =
            self.hosts.iter().map(|h| h.finished_at()).max().unwrap_or(0);
        let ticks = if finished == 0 {
            self.hosts
                .iter()
                .map(|h| h.queue_now())
                .max()
                .unwrap_or(0)
                .max(self.mq.now())
        } else {
            finished
        }
        .max(1);
        let seconds = ticks as f64 * 1e-12;
        let bytes: u64 = self.hosts.iter().map(|h| h.bytes_moved()).sum();
        let l1_hits: u64 = self
            .hosts
            .iter()
            .flat_map(|h| h.l1s.iter())
            .map(|l| l.stats.hits.get())
            .sum();
        let l1_miss: u64 = self
            .hosts
            .iter()
            .flat_map(|h| h.l1s.iter())
            .map(|l| l.stats.misses.get())
            .sum();
        let l2_hits: u64 =
            self.hosts.iter().map(|h| h.l2.stats.hits.get()).sum();
        let l2_miss: u64 =
            self.hosts.iter().map(|h| h.l2.stats.misses.get()).sum();
        // Media latency averaged over every device's samples.
        let (media_sum, media_n) = self
            .fabric
            .devices
            .iter()
            .fold((0.0f64, 0u64), |(s, n), d| {
                let st = &d.stats.media_latency.stats;
                (s + st.sum, n + st.n)
            });
        let media_mean =
            if media_n == 0 { 0.0 } else { media_sum / media_n as f64 };
        // Per-device fills summed over hosts (per-device link latency
        // may differ, so the protocol adder is traffic-weighted).
        let ndev = self.fabric.ndev();
        let dev_fills: Vec<u64> = (0..ndev)
            .map(|i| {
                self.hosts
                    .iter()
                    .map(|h| h.stats.cxl_dev_reads[i].get())
                    .sum()
            })
            .collect();
        let total_fills: u64 = dev_fills.iter().sum();
        let proto_ns = if total_fills == 0 {
            2.0 * (self.cfg.cxl.pkt_lat_ns + self.cfg.cxl.depkt_lat_ns)
                + 2.0 * self.cfg.cxl.link_lat_ns
        } else {
            dev_fills
                .iter()
                .enumerate()
                .map(|(i, &c)| {
                    c as f64
                        * (2.0
                            * (self.cfg.cxl.pkt_lat_ns
                                + self.cfg.cxl.depkt_lat_ns)
                            + 2.0 * self.cfg.cxl.path_lat_ns(i))
                })
                .sum::<f64>()
                / total_fills as f64
        };
        // DRAM latency pooled over hosts' controllers.
        let (dram_sum, dram_n) = self.hosts.iter().fold(
            (0.0f64, 0u64),
            |(s, n), h| {
                let st = &h.dram.timing.stats.latency.stats;
                (s + st.sum, n + st.n)
            },
        );
        let dram_mean =
            if dram_n == 0 { 0.0 } else { dram_sum / dram_n as f64 };
        RunSummary {
            ticks,
            seconds,
            bytes_moved: bytes,
            bandwidth_gbps: bytes as f64 / seconds / 1e9,
            l1_miss_rate: if l1_hits + l1_miss == 0 {
                0.0
            } else {
                l1_miss as f64 / (l1_hits + l1_miss) as f64
            },
            l2_miss_rate: if l2_hits + l2_miss == 0 {
                0.0
            } else {
                l2_miss as f64 / (l2_hits + l2_miss) as f64
            },
            dram_accesses: self
                .hosts
                .iter()
                .map(|h| h.stats.dram_reads.get())
                .sum(),
            cxl_accesses: self
                .hosts
                .iter()
                .map(|h| h.stats.cxl_reads.get())
                .sum(),
            cxl_dev_fills: dev_fills,
            avg_lat_dram_ns: dram_mean / 1000.0,
            avg_lat_cxl_ns: media_mean / 1000.0 + proto_ns,
            m2s_req: self.fabric.agg_link(|s| s.m2s_req.get()),
            m2s_rwd: self.fabric.agg_link(|s| s.m2s_rwd.get()),
            s2m_ndr: self.fabric.agg_link(|s| s.s2m_ndr.get()),
            s2m_drs: self.fabric.agg_link(|s| s.s2m_drs.get()),
            s2m_bisnp: self.fabric.agg_link(|s| s.s2m_bisnp.get()),
            m2s_birsp: self.fabric.agg_link(|s| s.m2s_birsp.get()),
            events: self.events_total(),
        }
    }

    /// Verify all hosts' workloads' functional results.
    pub fn verify(&mut self) -> Result<(), String> {
        for h in self.hosts.iter_mut() {
            h.verify()?;
        }
        Ok(())
    }

    // ---- runtime invariant checker (`[sim] check`) ------------------------

    /// Run the full audit suite against the current state. The mutation
    /// tests in `rust/tests/invariants.rs` corrupt state after a run and
    /// call this to collect the rule ids that fire; it is also the
    /// end-of-run pass `run` itself performs.
    pub fn check_now(&mut self) {
        self.audit_final();
    }

    /// The invariant checker, when `[sim] check` is on.
    pub fn checker(&self) -> Option<&InvariantChecker> {
        self.checker.as_ref()
    }

    /// Rule ids of every recorded violation, in audit order (empty when
    /// the checker is off or the run was clean).
    pub fn check_violation_rules(&self) -> Vec<&'static str> {
        self.checker
            .as_ref()
            .map(|c| c.violations().iter().map(|v| v.rule).collect())
            .unwrap_or_default()
    }

    /// Fault hook (mutation tests): grow device `dev`'s leaf-link
    /// credit pool without a matching free/in-flight entry — CR-1 must
    /// fire at the next audit. Marks the checker tolerant so the
    /// seeded corruption reports instead of failing the run.
    #[cfg(feature = "check")]
    pub fn debug_leak_credit(&mut self, dev: usize) {
        self.fabric.credit_link(dev).debug_leak_credit();
        if let Some(ck) = self.checker.as_mut() {
            ck.set_tolerant();
        }
    }

    /// Fault hook (mutation tests): hold the next committed key back
    /// one slot so it emerges out of order — EQ-2 must fire.
    #[cfg(feature = "check")]
    pub fn debug_reorder_commit(&mut self) {
        if let Some(ck) = self.checker.as_mut() {
            ck.order.arm_reorder_fault();
            ck.set_tolerant();
        }
    }

    /// Fault hook (mutation tests): clear device `dev`'s snoop filter
    /// under live host-side ownership — SF-1 must fire at the next
    /// quiesce audit.
    #[cfg(feature = "check")]
    pub fn debug_desync_sharer(&mut self, dev: usize) {
        self.fabric.devices[dev].debug_desync_sharer();
        if let Some(ck) = self.checker.as_mut() {
            ck.set_tolerant();
        }
    }

    pub fn dump_stats(&self) -> StatDump {
        let mut d = StatDump::default();
        let multi = self.hosts.len() > 1;
        for (i, host) in self.hosts.iter().enumerate() {
            let prefix =
                if multi { format!("host{i}.") } else { String::new() };
            host.dump(&prefix, &mut d);
        }
        self.fabric.dump(&mut d);
        if let Some(eng) = &self.fm_policy {
            eng.dump(&mut d);
        }
        d.push("sys.events", self.events_total() as f64);
        // Parallel-scheduler telemetry: identical at every thread
        // count (the epoch structure is a function of queue state, not
        // of thread scheduling), so these keys are safe inside the
        // bit-determinism contract.
        d.push("sim.par.epochs", self.par_epochs as f64);
        d.push("sim.par.barrier_waits", self.par_barrier_waits as f64);
        d.push(
            "sim.par.horizon_ns_min",
            if self.par_horizon_min == Tick::MAX {
                0.0
            } else {
                ticks_to_ns(self.par_horizon_min)
            },
        );
        d
    }

    /// [`Machine::dump_stats`] plus the wall-clock phase timers
    /// (`sim.par.drain_ns` / `commit_ns` / `merge_ns`). These measure
    /// host time, not simulated time, so they differ run-to-run and
    /// are deliberately OUTSIDE the deterministic dump: golden-digest
    /// comparisons use `dump_stats`, the CLI prints this one.
    pub fn dump_stats_full(&self) -> StatDump {
        let mut d = self.dump_stats();
        d.push("sim.par.drain_ns", self.wall_drain_ns as f64);
        d.push("sim.par.commit_ns", self.wall_commit_ns as f64);
        d.push("sim.par.merge_ns", self.wall_merge_ns as f64);
        // Checker telemetry lives here, not in the deterministic dump:
        // the audit *cadence* (per epoch serial, per section threaded)
        // legitimately differs across scheduler modes, so `check.epochs`
        // would break cross-thread-count golden comparisons. Violations
        // must be zero everywhere regardless of cadence.
        if let Some(ck) = &self.checker {
            d.push("check.epochs", ck.epochs() as f64);
            d.push("check.violations", ck.total_violations() as f64);
            d.push("check.rules_evaluated", ck.rules_evaluated() as f64);
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CpuModel, CxlAttach};
    use crate::workloads::{Stream, StreamKernel};

    fn small_cfg() -> SimConfig {
        let mut c = SimConfig::default();
        c.cores = 2;
        c.sys_mem_size = 256 << 20;
        c.cxl.mem_size = 256 << 20;
        c
    }

    fn booted(cfg: SimConfig) -> Machine {
        let mut m = Machine::new(cfg).unwrap();
        m.boot(ProgModel::Znuma).unwrap();
        m
    }

    #[test]
    fn boot_onlines_znuma_node() {
        let m = booted(small_cfg());
        let g = m.guest.as_ref().unwrap();
        assert_eq!(g.znuma_node(), Some(1));
        assert!(g.alloc.nodes[1].online);
        assert!(!g.alloc.nodes[1].has_cpus);
        assert_eq!(g.memdevs.len(), 1);
        // RC routing mirrors the committed decoder.
        assert!(m.rc.routes(m.bios.cxl_window_base));
    }

    #[test]
    fn two_device_interleave_routes_across_both() {
        let mut cfg = small_cfg();
        cfg.cxl.devices = 2;
        let mut m = booted(cfg);
        let g = m.guest.as_ref().unwrap();
        assert_eq!(g.memdevs.len(), 2);
        assert_eq!(g.cxl_nodes, vec![1], "one interleaved zNUMA node");
        assert_eq!(g.alloc.nodes[1].size, 512 << 20, "2 x 256 MiB window");
        let wl = Stream::new(StreamKernel::Copy, 16384, 1);
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![1] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.cxl_accesses > 0);
        assert_eq!(s.cxl_dev_fills.len(), 2);
        assert!(
            s.cxl_dev_fills.iter().all(|&f| f > 0),
            "every device must serve fills: {:?}",
            s.cxl_dev_fills
        );
        // 256 B granules over 64 B lines: near-even split.
        let (a, b) = (s.cxl_dev_fills[0] as f64, s.cxl_dev_fills[1] as f64);
        assert!((a / b - 1.0).abs() < 0.2, "split {a} vs {b}");
        m.verify().unwrap();
    }

    #[test]
    fn separate_windows_expose_separate_znuma_nodes() {
        let mut cfg = small_cfg();
        cfg.cxl.devices = 2;
        cfg.cxl.interleave_ways = 1; // two single-device windows
        let mut m = booted(cfg);
        let g = m.guest.as_ref().unwrap();
        assert_eq!(g.cxl_nodes, vec![1, 2]);
        assert!(g.alloc.nodes[2].online && !g.alloc.nodes[2].has_cpus);
        // Binding to node 2 exercises only device 1.
        let wl = Stream::new(StreamKernel::Copy, 4096, 1);
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![2] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.cxl_dev_fills[1] > 0);
        assert_eq!(s.cxl_dev_fills[0], 0);
        m.verify().unwrap();
    }

    #[test]
    fn switched_topology_boots_and_contends_upstream() {
        let mut cfg = small_cfg();
        cfg.cxl.devices = 2;
        cfg.cxl.switches = 1;
        let mut m = booted(cfg);
        {
            let g = m.guest.as_ref().unwrap();
            assert_eq!(g.memdevs.len(), 2);
            assert_eq!(g.cxl_nodes, vec![1, 2], "one node per endpoint");
            // Both endpoints bound to the same (single) host bridge.
            assert_eq!(g.memdevs[0].hb_uid, g.memdevs[1].hb_uid);
        }
        let a = Stream::new(StreamKernel::Copy, 8192, 1);
        let b = Stream::new(StreamKernel::Copy, 8192, 1);
        m.attach_workloads(
            vec![Box::new(a), Box::new(b)],
            &MemPolicy::Interleave { weights: vec![(1, 1), (2, 1)] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.cxl_dev_fills.iter().all(|&f| f > 0));
        // Every flit crossed the shared upstream link.
        let sw = &m.fabric.switches[0];
        assert_eq!(
            sw.stats.m2s_forwarded.get(),
            s.m2s_req + s.m2s_rwd,
            "all M2S traffic must be forwarded upstream"
        );
        let d = m.dump_stats();
        assert!(d.get("cxl.sw0.us_link.flits").unwrap() > 0.0);
        m.verify().unwrap();
    }

    #[test]
    fn switched_interleave_set_splits_traffic_across_members() {
        // PR-3: a 2-way interleave set behind ONE switch — previously
        // rejected, now decoded by the same RC hierarchy table.
        let mut cfg = small_cfg();
        cfg.cxl.devices = 2;
        cfg.cxl.switches = 1;
        cfg.cxl.interleave_ways = 2;
        let mut m = booted(cfg);
        {
            let g = m.guest.as_ref().unwrap();
            assert_eq!(g.cxl_nodes, vec![1], "one interleaved node");
            assert_eq!(g.alloc.nodes[1].size, 512 << 20);
            assert_eq!(g.memdevs.len(), 2);
            assert_eq!(
                (g.memdevs[0].position, g.memdevs[1].position),
                (0, 1),
                "same-bridge members claim consecutive CFMWS slots"
            );
        }
        let wl = Stream::new(StreamKernel::Copy, 16384, 1);
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![1] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(
            s.cxl_dev_fills.iter().all(|&f| f > 0),
            "both set members must serve fills: {:?}",
            s.cxl_dev_fills
        );
        // All of it crossed the one shared upstream link.
        assert_eq!(
            m.fabric.switches[0].stats.m2s_forwarded.get(),
            s.m2s_req + s.m2s_rwd
        );
        m.verify().unwrap();
    }

    #[test]
    fn mld_onlines_one_node_per_ld() {
        let mut cfg = small_cfg();
        cfg.cxl.mem_size = 512 << 20;
        cfg.cxl.dev_overrides =
            vec![crate::config::CxlDevOverride {
                lds: Some(2),
                ..Default::default()
            }];
        let mut m = booted(cfg);
        {
            let g = m.guest.as_ref().unwrap();
            assert_eq!(g.memdevs.len(), 2, "one memdev per LD");
            assert_eq!(g.memdevs[0].bdf, g.memdevs[1].bdf);
            assert_eq!((g.memdevs[0].ld, g.memdevs[1].ld), (0, 1));
            assert_eq!(g.cxl_nodes, vec![1, 2]);
            assert_eq!(g.alloc.nodes[1].size, 256 << 20);
            assert_eq!(g.alloc.nodes[2].size, 256 << 20);
        }
        // Traffic bound to node 2 exercises only LD 1's slice.
        let wl = Stream::new(StreamKernel::Copy, 4096, 1);
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![2] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.cxl_accesses > 0);
        assert_eq!(m.fabric.devices[0].stats.ld_reads[0].get(), 0);
        assert!(m.fabric.devices[0].stats.ld_reads[1].get() > 0);
        let d = m.dump_stats();
        assert!(d.get("cxl.dev0.ld1.reads").unwrap() > 0.0);
        m.verify().unwrap();
    }

    #[test]
    fn two_hosts_pool_one_mld_with_host_attribution() {
        // The acceptance scenario in miniature: one 2-LD MLD behind a
        // switch, its LDs parceled to two hosts. Each guest boots from
        // the unmodified enumeration path, onlines only its own LD, and
        // the device's stats attribute traffic per host.
        let mut cfg = small_cfg();
        cfg.hosts = 2;
        cfg.cxl.mem_size = 512 << 20;
        cfg.cxl.switches = 1;
        cfg.cxl.dev_overrides =
            vec![crate::config::CxlDevOverride {
                lds: Some(2),
                ..Default::default()
            }];
        let mut m = booted(cfg);
        for h in 0..2 {
            let g = m.hosts[h].guest.as_ref().unwrap();
            assert_eq!(g.memdevs.len(), 1, "host {h}: exactly its own LD");
            assert_eq!(g.memdevs[0].ld as usize, h);
            assert_eq!(g.memdevs[0].lds, 2);
            assert_eq!(g.cxl_nodes, vec![1]);
            assert_eq!(g.alloc.nodes[1].size, 256 << 20);
        }
        // Disjoint host-physical windows for the two LDs.
        let b0 = m.hosts[0].bios.cxl_windows[0];
        let b1 = m.hosts[1].bios.cxl_windows[0];
        assert!(b1.0 >= b0.0 + b0.1, "window bases must be disjoint");
        // Both hosts hammer their LD of the same MLD concurrently.
        for h in 0..2 {
            let wl = Stream::new(StreamKernel::Copy, 8192, 1);
            m.attach_workloads_to(
                h,
                vec![Box::new(wl)],
                &MemPolicy::Bind { nodes: vec![1] },
            )
            .unwrap();
        }
        let s = m.run(None);
        assert!(s.cxl_accesses > 0);
        let dstats = &m.fabric.devices[0].stats;
        assert!(dstats.ld_host_reads[0][0].get() > 0, "host 0 -> LD 0");
        assert!(dstats.ld_host_reads[1][1].get() > 0, "host 1 -> LD 1");
        assert_eq!(dstats.ld_host_reads[0][1].get(), 0);
        assert_eq!(dstats.ld_host_reads[1][0].get(), 0);
        let d = m.dump_stats();
        assert!(d.get("cxl.dev0.ld0.host0_reads").unwrap() > 0.0);
        assert!(d.get("cxl.dev0.ld1.host1_reads").unwrap() > 0.0);
        // Host-prefixed per-host stats exist alongside fabric stats.
        assert!(d.get("host0.l2.hits").is_some());
        assert!(d.get("host1.cxl.dev0.fills").unwrap() > 0.0);
        m.verify().unwrap();
    }

    #[test]
    fn cross_host_contention_slows_shared_mld() {
        // Host 0 running alone vs running while host 1 hammers the
        // other LD of the same switched MLD: the shared upstream link
        // and media must cost host 0 time.
        let build = || {
            let mut cfg = small_cfg();
            cfg.hosts = 2;
            cfg.cxl.mem_size = 512 << 20;
            cfg.cxl.switches = 1;
            cfg.cxl.dev_overrides =
                vec![crate::config::CxlDevOverride {
                    lds: Some(2),
                    ..Default::default()
                }];
            booted(cfg)
        };
        let solo = {
            let mut m = build();
            let wl = Stream::new(StreamKernel::Triad, 16384, 1);
            m.attach_workloads_to(
                0,
                vec![Box::new(wl)],
                &MemPolicy::Bind { nodes: vec![1] },
            )
            .unwrap();
            m.run(None);
            m.hosts[0].finished_at()
        };
        let contended = {
            let mut m = build();
            for h in 0..2 {
                let wl = Stream::new(StreamKernel::Triad, 16384, 1);
                m.attach_workloads_to(
                    h,
                    vec![Box::new(wl)],
                    &MemPolicy::Bind { nodes: vec![1] },
                )
                .unwrap();
            }
            m.run(None);
            m.hosts[0].finished_at()
        };
        assert!(
            contended > solo * 105 / 100,
            "cross-host sharing must cost time: solo {solo} vs \
             contended {contended}"
        );
    }

    #[test]
    fn stream_on_dram_runs_and_verifies() {
        let mut m = booted(small_cfg());
        let wl = Stream::new(StreamKernel::Copy, 4096, 1);
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![0] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.ticks > 0);
        assert!(s.cxl_accesses == 0, "bind:0 must not touch CXL");
        assert!(s.dram_accesses > 0);
        m.verify().unwrap();
    }

    #[test]
    fn stream_on_cxl_goes_through_link() {
        let mut m = booted(small_cfg());
        let wl = Stream::new(StreamKernel::Copy, 4096, 1);
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![1] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.cxl_accesses > 0);
        assert!(s.m2s_req > 0, "M2S requests must cross the link");
        assert!(s.s2m_drs > 0, "read data must return on DRS");
        m.verify().unwrap();
    }

    #[test]
    fn cxl_slower_than_dram() {
        let run = |node: u32| {
            let mut m = booted(small_cfg());
            let wl = Stream::new(StreamKernel::Triad, 8192, 1);
            m.attach_workloads(
                vec![Box::new(wl)],
                &MemPolicy::Bind { nodes: vec![node] },
            )
            .unwrap();
            m.run(None).ticks
        };
        let dram = run(0);
        let cxl = run(1);
        assert!(
            cxl > dram * 11 / 10,
            "CXL ({cxl}) must be slower than DRAM ({dram})"
        );
    }

    #[test]
    fn interleave_splits_traffic() {
        let mut m = booted(small_cfg());
        let wl = Stream::new(StreamKernel::Copy, 16384, 1);
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Interleave { weights: vec![(0, 1), (1, 1)] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.dram_accesses > 0 && s.cxl_accesses > 0);
        let ratio = s.dram_accesses as f64 / s.cxl_accesses as f64;
        assert!((0.5..2.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn deterministic_across_runs() {
        let go = || {
            let mut m = booted(small_cfg());
            let wl = Stream::new(StreamKernel::Add, 2048, 1);
            m.attach_workloads(
                vec![Box::new(wl)],
                &MemPolicy::Interleave { weights: vec![(0, 3), (1, 1)] },
            )
            .unwrap();
            let s = m.run(None);
            (s.ticks, s.events, s.dram_accesses, s.cxl_accesses)
        };
        assert_eq!(go(), go());
    }

    #[test]
    fn serial_and_threaded_sections_agree() {
        // The contract in miniature (the full property sweep lives in
        // tests/parallel_determinism.rs): a 2-host run behind one
        // switch must produce identical digests at threads = 1 and 2.
        let go = |threads: usize| {
            let mut cfg = small_cfg();
            cfg.hosts = 2;
            cfg.threads = threads;
            cfg.cxl.mem_size = 512 << 20;
            cfg.cxl.switches = 1;
            cfg.cxl.dev_overrides =
                vec![crate::config::CxlDevOverride {
                    lds: Some(2),
                    ..Default::default()
                }];
            let mut m = booted(cfg);
            for h in 0..2 {
                let wl = Stream::new(StreamKernel::Triad, 8192, 1);
                m.attach_workloads_to(
                    h,
                    vec![Box::new(wl)],
                    &MemPolicy::Bind { nodes: vec![1] },
                )
                .unwrap();
            }
            let s = m.run(None);
            m.verify().unwrap();
            (s.ticks, s.events, s.cxl_accesses, m.dump_stats().to_text())
        };
        assert_eq!(go(1), go(2));
    }

    #[test]
    fn two_cores_share_l2() {
        let mut m = booted(small_cfg());
        let a = Stream::new(StreamKernel::Copy, 2048, 1);
        let b = Stream::new(StreamKernel::Copy, 2048, 1);
        m.attach_workloads(
            vec![Box::new(a), Box::new(b)],
            &MemPolicy::Bind { nodes: vec![0] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.ticks > 0);
        assert!(m.cores.iter().all(|c| c.done));
        m.verify().unwrap();
    }

    #[test]
    fn membus_attach_baseline_skips_protocol() {
        let mut cfg = small_cfg();
        cfg.cxl.attach = CxlAttach::MemBus;
        let mut m = booted(cfg);
        let wl = Stream::new(StreamKernel::Copy, 4096, 1);
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![1] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.cxl_accesses > 0);
        assert_eq!(s.m2s_req, 0, "baseline must bypass the CXL.mem layer");
    }

    #[test]
    fn tiny_mshr_file_parks_and_completes() {
        // One L1 MSHR + an O3 core: the issue path parks ops hard on
        // the capacity pre-check (the primary mechanism; the in-flight
        // MshrRetry arm behind it is defensive and stays unreachable
        // while the pre-check exists). Everything must still complete
        // and verify under maximal structural pressure.
        let mut cfg = small_cfg();
        cfg.l1.mshrs = 1;
        let mut m = booted(cfg);
        let a = Stream::new(StreamKernel::Triad, 8192, 1);
        let b = Stream::new(StreamKernel::Copy, 8192, 1);
        m.attach_workloads(
            vec![Box::new(a), Box::new(b)],
            &MemPolicy::Interleave { weights: vec![(0, 1), (1, 1)] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.ticks > 0);
        for (i, c) in m.cores.iter().enumerate() {
            assert!(c.done, "core {i} never finished");
            assert_eq!(c.outstanding(), 0, "core {i} leaked requests");
            let issued = c.stats.loads.get() + c.stats.stores.get();
            assert_eq!(issued, c.stats.mem_latency.count());
        }
        m.verify().unwrap();
    }

    #[test]
    fn credit_starved_burst_drains() {
        // One M2S credit for an O3 core's whole miss burst: requests
        // must park on credit stalls and still all drain — no retry may
        // ever be scheduled at a sentinel tick, and the credit_wait
        // histogram must stay within the run's bounds.
        let mut cfg = small_cfg();
        cfg.cxl.credits = 1;
        let mut m = booted(cfg);
        let wl = Stream::new(StreamKernel::Triad, 8192, 1);
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![1] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.ticks > 0 && s.cxl_accesses > 0);
        for (i, c) in m.cores.iter().enumerate() {
            assert!(c.done, "core {i} parked forever");
            assert_eq!(c.outstanding(), 0, "core {i} leaked requests");
        }
        let link = &m.fabric.links[0].stats;
        assert!(link.credit_stalls.get() > 0, "burst must stall");
        assert_eq!(link.credit_wait.count(), link.credit_stalls.get());
        assert!(
            link.credit_wait.stats.max <= s.ticks as f64,
            "credit_wait poisoned: {} > run {}",
            link.credit_wait.stats.max,
            s.ticks
        );
        // The contended wire's occupancy histogram reaches the dump.
        let d = m.dump_stats();
        assert!(
            d.get("cxl.link0.occupancy_wait.count").unwrap() > 0.0,
            "occupancy_wait must be emitted"
        );
        m.verify().unwrap();
    }

    #[test]
    fn o3_faster_than_inorder_on_misses() {
        let run = |model: CpuModel| {
            let mut cfg = small_cfg();
            cfg.cpu_model = model;
            let mut m = booted(cfg);
            let wl = Stream::new(StreamKernel::Copy, 8192, 1);
            m.attach_workloads(
                vec![Box::new(wl)],
                &MemPolicy::Bind { nodes: vec![0] },
            )
            .unwrap();
            m.run(None).ticks
        };
        let o3 = run(CpuModel::OutOfOrder);
        let inorder = run(CpuModel::InOrder);
        assert!(
            o3 < inorder,
            "O3 ({o3}) must beat in-order ({inorder}) via MLP"
        );
    }
}
