//! The machine: topology + boot + the event-driven memory system.


use anyhow::{Context, Result};

use crate::bios::{self, layout, BiosInfo};
use crate::bus::Bus;
use crate::cache::prefetch::{PrefetchBook, StridePrefetcher};
use crate::cache::{Access, CacheArray, Directory, MesiState, MshrAlloc,
                   MshrFile, Victim};
use crate::config::{CxlAttach, InterleaveArith, SimConfig};
use crate::cpu::{Core, WlOp};
use crate::cxl::regs::ComponentRegs;
use crate::cxl::{CxlDevice, CxlRootComplex, HdmWindow};
use crate::guestos::{AddressSpace, GuestOs, MemPolicy, ProgModel};
use crate::mem::{MemCtrl, PhysMem};
use crate::pcie::{self, config_space as cs, Bdf, Ecam};
use crate::sim::{ns_to_ticks, EventQueue, MemCmd, Packet, ReqId, Tick};
use crate::stats::{Counter, Histogram, StatDump};
use crate::workloads::Workload;

use super::mmio::MmioWorld;

/// Machine events (only async points become events — see module docs).
#[derive(Debug)]
enum Ev {
    /// Core front-end tries to issue.
    Issue(u8),
    /// A request completed without a line fill (L1 hit / upgrade).
    Hit { core: u8, req: ReqId },
    /// A line fill arrived at a core's L1.
    LineFill { core: u8, line_pa: u64 },
    /// DRAM controller queue was full — retry the fetch.
    DramRetry { core: u8, line_pa: u64, wants_excl: bool },
    /// CXL M2S credit stall — retry packetization.
    CxlRetry { core: u8, line_pa: u64, wants_excl: bool },
}

/// Sentinel "core" marking an L2-prefetch fetch: the fill stops at L2.
const PF_CORE: u8 = u8::MAX;

/// Per-L2-line in-flight memory fetch (cores waiting on it).
#[derive(Debug, Default)]
struct L2Pending {
    cores: Vec<u8>,
    wants_excl: bool,
}

#[derive(Clone, Debug, Default)]
pub struct MachineStats {
    pub dram_reads: Counter,
    pub cxl_reads: Counter,
    pub lat_dram: Histogram,
    pub lat_cxl: Histogram,
    pub page_faults: Counter,
    pub upgrades: Counter,
    pub coherence_invals: Counter,
    pub writebacks_dram: Counter,
    pub writebacks_cxl: Counter,
    /// Per-device line fills served (indexed by device).
    pub cxl_dev_reads: Vec<Counter>,
    /// Per-device write-backs absorbed.
    pub cxl_dev_writebacks: Vec<Counter>,
}

/// End-of-run digest used by benches and examples.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub ticks: Tick,
    pub seconds: f64,
    pub bytes_moved: u64,
    pub bandwidth_gbps: f64,
    pub l1_miss_rate: f64,
    pub l2_miss_rate: f64,
    pub dram_accesses: u64,
    pub cxl_accesses: u64,
    /// Line fills per expander device.
    pub cxl_dev_fills: Vec<u64>,
    pub avg_lat_dram_ns: f64,
    pub avg_lat_cxl_ns: f64,
    pub m2s_req: u64,
    pub m2s_rwd: u64,
    pub s2m_ndr: u64,
    pub s2m_drs: u64,
    pub events: u64,
}

pub struct Machine {
    pub cfg: SimConfig,
    pub mem: PhysMem,
    pub ecam: Ecam,
    /// Endpoint BDFs, one per expander device.
    pub ep_bdfs: Vec<Bdf>,
    pub bios: BiosInfo,
    /// Host-bridge component blocks, one per device.
    pub hb_components: Vec<ComponentRegs>,
    pub rc: CxlRootComplex,
    /// Expander device models, indexed like `ep_bdfs`.
    pub cxl_devs: Vec<CxlDevice>,
    pub guest: Option<GuestOs>,

    pub cores: Vec<Core>,
    pub l1s: Vec<CacheArray>,
    pub l1_mshrs: Vec<MshrFile>,
    pub l2: CacheArray,
    pub dir: Directory,
    pub membus: Bus,
    pub iobus: Bus,
    pub dram: MemCtrl,

    queue: EventQueue<Ev>,
    issue_scheduled: Vec<bool>,
    pending_op: Vec<Option<WlOp>>,
    workloads: Vec<Box<dyn Workload>>,
    pub spaces: Vec<AddressSpace>,
    l2_pending: crate::util::fxhash::FxHashMap<u64, L2Pending>,
    next_req: ReqId,
    l1_lat: Tick,
    l2_lat: Tick,
    /// MemBus-baseline fixed protocol adder per device (pack + unpack
    /// both ways + wire), precomputed so the hot path is an index.
    dev_fixed_ticks: Vec<Tick>,
    fault_ticks: Tick,
    pub prefetcher: Option<StridePrefetcher>,
    pub pf_book: PrefetchBook,
    pub stats: MachineStats,
}

impl Machine {
    /// Build the hardware: BIOS tables in memory, PCIe topology with the
    /// CXL endpoint fully described (DVSECs, BARs), RC + device models.
    pub fn new(cfg: SimConfig) -> Result<Self> {
        cfg.validate()?;
        let mut mem = PhysMem::new();
        let bios = bios::build(&cfg, &mut mem);

        let mut ecam = Ecam::new(bios.ecam_base, layout::ECAM_BUSES);
        let n_dev = cfg.cxl.devices;
        let n_bridges = cfg.cxl.bridges();
        let ep_bdfs = if cfg.cxl.switches > 0 {
            let groups: Vec<usize> = (0..cfg.cxl.switches)
                .map(|j| cfg.cxl.switch(j).ndev)
                .collect();
            let (_hb, _sw, eps) =
                pcie::build_topology_switched(&mut ecam, &groups);
            eps
        } else {
            let (_hb, _rps, eps) = pcie::build_topology_n(&mut ecam, n_dev);
            eps
        };
        for (i, &ep_bdf) in ep_bdfs.iter().enumerate() {
            let dev_size = cfg.cxl.device(i).mem_size;
            let epc = ecam.function_mut(ep_bdf).unwrap();
            epc.add_bar64(0, 1 << 16); // component registers
            epc.add_bar64(2, 1 << 12); // device registers (mailbox)
            epc.add_dvsec(
                cs::DVSEC_CXL_DEVICE,
                &crate::cxl::regs::dvsec_payload::cxl_device(dev_size),
            );
            epc.add_dvsec(
                cs::DVSEC_GPF_DEVICE,
                &crate::cxl::regs::dvsec_payload::gpf_device(),
            );
            epc.add_dvsec(
                cs::DVSEC_FLEXBUS_PORT,
                &crate::cxl::regs::dvsec_payload::flexbus_port(),
            );
            epc.add_dvsec(
                cs::DVSEC_REGISTER_LOCATOR,
                &crate::cxl::regs::dvsec_payload::register_locator(&[
                    (0, crate::cxl::regs::dev_block_ids::COMPONENT, 0),
                    (2, crate::cxl::regs::dev_block_ids::DEVICE, 0),
                ]),
            );
        }

        let cores = (0..cfg.cores).map(|i| Core::new(i as u8, &cfg)).collect();
        let l1s = (0..cfg.cores).map(|_| CacheArray::new(&cfg.l1)).collect();
        let l1_mshrs =
            (0..cfg.cores).map(|_| MshrFile::new(cfg.l1.mshrs)).collect();
        let l2 = CacheArray::new(&cfg.l2);
        let membus =
            Bus::new("membus", cfg.membus_lat_ns, cfg.membus_bw_gbps, 2);
        let iobus = Bus::new("iobus", cfg.iobus_lat_ns, cfg.iobus_bw_gbps, 1);
        let dram = MemCtrl::new(&cfg.sys_dram, 64);
        let rc = CxlRootComplex::new(&cfg.cxl);
        let cxl_devs: Vec<CxlDevice> = (0..n_dev)
            .map(|i| CxlDevice::new_at(&cfg.cxl, i, 0xC0FFEE + i as u64))
            .collect();
        // One component block per host bridge, with one HDM decoder per
        // window it decodes (one per LD of each device beneath it).
        let hb_components = (0..n_bridges)
            .map(|b| {
                let decoders: usize = (0..n_dev)
                    .filter(|&i| cfg.cxl.bridge_of(i) == b)
                    .map(|i| cfg.cxl.device(i).lds)
                    .sum();
                ComponentRegs::new(decoders.max(1))
            })
            .collect();

        let l1_lat = ns_to_ticks(cfg.l1.lat_cycles as f64 * cfg.cycle_ns());
        let l2_lat = ns_to_ticks(cfg.l2.lat_cycles as f64 * cfg.cycle_ns());
        let dev_fixed_ticks = (0..n_dev)
            .map(|i| {
                ns_to_ticks(
                    2.0 * (cfg.cxl.pkt_lat_ns + cfg.cxl.depkt_lat_ns)
                        + 2.0 * cfg.cxl.path_lat_ns(i),
                )
            })
            .collect();
        let prefetcher = cfg
            .l2
            .prefetch
            .then(|| StridePrefetcher::new(256, cfg.l2.pf_degree));
        Ok(Machine {
            issue_scheduled: vec![false; cfg.cores],
            pending_op: vec![None; cfg.cores],
            spaces: Vec::new(),
            stats: MachineStats {
                cxl_dev_reads: vec![Counter::default(); n_dev],
                cxl_dev_writebacks: vec![Counter::default(); n_dev],
                ..Default::default()
            },
            cfg,
            mem,
            ecam,
            ep_bdfs,
            bios,
            hb_components,
            rc,
            cxl_devs,
            guest: None,
            cores,
            l1s,
            l1_mshrs,
            l2,
            dir: Directory::new(),
            membus,
            iobus,
            dram,
            queue: EventQueue::new(),
            workloads: Vec::new(),
            l2_pending: Default::default(),
            next_req: 1,
            l1_lat,
            l2_lat,
            dev_fixed_ticks,
            fault_ticks: ns_to_ticks(300.0),
            prefetcher,
            pf_book: PrefetchBook::default(),
        })
    }

    /// Boot the guest: ACPI parse, enumeration, CXL bind, onlining.
    pub fn boot(&mut self, model: ProgModel) -> Result<()> {
        let mut world = MmioWorld {
            ecam: &mut self.ecam,
            cxl_devs: &mut self.cxl_devs,
            hb_components: &mut self.hb_components,
            chbs_base: layout::CHBS_BASE,
            chbs_stride: layout::CHBS_SIZE,
            ep_bdfs: &self.ep_bdfs,
        };
        let guest =
            GuestOs::boot(&mut world, &self.mem, self.cfg.page_size, model)
                .context("guest boot failed")?;
        // Mirror the committed host-bridge decoders into the RC's
        // interleave decoder: one window per definition (interleave set
        // or MLD slice), carrying the member devices in CFMWS slot
        // order, provided every member's *bridge* actually committed
        // the range (routing is by hierarchy: device -> bridge).
        let xor = self.cfg.cxl.interleave_arith == InterleaveArith::Xor;
        let windows = self.bios.cxl_windows.clone();
        let defs = self.cfg.cxl.window_defs();
        for (def, &(base, size)) in defs.iter().zip(windows.iter()) {
            let all_committed = def.targets.iter().all(|&i| {
                self.hb_components[self.cfg.cxl.bridge_of(i)]
                    .committed_ranges()
                    .iter()
                    .any(|&(b, s)| b == base && s == size)
            });
            if all_committed {
                self.rc.add_window(HdmWindow {
                    base,
                    size,
                    granularity: self.cfg.cxl.interleave_granularity,
                    targets: def.targets.clone(),
                    xor,
                    // 1-way LD slices relocate densely by slice size.
                    dpa_base: def.ld as u64 * size,
                });
            }
        }
        self.guest = Some(guest);
        Ok(())
    }

    /// Attach one workload per core (fewer workloads than cores is fine)
    /// and perform the functional init phase (untimed, like a
    /// fast-forwarded boot+init in gem5).
    pub fn attach_workloads(
        &mut self,
        mut wls: Vec<Box<dyn Workload>>,
        policy: &MemPolicy,
    ) -> Result<()> {
        let guest = self.guest.as_mut().context("boot first")?;
        assert!(wls.len() <= self.cores.len());
        self.spaces.clear();
        for wl in wls.iter_mut() {
            let mut asp = AddressSpace::new(self.cfg.page_size);
            wl.setup(&mut asp, policy);
            for (va, bits) in wl.init_data() {
                let pa = asp.translate(va, &mut guest.alloc)?;
                self.mem.write_u64(pa, bits);
            }
            self.spaces.push(asp);
        }
        self.workloads = wls;
        for c in 0..self.workloads.len() {
            self.queue.schedule_at(0, Ev::Issue(c as u8));
            self.issue_scheduled[c] = true;
        }
        Ok(())
    }

    fn alloc_req(&mut self) -> ReqId {
        let r = self.next_req;
        self.next_req += 1;
        r
    }

    fn is_cxl_addr(&self, pa: u64) -> bool {
        self.rc.routes(pa)
            || (self.cfg.cxl.attach == CxlAttach::MemBus
                && pa >= self.bios.cxl_window_base
                && pa < self.bios.cxl_window_base + self.bios.cxl_window_size)
    }

    // ---- the memory path --------------------------------------------------

    /// A core issues a load/store to `pa` at `now`. Returns the request
    /// id the core should track.
    fn access(&mut self, core: u8, pa: u64, is_write: bool, now: Tick) {
        let req = self.alloc_req();
        self.cores[core as usize].begin_mem(now, req, is_write);
        let c = core as usize;
        let probe = self.l1s[c].probe(pa, is_write);
        match probe.access {
            Access::Hit if !probe.needs_upgrade => {
                self.queue
                    .schedule_at(now + self.l1_lat, Ev::Hit { core, req });
            }
            Access::Hit => {
                // Write hit on Shared: directory upgrade.
                self.stats.upgrades.inc();
                let line = self.l1s[c].line_addr(pa);
                let act = self.dir.write_req(line, core);
                let mut extra = 0;
                if let crate::cache::directory::DirAction::Invalidate { mask } =
                    act
                {
                    extra = self.invalidate_peers(mask, pa, now);
                }
                self.l1s[c].finish_upgrade(pa);
                self.dir.note_write(line, core);
                // Upgrade = L1 + membus round trip (+ peer inval time).
                let t = now
                    + self.l1_lat
                    + self.membus.transfer(now, 16)
                    .saturating_sub(now)
                    + extra;
                self.queue.schedule_at(t, Ev::Hit { core, req });
            }
            Access::Miss => {
                let line = self.l1s[c].line_addr(pa);
                match self.l1_mshrs[c].allocate(line, req, is_write) {
                    MshrAlloc::Secondary => { /* ride the primary */ }
                    MshrAlloc::Full => {
                        // Unreachable: try_issue parks the op when the
                        // MSHR file is full. Degrade gracefully anyway.
                        debug_assert!(false, "MSHR full past the pre-check");
                        self.cores[c].complete_mem(now, req);
                        self.cores[c].note_lsq_stall();
                        self.schedule_issue(core, now + self.l1_lat * 4);
                    }
                    MshrAlloc::Primary => {
                        self.l1_primary_miss(core, pa, is_write, now);
                    }
                }
            }
        }
    }

    /// Handle coherence + L2 for a primary L1 miss.
    fn l1_primary_miss(&mut self, core: u8, pa: u64, is_write: bool, now: Tick) {
        use crate::cache::directory::DirState;
        let line = self.l1s[core as usize].line_addr(pa);
        // Timing estimate for directory traffic; the *state* actions are
        // applied at fill time (complete_line_fill), which keeps SWMR
        // intact when multiple fills race.
        let coh_extra: Tick = match self.dir.state(line) {
            DirState::Owned { core: o } if o != core => {
                ns_to_ticks(2.0 * self.cfg.membus_lat_ns)
            }
            DirState::Sharers { .. } if is_write => {
                ns_to_ticks(2.0 * self.cfg.membus_lat_ns)
            }
            _ => 0,
        };

        // To L2 over the membus.
        let at_l2 = self.membus.transfer(now + self.l1_lat, 16) + self.l2_lat
            + coh_extra;
        // Train the prefetcher on the demand stream reaching L2.
        self.train_prefetcher(pa, at_l2);
        let l2_probe = self.l2.probe(pa, false);
        match l2_probe.access {
            Access::Hit => {
                if self.pf_book.note_demand(line) {
                    if let Some(p) = &mut self.prefetcher {
                        p.stats.useful.inc();
                    }
                }
                // Data back over the membus.
                let back = self.membus.transfer(at_l2, 64);
                self.queue.schedule_at(
                    back,
                    Ev::LineFill { core, line_pa: pa },
                );
            }
            Access::Miss => {
                let key = self.l2.line_addr(pa);
                if self.pf_book.note_demand_miss(key) {
                    // Prefetch in flight but not home yet: the demand
                    // merges onto it — a *late* prefetch.
                    if let Some(p) = &mut self.prefetcher {
                        p.stats.late.inc();
                    }
                }
                if let Some(p) = self.l2_pending.get_mut(&key) {
                    p.cores.push(core);
                    p.wants_excl |= is_write;
                    return;
                }
                self.l2_pending.insert(
                    key,
                    L2Pending { cores: vec![core], wants_excl: is_write },
                );
                self.fetch_from_memory(core, pa, is_write, at_l2);
            }
        }
    }

    /// Feed the L2 prefetcher and launch predicted fetches.
    fn train_prefetcher(&mut self, pa: u64, now: Tick) {
        let line = self.l2.line_addr(pa);
        let Some(p) = &mut self.prefetcher else { return };
        let predictions = p.train(line);
        for target_line in predictions {
            let target_pa = target_line * self.cfg.l2.line;
            // Skip resident / in-flight lines and unmapped space.
            if self.l2.find(target_pa).is_some()
                || self.l2_pending.contains_key(&target_line)
                || self.pf_book.is_inflight(target_line)
            {
                continue;
            }
            let in_dram = target_pa < self.cfg.sys_mem_size;
            let in_cxl = self.is_cxl_addr(target_pa);
            if !in_dram && !in_cxl {
                continue;
            }
            if let Some(pp) = &mut self.prefetcher {
                pp.stats.issued.inc();
            }
            self.pf_book.note_issued(target_line);
            self.l2_pending.insert(
                target_line,
                L2Pending { cores: Vec::new(), wants_excl: false },
            );
            self.fetch_from_memory(PF_CORE, target_pa, false, now);
        }
    }

    /// L2 miss -> system DRAM or CXL expander.
    fn fetch_from_memory(
        &mut self,
        core: u8,
        pa: u64,
        wants_excl: bool,
        now: Tick,
    ) {
        if self.is_cxl_addr(pa) {
            self.fetch_from_cxl(core, pa, wants_excl, now);
        } else {
            self.fetch_from_dram(core, pa, wants_excl, now);
        }
    }

    fn fetch_from_dram(
        &mut self,
        core: u8,
        pa: u64,
        wants_excl: bool,
        now: Tick,
    ) {
        let t = self.membus.transfer(now, 16);
        match self.dram.enqueue(t, pa, self.cfg.l1.line, false) {
            Some(done) => {
                self.stats.dram_reads.inc();
                let back = self.membus.transfer(done, 64);
                self.queue
                    .schedule_at(back, Ev::LineFill { core, line_pa: pa });
            }
            None => {
                self.queue.schedule_at(
                    now + ns_to_ticks(100.0),
                    Ev::DramRetry { core, line_pa: pa, wants_excl },
                );
            }
        }
    }

    fn fetch_from_cxl(
        &mut self,
        core: u8,
        pa: u64,
        wants_excl: bool,
        now: Tick,
    ) {
        if self.cfg.cxl.attach == CxlAttach::MemBus {
            // Baseline (CXL-DMSim/SimCXL style): expander hangs off the
            // membus; protocol costs collapse into a fixed adder (both
            // directions' pack+unpack + wire), no flit framing, no
            // credits, no IOBus contention. The interleave decode still
            // applies — the baseline shortcut is about the attach point,
            // not the window routing.
            let t = self.membus.transfer(now, 16);
            let (dev, dpa) = self
                .rc
                .route_dpa(pa)
                .unwrap_or((0, pa - self.bios.cxl_window_base));
            let fixed = self.dev_fixed_ticks[dev];
            let done = self.cxl_devs[dev].media.access(
                t + fixed,
                dpa,
                self.cfg.l1.line,
                false,
            );
            self.stats.cxl_reads.inc();
            self.stats.cxl_dev_reads[dev].inc();
            let back = self.membus.transfer(done, 64);
            self.queue
                .schedule_at(back, Ev::LineFill { core, line_pa: pa });
            return;
        }
        // Architecturally correct path: membus -> IOBus -> RC interleave
        // decode -> that device's link. On the IOBus attach
        // `is_cxl_addr` is exactly `rc.routes(pa)`, so the decode always
        // resolves; keep device 0 as the pre-commit fallback (never a
        // dropped request) should a future caller widen the predicate.
        let t = self.membus.transfer(now, 16);
        let t = self.iobus.transfer(t, 16);
        let dev = self.rc.route(pa).unwrap_or(0);
        let host_pkt =
            Packet::new(0, MemCmd::ReadReq, pa & !(self.cfg.l1.line - 1), 64, core, now);
        match self.rc.packetize_and_send(t, &host_pkt, dev) {
            Ok((m2s, arrival)) => {
                self.stats.cxl_reads.inc();
                self.stats.cxl_dev_reads[dev].inc();
                let (resp, ready) =
                    self.cxl_devs[dev].handle_m2s(arrival, &m2s);
                let host_done = self.rc.receive_s2m(ready, &resp, now, dev);
                let t = self.iobus.transfer(host_done, 64);
                let back = self.membus.transfer(t, 64);
                self.queue
                    .schedule_at(back, Ev::LineFill { core, line_pa: pa });
            }
            Err(retry_at) => {
                self.queue.schedule_at(
                    retry_at,
                    Ev::CxlRetry { core, line_pa: pa, wants_excl },
                );
            }
        }
    }

    /// Invalidate peer L1 copies per the directory mask; returns the
    /// added coherence latency.
    fn invalidate_peers(&mut self, mask: u64, pa: u64, now: Tick) -> Tick {
        let mut extra = 0;
        for peer in 0..self.cores.len() as u8 {
            if mask & (1 << peer) != 0 {
                self.stats.coherence_invals.inc();
                if let Some(_wb) = self.l1s[peer as usize].invalidate(pa) {
                    // Dirty copy flushes to L2 on the way out.
                    self.l2.fill(pa, MesiState::Modified);
                }
                self.dir
                    .note_evict(self.l1s[peer as usize].line_addr(pa), peer);
                extra = ns_to_ticks(2.0 * self.cfg.membus_lat_ns);
            }
        }
        let _ = now;
        extra
    }

    /// A line arrived at L2 from memory: fill L2, then distribute to the
    /// waiting cores' L1s. L2-*hit* fills carry no pending entry and
    /// must not touch L2 state (it could lose a dirty bit).
    fn memory_fill_arrived(&mut self, pa: u64, now: Tick) -> Vec<u8> {
        let key = self.l2.line_addr(pa);
        let Some(pending) = self.l2_pending.remove(&key) else {
            return Vec::new();
        };
        self.pf_book.note_fill(key);
        match self.l2.fill(pa, MesiState::Exclusive) {
            Victim::Dirty(victim_pa) => {
                self.pf_book.note_evict(self.l2.line_addr(victim_pa));
                self.writeback(victim_pa, now);
                self.inclusive_purge(victim_pa);
            }
            Victim::Clean(victim_pa) => {
                self.pf_book.note_evict(self.l2.line_addr(victim_pa));
                self.inclusive_purge(victim_pa);
            }
            Victim::None => {}
        }
        pending.cores
    }

    /// Inclusive hierarchy: an L2 eviction kills L1 copies above.
    /// The directory tells us exactly which L1s can hold the line, so
    /// this is O(sharers) rather than O(cores) (perf-pass change #3).
    fn inclusive_purge(&mut self, victim_pa: u64) {
        use crate::cache::directory::DirState;
        let line = self.l2.line_addr(victim_pa);
        let mask: u64 = match self.dir.state(line) {
            DirState::Uncached => 0,
            DirState::Owned { core } => 1 << core,
            DirState::Sharers { mask } => mask,
        };
        let mut m = mask;
        while m != 0 {
            let c = m.trailing_zeros() as usize;
            m &= m - 1;
            if let Some(_wb) = self.l1s[c].invalidate(victim_pa) {
                // Dirty L1 data above a dying L2 line goes to memory.
                self.writeback(victim_pa, self.queue.now());
            }
        }
        self.dir.purge(line);
    }

    /// Posted write-back of a dirty line to its memory class.
    fn writeback(&mut self, pa: u64, now: Tick) {
        if self.is_cxl_addr(pa) {
            self.stats.writebacks_cxl.inc();
            if self.cfg.cxl.attach == CxlAttach::MemBus {
                let t = self.membus.transfer(now, 64 + 16);
                let (dev, dpa) = self
                    .rc
                    .route_dpa(pa)
                    .unwrap_or((0, pa - self.bios.cxl_window_base));
                self.stats.cxl_dev_writebacks[dev].inc();
                self.cxl_devs[dev].media.access(
                    t,
                    dpa,
                    self.cfg.l1.line,
                    true,
                );
                return;
            }
            let Some(dev) = self.rc.route(pa) else { return };
            self.stats.cxl_dev_writebacks[dev].inc();
            let t = self.membus.transfer(now, 64 + 16);
            let t = self.iobus.transfer(t, 64 + 16);
            let host_pkt = Packet::new(
                0,
                MemCmd::WritebackDirty,
                pa & !(self.cfg.l1.line - 1),
                64,
                0,
                now,
            );
            if let Ok((m2s, arrival)) =
                self.rc.packetize_and_send(t, &host_pkt, dev)
            {
                let (resp, ready) =
                    self.cxl_devs[dev].handle_m2s(arrival, &m2s);
                // NDR completion retires the credit.
                self.rc.receive_s2m(ready, &resp, now, dev);
            }
            // On credit exhaustion the posted write is dropped from the
            // timing model (data is already functionally in physmem);
            // counted so the approximation is visible.
        } else {
            self.stats.writebacks_dram.inc();
            let t = self.membus.transfer(now, 64 + 16);
            // Posted: force-accept into the controller (write queue
            // drains are not modeled with retries).
            self.dram.timing.access(t, pa, self.cfg.l1.line, true);
        }
    }

    // ---- the issue engine ---------------------------------------------------

    fn schedule_issue(&mut self, core: u8, at: Tick) {
        if !self.issue_scheduled[core as usize] {
            self.issue_scheduled[core as usize] = true;
            self.queue.schedule_at(at.max(self.queue.now()), Ev::Issue(core));
        }
    }

    fn next_op_for(&mut self, core: usize) -> Option<WlOp> {
        if let Some(op) = self.pending_op[core].take() {
            return Some(op);
        }
        self.workloads.get_mut(core).and_then(|w| w.next_op())
    }

    fn try_issue(&mut self, core: u8, now: Tick) {
        let c = core as usize;
        if c >= self.workloads.len() || self.cores[c].done {
            return;
        }
        loop {
            if !self.cores[c].can_issue(now) {
                if !self.cores[c].done
                    && self.cores[c].lsq_free()
                    && self.cores[c].next_issue > now
                {
                    let at = self.cores[c].next_issue;
                    self.schedule_issue(core, at);
                }
                // Else: waiting on a response; completions re-trigger.
                return;
            }
            let Some(op) = self.next_op_for(c) else {
                if self.cores[c].outstanding() == 0 {
                    self.cores[c].finish(now);
                }
                return;
            };
            match op {
                WlOp::Work { cycles } => {
                    self.cores[c].do_work(now, cycles);
                }
                WlOp::Load { va, .. } | WlOp::Store { va, .. } => {
                    let is_write = matches!(op, WlOp::Store { .. });
                    // L1 MSHR structural hazard check happens in
                    // `access`; check capacity here to park the op.
                    if self.l1_mshrs[c].is_full() {
                        self.pending_op[c] = Some(op);
                        self.cores[c].note_lsq_stall();
                        return; // a LineFill will re-trigger issue
                    }
                    // Translate (may fault).
                    let guest = self.guest.as_mut().expect("booted");
                    let faults_before = self.spaces[c].stats.faults;
                    let pa = match self.spaces[c].translate(va, &mut guest.alloc)
                    {
                        Ok(pa) => pa,
                        Err(e) => {
                            log::error!("core {core}: {e}");
                            self.cores[c].finish(now);
                            return;
                        }
                    };
                    if self.spaces[c].stats.faults > faults_before {
                        self.stats.page_faults.inc();
                        self.cores[c].do_work(
                            now,
                            self.fault_ticks
                                / ns_to_ticks(self.cfg.cycle_ns()).max(1),
                        );
                    }
                    // Functional execution in program order.
                    if is_write {
                        let bits = self.workloads[c].store_value(va);
                        self.mem.write_u64(pa & !7, bits);
                    } else {
                        let bits = self.mem.read_u64(pa & !7);
                        self.workloads[c].load_done(va, bits);
                    }
                    self.access(core, pa, is_write, now);
                }
            }
        }
    }

    fn complete_line_fill(&mut self, core: u8, pa: u64, now: Tick) {
        let c = core as usize;
        let line = self.l1s[c].line_addr(pa);
        let Some(mshr) = self.l1_mshrs[c].complete(line) else {
            return; // duplicate fill (e.g. L2-hit raced a retry)
        };
        // Directory actions + fill state (applied here, at fill time).
        use crate::cache::directory::DirAction;
        let state = if mshr.wants_exclusive {
            if let DirAction::Invalidate { mask } =
                self.dir.write_req(line, core)
            {
                self.invalidate_peers(mask, pa, now);
            }
            self.dir.note_write(line, core);
            MesiState::Modified
        } else {
            if let DirAction::DowngradeOwner { core: owner } =
                self.dir.read_req(line, core)
            {
                let was_m = self.l1s[owner as usize].downgrade(pa);
                if was_m {
                    self.l2.fill(pa, MesiState::Modified);
                }
            }
            if self.dir.note_read(line, core) {
                MesiState::Exclusive
            } else {
                MesiState::Shared
            }
        };
        match self.l1s[c].fill(pa, state) {
            Victim::Dirty(victim_pa) => {
                // L1 dirty victim folds into L2.
                self.l2.fill(victim_pa, MesiState::Modified);
                self.dir.note_evict(self.l1s[c].line_addr(victim_pa), core);
            }
            Victim::Clean(victim_pa) => {
                self.dir.note_evict(self.l1s[c].line_addr(victim_pa), core);
            }
            Victim::None => {}
        }
        for req in mshr.waiters {
            self.cores[c].complete_mem(now, req);
        }
        self.try_issue(core, now);
    }

    // ---- the event loop -------------------------------------------------------

    /// Run until all attached workloads finish (or `max_ticks`).
    pub fn run(&mut self, max_ticks: Option<Tick>) -> RunSummary {
        while let Some((t, ev)) = self.queue.pop() {
            crate::util::logger::set_tick(t);
            if let Some(m) = max_ticks {
                if t > m {
                    break;
                }
            }
            match ev {
                Ev::Issue(core) => {
                    self.issue_scheduled[core as usize] = false;
                    self.try_issue(core, t);
                }
                Ev::Hit { core, req } => {
                    self.cores[core as usize].complete_mem(t, req);
                    self.try_issue(core, t);
                }
                Ev::LineFill { core, line_pa } => {
                    let cores = self.memory_fill_arrived(line_pa, t);
                    // First deliver to the requester on this event, then
                    // to any cores that merged at L2. PF_CORE marks a
                    // prefetch fill: it stops at L2 unless demand merged.
                    if core != PF_CORE {
                        self.complete_line_fill(core, line_pa, t);
                    }
                    for other in cores {
                        if other != core && other != PF_CORE {
                            self.complete_line_fill(other, line_pa, t);
                        }
                    }
                }
                Ev::DramRetry { core, line_pa, wants_excl } => {
                    self.fetch_from_dram(core, line_pa, wants_excl, t);
                }
                Ev::CxlRetry { core, line_pa, wants_excl } => {
                    self.fetch_from_cxl(core, line_pa, wants_excl, t);
                }
            }
        }
        self.summary()
    }

    pub fn summary(&self) -> RunSummary {
        let ticks = self
            .cores
            .iter()
            .map(|c| c.stats.finished_at)
            .max()
            .unwrap_or(self.queue.now())
            .max(1);
        let seconds = ticks as f64 * 1e-12;
        let bytes: u64 =
            self.workloads.iter().map(|w| w.bytes_moved()).sum();
        let l1_hits: u64 = self.l1s.iter().map(|l| l.stats.hits.get()).sum();
        let l1_miss: u64 =
            self.l1s.iter().map(|l| l.stats.misses.get()).sum();
        // Media latency averaged over every device's samples.
        let (media_sum, media_n) = self
            .cxl_devs
            .iter()
            .fold((0.0f64, 0u64), |(s, n), d| {
                let st = &d.stats.media_latency.stats;
                (s + st.sum, n + st.n)
            });
        let media_mean =
            if media_n == 0 { 0.0 } else { media_sum / media_n as f64 };
        // Protocol adder per fill, weighted by each device's share of
        // the traffic (per-device link latency may differ).
        let total_fills: u64 =
            self.stats.cxl_dev_reads.iter().map(|c| c.get()).sum();
        let proto_ns = if total_fills == 0 {
            2.0 * (self.cfg.cxl.pkt_lat_ns + self.cfg.cxl.depkt_lat_ns)
                + 2.0 * self.cfg.cxl.link_lat_ns
        } else {
            self.stats
                .cxl_dev_reads
                .iter()
                .enumerate()
                .map(|(i, c)| {
                    c.get() as f64
                        * (2.0
                            * (self.cfg.cxl.pkt_lat_ns
                                + self.cfg.cxl.depkt_lat_ns)
                            + 2.0 * self.cfg.cxl.path_lat_ns(i))
                })
                .sum::<f64>()
                / total_fills as f64
        };
        RunSummary {
            ticks,
            seconds,
            bytes_moved: bytes,
            bandwidth_gbps: bytes as f64 / seconds / 1e9,
            l1_miss_rate: if l1_hits + l1_miss == 0 {
                0.0
            } else {
                l1_miss as f64 / (l1_hits + l1_miss) as f64
            },
            l2_miss_rate: self.l2.stats.miss_rate(),
            dram_accesses: self.stats.dram_reads.get(),
            cxl_accesses: self.stats.cxl_reads.get(),
            cxl_dev_fills: self
                .stats
                .cxl_dev_reads
                .iter()
                .map(|c| c.get())
                .collect(),
            avg_lat_dram_ns: self.dram.timing.stats.latency.stats.mean()
                / 1000.0,
            avg_lat_cxl_ns: media_mean / 1000.0 + proto_ns,
            m2s_req: self.rc.agg_link(|s| s.m2s_req.get()),
            m2s_rwd: self.rc.agg_link(|s| s.m2s_rwd.get()),
            s2m_ndr: self.rc.agg_link(|s| s.s2m_ndr.get()),
            s2m_drs: self.rc.agg_link(|s| s.s2m_drs.get()),
            events: self.queue.processed(),
        }
    }

    /// Read access to an attached workload (coordinator hooks).
    pub fn workload(&self, i: usize) -> Option<&dyn Workload> {
        self.workloads.get(i).map(|b| b.as_ref())
    }

    /// Verify all workloads' functional results.
    pub fn verify(&mut self) -> Result<(), String> {
        let guest = self.guest.as_mut().ok_or("not booted")?;
        for (i, w) in self.workloads.iter().enumerate() {
            w.verify(&mut self.spaces[i], &mut guest.alloc, &self.mem)?;
        }
        Ok(())
    }

    pub fn dump_stats(&self) -> StatDump {
        let mut d = StatDump::default();
        for (i, c) in self.cores.iter().enumerate() {
            c.dump(&format!("core{i}"), &mut d);
        }
        for (i, l) in self.l1s.iter().enumerate() {
            l.stats.dump(&format!("l1.{i}"), &mut d);
        }
        self.l2.stats.dump("l2", &mut d);
        self.membus.dump("membus", &mut d);
        self.iobus.dump("iobus", &mut d);
        self.dram.timing.dump("dram", &mut d);
        self.rc.dump("cxl.rc", &mut d);
        for (j, sw) in self.rc.switches.iter().enumerate() {
            sw.dump(&format!("cxl.sw{j}"), &mut d);
        }
        for (i, dev) in self.cxl_devs.iter().enumerate() {
            dev.dump(&format!("cxl.dev{i}"), &mut d);
            d.counter(
                &format!("cxl.dev{i}.fills"),
                &self.stats.cxl_dev_reads[i],
            );
            d.counter(
                &format!("cxl.dev{i}.writebacks"),
                &self.stats.cxl_dev_writebacks[i],
            );
        }
        if let Some(p) = &self.prefetcher {
            crate::cache::prefetch::dump(p, "l2.pf", &mut d);
        }
        d.counter("sys.page_faults", &self.stats.page_faults);
        d.counter("sys.coherence_invals", &self.stats.coherence_invals);
        d.counter("sys.writebacks_dram", &self.stats.writebacks_dram);
        d.counter("sys.writebacks_cxl", &self.stats.writebacks_cxl);
        d.push("sys.events", self.queue.processed() as f64);
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CpuModel;
    use crate::workloads::{Stream, StreamKernel};

    fn small_cfg() -> SimConfig {
        let mut c = SimConfig::default();
        c.cores = 2;
        c.sys_mem_size = 256 << 20;
        c.cxl.mem_size = 256 << 20;
        c
    }

    fn booted(cfg: SimConfig) -> Machine {
        let mut m = Machine::new(cfg).unwrap();
        m.boot(ProgModel::Znuma).unwrap();
        m
    }

    #[test]
    fn boot_onlines_znuma_node() {
        let m = booted(small_cfg());
        let g = m.guest.as_ref().unwrap();
        assert_eq!(g.znuma_node(), Some(1));
        assert!(g.alloc.nodes[1].online);
        assert!(!g.alloc.nodes[1].has_cpus);
        assert_eq!(g.memdevs.len(), 1);
        // RC routing mirrors the committed decoder.
        assert!(m.rc.routes(m.bios.cxl_window_base));
    }

    #[test]
    fn two_device_interleave_routes_across_both() {
        let mut cfg = small_cfg();
        cfg.cxl.devices = 2;
        let mut m = booted(cfg);
        let g = m.guest.as_ref().unwrap();
        assert_eq!(g.memdevs.len(), 2);
        assert_eq!(g.cxl_nodes, vec![1], "one interleaved zNUMA node");
        assert_eq!(g.alloc.nodes[1].size, 512 << 20, "2 x 256 MiB window");
        let wl = Stream::new(StreamKernel::Copy, 16384, 1);
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![1] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.cxl_accesses > 0);
        assert_eq!(s.cxl_dev_fills.len(), 2);
        assert!(
            s.cxl_dev_fills.iter().all(|&f| f > 0),
            "every device must serve fills: {:?}",
            s.cxl_dev_fills
        );
        // 256 B granules over 64 B lines: near-even split.
        let (a, b) = (s.cxl_dev_fills[0] as f64, s.cxl_dev_fills[1] as f64);
        assert!((a / b - 1.0).abs() < 0.2, "split {a} vs {b}");
        m.verify().unwrap();
    }

    #[test]
    fn separate_windows_expose_separate_znuma_nodes() {
        let mut cfg = small_cfg();
        cfg.cxl.devices = 2;
        cfg.cxl.interleave_ways = 1; // two single-device windows
        let mut m = booted(cfg);
        let g = m.guest.as_ref().unwrap();
        assert_eq!(g.cxl_nodes, vec![1, 2]);
        assert!(g.alloc.nodes[2].online && !g.alloc.nodes[2].has_cpus);
        // Binding to node 2 exercises only device 1.
        let wl = Stream::new(StreamKernel::Copy, 4096, 1);
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![2] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.cxl_dev_fills[1] > 0);
        assert_eq!(s.cxl_dev_fills[0], 0);
        m.verify().unwrap();
    }

    #[test]
    fn switched_topology_boots_and_contends_upstream() {
        let mut cfg = small_cfg();
        cfg.cxl.devices = 2;
        cfg.cxl.switches = 1;
        let mut m = booted(cfg);
        {
            let g = m.guest.as_ref().unwrap();
            assert_eq!(g.memdevs.len(), 2);
            assert_eq!(g.cxl_nodes, vec![1, 2], "one node per endpoint");
            // Both endpoints bound to the same (single) host bridge.
            assert_eq!(g.memdevs[0].hb_uid, g.memdevs[1].hb_uid);
        }
        let a = Stream::new(StreamKernel::Copy, 8192, 1);
        let b = Stream::new(StreamKernel::Copy, 8192, 1);
        m.attach_workloads(
            vec![Box::new(a), Box::new(b)],
            &MemPolicy::Interleave { weights: vec![(1, 1), (2, 1)] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.cxl_dev_fills.iter().all(|&f| f > 0));
        // Every flit crossed the shared upstream link.
        let sw = &m.rc.switches[0];
        assert_eq!(
            sw.stats.m2s_forwarded.get(),
            s.m2s_req + s.m2s_rwd,
            "all M2S traffic must be forwarded upstream"
        );
        let d = m.dump_stats();
        assert!(d.get("cxl.sw0.us_link.flits").unwrap() > 0.0);
        m.verify().unwrap();
    }

    #[test]
    fn mld_onlines_one_node_per_ld() {
        let mut cfg = small_cfg();
        cfg.cxl.mem_size = 512 << 20;
        cfg.cxl.dev_overrides =
            vec![crate::config::CxlDevOverride {
                lds: Some(2),
                ..Default::default()
            }];
        let mut m = booted(cfg);
        {
            let g = m.guest.as_ref().unwrap();
            assert_eq!(g.memdevs.len(), 2, "one memdev per LD");
            assert_eq!(g.memdevs[0].bdf, g.memdevs[1].bdf);
            assert_eq!((g.memdevs[0].ld, g.memdevs[1].ld), (0, 1));
            assert_eq!(g.cxl_nodes, vec![1, 2]);
            assert_eq!(g.alloc.nodes[1].size, 256 << 20);
            assert_eq!(g.alloc.nodes[2].size, 256 << 20);
        }
        // Traffic bound to node 2 exercises only LD 1's slice.
        let wl = Stream::new(StreamKernel::Copy, 4096, 1);
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![2] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.cxl_accesses > 0);
        assert_eq!(m.cxl_devs[0].stats.ld_reads[0].get(), 0);
        assert!(m.cxl_devs[0].stats.ld_reads[1].get() > 0);
        let d = m.dump_stats();
        assert!(d.get("cxl.dev0.ld1.reads").unwrap() > 0.0);
        m.verify().unwrap();
    }

    #[test]
    fn stream_on_dram_runs_and_verifies() {
        let mut m = booted(small_cfg());
        let wl = Stream::new(StreamKernel::Copy, 4096, 1);
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![0] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.ticks > 0);
        assert!(s.cxl_accesses == 0, "bind:0 must not touch CXL");
        assert!(s.dram_accesses > 0);
        m.verify().unwrap();
    }

    #[test]
    fn stream_on_cxl_goes_through_link() {
        let mut m = booted(small_cfg());
        let wl = Stream::new(StreamKernel::Copy, 4096, 1);
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![1] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.cxl_accesses > 0);
        assert!(s.m2s_req > 0, "M2S requests must cross the link");
        assert!(s.s2m_drs > 0, "read data must return on DRS");
        m.verify().unwrap();
    }

    #[test]
    fn cxl_slower_than_dram() {
        let run = |node: u32| {
            let mut m = booted(small_cfg());
            let wl = Stream::new(StreamKernel::Triad, 8192, 1);
            m.attach_workloads(
                vec![Box::new(wl)],
                &MemPolicy::Bind { nodes: vec![node] },
            )
            .unwrap();
            m.run(None).ticks
        };
        let dram = run(0);
        let cxl = run(1);
        assert!(
            cxl > dram * 11 / 10,
            "CXL ({cxl}) must be slower than DRAM ({dram})"
        );
    }

    #[test]
    fn interleave_splits_traffic() {
        let mut m = booted(small_cfg());
        let wl = Stream::new(StreamKernel::Copy, 16384, 1);
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Interleave { weights: vec![(0, 1), (1, 1)] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.dram_accesses > 0 && s.cxl_accesses > 0);
        let ratio = s.dram_accesses as f64 / s.cxl_accesses as f64;
        assert!((0.5..2.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn deterministic_across_runs() {
        let go = || {
            let mut m = booted(small_cfg());
            let wl = Stream::new(StreamKernel::Add, 2048, 1);
            m.attach_workloads(
                vec![Box::new(wl)],
                &MemPolicy::Interleave { weights: vec![(0, 3), (1, 1)] },
            )
            .unwrap();
            let s = m.run(None);
            (s.ticks, s.events, s.dram_accesses, s.cxl_accesses)
        };
        assert_eq!(go(), go());
    }

    #[test]
    fn two_cores_share_l2() {
        let mut m = booted(small_cfg());
        let a = Stream::new(StreamKernel::Copy, 2048, 1);
        let b = Stream::new(StreamKernel::Copy, 2048, 1);
        m.attach_workloads(
            vec![Box::new(a), Box::new(b)],
            &MemPolicy::Bind { nodes: vec![0] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.ticks > 0);
        assert!(m.cores.iter().all(|c| c.done));
        m.verify().unwrap();
    }

    #[test]
    fn membus_attach_baseline_skips_protocol() {
        let mut cfg = small_cfg();
        cfg.cxl.attach = CxlAttach::MemBus;
        let mut m = booted(cfg);
        let wl = Stream::new(StreamKernel::Copy, 4096, 1);
        m.attach_workloads(
            vec![Box::new(wl)],
            &MemPolicy::Bind { nodes: vec![1] },
        )
        .unwrap();
        let s = m.run(None);
        assert!(s.cxl_accesses > 0);
        assert_eq!(s.m2s_req, 0, "baseline must bypass the CXL.mem layer");
    }

    #[test]
    fn o3_faster_than_inorder_on_misses() {
        let run = |model: CpuModel| {
            let mut cfg = small_cfg();
            cfg.cpu_model = model;
            let mut m = booted(cfg);
            let wl = Stream::new(StreamKernel::Copy, 8192, 1);
            m.attach_workloads(
                vec![Box::new(wl)],
                &MemPolicy::Bind { nodes: vec![0] },
            )
            .unwrap();
            m.run(None).ticks
        };
        let o3 = run(CpuModel::OutOfOrder);
        let inorder = run(CpuModel::InOrder);
        assert!(
            o3 < inorder,
            "O3 ({o3}) must beat in-order ({inorder}) via MLP"
        );
    }
}
