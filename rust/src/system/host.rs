//! One simulated host: cores, private caches, directory, buses, DRAM,
//! firmware image and guest OS — everything on the CPU side of the CXL
//! fabric boundary.
//!
//! A [`Host`] owns the per-host halves of the old monolithic machine:
//! the BIOS tables live in *its* physical memory, the PCIe/ECAM view,
//! host-bridge component registers and the root complex (HDM routing
//! windows + packetizer) are its hardware, and the event-driven memory
//! path runs against its caches. What it does **not** own is the CXL
//! tree below the root ports — devices, switches and links live in the
//! shared [`crate::cxl::Fabric`].
//!
//! # Split-phase event loop
//!
//! Since the rack-scale parallel scheduler, each host owns its **own**
//! event queue and never touches the fabric directly from the timing
//! path. Host-local events (issue, hits, fills, retries) are dispatched
//! from [`Host::drain_to`]; anything that must cross the fabric
//! boundary (a CXL fetch or write-back) is *emitted* as a
//! [`FabricReq`] into the host's outbox instead of being timed inline.
//! The machine merges every host's outbox into one globally ordered
//! `(tick, host, seq)` map and commits the requests against the shared
//! fabric on the main thread, which is what keeps `threads = N` runs
//! bit-identical to serial ones: fabric state only ever mutates in that
//! canonical order, regardless of which worker thread ran which host.
//!
//! The host self-throttles while draining: once it has emitted a
//! request at tick `e`, it stops processing local events beyond
//! `e + lookahead - 1`, where the lookahead is the minimum fixed
//! round-trip latency to any device it can reach (packetize + path +
//! de-packetize, both ways). No response can arrive earlier than that,
//! so the host never runs past a tick at which new input could still
//! appear — the conservative-parallel (null-message) invariant. The
//! machine applies the same bound across epochs for requests that are
//! still pending in the global map.
//!
//! The host also carries the per-host half of **runtime FM re-binding**
//! (`docs/ARCHITECTURE.md` has the full flow): before the fabric
//! manager takes a logical device away, [`Host::has_inflight_in`]
//! gates the unbind until every outstanding fetch to the departing
//! window has drained — parked credit retries included — so packets to
//! an unbinding LD complete (or retry onto the still-committed window)
//! deterministically, never route into a hole. Hot add/remove shows up
//! in the per-host stats as `sys.mem_online_events` /
//! `sys.mem_offline_events`.

use anyhow::{Context, Result};

use crate::bios::{self, layout, BiosInfo};
use crate::bus::Bus;
use crate::cache::prefetch::{PrefetchBook, StridePrefetcher};
use crate::cache::{Access, CacheArray, Directory, MesiState, MshrAlloc,
                   MshrFile, Victim};
use crate::config::{CxlAttach, SimConfig};
use crate::cpu::{Core, WlOp};
use crate::cxl::mem_proto::{self, CxlMemPacket};
use crate::cxl::regs::ComponentRegs;
use crate::cxl::CxlRootComplex;
use crate::guestos::{AddressSpace, GuestOs, MemPolicy};
use crate::mem::{MemCtrl, PhysMem};
use crate::pcie::{self, config_space as cs, Bdf, Ecam};
use crate::sim::{ns_to_ticks, EventQueue, MemCmd, Packet, ReqId, Tick};
use crate::stats::{Counter, Histogram, StatDump};
use crate::workloads::{WlStat, Workload};

/// Host-local events (only async points become events — see module
/// docs). Machine-level events (FM actions, policy epochs) live in the
/// machine's own queue, not here.
#[derive(Debug)]
pub(crate) enum Ev {
    /// Core front-end tries to issue.
    Issue(u8),
    /// A request completed without a line fill (L1 hit / upgrade).
    Hit { core: u8, req: ReqId },
    /// A line fill arrived at a core's L1.
    LineFill { core: u8, line_pa: u64 },
    /// DRAM controller queue was full — retry the fetch.
    DramRetry { core: u8, line_pa: u64, wants_excl: bool },
    /// L1 MSHR file was full when the miss arrived — the op is parked
    /// (request stays live in the core's LSQ) and re-probes later.
    MshrRetry { core: u8, pa: u64, is_write: bool, req: ReqId },
    /// A CXL response committed on the fabric landed back at this host
    /// (delivered by the machine's commit phase): de-packetized data is
    /// at the root complex / membus edge, ready to travel up to L2.
    CxlFill { core: u8, line_pa: u64, issued_at: Tick },
    /// A device-initiated S2M back-invalidate snoop (CXL 3.x BISnp)
    /// landed: another sharer host claimed the line at `dpa` on shared
    /// device `dev`. The host drops its cached copies and answers with
    /// an M2S BIRsp fabric request (dirty data rides the response).
    BiInv { dev: usize, dpa: u64 },
}

/// A fabric-crossing request emitted by a host's timing path. The
/// machine commits these against the shared [`crate::cxl::Fabric`] in
/// global `(tick, host, seq)` order — the only place fabric state
/// mutates, in both serial and parallel runs.
#[derive(Debug)]
pub(crate) enum FabricReq {
    /// IOBus-attach line fetch: an already-packetized M2S read heading
    /// for device `dev`'s fabric path.
    Fetch {
        dev: usize,
        pkt: CxlMemPacket,
        core: u8,
        line_pa: u64,
        issued_at: Tick,
    },
    /// IOBus-attach posted write-back (NDR completion retires the
    /// credit; no host-visible response).
    Writeback { dev: usize, pkt: CxlMemPacket },
    /// MemBus-baseline line fetch: straight to device media, protocol
    /// collapsed into the host's fixed adder.
    MediaFetch { dev: usize, dpa: u64, core: u8, line_pa: u64 },
    /// MemBus-baseline posted write-back.
    MediaWriteback { dev: usize, dpa: u64 },
    /// Answer to a device BISnp: the host invalidated its copies of the
    /// shared line at `dpa` and acks on the dedicated uncredited BI
    /// channel (`dirty` = a Modified copy rides home with the ack).
    BiRsp { dev: usize, pkt: CxlMemPacket, dpa: u64, dirty: bool },
}

impl FabricReq {
    /// The routed target device — fixed at enqueue time (the RC's
    /// interleave decoder already ran), which is what lets the machine
    /// partition pending entries into per-device commit lanes without
    /// touching fabric state.
    pub(crate) fn dev(&self) -> usize {
        match self {
            FabricReq::Fetch { dev, .. }
            | FabricReq::Writeback { dev, .. }
            | FabricReq::MediaFetch { dev, .. }
            | FabricReq::MediaWriteback { dev, .. }
            | FabricReq::BiRsp { dev, .. } => *dev,
        }
    }
}

/// Sentinel "core" marking an L2-prefetch fetch: the fill stops at L2.
const PF_CORE: u8 = u8::MAX;

/// Slack subtracted from the fixed-path lookahead: the per-term
/// `ns_to_ticks` roundings along a committed response path (pkt/depkt
/// both ways + up to three link-latency terms each way) can each lose
/// up to half a tick against the single combined rounding the horizon
/// is derived from. 16 ticks (16 ps) over-covers the worst case while
/// staying negligible against real horizons (tens of ns).
const LOOKAHEAD_ROUNDING_MARGIN: Tick = 16;

/// Per-L2-line in-flight memory fetch (cores waiting on it).
#[derive(Debug, Default)]
struct L2Pending {
    cores: Vec<u8>,
    wants_excl: bool,
}

/// Per-host counters (kept under the historic name: with one host this
/// IS the machine's stat block).
#[derive(Clone, Debug, Default)]
pub struct MachineStats {
    pub dram_reads: Counter,
    pub cxl_reads: Counter,
    pub lat_dram: Histogram,
    pub lat_cxl: Histogram,
    pub page_faults: Counter,
    pub upgrades: Counter,
    pub coherence_invals: Counter,
    pub writebacks_dram: Counter,
    pub writebacks_cxl: Counter,
    /// Per-device line fills served to THIS host (indexed by device).
    pub cxl_dev_reads: Vec<Counter>,
    /// Per-device write-backs from this host.
    pub cxl_dev_writebacks: Vec<Counter>,
    /// Misses parked on a full L1 MSHR file and retried.
    pub mshr_retries: Counter,
    /// zNUMA windows hot-added to this host at runtime (FM bind).
    pub mem_online_events: Counter,
    /// zNUMA windows hot-removed from this host at runtime (FM unbind).
    pub mem_offline_events: Counter,
    /// FM unbind requests this host's guest refused (pages in use).
    pub mem_offline_refused: Counter,
    /// FM unbinds deferred because requests to the departing window
    /// were still in flight (quiesce-and-retry).
    pub fm_quiesce_retries: Counter,
    /// Dirty evictions to addresses no routed window backs any more
    /// (their CXL window was hot-removed) — dropped from the timing
    /// model, data already functionally in memory.
    pub writebacks_unmapped: Counter,
    /// Device BISnps processed: cached copies of a shared line dropped
    /// because another sharer host claimed it.
    pub bi_invalidations: Counter,
}

pub struct Host {
    /// This host's id on the fabric (tag in the global commit order).
    pub id: u8,
    /// Construction-time snapshot of the machine config. Knobs are
    /// consumed at build time (latencies, geometries and the decode
    /// tables are all precomputed from it), so — exactly as before the
    /// host/fabric split — mutate the config and rebuild the machine
    /// rather than editing this copy.
    pub cfg: SimConfig,
    pub mem: PhysMem,
    pub ecam: Ecam,
    /// Endpoint BDFs, one per expander device (this host's view of the
    /// shared fabric endpoints).
    pub ep_bdfs: Vec<Bdf>,
    pub bios: BiosInfo,
    /// Host-bridge component blocks, one per bridge.
    pub hb_components: Vec<ComponentRegs>,
    /// Host-side CXL protocol entity: routing windows + packetizer.
    pub rc: CxlRootComplex,
    pub guest: Option<GuestOs>,

    pub cores: Vec<Core>,
    pub l1s: Vec<CacheArray>,
    pub l1_mshrs: Vec<MshrFile>,
    pub l2: CacheArray,
    pub dir: Directory,
    pub membus: Bus,
    pub iobus: Bus,
    pub dram: MemCtrl,

    issue_scheduled: Vec<bool>,
    pending_op: Vec<Option<WlOp>>,
    workloads: Vec<Box<dyn Workload>>,
    pub spaces: Vec<AddressSpace>,
    l2_pending: crate::util::fxhash::FxHashMap<u64, L2Pending>,
    next_req: ReqId,
    l1_lat: Tick,
    l2_lat: Tick,
    /// Fixed protocol adder per device (pack + unpack both ways +
    /// wire), precomputed so the hot path is an index. Times the
    /// MemBus-baseline media path and floors the parallel lookahead.
    dev_fixed_ticks: Vec<Tick>,
    fault_ticks: Tick,
    pub prefetcher: Option<StridePrefetcher>,
    pub pf_book: PrefetchBook,
    pub stats: MachineStats,

    /// This host's private event queue (split-phase loop; see module
    /// docs). `(tick, seq)` order within the queue is host-local.
    queue: EventQueue<Ev>,
    /// Fabric-crossing requests emitted since the machine last drained
    /// [`Host::outbox_mut`], as `(entry tick, per-host seq, request)`.
    outbox: Vec<(Tick, u64, FabricReq)>,
    /// Monotonic per-host sequence for outbox entries: the global
    /// commit order's tie-breaker within one host and tick.
    fab_seq: u64,
    /// Conservative horizon: no fabric response can land fewer than
    /// this many ticks after its request's fabric-entry tick.
    lookahead: Tick,
    /// Test hook: pinned lookahead overriding the derived one
    /// ([`Host::force_lookahead`]). A too-large pin breaks causality,
    /// which the queue's scheduling debug-assertion then catches.
    lookahead_override: Option<Tick>,
    /// Earliest fabric-entry tick emitted during the current drain
    /// (`Tick::MAX` when nothing was emitted yet).
    emit_floor: Tick,
    /// Host-physical `(base, size)` of every published window this host
    /// shares with at least one other host (BI-coherent addresses).
    shared_ranges: Vec<(u64, u64)>,
    /// Shared line addresses this host holds exclusively (RFO granted,
    /// not yet written back or back-invalidated). A store to a shared
    /// line outside this set must take the RFO miss path even on a
    /// local cache hit — the device's snoop filter is the only
    /// authority on who else caches the line.
    owned_lines: std::collections::BTreeSet<u64>,
    /// Membus-edge delay between a BISnp landing and its BIRsp entering
    /// the fabric; equals the machine's `d_min` so every emission keeps
    /// the conservative-parallel w-invariant (emit tick >= event tick
    /// + d_min is never required, but response tick >= event tick + 1
    /// membus hop is what the commit-horizon proof uses).
    bi_rsp_delay: Tick,
}

impl Host {
    /// Build host `id`'s hardware: BIOS tables (publishing only the
    /// CXL windows `window_sharers` assigns to this host — a shared
    /// window lists several sharer hosts and is published on each —
    /// placed from `first_window_base` up so bases are fabric-globally
    /// unique), the PCIe/ECAM view of the shared endpoints, and the
    /// CPU-side memory system. `cfg` must already be validated.
    pub(crate) fn new(
        cfg: &SimConfig,
        id: u8,
        first_window_base: u64,
        window_sharers: &[Vec<usize>],
    ) -> Result<Host> {
        let mut mem = PhysMem::new();
        // With runtime FM dynamics (an `[fm] events` schedule or an
        // `[fm] policy`), firmware publishes EVERY window to every host
        // (the hot-plug layout: one CFMWS + SRAT hotplug domain per
        // logical device, still at per-host disjoint bases); the guest
        // onlines only the LDs bound to it and keeps the rest as its
        // hot-add pool. Otherwise only this host's bound windows are
        // described — the PR-3 static layout.
        let my_defs: Vec<usize> = if !cfg.fm_dynamic() {
            window_sharers
                .iter()
                .enumerate()
                .filter(|(_, sharers)| sharers.contains(&(id as usize)))
                .map(|(i, _)| i)
                .collect()
        } else {
            (0..window_sharers.len()).collect()
        };
        let bios = bios::build_with(cfg, &mut mem, &my_defs, first_window_base);

        let mut ecam = Ecam::new(bios.ecam_base, layout::ECAM_BUSES);
        let n_dev = cfg.cxl.devices;
        let n_bridges = cfg.cxl.bridges();
        let ep_bdfs = if cfg.cxl.switches > 0 {
            let groups: Vec<usize> = (0..cfg.cxl.switches)
                .map(|j| cfg.cxl.switch(j).ndev)
                .collect();
            let (_hb, _sw, eps) =
                pcie::build_topology_switched(&mut ecam, &groups);
            eps
        } else {
            let (_hb, _rps, eps) = pcie::build_topology_n(&mut ecam, n_dev);
            eps
        };
        for (i, &ep_bdf) in ep_bdfs.iter().enumerate() {
            let dev_size = cfg.cxl.device(i).mem_size;
            let epc = ecam.function_mut(ep_bdf).unwrap();
            epc.add_bar64(0, 1 << 16); // component registers
            epc.add_bar64(2, 1 << 12); // device registers (mailbox)
            epc.add_dvsec(
                cs::DVSEC_CXL_DEVICE,
                &crate::cxl::regs::dvsec_payload::cxl_device(dev_size),
            );
            epc.add_dvsec(
                cs::DVSEC_GPF_DEVICE,
                &crate::cxl::regs::dvsec_payload::gpf_device(),
            );
            epc.add_dvsec(
                cs::DVSEC_FLEXBUS_PORT,
                &crate::cxl::regs::dvsec_payload::flexbus_port(),
            );
            epc.add_dvsec(
                cs::DVSEC_REGISTER_LOCATOR,
                &crate::cxl::regs::dvsec_payload::register_locator(&[
                    (0, crate::cxl::regs::dev_block_ids::COMPONENT, 0),
                    (2, crate::cxl::regs::dev_block_ids::DEVICE, 0),
                ]),
            );
        }

        let cores = (0..cfg.cores).map(|i| Core::new(i as u8, cfg)).collect();
        let l1s = (0..cfg.cores).map(|_| CacheArray::new(&cfg.l1)).collect();
        let l1_mshrs =
            (0..cfg.cores).map(|_| MshrFile::new(cfg.l1.mshrs)).collect();
        let l2 = CacheArray::new(&cfg.l2);
        let membus =
            Bus::new("membus", cfg.membus_lat_ns, cfg.membus_bw_gbps, 2);
        let iobus = Bus::new("iobus", cfg.iobus_lat_ns, cfg.iobus_bw_gbps, 1);
        let dram = MemCtrl::new(&cfg.sys_dram, 64);
        let rc = CxlRootComplex::new(&cfg.cxl);
        // One component block per host bridge, with one HDM decoder per
        // window it decodes (one per LD of each device beneath it).
        let hb_components = (0..n_bridges)
            .map(|b| {
                let decoders: usize = (0..n_dev)
                    .filter(|&i| cfg.cxl.bridge_of(i) == b)
                    .map(|i| cfg.cxl.device(i).lds)
                    .sum();
                ComponentRegs::new(decoders.max(1))
            })
            .collect();

        let l1_lat = ns_to_ticks(cfg.l1.lat_cycles as f64 * cfg.cycle_ns());
        let l2_lat = ns_to_ticks(cfg.l2.lat_cycles as f64 * cfg.cycle_ns());
        let dev_fixed_ticks = (0..n_dev)
            .map(|i| {
                ns_to_ticks(
                    2.0 * (cfg.cxl.pkt_lat_ns + cfg.cxl.depkt_lat_ns)
                        + 2.0 * cfg.cxl.path_lat_ns(i),
                )
            })
            .collect();
        let prefetcher = cfg
            .l2
            .prefetch
            .then(|| StridePrefetcher::new(256, cfg.l2.pf_degree));
        // Which published windows are BI-coherent on this host: the
        // window's sharer list names this host AND at least one other.
        let shared_ranges: Vec<(u64, u64)> = bios
            .cxl_window_defs
            .iter()
            .zip(bios.cxl_windows.iter())
            .filter(|(&d, _)| {
                window_sharers[d].len() > 1
                    && window_sharers[d].contains(&(id as usize))
            })
            .map(|(_, &(base, size))| (base, size))
            .collect();
        let mut host = Host {
            id,
            issue_scheduled: vec![false; cfg.cores],
            pending_op: vec![None; cfg.cores],
            spaces: Vec::new(),
            stats: MachineStats {
                cxl_dev_reads: vec![Counter::default(); n_dev],
                cxl_dev_writebacks: vec![Counter::default(); n_dev],
                ..Default::default()
            },
            cfg: cfg.clone(),
            mem,
            ecam,
            ep_bdfs,
            bios,
            hb_components,
            rc,
            guest: None,
            cores,
            l1s,
            l1_mshrs,
            l2,
            dir: Directory::new(),
            membus,
            iobus,
            dram,
            workloads: Vec::new(),
            l2_pending: Default::default(),
            next_req: 1,
            l1_lat,
            l2_lat,
            dev_fixed_ticks,
            fault_ticks: ns_to_ticks(300.0),
            prefetcher,
            pf_book: PrefetchBook::default(),
            queue: EventQueue::new(),
            outbox: Vec::new(),
            fab_seq: 0,
            lookahead: 1,
            lookahead_override: None,
            emit_floor: Tick::MAX,
            shared_ranges,
            owned_lines: std::collections::BTreeSet::new(),
            bi_rsp_delay: ns_to_ticks(cfg.membus_lat_ns) + 1,
        };
        host.recompute_lookahead();
        Ok(host)
    }

    #[inline]
    fn sched(&mut self, at: Tick, ev: Ev) {
        self.queue.schedule_at(at, ev);
    }

    /// Queue a fabric-crossing request entering the fabric at `at`.
    /// Tightens the drain throttle: local time must not pass
    /// `at + lookahead - 1` until the machine has committed the request
    /// (its response can land as early as `at + lookahead`).
    fn emit(&mut self, at: Tick, req: FabricReq) {
        self.emit_floor = self.emit_floor.min(at);
        let seq = self.fab_seq;
        self.fab_seq += 1;
        self.outbox.push((at, seq, req));
    }

    // ---- the split-phase epoch API (driven by system::Machine) ------------

    /// Apply fabric responses delivered by the machine's commit phase,
    /// then drain local events up to `cap` (inclusive), self-throttled
    /// by the lookahead horizon. Drains `inbox` in place (the caller
    /// keeps the allocation — the machine reuses one buffer per host
    /// across every epoch). Returns the number of events dispatched.
    pub(crate) fn epoch_step(
        &mut self,
        cap: Tick,
        inbox: &mut Vec<(Tick, Ev)>,
    ) -> u64 {
        for (at, ev) in inbox.drain(..) {
            // `at >= queue.now()` by the lookahead argument; the queue
            // debug-asserts it ("scheduling into the past"), which is
            // exactly what trips when a test pins a too-large horizon.
            self.queue.schedule_at(at, ev);
        }
        self.drain_to(cap)
    }

    /// Dispatch local events in `(tick, seq)` order while their tick is
    /// within `cap` AND within `emitted + lookahead - 1` of the oldest
    /// fabric request emitted during this drain (conservative-parallel
    /// self-throttle; see module docs).
    pub(crate) fn drain_to(&mut self, cap: Tick) -> u64 {
        self.emit_floor = Tick::MAX;
        let before = self.queue.processed();
        while let Some(t) = self.queue.next_tick() {
            let lim = if self.emit_floor == Tick::MAX {
                cap
            } else {
                cap.min(
                    self.emit_floor
                        .saturating_add(self.lookahead)
                        .saturating_sub(1),
                )
            };
            if t > lim {
                break;
            }
            let (t, ev) = self.queue.pop().unwrap();
            crate::util::logger::set_tick(t);
            self.dispatch(ev, t);
        }
        self.queue.processed() - before
    }

    /// The emitted fabric requests, for the machine to drain (or swap
    /// against a recycled buffer — the host never inspects past
    /// entries, only pushes).
    pub(crate) fn outbox_mut(
        &mut self,
    ) -> &mut Vec<(Tick, u64, FabricReq)> {
        &mut self.outbox
    }

    /// Tick of this host's next local event, if any.
    pub(crate) fn next_event_tick(&self) -> Option<Tick> {
        self.queue.next_tick()
    }

    /// This host's local clock (tick of the last dispatched event).
    pub(crate) fn queue_now(&self) -> Tick {
        self.queue.now()
    }

    /// Events this host has dispatched over its lifetime.
    pub(crate) fn events_processed(&self) -> u64 {
        self.queue.processed()
    }

    // ---- invariant-checker surface (sim::invariants) ----------------------

    /// Outstanding fetch MSHRs: every in-flight demand/prefetch fetch
    /// holds an `l2_pending` entry from issue until its fill lands, so
    /// the checker's quiesce rule RT-1 demands zero once the machine
    /// has drained.
    pub(crate) fn inflight_fetches(&self) -> usize {
        self.l2_pending.len()
    }

    /// Fabric requests emitted but not yet drained by the machine
    /// (RT-1: must be zero at quiesce).
    pub(crate) fn outbox_len(&self) -> usize {
        self.outbox.len()
    }

    /// Shared lines this host currently holds exclusively, in address
    /// order (BTreeSet storage order — deterministic). Rule SF-1
    /// checks each against the owning device's snoop filter.
    pub(crate) fn owned_shared_lines(
        &self,
    ) -> impl Iterator<Item = u64> + '_ {
        self.owned_lines.iter().copied()
    }

    /// Derive the conservative lookahead horizon from the bound
    /// topology: the minimum fixed round-trip cost (packetize + path +
    /// de-packetize, both ways) over every device this host can reach,
    /// minus a rounding margin. Bound-LD changes (FM re-binds) change
    /// the reachable set, so the machine re-derives horizons at every
    /// section boundary. With no reachable device nothing can ever come
    /// back: the horizon is unbounded.
    pub fn recompute_lookahead(&mut self) {
        if let Some(la) = self.lookahead_override {
            self.lookahead = la.max(1);
            return;
        }
        let min_fixed = if self.cfg.cxl.attach == CxlAttach::MemBus {
            // The baseline window routes by interleave decode but may
            // also fall back to device 0: every device is reachable.
            self.dev_fixed_ticks.iter().copied().min()
        } else {
            self.rc
                .windows()
                .iter()
                .flat_map(|w| w.targets.iter().copied())
                .map(|dev| self.dev_fixed_ticks[dev])
                .min()
        };
        self.lookahead = match min_fixed {
            Some(f) => f.saturating_sub(LOOKAHEAD_ROUNDING_MARGIN).max(1),
            None => Tick::MAX,
        };
    }

    /// The current conservative horizon in ticks (`Tick::MAX` when no
    /// device is reachable).
    pub fn lookahead(&self) -> Tick {
        self.lookahead
    }

    /// Test hook: pin the lookahead to `la` (or back to derived with
    /// `None`). A deliberately-too-large pin lets responses land behind
    /// the host's clock, which the event queue's "scheduling into the
    /// past" debug assertion catches — the harness proving the horizon
    /// math is load-bearing.
    pub fn force_lookahead(&mut self, la: Option<Tick>) {
        self.lookahead_override = la;
        self.recompute_lookahead();
    }

    /// Attach one workload per core (fewer workloads than cores is
    /// fine) and perform the functional init phase (untimed, like a
    /// fast-forwarded boot+init in gem5).
    pub(crate) fn attach_workloads(
        &mut self,
        mut wls: Vec<Box<dyn Workload>>,
        policy: &MemPolicy,
    ) -> Result<()> {
        let guest = self.guest.as_mut().context("boot first")?;
        assert!(wls.len() <= self.cores.len());
        self.spaces.clear();
        for wl in wls.iter_mut() {
            let mut asp = AddressSpace::new(self.cfg.page_size);
            wl.setup(&mut asp, policy);
            for (va, bits) in wl.init_data() {
                let pa = asp.translate(va, &mut guest.alloc)?;
                self.mem.write_u64(pa, bits);
            }
            self.spaces.push(asp);
        }
        self.workloads = wls;
        let at = self.queue.now();
        for c in 0..self.workloads.len() {
            self.sched(at, Ev::Issue(c as u8));
            self.issue_scheduled[c] = true;
        }
        Ok(())
    }

    fn alloc_req(&mut self) -> ReqId {
        let r = self.next_req;
        self.next_req += 1;
        r
    }

    fn is_cxl_addr(&self, pa: u64) -> bool {
        self.rc.routes(pa)
            || (self.cfg.cxl.attach == CxlAttach::MemBus
                && self.bios.cxl_window_size > 0
                && pa >= self.bios.cxl_window_base
                && pa < self.bios.cxl_window_base + self.bios.cxl_window_size)
    }

    // ---- the memory path --------------------------------------------------

    /// A core issues a load/store to `pa` at `now`.
    fn access(&mut self, core: u8, pa: u64, is_write: bool, now: Tick) {
        let req = self.alloc_req();
        self.cores[core as usize].begin_mem(now, req, is_write);
        self.access_with_req(core, pa, is_write, req, now);
    }

    /// Timing for a live request `req` (fresh, or re-probing after an
    /// MSHR-full park — the functional effect already happened at issue
    /// time, so retries re-run only the timing path).
    fn access_with_req(
        &mut self,
        core: u8,
        pa: u64,
        is_write: bool,
        req: ReqId,
        now: Tick,
    ) {
        let c = core as usize;
        // A store to a BI-coherent shared line this host does not own
        // must reach the device as an RFO (MemInv) so the snoop filter
        // can back-invalidate the other sharers — a stale local hit
        // would write behind their caches. Demote local copies first so
        // the probe below takes the miss path.
        if is_write && self.needs_shared_rfo(pa) {
            self.rfo_demote(pa, now);
        }
        let probe = self.l1s[c].probe(pa, is_write);
        match probe.access {
            Access::Hit if !probe.needs_upgrade => {
                let at = now + self.l1_lat;
                self.sched(at, Ev::Hit { core, req });
            }
            Access::Hit => {
                // Write hit on Shared: directory upgrade.
                self.stats.upgrades.inc();
                let line = self.l1s[c].line_addr(pa);
                let act = self.dir.write_req(line, core);
                let mut extra = 0;
                if let crate::cache::directory::DirAction::Invalidate { mask } =
                    act
                {
                    extra = self.invalidate_peers(mask, pa, now);
                }
                self.l1s[c].finish_upgrade(pa);
                self.dir.note_write(line, core);
                // Upgrade = L1 + membus round trip (+ peer inval time).
                let t = now
                    + self.l1_lat
                    + self.membus.transfer(now, 16)
                    .saturating_sub(now)
                    + extra;
                self.sched(t, Ev::Hit { core, req });
            }
            Access::Miss => {
                let line = self.l1s[c].line_addr(pa);
                match self.l1_mshrs[c].allocate(line, req, is_write) {
                    MshrAlloc::Secondary => { /* ride the primary */ }
                    MshrAlloc::Full => {
                        // Defensive: `try_issue` parks ops on its
                        // capacity pre-check before they reach here, so
                        // today this fires only for a future caller
                        // that skips that check. Unlike the old
                        // zero-latency degrade (which completed and
                        // dropped the request), park the miss and
                        // re-probe once the file has had time to
                        // drain; the request stays live in the core,
                        // so conservation holds even on this path.
                        self.stats.mshr_retries.inc();
                        self.cores[c].note_lsq_stall();
                        let at = now + self.l1_lat * 4;
                        self.sched(at, Ev::MshrRetry { core, pa, is_write, req });
                    }
                    MshrAlloc::Primary => {
                        self.l1_primary_miss(core, pa, is_write, now);
                    }
                }
            }
        }
    }

    /// Handle coherence + L2 for a primary L1 miss.
    fn l1_primary_miss(
        &mut self,
        core: u8,
        pa: u64,
        is_write: bool,
        now: Tick,
    ) {
        use crate::cache::directory::DirState;
        let line = self.l1s[core as usize].line_addr(pa);
        // Timing estimate for directory traffic; the *state* actions are
        // applied at fill time (complete_line_fill), which keeps SWMR
        // intact when multiple fills race.
        let coh_extra: Tick = match self.dir.state(line) {
            DirState::Owned { core: o } if o != core => {
                ns_to_ticks(2.0 * self.cfg.membus_lat_ns)
            }
            DirState::Sharers { .. } if is_write => {
                ns_to_ticks(2.0 * self.cfg.membus_lat_ns)
            }
            _ => 0,
        };

        // To L2 over the membus.
        let at_l2 = self.membus.transfer(now + self.l1_lat, 16) + self.l2_lat
            + coh_extra;
        // Train the prefetcher on the demand stream reaching L2.
        self.train_prefetcher(pa, at_l2);
        let l2_probe = self.l2.probe(pa, false);
        match l2_probe.access {
            Access::Hit => {
                if self.pf_book.note_demand(line) {
                    if let Some(p) = &mut self.prefetcher {
                        p.stats.useful.inc();
                    }
                }
                // Data back over the membus.
                let back = self.membus.transfer(at_l2, 64);
                self.sched(back, Ev::LineFill { core, line_pa: pa });
            }
            Access::Miss => {
                let key = self.l2.line_addr(pa);
                if self.pf_book.note_demand_miss(key) {
                    // Prefetch in flight but not home yet: the demand
                    // merges onto it — a *late* prefetch.
                    if let Some(p) = &mut self.prefetcher {
                        p.stats.late.inc();
                    }
                }
                if let Some(p) = self.l2_pending.get_mut(&key) {
                    p.cores.push(core);
                    p.wants_excl |= is_write;
                    return;
                }
                self.l2_pending.insert(
                    key,
                    L2Pending { cores: vec![core], wants_excl: is_write },
                );
                self.fetch_from_memory(core, pa, is_write, at_l2);
            }
        }
    }

    /// Feed the L2 prefetcher and launch predicted fetches.
    fn train_prefetcher(&mut self, pa: u64, now: Tick) {
        let line = self.l2.line_addr(pa);
        let Some(p) = &mut self.prefetcher else { return };
        let predictions = p.train(line);
        for target_line in predictions {
            let target_pa = target_line * self.cfg.l2.line;
            // Skip resident / in-flight lines and unmapped space.
            if self.l2.find(target_pa).is_some()
                || self.l2_pending.contains_key(&target_line)
                || self.pf_book.is_inflight(target_line)
            {
                continue;
            }
            let in_dram = target_pa < self.cfg.sys_mem_size;
            let in_cxl = self.is_cxl_addr(target_pa);
            if !in_dram && !in_cxl {
                continue;
            }
            if let Some(pp) = &mut self.prefetcher {
                pp.stats.issued.inc();
            }
            self.pf_book.note_issued(target_line);
            self.l2_pending.insert(
                target_line,
                L2Pending { cores: Vec::new(), wants_excl: false },
            );
            self.fetch_from_memory(PF_CORE, target_pa, false, now);
        }
    }

    /// L2 miss -> system DRAM or CXL expander.
    fn fetch_from_memory(
        &mut self,
        core: u8,
        pa: u64,
        wants_excl: bool,
        now: Tick,
    ) {
        if self.is_cxl_addr(pa) {
            self.fetch_from_cxl(core, pa, wants_excl, now);
        } else {
            self.fetch_from_dram(core, pa, wants_excl, now);
        }
    }

    /// True when `pa` falls inside a window this host shares with at
    /// least one other host (device-side BI coherence applies).
    fn is_shared_addr(&self, pa: u64) -> bool {
        self.shared_ranges
            .iter()
            .any(|&(base, size)| pa >= base && pa < base + size)
    }

    #[inline]
    fn shared_line_key(&self, pa: u64) -> u64 {
        pa & !(self.cfg.l1.line - 1)
    }

    /// Should a store to `pa` take the RFO miss path? Yes iff the line
    /// is BI-coherent and this host holds no exclusive grant for it.
    fn needs_shared_rfo(&self, pa: u64) -> bool {
        !self.shared_ranges.is_empty()
            && self.is_shared_addr(pa)
            && !self.owned_lines.contains(&self.shared_line_key(pa))
    }

    /// Drop every local copy of an unowned shared line ahead of the RFO
    /// miss path; dirty data goes home first so device media stays the
    /// single source of truth the other sharers refill from.
    fn rfo_demote(&mut self, pa: u64, now: Tick) {
        let mut dirty = false;
        for c in 0..self.l1s.len() {
            if self.l1s[c].invalidate(pa).is_some() {
                dirty = true;
            }
        }
        self.dir.purge(self.l2.line_addr(pa));
        if self.l2.invalidate(pa).is_some() {
            dirty = true;
        }
        if dirty {
            self.writeback(pa, now);
        }
    }

    fn fetch_from_dram(
        &mut self,
        core: u8,
        pa: u64,
        wants_excl: bool,
        now: Tick,
    ) {
        let t = self.membus.transfer(now, 16);
        match self.dram.enqueue(t, pa, self.cfg.l1.line, false) {
            Some(done) => {
                self.stats.dram_reads.inc();
                let back = self.membus.transfer(done, 64);
                self.sched(back, Ev::LineFill { core, line_pa: pa });
            }
            None => {
                let at = now + ns_to_ticks(100.0);
                self.sched(at, Ev::DramRetry { core, line_pa: pa, wants_excl });
            }
        }
    }

    /// Time the host-side leg of a CXL line fetch and emit the
    /// fabric-crossing request. The fabric leg (credits, links, media)
    /// is committed later by the machine in global order; the response
    /// comes back as [`Ev::CxlFill`]. Credit-stall retries are the
    /// commit phase's business now — the emission here is
    /// unconditional, so fetch stats count requests, not attempts.
    fn fetch_from_cxl(
        &mut self,
        core: u8,
        pa: u64,
        wants_excl: bool,
        now: Tick,
    ) {
        if self.cfg.cxl.attach == CxlAttach::MemBus {
            // Baseline (CXL-DMSim/SimCXL style): expander hangs off the
            // membus; protocol costs collapse into a fixed adder (both
            // directions' pack+unpack + wire), no flit framing, no
            // credits, no IOBus contention. The interleave decode still
            // applies — the baseline shortcut is about the attach point,
            // not the window routing.
            let t = self.membus.transfer(now, 16);
            let (dev, dpa) = self
                .rc
                .route_dpa(pa)
                .unwrap_or((0, pa - self.bios.cxl_window_base));
            self.stats.cxl_reads.inc();
            self.stats.cxl_dev_reads[dev].inc();
            self.emit(t, FabricReq::MediaFetch { dev, dpa, core, line_pa: pa });
            return;
        }
        // Architecturally correct path: membus -> IOBus -> RC interleave
        // decode -> that device's fabric path. On the IOBus attach
        // `is_cxl_addr` is exactly `rc.routes(pa)`, so the decode always
        // resolves; keep device 0 as the pre-commit fallback (never a
        // dropped request) should a future caller widen the predicate.
        let t = self.membus.transfer(now, 16);
        let t = self.iobus.transfer(t, 16);
        let dev = self.rc.route(pa).unwrap_or(0);
        let host_pkt = Packet::new(
            0,
            MemCmd::ReadReq,
            pa & !(self.cfg.l1.line - 1),
            64,
            core,
            now,
        );
        // Stores to BI-coherent lines ride an RFO (M2S MemInv): same
        // wire cost as a read, but the device's snoop filter records
        // this host as owner and back-invalidates the other sharers.
        let rfo = wants_excl && self.is_shared_addr(pa);
        let pkt = if rfo {
            self.rc.packetize_rfo(&host_pkt)
        } else {
            self.rc.packetize(&host_pkt)
        };
        if rfo {
            let key = self.shared_line_key(pa);
            self.owned_lines.insert(key);
        }
        self.stats.cxl_reads.inc();
        self.stats.cxl_dev_reads[dev].inc();
        self.emit(
            t,
            FabricReq::Fetch { dev, pkt, core, line_pa: pa, issued_at: now },
        );
    }

    /// Invalidate peer L1 copies per the directory mask; returns the
    /// added coherence latency.
    fn invalidate_peers(&mut self, mask: u64, pa: u64, now: Tick) -> Tick {
        let mut extra = 0;
        for peer in 0..self.cores.len() as u8 {
            if mask & (1 << peer) != 0 {
                self.stats.coherence_invals.inc();
                if let Some(_wb) = self.l1s[peer as usize].invalidate(pa) {
                    // Dirty copy flushes to L2 on the way out.
                    self.l2.fill(pa, MesiState::Modified);
                }
                self.dir
                    .note_evict(self.l1s[peer as usize].line_addr(pa), peer);
                extra = ns_to_ticks(2.0 * self.cfg.membus_lat_ns);
            }
        }
        let _ = now;
        extra
    }

    /// A line arrived at L2 from memory: fill L2, then distribute to the
    /// waiting cores' L1s. L2-*hit* fills carry no pending entry and
    /// must not touch L2 state (it could lose a dirty bit).
    fn memory_fill_arrived(&mut self, pa: u64, now: Tick) -> Vec<u8> {
        let key = self.l2.line_addr(pa);
        let Some(pending) = self.l2_pending.remove(&key) else {
            return Vec::new();
        };
        self.pf_book.note_fill(key);
        match self.l2.fill(pa, MesiState::Exclusive) {
            Victim::Dirty(victim_pa) => {
                self.pf_book.note_evict(self.l2.line_addr(victim_pa));
                self.writeback(victim_pa, now);
                self.inclusive_purge(victim_pa, now);
            }
            Victim::Clean(victim_pa) => {
                self.pf_book.note_evict(self.l2.line_addr(victim_pa));
                self.inclusive_purge(victim_pa, now);
            }
            Victim::None => {}
        }
        pending.cores
    }

    /// Inclusive hierarchy: an L2 eviction kills L1 copies above.
    /// The directory tells us exactly which L1s can hold the line, so
    /// this is O(sharers) rather than O(cores) (perf-pass change #3).
    fn inclusive_purge(&mut self, victim_pa: u64, now: Tick) {
        use crate::cache::directory::DirState;
        let line = self.l2.line_addr(victim_pa);
        let mask: u64 = match self.dir.state(line) {
            DirState::Uncached => 0,
            DirState::Owned { core } => 1 << core,
            DirState::Sharers { mask } => mask,
        };
        let mut m = mask;
        while m != 0 {
            let c = m.trailing_zeros() as usize;
            m &= m - 1;
            if let Some(_wb) = self.l1s[c].invalidate(victim_pa) {
                // Dirty L1 data above a dying L2 line goes to memory.
                self.writeback(victim_pa, now);
            }
        }
        self.dir.purge(line);
    }

    /// Posted write-back of a dirty line to its memory class. CXL
    /// write-backs emit a fabric request (committed in global order);
    /// credit exhaustion drops them from the timing model at commit,
    /// exactly as the inline path did.
    fn writeback(&mut self, pa: u64, now: Tick) {
        if self.is_cxl_addr(pa) {
            self.stats.writebacks_cxl.inc();
            if !self.shared_ranges.is_empty() {
                // Writing a shared line back surrenders the exclusive
                // grant (the device clears its owner mark on MemWr).
                let key = self.shared_line_key(pa);
                self.owned_lines.remove(&key);
            }
            if self.cfg.cxl.attach == CxlAttach::MemBus {
                let t = self.membus.transfer(now, 64 + 16);
                let (dev, dpa) = self
                    .rc
                    .route_dpa(pa)
                    .unwrap_or((0, pa - self.bios.cxl_window_base));
                self.stats.cxl_dev_writebacks[dev].inc();
                self.emit(t, FabricReq::MediaWriteback { dev, dpa });
                return;
            }
            let Some(dev) = self.rc.route(pa) else { return };
            self.stats.cxl_dev_writebacks[dev].inc();
            let t = self.membus.transfer(now, 64 + 16);
            let t = self.iobus.transfer(t, 64 + 16);
            let host_pkt = Packet::new(
                0,
                MemCmd::WritebackDirty,
                pa & !(self.cfg.l1.line - 1),
                64,
                0,
                now,
            );
            let pkt = self.rc.packetize(&host_pkt);
            self.emit(t, FabricReq::Writeback { dev, pkt });
        } else if pa < self.cfg.sys_mem_size {
            self.stats.writebacks_dram.inc();
            let t = self.membus.transfer(now, 64 + 16);
            // Posted: force-accept into the controller (write queue
            // drains are not modeled with retries).
            self.dram.timing.access(t, pa, self.cfg.l1.line, true);
        } else {
            // Neither DRAM nor a routed CXL window: a dirty line whose
            // backing window was hot-removed after its pages were freed
            // (the FM quiesce drains in-flight *fetches*; clean-by-then
            // resident dirty lines can outlive the window). The data is
            // already functionally in physmem — drop the posted write
            // from the timing model, as the credit-exhaustion path
            // does, and count it so the approximation stays visible.
            self.stats.writebacks_unmapped.inc();
        }
    }

    // ---- the issue engine -------------------------------------------------

    fn schedule_issue(&mut self, core: u8, at: Tick) {
        if !self.issue_scheduled[core as usize] {
            self.issue_scheduled[core as usize] = true;
            let at = at.max(self.queue.now());
            self.sched(at, Ev::Issue(core));
        }
    }

    fn next_op_for(&mut self, core: usize, now: Tick) -> Option<WlOp> {
        if let Some(op) = self.pending_op[core].take() {
            return Some(op);
        }
        self.workloads.get_mut(core).and_then(|w| {
            // Let request-oriented workloads timestamp op boundaries
            // (fresh pulls only — parked re-issues keep their origin).
            w.tick_hint(now);
            w.next_op()
        })
    }

    fn try_issue(&mut self, core: u8, now: Tick) {
        let c = core as usize;
        if c >= self.workloads.len() || self.cores[c].done {
            return;
        }
        loop {
            if !self.cores[c].can_issue(now) {
                if !self.cores[c].done
                    && self.cores[c].lsq_free()
                    && self.cores[c].next_issue > now
                {
                    let at = self.cores[c].next_issue;
                    self.schedule_issue(core, at);
                }
                // Else: waiting on a response; completions re-trigger.
                return;
            }
            let Some(op) = self.next_op_for(c, now) else {
                if self.cores[c].outstanding() == 0 {
                    self.cores[c].finish(now);
                }
                return;
            };
            match op {
                WlOp::Work { cycles } => {
                    self.cores[c].do_work(now, cycles);
                }
                WlOp::Load { va, .. } | WlOp::Store { va, .. } => {
                    let is_write = matches!(op, WlOp::Store { .. });
                    // L1 MSHR structural hazard check happens in
                    // `access_with_req`; check capacity here to park
                    // the op before it even enters the machine.
                    if self.l1_mshrs[c].is_full() {
                        self.pending_op[c] = Some(op);
                        self.cores[c].note_lsq_stall();
                        return; // a LineFill will re-trigger issue
                    }
                    // Translate (may fault).
                    let guest = self.guest.as_mut().expect("booted");
                    let faults_before = self.spaces[c].stats.faults;
                    let pa = match self.spaces[c].translate(va, &mut guest.alloc)
                    {
                        Ok(pa) => pa,
                        Err(e) => {
                            log::error!("host {} core {core}: {e}", self.id);
                            self.cores[c].finish(now);
                            return;
                        }
                    };
                    if self.spaces[c].stats.faults > faults_before {
                        self.stats.page_faults.inc();
                        self.cores[c].do_work(
                            now,
                            self.fault_ticks
                                / ns_to_ticks(self.cfg.cycle_ns()).max(1),
                        );
                    }
                    // Functional execution in program order.
                    if is_write {
                        let bits = self.workloads[c].store_value(va);
                        self.mem.write_u64(pa & !7, bits);
                    } else {
                        let bits = self.mem.read_u64(pa & !7);
                        self.workloads[c].load_done(va, bits);
                    }
                    self.access(core, pa, is_write, now);
                }
            }
        }
    }

    fn complete_line_fill(&mut self, core: u8, pa: u64, now: Tick) {
        let c = core as usize;
        let line = self.l1s[c].line_addr(pa);
        let Some(mshr) = self.l1_mshrs[c].complete(line) else {
            return; // duplicate fill (e.g. L2-hit raced a retry)
        };
        // Directory actions + fill state (applied here, at fill time).
        use crate::cache::directory::DirAction;
        let state = if mshr.wants_exclusive {
            if let DirAction::Invalidate { mask } =
                self.dir.write_req(line, core)
            {
                self.invalidate_peers(mask, pa, now);
            }
            self.dir.note_write(line, core);
            MesiState::Modified
        } else {
            if let DirAction::DowngradeOwner { core: owner } =
                self.dir.read_req(line, core)
            {
                let was_m = self.l1s[owner as usize].downgrade(pa);
                if was_m {
                    self.l2.fill(pa, MesiState::Modified);
                }
            }
            if self.dir.note_read(line, core) {
                MesiState::Exclusive
            } else {
                MesiState::Shared
            }
        };
        match self.l1s[c].fill(pa, state) {
            Victim::Dirty(victim_pa) => {
                // L1 dirty victim folds into L2.
                self.l2.fill(victim_pa, MesiState::Modified);
                self.dir.note_evict(self.l1s[c].line_addr(victim_pa), core);
            }
            Victim::Clean(victim_pa) => {
                self.dir.note_evict(self.l1s[c].line_addr(victim_pa), core);
            }
            Victim::None => {}
        }
        for req in mshr.waiters {
            self.cores[c].complete_mem(now, req);
        }
        self.try_issue(core, now);
    }

    /// Translate a device BISnp's DPA back to this host's physical
    /// address through the routed 1-way windows (shared windows never
    /// interleave, so the slice math is a straight offset).
    fn bi_dpa_to_pa(&self, dev: usize, dpa: u64) -> Option<u64> {
        for w in self.rc.windows() {
            if w.targets.len() == 1
                && w.targets[0] == dev
                && dpa >= w.dpa_base
                && dpa < w.dpa_base + w.size
            {
                return Some(w.base + (dpa - w.dpa_base));
            }
        }
        None
    }

    /// Handle one of this host's local events.
    fn dispatch(&mut self, ev: Ev, t: Tick) {
        match ev {
            Ev::Issue(core) => {
                self.issue_scheduled[core as usize] = false;
                self.try_issue(core, t);
            }
            Ev::Hit { core, req } => {
                self.cores[core as usize].complete_mem(t, req);
                self.try_issue(core, t);
            }
            Ev::LineFill { core, line_pa } => {
                let cores = self.memory_fill_arrived(line_pa, t);
                // First deliver to the requester on this event, then
                // to any cores that merged at L2. PF_CORE marks a
                // prefetch fill: it stops at L2 unless demand merged.
                if core != PF_CORE {
                    self.complete_line_fill(core, line_pa, t);
                }
                for other in cores {
                    if other != core && other != PF_CORE {
                        self.complete_line_fill(other, line_pa, t);
                    }
                }
            }
            Ev::DramRetry { core, line_pa, wants_excl } => {
                self.fetch_from_dram(core, line_pa, wants_excl, t);
            }
            Ev::MshrRetry { core, pa, is_write, req } => {
                self.access_with_req(core, pa, is_write, req, t);
            }
            Ev::BiInv { dev, dpa } => {
                self.stats.bi_invalidations.inc();
                let dirty = match self.bi_dpa_to_pa(dev, dpa) {
                    Some(pa) => {
                        let mut d = false;
                        for c in 0..self.l1s.len() {
                            if self.l1s[c].invalidate(pa).is_some() {
                                d = true;
                            }
                        }
                        self.dir.purge(self.l2.line_addr(pa));
                        if self.l2.invalidate(pa).is_some() {
                            d = true;
                        }
                        let key = self.shared_line_key(pa);
                        self.owned_lines.remove(&key);
                        d
                    }
                    // Window already offline (unbound after the snoop
                    // departed): nothing cached, ack clean.
                    None => false,
                };
                // Ack on the uncredited BI channel after one membus hop;
                // a dirty copy rides home inside the response packet
                // (no separate MemWr — the device counts it as a BI
                // write-back when the ack lands).
                let pkt = mem_proto::make_bi_response(dpa, 0, 0, dirty);
                self.emit(
                    t + self.bi_rsp_delay,
                    FabricReq::BiRsp { dev, pkt, dpa, dirty },
                );
            }
            Ev::CxlFill { core, line_pa, issued_at } => {
                if self.cfg.cxl.attach == CxlAttach::MemBus {
                    // Baseline: media data rides the membus home.
                    let back = self.membus.transfer(t, 64);
                    self.sched(back, Ev::LineFill { core, line_pa });
                } else {
                    // RC protocol accounting, then IOBus + membus home.
                    self.rc.note_response(t, issued_at);
                    let tt = self.iobus.transfer(t, 64);
                    let back = self.membus.transfer(tt, 64);
                    self.sched(back, Ev::LineFill { core, line_pa });
                }
            }
        }
    }

    /// Every workload-carrying core on this host has retired its last
    /// op (vacuously true with no workloads attached). The policy
    /// engine stops re-scheduling its sampling epoch once every host
    /// is done, so the event queue can drain.
    pub(crate) fn all_done(&self) -> bool {
        (0..self.workloads.len()).all(|c| self.cores[c].done)
    }

    /// Quiesce check for FM-driven hot-remove: is any memory fetch to
    /// `[base, base+size)` still in flight? Every outstanding fetch —
    /// demand or prefetch, including requests awaiting fabric commit or
    /// parked on credit retries — holds an `l2_pending` entry from
    /// issue until its fill lands, so an empty intersection means no
    /// packet can still be routed at the departing window.
    pub(crate) fn has_inflight_in(&self, base: u64, size: u64) -> bool {
        let line = self.cfg.l2.line;
        // Audited for the determinism contract: `any` over disjoint
        // keys is a pure existence test, so hash iteration order
        // cannot reach the result.
        // simlint: allow(hash-iter, order-insensitive existence check)
        self.l2_pending
            .keys()
            .any(|&k| k * line >= base && k * line < base + size)
    }

    // ---- results ----------------------------------------------------------

    /// Tick at which this host's last core finished (0 if none ran).
    pub fn finished_at(&self) -> Tick {
        self.cores.iter().map(|c| c.stats.finished_at).max().unwrap_or(0)
    }

    /// Bytes moved by this host's workloads.
    pub fn bytes_moved(&self) -> u64 {
        self.workloads.iter().map(|w| w.bytes_moved()).sum()
    }

    /// Read access to an attached workload (coordinator hooks).
    pub fn workload(&self, i: usize) -> Option<&dyn Workload> {
        self.workloads.get(i).map(|b| b.as_ref())
    }

    /// Verify this host's workloads' functional results.
    pub fn verify(&mut self) -> Result<(), String> {
        let guest = self.guest.as_mut().ok_or("not booted")?;
        for (i, w) in self.workloads.iter().enumerate() {
            w.verify(&mut self.spaces[i], &mut guest.alloc, &self.mem)?;
        }
        Ok(())
    }

    /// Dump this host's stats under `prefix` (empty for single-host
    /// machines, `host{N}.` otherwise).
    pub fn dump(&self, prefix: &str, d: &mut StatDump) {
        for (i, c) in self.cores.iter().enumerate() {
            c.dump(&format!("{prefix}core{i}"), d);
        }
        for (i, l) in self.l1s.iter().enumerate() {
            l.stats.dump(&format!("{prefix}l1.{i}"), d);
        }
        self.l2.stats.dump(&format!("{prefix}l2"), d);
        self.membus.dump(&format!("{prefix}membus"), d);
        self.iobus.dump(&format!("{prefix}iobus"), d);
        self.dram.timing.dump(&format!("{prefix}dram"), d);
        self.rc.dump(&format!("{prefix}cxl.rc"), d);
        for (i, r) in self.stats.cxl_dev_reads.iter().enumerate() {
            d.counter(&format!("{prefix}cxl.dev{i}.fills"), r);
        }
        for (i, w) in self.stats.cxl_dev_writebacks.iter().enumerate() {
            d.counter(&format!("{prefix}cxl.dev{i}.writebacks"), w);
        }
        if let Some(p) = &self.prefetcher {
            crate::cache::prefetch::dump(p, &format!("{prefix}l2.pf"), d);
        }
        d.counter(&format!("{prefix}sys.page_faults"), &self.stats.page_faults);
        d.counter(
            &format!("{prefix}sys.coherence_invals"),
            &self.stats.coherence_invals,
        );
        d.counter(
            &format!("{prefix}sys.writebacks_dram"),
            &self.stats.writebacks_dram,
        );
        d.counter(
            &format!("{prefix}sys.writebacks_cxl"),
            &self.stats.writebacks_cxl,
        );
        d.counter(
            &format!("{prefix}sys.mshr_retries"),
            &self.stats.mshr_retries,
        );
        d.counter(
            &format!("{prefix}sys.mem_online_events"),
            &self.stats.mem_online_events,
        );
        d.counter(
            &format!("{prefix}sys.mem_offline_events"),
            &self.stats.mem_offline_events,
        );
        d.counter(
            &format!("{prefix}sys.mem_offline_refused"),
            &self.stats.mem_offline_refused,
        );
        d.counter(
            &format!("{prefix}sys.fm_quiesce_retries"),
            &self.stats.fm_quiesce_retries,
        );
        d.counter(
            &format!("{prefix}sys.writebacks_unmapped"),
            &self.stats.writebacks_unmapped,
        );
        d.counter(
            &format!("{prefix}sys.bi_invalidations"),
            &self.stats.bi_invalidations,
        );
        // Guest-side capacity-pressure signal (pages that spilled off
        // their policy node); 0 until the guest boots.
        let fallback = self
            .guest
            .as_ref()
            .map(|g| g.alloc.fallback_allocs)
            .unwrap_or(0);
        d.push(
            &format!("{prefix}sys.numa_fallback_allocs"),
            fallback as f64,
        );
        // Workload-contributed stats, merged across this host's cores:
        // counts sum; latency sample sets concatenate (in core order,
        // for determinism) before one host-wide percentile pass.
        let mut counts: std::collections::BTreeMap<String, u64> =
            Default::default();
        let mut samples: std::collections::BTreeMap<
            String,
            crate::stats::Samples,
        > = Default::default();
        for w in &self.workloads {
            for (key, stat) in w.extra_stats() {
                match stat {
                    WlStat::Count(n) => {
                        *counts.entry(key).or_default() += n
                    }
                    WlStat::SamplesNs(vs) => {
                        samples.entry(key).or_default().extend(&vs)
                    }
                }
            }
        }
        for (key, n) in counts {
            d.push(&format!("{prefix}{key}"), n as f64);
        }
        for (key, s) in samples {
            d.push(&format!("{prefix}{key}.p50_ns"), s.percentile(0.50) as f64);
            d.push(&format!("{prefix}{key}.p95_ns"), s.percentile(0.95) as f64);
            d.push(&format!("{prefix}{key}.p99_ns"), s.percentile(0.99) as f64);
        }
    }
}
