//! MESI coherence states and transition table.
//!
//! The paper's Table I: "Cache Coherence — MESI (Two-level,
//! Directory-based)". L1 caches hold MESI states; the shared L2 carries
//! a directory ([`super::Directory`]) tracking which cores hold each line
//! and in what mode. This module defines the states and the *legal*
//! transitions; the event-driven protocol (who sends what when) lives in
//! `system::coherence_flow`.

/// Classic MESI.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MesiState {
    Modified,
    Exclusive,
    Shared,
    Invalid,
}

impl MesiState {
    pub fn readable(&self) -> bool {
        *self != MesiState::Invalid
    }

    pub fn writable(&self) -> bool {
        matches!(self, MesiState::Modified | MesiState::Exclusive)
    }

    /// True if the line holds (possibly) newer data than memory.
    pub fn dirtyish(&self) -> bool {
        *self == MesiState::Modified
    }

    pub fn short(&self) -> char {
        match self {
            MesiState::Modified => 'M',
            MesiState::Exclusive => 'E',
            MesiState::Shared => 'S',
            MesiState::Invalid => 'I',
        }
    }
}

/// Coherence events a line can experience (local = this cache's CPU,
/// remote = directory-forwarded from another core).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CohEvent {
    LocalRead,
    LocalWrite,
    RemoteRead,
    RemoteWrite,
    Evict,
}

/// The MESI next-state function. Returns `None` for transitions that
/// require a bus/directory transaction first (handled by the protocol
/// layer), `Some(next)` for immediate transitions.
pub fn next_state(cur: MesiState, ev: CohEvent) -> Option<MesiState> {
    use CohEvent::*;
    use MesiState::*;
    match (cur, ev) {
        // Hits that need no transaction:
        (Modified, LocalRead) | (Modified, LocalWrite) => Some(Modified),
        (Exclusive, LocalRead) => Some(Exclusive),
        (Exclusive, LocalWrite) => Some(Modified), // silent upgrade
        (Shared, LocalRead) => Some(Shared),
        // Transactions required:
        (Shared, LocalWrite) => None,  // upgrade (BusUpgr)
        (Invalid, LocalRead) => None,  // fetch
        (Invalid, LocalWrite) => None, // fetch-exclusive
        // Snoops:
        (Modified, RemoteRead) => Some(Shared), // flush + downgrade
        (Exclusive, RemoteRead) => Some(Shared),
        (Shared, RemoteRead) => Some(Shared),
        (_, RemoteWrite) => Some(Invalid),
        (_, Evict) => Some(Invalid),
        (Invalid, RemoteRead) => Some(Invalid),
    }
}

/// Protocol invariant check used by the property tests: at most one core
/// in M/E, and M/E excludes any S elsewhere (SWMR).
pub fn swmr_holds(states: &[MesiState]) -> bool {
    let writers = states
        .iter()
        .filter(|s| matches!(s, MesiState::Modified | MesiState::Exclusive))
        .count();
    let readers = states
        .iter()
        .filter(|s| **s == MesiState::Shared)
        .count();
    writers <= 1 && (writers == 0 || readers == 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use MesiState::*;

    #[test]
    fn state_predicates() {
        assert!(Modified.writable() && Modified.readable() && Modified.dirtyish());
        assert!(Exclusive.writable() && !Exclusive.dirtyish());
        assert!(Shared.readable() && !Shared.writable());
        assert!(!Invalid.readable());
    }

    #[test]
    fn silent_e_to_m() {
        assert_eq!(next_state(Exclusive, CohEvent::LocalWrite), Some(Modified));
    }

    #[test]
    fn transactions_required() {
        assert_eq!(next_state(Shared, CohEvent::LocalWrite), None);
        assert_eq!(next_state(Invalid, CohEvent::LocalRead), None);
        assert_eq!(next_state(Invalid, CohEvent::LocalWrite), None);
    }

    #[test]
    fn snoops_downgrade_and_invalidate() {
        assert_eq!(next_state(Modified, CohEvent::RemoteRead), Some(Shared));
        assert_eq!(next_state(Exclusive, CohEvent::RemoteWrite), Some(Invalid));
        assert_eq!(next_state(Shared, CohEvent::RemoteWrite), Some(Invalid));
    }

    #[test]
    fn swmr_checker() {
        assert!(swmr_holds(&[Modified, Invalid, Invalid]));
        assert!(swmr_holds(&[Shared, Shared, Invalid]));
        assert!(!swmr_holds(&[Modified, Shared]));
        assert!(!swmr_holds(&[Modified, Exclusive]));
        assert!(swmr_holds(&[]));
    }
}
