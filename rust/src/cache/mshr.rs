//! Miss Status Holding Registers.
//!
//! Tracks outstanding line fetches so that (a) secondary misses to an
//! in-flight line merge instead of re-requesting, and (b) the cache
//! back-pressures when all registers are busy (the CPU models see this
//! as a structural stall).

use crate::sim::ReqId;

#[derive(Clone, Debug)]
pub struct Mshr {
    pub line_addr: u64,
    /// Requests (by id) waiting on this line; first is the primary miss.
    pub waiters: Vec<ReqId>,
    /// True if any merged request is a write (fill must be exclusive).
    pub wants_exclusive: bool,
}

#[derive(Clone, Debug)]
pub struct MshrFile {
    capacity: usize,
    entries: Vec<Mshr>,
    pub merged: u64,
    pub full_stalls: u64,
}

/// Result of registering a miss.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MshrAlloc {
    /// New entry created — caller must send the fetch downstream.
    Primary,
    /// Merged into an existing in-flight fetch.
    Secondary,
    /// No free register — caller must stall and retry.
    Full,
}

impl MshrFile {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        MshrFile { capacity, entries: Vec::new(), merged: 0, full_stalls: 0 }
    }

    pub fn outstanding(&self) -> usize {
        self.entries.len()
    }

    pub fn is_full(&self) -> bool {
        self.entries.len() >= self.capacity
    }

    pub fn contains(&self, line_addr: u64) -> bool {
        self.entries.iter().any(|m| m.line_addr == line_addr)
    }

    /// Register a miss for `line_addr` by request `id`.
    pub fn allocate(
        &mut self,
        line_addr: u64,
        id: ReqId,
        is_write: bool,
    ) -> MshrAlloc {
        if let Some(m) =
            self.entries.iter_mut().find(|m| m.line_addr == line_addr)
        {
            m.waiters.push(id);
            m.wants_exclusive |= is_write;
            self.merged += 1;
            return MshrAlloc::Secondary;
        }
        if self.is_full() {
            self.full_stalls += 1;
            return MshrAlloc::Full;
        }
        self.entries.push(Mshr {
            line_addr,
            waiters: vec![id],
            wants_exclusive: is_write,
        });
        MshrAlloc::Primary
    }

    /// Fill arrived: pop the entry, returning all waiters.
    pub fn complete(&mut self, line_addr: u64) -> Option<Mshr> {
        let i = self
            .entries
            .iter()
            .position(|m| m.line_addr == line_addr)?;
        Some(self.entries.swap_remove(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primary_secondary_full() {
        let mut f = MshrFile::new(2);
        assert_eq!(f.allocate(10, 1, false), MshrAlloc::Primary);
        assert_eq!(f.allocate(10, 2, true), MshrAlloc::Secondary);
        assert_eq!(f.allocate(20, 3, false), MshrAlloc::Primary);
        assert_eq!(f.allocate(30, 4, false), MshrAlloc::Full);
        assert_eq!(f.outstanding(), 2);
        assert_eq!(f.merged, 1);
        assert_eq!(f.full_stalls, 1);
    }

    #[test]
    fn complete_returns_waiters_and_exclusivity() {
        let mut f = MshrFile::new(4);
        f.allocate(10, 1, false);
        f.allocate(10, 2, true);
        let m = f.complete(10).unwrap();
        assert_eq!(m.waiters, vec![1, 2]);
        assert!(m.wants_exclusive);
        assert!(!f.contains(10));
        assert!(f.complete(10).is_none());
    }

    #[test]
    fn freeing_makes_room() {
        let mut f = MshrFile::new(1);
        assert_eq!(f.allocate(1, 1, false), MshrAlloc::Primary);
        assert_eq!(f.allocate(2, 2, false), MshrAlloc::Full);
        f.complete(1);
        assert_eq!(f.allocate(2, 2, false), MshrAlloc::Primary);
    }
}
