//! L2 stride prefetcher (region-based, gem5 `StridePrefetcher`-like).
//!
//! Trains on the L2 access stream per 4 KiB region: when consecutive
//! accesses within a region exhibit a stable line stride, issues
//! prefetches `degree` lines ahead. Prefetch *timeliness* is the
//! mechanism that makes Fig.-5-style sweeps latency-sensitive: a
//! prefetch covers a future demand miss only if memory returns it
//! before the demand arrives — so the same workload shows different
//! *demand* miss rates on DRAM vs CXL even though the cache geometry
//! never changes. This is the "cache pollution / latency interaction"
//! effect the paper's abstract calls out, made measurable.

use crate::stats::{Counter, StatDump};

/// Training entry for one 4 KiB region.
#[derive(Clone, Copy, Debug)]
struct RegionEntry {
    region: u64,
    last_line: u64,
    stride: i64,
    confidence: u8,
    valid: bool,
}

#[derive(Clone, Debug, Default)]
pub struct PrefetchStats {
    pub trained: Counter,
    pub issued: Counter,
    pub useful: Counter,
    pub late: Counter,
}

/// Stride detector + prefetch address generator.
#[derive(Clone, Debug)]
pub struct StridePrefetcher {
    table: Vec<RegionEntry>,
    /// Lines to run ahead once confident.
    pub degree: usize,
    /// Confidence needed before issuing (2 = two stride confirmations).
    pub threshold: u8,
    pub stats: PrefetchStats,
}

impl StridePrefetcher {
    pub fn new(entries: usize, degree: usize) -> Self {
        StridePrefetcher {
            table: vec![
                RegionEntry {
                    region: 0,
                    last_line: 0,
                    stride: 0,
                    confidence: 0,
                    valid: false,
                };
                entries.max(1)
            ],
            degree: degree.max(1),
            threshold: 2,
            stats: PrefetchStats::default(),
        }
    }

    /// Observe a demand access to `line_addr`; returns the line
    /// addresses to prefetch (possibly empty).
    pub fn train(&mut self, line_addr: u64) -> Vec<u64> {
        let region = line_addr >> 6; // 64 lines = 4 KiB region
        let idx = (region as usize) % self.table.len();
        let e = &mut self.table[idx];

        if !e.valid || e.region != region {
            *e = RegionEntry {
                region,
                last_line: line_addr,
                stride: 0,
                confidence: 0,
                valid: true,
            };
            return Vec::new();
        }
        let new_stride = line_addr as i64 - e.last_line as i64;
        if new_stride == 0 {
            return Vec::new(); // same line (MSHR merge territory)
        }
        if new_stride == e.stride {
            e.confidence = e.confidence.saturating_add(1);
        } else {
            e.stride = new_stride;
            e.confidence = 1;
        }
        e.last_line = line_addr;
        self.stats.trained.inc();
        if e.confidence < self.threshold {
            return Vec::new();
        }
        let stride = e.stride;
        let degree = self.degree;
        (1..=degree as i64)
            .filter_map(|k| {
                let target = line_addr as i64 + stride * k;
                (target > 0).then_some(target as u64)
            })
            .collect()
    }
}

/// Per-cache prefetch outcome bookkeeping (who brought the line in).
#[derive(Clone, Debug, Default)]
pub struct PrefetchBook {
    /// Lines currently resident because of a prefetch, not yet touched
    /// by demand. (Line-address keyed; pruned on eviction/demand.)
    resident: crate::util::fxhash::FxHashSet<u64>,
    /// Prefetches still in flight.
    inflight: crate::util::fxhash::FxHashSet<u64>,
}

impl PrefetchBook {
    pub fn note_issued(&mut self, line: u64) {
        self.inflight.insert(line);
    }

    pub fn is_inflight(&self, line: u64) -> bool {
        self.inflight.contains(&line)
    }

    pub fn note_fill(&mut self, line: u64) {
        if self.inflight.remove(&line) {
            self.resident.insert(line);
        }
    }

    /// Demand touched the line: returns true if a prefetch covered it.
    pub fn note_demand(&mut self, line: u64) -> bool {
        self.resident.remove(&line)
    }

    /// Demand missed while the prefetch was still in flight ("late").
    pub fn note_demand_miss(&mut self, line: u64) -> bool {
        self.inflight.contains(&line)
    }

    pub fn note_evict(&mut self, line: u64) {
        self.resident.remove(&line);
    }

    pub fn inflight_count(&self) -> usize {
        self.inflight.len()
    }
}

pub fn dump(p: &StridePrefetcher, path: &str, d: &mut StatDump) {
    d.counter(&format!("{path}.trained"), &p.stats.trained);
    d.counter(&format!("{path}.issued"), &p.stats.issued);
    d.counter(&format!("{path}.useful"), &p.stats.useful);
    d.counter(&format!("{path}.late"), &p.stats.late);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_stride_detected_after_threshold() {
        let mut p = StridePrefetcher::new(64, 4);
        assert!(p.train(100).is_empty()); // allocate
        assert!(p.train(101).is_empty()); // conf 1
        let pf = p.train(102); // conf 2 -> fire
        assert_eq!(pf, vec![103, 104, 105, 106]);
    }

    #[test]
    fn negative_stride_supported() {
        let mut p = StridePrefetcher::new(64, 2);
        p.train(200);
        p.train(198);
        let pf = p.train(196);
        assert_eq!(pf, vec![194, 192]);
    }

    #[test]
    fn stride_change_resets_confidence() {
        let mut p = StridePrefetcher::new(64, 2);
        p.train(10);
        p.train(11);
        assert!(!p.train(12).is_empty());
        assert!(p.train(20).is_empty()); // stride jumped: conf resets to 1
        assert_eq!(p.train(28), vec![36, 44]); // stride 8 confirmed
        assert_eq!(p.train(36), vec![44, 52]);
    }

    #[test]
    fn regions_do_not_interfere() {
        let mut p = StridePrefetcher::new(64, 1);
        // Interleave two regions with unit strides.
        p.train(0);
        p.train(64 * 100);
        p.train(1);
        p.train(64 * 100 + 1);
        let a = p.train(2);
        let b = p.train(64 * 100 + 2);
        assert_eq!(a, vec![3]);
        assert_eq!(b, vec![64 * 100 + 3]);
    }

    #[test]
    fn same_line_repeats_ignored() {
        let mut p = StridePrefetcher::new(64, 2);
        p.train(5);
        assert!(p.train(5).is_empty());
        assert!(p.train(5).is_empty());
        // Still trains cleanly afterwards.
        p.train(6);
        assert!(!p.train(7).is_empty());
    }

    #[test]
    fn book_tracks_outcomes() {
        let mut b = PrefetchBook::default();
        b.note_issued(10);
        assert!(b.is_inflight(10));
        assert!(b.note_demand_miss(10)); // late
        b.note_fill(10);
        assert!(!b.is_inflight(10));
        assert!(b.note_demand(10)); // useful
        assert!(!b.note_demand(10)); // only counted once
        b.note_issued(11);
        b.note_fill(11);
        b.note_evict(11);
        assert!(!b.note_demand(11)); // evicted before use
    }
}
