//! Directory for the two-level MESI protocol.
//!
//! Lives logically at the shared L2: for every line cached above, track
//! the owner/sharer set across cores. The system layer consults it to
//! decide which invalidations/downgrades to issue; the property tests
//! assert the SWMR invariant over (directory x L1 states).

use crate::util::fxhash::FxHashMap;

/// Directory entry state for one line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DirState {
    /// No L1 holds the line.
    Uncached,
    /// Exactly one L1 holds it in M or E.
    Owned { core: u8 },
    /// One or more L1s hold it Shared (bitmask of cores).
    Sharers { mask: u64 },
}

#[derive(Clone, Debug, Default)]
pub struct Directory {
    map: FxHashMap<u64, DirState>, // keyed by line address
    pub invals_sent: u64,
    pub downgrades_sent: u64,
}

/// Actions the protocol layer must perform before a request can proceed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DirAction {
    /// Grant immediately (line uncached, or requester already owner).
    Grant,
    /// Downgrade the owner (remote read of an owned line), then grant
    /// Shared to both.
    DowngradeOwner { core: u8 },
    /// Invalidate these cores (remote write / upgrade), then grant.
    Invalidate { mask: u64 },
}

impl Directory {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn state(&self, line: u64) -> DirState {
        *self.map.get(&line).unwrap_or(&DirState::Uncached)
    }

    /// A core requests read access. Returns the required action; the
    /// caller applies it and then calls `note_read`.
    pub fn read_req(&mut self, line: u64, core: u8) -> DirAction {
        match self.state(line) {
            DirState::Uncached => DirAction::Grant,
            DirState::Owned { core: o } if o == core => DirAction::Grant,
            DirState::Owned { core: o } => {
                self.downgrades_sent += 1;
                DirAction::DowngradeOwner { core: o }
            }
            DirState::Sharers { .. } => DirAction::Grant,
        }
    }

    /// A core requests write (exclusive) access.
    pub fn write_req(&mut self, line: u64, core: u8) -> DirAction {
        match self.state(line) {
            DirState::Uncached => DirAction::Grant,
            DirState::Owned { core: o } if o == core => DirAction::Grant,
            DirState::Owned { core: o } => {
                self.invals_sent += 1;
                DirAction::Invalidate { mask: 1 << o }
            }
            DirState::Sharers { mask } => {
                let others = mask & !(1u64 << core);
                if others == 0 {
                    DirAction::Grant
                } else {
                    self.invals_sent += others.count_ones() as u64;
                    DirAction::Invalidate { mask: others }
                }
            }
        }
    }

    /// Record that `core` now holds the line Shared (after a read grant).
    /// If it was Uncached the core gets Exclusive (recorded as Owned) —
    /// the standard E-state optimisation.
    pub fn note_read(&mut self, line: u64, core: u8) -> bool {
        match self.state(line) {
            DirState::Uncached => {
                self.map.insert(line, DirState::Owned { core });
                true // granted Exclusive
            }
            DirState::Owned { core: o } if o == core => true,
            DirState::Owned { core: o } => {
                // After downgrade both are sharers.
                let mask = (1u64 << o) | (1u64 << core);
                self.map.insert(line, DirState::Sharers { mask });
                false
            }
            DirState::Sharers { mask } => {
                self.map
                    .insert(line, DirState::Sharers { mask: mask | (1 << core) });
                false
            }
        }
    }

    /// Record that `core` now owns the line (after a write grant).
    pub fn note_write(&mut self, line: u64, core: u8) {
        self.map.insert(line, DirState::Owned { core });
    }

    /// Record that `core` dropped the line (L1 eviction).
    pub fn note_evict(&mut self, line: u64, core: u8) {
        match self.state(line) {
            DirState::Owned { core: o } if o == core => {
                self.map.remove(&line);
            }
            DirState::Sharers { mask } => {
                let m = mask & !(1u64 << core);
                if m == 0 {
                    self.map.remove(&line);
                } else {
                    self.map.insert(line, DirState::Sharers { mask: m });
                }
            }
            _ => {}
        }
    }

    /// Drop the entry entirely (L2 eviction invalidated all L1 copies).
    pub fn purge(&mut self, line: u64) {
        self.map.remove(&line);
    }

    /// Import a line's ownership (fast-forward warm-state rebuild): the
    /// warmed L1 holds the line M/E (`writable`) or S.
    pub fn note_import(&mut self, line: u64, core: u8, writable: bool) {
        if writable {
            self.map.insert(line, DirState::Owned { core });
            return;
        }
        let mask = match self.state(line) {
            DirState::Sharers { mask } => mask | (1u64 << core),
            DirState::Owned { core: o } => (1u64 << o) | (1u64 << core),
            DirState::Uncached => 1u64 << core,
        };
        self.map.insert(line, DirState::Sharers { mask });
    }

    pub fn clear(&mut self) {
        self.map.clear();
    }

    pub fn tracked_lines(&self) -> usize {
        self.map.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_reader_gets_exclusive() {
        let mut d = Directory::new();
        assert_eq!(d.read_req(100, 0), DirAction::Grant);
        assert!(d.note_read(100, 0));
        assert_eq!(d.state(100), DirState::Owned { core: 0 });
    }

    #[test]
    fn second_reader_downgrades_owner() {
        let mut d = Directory::new();
        d.read_req(1, 0);
        d.note_read(1, 0);
        assert_eq!(d.read_req(1, 1), DirAction::DowngradeOwner { core: 0 });
        assert!(!d.note_read(1, 1));
        assert_eq!(d.state(1), DirState::Sharers { mask: 0b11 });
        assert_eq!(d.downgrades_sent, 1);
    }

    #[test]
    fn writer_invalidates_sharers() {
        let mut d = Directory::new();
        d.note_read(5, 0);
        d.read_req(5, 1);
        d.note_read(5, 1);
        d.read_req(5, 2);
        d.note_read(5, 2);
        match d.write_req(5, 1) {
            DirAction::Invalidate { mask } => {
                assert_eq!(mask, (1 << 0) | (1 << 2));
            }
            a => panic!("expected invalidate, got {a:?}"),
        }
        d.note_write(5, 1);
        assert_eq!(d.state(5), DirState::Owned { core: 1 });
    }

    #[test]
    fn sole_sharer_upgrades_free() {
        let mut d = Directory::new();
        d.note_read(9, 0);
        d.read_req(9, 1); // downgrade 0
        d.note_read(9, 1);
        d.note_evict(9, 0);
        assert_eq!(d.write_req(9, 1), DirAction::Grant);
    }

    #[test]
    fn evictions_clean_up() {
        let mut d = Directory::new();
        d.note_read(7, 0);
        d.note_evict(7, 0);
        assert_eq!(d.state(7), DirState::Uncached);
        assert_eq!(d.tracked_lines(), 0);

        d.note_read(8, 0);
        d.read_req(8, 1);
        d.note_read(8, 1);
        d.note_evict(8, 0);
        assert_eq!(d.state(8), DirState::Sharers { mask: 0b10 });
        d.purge(8);
        assert_eq!(d.state(8), DirState::Uncached);
    }
}
