//! Cache substrate: set-associative arrays, MESI coherence state,
//! MSHRs and the L2 directory.
//!
//! These are pure, deterministic data structures; the event-driven wiring
//! (latencies, buses, request ordering) lives in [`crate::system`]. The
//! same structures back both the detailed model and the golden tests
//! against the Python reference (`python/compile/kernels/ref.py`).

pub mod coherence;
pub mod directory;
pub mod mshr;
pub mod prefetch;

pub use coherence::MesiState;
pub use directory::Directory;
pub use mshr::{Mshr, MshrAlloc, MshrFile};

use crate::config::CacheConfig;
use crate::stats::{Counter, StatDump};

/// One cache line's bookkeeping.
#[derive(Clone, Copy, Debug)]
pub struct Line {
    pub tag: u64,
    pub state: MesiState,
    /// LRU stamp; larger = more recently used.
    pub lru: u64,
}

impl Line {
    fn invalid() -> Self {
        Line { tag: 0, state: MesiState::Invalid, lru: 0 }
    }
    pub fn valid(&self) -> bool {
        self.state != MesiState::Invalid
    }
    pub fn dirty(&self) -> bool {
        self.state == MesiState::Modified
    }
}

/// Outcome of a lookup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Access {
    Hit,
    Miss,
}

/// What a fill displaced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Victim {
    None,
    Clean(u64),
    /// Dirty line (address) that must be written back.
    Dirty(u64),
}

#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    pub hits: Counter,
    pub misses: Counter,
    pub evictions: Counter,
    pub writebacks: Counter,
    pub invalidations: Counter,
    pub upgrades: Counter,
}

impl CacheStats {
    pub fn accesses(&self) -> u64 {
        self.hits.get() + self.misses.get()
    }
    pub fn miss_rate(&self) -> f64 {
        let a = self.accesses();
        if a == 0 {
            0.0
        } else {
            self.misses.get() as f64 / a as f64
        }
    }
    pub fn dump(&self, path: &str, d: &mut StatDump) {
        d.counter(&format!("{path}.hits"), &self.hits);
        d.counter(&format!("{path}.misses"), &self.misses);
        d.counter(&format!("{path}.evictions"), &self.evictions);
        d.counter(&format!("{path}.writebacks"), &self.writebacks);
        d.counter(&format!("{path}.invalidations"), &self.invalidations);
        d.push(&format!("{path}.miss_rate"), self.miss_rate());
    }
}

/// Set-associative cache array with true-LRU replacement.
///
/// Addressing: `set = line_addr % sets`, `tag = line_addr / sets`,
/// where `line_addr = paddr >> log2(line)` — identical to the Pallas
/// kernel (`python/compile/kernels/cache_probe.py`) so warm state can be
/// imported/exported across the fast-forward boundary.
#[derive(Clone, Debug)]
pub struct CacheArray {
    pub sets: usize,
    pub ways: usize,
    pub line_bytes: u64,
    lines: Vec<Line>, // sets * ways, row-major
    stamp: u64,
    pub stats: CacheStats,
}

impl CacheArray {
    pub fn new(cfg: &CacheConfig) -> Self {
        let sets = cfg.sets();
        CacheArray {
            sets,
            ways: cfg.assoc,
            line_bytes: cfg.line,
            lines: vec![Line::invalid(); sets * cfg.assoc],
            stamp: 0,
            stats: CacheStats::default(),
        }
    }

    #[inline]
    pub fn line_addr(&self, paddr: u64) -> u64 {
        paddr / self.line_bytes
    }

    #[inline]
    fn set_of(&self, line_addr: u64) -> usize {
        (line_addr % self.sets as u64) as usize
    }

    #[inline]
    fn tag_of(&self, line_addr: u64) -> u64 {
        line_addr / self.sets as u64
    }

    #[inline]
    fn idx(&self, set: usize, way: usize) -> usize {
        set * self.ways + way
    }

    fn bump(&mut self) -> u64 {
        self.stamp += 1;
        self.stamp
    }

    /// Find the way holding `paddr`'s line, if any valid.
    pub fn find(&self, paddr: u64) -> Option<(usize, usize)> {
        let la = self.line_addr(paddr);
        let set = self.set_of(la);
        let tag = self.tag_of(la);
        (0..self.ways).find_map(|w| {
            let l = &self.lines[self.idx(set, w)];
            (l.valid() && l.tag == tag).then_some((set, w))
        })
    }

    pub fn state_of(&self, paddr: u64) -> MesiState {
        self.find(paddr)
            .map(|(s, w)| self.lines[self.idx(s, w)].state)
            .unwrap_or(MesiState::Invalid)
    }

    /// Probe for a read/write; touches LRU on hit. Does NOT fill.
    /// `is_write` distinguishes the coherence requirement: a write hit on
    /// a Shared line is reported as `Hit` but `needs_upgrade` is set.
    pub fn probe(&mut self, paddr: u64, is_write: bool) -> ProbeResult {
        match self.find(paddr) {
            Some((set, way)) => {
                let stamp = self.bump();
                let l = &mut self.lines[set * self.ways + way];
                l.lru = stamp;
                let needs_upgrade = is_write
                    && matches!(l.state, MesiState::Shared);
                if is_write && l.state == MesiState::Exclusive {
                    // Silent E->M upgrade, no bus traffic.
                    l.state = MesiState::Modified;
                }
                if is_write && l.state == MesiState::Modified {
                    // stays M
                }
                if !needs_upgrade {
                    self.stats.hits.inc();
                } else {
                    self.stats.upgrades.inc();
                }
                ProbeResult { access: Access::Hit, needs_upgrade }
            }
            None => {
                self.stats.misses.inc();
                ProbeResult { access: Access::Miss, needs_upgrade: false }
            }
        }
    }

    /// Complete an upgrade: S -> M after the directory acked.
    pub fn finish_upgrade(&mut self, paddr: u64) {
        if let Some((set, way)) = self.find(paddr) {
            let i = self.idx(set, way);
            let l = &mut self.lines[i];
            debug_assert_eq!(l.state, MesiState::Shared);
            l.state = MesiState::Modified;
        }
    }

    /// Install a line in `state`, returning the victim (if any).
    pub fn fill(&mut self, paddr: u64, state: MesiState) -> Victim {
        debug_assert!(state != MesiState::Invalid);
        let la = self.line_addr(paddr);
        let set = self.set_of(la);
        let tag = self.tag_of(la);
        // Already present (e.g. race with a second fill): update state.
        if let Some((s, w)) = self.find(paddr) {
            let stamp = self.bump();
            let l = &mut self.lines[s * self.ways + w];
            l.state = state;
            l.lru = stamp;
            return Victim::None;
        }
        // Choose victim: first invalid way, else true-LRU.
        let mut victim_way = 0;
        let mut best = u64::MAX;
        for w in 0..self.ways {
            let l = &self.lines[self.idx(set, w)];
            if !l.valid() {
                victim_way = w;
                break;
            }
            if l.lru < best {
                best = l.lru;
                victim_way = w;
            }
        }
        let stamp = self.bump();
        let i = self.idx(set, victim_way);
        let old = self.lines[i];
        self.lines[i] = Line { tag, state, lru: stamp };
        if old.valid() {
            self.stats.evictions.inc();
            let old_line_addr = old.tag * self.sets as u64 + set as u64;
            let old_paddr = old_line_addr * self.line_bytes;
            if old.dirty() {
                self.stats.writebacks.inc();
                Victim::Dirty(old_paddr)
            } else {
                Victim::Clean(old_paddr)
            }
        } else {
            Victim::None
        }
    }

    /// Invalidate a line (directory-initiated). Returns the line's dirty
    /// address if a writeback is required.
    pub fn invalidate(&mut self, paddr: u64) -> Option<u64> {
        if let Some((set, way)) = self.find(paddr) {
            let i = self.idx(set, way);
            let was_dirty = self.lines[i].dirty();
            self.lines[i].state = MesiState::Invalid;
            self.stats.invalidations.inc();
            was_dirty.then_some(self.lines[i].tag * self.sets as u64 * self.line_bytes
                + (set as u64) * self.line_bytes)
        } else {
            None
        }
    }

    /// Downgrade M/E -> S (directory-initiated on a remote read).
    /// Returns true if data must be flushed (was Modified).
    pub fn downgrade(&mut self, paddr: u64) -> bool {
        if let Some((set, way)) = self.find(paddr) {
            let i = self.idx(set, way);
            let was_m = self.lines[i].state == MesiState::Modified;
            if self.lines[i].valid() {
                self.lines[i].state = MesiState::Shared;
            }
            was_m
        } else {
            false
        }
    }

    /// Number of valid lines (occupancy, for tests/stats).
    pub fn occupancy(&self) -> usize {
        self.lines.iter().filter(|l| l.valid()).count()
    }

    /// Enumerate resident lines as (line_address, state) — used by the
    /// coherence property tests to check SWMR across caches.
    pub fn valid_lines(&self) -> Vec<(u64, MesiState)> {
        let mut out = Vec::new();
        for set in 0..self.sets {
            for way in 0..self.ways {
                let l = &self.lines[self.idx(set, way)];
                if l.valid() {
                    out.push((
                        l.tag * self.sets as u64 + set as u64,
                        l.state,
                    ));
                }
            }
        }
        out
    }

    /// Export per-line state for the fast-forward boundary
    /// (tags/valid/dirty/lru int32 arrays, kernel layout).
    pub fn export_state(&self) -> (Vec<i32>, Vec<i32>, Vec<i32>, Vec<i32>) {
        let n = self.sets * self.ways;
        let mut tags = vec![0i32; n];
        let mut valid = vec![0i32; n];
        let mut dirty = vec![0i32; n];
        let mut lru = vec![0i32; n];
        // Compress LRU stamps to small i32s preserving order per set.
        for set in 0..self.sets {
            let mut ways: Vec<usize> = (0..self.ways).collect();
            ways.sort_by_key(|&w| self.lines[self.idx(set, w)].lru);
            for (rank, &w) in ways.iter().enumerate() {
                let i = self.idx(set, w);
                let l = &self.lines[i];
                tags[i] = l.tag as i32;
                valid[i] = l.valid() as i32;
                dirty[i] = l.dirty() as i32;
                lru[i] = rank as i32;
            }
        }
        (tags, valid, dirty, lru)
    }

    /// Import state produced by the fast-forward kernel. Warmed lines
    /// enter as Exclusive (clean) or Modified (dirty) — the directory is
    /// rebuilt by the caller.
    pub fn import_state(
        &mut self,
        tags: &[i32],
        valid: &[i32],
        dirty: &[i32],
        lru: &[i32],
    ) {
        assert_eq!(tags.len(), self.sets * self.ways);
        self.stamp += 1;
        let base = self.stamp;
        let mut max_l = 0;
        for i in 0..tags.len() {
            let state = if valid[i] == 0 {
                MesiState::Invalid
            } else if dirty[i] == 1 {
                MesiState::Modified
            } else {
                MesiState::Exclusive
            };
            let lr = lru[i].max(0) as u64;
            max_l = max_l.max(lr);
            self.lines[i] = Line {
                tag: tags[i] as u64,
                state,
                lru: base + lr,
            };
        }
        self.stamp = base + max_l;
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProbeResult {
    pub access: Access,
    /// Write hit on a Shared line: needs a directory upgrade round-trip.
    pub needs_upgrade: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn small() -> CacheArray {
        CacheArray::new(&CacheConfig {
            size: 4 * 64 * 2, // 2 sets x 4 ways x 64B
            assoc: 4,
            line: 64,
            lat_cycles: 1,
            mshrs: 4,
            prefetch: false,
            pf_degree: 0,
        })
    }
    use crate::config::CacheConfig;

    #[test]
    fn geometry_from_config() {
        let c = SimConfig::default();
        let a = CacheArray::new(&c.l1);
        assert_eq!(a.sets, 64);
        assert_eq!(a.ways, 8);
    }

    #[test]
    fn miss_then_hit() {
        let mut a = small();
        assert_eq!(a.probe(0x1000, false).access, Access::Miss);
        assert_eq!(a.fill(0x1000, MesiState::Exclusive), Victim::None);
        assert_eq!(a.probe(0x1000, false).access, Access::Hit);
        assert_eq!(a.stats.hits.get(), 1);
        assert_eq!(a.stats.misses.get(), 1);
    }

    #[test]
    fn lru_eviction_order() {
        let mut a = small();
        // Fill all 4 ways of set 0 (set = line_addr % 2 == 0).
        // line addr = paddr/64; choose addrs with even line addr.
        let addrs: Vec<u64> = (0..4).map(|i| (i * 2) * 128).collect();
        for &ad in &addrs {
            a.probe(ad, false);
            a.fill(ad, MesiState::Exclusive);
        }
        // Touch addr[0] so addr[1] becomes LRU.
        a.probe(addrs[0], false);
        let v = a.fill(8 * 128, MesiState::Exclusive);
        assert_eq!(v, Victim::Clean(addrs[1]));
    }

    #[test]
    fn dirty_eviction_reports_writeback() {
        let mut a = small();
        a.fill(0x0, MesiState::Modified);
        // Evict it by filling 4 more lines in set 0.
        let mut wb = None;
        for i in 1..=4 {
            if let Victim::Dirty(ad) = a.fill(i * 128, MesiState::Exclusive) {
                wb = Some(ad);
            }
        }
        assert_eq!(wb, Some(0x0));
        assert_eq!(a.stats.writebacks.get(), 1);
    }

    #[test]
    fn write_hit_states() {
        let mut a = small();
        a.fill(0x40, MesiState::Exclusive);
        let r = a.probe(0x40, true);
        assert_eq!(r.access, Access::Hit);
        assert!(!r.needs_upgrade); // E -> M silently
        assert_eq!(a.state_of(0x40), MesiState::Modified);

        a.fill(0x80, MesiState::Shared);
        let r = a.probe(0x80, true);
        assert_eq!(r.access, Access::Hit);
        assert!(r.needs_upgrade);
        a.finish_upgrade(0x80);
        assert_eq!(a.state_of(0x80), MesiState::Modified);
    }

    #[test]
    fn invalidate_and_downgrade() {
        let mut a = small();
        a.fill(0x100, MesiState::Modified);
        assert!(a.downgrade(0x100)); // M -> S flushes
        assert_eq!(a.state_of(0x100), MesiState::Shared);
        assert!(a.invalidate(0x100).is_none()); // S -> I, no wb needed
        assert_eq!(a.state_of(0x100), MesiState::Invalid);
        assert_eq!(a.stats.invalidations.get(), 1);
    }

    #[test]
    fn export_import_roundtrip() {
        let mut a = small();
        for i in 0..6u64 {
            a.fill(i * 64, if i % 2 == 0 { MesiState::Modified } else { MesiState::Exclusive });
        }
        let (t, v, d, l) = a.export_state();
        let mut b = small();
        b.import_state(&t, &v, &d, &l);
        assert_eq!(b.occupancy(), a.occupancy());
        for i in 0..6u64 {
            assert_eq!(b.state_of(i * 64).dirtyish(), a.state_of(i * 64).dirtyish());
        }
        // LRU order preserved: evicting from set 0 picks same victim.
        let va = a.fill(100 * 64, MesiState::Exclusive);
        let vb = b.fill(100 * 64, MesiState::Exclusive);
        assert_eq!(va, vb);
    }

    #[test]
    fn miss_rate_math() {
        let mut a = small();
        a.probe(0, false);
        a.fill(0, MesiState::Exclusive);
        a.probe(0, false);
        a.probe(0, false);
        assert!((a.stats.miss_rate() - 1.0 / 3.0).abs() < 1e-12);
    }
}
