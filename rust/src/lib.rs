//! # CXLRAMSim
//!
//! Full-system simulation of CXL memory-expander cards with the expander
//! at its architecturally correct position: **on the I/O bus, behind a CXL
//! Root Complex** — not on the memory bus (the shortcut taken by
//! CXL-DMSim / SimCXL, reproduced here as the `baseline` module for the
//! Fig.-1 ablation).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — discrete-event full-system simulator: CPU
//!   models (in-order + O3), two-level MESI directory-coherent caches,
//!   memory bus, I/O bus, PCIe hierarchy + ECAM config space, CXL.io
//!   register sets (DVSEC, HDM decoders, mailbox/doorbell), the CXL.mem
//!   transaction layer (M2S Req/RwD, S2M NDR/DRS) with packetization at
//!   the root complex and de-packetization at the endpoint, an x86 BIOS
//!   builder (E820/MADT/MCFG/SRAT/CEDT/DSDT) and a guest-OS model that
//!   consumes those tables exactly as a real kernel would.
//! * **L2/L1 (python/, build time only)** — JAX graphs + Pallas kernels,
//!   AOT-lowered to `artifacts/*.hlo.txt`, executed from [`runtime`] via
//!   the PJRT C API: functional cache warming (fast-forward) and the
//!   differentiable latency-bandwidth calibration model.
//!
//! Start with [`system::Machine`] (topology + boot + run) or the
//! `examples/quickstart.rs` end-to-end driver; `README.md` has the
//! layer map and `docs/CONFIG.md` the configuration reference.

pub mod util;
pub mod stats;
pub mod config;
pub mod sim;
pub mod mem;
pub mod cache;
pub mod bus;
pub mod pcie;
pub mod cxl;
pub mod bios;
pub mod guestos;
pub mod cpu;
pub mod workloads;
pub mod system;
pub mod baseline;
pub mod runtime;
pub mod coordinator;
pub mod calibrate;
pub mod trace;
pub mod cli;
