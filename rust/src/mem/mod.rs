//! Memory backends: DRAM timing model, memory controller queue and the
//! sparse functional backing store.

pub mod dram;
pub mod physmem;

pub use dram::{DramTiming, MemCtrl};
pub use physmem::PhysMem;
