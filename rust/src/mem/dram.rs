//! DRAM timing model (banked, open-page, FR-FCFS-lite).
//!
//! Used for both the system DRAM channel and the CXL expander's media.
//! Each bank keeps its open row; an access costs
//!   row hit:      tCAS
//!   row empty:    tRCD + tCAS
//!   row conflict: tRP + tRCD + tCAS
//! plus data-bus serialization (line / bw) and any queueing behind
//! earlier accesses to the same bank / the shared data bus.

use crate::config::DramConfig;
use crate::sim::{ns_to_ticks, ser_ticks, Tick};
use crate::stats::{Counter, Histogram, StatDump};

#[derive(Clone, Debug)]
struct Bank {
    open_row: Option<u64>,
    ready_at: Tick,
}

#[derive(Clone, Debug, Default)]
pub struct DramStats {
    pub reads: Counter,
    pub writes: Counter,
    pub row_hits: Counter,
    pub row_misses: Counter,
    pub row_conflicts: Counter,
    pub latency: Histogram,
    pub busy_ticks: Counter,
}

/// Pure timing calculator: given an arrival tick and address, returns the
/// completion tick. State (open rows, bank/bus occupancy) advances.
#[derive(Clone, Debug)]
pub struct DramTiming {
    cfg: DramConfig,
    banks: Vec<Bank>,
    bus_free_at: Tick,
    pub stats: DramStats,
}

impl DramTiming {
    pub fn new(cfg: &DramConfig) -> Self {
        DramTiming {
            cfg: cfg.clone(),
            banks: vec![
                Bank { open_row: None, ready_at: 0 };
                cfg.banks.max(1)
            ],
            bus_free_at: 0,
            stats: DramStats::default(),
        }
    }

    /// Address mapping: row = addr / row_bytes; bank = row % banks
    /// (row-interleaved across banks, gem5's RoRaBaCoCh-ish default).
    fn map(&self, addr: u64) -> (usize, u64) {
        let row = addr / self.cfg.row_bytes;
        ((row % self.banks.len() as u64) as usize, row)
    }

    /// Schedule one `bytes`-sized access arriving at `at`; returns the
    /// tick when data is fully transferred.
    pub fn access(&mut self, at: Tick, addr: u64, bytes: u64, is_write: bool) -> Tick {
        let (bank_idx, row) = self.map(addr);
        let bank = &mut self.banks[bank_idx];

        // Wait for the bank to be free.
        let start = at.max(bank.ready_at);
        let array_lat = match bank.open_row {
            Some(r) if r == row => {
                self.stats.row_hits.inc();
                ns_to_ticks(self.cfg.t_cas_ns)
            }
            Some(_) => {
                self.stats.row_conflicts.inc();
                ns_to_ticks(
                    self.cfg.t_rp_ns + self.cfg.t_rcd_ns + self.cfg.t_cas_ns,
                )
            }
            None => {
                self.stats.row_misses.inc();
                ns_to_ticks(self.cfg.t_rcd_ns + self.cfg.t_cas_ns)
            }
        };
        bank.open_row = Some(row);

        let data_ready = start + array_lat;
        // Serialize on the shared data bus.
        let xfer = ser_ticks(bytes, self.cfg.bw_gbps).max(1);
        let bus_start = data_ready.max(self.bus_free_at);
        let done = bus_start + xfer;
        self.bus_free_at = done;
        bank.ready_at = done;

        if is_write {
            self.stats.writes.inc();
        } else {
            self.stats.reads.inc();
        }
        self.stats.latency.sample(done - at);
        self.stats.busy_ticks.add(xfer);
        done
    }

    pub fn row_hit_rate(&self) -> f64 {
        let h = self.stats.row_hits.get();
        let t = h + self.stats.row_misses.get() + self.stats.row_conflicts.get();
        if t == 0 {
            0.0
        } else {
            h as f64 / t as f64
        }
    }

    pub fn dump(&self, path: &str, d: &mut StatDump) {
        d.counter(&format!("{path}.reads"), &self.stats.reads);
        d.counter(&format!("{path}.writes"), &self.stats.writes);
        d.push(&format!("{path}.row_hit_rate"), self.row_hit_rate());
        d.hist(&format!("{path}.latency_ticks"), &self.stats.latency);
    }
}

/// Memory controller: bounded request queue in front of [`DramTiming`].
/// Models queueing delay under load; the system layer uses `enqueue` and
/// receives the completion tick.
#[derive(Clone, Debug)]
pub struct MemCtrl {
    pub timing: DramTiming,
    queue_depth: usize,
    inflight: Vec<Tick>, // completion ticks of queued requests
    pub rejected: u64,
}

impl MemCtrl {
    pub fn new(cfg: &DramConfig, queue_depth: usize) -> Self {
        MemCtrl {
            timing: DramTiming::new(cfg),
            queue_depth: queue_depth.max(1),
            inflight: Vec::new(),
            rejected: 0,
        }
    }

    fn gc(&mut self, now: Tick) {
        self.inflight.retain(|&t| t > now);
    }

    pub fn queue_len(&mut self, now: Tick) -> usize {
        self.gc(now);
        self.inflight.len()
    }

    /// Returns `Some(done_tick)` or `None` if the queue is full (caller
    /// must retry — back-pressure propagates to the bus).
    pub fn enqueue(
        &mut self,
        now: Tick,
        addr: u64,
        bytes: u64,
        is_write: bool,
    ) -> Option<Tick> {
        self.gc(now);
        if self.inflight.len() >= self.queue_depth {
            self.rejected += 1;
            return None;
        }
        let done = self.timing.access(now, addr, bytes, is_write);
        self.inflight.push(done);
        Some(done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn cfg() -> DramConfig {
        SimConfig::default().sys_dram
    }

    #[test]
    fn row_hit_faster_than_conflict() {
        let mut d = DramTiming::new(&cfg());
        let t1 = d.access(0, 0, 64, false); // row miss (empty)
        let t2 = d.access(t1, 64, 64, false) - t1; // same row: hit
        let far = 17 * 8192; // same bank (17 % 16 = 1)... ensure same bank:
        // bank = row % banks; row0 = 0 -> bank 0; row 16 -> bank 0.
        let t3start = t1 + t2;
        let t3 = d.access(t3start, 16 * 8192, 64, false) - t3start; // conflict
        assert!(t2 < t3, "hit {t2} !< conflict {t3}");
        let _ = far;
        assert_eq!(d.stats.row_hits.get(), 1);
        assert_eq!(d.stats.row_conflicts.get(), 1);
    }

    #[test]
    fn banks_overlap_but_bus_serializes() {
        let mut d = DramTiming::new(&cfg());
        // Two different banks, same arrival: completions must not be
        // equal (bus serialization) but the second should finish well
        // before 2x the isolated latency (bank overlap).
        let iso = {
            let mut d2 = DramTiming::new(&cfg());
            d2.access(0, 0, 64, false)
        };
        let a = d.access(0, 0, 64, false);
        let b = d.access(0, 8192, 64, false); // row 1 -> bank 1
        assert!(b > a);
        assert!(b < 2 * iso, "no overlap: b={b} iso={iso}");
    }

    #[test]
    fn same_bank_serializes_fully() {
        let mut d = DramTiming::new(&cfg());
        let a = d.access(0, 0, 64, false);
        let b = d.access(0, 16 * 8192, 64, false); // same bank, other row
        assert!(b >= a + ns_to_ticks(cfg().t_rp_ns));
    }

    #[test]
    fn ctrl_backpressures_when_full() {
        let mut c = MemCtrl::new(&cfg(), 2);
        assert!(c.enqueue(0, 0, 64, false).is_some());
        assert!(c.enqueue(0, 8192, 64, false).is_some());
        assert!(c.enqueue(0, 2 * 8192, 64, false).is_none());
        assert_eq!(c.rejected, 1);
        // After completions pass, room again.
        let later = 1_000_000;
        assert!(c.enqueue(later, 3 * 8192, 64, false).is_some());
    }

    #[test]
    fn write_read_counted() {
        let mut d = DramTiming::new(&cfg());
        d.access(0, 0, 64, true);
        d.access(0, 64, 64, false);
        assert_eq!(d.stats.writes.get(), 1);
        assert_eq!(d.stats.reads.get(), 1);
    }
}
