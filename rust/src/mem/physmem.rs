//! Sparse functional backing store.
//!
//! The simulator is functional-first: loads and stores actually move
//! data, so STREAM can verify its results and the guest's page tables /
//! BIOS tables are real bytes in simulated physical memory. Backed by a
//! page-granular hash map so multi-GiB address spaces cost only what is
//! touched.

use crate::util::fxhash::FxHashMap;

const PAGE: u64 = 4096;

#[derive(Default)]
pub struct PhysMem {
    pages: FxHashMap<u64, Box<[u8; PAGE as usize]>>,
}

impl PhysMem {
    pub fn new() -> Self {
        Self::default()
    }

    fn page_mut(&mut self, pfn: u64) -> &mut [u8; PAGE as usize] {
        self.pages
            .entry(pfn)
            .or_insert_with(|| Box::new([0u8; PAGE as usize]))
    }

    pub fn write(&mut self, addr: u64, data: &[u8]) {
        let mut off = 0usize;
        while off < data.len() {
            let a = addr + off as u64;
            let pfn = a / PAGE;
            let po = (a % PAGE) as usize;
            let n = (PAGE as usize - po).min(data.len() - off);
            self.page_mut(pfn)[po..po + n]
                .copy_from_slice(&data[off..off + n]);
            off += n;
        }
    }

    pub fn read(&self, addr: u64, out: &mut [u8]) {
        let mut off = 0usize;
        while off < out.len() {
            let a = addr + off as u64;
            let pfn = a / PAGE;
            let po = (a % PAGE) as usize;
            let n = (PAGE as usize - po).min(out.len() - off);
            match self.pages.get(&pfn) {
                Some(p) => out[off..off + n].copy_from_slice(&p[po..po + n]),
                None => out[off..off + n].fill(0),
            }
            off += n;
        }
    }

    /// 8-byte read — the simulator's per-operation functional access.
    /// Fast path for the (overwhelmingly common) page-internal case;
    /// perf-pass change #2 (EXPERIMENTS.md §Perf).
    pub fn read_u64(&self, addr: u64) -> u64 {
        let po = (addr % PAGE) as usize;
        if po <= PAGE as usize - 8 {
            return match self.pages.get(&(addr / PAGE)) {
                Some(p) => u64::from_le_bytes(
                    p[po..po + 8].try_into().unwrap(),
                ),
                None => 0,
            };
        }
        let mut b = [0u8; 8];
        self.read(addr, &mut b);
        u64::from_le_bytes(b)
    }

    pub fn write_u64(&mut self, addr: u64, v: u64) {
        let po = (addr % PAGE) as usize;
        if po <= PAGE as usize - 8 {
            let p = self.page_mut(addr / PAGE);
            p[po..po + 8].copy_from_slice(&v.to_le_bytes());
            return;
        }
        self.write(addr, &v.to_le_bytes());
    }

    pub fn read_u32(&self, addr: u64) -> u32 {
        let mut b = [0u8; 4];
        self.read(addr, &mut b);
        u32::from_le_bytes(b)
    }

    pub fn write_u32(&mut self, addr: u64, v: u32) {
        self.write(addr, &v.to_le_bytes());
    }

    pub fn read_f64(&self, addr: u64) -> f64 {
        f64::from_bits(self.read_u64(addr))
    }

    pub fn write_f64(&mut self, addr: u64, v: f64) {
        self.write_u64(addr, v.to_bits());
    }

    /// Number of materialized pages (footprint accounting).
    pub fn resident_pages(&self) -> usize {
        self.pages.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_fill_default() {
        let m = PhysMem::new();
        let mut b = [1u8; 16];
        m.read(0xdead_0000, &mut b);
        assert_eq!(b, [0u8; 16]);
    }

    #[test]
    fn rw_roundtrip_cross_page() {
        let mut m = PhysMem::new();
        let addr = PAGE - 3; // straddles two pages
        m.write(addr, &[1, 2, 3, 4, 5, 6]);
        let mut b = [0u8; 6];
        m.read(addr, &mut b);
        assert_eq!(b, [1, 2, 3, 4, 5, 6]);
        assert_eq!(m.resident_pages(), 2);
    }

    #[test]
    fn typed_accessors() {
        let mut m = PhysMem::new();
        m.write_u64(8, 0x0123456789abcdef);
        assert_eq!(m.read_u64(8), 0x0123456789abcdef);
        m.write_u32(100, 42);
        assert_eq!(m.read_u32(100), 42);
        m.write_f64(200, 3.5);
        assert_eq!(m.read_f64(200), 3.5);
    }

    #[test]
    fn sparse_footprint() {
        let mut m = PhysMem::new();
        m.write_u64(0, 1);
        m.write_u64(1 << 40, 2); // a terabyte away
        assert_eq!(m.resident_pages(), 2);
    }
}
