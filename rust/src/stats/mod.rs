//! Statistics framework (gem5-style, minimal).
//!
//! Components own concrete stat structs made of [`Counter`],
//! [`Histogram`] and [`RunningStats`]; the machine aggregates them into a
//! [`StatDump`] (name -> value tree rendered as JSON or text). Keeping
//! stats as plain fields (not a string-keyed registry) keeps the hot path
//! allocation-free; naming happens only at dump time.

use std::fmt::Write as _;

use crate::util::json::Json;

/// Monotonic event counter.
#[derive(Clone, Copy, Debug, Default)]
pub struct Counter(pub u64);

impl Counter {
    #[inline]
    pub fn inc(&mut self) {
        self.0 += 1;
    }
    #[inline]
    pub fn add(&mut self, n: u64) {
        self.0 += n;
    }
    pub fn get(&self) -> u64 {
        self.0
    }
}

/// Mean/min/max tracker for latencies etc.
#[derive(Clone, Copy, Debug)]
pub struct RunningStats {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    sum_sq: f64,
}

impl Default for RunningStats {
    fn default() -> Self {
        RunningStats {
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sum_sq: 0.0,
        }
    }
}

impl RunningStats {
    #[inline]
    pub fn sample(&mut self, v: f64) {
        self.n += 1;
        self.sum += v;
        self.sum_sq += v * v;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        ((self.sum_sq / self.n as f64) - m * m).max(0.0).sqrt()
    }
}

/// Power-of-two bucketed histogram (bucket i covers [2^i, 2^(i+1))).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub buckets: Vec<u64>,
    pub underflow: u64, // value == 0
    pub stats: RunningStats,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: vec![0; 40], underflow: 0, stats: RunningStats::default() }
    }
}

impl Histogram {
    #[inline]
    pub fn sample(&mut self, v: u64) {
        self.stats.sample(v as f64);
        if v == 0 {
            self.underflow += 1;
            return;
        }
        let b = (63 - v.leading_zeros()) as usize;
        let b = b.min(self.buckets.len() - 1);
        self.buckets[b] += 1;
    }

    pub fn count(&self) -> u64 {
        self.underflow + self.buckets.iter().sum::<u64>()
    }

    /// Approximate percentile from bucket boundaries (upper edge).
    pub fn percentile(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * p).ceil() as u64;
        let mut seen = self.underflow;
        if seen >= target {
            return 0;
        }
        for (i, c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        u64::MAX
    }
}

/// Exact-percentile sample set (nearest-rank over the sorted samples).
///
/// [`Histogram`]'s power-of-two buckets are fine for latencies spanning
/// decades, but per-request serving percentiles (`serve.p99_ns`) need
/// exact tail values — a p99 that rounds to the next power of two is
/// useless for a DRAM-vs-CXL tier-mix comparison. Sample counts here
/// are per-request (thousands), not per-access (millions), so keeping
/// the raw values is cheap.
#[derive(Clone, Debug, Default)]
pub struct Samples {
    vals: Vec<u64>,
}

impl Samples {
    pub fn add(&mut self, v: u64) {
        self.vals.push(v);
    }

    pub fn extend(&mut self, vs: &[u64]) {
        self.vals.extend_from_slice(vs);
    }

    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.vals.is_empty() {
            return 0.0;
        }
        self.vals.iter().sum::<u64>() as f64 / self.vals.len() as f64
    }

    /// Nearest-rank percentile (`p` a fraction, e.g. 0.99): the value
    /// at rank `ceil(p * n)` of the sorted samples. 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.vals.is_empty() {
            return 0;
        }
        let mut sorted = self.vals.clone();
        sorted.sort_unstable();
        let n = sorted.len();
        let rank = ((p * n as f64).ceil() as usize).clamp(1, n);
        sorted[rank - 1]
    }
}

/// A flat named dump of stats: `(path, value)` pairs.
#[derive(Clone, Debug, Default)]
pub struct StatDump {
    pub entries: Vec<(String, f64)>,
}

impl StatDump {
    pub fn push(&mut self, path: &str, v: f64) {
        self.entries.push((path.to_string(), v));
    }

    pub fn counter(&mut self, path: &str, c: &Counter) {
        self.push(path, c.get() as f64);
    }

    pub fn running(&mut self, path: &str, r: &RunningStats) {
        self.push(&format!("{path}.n"), r.n as f64);
        self.push(&format!("{path}.mean"), r.mean());
        if r.n > 0 {
            self.push(&format!("{path}.min"), r.min);
            self.push(&format!("{path}.max"), r.max);
        }
    }

    pub fn hist(&mut self, path: &str, h: &Histogram) {
        self.push(&format!("{path}.count"), h.count() as f64);
        self.push(&format!("{path}.mean"), h.stats.mean());
        self.push(&format!("{path}.p50"), h.percentile(0.5) as f64);
        self.push(&format!("{path}.p99"), h.percentile(0.99) as f64);
    }

    pub fn get(&self, path: &str) -> Option<f64> {
        self.entries.iter().find(|(k, _)| k == path).map(|(_, v)| *v)
    }

    pub fn to_text(&self) -> String {
        let mut s = String::new();
        let width = self
            .entries
            .iter()
            .map(|(k, _)| k.len())
            .max()
            .unwrap_or(0);
        for (k, v) in &self.entries {
            if v.fract() == 0.0 && v.abs() < 1e15 {
                let _ = writeln!(s, "{k:<width$}  {}", *v as i64);
            } else {
                let _ = writeln!(s, "{k:<width$}  {v:.6}");
            }
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.entries
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v)))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let mut c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn running_stats_moments() {
        let mut r = RunningStats::default();
        for v in [1.0, 2.0, 3.0, 4.0] {
            r.sample(v);
        }
        assert_eq!(r.n, 4);
        assert!((r.mean() - 2.5).abs() < 1e-12);
        assert_eq!(r.min, 1.0);
        assert_eq!(r.max, 4.0);
        assert!((r.stddev() - 1.118033988749895).abs() < 1e-9);
    }

    #[test]
    fn histogram_buckets_and_percentiles() {
        let mut h = Histogram::default();
        for v in [0u64, 1, 1, 2, 3, 4, 100, 1000] {
            h.sample(v);
        }
        assert_eq!(h.count(), 8);
        assert_eq!(h.underflow, 1);
        assert!(h.percentile(0.5) <= 8);
        assert!(h.percentile(1.0) >= 1000);
    }

    #[test]
    fn samples_empty_is_zero() {
        let s = Samples::default();
        assert!(s.is_empty());
        assert_eq!(s.percentile(0.5), 0);
        assert_eq!(s.percentile(0.99), 0);
        assert_eq!(s.mean(), 0.0);
    }

    #[test]
    fn samples_single_value_is_every_percentile() {
        let mut s = Samples::default();
        s.add(12345);
        for p in [0.01, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(s.percentile(p), 12345, "p={p}");
        }
        assert_eq!(s.mean(), 12345.0);
    }

    #[test]
    fn samples_p99_heavy_tail_is_exact() {
        // 900 fast requests + 100 pathological stragglers: p50 must
        // stay on the body, p99 must land exactly on the tail value —
        // not a power-of-two bucket edge.
        let mut s = Samples::default();
        for _ in 0..900 {
            s.add(10);
        }
        for _ in 0..100 {
            s.add(1_000_000);
        }
        assert_eq!(s.len(), 1000);
        assert_eq!(s.percentile(0.5), 10);
        assert_eq!(s.percentile(0.90), 1_000_000);
        assert_eq!(s.percentile(0.99), 1_000_000);
        // The same tail through the pow2 Histogram rounds up to a
        // bucket edge — the imprecision Samples exists to avoid.
        let mut h = Histogram::default();
        for _ in 0..900 {
            h.sample(10);
        }
        for _ in 0..100 {
            h.sample(1_000_000);
        }
        assert_ne!(h.percentile(0.99), 1_000_000);
    }

    #[test]
    fn samples_percentiles_are_order_independent() {
        let mut a = Samples::default();
        let mut b = Samples::default();
        for v in [5u64, 1, 9, 3, 7] {
            a.add(v);
        }
        for v in [9u64, 7, 5, 3, 1] {
            b.add(v);
        }
        assert_eq!(a.percentile(0.5), b.percentile(0.5));
        assert_eq!(a.percentile(0.5), 5);
        assert_eq!(a.percentile(1.0), 9);
        assert_eq!(a.percentile(0.01), 1);
    }

    #[test]
    fn dump_text_and_json() {
        let mut d = StatDump::default();
        d.push("a.b", 1.0);
        d.push("a.c", 2.5);
        let txt = d.to_text();
        assert!(txt.contains("a.b"));
        assert!(txt.contains("2.5"));
        assert_eq!(d.get("a.c"), Some(2.5));
        let j = d.to_json();
        assert_eq!(j.get("a.b").unwrap().as_f64(), Some(1.0));
    }
}
