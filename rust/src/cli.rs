//! CLI front end (hand-rolled — no clap in this offline environment).
//!
//! Subcommands:
//!   boot                          boot + print the guest's view
//!   run                           run a workload (stream|random|chase|kv)
//!   sweep                         Fig.-5 style WSS x interleave sweep
//!   calibrate                     fit link params to a vendor curve
//!   table1                        print the Table-I configuration
//!   stats                         run + full stat dump
//!
//! Common flags: --config <file.toml>, --set key=value (repeatable),
//! --policy <local|bind:N|preferred:N|interleave:SPEC>, --cpu <inorder|o3>,
//! --workload <name>, --wss-mult <N>, --attach <iobus|membus>,
//! --prog-model <znuma|flat>, --artifacts <dir>.

use anyhow::{bail, Context, Result};

use crate::config::SimConfig;
use crate::guestos::{MemPolicy, ProgModel};
use crate::system::Machine;
use crate::trace::{EventTrace, Recorder};
use crate::util::bench::Table;
use crate::workloads::{
    PointerChase, RandomAccess, Replay, Serve, Stream, StreamKernel,
    TieredKv, Workload,
};

#[derive(Debug, Default)]
pub struct Args {
    pub cmd: String,
    pub config_path: Option<String>,
    pub sets: Vec<String>,
    pub policy: String,
    pub workload: String,
    /// `--workload` was given explicitly (it then beats `[workload]
    /// kind` from the config file).
    pub workload_explicit: bool,
    pub wss_mult: u64,
    pub prog_model: ProgModel,
    pub artifacts: String,
    pub verify: bool,
    /// Fabric-Manager event script: one `@<time> bind|unbind …` line
    /// per scheduled action (appended to any `[fm] events` from TOML).
    pub fm_script: Option<String>,
    /// Capture the run's memory events into this v2 trace file.
    pub trace_out: Option<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args {
            cmd: argv.first().cloned().unwrap_or_else(|| "help".into()),
            policy: "local".into(),
            workload: "stream-triad".into(),
            wss_mult: 4,
            prog_model: ProgModel::Znuma,
            artifacts: "artifacts".into(),
            verify: false,
            ..Default::default()
        };
        let mut i = 1;
        while i < argv.len() {
            let flag = argv[i].clone();
            let val = |i: &mut usize| -> Result<String> {
                *i += 1;
                argv.get(*i)
                    .cloned()
                    .with_context(|| format!("{flag} needs a value"))
            };
            match flag.as_str() {
                "--config" => a.config_path = Some(val(&mut i)?),
                "--set" => {
                    let v = val(&mut i)?;
                    a.sets.push(v);
                }
                "--policy" => a.policy = val(&mut i)?,
                "--workload" => {
                    a.workload = val(&mut i)?;
                    a.workload_explicit = true;
                }
                "--trace-out" => a.trace_out = Some(val(&mut i)?),
                "--wss-mult" => {
                    a.wss_mult = val(&mut i)?.parse().context("--wss-mult")?
                }
                "--cpu" => {
                    let v = val(&mut i)?;
                    a.sets.push(format!("system.cpu=\"{v}\""));
                }
                "--attach" => {
                    let v = val(&mut i)?;
                    a.sets.push(format!("cxl.attach=\"{v}\""));
                }
                "--devices" => {
                    let v = val(&mut i)?;
                    a.sets.push(format!("cxl.devices={v}"));
                }
                "--hosts" => {
                    let v = val(&mut i)?;
                    a.sets.push(format!("system.hosts={v}"));
                }
                "--threads" => {
                    let v = val(&mut i)?;
                    a.sets.push(format!("sim.threads={v}"));
                }
                "--commit-lanes" => {
                    // "auto" is spelled 0 in the config (the override
                    // parser only accepts bare scalars).
                    let v = val(&mut i)?;
                    let v = if v.eq_ignore_ascii_case("auto") {
                        "0".to_string()
                    } else {
                        v
                    };
                    a.sets.push(format!("sim.commit_lanes={v}"));
                }
                "--switches" => {
                    let v = val(&mut i)?;
                    a.sets.push(format!("cxl.switches={v}"));
                }
                "--ways" => {
                    let v = val(&mut i)?;
                    a.sets.push(format!("cxl.interleave_ways={v}"));
                }
                "--granularity" => {
                    let v = val(&mut i)?;
                    a.sets.push(format!("cxl.interleave_granularity={v}"));
                }
                "--prog-model" => {
                    a.prog_model = match val(&mut i)?.as_str() {
                        "znuma" => ProgModel::Znuma,
                        "flat" => ProgModel::Flat,
                        other => bail!("unknown prog model '{other}'"),
                    }
                }
                "--artifacts" => a.artifacts = val(&mut i)?,
                "--fm-script" => a.fm_script = Some(val(&mut i)?),
                "--fm-policy" => {
                    let v = val(&mut i)?;
                    a.sets.push(format!("fm.policy=\"{v}\""));
                }
                "--check" => {
                    a.sets.push("sim.check=true".to_string());
                }
                "--verify" => a.verify = true,
                other => bail!("unknown flag '{other}' (see `cxlramsim help`)"),
            }
            i += 1;
        }
        Ok(a)
    }

    pub fn config(&self) -> Result<SimConfig> {
        let text = match &self.config_path {
            Some(p) => std::fs::read_to_string(p)
                .with_context(|| format!("reading {p}"))?,
            None => String::new(),
        };
        let mut cfg = SimConfig::from_toml(&text, &self.sets)?;
        if let Some(p) = &self.fm_script {
            let script = std::fs::read_to_string(p)
                .with_context(|| format!("reading FM script {p}"))?;
            for line in script.lines() {
                let line = line.trim();
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                cfg.fm_events
                    .push(crate::config::FmEventDef::parse(line)?);
            }
            // The schedule changes the BIOS window layout and must
            // replay cleanly against the boot-time LD assignment.
            cfg.validate()?;
        }
        Ok(cfg)
    }

    pub fn mem_policy(&self) -> Result<MemPolicy> {
        MemPolicy::parse(&self.policy)
    }

    pub fn make_workload(&self, cfg: &SimConfig) -> Result<Box<dyn Workload>> {
        let w: Box<dyn Workload> = match self.workload.as_str() {
            "stream-copy" => Box::new(Stream::for_wss(
                StreamKernel::Copy,
                cfg.l2.size,
                self.wss_mult,
            )),
            "stream-scale" => Box::new(Stream::for_wss(
                StreamKernel::Scale,
                cfg.l2.size,
                self.wss_mult,
            )),
            "stream-add" => Box::new(Stream::for_wss(
                StreamKernel::Add,
                cfg.l2.size,
                self.wss_mult,
            )),
            "stream-triad" => Box::new(Stream::for_wss(
                StreamKernel::Triad,
                cfg.l2.size,
                self.wss_mult,
            )),
            "random" => Box::new(RandomAccess::new(
                cfg.l2.size * self.wss_mult,
                50_000,
                0.2,
                cfg.seed,
            )),
            "chase" => Box::new(PointerChase::new(
                cfg.l2.size * self.wss_mult / 64,
                20_000,
                cfg.seed,
            )),
            "kv" => Box::new(TieredKv::new(4096, 256, 20_000, cfg.seed)),
            other => bail!("unknown workload '{other}'"),
        };
        Ok(w)
    }

    /// The workload kind this invocation runs: an explicit `--workload`
    /// wins, else the config's `[workload] kind`, else the CLI default.
    pub fn effective_workload(&self, cfg: &SimConfig) -> String {
        if self.workload_explicit {
            return self.workload.clone();
        }
        cfg.workload
            .kind
            .clone()
            .unwrap_or_else(|| self.workload.clone())
    }

    /// Workloads to attach to host `h` of the booted machine `m`: one
    /// per recorded core for replay, a single workload otherwise.
    /// Serve gets its DRAM/CXL tier policies from the host's booted
    /// NUMA topology, which is why this needs the machine.
    pub fn make_workloads_for(
        &self,
        cfg: &SimConfig,
        m: &Machine,
        h: usize,
    ) -> Result<Vec<Box<dyn Workload>>> {
        match self.effective_workload(cfg).as_str() {
            "serve" => {
                let (hot, cold) = m.hosts[h]
                    .guest
                    .as_ref()
                    .context("machine must boot before serve attaches")?
                    .alloc
                    .tier_policies();
                // Per-host seed decorrelation keeps a multi-host fleet
                // from issuing clone request streams (still fully
                // deterministic for a given config seed).
                let seed = cfg
                    .seed
                    .wrapping_add((h as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                Ok(vec![Box::new(Serve::new(
                    cfg.workload.serve.clone(),
                    hot,
                    cold,
                    seed,
                ))])
            }
            "replay" => {
                let path = cfg.workload.trace.as_ref().context(
                    "workload.trace must name the trace to replay \
                     (set [workload] trace = \"file.cxlt\")",
                )?;
                let t = EventTrace::load(std::path::Path::new(path))?;
                Ok(Replay::for_host(&t, h))
            }
            _ => Ok(vec![self.make_workload(cfg)?]),
        }
    }
}

pub fn print_help() {
    println!(
        "cxlramsim — full-system CXL memory expander simulation\n\
         \n\
         USAGE: cxlramsim <boot|run|sweep|calibrate|table1|stats|help> [flags]\n\
         \n\
         FLAGS:\n\
           --config <file.toml>   load configuration\n\
           --set key=value        override a config key (repeatable)\n\
           --cpu inorder|o3       CPU model\n\
           --attach iobus|membus  CXL attach point (membus = baseline)\n\
           --hosts H              simulated hosts sharing the fabric\n\
                                  (LD pooling via [host.N] lds lists)\n\
           --threads N            worker threads for the parallel event\n\
                                  loop (1 = serial; results are\n\
                                  bit-identical at every N)\n\
           --commit-lanes L       fabric-commit lanes sharded by device\n\
                                  (auto = follow --threads; bit-identical\n\
                                  at every L)\n\
           --devices N            number of CXL expander cards\n\
           --switches M           CXL switches between root ports and\n\
                                  endpoints (0 = direct attach)\n\
           --ways W               interleave ways across devices (0=auto)\n\
           --granularity B        interleave granularity in bytes\n\
           --policy P             local | bind:N | preferred:N |\n\
                                  interleave:0=3,1=1\n\
           --workload W           stream-{{copy,scale,add,triad}} | random |\n\
                                  chase | kv | serve | replay\n\
                                  (serve/replay read their parameters from\n\
                                  the [workload] / [workload.serve] config\n\
                                  sections)\n\
           --wss-mult N           working set = N x L2 size (default 4)\n\
           --trace-out FILE       capture the run's memory events into a\n\
                                  v2 .cxlt trace (replay it with\n\
                                  [workload] kind = \"replay\")\n\
           --fm-script FILE       runtime Fabric-Manager schedule: one\n\
                                  '@<time> unbind devN.ldK' or\n\
                                  '@<time> bind devN.ldK hostH' per line\n\
                                  (LD hot remove/add while guests run)\n\
           --fm-policy P          telemetry-driven FM policy instead of\n\
                                  a schedule: capacity_rebalance |\n\
                                  bandwidth_fairness ([fm] epoch /\n\
                                  min_residency / cooldown /\n\
                                  refusal_backoff tune it via --set)\n\
           --prog-model M         znuma | flat\n\
           --artifacts DIR        AOT artifact directory\n\
           --check                arm the runtime protocol-invariant\n\
                                  checker ([sim] check): credit\n\
                                  conservation, commit ordering, window\n\
                                  disjointness, snoop-filter soundness\n\
           --verify               functional verification after the run"
    );
}

pub fn cmd_boot(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let mut m = Machine::new(cfg)?;
    m.boot(args.prog_model)?;
    let nhosts = m.hosts.len();
    for h in 0..nhosts {
        if nhosts > 1 {
            println!("\n===== host {h} =====");
        }
        let memdevs = {
            let g = m.hosts[h].guest.as_ref().unwrap();
            for line in &g.boot_log {
                println!("[guest] {line}");
            }
            println!("\nNUMA topology:");
            for n in &g.alloc.nodes {
                println!(
                    "  node {}: {:#x}..{:#x} {} {}",
                    n.id,
                    n.base,
                    n.base + n.size,
                    if n.has_cpus { "cpus" } else { "CPU-LESS (zNUMA)" },
                    if n.online { "online" } else { "offline" }
                );
            }
            g.memdevs.clone()
        };
        if !memdevs.is_empty() {
            println!("\ncxl list:");
            let mut world = m.mmio_world(h);
            for (i, md) in memdevs.iter().enumerate() {
                println!(
                    "  {}",
                    crate::guestos::cxlcli::cxl_list(&mut world, md, i)?
                );
            }
        }
    }
    Ok(())
}

pub fn cmd_run(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let mut m = Machine::new(cfg.clone())?;
    m.boot(args.prog_model)?;
    // Every host runs the same workload/policy concurrently (policy
    // node ids are host-local), so a --hosts N run actually measures
    // the N-host contention scenario rather than idling hosts 1..N.
    let policy = args.mem_policy()?;
    let recorder = args.trace_out.as_ref().map(|_| Recorder::new());
    let mut name = String::from("idle");
    for h in 0..m.hosts.len() {
        let mut wls = args.make_workloads_for(&cfg, &m, h)?;
        if h == 0 {
            if let Some(w) = wls.first() {
                name = w.name();
            }
        }
        if let Some(rec) = &recorder {
            wls = wls
                .into_iter()
                .enumerate()
                .map(|(c, w)| rec.wrap(h, c, w))
                .collect();
        }
        m.attach_workloads_to(h, wls, &policy).with_context(
            || {
                format!(
                    "host {h}: attaching workload (the policy's NUMA \
                     node ids are host-local — does this host own a \
                     matching node?)"
                )
            },
        )?;
    }
    let s = m.run(None);
    println!("workload: {name}");
    println!("policy:   {}", args.policy);
    println!(
        "time: {:.3} ms   bandwidth: {:.2} GB/s",
        s.seconds * 1e3,
        s.bandwidth_gbps
    );
    println!(
        "L1 miss rate: {:.4}   L2 (LLC) miss rate: {:.4}",
        s.l1_miss_rate, s.l2_miss_rate
    );
    println!(
        "memory: {} DRAM fills, {} CXL fills (lat {:.0} / {:.0} ns)",
        s.dram_accesses, s.cxl_accesses, s.avg_lat_dram_ns, s.avg_lat_cxl_ns
    );
    if s.cxl_dev_fills.len() > 1 {
        let per: Vec<String> = s
            .cxl_dev_fills
            .iter()
            .enumerate()
            .map(|(i, f)| format!("dev{i}={f}"))
            .collect();
        println!("per-device fills: {}", per.join("  "));
    }
    println!(
        "CXL.mem: M2S Req {}  RwD {}  |  S2M NDR {}  DRS {}",
        s.m2s_req, s.m2s_rwd, s.s2m_ndr, s.s2m_drs
    );
    if args.verify {
        m.verify().map_err(|e| anyhow::anyhow!(e))?;
        println!("functional verification: OK");
    }
    if let (Some(rec), Some(path)) = (&recorder, &args.trace_out) {
        let t = rec.take();
        t.save(std::path::Path::new(path))?;
        println!(
            "trace: {} vmas, {} inits, {} events -> {path}",
            t.vmas.len(),
            t.inits.len(),
            t.len()
        );
    }
    Ok(())
}

pub fn cmd_table1(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let mut t = Table::new(
        "TABLE I — SIMULATION CONFIGURATION",
        &["Component", "Specification"],
    );
    for (k, v) in cfg.table1_rows() {
        t.row(&[k, v]);
    }
    t.print();
    Ok(())
}

pub fn cmd_stats(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let mut m = Machine::new(cfg.clone())?;
    m.boot(args.prog_model)?;
    let policy = args.mem_policy()?;
    let recorder = args.trace_out.as_ref().map(|_| Recorder::new());
    for h in 0..m.hosts.len() {
        let mut wls = args.make_workloads_for(&cfg, &m, h)?;
        if let Some(rec) = &recorder {
            wls = wls
                .into_iter()
                .enumerate()
                .map(|(c, w)| rec.wrap(h, c, w))
                .collect();
        }
        m.attach_workloads_to(h, wls, &policy)
            .with_context(|| format!("host {h}: attaching workload"))?;
    }
    m.run(None);
    print!("{}", m.dump_stats_full().to_text());
    if let (Some(rec), Some(path)) = (&recorder, &args.trace_out) {
        let t = rec.take();
        t.save(std::path::Path::new(path))?;
        // stderr: stdout stays a pure, diffable stat dump.
        eprintln!(
            "trace: {} vmas, {} inits, {} events -> {path}",
            t.vmas.len(),
            t.inits.len(),
            t.len()
        );
    }
    Ok(())
}

pub fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let ratios: [(&str, Vec<(u32, u32)>); 5] = [
        ("100:0", vec![(0, 1)]),
        ("75:25", vec![(0, 3), (1, 1)]),
        ("50:50", vec![(0, 1), (1, 1)]),
        ("25:75", vec![(0, 1), (1, 3)]),
        ("0:100", vec![(1, 1)]),
    ];
    let mut t = Table::new(
        "STREAM LLC MISS-RATE SWEEP (Fig. 5 axes)",
        &["wss(xL2)", "ratio", "L2 miss", "GB/s", "CXL fills"],
    );
    for mult in [2u64, 4, 6, 8] {
        for (label, weights) in &ratios {
            let mut m = Machine::new(cfg.clone())?;
            m.boot(args.prog_model)?;
            let wl = Stream::for_wss(StreamKernel::Triad, cfg.l2.size, mult);
            m.attach_workloads(
                vec![Box::new(wl)],
                &MemPolicy::Interleave { weights: weights.clone() },
            )?;
            let s = m.run(None);
            t.row(&[
                mult.to_string(),
                label.to_string(),
                format!("{:.4}", s.l2_miss_rate),
                format!("{:.2}", s.bandwidth_gbps),
                s.cxl_accesses.to_string(),
            ]);
        }
    }
    t.print();
    Ok(())
}

pub fn cmd_calibrate(args: &Args) -> Result<()> {
    use crate::calibrate::{hwref, Fitter};
    let cfg = args.config()?;
    let rt = crate::runtime::XlaRuntime::load(std::path::Path::new(
        &args.artifacts,
    ))?;
    println!("PJRT platform: {}", rt.platform());
    let card = &hwref::CARDS[0];
    let loads = hwref::load_grid(rt.manifest.calib_points, card.sat_bw_gbps);
    let meas = hwref::measure(card, &loads, 0.02, cfg.seed);
    let fitter = Fitter::default();
    let seed = Fitter::seed_from(&cfg.cxl);
    let report = fitter.fit(&rt, seed, &loads, &meas)?;
    println!(
        "card {}: loss {:.1} -> {:.3} in {} iters (rms {:.2} ns)",
        card.name,
        report.initial_loss,
        report.final_loss,
        report.iterations,
        report.rms_ns
    );
    println!("fitted params [base, pkt, media, bw, k] = {:?}", report.fitted);
    let mut cxl = cfg.cxl.clone();
    Fitter::apply(&report.fitted, &mut cxl);
    println!(
        "calibrated config: pkt {:.1} ns, link {:.1} ns, media tRCD/tCAS \
         {:.1}/{:.1} ns, bw {:.1} GB/s",
        cxl.pkt_lat_ns,
        cxl.link_lat_ns,
        cxl.media.t_rcd_ns,
        cxl.media.t_cas_ns,
        cxl.link_bw_gbps
    );
    Ok(())
}

/// Entry point used by main.rs.
pub fn dispatch(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(&argv)?;
    match args.cmd.as_str() {
        "boot" => cmd_boot(&args),
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "calibrate" => cmd_calibrate(&args),
        "table1" => cmd_table1(&args),
        "stats" => cmd_stats(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            bail!("unknown command '{other}'")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CpuModel;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_flags() {
        let a = Args::parse(&sv(&[
            "run",
            "--policy",
            "interleave:0=3,1=1",
            "--cpu",
            "inorder",
            "--wss-mult",
            "6",
            "--verify",
        ]))
        .unwrap();
        assert_eq!(a.cmd, "run");
        assert_eq!(a.wss_mult, 6);
        assert!(a.verify);
        let cfg = a.config().unwrap();
        assert_eq!(cfg.cpu_model, CpuModel::InOrder);
        assert!(a.mem_policy().is_ok());
    }

    #[test]
    fn multi_device_flags_reach_config() {
        let a = Args::parse(&sv(&[
            "run",
            "--devices",
            "2",
            "--ways",
            "2",
            "--granularity",
            "1024",
        ]))
        .unwrap();
        let cfg = a.config().unwrap();
        assert_eq!(cfg.cxl.devices, 2);
        assert_eq!(cfg.cxl.ways(), 2);
        assert_eq!(cfg.cxl.interleave_granularity, 1024);
    }

    #[test]
    fn hosts_flag_reaches_config() {
        let a = Args::parse(&sv(&["boot", "--hosts", "2"])).unwrap();
        let cfg = a.config().unwrap();
        assert_eq!(cfg.hosts, 2);
    }

    #[test]
    fn check_flag_reaches_config() {
        let a = Args::parse(&sv(&["run", "--check"])).unwrap();
        let cfg = a.config().unwrap();
        assert!(cfg.check);
    }

    #[test]
    fn threads_flag_reaches_config() {
        let a =
            Args::parse(&sv(&["run", "--threads", "4"])).unwrap();
        let cfg = a.config().unwrap();
        assert_eq!(cfg.threads, 4);
    }

    #[test]
    fn commit_lanes_flag_reaches_config() {
        let a =
            Args::parse(&sv(&["run", "--commit-lanes", "2"])).unwrap();
        let cfg = a.config().unwrap();
        assert_eq!(cfg.commit_lanes, 2);
        let a =
            Args::parse(&sv(&["run", "--commit-lanes", "auto"])).unwrap();
        let cfg = a.config().unwrap();
        assert_eq!(cfg.commit_lanes, 0, "auto is spelled 0 internally");
    }

    #[test]
    fn switch_flag_reaches_config() {
        let a = Args::parse(&sv(&[
            "run", "--devices", "4", "--switches", "1",
        ]))
        .unwrap();
        let cfg = a.config().unwrap();
        assert_eq!(cfg.cxl.switches, 1);
        assert_eq!(cfg.cxl.switch(0).ndev, 4);
    }

    #[test]
    fn fm_script_flag_loads_schedule() {
        let path = std::env::temp_dir().join("cxlramsim_fm_test.txt");
        std::fs::write(
            &path,
            "# move LD 1 to host 1 mid-run\n\
             @20us unbind dev0.ld1\n\n\
             @25us bind dev0.ld1 host1\n",
        )
        .unwrap();
        let a = Args::parse(&sv(&[
            "run",
            "--hosts",
            "2",
            "--set",
            "cxl.dev0.lds=2",
            "--set",
            "cxl.interleave_ways=1",
            "--fm-script",
            path.to_str().unwrap(),
        ]))
        .unwrap();
        let cfg = a.config().unwrap();
        assert_eq!(cfg.fm_events.len(), 2, "comments/blank lines skipped");
        assert_eq!(cfg.fm_events[0].at_ns, 20_000.0);
        let _ = std::fs::remove_file(&path);

        // A script that fails schedule validation is rejected.
        let bad = std::env::temp_dir().join("cxlramsim_fm_bad.txt");
        std::fs::write(&bad, "@20us bind dev0.ld0 host0\n").unwrap();
        let a = Args::parse(&sv(&[
            "run",
            "--fm-script",
            bad.to_str().unwrap(),
        ]))
        .unwrap();
        assert!(a.config().is_err(), "bind of a bound LD must fail");
        let _ = std::fs::remove_file(&bad);
    }

    #[test]
    fn fm_policy_flag_reaches_config() {
        use crate::config::FmPolicyKind;
        let a = Args::parse(&sv(&[
            "run",
            "--hosts",
            "2",
            "--set",
            "cxl.dev0.lds=2",
            "--set",
            "cxl.interleave_ways=1",
            "--fm-policy",
            "capacity_rebalance",
        ]))
        .unwrap();
        let cfg = a.config().unwrap();
        let p = cfg.fm_policy.as_ref().expect("policy configured");
        assert_eq!(p.kind, FmPolicyKind::CapacityRebalance);
        assert!(cfg.fm_events.is_empty());

        // Unknown policy names fail at config time.
        let a = Args::parse(&sv(&[
            "run", "--hosts", "2", "--fm-policy", "chaos",
        ]))
        .unwrap();
        assert!(a.config().is_err());
    }

    #[test]
    fn rejects_unknown_flag_and_workload() {
        assert!(Args::parse(&sv(&["run", "--bogus"])).is_err());
        let a = Args::parse(&sv(&["run", "--workload", "doom"])).unwrap();
        assert!(a.make_workload(&SimConfig::default()).is_err());
    }

    #[test]
    fn workload_factory_builds_all() {
        let cfg = SimConfig::default();
        for w in [
            "stream-copy",
            "stream-scale",
            "stream-add",
            "stream-triad",
            "random",
            "chase",
            "kv",
        ] {
            let a = Args::parse(&sv(&["run", "--workload", w])).unwrap();
            assert!(a.make_workload(&cfg).is_ok(), "{w}");
        }
    }

    #[test]
    fn config_workload_kind_vs_explicit_flag() {
        let mut cfg = SimConfig::default();
        cfg.workload.kind = Some("serve".into());
        // No --workload: the config's kind wins.
        let a = Args::parse(&sv(&["run"])).unwrap();
        assert_eq!(a.effective_workload(&cfg), "serve");
        // Explicit --workload beats the config.
        let a = Args::parse(&sv(&["run", "--workload", "chase"])).unwrap();
        assert!(a.workload_explicit);
        assert_eq!(a.effective_workload(&cfg), "chase");
        // Neither: the CLI default.
        cfg.workload.kind = None;
        let a = Args::parse(&sv(&["run"])).unwrap();
        assert_eq!(a.effective_workload(&cfg), "stream-triad");
    }

    #[test]
    fn trace_out_flag_parses() {
        let a = Args::parse(&sv(&["run", "--trace-out", "x.cxlt"])).unwrap();
        assert_eq!(a.trace_out.as_deref(), Some("x.cxlt"));
        assert!(Args::parse(&sv(&["run", "--trace-out"])).is_err());
    }
}
