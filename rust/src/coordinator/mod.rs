//! The hybrid coordinator: fast-forward warming + detailed simulation,
//! and the multi-threaded sweep runner behind the benches.
//!
//! gem5 runs hour-long boots by fast-forwarding with a functional CPU
//! and switching to the detailed model at the region of interest.
//! CXLRAMSim-rs does the same with its Layer-1/2 artifact: the init
//! phase's access trace is pushed through the AOT-compiled Pallas cache
//! model ([`crate::runtime::XlaRuntime::cache_warm`]) at vectorized
//! speed, the resulting tag/LRU/dirty state is imported into the
//! detailed caches, and only the measurement region runs event-driven.

use anyhow::{bail, Result};

use crate::cpu::WlOp;
use crate::guestos::MemPolicy;
use crate::runtime::{CacheState, XlaRuntime};
use crate::system::Machine;
use crate::trace::Trace;
use crate::workloads::{Replay, Workload};

/// Wraps a workload so its init phase runs as *timed* stores through
/// the detailed model — the "no fast-forward" baseline for the E7
/// bench (everything simulated event-by-event).
pub struct WithTimedInit<W: Workload> {
    inner: W,
    pairs: Vec<(u64, u64)>,
    i: usize,
    in_init: bool,
    last_bits: u64,
}

impl<W: Workload> WithTimedInit<W> {
    pub fn new(inner: W) -> Self {
        WithTimedInit {
            inner,
            pairs: Vec::new(),
            i: 0,
            in_init: true,
            last_bits: 0,
        }
    }
}

impl<W: Workload> Workload for WithTimedInit<W> {
    fn name(&self) -> String {
        format!("{}+timed-init", self.inner.name())
    }
    fn setup(
        &mut self,
        asp: &mut crate::guestos::AddressSpace,
        policy: &MemPolicy,
    ) {
        self.inner.setup(asp, policy);
        self.pairs = self.inner.init_data();
    }
    // No functional pre-init: the stores below do the initialization.
    fn init_data(&self) -> Vec<(u64, u64)> {
        Vec::new()
    }
    fn next_op(&mut self) -> Option<WlOp> {
        if self.in_init {
            if let Some(&(va, bits)) = self.pairs.get(self.i) {
                self.i += 1;
                self.last_bits = bits;
                return Some(WlOp::Store { va, size: 8 });
            }
            self.in_init = false;
        }
        self.inner.next_op()
    }
    fn bytes_moved(&self) -> u64 {
        self.inner.bytes_moved() + self.pairs.len() as u64 * 8
    }
    fn load_done(&mut self, va: u64, bits: u64) {
        if !self.in_init {
            self.inner.load_done(va, bits);
        }
    }
    fn store_value(&mut self, va: u64) -> u64 {
        if self.in_init {
            self.last_bits
        } else {
            self.inner.store_value(va)
        }
    }
    fn tick_hint(&mut self, tick: u64) {
        self.inner.tick_hint(tick);
    }
    fn extra_stats(&self) -> Vec<(String, crate::workloads::WlStat)> {
        self.inner.extra_stats()
    }
    fn verify(
        &self,
        asp: &mut crate::guestos::AddressSpace,
        alloc: &mut crate::guestos::PageAlloc,
        mem: &crate::mem::PhysMem,
    ) -> Result<(), String> {
        self.inner.verify(asp, alloc, mem)
    }
}

/// Capture the physical-line trace of a machine's init phase (per core
/// of host 0). Must be called after `attach_workloads` (pages are
/// faulted by then).
pub fn capture_init_trace(m: &mut Machine, core: usize) -> Result<Trace> {
    let line = m.cfg.l1.line;
    let host = &mut m.hosts[0];
    let pairs = host
        .workload(core)
        .map(|w| w.init_data())
        .unwrap_or_default();
    let Some(guest) = host.guest.as_mut() else {
        bail!("machine not booted");
    };
    let mut t = Trace::default();
    for (va, _) in pairs {
        let pa = host.spaces[core].translate(va, &mut guest.alloc)?;
        t.push((pa / line) as i32, true);
    }
    Ok(t)
}

/// Attach a captured v2 event trace to a booted machine: every host
/// present in the trace gets its recorded per-core [`Replay`] streams
/// (hosts the trace doesn't mention stay idle). The machine config
/// must match the one the trace was captured under — replay asserts
/// the recorded VMA addresses come back from the deterministic mmap
/// cursor.
pub fn attach_replay(
    m: &mut Machine,
    t: &crate::trace::EventTrace,
) -> Result<()> {
    for h in 0..m.hosts.len() {
        let wls = Replay::for_host(t, h);
        // Replay re-mmaps its recorded policies; the attach policy is
        // only a default for workloads that honor it, so Local{0} is a
        // safe stand-in.
        m.attach_workloads_to(h, wls, &MemPolicy::Local { home: 0 })?;
    }
    Ok(())
}

/// Outcome of a warming pass.
#[derive(Clone, Debug)]
pub struct WarmStats {
    pub accesses: usize,
    pub windows: usize,
    pub l1_hits: u64,
    pub l2_hits: u64,
    pub l1_occupancy: usize,
    pub l2_occupancy: usize,
}

/// Fast-forward: push `trace` through the XLA cache model and import
/// the warmed state into core `core`'s L1 and the shared L2.
pub fn warm_machine(
    m: &mut Machine,
    rt: &XlaRuntime,
    core: usize,
    trace: &Trace,
) -> Result<WarmStats> {
    let man = &rt.manifest;
    if m.l1s[core].sets != man.l1_sets
        || m.l1s[core].ways != man.l1_ways
        || m.l2.sets != man.l2_sets
        || m.l2.ways != man.l2_ways
    {
        bail!(
            "machine cache geometry (l1 {}x{}, l2 {}x{}) does not match \
             the AOT artifact ({}x{}, {}x{}) — re-run `make artifacts` \
             after changing python/compile/model.py",
            m.l1s[core].sets,
            m.l1s[core].ways,
            m.l2.sets,
            m.l2.ways,
            man.l1_sets,
            man.l1_ways,
            man.l2_sets,
            man.l2_ways
        );
    }
    // Export current detailed state into kernel layout.
    let (t, v, d, l) = m.l1s[core].export_state();
    let mut l1 = CacheState { sets: man.l1_sets, ways: man.l1_ways, tags: t, valid: v, dirty: d, lru: l };
    let (t, v, d, l) = m.l2.export_state();
    let mut l2 = CacheState { sets: man.l2_sets, ways: man.l2_ways, tags: t, valid: v, dirty: d, lru: l };

    let mut stats = WarmStats {
        accesses: trace.len(),
        windows: 0,
        l1_hits: 0,
        l2_hits: 0,
        l1_occupancy: 0,
        l2_occupancy: 0,
    };
    let mut t0 = 1i32;
    for (addrs, writes) in trace.windows(man.window) {
        let r = rt.cache_warm(addrs, writes, t0, &l1, &l2)?;
        stats.windows += 1;
        stats.l1_hits += r.hit1.iter().filter(|&&h| h == 1).count() as u64;
        stats.l2_hits += r.hit2.iter().filter(|&&h| h == 1).count() as u64;
        l1 = r.l1;
        l2 = r.l2;
        t0 = t0.wrapping_add(man.window as i32);
    }
    stats.l1_occupancy = l1.occupancy();
    stats.l2_occupancy = l2.occupancy();

    m.l1s[core].import_state(&l1.tags, &l1.valid, &l1.dirty, &l1.lru);
    m.l2.import_state(&l2.tags, &l2.valid, &l2.dirty, &l2.lru);
    // Rebuild the directory for the imported L1 lines so inclusion and
    // coherence bookkeeping stay exact after the fast-forward boundary.
    for (line, state) in m.l1s[core].valid_lines() {
        m.dir.note_import(line, core as u8, state.writable());
    }
    Ok(stats)
}

/// Multi-threaded sweep runner: runs `points` through `f` on worker
/// threads (each worker builds its own machine — nothing is shared),
/// preserving input order in the output.
pub fn run_sweep<P, R, F>(points: Vec<P>, threads: usize, f: F) -> Vec<R>
where
    P: Send + 'static,
    R: Send + 'static,
    F: Fn(P) -> R + Send + Sync + 'static,
{
    let threads = threads.max(1);
    let f = std::sync::Arc::new(f);
    let work: Vec<(usize, P)> = points.into_iter().enumerate().collect();
    let queue = std::sync::Arc::new(std::sync::Mutex::new(work));
    let results = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
    let mut handles = Vec::new();
    for _ in 0..threads {
        let q = queue.clone();
        let r = results.clone();
        let f = f.clone();
        handles.push(std::thread::spawn(move || loop {
            let item = q.lock().unwrap().pop();
            let Some((idx, p)) = item else { break };
            let out = f(p);
            r.lock().unwrap().push((idx, out));
        }));
    }
    for h in handles {
        h.join().expect("sweep worker panicked");
    }
    let mut out = std::sync::Arc::try_unwrap(results)
        .ok()
        .expect("workers done")
        .into_inner()
        .unwrap();
    out.sort_by_key(|(i, _)| *i);
    out.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_preserves_order_and_runs_all() {
        let out = run_sweep((0..50u64).collect(), 4, |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn sweep_single_thread_works() {
        let out = run_sweep(vec![3u64, 1, 4], 1, |x| x + 1);
        assert_eq!(out, vec![4, 2, 5]);
    }
}
