//! The hybrid coordinator: fast-forward warming + detailed simulation,
//! and the multi-threaded sweep runner behind the benches.
//!
//! gem5 runs hour-long boots by fast-forwarding with a functional CPU
//! and switching to the detailed model at the region of interest.
//! CXLRAMSim-rs does the same with its Layer-1/2 artifact: the init
//! phase's access trace is pushed through the AOT-compiled Pallas cache
//! model ([`crate::runtime::XlaRuntime::cache_warm`]) at vectorized
//! speed, the resulting tag/LRU/dirty state is imported into the
//! detailed caches, and only the measurement region runs event-driven.

use anyhow::{bail, Result};

use crate::cpu::WlOp;
use crate::guestos::MemPolicy;
use crate::runtime::{CacheState, XlaRuntime};
use crate::system::Machine;
use crate::trace::Trace;
use crate::workloads::{Replay, Workload};

/// Wraps a workload so its init phase runs as *timed* stores through
/// the detailed model — the "no fast-forward" baseline for the E7
/// bench (everything simulated event-by-event).
pub struct WithTimedInit<W: Workload> {
    inner: W,
    pairs: Vec<(u64, u64)>,
    i: usize,
    in_init: bool,
    last_bits: u64,
}

impl<W: Workload> WithTimedInit<W> {
    pub fn new(inner: W) -> Self {
        WithTimedInit {
            inner,
            pairs: Vec::new(),
            i: 0,
            in_init: true,
            last_bits: 0,
        }
    }
}

impl<W: Workload> Workload for WithTimedInit<W> {
    fn name(&self) -> String {
        format!("{}+timed-init", self.inner.name())
    }
    fn setup(
        &mut self,
        asp: &mut crate::guestos::AddressSpace,
        policy: &MemPolicy,
    ) {
        self.inner.setup(asp, policy);
        self.pairs = self.inner.init_data();
    }
    // No functional pre-init: the stores below do the initialization.
    fn init_data(&self) -> Vec<(u64, u64)> {
        Vec::new()
    }
    fn next_op(&mut self) -> Option<WlOp> {
        if self.in_init {
            if let Some(&(va, bits)) = self.pairs.get(self.i) {
                self.i += 1;
                self.last_bits = bits;
                return Some(WlOp::Store { va, size: 8 });
            }
            self.in_init = false;
        }
        self.inner.next_op()
    }
    fn bytes_moved(&self) -> u64 {
        self.inner.bytes_moved() + self.pairs.len() as u64 * 8
    }
    fn load_done(&mut self, va: u64, bits: u64) {
        if !self.in_init {
            self.inner.load_done(va, bits);
        }
    }
    fn store_value(&mut self, va: u64) -> u64 {
        if self.in_init {
            self.last_bits
        } else {
            self.inner.store_value(va)
        }
    }
    fn tick_hint(&mut self, tick: u64) {
        self.inner.tick_hint(tick);
    }
    fn extra_stats(&self) -> Vec<(String, crate::workloads::WlStat)> {
        self.inner.extra_stats()
    }
    fn verify(
        &self,
        asp: &mut crate::guestos::AddressSpace,
        alloc: &mut crate::guestos::PageAlloc,
        mem: &crate::mem::PhysMem,
    ) -> Result<(), String> {
        self.inner.verify(asp, alloc, mem)
    }
}

/// Capture the physical-line trace of a machine's init phase (per core
/// of host 0). Must be called after `attach_workloads` (pages are
/// faulted by then).
pub fn capture_init_trace(m: &mut Machine, core: usize) -> Result<Trace> {
    let line = m.cfg.l1.line;
    let host = &mut m.hosts[0];
    let pairs = host
        .workload(core)
        .map(|w| w.init_data())
        .unwrap_or_default();
    let Some(guest) = host.guest.as_mut() else {
        bail!("machine not booted");
    };
    let mut t = Trace::default();
    for (va, _) in pairs {
        let pa = host.spaces[core].translate(va, &mut guest.alloc)?;
        t.push((pa / line) as i32, true);
    }
    Ok(t)
}

/// Attach a captured v2 event trace to a booted machine: every host
/// present in the trace gets its recorded per-core [`Replay`] streams
/// (hosts the trace doesn't mention stay idle). The machine config
/// must match the one the trace was captured under — replay asserts
/// the recorded VMA addresses come back from the deterministic mmap
/// cursor.
pub fn attach_replay(
    m: &mut Machine,
    t: &crate::trace::EventTrace,
) -> Result<()> {
    for h in 0..m.hosts.len() {
        let wls = Replay::for_host(t, h);
        // Replay re-mmaps its recorded policies; the attach policy is
        // only a default for workloads that honor it, so Local{0} is a
        // safe stand-in.
        m.attach_workloads_to(h, wls, &MemPolicy::Local { home: 0 })?;
    }
    Ok(())
}

/// Outcome of a warming pass.
#[derive(Clone, Debug)]
pub struct WarmStats {
    pub accesses: usize,
    pub windows: usize,
    pub l1_hits: u64,
    pub l2_hits: u64,
    pub l1_occupancy: usize,
    pub l2_occupancy: usize,
}

/// Fast-forward: push `trace` through the XLA cache model and import
/// the warmed state into core `core`'s L1 and the shared L2.
pub fn warm_machine(
    m: &mut Machine,
    rt: &XlaRuntime,
    core: usize,
    trace: &Trace,
) -> Result<WarmStats> {
    let man = &rt.manifest;
    if m.l1s[core].sets != man.l1_sets
        || m.l1s[core].ways != man.l1_ways
        || m.l2.sets != man.l2_sets
        || m.l2.ways != man.l2_ways
    {
        bail!(
            "machine cache geometry (l1 {}x{}, l2 {}x{}) does not match \
             the AOT artifact ({}x{}, {}x{}) — re-run `make artifacts` \
             after changing python/compile/model.py",
            m.l1s[core].sets,
            m.l1s[core].ways,
            m.l2.sets,
            m.l2.ways,
            man.l1_sets,
            man.l1_ways,
            man.l2_sets,
            man.l2_ways
        );
    }
    // Export current detailed state into kernel layout.
    let (t, v, d, l) = m.l1s[core].export_state();
    let mut l1 = CacheState { sets: man.l1_sets, ways: man.l1_ways, tags: t, valid: v, dirty: d, lru: l };
    let (t, v, d, l) = m.l2.export_state();
    let mut l2 = CacheState { sets: man.l2_sets, ways: man.l2_ways, tags: t, valid: v, dirty: d, lru: l };

    let mut stats = WarmStats {
        accesses: trace.len(),
        windows: 0,
        l1_hits: 0,
        l2_hits: 0,
        l1_occupancy: 0,
        l2_occupancy: 0,
    };
    let mut t0 = 1i32;
    for (addrs, writes) in trace.windows(man.window) {
        let r = rt.cache_warm(addrs, writes, t0, &l1, &l2)?;
        stats.windows += 1;
        stats.l1_hits += r.hit1.iter().filter(|&&h| h == 1).count() as u64;
        stats.l2_hits += r.hit2.iter().filter(|&&h| h == 1).count() as u64;
        l1 = r.l1;
        l2 = r.l2;
        t0 = t0.wrapping_add(man.window as i32);
    }
    stats.l1_occupancy = l1.occupancy();
    stats.l2_occupancy = l2.occupancy();

    m.l1s[core].import_state(&l1.tags, &l1.valid, &l1.dirty, &l1.lru);
    m.l2.import_state(&l2.tags, &l2.valid, &l2.dirty, &l2.lru);
    // Rebuild the directory for the imported L1 lines so inclusion and
    // coherence bookkeeping stay exact after the fast-forward boundary.
    for (line, state) in m.l1s[core].valid_lines() {
        m.dir.note_import(line, core as u8, state.writable());
    }
    Ok(stats)
}

/// Multi-threaded sweep runner: runs `points` through `f` on worker
/// threads (each worker builds its own machine — nothing is shared),
/// preserving input order in the output.
///
/// Panic-safe by construction: a panicking point is caught in its
/// worker, every *other* point still runs to completion (no stranded
/// queue entries, no poisoned-mutex cascade through the siblings), and
/// the first panic re-raises in the caller only after all workers have
/// drained and joined. Scoped threads also drop the old `'static`
/// bounds, so closures may borrow from the caller's stack.
pub fn run_sweep<P, R, F>(points: Vec<P>, threads: usize, f: F) -> Vec<R>
where
    P: Send,
    R: Send,
    F: Fn(P) -> R + Send + Sync,
{
    let n = points.len();
    let threads = threads.max(1).min(n.max(1));
    // Reversed so `pop()` hands points out in input order; results go
    // home by index, so completion order never matters.
    let work: std::sync::Mutex<Vec<(usize, P)>> =
        std::sync::Mutex::new(points.into_iter().enumerate().rev().collect());
    let results: std::sync::Mutex<Vec<Option<R>>> =
        std::sync::Mutex::new((0..n).map(|_| None).collect());
    let first_panic: std::sync::Mutex<
        Option<Box<dyn std::any::Any + Send>>,
    > = std::sync::Mutex::new(None);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let item = work.lock().unwrap().pop();
                let Some((idx, p)) = item else { break };
                match std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| f(p)),
                ) {
                    Ok(out) => results.lock().unwrap()[idx] = Some(out),
                    Err(e) => {
                        let mut slot = first_panic.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(e);
                        }
                    }
                }
            });
        }
    });
    if let Some(p) = first_panic.into_inner().unwrap() {
        std::panic::resume_unwind(p);
    }
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("every point completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_preserves_order_and_runs_all() {
        let out = run_sweep((0..50u64).collect(), 4, |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn sweep_single_thread_works() {
        let out = run_sweep(vec![3u64, 1, 4], 1, |x| x + 1);
        assert_eq!(out, vec![4, 2, 5]);
    }

    #[test]
    fn sweep_more_threads_than_points_preserves_input_order() {
        // Property over every small point count, including the empty
        // sweep: far more workers than work must neither hang nor
        // scramble the input order.
        for n in 0..8u64 {
            let pts: Vec<u64> = (0..n).collect();
            let want: Vec<u64> = pts.iter().map(|&x| x * 3 + 1).collect();
            let out = run_sweep(pts, 16, |x| x * 3 + 1);
            assert_eq!(out, want, "n = {n}");
        }
    }

    #[test]
    fn sweep_panicking_point_does_not_strand_the_rest() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let done = AtomicUsize::new(0);
        let res = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                run_sweep((0..20u64).collect(), 3, |x| {
                    if x == 5 {
                        panic!("sweep point {x} exploded");
                    }
                    done.fetch_add(1, Ordering::SeqCst);
                    x
                })
            }),
        );
        assert!(res.is_err(), "the point's panic must reach the caller");
        // Every other point still ran: workers drain the queue rather
        // than deadlocking on a dead sibling or a poisoned mutex.
        assert_eq!(done.load(Ordering::SeqCst), 19);
    }

    #[test]
    fn sweep_borrows_caller_state() {
        // The scoped rewrite dropped the 'static bounds: closures may
        // read (and results may reference) the caller's stack.
        let base = vec![10u64, 20, 30];
        let out = run_sweep(vec![0usize, 1, 2], 2, |i| base[i] + 1);
        assert_eq!(out, vec![11, 21, 31]);
    }
}
