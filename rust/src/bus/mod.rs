//! Bus models: the coherent memory bus and the I/O bus.
//!
//! Both are crossbars with a fixed traversal latency plus bandwidth-
//! limited occupancy (serialization of packets over the shared fabric).
//! The *position* of the CXL device relative to these two buses is the
//! paper's central architectural point: CXLRAMSim routes CXL traffic
//! membus -> IOBus -> root complex (Fig. 1B); the `baseline` module
//! attaches the expander directly to the membus (Fig. 1A).

use crate::sim::{ns_to_ticks, ser_ticks, Tick};
use crate::stats::{Counter, Histogram, StatDump};

#[derive(Clone, Debug, Default)]
pub struct BusStats {
    pub packets: Counter,
    pub bytes: Counter,
    pub queue_delay: Histogram,
    pub busy_ticks: Counter,
}

/// A shared split-transaction bus with `width`-parallel layers
/// (modern membus crossbars are multi-layer; IOBus is single-layer).
#[derive(Clone, Debug)]
pub struct Bus {
    pub name: &'static str,
    lat_ticks: Tick,
    bw_gbps: f64,
    layers: Vec<Tick>, // next-free tick per layer
    pub stats: BusStats,
}

impl Bus {
    pub fn new(name: &'static str, lat_ns: f64, bw_gbps: f64, width: usize) -> Self {
        Bus {
            name,
            lat_ticks: ns_to_ticks(lat_ns),
            bw_gbps,
            layers: vec![0; width.max(1)],
            stats: BusStats::default(),
        }
    }

    /// Transfer `bytes` arriving at `at`; returns delivery tick at the
    /// other side (arbitration + traversal + serialization).
    pub fn transfer(&mut self, at: Tick, bytes: u64) -> Tick {
        // Pick the earliest-free layer (round-robin-equivalent under
        // determinism: min, ties by index).
        let (idx, &free) = self
            .layers
            .iter()
            .enumerate()
            .min_by_key(|(i, &t)| (t, *i))
            .unwrap();
        let start = at.max(free);
        let ser = ser_ticks(bytes, self.bw_gbps).max(1);
        let done = start + ser;
        self.layers[idx] = done;
        self.stats.packets.inc();
        self.stats.bytes.add(bytes);
        self.stats.queue_delay.sample(start - at);
        self.stats.busy_ticks.add(ser);
        done + self.lat_ticks
    }

    /// Utilization over an interval of `window` ticks.
    pub fn utilization(&self, window: Tick) -> f64 {
        if window == 0 {
            return 0.0;
        }
        self.stats.busy_ticks.get() as f64
            / (window as f64 * self.layers.len() as f64)
    }

    pub fn dump(&self, path: &str, d: &mut StatDump) {
        d.counter(&format!("{path}.packets"), &self.stats.packets);
        d.counter(&format!("{path}.bytes"), &self.stats.bytes);
        d.hist(&format!("{path}.queue_delay"), &self.stats.queue_delay);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unloaded_latency_is_traversal_plus_ser() {
        let mut b = Bus::new("t", 4.0, 64.0, 1);
        // 64B at 64GB/s = 1ns = 1000 ticks; traversal 4ns.
        assert_eq!(b.transfer(0, 64), 1000 + 4000);
    }

    #[test]
    fn contention_queues() {
        let mut b = Bus::new("t", 0.0, 64.0, 1);
        let a = b.transfer(0, 64);
        let c = b.transfer(0, 64);
        assert_eq!(c, a + 1000); // second waits for first
        assert!(b.stats.queue_delay.stats.max >= 1000.0);
    }

    #[test]
    fn multi_layer_overlaps() {
        let mut b = Bus::new("t", 0.0, 64.0, 2);
        let a = b.transfer(0, 64);
        let c = b.transfer(0, 64);
        assert_eq!(a, c); // parallel layers
        let d = b.transfer(0, 64);
        assert!(d > a); // third must queue
    }

    #[test]
    fn stats_accumulate() {
        let mut b = Bus::new("t", 1.0, 32.0, 1);
        b.transfer(0, 64);
        b.transfer(0, 128);
        assert_eq!(b.stats.packets.get(), 2);
        assert_eq!(b.stats.bytes.get(), 192);
        assert!(b.utilization(100_000) > 0.0);
    }
}
