//! cxlramsim — leader binary.

fn main() {
    cxlramsim::util::logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = cxlramsim::cli::dispatch(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
