//! Synthetic "vendor silicon" loaded-latency curves.
//!
//! Substitutes the real CXL expander cards the paper calibrates against
//! (hardware gate — DESIGN.md §1). Curves have the empirically observed
//! shape of MLC/Mess-style loaded-latency measurements on CXL devices:
//! a flat unloaded region, a gentle queueing slope, and a sharp knee as
//! the link saturates; plus vendor-to-vendor variation and measurement
//! noise.

use crate::util::rng::Rng;

/// A synthetic vendor card's ground-truth characteristics.
#[derive(Clone, Copy, Debug)]
pub struct VendorCard {
    pub name: &'static str,
    pub idle_lat_ns: f32,
    pub sat_bw_gbps: f32,
    pub knee_sharpness: f32,
}

/// Representative cards (shapes inspired by published CXL-expander
/// measurements: ~170-250 ns idle, 20-28 GB/s x8 saturating).
pub const CARDS: [VendorCard; 3] = [
    VendorCard {
        name: "vendor-A-ddr5",
        idle_lat_ns: 180.0,
        sat_bw_gbps: 26.0,
        knee_sharpness: 35.0,
    },
    VendorCard {
        name: "vendor-B-ddr4",
        idle_lat_ns: 240.0,
        sat_bw_gbps: 20.0,
        knee_sharpness: 55.0,
    },
    VendorCard {
        name: "vendor-C-optimized",
        idle_lat_ns: 150.0,
        sat_bw_gbps: 28.0,
        knee_sharpness: 25.0,
    },
];

/// "Measure" the card: loaded latency at the given offered loads, with
/// multiplicative measurement noise of `noise` (e.g. 0.02 = 2%).
pub fn measure(
    card: &VendorCard,
    loads: &[f32],
    noise: f32,
    seed: u64,
) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    loads
        .iter()
        .map(|&l| {
            let x = (card.sat_bw_gbps - l) as f64;
            let headroom = x.exp().ln_1p() as f32 + 1e-3;
            let lat = card.idle_lat_ns
                + card.knee_sharpness * l / headroom;
            let jitter = 1.0 + noise * (2.0 * rng.f64() as f32 - 1.0);
            lat * jitter
        })
        .collect()
}

/// The load grid a user would sweep (fraction of nominal link bw).
pub fn load_grid(points: usize, max_gbps: f32) -> Vec<f32> {
    (0..points)
        .map(|i| 0.25 + (i as f32 / points as f32) * (max_gbps - 0.5))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unloaded_latency_near_idle() {
        let m = measure(&CARDS[0], &[0.3], 0.0, 1);
        assert!((m[0] - CARDS[0].idle_lat_ns).abs() < 2.0, "{}", m[0]);
    }

    #[test]
    fn latency_explodes_past_saturation() {
        let loads = [5.0, CARDS[0].sat_bw_gbps + 2.0];
        let m = measure(&CARDS[0], &loads, 0.0, 1);
        assert!(m[1] > m[0] * 3.0, "no knee: {m:?}");
    }

    #[test]
    fn noise_is_bounded_and_seeded() {
        let loads = load_grid(32, 26.0);
        let a = measure(&CARDS[1], &loads, 0.02, 7);
        let b = measure(&CARDS[1], &loads, 0.02, 7);
        let clean = measure(&CARDS[1], &loads, 0.0, 7);
        assert_eq!(a, b, "same seed must reproduce");
        for (x, c) in a.iter().zip(&clean) {
            assert!((x - c).abs() / c <= 0.021);
        }
    }

    #[test]
    fn grid_spans_range() {
        let g = load_grid(32, 26.0);
        assert_eq!(g.len(), 32);
        assert!(g[0] < 1.0);
        assert!(*g.last().unwrap() > 24.0);
    }
}
