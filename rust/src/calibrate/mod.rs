//! Latency-bandwidth calibration (paper §III-B.2 / §V).
//!
//! "The bandwidth-latency characteristics of the CXL memory is highly
//! vendor specific. Hence, we provide a user-friendly mechanism to
//! calibrate the latency of the CXL interconnects to match the
//! latency/bandwidth of the actual CXL memory."
//!
//! * [`hwref`] generates synthetic "vendor silicon" loaded-latency
//!   curves (the hardware-gated measurement the paper takes on a real
//!   expander — substituted per DESIGN.md §1).
//! * [`Fitter`] runs the AOT-compiled fwd+grad step
//!   ([`crate::runtime::XlaRuntime::calib_step`]) until the model curve
//!   matches, then maps fitted parameters back onto [`CxlConfig`]
//!   knobs (pkt/link/media latencies, link bandwidth).

pub mod hwref;

use anyhow::Result;

use crate::config::CxlConfig;
use crate::runtime::XlaRuntime;

/// Parameter vector layout (matches python/compile/model.py):
/// [base, pkt, media, bw, k].
pub type Params = [f32; 5];

#[derive(Clone, Debug)]
pub struct FitReport {
    pub initial: Params,
    pub fitted: Params,
    pub initial_loss: f32,
    pub final_loss: f32,
    pub iterations: usize,
    /// RMS latency error (ns) of the fitted curve on the measurements.
    pub rms_ns: f32,
}

pub struct Fitter {
    /// Initial per-parameter step sizes (the artifact applies sign-SGD;
    /// see python/compile/model.py::calib_step for why not raw SGD).
    pub lr: [f32; 5],
    /// Halve the step sizes every this many iterations.
    pub decay_every: usize,
    pub max_iters: usize,
    pub target_loss: f32,
}

impl Default for Fitter {
    fn default() -> Self {
        Fitter {
            // ns-scale steps for the latency params; GB/s-scale for bw/k.
            lr: [2.0, 2.0, 2.0, 0.5, 0.5],
            decay_every: 400,
            max_iters: 3000,
            target_loss: 4.0, // MSE in ns^2 => rms ~2 ns
        }
    }
}

impl Fitter {
    /// Fit the model to measured (load, latency) points.
    pub fn fit(
        &self,
        rt: &XlaRuntime,
        init: Params,
        loads: &[f32],
        lat_meas: &[f32],
    ) -> Result<FitReport> {
        let mut p = init;
        let mut lr = self.lr;
        let mut initial_loss = f32::INFINITY;
        let mut loss = f32::INFINITY;
        let mut iters = 0;
        for i in 0..self.max_iters {
            let (np, l) = rt.calib_step(&p, loads, lat_meas, &lr)?;
            if i == 0 {
                initial_loss = l;
            }
            p = np;
            loss = l;
            iters = i + 1;
            if loss < self.target_loss {
                break;
            }
            if (i + 1) % self.decay_every == 0 {
                for x in &mut lr {
                    *x *= 0.5;
                }
            }
        }
        Ok(FitReport {
            initial: init,
            fitted: p,
            initial_loss,
            final_loss: loss,
            iterations: iters,
            rms_ns: loss.max(0.0).sqrt(),
        })
    }

    /// Seed the fit from the current config (what a user would do:
    /// start from the datasheet, fit to their card).
    pub fn seed_from(cfg: &CxlConfig) -> Params {
        [
            10.0, // base: RC/IOBus traversal guess
            cfg.pkt_lat_ns as f32,
            cfg.media.t_rcd_ns as f32 + cfg.media.t_cas_ns as f32,
            cfg.link_bw_gbps as f32,
            20.0, // queueing sensitivity guess
        ]
    }

    /// Write fitted parameters back onto the config knobs the simulator
    /// exposes (the user-facing calibration the paper describes).
    pub fn apply(fitted: &Params, cfg: &mut CxlConfig) {
        cfg.pkt_lat_ns = fitted[1].max(0.0) as f64;
        cfg.depkt_lat_ns = fitted[1].max(0.0) as f64;
        // media = tRCD + tCAS split evenly.
        let media = fitted[2].max(1.0) as f64;
        cfg.media.t_rcd_ns = media / 2.0;
        cfg.media.t_cas_ns = media / 2.0;
        cfg.link_bw_gbps = fitted[3].max(1.0) as f64;
        // base + k have no direct knob: base folds into link latency.
        cfg.link_lat_ns = (fitted[0].max(0.0) as f64 / 2.0).max(1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    #[test]
    fn seed_uses_config_values() {
        let cfg = SimConfig::default().cxl;
        let s = Fitter::seed_from(&cfg);
        assert_eq!(s[1], cfg.pkt_lat_ns as f32);
        assert_eq!(s[3], cfg.link_bw_gbps as f32);
    }

    #[test]
    fn apply_roundtrips_onto_config() {
        let mut cfg = SimConfig::default().cxl;
        let fitted: Params = [40.0, 30.0, 36.0, 24.0, 55.0];
        Fitter::apply(&fitted, &mut cfg);
        assert_eq!(cfg.pkt_lat_ns, 30.0);
        assert_eq!(cfg.media.t_rcd_ns, 18.0);
        assert_eq!(cfg.link_bw_gbps, 24.0);
        assert_eq!(cfg.link_lat_ns, 20.0);
    }
}
