//! PCIe configuration space (4 KiB per function, PCIe ECAM-addressable).
//!
//! Real binary layout: Type-0/Type-1 headers, the classic capability
//! list at 0x34, and PCIe *extended* capabilities from offset 0x100 —
//! including the Designated Vendor-Specific Extended Capabilities
//! (DVSEC) that CXL 2.0 §8.1 builds its discovery on. The guest's
//! enumeration and CXL driver read these bytes exactly as Linux would
//! (`pci_find_ext_capability`, DVSEC walk), which is the paper's "no
//! kernel patches" claim in miniature.

/// Classic header offsets (PCI 3.0 / PCIe).
pub mod off {
    pub const VENDOR_ID: usize = 0x00;
    pub const DEVICE_ID: usize = 0x02;
    pub const COMMAND: usize = 0x04;
    pub const STATUS: usize = 0x06;
    pub const REVISION: usize = 0x08;
    pub const CLASS_PROG: usize = 0x09;
    pub const CLASS_SUB: usize = 0x0A;
    pub const CLASS_BASE: usize = 0x0B;
    pub const HEADER_TYPE: usize = 0x0E;
    pub const BAR0: usize = 0x10;
    // Type 1 (bridge) specifics:
    pub const PRIMARY_BUS: usize = 0x18;
    pub const SECONDARY_BUS: usize = 0x19;
    pub const SUBORDINATE_BUS: usize = 0x1A;
    pub const MEM_BASE: usize = 0x20;
    pub const MEM_LIMIT: usize = 0x22;
    pub const CAP_PTR: usize = 0x34;
    pub const EXT_CAP_START: usize = 0x100;
}

/// Status-register bit: capabilities list present.
pub const STATUS_CAP_LIST: u16 = 1 << 4;
/// Command-register bits.
pub const CMD_MEM_ENABLE: u16 = 1 << 1;
pub const CMD_BUS_MASTER: u16 = 1 << 2;

/// PCIe extended capability IDs we emit.
pub const EXTCAP_DVSEC: u16 = 0x0023;

/// CXL DVSEC vendor ID (CXL consortium) and DVSEC IDs (CXL 2.0 §8.1).
pub const CXL_VENDOR_ID: u16 = 0x1E98;
pub const DVSEC_CXL_DEVICE: u16 = 0x0000; // §8.1.3 PCIe DVSEC for CXL devices
pub const DVSEC_NON_CXL_FUNC: u16 = 0x0002;
pub const DVSEC_GPF_PORT: u16 = 0x0003; // §8.1.6
pub const DVSEC_GPF_DEVICE: u16 = 0x0004; // §8.1.7
pub const DVSEC_FLEXBUS_PORT: u16 = 0x0007; // §8.1.5
pub const DVSEC_REGISTER_LOCATOR: u16 = 0x0008; // §8.1.9

/// Register-block identifiers inside the Register Locator DVSEC
/// (CXL 2.0 table 8-22).
pub const BLOCK_COMPONENT: u8 = 0x01;
pub const BLOCK_BAR_VIRT: u8 = 0x02;
pub const BLOCK_DEVICE: u8 = 0x03; // device registers (mailbox lives here)

const CFG_SIZE: usize = 4096;

/// One function's 4 KiB configuration space with BAR-sizing semantics.
#[derive(Clone)]
pub struct ConfigSpace {
    bytes: Vec<u8>,
    /// BAR size masks (0 = BAR not implemented). Index 0..6.
    bar_size: [u64; 6],
    /// Shadow of programmed BAR values.
    bar_val: [u64; 6],
    /// Next free offset for classic capabilities.
    cap_tail: usize,
    /// Next free offset for extended capabilities (0 = none yet).
    ext_tail: usize,
}

impl ConfigSpace {
    /// Type-0 (endpoint) header.
    pub fn endpoint(vendor: u16, device: u16, class: [u8; 3]) -> Self {
        let mut c = ConfigSpace {
            bytes: vec![0; CFG_SIZE],
            bar_size: [0; 6],
            bar_val: [0; 6],
            cap_tail: 0x40,
            ext_tail: 0,
        };
        c.w16(off::VENDOR_ID, vendor);
        c.w16(off::DEVICE_ID, device);
        c.bytes[off::HEADER_TYPE] = 0x00;
        c.bytes[off::CLASS_PROG] = class[2];
        c.bytes[off::CLASS_SUB] = class[1];
        c.bytes[off::CLASS_BASE] = class[0];
        c
    }

    /// Type-1 (bridge / root port) header.
    pub fn bridge(vendor: u16, device: u16) -> Self {
        let mut c = Self::endpoint(vendor, device, [0x06, 0x04, 0x00]);
        c.bytes[off::HEADER_TYPE] = 0x01;
        c
    }

    pub fn is_bridge(&self) -> bool {
        self.bytes[off::HEADER_TYPE] & 0x7F == 0x01
    }

    // -- raw accessors ---------------------------------------------------
    pub fn r8(&self, o: usize) -> u8 {
        self.bytes[o]
    }
    pub fn r16(&self, o: usize) -> u16 {
        u16::from_le_bytes([self.bytes[o], self.bytes[o + 1]])
    }
    pub fn r32(&self, o: usize) -> u32 {
        u32::from_le_bytes(self.bytes[o..o + 4].try_into().unwrap())
    }
    pub fn w8(&mut self, o: usize, v: u8) {
        self.bytes[o] = v;
    }
    pub fn w16(&mut self, o: usize, v: u16) {
        self.bytes[o..o + 2].copy_from_slice(&v.to_le_bytes());
    }
    pub fn w32(&mut self, o: usize, v: u32) {
        self.bytes[o..o + 4].copy_from_slice(&v.to_le_bytes());
    }

    // -- BARs --------------------------------------------------------------
    /// Declare a 64-bit memory BAR of `size` bytes at BAR index `idx`
    /// (consumes idx and idx+1).
    pub fn add_bar64(&mut self, idx: usize, size: u64) {
        assert!(idx < 5, "64-bit BAR needs two slots");
        assert!(size.is_power_of_two() && size >= 4096);
        self.bar_size[idx] = size;
        // Type bits: 64-bit (0b10 << 1), non-prefetchable.
        self.w32(off::BAR0 + idx * 4, 0b100);
        self.w32(off::BAR0 + (idx + 1) * 4, 0);
    }

    /// Config write that honors BAR sizing protocol.
    pub fn cfg_write32(&mut self, o: usize, v: u32) {
        if (off::BAR0..off::BAR0 + 24).contains(&o) && (o - off::BAR0) % 4 == 0 {
            let idx = (o - off::BAR0) / 4;
            // Which BAR does this dword belong to?
            let (base_idx, is_high) = if idx > 0 && self.bar_size[idx - 1] != 0
                && self.bar_size[idx] == 0
            {
                (idx - 1, true)
            } else {
                (idx, false)
            };
            let size = self.bar_size[base_idx];
            if size == 0 {
                // Unimplemented BAR (PCIe 5.0 §7.5.1.2.1): hardwired to
                // zero. Writes — sizing probes included — are dropped,
                // and because nothing is ever stored at this dword it
                // keeps reading back 0, which is exactly how
                // enumeration discovers the BAR is absent. This also
                // covers the would-be *high* dword of an unimplemented
                // 64-bit pair; the high dword of an IMPLEMENTED 64-bit
                // BAR never lands here (it resolves to `base_idx` with
                // `is_high` above and answers the sizing protocol).
                return;
            }
            let mask = !(size - 1);
            let cur = self.bar_val[base_idx];
            let new = if is_high {
                (cur & 0xFFFF_FFFF) | ((v as u64) << 32)
            } else {
                (cur & !0xFFFF_FFFF) | (v as u64)
            } & mask;
            self.bar_val[base_idx] = new;
            // Readback: low dword carries type bits; all-ones write reads
            // back the size mask per the sizing protocol.
            let lo = (new as u32 & mask as u32) | 0b100;
            self.w32(off::BAR0 + base_idx * 4, lo);
            self.w32(off::BAR0 + (base_idx + 1) * 4, (new >> 32) as u32);
            if v == 0xFFFF_FFFF {
                if is_high {
                    self.w32(off::BAR0 + idx * 4, (mask >> 32) as u32);
                } else {
                    self.w32(
                        off::BAR0 + base_idx * 4,
                        (mask as u32) | 0b100,
                    );
                }
            }
            return;
        }
        self.w32(o, v);
    }

    pub fn bar_addr(&self, idx: usize) -> Option<u64> {
        (self.bar_size[idx] != 0 && self.bar_val[idx] != 0)
            .then_some(self.bar_val[idx])
    }

    pub fn bar_size(&self, idx: usize) -> u64 {
        self.bar_size[idx]
    }

    /// Set BAR base directly (BIOS-side assignment).
    pub fn assign_bar(&mut self, idx: usize, base: u64) {
        assert!(self.bar_size[idx] != 0);
        self.bar_val[idx] = base;
        self.w32(off::BAR0 + idx * 4, (base as u32) | 0b100);
        self.w32(off::BAR0 + idx * 4 + 4, (base >> 32) as u32);
    }

    // -- classic capabilities ----------------------------------------------
    /// Append a classic capability; returns its offset.
    pub fn add_capability(&mut self, cap_id: u8, body: &[u8]) -> usize {
        let at = self.cap_tail;
        let total = 2 + body.len();
        assert!(at + total <= 0x100, "classic cap area overflow");
        // Link into the list.
        let status = self.r16(off::STATUS) | STATUS_CAP_LIST;
        self.w16(off::STATUS, status);
        if self.bytes[off::CAP_PTR] == 0 {
            self.bytes[off::CAP_PTR] = at as u8;
        } else {
            // walk to the end
            let mut p = self.bytes[off::CAP_PTR] as usize;
            while self.bytes[p + 1] != 0 {
                p = self.bytes[p + 1] as usize;
            }
            self.bytes[p + 1] = at as u8;
        }
        self.bytes[at] = cap_id;
        self.bytes[at + 1] = 0;
        self.bytes[at + 2..at + 2 + body.len()].copy_from_slice(body);
        self.cap_tail = (at + total + 3) & !3;
        at
    }

    // -- extended capabilities ----------------------------------------------
    /// Append an extended capability; returns its offset.
    pub fn add_ext_capability(&mut self, cap_id: u16, version: u8, body: &[u8]) -> usize {
        let at = if self.ext_tail == 0 {
            off::EXT_CAP_START
        } else {
            self.ext_tail
        };
        let total = 4 + body.len();
        assert!(at + total <= CFG_SIZE, "ext cap overflow");
        // Fix previous header's next pointer.
        if at != off::EXT_CAP_START {
            let mut p = off::EXT_CAP_START;
            loop {
                let hdr = self.r32(p);
                let next = (hdr >> 20) as usize & 0xFFC;
                if next == 0 {
                    self.w32(p, (hdr & 0x000F_FFFF) | ((at as u32) << 20));
                    break;
                }
                p = next;
            }
        }
        let hdr = (cap_id as u32) | ((version as u32) << 16);
        self.w32(at, hdr);
        self.bytes[at + 4..at + 4 + body.len()].copy_from_slice(body);
        self.ext_tail = (at + total + 3) & !3;
        at
    }

    /// DVSEC: extended cap 0x23 wrapping (vendor, revision, id) + payload.
    /// Layout per PCIe 5.0 §7.9.6: hdr1 @ +4 (vendor | rev<<16 | len<<20),
    /// hdr2 @ +8 (DVSEC id in low 16 bits).
    pub fn add_dvsec(&mut self, dvsec_id: u16, payload: &[u8]) -> usize {
        let len = (12 + payload.len()) as u32;
        let mut body = Vec::with_capacity(8 + payload.len());
        let hdr1 = (CXL_VENDOR_ID as u32) | (1 << 16) | (len << 20);
        body.extend_from_slice(&hdr1.to_le_bytes());
        body.extend_from_slice(&(dvsec_id as u32).to_le_bytes());
        body.extend_from_slice(payload);
        self.add_ext_capability(EXTCAP_DVSEC, 1, &body)
    }

    /// Walk extended caps, returning offsets of DVSECs with our vendor
    /// and the given id (guest-driver-side helper mirrors Linux's
    /// `pci_find_dvsec_capability`).
    pub fn find_dvsec(&self, dvsec_id: u16) -> Option<usize> {
        let mut p = off::EXT_CAP_START;
        loop {
            let hdr = self.r32(p);
            if hdr == 0 {
                return None;
            }
            let cap = (hdr & 0xFFFF) as u16;
            if cap == EXTCAP_DVSEC {
                let vendor = self.r16(p + 4);
                let id = self.r16(p + 8);
                if vendor == CXL_VENDOR_ID && id == dvsec_id {
                    return Some(p);
                }
            }
            let next = (hdr >> 20) as usize & 0xFFC;
            if next == 0 {
                return None;
            }
            p = next;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_header_layout() {
        let c = ConfigSpace::endpoint(0x8086, 0x0d93, [0x05, 0x02, 0x10]);
        assert_eq!(c.r16(off::VENDOR_ID), 0x8086);
        assert_eq!(c.r16(off::DEVICE_ID), 0x0d93);
        assert_eq!(c.r8(off::CLASS_BASE), 0x05); // memory controller
        assert_eq!(c.r8(off::CLASS_SUB), 0x02); // CXL
        assert!(!c.is_bridge());
    }

    #[test]
    fn bridge_header() {
        let mut c = ConfigSpace::bridge(0x8086, 0x7075);
        assert!(c.is_bridge());
        c.w8(off::SECONDARY_BUS, 1);
        c.w8(off::SUBORDINATE_BUS, 2);
        assert_eq!(c.r8(off::SECONDARY_BUS), 1);
    }

    #[test]
    fn bar_sizing_protocol() {
        let mut c = ConfigSpace::endpoint(1, 2, [0, 0, 0]);
        c.add_bar64(0, 1 << 20); // 1 MiB
        // Write all-ones, read back size mask.
        c.cfg_write32(off::BAR0, 0xFFFF_FFFF);
        let lo = c.r32(off::BAR0);
        assert_eq!(lo & 0xFFFF_F000, 0xFFF0_0000); // low 20 bits clear
        assert_eq!(lo & 0b111, 0b100); // 64-bit memory type
        // Program a base.
        c.cfg_write32(off::BAR0, 0xFE00_0000);
        c.cfg_write32(off::BAR0 + 4, 0x0000_0012);
        assert_eq!(c.bar_addr(0), Some(0x12_FE00_0000));
    }

    #[test]
    fn unimplemented_bar_reads_zero() {
        let mut c = ConfigSpace::endpoint(1, 2, [0, 0, 0]);
        c.cfg_write32(off::BAR0 + 8, 0xFFFF_FFFF);
        assert_eq!(c.r32(off::BAR0 + 8), 0);
        assert_eq!(c.bar_addr(2), None);

        // 64-bit coverage: with a 1 MiB 64-bit BAR in slots 0+1, the
        // implemented pair's HIGH dword answers the sizing protocol
        // (all-ones mask for a < 4 GiB BAR) ...
        c.add_bar64(0, 1 << 20);
        c.cfg_write32(off::BAR0 + 4, 0xFFFF_FFFF);
        assert_eq!(c.r32(off::BAR0 + 4), 0xFFFF_FFFF);
        // ... while the would-be high dword of the UNIMPLEMENTED pair
        // at slots 2+3 stays hardwired to zero through sizing probes,
        // so enumeration sees "no BAR" in both dwords.
        c.cfg_write32(off::BAR0 + 8, 0xFFFF_FFFF);
        c.cfg_write32(off::BAR0 + 12, 0xFFFF_FFFF);
        assert_eq!(c.r32(off::BAR0 + 8), 0);
        assert_eq!(c.r32(off::BAR0 + 12), 0);
        assert_eq!(c.bar_addr(2), None);
        assert_eq!(c.bar_addr(3), None);
    }

    #[test]
    fn classic_capability_chain() {
        let mut c = ConfigSpace::endpoint(1, 2, [0, 0, 0]);
        let a = c.add_capability(0x10, &[0; 14]); // PCIe cap
        let b = c.add_capability(0x05, &[0; 10]); // MSI
        assert_eq!(c.r8(off::CAP_PTR) as usize, a);
        assert_eq!(c.r8(a + 1) as usize, b);
        assert_eq!(c.r8(b + 1), 0);
        assert!(c.r16(off::STATUS) & STATUS_CAP_LIST != 0);
    }

    #[test]
    fn dvsec_walk_finds_by_id() {
        let mut c = ConfigSpace::endpoint(1, 2, [0, 0, 0]);
        c.add_dvsec(DVSEC_CXL_DEVICE, &[0xAA; 16]);
        c.add_dvsec(DVSEC_GPF_DEVICE, &[0xBB; 8]);
        c.add_dvsec(DVSEC_REGISTER_LOCATOR, &[0xCC; 24]);
        assert!(c.find_dvsec(DVSEC_CXL_DEVICE).is_some());
        assert!(c.find_dvsec(DVSEC_REGISTER_LOCATOR).is_some());
        assert!(c.find_dvsec(DVSEC_FLEXBUS_PORT).is_none());
        // Payload is where we expect (after the 12-byte DVSEC header).
        let p = c.find_dvsec(DVSEC_GPF_DEVICE).unwrap();
        assert_eq!(c.r8(p + 12), 0xBB);
    }

    #[test]
    fn ext_cap_chain_links() {
        let mut c = ConfigSpace::endpoint(1, 2, [0, 0, 0]);
        let a = c.add_ext_capability(0x0001, 1, &[0; 4]); // AER-ish
        let b = c.add_dvsec(DVSEC_CXL_DEVICE, &[0; 4]);
        assert_eq!(a, off::EXT_CAP_START);
        let next = (c.r32(a) >> 20) as usize & 0xFFC;
        assert_eq!(next, b);
    }
}
