//! ECAM — Enhanced Configuration Access Mechanism.
//!
//! The MCFG ACPI table points the OS at a memory-mapped window where
//! `address = base + (bus << 20 | dev << 15 | func << 12 | offset)`.
//! This module provides the BDF<->address math and the dispatch from an
//! ECAM MMIO access to the right function's [`ConfigSpace`].

use std::collections::BTreeMap;

use super::config_space::ConfigSpace;

/// Bus/Device/Function address.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Bdf {
    pub bus: u8,
    pub dev: u8,
    pub func: u8,
}

impl Bdf {
    pub fn new(bus: u8, dev: u8, func: u8) -> Self {
        assert!(dev < 32 && func < 8);
        Bdf { bus, dev, func }
    }

    pub fn ecam_offset(&self) -> u64 {
        ((self.bus as u64) << 20)
            | ((self.dev as u64) << 15)
            | ((self.func as u64) << 12)
    }

    pub fn from_ecam_offset(off: u64) -> (Bdf, usize) {
        let bus = ((off >> 20) & 0xFF) as u8;
        let dev = ((off >> 15) & 0x1F) as u8;
        let func = ((off >> 12) & 0x7) as u8;
        (Bdf { bus, dev, func }, (off & 0xFFF) as usize)
    }
}

impl std::fmt::Display for Bdf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:02x}:{:02x}.{}", self.bus, self.dev, self.func)
    }
}

/// The ECAM region: owns every function's config space.
pub struct Ecam {
    pub base: u64,
    pub buses: u8,
    devices: BTreeMap<Bdf, ConfigSpace>,
}

impl Ecam {
    pub fn new(base: u64, buses: u8) -> Self {
        Ecam { base, buses, devices: BTreeMap::new() }
    }

    pub fn size(&self) -> u64 {
        (self.buses as u64) << 20
    }

    pub fn contains(&self, addr: u64) -> bool {
        addr >= self.base && addr < self.base + self.size()
    }

    pub fn attach(&mut self, bdf: Bdf, cfg: ConfigSpace) {
        assert!(
            self.devices.insert(bdf, cfg).is_none(),
            "duplicate function at {bdf}"
        );
    }

    pub fn function(&self, bdf: Bdf) -> Option<&ConfigSpace> {
        self.devices.get(&bdf)
    }

    pub fn function_mut(&mut self, bdf: Bdf) -> Option<&mut ConfigSpace> {
        self.devices.get_mut(&bdf)
    }

    pub fn functions(&self) -> impl Iterator<Item = (&Bdf, &ConfigSpace)> {
        self.devices.iter()
    }

    /// MMIO read (guest-visible behaviour: absent functions float high —
    /// all-ones — exactly how enumeration detects emptiness).
    pub fn mmio_read32(&self, addr: u64) -> u32 {
        debug_assert!(self.contains(addr));
        let (bdf, off) = Bdf::from_ecam_offset(addr - self.base);
        match self.devices.get(&bdf) {
            Some(cfg) => cfg.r32(off & !3),
            None => 0xFFFF_FFFF,
        }
    }

    pub fn mmio_write32(&mut self, addr: u64, v: u32) {
        debug_assert!(self.contains(addr));
        let (bdf, off) = Bdf::from_ecam_offset(addr - self.base);
        if let Some(cfg) = self.devices.get_mut(&bdf) {
            cfg.cfg_write32(off & !3, v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pcie::config_space::off;

    #[test]
    fn bdf_ecam_math_roundtrip() {
        let b = Bdf::new(3, 17, 2);
        let off = b.ecam_offset() + 0x0F4;
        let (back, reg) = Bdf::from_ecam_offset(off);
        assert_eq!(back, b);
        assert_eq!(reg, 0x0F4);
    }

    #[test]
    fn absent_function_reads_ffffffff() {
        let e = Ecam::new(0xE000_0000, 4);
        assert_eq!(e.mmio_read32(0xE000_0000), 0xFFFF_FFFF);
    }

    #[test]
    fn attached_function_readable_through_mmio() {
        let mut e = Ecam::new(0xE000_0000, 4);
        let cfg = ConfigSpace::endpoint(0x1E98, 0x0100, [5, 2, 0]);
        let bdf = Bdf::new(1, 0, 0);
        e.attach(bdf, cfg);
        let addr = 0xE000_0000 + bdf.ecam_offset() + off::VENDOR_ID as u64;
        assert_eq!(e.mmio_read32(addr) & 0xFFFF, 0x1E98);
    }

    #[test]
    fn mmio_write_reaches_config() {
        let mut e = Ecam::new(0xE000_0000, 2);
        let mut cfg = ConfigSpace::endpoint(1, 2, [0, 0, 0]);
        cfg.add_bar64(0, 1 << 16);
        let bdf = Bdf::new(0, 3, 0);
        e.attach(bdf, cfg);
        let bar0 = 0xE000_0000 + bdf.ecam_offset() + off::BAR0 as u64;
        e.mmio_write32(bar0, 0xFFFF_FFFF);
        let mask = e.mmio_read32(bar0);
        assert_eq!(mask & 0xFFFF_0000, 0xFFFF_0000);
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_attach_panics() {
        let mut e = Ecam::new(0, 1);
        e.attach(Bdf::new(0, 0, 0), ConfigSpace::endpoint(1, 1, [0; 3]));
        e.attach(Bdf::new(0, 0, 0), ConfigSpace::endpoint(1, 1, [0; 3]));
    }
}
