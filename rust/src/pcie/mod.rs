//! PCIe hierarchy substrate.
//!
//! CXLRAMSim's architectural-correctness claim rests on modeling the full
//! PCIe plumbing the CXL stack rides on: per-function 4 KiB config
//! spaces ([`config_space`]), the ECAM window the MCFG table advertises
//! ([`ecam`]), and a hierarchy of root complex -> root port (type-1
//! bridge) -> endpoint that the guest enumerates bus-by-bus.

pub mod config_space;
pub mod ecam;

pub use config_space::ConfigSpace;
pub use ecam::{Bdf, Ecam};

/// Well-known IDs used by the modeled hardware.
pub mod ids {
    /// Our root-port / host-bridge "silicon".
    pub const VENDOR_SIM: u16 = 0x1AF4;
    pub const DEV_ROOT_PORT: u16 = 0x0C01;
    /// CXL switch upstream / downstream port bridges.
    pub const DEV_SWITCH_USP: u16 = 0x0C02;
    pub const DEV_SWITCH_DSP: u16 = 0x0C03;
    /// CXL Type-3 memory expander function.
    pub const VENDOR_CXL_DEV: u16 = 0x1E98;
    pub const DEV_CXL_MEMDEV: u16 = 0x0D93;
    /// Class code for a CXL memory device (base 05h memory, sub 02h CXL,
    /// prog-if 10h — what Linux's cxl_pci driver matches).
    pub const CLASS_CXL_MEM: [u8; 3] = [0x05, 0x02, 0x10];
}

/// Build an N-expander topology:
/// bus 0: dev 0 = host bridge (RC), dev 1+i = CXL root port i (a type-1
/// bridge to bus 1+i); bus 1+i: dev 0 = CXL Type-3 expander endpoint i.
/// Every endpoint gets a distinct BDF and its own 4 KiB config space;
/// the caller (machine builder) then adds DVSECs/BARs per endpoint.
pub fn build_topology_n(
    ecam: &mut Ecam,
    n: usize,
) -> (Bdf, Vec<Bdf>, Vec<Bdf>) {
    assert!(n >= 1, "need at least one expander");
    assert!(
        n < ecam.buses as usize,
        "ECAM window has {} buses; {} expanders need {}",
        ecam.buses,
        n,
        n + 1
    );
    let host_bridge = Bdf::new(0, 0, 0);
    let hb = ConfigSpace::endpoint(
        ids::VENDOR_SIM,
        0x0C00,
        [0x06, 0x00, 0x00], // host bridge class
    );
    ecam.attach(host_bridge, hb);

    let mut root_ports = Vec::with_capacity(n);
    let mut endpoints = Vec::with_capacity(n);
    for i in 0..n {
        let bus = (1 + i) as u8;
        let root_port = Bdf::new(0, bus, 0);
        let mut rp =
            ConfigSpace::bridge(ids::VENDOR_SIM, ids::DEV_ROOT_PORT);
        rp.w8(config_space::off::PRIMARY_BUS, 0);
        rp.w8(config_space::off::SECONDARY_BUS, bus);
        rp.w8(config_space::off::SUBORDINATE_BUS, bus);
        ecam.attach(root_port, rp);

        let endpoint = Bdf::new(bus, 0, 0);
        let ep = ConfigSpace::endpoint(
            ids::VENDOR_CXL_DEV,
            ids::DEV_CXL_MEMDEV,
            ids::CLASS_CXL_MEM,
        );
        ecam.attach(endpoint, ep);
        root_ports.push(root_port);
        endpoints.push(endpoint);
    }
    (host_bridge, root_ports, endpoints)
}

/// Single-expander convenience wrapper (the original topology).
pub fn build_topology(ecam: &mut Ecam) -> (Bdf, Bdf, Bdf) {
    let (hb, rps, eps) = build_topology_n(ecam, 1);
    (hb, rps[0], eps[0])
}

/// The ECAM functions of one modeled switch.
pub struct SwitchBdfs {
    pub root_port: Bdf,
    /// Upstream switch port (type-1 bridge below the root port).
    pub upstream: Bdf,
    /// One downstream port bridge per attached endpoint, in port order.
    pub downstream: Vec<Bdf>,
}

/// Build a switched topology: bus 0 carries the host bridge plus one
/// CXL root port per switch; each root port's secondary bus holds the
/// switch's upstream bridge, whose internal bus carries one downstream
/// bridge per attached endpoint; every endpoint sits alone on a leaf
/// bus. `groups[j]` = endpoints behind switch j (assigned
/// consecutively). The guest's flat bus scan discovers the full
/// 3-bridge-deep hierarchy from the type-1 secondary/subordinate
/// registers alone. Returns (host bridge, per-switch ports, endpoint
/// BDFs flattened in device order).
pub fn build_topology_switched(
    ecam: &mut Ecam,
    groups: &[usize],
) -> (Bdf, Vec<SwitchBdfs>, Vec<Bdf>) {
    let total: usize = groups.iter().sum();
    assert!(total >= 1, "need at least one expander");
    let buses_needed = 1 + groups.iter().map(|n| 2 + n).sum::<usize>();
    assert!(
        buses_needed <= ecam.buses as usize,
        "ECAM window has {} buses; this switched topology needs \
         {buses_needed}",
        ecam.buses
    );
    let host_bridge = Bdf::new(0, 0, 0);
    let hb = ConfigSpace::endpoint(
        ids::VENDOR_SIM,
        0x0C00,
        [0x06, 0x00, 0x00], // host bridge class
    );
    ecam.attach(host_bridge, hb);

    let mut switches = Vec::with_capacity(groups.len());
    let mut endpoints = Vec::with_capacity(total);
    let mut next_bus = 1u8;
    for (j, &n) in groups.iter().enumerate() {
        assert!(n >= 1 && n <= 30, "switch fanout out of range");
        let usp_bus = next_bus;
        let int_bus = usp_bus + 1;
        let sub_bus = int_bus + n as u8;

        let root_port = Bdf::new(0, (1 + j) as u8, 0);
        let mut rp =
            ConfigSpace::bridge(ids::VENDOR_SIM, ids::DEV_ROOT_PORT);
        rp.w8(config_space::off::PRIMARY_BUS, 0);
        rp.w8(config_space::off::SECONDARY_BUS, usp_bus);
        rp.w8(config_space::off::SUBORDINATE_BUS, sub_bus);
        ecam.attach(root_port, rp);

        let upstream = Bdf::new(usp_bus, 0, 0);
        let mut us =
            ConfigSpace::bridge(ids::VENDOR_SIM, ids::DEV_SWITCH_USP);
        us.w8(config_space::off::PRIMARY_BUS, usp_bus);
        us.w8(config_space::off::SECONDARY_BUS, int_bus);
        us.w8(config_space::off::SUBORDINATE_BUS, sub_bus);
        ecam.attach(upstream, us);

        let mut downstream = Vec::with_capacity(n);
        for k in 0..n {
            let leaf = int_bus + 1 + k as u8;
            let dsp = Bdf::new(int_bus, k as u8, 0);
            let mut ds =
                ConfigSpace::bridge(ids::VENDOR_SIM, ids::DEV_SWITCH_DSP);
            ds.w8(config_space::off::PRIMARY_BUS, int_bus);
            ds.w8(config_space::off::SECONDARY_BUS, leaf);
            ds.w8(config_space::off::SUBORDINATE_BUS, leaf);
            ecam.attach(dsp, ds);

            let ep_bdf = Bdf::new(leaf, 0, 0);
            let ep = ConfigSpace::endpoint(
                ids::VENDOR_CXL_DEV,
                ids::DEV_CXL_MEMDEV,
                ids::CLASS_CXL_MEM,
            );
            ecam.attach(ep_bdf, ep);
            downstream.push(dsp);
            endpoints.push(ep_bdf);
        }
        switches.push(SwitchBdfs { root_port, upstream, downstream });
        next_bus = sub_bus + 1;
    }
    (host_bridge, switches, endpoints)
}

#[cfg(test)]
mod tests {
    use super::*;
    use config_space::off;

    #[test]
    fn topology_has_three_functions() {
        let mut e = Ecam::new(0xE000_0000, 8);
        let (hb, rp, ep) = build_topology(&mut e);
        assert_eq!(e.functions().count(), 3);
        assert!(e.function(hb).is_some());
        assert!(e.function(rp).unwrap().is_bridge());
        let epc = e.function(ep).unwrap();
        assert_eq!(epc.r8(off::CLASS_BASE), 0x05);
        assert_eq!(epc.r8(off::CLASS_SUB), 0x02);
    }

    #[test]
    fn root_port_routes_bus1() {
        let mut e = Ecam::new(0xE000_0000, 8);
        let (_, rp, _) = build_topology(&mut e);
        let c = e.function(rp).unwrap();
        assert_eq!(c.r8(off::SECONDARY_BUS), 1);
        assert_eq!(c.r8(off::SUBORDINATE_BUS), 1);
    }

    #[test]
    fn switched_topology_builds_two_level_hierarchy() {
        let mut e = Ecam::new(0xE000_0000, 16);
        let (hb, sws, eps) = build_topology_switched(&mut e, &[4]);
        // 1 HB + 1 RP + 1 USP + 4 DSP + 4 EP = 11 functions.
        assert_eq!(e.functions().count(), 11);
        assert!(e.function(hb).is_some());
        assert_eq!(sws.len(), 1);
        assert_eq!(eps.len(), 4);
        let rp = e.function(sws[0].root_port).unwrap();
        assert!(rp.is_bridge());
        assert_eq!(rp.r8(off::SECONDARY_BUS), 1);
        assert_eq!(rp.r8(off::SUBORDINATE_BUS), 6);
        let us = e.function(sws[0].upstream).unwrap();
        assert_eq!(us.r8(off::SECONDARY_BUS), 2);
        assert_eq!(us.r8(off::SUBORDINATE_BUS), 6);
        // Endpoints on leaf buses 3..=6, each behind its own DSP.
        for (k, ep) in eps.iter().enumerate() {
            assert_eq!(ep.bus, 3 + k as u8);
            let ds = e.function(sws[0].downstream[k]).unwrap();
            assert_eq!(ds.r8(off::SECONDARY_BUS), ep.bus);
            assert_eq!(ds.r8(off::SUBORDINATE_BUS), ep.bus);
            let epc = e.function(*ep).unwrap();
            assert_eq!(epc.r8(off::CLASS_BASE), 0x05);
        }
    }

    #[test]
    fn two_switch_topology_keeps_bus_ranges_disjoint() {
        let mut e = Ecam::new(0xE000_0000, 16);
        let (_, sws, eps) = build_topology_switched(&mut e, &[2, 2]);
        assert_eq!(eps.len(), 4);
        let rp0 = e.function(sws[0].root_port).unwrap();
        let rp1 = e.function(sws[1].root_port).unwrap();
        assert!(
            rp0.r8(off::SUBORDINATE_BUS) < rp1.r8(off::SECONDARY_BUS),
            "bus ranges must not overlap"
        );
        // Device order follows switch order.
        assert!(eps[1].bus < eps[2].bus);
    }

    #[test]
    fn n_way_topology_assigns_distinct_buses() {
        let mut e = Ecam::new(0xE000_0000, 8);
        let (hb, rps, eps) = build_topology_n(&mut e, 3);
        assert_eq!(e.functions().count(), 1 + 3 + 3);
        assert!(e.function(hb).is_some());
        for (i, (rp, ep)) in rps.iter().zip(&eps).enumerate() {
            let bus = (1 + i) as u8;
            assert_eq!(ep.bus, bus);
            assert_eq!(ep.dev, 0);
            let c = e.function(*rp).unwrap();
            assert!(c.is_bridge());
            assert_eq!(c.r8(off::SECONDARY_BUS), bus);
            let epc = e.function(*ep).unwrap();
            assert_eq!(epc.r8(off::CLASS_BASE), 0x05);
        }
    }
}
