//! Baseline: membus-attached CXL (the CXL-DMSim / SimCXL architecture,
//! paper Fig. 1A) for the E3 ablation.
//!
//! The baseline's *mechanism* lives in the machine
//! (`CxlAttach::MemBus` short-circuits the IOBus/RC/link path into a
//! fixed-latency adder on the membus); this module provides the
//! config constructors and documents what the baseline deliberately
//! gets wrong relative to the architecturally-correct IOBus attach:
//!
//! * no CXL.io surface (device would enumerate as a PCI memory
//!   controller -> kernel must be patched; we keep the registers but
//!   nothing routes through them),
//! * no M2S/S2M packetization, flit framing or credit back-pressure,
//! * no IOBus sharing/contention with other I/O traffic,
//! * protocol latencies collapse into one constant, so loaded latency
//!   under-estimates at high intensity (no queueing in the link).

use crate::config::{CxlAttach, SimConfig};

/// The paper's system: expander behind the root complex on the IOBus.
pub fn iobus_config() -> SimConfig {
    let mut c = SimConfig::default();
    c.cxl.attach = CxlAttach::IoBus;
    c
}

/// The baseline: expander directly on the membus (Fig. 1A).
pub fn membus_config() -> SimConfig {
    let mut c = SimConfig::default();
    c.cxl.attach = CxlAttach::MemBus;
    c
}

/// Derive the membus-attached twin of an arbitrary config (same sizes,
/// latencies and workload surface — only the attach point differs).
pub fn membus_twin(cfg: &SimConfig) -> SimConfig {
    let mut c = cfg.clone();
    c.cxl.attach = CxlAttach::MemBus;
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twins_differ_only_in_attach() {
        let a = iobus_config();
        let b = membus_twin(&a);
        assert_eq!(a.cxl.attach, CxlAttach::IoBus);
        assert_eq!(b.cxl.attach, CxlAttach::MemBus);
        assert_eq!(a.cxl.mem_size, b.cxl.mem_size);
        assert_eq!(a.cxl.link_lat_ns, b.cxl.link_lat_ns);
    }
}
