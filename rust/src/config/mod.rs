//! Simulation configuration (Table I surface).
//!
//! `SimConfig` is the single schema for the whole machine; it can be
//! loaded from a TOML file, overridden from the CLI (`--set key=value`)
//! and printed in the paper's Table-I format (`bench table1_config`).

use anyhow::{bail, Context, Result};

use crate::util::toml::{TomlDoc, TomlValue};
use crate::util::{human_bytes, is_pow2};
use crate::workloads::serve::ServeConfig;

/// `[workload]` section: which workload `run` drives when the CLI does
/// not override it, plus the serve/replay parameters.
#[derive(Clone, Debug, Default)]
pub struct WorkloadConfig {
    /// Workload kind (`"serve"`, `"replay"`, `"stream-triad"`, …).
    /// `None` = the CLI's default.
    pub kind: Option<String>,
    /// Trace path; required by (and only valid with) `kind = "replay"`.
    pub trace: Option<String>,
    /// `[workload.serve]` knobs (defaults when the section is absent).
    pub serve: ServeConfig,
}

/// Maximum simulated hosts sharing one CXL fabric (`system.hosts`).
/// Rack scale: a pod of up to 64 hosts over one fabric; the parallel
/// event loop (`[sim] threads`) is what makes runs this wide tractable.
pub const MAX_HOSTS: usize = 64;

/// Reference to one logical device, written `"devN.ldK"` (or just
/// `"devN"` for LD 0) in `[host.N] lds` lists. CXL windows are keyed by
/// their first member device and LD index, so an interleave-set window
/// is named by its first member with `ld0`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct LdRef {
    pub dev: usize,
    pub ld: u16,
}

impl LdRef {
    pub fn parse(s: &str) -> Result<Self> {
        let rest = s
            .strip_prefix("dev")
            .with_context(|| format!("LD ref '{s}' must look like devN.ldK"))?;
        let (d, l) = match rest.split_once(".ld") {
            Some((d, l)) => (d, l),
            None => (rest, "0"),
        };
        let dev = d
            .parse::<usize>()
            .with_context(|| format!("bad device index in LD ref '{s}'"))?;
        let ld = l
            .parse::<u16>()
            .with_context(|| format!("bad LD index in LD ref '{s}'"))?;
        Ok(LdRef { dev, ld })
    }
}

impl std::fmt::Display for LdRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "dev{}.ld{}", self.dev, self.ld)
    }
}

/// One scheduled Fabric-Manager action: at simulated time `at_ns` the
/// FM issues a bind or unbind for one logical device, while guests are
/// executing workloads. Written `"@<time> unbind devN.ldK"` /
/// `"@<time> bind devN.ldK hostH"` in `[fm] events` lists and
/// `--fm-script` files (time units: ns|us|ms|s).
#[derive(Clone, Debug, PartialEq)]
pub struct FmEventDef {
    /// Simulated time of the FM action, in nanoseconds.
    pub at_ns: f64,
    pub op: FmOp,
}

/// The FM-API action an [`FmEventDef`] performs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FmOp {
    /// `BIND_LD`: give `ld` to `host` (must currently be unbound).
    Bind { ld: LdRef, host: usize },
    /// `UNBIND_LD`: take `ld` away from its current owner (the owning
    /// guest offlines the zNUMA node through the hot-remove path first).
    Unbind { ld: LdRef },
}

impl FmEventDef {
    /// The logical device this event operates on.
    pub fn ld(&self) -> LdRef {
        match self.op {
            FmOp::Bind { ld, .. } | FmOp::Unbind { ld } => ld,
        }
    }

    /// Parse `"@50us unbind dev0.ld1"` / `"@1.5ms bind dev0.ld1 host1"`.
    pub fn parse(s: &str) -> Result<Self> {
        let mut it = s.split_whitespace();
        let t = it
            .next()
            .with_context(|| format!("empty FM event '{s}'"))?;
        let t = t.strip_prefix('@').with_context(|| {
            format!("FM event '{s}' must start with @<time>")
        })?;
        let at_ns = parse_time_ns(t)
            .with_context(|| format!("bad time in FM event '{s}'"))?;
        let verb = it
            .next()
            .with_context(|| format!("FM event '{s}' lacks a verb"))?;
        let ld = LdRef::parse(it.next().with_context(|| {
            format!("FM event '{s}' lacks a devN.ldK target")
        })?)?;
        let op = match verb {
            "unbind" => FmOp::Unbind { ld },
            "bind" => {
                let h = it.next().with_context(|| {
                    format!("FM bind event '{s}' lacks a hostH target")
                })?;
                let host = h
                    .strip_prefix("host")
                    .and_then(|n| n.parse::<usize>().ok())
                    .with_context(|| {
                        format!("bad host '{h}' in FM event '{s}' \
                                 (expected hostH)")
                    })?;
                FmOp::Bind { ld, host }
            }
            other => bail!(
                "unknown FM verb '{other}' in '{s}' (bind|unbind)"
            ),
        };
        if it.next().is_some() {
            bail!("trailing tokens in FM event '{s}'");
        }
        Ok(FmEventDef { at_ns, op })
    }
}

/// The load signal a `[fm] policy` optimizes (see `docs/CONFIG.md`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FmPolicyKind {
    /// Move idle logical devices toward the host whose allocator is
    /// spilling pages off its policy node (capacity pressure, sampled
    /// as `sys.numa_fallback_allocs` deltas).
    CapacityRebalance,
    /// Move idle logical devices toward the host generating the most
    /// CXL traffic (bandwidth pressure, sampled as per-host CXL
    /// fill/write-back deltas), spreading load over more LD capacity.
    BandwidthFairness,
}

impl FmPolicyKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "capacity_rebalance" => Ok(FmPolicyKind::CapacityRebalance),
            "bandwidth_fairness" => Ok(FmPolicyKind::BandwidthFairness),
            _ => bail!(
                "unknown fm policy '{s}' \
                 (capacity_rebalance|bandwidth_fairness)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FmPolicyKind::CapacityRebalance => "capacity_rebalance",
            FmPolicyKind::BandwidthFairness => "bandwidth_fairness",
        }
    }
}

/// Telemetry-driven Fabric-Manager policy (`[fm] policy`): instead of a
/// hand-written `[fm] events` schedule, the FM samples per-host and
/// per-LD stats at a deterministic `epoch` cadence and computes
/// UNBIND/BIND moves itself, with hysteresis so decisions do not
/// ping-pong. Mutually exclusive with `[fm] events`.
#[derive(Clone, Debug, PartialEq)]
pub struct FmPolicyConfig {
    pub kind: FmPolicyKind,
    /// Sampling/decision cadence in simulated ns (`[fm] epoch`).
    pub epoch_ns: f64,
    /// Minimum time an LD stays put after any bind — boot or policy —
    /// before the policy may move it (`[fm] min_residency`).
    pub min_residency_ns: f64,
    /// Per-host cooldown after participating in a move; neither end of
    /// a move donates or receives again until it expires
    /// (`[fm] cooldown`).
    pub cooldown_ns: f64,
    /// Back-off after the owning guest refuses an offline (pages in
    /// use); doubles per consecutive refusal of the same LD, capped at
    /// 8x (`[fm] refusal_backoff`).
    pub refusal_backoff_ns: f64,
}

impl FmPolicyConfig {
    /// Policy `kind` with the default cadence/hysteresis knobs.
    pub fn new(kind: FmPolicyKind) -> Self {
        FmPolicyConfig {
            kind,
            epoch_ns: 10_000.0,          // 10 us
            min_residency_ns: 20_000.0,  // 20 us
            cooldown_ns: 20_000.0,       // 20 us
            refusal_backoff_ns: 50_000.0, // 50 us
        }
    }
}

/// Parse a duration with a unit suffix into nanoseconds.
fn parse_time_ns(s: &str) -> Result<f64> {
    // Longest suffixes first: "s" would otherwise swallow "ns"/"us"/"ms".
    for (suf, mult) in
        [("ns", 1.0), ("us", 1e3), ("ms", 1e6), ("s", 1e9)]
    {
        if let Some(v) = s.strip_suffix(suf) {
            let x: f64 = v
                .parse()
                .with_context(|| format!("bad number '{v}'"))?;
            return Ok(x * mult);
        }
    }
    bail!("time '{s}' needs a unit suffix (ns|us|ms|s)")
}

/// CPU model selector (paper Table I: In-order, Out-of-Order).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CpuModel {
    /// gem5 "TimingSimpleCPU" analogue: one outstanding memory op.
    InOrder,
    /// gem5 "O3CPU" analogue: ROB/LSQ, multiple outstanding misses.
    OutOfOrder,
}

impl CpuModel {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "inorder" | "timing" => Ok(CpuModel::InOrder),
            "o3" | "ooo" | "out-of-order" => Ok(CpuModel::OutOfOrder),
            _ => bail!("unknown cpu model '{s}' (inorder|o3)"),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            CpuModel::InOrder => "In-order (Timing)",
            CpuModel::OutOfOrder => "Out-of-Order (O3)",
        }
    }
}

/// Where the CXL expander is attached — the paper's core architectural
/// point (Fig. 1). `IoBus` is CXLRAMSim; `MemBus` reproduces the
/// CXL-DMSim / SimCXL shortcut for the E3 ablation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CxlAttach {
    IoBus,
    MemBus,
}

#[derive(Clone, Debug)]
pub struct CacheConfig {
    pub size: u64,
    pub assoc: usize,
    pub line: u64,
    /// Hit latency in CPU cycles.
    pub lat_cycles: u64,
    pub mshrs: usize,
    /// Stride prefetcher at this level (modeled for L2 only).
    pub prefetch: bool,
    /// Prefetch run-ahead distance in lines.
    pub pf_degree: usize,
}

impl CacheConfig {
    pub fn sets(&self) -> usize {
        (self.size / (self.line * self.assoc as u64)) as usize
    }
    fn validate(&self, name: &str) -> Result<()> {
        if !is_pow2(self.line) || self.line < 16 {
            bail!("{name}: line size must be pow2 >= 16");
        }
        if self.size % (self.line * self.assoc as u64) != 0 {
            bail!("{name}: size not divisible by line*assoc");
        }
        if !is_pow2(self.sets() as u64) {
            bail!("{name}: set count must be a power of two");
        }
        if self.mshrs == 0 {
            bail!("{name}: need at least one MSHR");
        }
        Ok(())
    }
}

/// DRAM timing (applies to both system DRAM and the expander's media,
/// with independent values).
#[derive(Clone, Debug)]
pub struct DramConfig {
    pub banks: usize,
    /// Row-hit access latency (ns).
    pub t_cas_ns: f64,
    /// Row activation (ns) added on row miss.
    pub t_rcd_ns: f64,
    /// Precharge (ns) added on row conflict.
    pub t_rp_ns: f64,
    /// Row-buffer size in bytes.
    pub row_bytes: u64,
    /// Peak data bus bandwidth (GB/s) of the channel.
    pub bw_gbps: f64,
}

/// Media latency class of an expander card — scales the shared media
/// timing so heterogeneous fleets (near/baseline/far devices) can be
/// described without repeating every DRAM knob per device.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LatencyClass {
    /// DDR5-class media: 25% faster than the shared baseline timing.
    Near,
    #[default]
    Baseline,
    /// Capacity-optimized / far media: 50% slower than baseline.
    Far,
}

impl LatencyClass {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "near" => Ok(LatencyClass::Near),
            "baseline" | "default" => Ok(LatencyClass::Baseline),
            "far" => Ok(LatencyClass::Far),
            _ => bail!("unknown latency class '{s}' (near|baseline|far)"),
        }
    }

    pub fn media_scale(&self) -> f64 {
        match self {
            LatencyClass::Near => 0.75,
            LatencyClass::Baseline => 1.0,
            LatencyClass::Far => 1.5,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LatencyClass::Near => "near",
            LatencyClass::Baseline => "baseline",
            LatencyClass::Far => "far",
        }
    }
}

/// Interleave arithmetic used by the window decoders (CFMWS byte 25:
/// 0 = modulo, 1 = XOR of the target-selection bit groups).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum InterleaveArith {
    #[default]
    Modulo,
    Xor,
}

/// Sparse per-device override of the shared `[cxl]` parameters, loaded
/// from `[cxl.devN]` TOML sections (or `--set cxl.devN.key=value`).
#[derive(Clone, Debug, Default)]
pub struct CxlDevOverride {
    pub mem_size: Option<u64>,
    pub link_lat_ns: Option<f64>,
    pub link_bw_gbps: Option<f64>,
    /// Link width in lanes (default x8). Without an explicit bandwidth
    /// override, bandwidth scales linearly with width.
    pub link_width: Option<u32>,
    pub latency_class: Option<LatencyClass>,
    /// Logical devices (MLD pooling): the card's capacity splits into
    /// `lds` equal slices, each with its own HDM decoder and window.
    pub lds: Option<usize>,
    /// Logical devices of this card mapped into SEVERAL hosts at once
    /// (CXL 3.x sharing): each listed LD index becomes a guest-visible
    /// shared zNUMA node with device-side back-invalidate coherence.
    /// Sharers are the hosts listing the LD in `[host.N] lds` (every
    /// host when nobody lists it explicitly).
    pub shared_lds: Option<Vec<u16>>,
}

/// Fully-resolved parameters of one expander card: the shared `[cxl]`
/// values with this device's override applied.
#[derive(Clone, Debug)]
pub struct CxlDeviceCfg {
    pub mem_size: u64,
    pub link_lat_ns: f64,
    pub link_bw_gbps: f64,
    pub link_width: u32,
    pub latency_class: LatencyClass,
    pub media: DramConfig,
    /// Logical devices exposed (1 = plain SLD).
    pub lds: usize,
    /// LD indices declared shared via `[cxl.devN] shared_lds` (empty
    /// when sharing is expressed only through multi-host `[host.N]
    /// lds` lists — the machine marks those at build time).
    pub shared_lds: Vec<u16>,
}

/// Default store-and-forward latency of a virtual switch hop (ns) when
/// `[cxl.switchN] fwd_lat_ns` is not given. Real CXL 2.0 switch parts
/// add a few tens of ns port-to-port.
pub const SWITCH_FWD_LAT_NS: f64 = 25.0;

/// Sparse per-switch override of the shared link parameters, loaded
/// from `[cxl.switchN]` TOML sections (or `--set cxl.switchN.key=v`).
#[derive(Clone, Debug, Default)]
pub struct CxlSwitchOverride {
    /// Downstream ports on this switch (devices assigned consecutively).
    pub fanout: Option<usize>,
    /// Upstream-link propagation latency (ns).
    pub link_lat_ns: Option<f64>,
    /// Upstream-link bandwidth (GB/s) — shared by every endpoint below.
    pub link_bw_gbps: Option<f64>,
    /// Store-and-forward latency per switch hop (ns).
    pub fwd_lat_ns: Option<f64>,
}

/// Fully-resolved parameters of one virtual CXL switch, including its
/// consecutive slice of the device list.
#[derive(Clone, Debug)]
pub struct CxlSwitchCfg {
    pub fanout: usize,
    pub link_lat_ns: f64,
    pub link_bw_gbps: f64,
    pub fwd_lat_ns: f64,
    /// First device index behind this switch.
    pub first_dev: usize,
    /// Devices actually attached (`<= fanout`).
    pub ndev: usize,
}

/// One host-physical fixed memory window (one CEDT CFMWS, one SRAT
/// domain, one guest zNUMA node): either an interleave set of SLD
/// devices or a single logical-device capacity slice of an MLD.
#[derive(Clone, Debug)]
pub struct CxlWindowDef {
    /// Member device indices in CFMWS target-slot order.
    pub targets: Vec<usize>,
    /// Logical-device index within the (single) target for MLD slice
    /// windows; 0 for SLD windows.
    pub ld: u16,
    /// Window size in bytes.
    pub size: u64,
}

/// CXL link + protocol parameters (paper §III-B.2: all user-calibratable).
#[derive(Clone, Debug)]
pub struct CxlConfig {
    /// Per-expander capacity (shared default; `[cxl.devN] size` overrides).
    pub mem_size: u64,
    /// M2S/S2M packetization latency at the root complex (ns).
    pub pkt_lat_ns: f64,
    /// De-packetization latency at the endpoint (ns).
    pub depkt_lat_ns: f64,
    /// Link propagation latency one way (ns).
    pub link_lat_ns: f64,
    /// Link bandwidth (GB/s) — x8 CXL 2.0 ~ 32 GB/s raw.
    pub link_bw_gbps: f64,
    /// Flit size in bytes (CXL 2.0: 68B flit carrying 64B payload).
    pub flit_bytes: u64,
    /// Request credits per channel (M2S / S2M).
    pub credits: usize,
    /// Device media timing.
    pub media: DramConfig,
    pub attach: CxlAttach,
    /// Number of expander cards on the I/O bus (each behind its own
    /// host bridge + root port, on its own PCIe bus).
    pub devices: usize,
    /// Interleave ways across devices. 0 = auto: all devices form one
    /// interleave set when the count is a power of two, else one
    /// single-device window per card.
    pub interleave_ways: usize,
    /// Interleave granularity in bytes (power of two, 256..=16384).
    pub interleave_granularity: u64,
    pub interleave_arith: InterleaveArith,
    /// Sparse per-device overrides, indexed by device.
    pub dev_overrides: Vec<CxlDevOverride>,
    /// Virtual CXL switches between root ports and endpoints. 0 =
    /// direct attach (every device on its own root port); M > 0 places
    /// M switches, each with one upstream port to its own root port and
    /// `fanout` downstream ports, devices assigned consecutively.
    pub switches: usize,
    /// Sparse per-switch overrides, indexed by switch.
    pub switch_overrides: Vec<CxlSwitchOverride>,
}

impl CxlConfig {
    /// Effective interleave ways (resolves the `0 = auto` encoding).
    /// See `docs/CONFIG.md` for the full auto-width rule.
    pub fn ways(&self) -> usize {
        if self.interleave_ways != 0 {
            return self.interleave_ways;
        }
        if self.switches > 0 {
            // Switched topologies decode per endpoint (each device —
            // or LD — is its own window); auto resolves to 1.
            return 1;
        }
        if self.devices.is_power_of_two() {
            self.devices
        } else {
            1
        }
    }

    /// Number of interleave sets (each set = one CFMWS window = one
    /// guest NUMA domain).
    pub fn interleave_sets(&self) -> usize {
        self.devices / self.ways()
    }

    /// Device indices participating in interleave set `set`.
    pub fn set_members(&self, set: usize) -> std::ops::Range<usize> {
        let w = self.ways();
        set * w..(set + 1) * w
    }

    /// Resolved parameters for device `i`.
    pub fn device(&self, i: usize) -> CxlDeviceCfg {
        let ov = self.dev_overrides.get(i).cloned().unwrap_or_default();
        let class = ov.latency_class.unwrap_or_default();
        let mut media = self.media.clone();
        let s = class.media_scale();
        media.t_cas_ns *= s;
        media.t_rcd_ns *= s;
        media.t_rp_ns *= s;
        let width = ov.link_width.unwrap_or(8);
        let bw = ov
            .link_bw_gbps
            .unwrap_or(self.link_bw_gbps * width as f64 / 8.0);
        CxlDeviceCfg {
            mem_size: ov.mem_size.unwrap_or(self.mem_size),
            link_lat_ns: ov.link_lat_ns.unwrap_or(self.link_lat_ns),
            link_bw_gbps: bw,
            link_width: width,
            latency_class: class,
            media,
            lds: ov.lds.unwrap_or(1),
            shared_lds: ov.shared_lds.unwrap_or_default(),
        }
    }

    /// Resolved parameters of switch `j`, including the consecutive
    /// device slice it fans out to.
    pub fn switch(&self, j: usize) -> CxlSwitchCfg {
        assert!(j < self.switches, "switch {j} out of range");
        let default_fanout = self.devices.div_ceil(self.switches.max(1));
        let fanout_of = |k: usize| {
            self.switch_overrides
                .get(k)
                .and_then(|o| o.fanout)
                .unwrap_or(default_fanout)
        };
        let first: usize = (0..j).map(|k| fanout_of(k)).sum();
        let first_dev = first.min(self.devices);
        let fanout = fanout_of(j);
        let ndev = fanout.min(self.devices - first_dev);
        let ov = self.switch_overrides.get(j).cloned().unwrap_or_default();
        CxlSwitchCfg {
            fanout,
            link_lat_ns: ov.link_lat_ns.unwrap_or(self.link_lat_ns),
            link_bw_gbps: ov.link_bw_gbps.unwrap_or(self.link_bw_gbps),
            fwd_lat_ns: ov.fwd_lat_ns.unwrap_or(SWITCH_FWD_LAT_NS),
            first_dev,
            ndev,
        }
    }

    /// The switch device `i` sits behind, if any.
    pub fn switch_of(&self, dev: usize) -> Option<usize> {
        (0..self.switches).find(|&j| {
            let s = self.switch(j);
            dev >= s.first_dev && dev < s.first_dev + s.ndev
        })
    }

    /// Number of CXL host bridges (ACPI0016 devices / CHBS blocks /
    /// root ports): one per switch when switches are configured, else
    /// one per device (the PR-1 direct-attach topology).
    pub fn bridges(&self) -> usize {
        if self.switches == 0 {
            self.devices
        } else {
            self.switches
        }
    }

    /// Host-bridge index owning device `i`.
    pub fn bridge_of(&self, dev: usize) -> usize {
        match self.switch_of(dev) {
            Some(j) => j,
            None => dev,
        }
    }

    /// One-way propagation from root port to device `i`'s endpoint (ns),
    /// including the switch hop when the device is switch-attached.
    pub fn path_lat_ns(&self, i: usize) -> f64 {
        let d = self.device(i);
        match self.switch_of(i) {
            None => d.link_lat_ns,
            Some(j) => {
                let s = self.switch(j);
                s.link_lat_ns + s.fwd_lat_ns + d.link_lat_ns
            }
        }
    }

    /// The host-physical fixed windows this topology publishes, in
    /// CEDT/SRAT order: one per interleave set, except that a
    /// single-device set whose device is an MLD (`lds = K`) expands into
    /// K per-LD slice windows.
    pub fn window_defs(&self) -> Vec<CxlWindowDef> {
        let mut out = Vec::new();
        for set in 0..self.interleave_sets() {
            let members: Vec<usize> = self.set_members(set).collect();
            if members.len() == 1 {
                let i = members[0];
                let d = self.device(i);
                let lds = d.lds.max(1);
                let slice = d.mem_size / lds as u64;
                for ld in 0..lds {
                    out.push(CxlWindowDef {
                        targets: vec![i],
                        ld: ld as u16,
                        size: slice,
                    });
                }
            } else {
                out.push(CxlWindowDef {
                    targets: members,
                    ld: 0,
                    size: self.set_size(set),
                });
            }
        }
        out
    }

    /// Host-physical size of interleave set `set`'s window (the sum of
    /// its member capacities; members are validated equal-sized).
    pub fn set_size(&self, set: usize) -> u64 {
        self.set_members(set).map(|i| self.device(i).mem_size).sum()
    }

    /// Total expander capacity across all devices.
    pub fn total_size(&self) -> u64 {
        (0..self.devices).map(|i| self.device(i).mem_size).sum()
    }
}

#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Simulated hosts sharing the CXL fabric (1..=MAX_HOSTS). Each
    /// host gets its own cores/caches/DRAM/BIOS/guest; the expanders,
    /// switches and links are shared. LD ownership comes from
    /// `[host.N] lds` lists, or round-robin over the windows when none
    /// are given.
    pub hosts: usize,
    /// Explicit per-host LD assignments (`[host.N] lds = ["dev0.ld1"]`);
    /// empty inner lists everywhere = automatic round-robin.
    pub host_lds: Vec<Vec<LdRef>>,
    pub cores: usize,
    pub cpu_model: CpuModel,
    pub freq_ghz: f64,
    /// O3 parameters (ignored by InOrder).
    pub rob_entries: usize,
    pub lsq_entries: usize,
    pub issue_width: usize,
    pub l1: CacheConfig,
    pub l2: CacheConfig,
    pub sys_mem_size: u64,
    pub sys_dram: DramConfig,
    pub membus_lat_ns: f64,
    pub membus_bw_gbps: f64,
    pub iobus_lat_ns: f64,
    pub iobus_bw_gbps: f64,
    pub cxl: CxlConfig,
    /// Scheduled runtime Fabric-Manager actions (`[fm] events` /
    /// `--fm-script`). Non-empty schedules switch every host's BIOS to
    /// the hot-plug window layout: all CXL windows are published to all
    /// hosts (at per-host disjoint bases), unbound windows staying
    /// offline as the hot-add pool.
    pub fm_events: Vec<FmEventDef>,
    /// Telemetry-driven FM policy (`[fm] policy` / `--fm-policy`).
    /// Mutually exclusive with `fm_events`; also switches firmware to
    /// the hot-plug window layout, since any LD may move at runtime.
    pub fm_policy: Option<FmPolicyConfig>,
    pub page_size: u64,
    pub seed: u64,
    /// `[workload]` section (kind/trace selection + serve knobs).
    pub workload: WorkloadConfig,
    /// `[sim] threads`: worker threads for the conservative-parallel
    /// event loop (`--threads`). 1 = serial. Any value produces
    /// bit-identical results — the epoch structure is a function of
    /// queue state, not thread count — so this is purely a wall-clock
    /// knob. Defaults to `$CXLRAMSIM_THREADS` when set, else 1.
    pub threads: usize,
    /// `[sim] commit_lanes`: worker lanes for the sharded fabric commit
    /// phase (`--commit-lanes`). Pending fabric entries are partitioned
    /// by routed device into switch-credit-disjoint lane groups and
    /// committed concurrently; 0 = `"auto"` follows `threads`. Like
    /// `threads`, every value is bit-identical — purely a wall-clock
    /// knob. Defaults to `$CXLRAMSIM_COMMIT_LANES` when set, else auto.
    pub commit_lanes: usize,
    /// `[sim] check`: arm the runtime protocol-invariant checker
    /// (`--check`). Audits credit conservation, event-queue and commit
    /// ordering, window disjointness and snoop-filter soundness on the
    /// live run and fails it loudly on any violation — see
    /// `sim::invariants` for the rule catalog. Off by default (the
    /// audits cost wall-clock, never simulated behaviour). Defaults to
    /// `$CXLRAMSIM_CHECK` when set, else false.
    pub check: bool,
}

/// Default for `[sim] threads`: the `CXLRAMSIM_THREADS` environment
/// variable when it parses to a positive count, else 1 (serial). The
/// env hook is how CI runs the whole tier-1 suite under the parallel
/// scheduler without touching any test's config.
fn default_threads() -> usize {
    std::env::var("CXLRAMSIM_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&t| t >= 1)
        .unwrap_or(1)
}

/// Default for `[sim] commit_lanes`: the `CXLRAMSIM_COMMIT_LANES`
/// environment variable when it parses (`"auto"` or a lane count),
/// else 0 (auto — follow `[sim] threads`). Same CI hook as
/// [`default_threads`]: the nightly TSan smoke exercises the sharded
/// commit path suite-wide without touching any test's config.
fn default_commit_lanes() -> usize {
    std::env::var("CXLRAMSIM_COMMIT_LANES")
        .ok()
        .and_then(|v| parse_commit_lanes(&v))
        .unwrap_or(0)
}

/// Parse a `commit_lanes` spelling: `"auto"` maps to 0, otherwise a
/// plain lane count. Shared by the env default and the TOML loader.
fn parse_commit_lanes(s: &str) -> Option<usize> {
    if s.eq_ignore_ascii_case("auto") {
        Some(0)
    } else {
        s.parse::<usize>().ok()
    }
}

/// Default for `[sim] check`: the `CXLRAMSIM_CHECK` environment
/// variable (`1`/`true` arms the checker), else false. Same CI hook as
/// [`default_threads`]: a workflow leg runs the whole tier-1 suite
/// under the invariant checker without touching any test's config.
fn default_check() -> bool {
    std::env::var("CXLRAMSIM_CHECK")
        .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
        .unwrap_or(false)
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            hosts: 1,
            host_lds: Vec::new(),
            cores: 4,
            cpu_model: CpuModel::OutOfOrder,
            freq_ghz: 3.0,
            rob_entries: 192,
            lsq_entries: 48,
            issue_width: 4,
            l1: CacheConfig {
                size: 32 << 10,
                assoc: 8,
                line: 64,
                lat_cycles: 4,
                mshrs: 8,
                prefetch: false,
                pf_degree: 0,
            },
            l2: CacheConfig {
                size: 1 << 20,
                assoc: 16,
                line: 64,
                lat_cycles: 30,
                mshrs: 32,
                prefetch: true,
                // Run-ahead 16 lines: covers the 2-stream STREAM kernels'
                // demand rate (deg 8 turns late for copy/scale — see the
                // pf-depth ablation in EXPERIMENTS.md §E2).
                pf_degree: 16,
            },
            sys_mem_size: 2 << 30,
            sys_dram: DramConfig {
                banks: 16,
                t_cas_ns: 14.0,
                t_rcd_ns: 14.0,
                t_rp_ns: 14.0,
                row_bytes: 8192,
                bw_gbps: 25.6,
            },
            membus_lat_ns: 4.0,
            membus_bw_gbps: 51.2,
            iobus_lat_ns: 8.0,
            iobus_bw_gbps: 32.0,
            cxl: CxlConfig {
                mem_size: 4 << 30,
                pkt_lat_ns: 25.0,
                depkt_lat_ns: 25.0,
                link_lat_ns: 20.0,
                link_bw_gbps: 32.0,
                flit_bytes: 68,
                credits: 32,
                media: DramConfig {
                    banks: 16,
                    t_cas_ns: 16.0,
                    t_rcd_ns: 16.0,
                    t_rp_ns: 16.0,
                    row_bytes: 8192,
                    bw_gbps: 19.2,
                },
                attach: CxlAttach::IoBus,
                devices: 1,
                interleave_ways: 0,
                interleave_granularity: 256,
                interleave_arith: InterleaveArith::Modulo,
                dev_overrides: Vec::new(),
                switches: 0,
                switch_overrides: Vec::new(),
            },
            fm_events: Vec::new(),
            fm_policy: None,
            page_size: 4096,
            seed: 1,
            workload: WorkloadConfig::default(),
            threads: default_threads(),
            commit_lanes: default_commit_lanes(),
            check: default_check(),
        }
    }
}

impl SimConfig {
    pub fn cycle_ns(&self) -> f64 {
        1.0 / self.freq_ghz
    }

    /// Whether LD ownership can change at runtime — an `[fm] events`
    /// schedule or an `[fm] policy` is configured. Selects the
    /// hot-plug BIOS window layout (every host publishes every window,
    /// unowned ones offline as its hot-add pool), since any LD may
    /// move while guests run.
    pub fn fm_dynamic(&self) -> bool {
        !self.fm_events.is_empty() || self.fm_policy.is_some()
    }

    /// The `devN.ldK` key of every CXL window definition, in
    /// [`CxlConfig::window_defs`] order.
    pub fn window_keys(&self) -> Vec<LdRef> {
        self.cxl
            .window_defs()
            .iter()
            .map(|d| LdRef { dev: d.targets[0], ld: d.ld })
            .collect()
    }

    /// The host owning each CXL window definition, in
    /// [`CxlConfig::window_defs`] order: explicit `[host.N] lds` lists
    /// when given, else round-robin over the windows. With one host
    /// everything lands on host 0 (the pre-pooling behaviour). Shared
    /// windows report their first sharer here; use
    /// [`Self::window_sharers`] for the full mapping.
    pub fn window_hosts(&self) -> Vec<usize> {
        self.window_sharers()
            .iter()
            .map(|s| s.first().copied().unwrap_or(0))
            .collect()
    }

    /// The sharer hosts of each CXL window definition, in
    /// [`CxlConfig::window_defs`] order, ascending host order. Private
    /// (pooled) windows carry exactly one entry — the host
    /// [`Self::window_hosts`] reports. Shared LDs (CXL 3.x) carry one
    /// entry per sharer: the hosts listing the window under
    /// `[host.N] lds`, or every host when a `[cxl.devN] shared_lds`
    /// window is listed by nobody.
    pub fn window_sharers(&self) -> Vec<Vec<usize>> {
        let keys = self.window_keys();
        let explicit = self.host_lds.iter().any(|l| !l.is_empty());
        keys.iter()
            .enumerate()
            .map(|(i, k)| {
                let listed: Vec<usize> = self
                    .host_lds
                    .iter()
                    .enumerate()
                    .filter(|(_, lds)| lds.contains(k))
                    .map(|(h, _)| h)
                    .collect();
                if !listed.is_empty() {
                    listed
                } else if self.ld_declared_shared(k) {
                    (0..self.hosts).collect()
                } else if explicit {
                    // Unreachable after validate() (totality), but a
                    // harmless answer beats a panic for ad-hoc configs.
                    vec![0]
                } else {
                    vec![i % self.hosts]
                }
            })
            .collect()
    }

    /// Whether `devN.ldK` appears in its device's `[cxl.devN]
    /// shared_lds` list.
    pub fn ld_declared_shared(&self, k: &LdRef) -> bool {
        self.cxl
            .dev_overrides
            .get(k.dev)
            .and_then(|o| o.shared_lds.as_ref())
            .is_some_and(|s| s.contains(&k.ld))
    }

    /// Whether window definition `w` is shared by more than one host.
    pub fn window_is_shared(&self, w: usize) -> bool {
        self.window_sharers().get(w).is_some_and(|s| s.len() > 1)
    }

    pub fn validate(&self) -> Result<()> {
        if self.cores == 0 || self.cores > 64 {
            bail!("cores must be 1..=64 (paper evaluates up to 4)");
        }
        if self.hosts == 0 || self.hosts > MAX_HOSTS {
            bail!("system.hosts must be 1..={MAX_HOSTS}");
        }
        if self.threads == 0 || self.threads > 256 {
            bail!("sim.threads must be 1..=256");
        }
        if self.commit_lanes > 256 {
            bail!("sim.commit_lanes must be \"auto\" (0) or 1..=256");
        }
        if !self.host_lds.is_empty() && self.host_lds.len() != self.hosts {
            bail!(
                "host_lds has {} entries for {} hosts",
                self.host_lds.len(),
                self.hosts
            );
        }
        // Shared-LD declarations must denote real LDs before the
        // ownership rules below lean on them.
        let mut any_shared = false;
        for (i, ov) in self.cxl.dev_overrides.iter().enumerate() {
            let Some(shared) = &ov.shared_lds else { continue };
            if i >= self.cxl.devices {
                bail!(
                    "cxl.dev{i}.shared_lds targets a device outside \
                     cxl.devices = {}",
                    self.cxl.devices
                );
            }
            let lds = self.cxl.device(i).lds;
            let mut seen_ld = std::collections::BTreeSet::new();
            for &k in shared {
                if (k as usize) >= lds {
                    bail!(
                        "cxl.dev{i}.shared_lds: ld{k} is out of range \
                         (device exposes {lds} LDs)"
                    );
                }
                if !seen_ld.insert(k) {
                    bail!("cxl.dev{i}.shared_lds lists ld{k} twice");
                }
            }
            any_shared |= !shared.is_empty();
        }
        if self.host_lds.iter().any(|l| !l.is_empty()) {
            // Explicit assignment: every name must denote an existing
            // window. Ownership is exclusive for PRIVATE (pooled) LDs;
            // shared LDs (CXL 3.x) may — and, when any host lists
            // them, must — appear on several hosts' lists.
            let keys = self.window_keys();
            let mut count: std::collections::BTreeMap<LdRef, usize> =
                Default::default();
            for (h, lds) in self.host_lds.iter().enumerate() {
                let mut mine = std::collections::BTreeSet::new();
                for r in lds {
                    if !keys.contains(r) {
                        bail!(
                            "host.{h}: '{r}' does not name a CXL window \
                             (windows are keyed by first member device + \
                             LD; this topology has: {})",
                            keys.iter()
                                .map(|k| k.to_string())
                                .collect::<Vec<_>>()
                                .join(", ")
                        );
                    }
                    if !mine.insert(*r) {
                        bail!("host.{h} lists '{r}' twice");
                    }
                    *count.entry(*r).or_insert(0) += 1;
                }
            }
            for (r, n) in &count {
                if *n > 1 {
                    any_shared = true;
                } else if self.ld_declared_shared(r) {
                    bail!(
                        "'{r}' is declared shared (cxl.dev{}.shared_lds) \
                         but assigned to a single host — a shared LD \
                         needs >= 2 sharers; list it on every sharer \
                         host, or drop it from shared_lds to keep it a \
                         private (exclusively owned) LD",
                        r.dev
                    );
                }
            }
            for k in &keys {
                if count.contains_key(k) {
                    continue;
                }
                if self.ld_declared_shared(k) {
                    continue; // shared by every host by default
                }
                bail!(
                    "window '{k}' is not assigned to any host \
                     (explicit [host.N] lds lists must be total; \
                     private LD ownership is exclusive — to share an \
                     LD across hosts declare it in cxl.devN.shared_lds \
                     or list it on every sharer host)"
                );
            }
        }
        if any_shared {
            if self.hosts < 2 {
                bail!(
                    "shared LDs need at least 2 sharer hosts \
                     (system.hosts = {}); sharer count cannot exceed \
                     system.hosts",
                    self.hosts
                );
            }
            if self.cxl.ways() != 1 {
                bail!(
                    "shared LDs require 1-way windows (set \
                     cxl.interleave_ways = 1)"
                );
            }
            if self.cxl.attach == CxlAttach::MemBus {
                bail!(
                    "shared LDs require the architectural iobus attach: \
                     back-invalidate coherence rides the CXL.mem \
                     link/credit model the membus baseline bypasses"
                );
            }
            // Every sharer commits its own endpoint HDM decoder for
            // the shared LD (distinct HPA base, same DPA skip), so a
            // device's decoder demand is the sum of sharer counts over
            // its windows — bounded by the 10 decoders the component
            // block models.
            let mut demand = vec![0usize; self.cxl.devices];
            for (def, sharers) in
                self.cxl.window_defs().iter().zip(self.window_sharers())
            {
                for &t in &def.targets {
                    demand[t] += sharers.len().max(1);
                }
            }
            for (d, n) in demand.iter().enumerate() {
                if *n > 10 {
                    bail!(
                        "cxl.dev{d} needs {n} endpoint HDM decoders \
                         (one per window sharer; max 10 modeled) — \
                         reduce sharer counts or LDs"
                    );
                }
            }
        }
        self.l1.validate("l1")?;
        self.l2.validate("l2")?;
        if self.l1.line != self.l2.line {
            bail!("l1/l2 line sizes must match");
        }
        if !is_pow2(self.page_size) || self.page_size < self.l1.line {
            bail!("page size must be pow2 >= line size");
        }
        if self.sys_mem_size % self.page_size != 0
            || self.cxl.mem_size % self.page_size != 0
        {
            bail!("memory sizes must be page-aligned");
        }
        if self.cxl.link_bw_gbps <= 0.0 || self.cxl.credits == 0 {
            bail!("cxl link parameters must be positive");
        }
        // Multi-device topology: one PCIe bus (and host bridge) per
        // expander; bus 0 plus up to 6 expander buses fit the ECAM.
        if self.cxl.devices == 0 || self.cxl.devices > 6 {
            bail!("cxl.devices must be 1..=6");
        }
        let ways = self.cxl.ways();
        if !ways.is_power_of_two() || ways > 16 {
            bail!("cxl.interleave_ways must be a power of two <= 16");
        }
        if self.cxl.devices % ways != 0 {
            bail!(
                "cxl.devices ({}) must be a multiple of the interleave \
                 ways ({ways})",
                self.cxl.devices
            );
        }
        let gran = self.cxl.interleave_granularity;
        if !is_pow2(gran) || !(256..=16384).contains(&gran) {
            bail!(
                "cxl.interleave_granularity must be a power of two in \
                 256..=16384 (CFMWS HBIG encodings)"
            );
        }
        if gran < self.l1.line {
            bail!("interleave granularity must cover a full cache line");
        }
        for i in 0..self.cxl.devices {
            let d = self.cxl.device(i);
            // CXL 2.0 mailbox capacity fields are in 256 MiB multiples;
            // a smaller expander would IDENTIFY as zero capacity.
            if d.mem_size % (256 << 20) != 0 || d.mem_size == 0 {
                bail!(
                    "cxl.dev{i}: capacity must be a non-zero multiple of \
                     256 MiB"
                );
            }
            if d.link_bw_gbps <= 0.0 {
                bail!("cxl.dev{i}: link bandwidth must be positive");
            }
            if !(1..=16u32).contains(&d.link_width) {
                bail!("cxl.dev{i}: link width must be 1..=16 lanes");
            }
            if !(1..=4).contains(&d.lds) {
                bail!("cxl.dev{i}: lds must be 1..=4");
            }
            if d.lds > 1 {
                if ways != 1 {
                    bail!(
                        "cxl.dev{i}: MLD devices (lds > 1) require 1-way \
                         windows (set cxl.interleave_ways = 1)"
                    );
                }
                if d.mem_size % (d.lds as u64 * (256u64 << 20)) != 0 {
                    bail!(
                        "cxl.dev{i}: capacity must split into lds equal \
                         256 MiB-multiple slices"
                    );
                }
            }
        }
        if self.cxl.switches > 6 {
            bail!("cxl.switches must be 0..=6");
        }
        if self.cxl.switches > 0 {
            if ways != 1 {
                // Interleaving across switched endpoints is modeled for
                // sets living entirely under ONE switch (the shared
                // upstream link then carries the whole set's traffic);
                // sets spanning switches or mixing direct/switched
                // attach points are not.
                for set in 0..self.cxl.interleave_sets() {
                    let members: Vec<usize> =
                        self.cxl.set_members(set).collect();
                    let sw0 = self.cxl.switch_of(members[0]);
                    if sw0.is_none()
                        || members
                            .iter()
                            .any(|&i| self.cxl.switch_of(i) != sw0)
                    {
                        bail!(
                            "interleave set {set} spans switch \
                             boundaries; all members of a multi-way set \
                             must sit behind the same switch"
                        );
                    }
                }
            }
            let mut covered = 0usize;
            // bus 0 + per switch: upstream-bridge bus, internal bus and
            // one leaf bus per attached endpoint — must fit the ECAM.
            let mut buses = 1usize;
            for j in 0..self.cxl.switches {
                let s = self.cxl.switch(j);
                if !(1..=16).contains(&s.fanout) {
                    bail!("cxl.switch{j}: fanout must be 1..=16");
                }
                if s.ndev == 0 {
                    bail!(
                        "cxl.switch{j} has no devices behind it (the \
                         preceding switches' fanout already covers all \
                         {} devices)",
                        self.cxl.devices
                    );
                }
                if s.link_bw_gbps <= 0.0 {
                    bail!("cxl.switch{j}: link bandwidth must be positive");
                }
                if s.link_lat_ns < 0.0 || s.fwd_lat_ns < 0.0 {
                    bail!("cxl.switch{j}: latencies must be non-negative");
                }
                covered += s.ndev;
                buses += 2 + s.ndev;
            }
            if covered < self.cxl.devices {
                bail!(
                    "cxl.devices ({}) exceeds the total switch fanout \
                     ({covered})",
                    self.cxl.devices
                );
            }
            if buses > crate::bios::layout::ECAM_BUSES as usize {
                bail!(
                    "switched topology needs {buses} PCIe buses; the ECAM \
                     window has {}",
                    crate::bios::layout::ECAM_BUSES
                );
            }
        }
        // Every window a bridge decodes needs an HDM decoder on it.
        for b in 0..self.cxl.bridges() {
            let decoders: usize = (0..self.cxl.devices)
                .filter(|&i| self.cxl.bridge_of(i) == b)
                .map(|i| self.cxl.device(i).lds)
                .sum();
            if decoders > 10 {
                bail!(
                    "CXL host bridge {b} would need {decoders} HDM \
                     decoders (max 10 modeled); reduce fanout or lds"
                );
            }
        }
        for set in 0..self.cxl.interleave_sets() {
            let members = self.cxl.set_members(set);
            let cap0 = self.cxl.device(members.start).mem_size;
            if members.clone().any(|i| self.cxl.device(i).mem_size != cap0)
            {
                bail!(
                    "interleave set {set}: member capacities must match \
                     (hardware-style N-way interleave)"
                );
            }
        }
        if self.issue_width == 0 || self.lsq_entries == 0 {
            bail!("o3 parameters must be positive");
        }
        // Constraints shared by every runtime FM mechanism — scripted
        // `[fm] events` and telemetry `[fm] policy` alike (both drive
        // the same hot-remove/hot-add flow through the RC routing
        // windows).
        if self.fm_dynamic() {
            if ways != 1 {
                bail!(
                    "runtime FM re-binding ([fm] events / [fm] policy) \
                     moves individual logical devices and requires \
                     1-way windows (set cxl.interleave_ways = 1)"
                );
            }
            if self.cxl.attach == CxlAttach::MemBus {
                bail!(
                    "runtime FM re-binding ([fm] events / [fm] policy) \
                     requires the architectural iobus attach: the \
                     membus baseline bypasses the root complex's \
                     routing windows, so hot-removed capacity cannot \
                     be torn out of its path"
                );
            }
        }
        if let Some(p) = &self.fm_policy {
            // Policy XOR explicit events: a policy computes its own
            // schedule from telemetry; mixing the two would make the
            // hand-written events race the closed loop.
            if !self.fm_events.is_empty() {
                bail!(
                    "[fm] policy and [fm] events are mutually \
                     exclusive (the policy computes its own schedule)"
                );
            }
            if self.hosts < 2 {
                bail!(
                    "fm.policy needs system.hosts >= 2 (nothing to \
                     rebalance between)"
                );
            }
            if !p.epoch_ns.is_finite() || p.epoch_ns <= 0.0 {
                bail!("fm.epoch must be a positive duration");
            }
            for (name, v) in [
                ("min_residency", p.min_residency_ns),
                ("cooldown", p.cooldown_ns),
                ("refusal_backoff", p.refusal_backoff_ns),
            ] {
                if !v.is_finite() || v < 0.0 {
                    bail!("fm.{name} must be a non-negative duration");
                }
            }
        }
        if !self.fm_events.is_empty() {
            // Replay the schedule against the boot-time assignment:
            // every unbind must target a bound LD, every bind an
            // unbound one (ownership is exclusive), so a valid schedule
            // can never fail at runtime for ownership reasons.
            let keys = self.window_keys();
            let shared: std::collections::BTreeSet<LdRef> = keys
                .iter()
                .zip(self.window_sharers())
                .filter(|(_, s)| s.len() > 1)
                .map(|(k, _)| *k)
                .collect();
            let mut owner: std::collections::BTreeMap<LdRef, Option<usize>> =
                keys.iter()
                    .copied()
                    .zip(self.window_hosts().into_iter().map(Some))
                    .collect();
            for i in self.fm_events_in_time_order() {
                let ev = &self.fm_events[i];
                if !ev.at_ns.is_finite() || ev.at_ns < 0.0 {
                    bail!("fm event {i}: time must be finite and >= 0");
                }
                if shared.contains(&ev.ld()) {
                    bail!(
                        "fm event {i}: '{}' is a shared LD — runtime FM \
                         re-binding moves private (pooled) LDs only",
                        ev.ld()
                    );
                }
                let slot = owner.get_mut(&ev.ld()).with_context(|| {
                    format!(
                        "fm event {i}: '{}' does not name a CXL window",
                        ev.ld()
                    )
                })?;
                match ev.op {
                    FmOp::Unbind { ld } => {
                        if slot.is_none() {
                            bail!(
                                "fm event {i}: unbind of '{ld}' which is \
                                 not bound at that point in the schedule"
                            );
                        }
                        *slot = None;
                    }
                    FmOp::Bind { ld, host } => {
                        if host >= self.hosts {
                            bail!(
                                "fm event {i}: bind of '{ld}' targets \
                                 host{host} outside system.hosts = {}",
                                self.hosts
                            );
                        }
                        if slot.is_some() {
                            bail!(
                                "fm event {i}: bind of '{ld}' which is \
                                 still bound — unbind it first \
                                 (LD ownership is exclusive)"
                            );
                        }
                        *slot = Some(host);
                    }
                }
            }
        }
        // [workload] section consistency.
        if let Some(kind) = &self.workload.kind {
            const KINDS: [&str; 9] = [
                "serve",
                "replay",
                "stream-copy",
                "stream-scale",
                "stream-add",
                "stream-triad",
                "random",
                "chase",
                "kv",
            ];
            if !KINDS.contains(&kind.as_str()) {
                bail!("workload.kind '{kind}' is not one of {KINDS:?}");
            }
            if kind == "replay" && self.workload.trace.is_none() {
                bail!(
                    "workload.kind = \"replay\" needs \
                     workload.trace = \"<path>\""
                );
            }
        }
        if self.workload.trace.is_some()
            && self.workload.kind.as_deref() != Some("replay")
        {
            bail!(
                "workload.trace only applies with \
                 workload.kind = \"replay\""
            );
        }
        let sv = &self.workload.serve;
        if sv.users == 0 {
            bail!("workload.serve.users must be positive");
        }
        if sv.kv_block < 64 || sv.kv_block % 64 != 0 {
            bail!(
                "workload.serve.kv_block must be a positive multiple of \
                 64 (whole cache lines)"
            );
        }
        if sv.context_blocks == 0 {
            bail!("workload.serve.context_blocks must be positive");
        }
        if sv.dram_slots == 0 {
            bail!(
                "workload.serve.dram_slots must be positive (the hot \
                 tier always exists; cxl_slots = 0 disables the warm one)"
            );
        }
        if !sv.zipf_s.is_finite() || sv.zipf_s < 0.0 {
            bail!("workload.serve.zipf_s must be finite and >= 0");
        }
        Ok(())
    }

    /// Indices of `fm_events` in execution order: by time, config order
    /// breaking ties — the order the machine schedules (and validation
    /// replays) them in.
    pub fn fm_events_in_time_order(&self) -> Vec<usize> {
        let mut idxs: Vec<usize> = (0..self.fm_events.len()).collect();
        idxs.sort_by(|&a, &b| {
            self.fm_events[a]
                .at_ns
                .partial_cmp(&self.fm_events[b].at_ns)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idxs
    }

    /// Load from TOML text plus `key=value` overrides.
    pub fn from_toml(text: &str, overrides: &[String]) -> Result<Self> {
        let mut doc = TomlDoc::parse(text).context("parsing config")?;
        for ov in overrides {
            doc.set_override(ov)
                .map_err(|e| anyhow::anyhow!("bad --set '{ov}': {e}"))?;
        }
        Self::from_doc(&doc)
    }

    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let mut c = SimConfig::default();
        let known = |_k: &str| {};
        macro_rules! get {
            ($key:expr, $slot:expr, u64) => {
                if let Some(v) = doc.get($key) {
                    known($key);
                    $slot = v
                        .as_u64()
                        .with_context(|| format!("{} must be int", $key))?;
                }
            };
            ($key:expr, $slot:expr, usize) => {
                if let Some(v) = doc.get($key) {
                    known($key);
                    $slot = v
                        .as_u64()
                        .with_context(|| format!("{} must be int", $key))?
                        as usize;
                }
            };
            ($key:expr, $slot:expr, f64) => {
                if let Some(v) = doc.get($key) {
                    known($key);
                    $slot = v
                        .as_f64()
                        .with_context(|| format!("{} must be number", $key))?;
                }
            };
        }
        get!("system.hosts", c.hosts, usize);
        // Bound before the per-host allocation/lookup loop below runs
        // off this value (validate() re-checks for programmatic use).
        if c.hosts == 0 || c.hosts > MAX_HOSTS {
            bail!("system.hosts must be 1..={MAX_HOSTS}");
        }
        get!("system.cores", c.cores, usize);
        get!("sim.threads", c.threads, usize);
        if let Some(v) = doc.get("sim.commit_lanes") {
            // Accepts the string "auto" (0) or an integer lane count.
            c.commit_lanes = match v.as_str() {
                Some(s) => parse_commit_lanes(s).with_context(|| {
                    format!("sim.commit_lanes string must be \"auto\", got '{s}'")
                })?,
                None => v
                    .as_u64()
                    .context("sim.commit_lanes must be \"auto\" or integer")?
                    as usize,
            };
        }
        if let Some(v) = doc.get("sim.check") {
            c.check = v.as_bool().context("sim.check must be bool")?;
        }
        get!("system.freq_ghz", c.freq_ghz, f64);
        get!("system.rob", c.rob_entries, usize);
        get!("system.lsq", c.lsq_entries, usize);
        get!("system.issue_width", c.issue_width, usize);
        get!("system.page_size", c.page_size, u64);
        get!("system.seed", c.seed, u64);
        if let Some(v) = doc.get("system.cpu") {
            c.cpu_model = CpuModel::parse(
                v.as_str().context("system.cpu must be string")?,
            )?;
        }
        get!("l1.size", c.l1.size, u64);
        get!("l1.assoc", c.l1.assoc, usize);
        get!("l1.line", c.l1.line, u64);
        get!("l1.lat_cycles", c.l1.lat_cycles, u64);
        get!("l1.mshrs", c.l1.mshrs, usize);
        get!("l2.size", c.l2.size, u64);
        get!("l2.assoc", c.l2.assoc, usize);
        get!("l2.line", c.l2.line, u64);
        get!("l2.lat_cycles", c.l2.lat_cycles, u64);
        get!("l2.mshrs", c.l2.mshrs, usize);
        get!("l2.pf_degree", c.l2.pf_degree, usize);
        if let Some(v) = doc.get("l2.prefetch") {
            c.l2.prefetch =
                v.as_bool().context("l2.prefetch must be bool")?;
        }
        get!("mem.size", c.sys_mem_size, u64);
        get!("mem.banks", c.sys_dram.banks, usize);
        get!("mem.t_cas_ns", c.sys_dram.t_cas_ns, f64);
        get!("mem.t_rcd_ns", c.sys_dram.t_rcd_ns, f64);
        get!("mem.t_rp_ns", c.sys_dram.t_rp_ns, f64);
        get!("mem.bw_gbps", c.sys_dram.bw_gbps, f64);
        get!("bus.mem_lat_ns", c.membus_lat_ns, f64);
        get!("bus.mem_bw_gbps", c.membus_bw_gbps, f64);
        get!("bus.io_lat_ns", c.iobus_lat_ns, f64);
        get!("bus.io_bw_gbps", c.iobus_bw_gbps, f64);
        get!("cxl.size", c.cxl.mem_size, u64);
        get!("cxl.pkt_lat_ns", c.cxl.pkt_lat_ns, f64);
        get!("cxl.depkt_lat_ns", c.cxl.depkt_lat_ns, f64);
        get!("cxl.link_lat_ns", c.cxl.link_lat_ns, f64);
        get!("cxl.link_bw_gbps", c.cxl.link_bw_gbps, f64);
        get!("cxl.flit_bytes", c.cxl.flit_bytes, u64);
        get!("cxl.credits", c.cxl.credits, usize);
        get!("cxl.media_t_cas_ns", c.cxl.media.t_cas_ns, f64);
        get!("cxl.media_t_rcd_ns", c.cxl.media.t_rcd_ns, f64);
        get!("cxl.media_t_rp_ns", c.cxl.media.t_rp_ns, f64);
        get!("cxl.media_bw_gbps", c.cxl.media.bw_gbps, f64);
        if let Some(v) = doc.get("cxl.attach") {
            c.cxl.attach = match v.as_str() {
                Some("iobus") => CxlAttach::IoBus,
                Some("membus") => CxlAttach::MemBus,
                _ => bail!("cxl.attach must be \"iobus\" or \"membus\""),
            };
        }
        get!("cxl.devices", c.cxl.devices, usize);
        get!("cxl.switches", c.cxl.switches, usize);
        get!("cxl.interleave_ways", c.cxl.interleave_ways, usize);
        get!(
            "cxl.interleave_granularity",
            c.cxl.interleave_granularity,
            u64
        );
        if let Some(v) = doc.get("cxl.interleave_arith") {
            c.cxl.interleave_arith = match v.as_str() {
                Some("modulo") => InterleaveArith::Modulo,
                Some("xor") => InterleaveArith::Xor,
                _ => bail!(
                    "cxl.interleave_arith must be \"modulo\" or \"xor\""
                ),
            };
        }
        // Per-device overrides from [cxl.devN] sections.
        c.cxl.dev_overrides =
            vec![CxlDevOverride::default(); c.cxl.devices.max(1)];
        for i in 0..c.cxl.devices.max(1) {
            let pre = format!("cxl.dev{i}");
            let ov = &mut c.cxl.dev_overrides[i];
            if let Some(v) = doc.get(&format!("{pre}.size")) {
                ov.mem_size = Some(v.as_u64().with_context(|| {
                    format!("{pre}.size must be int")
                })?);
            }
            if let Some(v) = doc.get(&format!("{pre}.link_lat_ns")) {
                ov.link_lat_ns = Some(v.as_f64().with_context(|| {
                    format!("{pre}.link_lat_ns must be number")
                })?);
            }
            if let Some(v) = doc.get(&format!("{pre}.link_bw_gbps")) {
                ov.link_bw_gbps = Some(v.as_f64().with_context(|| {
                    format!("{pre}.link_bw_gbps must be number")
                })?);
            }
            if let Some(v) = doc.get(&format!("{pre}.link_width")) {
                ov.link_width = Some(v.as_u64().with_context(|| {
                    format!("{pre}.link_width must be int")
                })? as u32);
            }
            if let Some(v) = doc.get(&format!("{pre}.latency_class")) {
                let s = v.as_str().with_context(|| {
                    format!("{pre}.latency_class must be string")
                })?;
                ov.latency_class = Some(LatencyClass::parse(s)?);
            }
            if let Some(v) = doc.get(&format!("{pre}.lds")) {
                ov.lds = Some(v.as_u64().with_context(|| {
                    format!("{pre}.lds must be int")
                })? as usize);
            }
            if let Some(v) = doc.get(&format!("{pre}.shared_lds")) {
                let items = match v {
                    TomlValue::Arr(items) => items,
                    _ => bail!(
                        "{pre}.shared_lds must be an array of LD indices"
                    ),
                };
                let mut lds = Vec::new();
                for it in items {
                    lds.push(it.as_u64().with_context(|| {
                        format!("{pre}.shared_lds entries must be ints")
                    })? as u16);
                }
                ov.shared_lds = Some(lds);
            }
        }
        // Per-switch overrides from [cxl.switchN] sections.
        c.cxl.switch_overrides =
            vec![CxlSwitchOverride::default(); c.cxl.switches];
        for j in 0..c.cxl.switches {
            let pre = format!("cxl.switch{j}");
            let ov = &mut c.cxl.switch_overrides[j];
            if let Some(v) = doc.get(&format!("{pre}.fanout")) {
                ov.fanout = Some(v.as_u64().with_context(|| {
                    format!("{pre}.fanout must be int")
                })? as usize);
            }
            if let Some(v) = doc.get(&format!("{pre}.link_lat_ns")) {
                ov.link_lat_ns = Some(v.as_f64().with_context(|| {
                    format!("{pre}.link_lat_ns must be number")
                })?);
            }
            if let Some(v) = doc.get(&format!("{pre}.link_bw_gbps")) {
                ov.link_bw_gbps = Some(v.as_f64().with_context(|| {
                    format!("{pre}.link_bw_gbps must be number")
                })?);
            }
            if let Some(v) = doc.get(&format!("{pre}.fwd_lat_ns")) {
                ov.fwd_lat_ns = Some(v.as_f64().with_context(|| {
                    format!("{pre}.fwd_lat_ns must be number")
                })?);
            }
        }
        // Per-host LD assignments from [host.N] sections.
        c.host_lds = vec![Vec::new(); c.hosts];
        for h in 0..c.hosts {
            if let Some(v) = doc.get(&format!("host.{h}.lds")) {
                let items = match v {
                    TomlValue::Arr(items) => items,
                    _ => bail!(
                        "host.{h}.lds must be an array of \"devN.ldK\" \
                         strings"
                    ),
                };
                for it in items {
                    let s = it.as_str().with_context(|| {
                        format!("host.{h}.lds entries must be strings")
                    })?;
                    c.host_lds[h].push(LdRef::parse(s)?);
                }
            }
        }
        // Runtime Fabric-Manager schedule from the [fm] section.
        if let Some(v) = doc.get("fm.events") {
            let items = match v {
                TomlValue::Arr(items) => items,
                _ => bail!(
                    "fm.events must be an array of \
                     \"@<time> bind|unbind devN.ldK [hostH]\" strings"
                ),
            };
            for it in items {
                let s = it
                    .as_str()
                    .context("fm.events entries must be strings")?;
                c.fm_events.push(FmEventDef::parse(s)?);
            }
        }
        // Telemetry-driven FM policy from the [fm] section.
        if let Some(v) = doc.get("fm.policy") {
            let s = v.as_str().context("fm.policy must be a string")?;
            c.fm_policy = Some(FmPolicyConfig::new(FmPolicyKind::parse(s)?));
        }
        if let Some(p) = &mut c.fm_policy {
            let dur = |key: &str| -> Result<Option<f64>> {
                match doc.get(key) {
                    None => Ok(None),
                    Some(v) => {
                        let s = v.as_str().with_context(|| {
                            format!("{key} must be a duration string")
                        })?;
                        Ok(Some(parse_time_ns(s).with_context(|| {
                            format!("bad duration in {key}")
                        })?))
                    }
                }
            };
            if let Some(ns) = dur("fm.epoch")? {
                p.epoch_ns = ns;
            }
            if let Some(ns) = dur("fm.min_residency")? {
                p.min_residency_ns = ns;
            }
            if let Some(ns) = dur("fm.cooldown")? {
                p.cooldown_ns = ns;
            }
            if let Some(ns) = dur("fm.refusal_backoff")? {
                p.refusal_backoff_ns = ns;
            }
        }
        // [workload] section: run-time workload selection + serve knobs.
        if let Some(v) = doc.get("workload.kind") {
            c.workload.kind = Some(
                v.as_str()
                    .context("workload.kind must be string")?
                    .to_string(),
            );
        }
        if let Some(v) = doc.get("workload.trace") {
            c.workload.trace = Some(
                v.as_str()
                    .context("workload.trace must be string")?
                    .to_string(),
            );
        }
        get!("workload.serve.users", c.workload.serve.users, u64);
        get!("workload.serve.zipf_s", c.workload.serve.zipf_s, f64);
        get!("workload.serve.requests", c.workload.serve.requests, u64);
        get!("workload.serve.kv_block", c.workload.serve.kv_block, u64);
        get!(
            "workload.serve.context_blocks",
            c.workload.serve.context_blocks,
            u64
        );
        get!(
            "workload.serve.dram_slots",
            c.workload.serve.dram_slots,
            usize
        );
        get!("workload.serve.cxl_slots", c.workload.serve.cxl_slots, usize);
        get!(
            "workload.serve.decode_work",
            c.workload.serve.decode_work,
            u64
        );
        // Reject overrides for devices/switches/hosts that don't exist,
        // and unknown keys inside valid sections, rather than silently
        // dropping them (a likely off-by-one or typo in configs).
        for key in doc.entries.keys() {
            if let Some(rest) = key.strip_prefix("host.") {
                // `[host]` without an index (key = "host.lds") is a
                // likely typo for `[host.0]` — reject it too, rather
                // than silently dropping the assignment.
                let Some((idx, field)) = rest.split_once('.') else {
                    bail!(
                        "'{key}': host sections must be indexed \
                         ([host.N] with N in 0..{})",
                        c.hosts
                    );
                };
                match idx.parse::<usize>() {
                    Ok(h) if h < c.hosts => {}
                    _ => bail!(
                        "'{key}' targets a host outside \
                         system.hosts = {}",
                        c.hosts
                    ),
                }
                if field != "lds" {
                    bail!(
                        "unknown key '{key}' ([host.N] keys: [\"lds\"])"
                    );
                }
            }
            if let Some(rest) = key.strip_prefix("fm.") {
                const FM_KEYS: [&str; 6] = [
                    "events",
                    "policy",
                    "epoch",
                    "min_residency",
                    "cooldown",
                    "refusal_backoff",
                ];
                if !FM_KEYS.contains(&rest) {
                    bail!("unknown key '{key}' ([fm] keys: {FM_KEYS:?})");
                }
                if rest != "events"
                    && rest != "policy"
                    && c.fm_policy.is_none()
                {
                    bail!(
                        "'{key}' only applies with [fm] policy set \
                         (it tunes the policy's cadence/hysteresis)"
                    );
                }
            }
            if let Some(rest) = key.strip_prefix("cxl.dev") {
                if let Some((idx, field)) = rest.split_once('.') {
                    match idx.parse::<usize>() {
                        Ok(i) if i < c.cxl.devices => {}
                        _ => bail!(
                            "'{key}' targets a device outside \
                             cxl.devices = {}",
                            c.cxl.devices
                        ),
                    }
                    const DEV_KEYS: [&str; 7] = [
                        "size",
                        "link_lat_ns",
                        "link_bw_gbps",
                        "link_width",
                        "latency_class",
                        "lds",
                        "shared_lds",
                    ];
                    if !DEV_KEYS.contains(&field) {
                        bail!(
                            "unknown key '{key}' (cxl.devN keys: \
                             {DEV_KEYS:?})"
                        );
                    }
                }
            }
            if let Some(rest) = key.strip_prefix("workload.") {
                const WL_KEYS: [&str; 2] = ["kind", "trace"];
                const SERVE_KEYS: [&str; 8] = [
                    "users",
                    "zipf_s",
                    "requests",
                    "kv_block",
                    "context_blocks",
                    "dram_slots",
                    "cxl_slots",
                    "decode_work",
                ];
                if let Some(sk) = rest.strip_prefix("serve.") {
                    if !SERVE_KEYS.contains(&sk) {
                        bail!(
                            "unknown key '{key}' ([workload.serve] keys: \
                             {SERVE_KEYS:?})"
                        );
                    }
                } else if !WL_KEYS.contains(&rest) {
                    bail!(
                        "unknown key '{key}' ([workload] keys: {WL_KEYS:?} \
                         plus the [workload.serve] table)"
                    );
                }
            }
            if let Some(rest) = key.strip_prefix("cxl.switch") {
                if let Some((idx, field)) = rest.split_once('.') {
                    match idx.parse::<usize>() {
                        Ok(j) if j < c.cxl.switches => {}
                        _ => bail!(
                            "'{key}' targets a switch outside \
                             cxl.switches = {}",
                            c.cxl.switches
                        ),
                    }
                    const SW_KEYS: [&str; 4] = [
                        "fanout",
                        "link_lat_ns",
                        "link_bw_gbps",
                        "fwd_lat_ns",
                    ];
                    if !SW_KEYS.contains(&field) {
                        bail!(
                            "unknown key '{key}' (cxl.switchN keys: \
                             {SW_KEYS:?})"
                        );
                    }
                }
            }
        }
        c.validate()?;
        Ok(c)
    }

    /// Paper Table I rows, generated from the live schema.
    pub fn table1_rows(&self) -> Vec<(String, String)> {
        vec![
            (
                "CPU Models".into(),
                "In-order, Out-of-Order".into(),
            ),
            (
                "Cores".into(),
                if self.hosts > 1 {
                    format!(
                        "{} hosts x up to {} cores (x86 ISA)",
                        self.hosts, self.cores
                    )
                } else {
                    format!("Up to {} cores (x86 ISA)", self.cores)
                },
            ),
            (
                "Cache Coherence".into(),
                "MESI (Two-level, Directory-based)".into(),
            ),
            (
                "System Memory".into(),
                format!(
                    "Configurable (Unbounded) — {}",
                    human_bytes(self.sys_mem_size)
                ),
            ),
            (
                "CXL Memory".into(),
                format!(
                    "Configurable Extension (Unbounded) — {} across {} \
                     device(s), {}-way interleave @ {} B{}",
                    human_bytes(self.cxl.total_size()),
                    self.cxl.devices,
                    self.cxl.ways(),
                    self.cxl.interleave_granularity,
                    if self.cxl.switches > 0 {
                        format!(", behind {} switch(es)", self.cxl.switches)
                    } else {
                        String::new()
                    }
                ),
            ),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        SimConfig::default().validate().unwrap();
    }

    #[test]
    fn cache_sets_derived() {
        let c = SimConfig::default();
        assert_eq!(c.l1.sets(), 64);
        assert_eq!(c.l2.sets(), 1024);
    }

    #[test]
    fn from_toml_and_overrides() {
        let cfg = SimConfig::from_toml(
            "[system]\ncores = 2\ncpu = \"inorder\"\n[l2]\nsize = 2 MiB\n",
            &["cxl.attach=\"membus\"".to_string()],
        )
        .unwrap();
        assert_eq!(cfg.cores, 2);
        assert_eq!(cfg.cpu_model, CpuModel::InOrder);
        assert_eq!(cfg.l2.size, 2 << 20);
        assert_eq!(cfg.cxl.attach, CxlAttach::MemBus);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = SimConfig::default();
        c.cores = 0;
        assert!(c.validate().is_err());

        let mut c = SimConfig::default();
        c.l1.line = 48;
        assert!(c.validate().is_err());

        let mut c = SimConfig::default();
        c.l2.line = 128; // mismatch with l1
        assert!(c.validate().is_err());

        assert!(SimConfig::from_toml("[system]\ncpu = \"riscv\"", &[])
            .is_err());
    }

    #[test]
    fn workload_section_parses_and_validates() {
        let cfg = SimConfig::from_toml(
            "[workload]\nkind = \"serve\"\n\
             [workload.serve]\nusers = 64\nzipf_s = 0.9\nrequests = 10\n\
             kv_block = 256\ncontext_blocks = 2\ndram_slots = 8\n\
             cxl_slots = 16\ndecode_work = 8\n",
            &[],
        )
        .unwrap();
        assert_eq!(cfg.workload.kind.as_deref(), Some("serve"));
        assert_eq!(cfg.workload.serve.users, 64);
        assert_eq!(cfg.workload.serve.kv_block, 256);
        assert_eq!(cfg.workload.serve.cxl_slots, 16);

        // Replay requires a trace, and a trace requires replay.
        assert!(SimConfig::from_toml("[workload]\nkind = \"replay\"\n", &[])
            .is_err());
        assert!(SimConfig::from_toml(
            "[workload]\ntrace = \"t.cxlt\"\n",
            &[]
        )
        .is_err());
        let cfg = SimConfig::from_toml(
            "[workload]\nkind = \"replay\"\ntrace = \"t.cxlt\"\n",
            &[],
        )
        .unwrap();
        assert_eq!(cfg.workload.trace.as_deref(), Some("t.cxlt"));

        // Unknown kinds, unknown keys, and bad serve values.
        assert!(SimConfig::from_toml(
            "[workload]\nkind = \"fortran\"\n",
            &[]
        )
        .is_err());
        assert!(SimConfig::from_toml(
            "[workload]\nbatch = 4\n",
            &[]
        )
        .is_err());
        assert!(SimConfig::from_toml(
            "[workload.serve]\nwindow = 9\n",
            &[]
        )
        .is_err());
        assert!(SimConfig::from_toml(
            "[workload.serve]\nkv_block = 100\n",
            &[]
        )
        .is_err());
        assert!(SimConfig::from_toml(
            "[workload.serve]\ndram_slots = 0\n",
            &[]
        )
        .is_err());
    }

    #[test]
    fn table1_mentions_mesi_and_sizes() {
        let rows = SimConfig::default().table1_rows();
        assert_eq!(rows.len(), 5);
        assert!(rows[2].1.contains("MESI"));
        assert!(rows[4].1.contains("4 GiB"));
    }

    #[test]
    fn multi_device_defaults_and_auto_ways() {
        let mut c = SimConfig::default();
        c.cxl.devices = 4;
        c.validate().unwrap();
        assert_eq!(c.cxl.ways(), 4, "pow2 count auto-interleaves fully");
        assert_eq!(c.cxl.interleave_sets(), 1);
        assert_eq!(c.cxl.set_size(0), 4 * c.cxl.mem_size);

        c.cxl.devices = 3;
        c.validate().unwrap();
        assert_eq!(c.cxl.ways(), 1, "non-pow2 auto falls back to 1 way");
        assert_eq!(c.cxl.interleave_sets(), 3);
    }

    #[test]
    fn per_device_overrides_from_toml() {
        let cfg = SimConfig::from_toml(
            "[cxl]\ndevices = 2\ninterleave_ways = 1\n\
             interleave_granularity = 1024\n\
             [cxl.dev1]\nsize = 512 MiB\nlatency_class = \"far\"\n\
             link_width = 4\n",
            &[],
        )
        .unwrap();
        assert_eq!(cfg.cxl.devices, 2);
        assert_eq!(cfg.cxl.interleave_granularity, 1024);
        let d0 = cfg.cxl.device(0);
        let d1 = cfg.cxl.device(1);
        assert_eq!(d0.mem_size, 4 << 30);
        assert_eq!(d1.mem_size, 512 << 20);
        assert_eq!(d1.latency_class, LatencyClass::Far);
        assert!(d1.media.t_cas_ns > d0.media.t_cas_ns);
        assert_eq!(d1.link_width, 4);
        assert!((d1.link_bw_gbps - d0.link_bw_gbps / 2.0).abs() < 1e-9);
    }

    #[test]
    fn out_of_range_device_override_rejected() {
        // [cxl.dev2] with only 2 devices: index is out of range.
        let err = SimConfig::from_toml(
            "[cxl]\ndevices = 2\ninterleave_ways = 1\n\
             [cxl.dev2]\nsize = 512 MiB\n",
            &[],
        );
        assert!(err.is_err());
        // The same via --set.
        let err = SimConfig::from_toml(
            "",
            &["cxl.dev1.size=512 MiB".to_string()],
        );
        assert!(err.is_err(), "default has one device; dev1 is invalid");
    }

    #[test]
    fn switch_config_resolves_and_validates() {
        let cfg = SimConfig::from_toml(
            "[cxl]\ndevices = 4\nswitches = 1\n\
             [cxl.switch0]\nfanout = 4\nlink_lat_ns = 30.0\n",
            &[],
        )
        .unwrap();
        assert_eq!(cfg.cxl.switches, 1);
        assert_eq!(cfg.cxl.ways(), 1, "switched auto resolves to 1-way");
        let s = cfg.cxl.switch(0);
        assert_eq!(s.fanout, 4);
        assert_eq!((s.first_dev, s.ndev), (0, 4));
        assert_eq!(s.link_lat_ns, 30.0);
        assert_eq!(s.fwd_lat_ns, SWITCH_FWD_LAT_NS);
        assert_eq!(cfg.cxl.bridges(), 1);
        for i in 0..4 {
            assert_eq!(cfg.cxl.switch_of(i), Some(0));
            assert_eq!(cfg.cxl.bridge_of(i), 0);
        }
        // Path latency includes the switch hop both ways of the tree.
        let direct = SimConfig::default();
        assert!(cfg.cxl.path_lat_ns(0) > direct.cxl.path_lat_ns(0));
    }

    #[test]
    fn switch_default_fanout_splits_devices() {
        let mut c = SimConfig::default();
        c.cxl.devices = 4;
        c.cxl.switches = 2;
        c.validate().unwrap();
        assert_eq!(c.cxl.switch(0).ndev, 2);
        assert_eq!(c.cxl.switch(1).first_dev, 2);
        assert_eq!(c.cxl.switch(1).ndev, 2);
        assert_eq!(c.cxl.bridge_of(3), 1);
    }

    #[test]
    fn same_switch_interleave_now_allowed() {
        // PR-3 lifts the 1-way restriction when the whole set sits
        // behind ONE switch.
        let mut c = SimConfig::default();
        c.cxl.devices = 4;
        c.cxl.switches = 1;
        c.cxl.interleave_ways = 4;
        c.validate().unwrap();
        assert_eq!(c.cxl.interleave_sets(), 1);
        assert_eq!(c.cxl.window_defs()[0].targets, vec![0, 1, 2, 3]);

        // Two switches x two devices each: 2-way sets align per switch.
        let mut c = SimConfig::default();
        c.cxl.devices = 4;
        c.cxl.switches = 2;
        c.cxl.interleave_ways = 2;
        c.validate().unwrap();
    }

    #[test]
    fn cross_switch_interleave_still_rejected() {
        // A 4-way set over two 2-device switches spans the boundary.
        let mut c = SimConfig::default();
        c.cxl.devices = 4;
        c.cxl.switches = 2;
        c.cxl.interleave_ways = 4;
        assert!(c.validate().is_err());
    }

    #[test]
    fn hosts_and_ld_assignment_parse_and_validate() {
        let cfg = SimConfig::from_toml(
            "[system]\nhosts = 2\n[cxl]\ninterleave_ways = 1\n\
             [cxl.dev0]\nlds = 2\n\
             [host.0]\nlds = [\"dev0.ld0\"]\n\
             [host.1]\nlds = [\"dev0.ld1\"]\n",
            &[],
        )
        .unwrap();
        assert_eq!(cfg.hosts, 2);
        assert_eq!(cfg.host_lds[0], vec![LdRef { dev: 0, ld: 0 }]);
        assert_eq!(cfg.window_hosts(), vec![0, 1]);

        // Auto round-robin when no [host.N] lists are given.
        let cfg = SimConfig::from_toml(
            "[system]\nhosts = 2\n[cxl]\ndevices = 2\n\
             interleave_ways = 1\n",
            &[],
        )
        .unwrap();
        assert_eq!(cfg.window_hosts(), vec![0, 1]);

        // Single host: everything on host 0.
        assert_eq!(SimConfig::default().window_hosts(), vec![0]);
    }

    #[test]
    fn ld_assignment_rejects_bad_shapes() {
        // Duplicate assignment (exclusivity).
        let err = SimConfig::from_toml(
            "[system]\nhosts = 2\n[cxl]\ndevices = 2\n\
             interleave_ways = 1\n\
             [host.0]\nlds = [\"dev0\"]\n\
             [host.1]\nlds = [\"dev0\", \"dev1\"]\n",
            &[],
        );
        assert!(err.is_err(), "duplicate LD assignment must fail");

        // Partial assignment (totality).
        let err = SimConfig::from_toml(
            "[system]\nhosts = 2\n[cxl]\ndevices = 2\n\
             interleave_ways = 1\n\
             [host.0]\nlds = [\"dev0\"]\n",
            &[],
        );
        assert!(err.is_err(), "partial explicit assignment must fail");

        // Nonexistent window key.
        let err = SimConfig::from_toml(
            "[system]\nhosts = 2\n\
             [host.0]\nlds = [\"dev0.ld3\"]\n\
             [host.1]\nlds = [\"dev0.ld0\"]\n",
            &[],
        );
        assert!(err.is_err(), "unknown LD ref must fail");

        // [host.N] section outside system.hosts.
        let err = SimConfig::from_toml(
            "[host.1]\nlds = [\"dev0\"]\n",
            &[],
        );
        assert!(err.is_err(), "host.1 with hosts = 1 must fail");

        // Index-less [host] section (typo for [host.0]).
        let err = SimConfig::from_toml(
            "[host]\nlds = [\"dev0\"]\n",
            &[],
        );
        assert!(err.is_err(), "[host] without an index must fail");

        // hosts out of range.
        let mut c = SimConfig::default();
        c.hosts = MAX_HOSTS + 1;
        assert!(c.validate().is_err());

        // Absurd hosts value in TOML fails cleanly (bounded before the
        // per-host section loop allocates off it).
        let err =
            SimConfig::from_toml("[system]\nhosts = 1000000000\n", &[]);
        assert!(err.is_err(), "huge hosts value must be rejected");
        let err = SimConfig::from_toml("[system]\nhosts = 0\n", &[]);
        assert!(err.is_err(), "hosts = 0 must be rejected");
    }

    #[test]
    fn shared_ld_validation_splits_private_and_shared() {
        // Positive: both hosts list the declared-shared LD; the sharer
        // set is exactly the listing hosts, in ascending order.
        let cfg = SimConfig::from_toml(
            "[system]\nhosts = 2\n[cxl]\ninterleave_ways = 1\n\
             [cxl.dev0]\nlds = 2\nshared_lds = [0]\n\
             [host.0]\nlds = [\"dev0.ld0\", \"dev0.ld1\"]\n\
             [host.1]\nlds = [\"dev0.ld0\"]\n",
            &[],
        )
        .unwrap();
        assert!(cfg.ld_declared_shared(&LdRef { dev: 0, ld: 0 }));
        assert_eq!(cfg.window_sharers()[0], vec![0, 1]);
        assert_eq!(cfg.window_sharers()[1], vec![0]);
        assert!(cfg.window_is_shared(0));
        assert!(!cfg.window_is_shared(1));

        // A declared-shared LD listed by nobody defaults to ALL hosts.
        let cfg = SimConfig::from_toml(
            "[system]\nhosts = 3\n[cxl]\ninterleave_ways = 1\n\
             [cxl.dev0]\nshared_lds = [0]\n",
            &[],
        )
        .unwrap();
        assert_eq!(cfg.window_sharers()[0], vec![0, 1, 2]);

        // Same LD private AND shared: declared shared but assigned to
        // exactly one host — exclusivity and sharing are contradictory.
        let err = SimConfig::from_toml(
            "[system]\nhosts = 2\n[cxl]\ninterleave_ways = 1\n\
             [cxl.dev0]\nlds = 2\nshared_lds = [0]\n\
             [host.0]\nlds = [\"dev0.ld0\", \"dev0.ld1\"]\n\
             [host.1]\nlds = []\n",
            &[],
        );
        assert!(
            err.is_err(),
            "an LD cannot be both private (single owner) and shared"
        );
        let msg = format!("{:#}", err.unwrap_err());
        assert!(
            msg.contains("declared shared") && msg.contains("single host"),
            "error must explain the private/shared split: {msg}"
        );

        // A multi-host listing WITHOUT a shared_lds declaration is the
        // duplicate-assignment error path only when sharing never
        // enters the config; listing the same LD on two hosts is the
        // sharing opt-in, so it validates (CXL 3.x shared LD).
        let cfg = SimConfig::from_toml(
            "[system]\nhosts = 2\n[cxl]\ninterleave_ways = 1\n\
             [cxl.dev0]\nlds = 2\n\
             [host.0]\nlds = [\"dev0.ld0\", \"dev0.ld1\"]\n\
             [host.1]\nlds = [\"dev0.ld0\"]\n",
            &[],
        )
        .unwrap();
        assert!(cfg.window_is_shared(0));
    }

    #[test]
    fn shared_ld_validation_rejects_bad_shapes() {
        // Sharer count can never exceed system.hosts: a lone host
        // cannot share with anyone.
        let err = SimConfig::from_toml(
            "[system]\nhosts = 1\n[cxl]\ninterleave_ways = 1\n\
             [cxl.dev0]\nshared_lds = [0]\n",
            &[],
        );
        assert!(err.is_err(), "sharing needs >= 2 hosts");
        let msg = format!("{:#}", err.unwrap_err());
        assert!(
            msg.contains("sharer count cannot exceed system.hosts"),
            "error must name the bound: {msg}"
        );

        // More sharers than the device has endpoint decoders to
        // commit: 11 default-sharers overflow the 10-decoder block.
        let err = SimConfig::from_toml(
            "[system]\nhosts = 11\n[cxl]\ninterleave_ways = 1\n\
             [cxl.dev0]\nshared_lds = [0]\n",
            &[],
        );
        assert!(err.is_err(), "sharers must fit the decoder pool");
        assert!(format!("{:#}", err.unwrap_err())
            .contains("endpoint HDM decoders"));

        // Out-of-range and duplicate shared_lds entries.
        let err = SimConfig::from_toml(
            "[system]\nhosts = 2\n[cxl]\ninterleave_ways = 1\n\
             [cxl.dev0]\nlds = 2\nshared_lds = [5]\n",
            &[],
        );
        assert!(err.is_err(), "shared_lds must name a real LD");
        let err = SimConfig::from_toml(
            "[system]\nhosts = 2\n[cxl]\ninterleave_ways = 1\n\
             [cxl.dev0]\nlds = 2\nshared_lds = [0, 0]\n",
            &[],
        );
        assert!(err.is_err(), "duplicate shared_lds entries must fail");

        // Shared LDs ride the CXL.mem link model: interleaved windows
        // and the membus-attach baseline cannot express them.
        let mut c = SimConfig::default();
        c.hosts = 2;
        c.cxl.devices = 2;
        c.cxl.interleave_ways = 2;
        c.cxl.dev_overrides = vec![CxlDevOverride {
            shared_lds: Some(vec![0]),
            ..Default::default()
        }];
        assert!(c.validate().is_err(), "shared LDs need 1-way windows");

        // A runtime FM event may never target a shared LD (it is
        // pinned to its sharer set).
        let err = SimConfig::from_toml(
            "[system]\nhosts = 2\n[cxl]\ninterleave_ways = 1\n\
             [cxl.dev0]\nshared_lds = [0]\n\
             [fm]\nevents = [\"@10us unbind dev0.ld0\"]\n",
            &[],
        );
        assert!(err.is_err(), "FM rebind of a shared LD must fail");
        assert!(format!("{:#}", err.unwrap_err()).contains("shared"));
    }

    #[test]
    fn sim_threads_parses_and_validates() {
        let cfg =
            SimConfig::from_toml("[sim]\nthreads = 8\n", &[]).unwrap();
        assert_eq!(cfg.threads, 8);
        let cfg =
            SimConfig::from_toml("", &["sim.threads=3".to_string()])
                .unwrap();
        assert_eq!(cfg.threads, 3);
        let mut c = SimConfig::default();
        c.threads = 0;
        assert!(c.validate().is_err(), "threads = 0 must be rejected");
        c.threads = 257;
        assert!(c.validate().is_err(), "threads > 256 must be rejected");
        c.threads = 16;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn sim_commit_lanes_parses_and_validates() {
        let cfg =
            SimConfig::from_toml("[sim]\ncommit_lanes = 4\n", &[]).unwrap();
        assert_eq!(cfg.commit_lanes, 4);
        let cfg =
            SimConfig::from_toml("[sim]\ncommit_lanes = \"auto\"\n", &[])
                .unwrap();
        assert_eq!(cfg.commit_lanes, 0, "\"auto\" spells lane count 0");
        let cfg = SimConfig::from_toml(
            "",
            &["sim.commit_lanes=2".to_string()],
        )
        .unwrap();
        assert_eq!(cfg.commit_lanes, 2);
        assert!(
            SimConfig::from_toml("[sim]\ncommit_lanes = \"three\"\n", &[])
                .is_err(),
            "non-auto strings must be rejected"
        );
        let mut c = SimConfig::default();
        c.commit_lanes = 0;
        assert!(c.validate().is_ok(), "0 = auto is valid");
        c.commit_lanes = 257;
        assert!(c.validate().is_err(), "lanes > 256 must be rejected");
        c.commit_lanes = 256;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn sim_check_parses() {
        let cfg =
            SimConfig::from_toml("[sim]\ncheck = true\n", &[]).unwrap();
        assert!(cfg.check);
        let cfg = SimConfig::from_toml("[sim]\ncheck = false\n", &[])
            .unwrap();
        assert!(!cfg.check);
        let cfg =
            SimConfig::from_toml("", &["sim.check=true".to_string()])
                .unwrap();
        assert!(cfg.check, "--set sim.check=true (the --check flag) arms it");
        assert!(
            SimConfig::from_toml("[sim]\ncheck = 1\n", &[]).is_err(),
            "non-bool must be rejected"
        );
    }

    #[test]
    fn ld_ref_parses_both_forms() {
        assert_eq!(
            LdRef::parse("dev2.ld1").unwrap(),
            LdRef { dev: 2, ld: 1 }
        );
        assert_eq!(LdRef::parse("dev0").unwrap(), LdRef { dev: 0, ld: 0 });
        assert!(LdRef::parse("ld1").is_err());
        assert!(LdRef::parse("dev.ld").is_err());
        assert_eq!(LdRef { dev: 1, ld: 2 }.to_string(), "dev1.ld2");
    }

    #[test]
    fn switch_validation_rejects_bad_shapes() {
        // More switches than devices: some switch is empty.
        let mut c = SimConfig::default();
        c.cxl.devices = 2;
        c.cxl.switches = 3;
        assert!(c.validate().is_err());

        // Fanout too small to cover every device.
        let err = SimConfig::from_toml(
            "[cxl]\ndevices = 4\nswitches = 1\n[cxl.switch0]\nfanout = 2\n",
            &[],
        );
        assert!(err.is_err());

        // Override targeting a switch that doesn't exist.
        let err = SimConfig::from_toml(
            "[cxl]\ndevices = 2\nswitches = 1\n[cxl.switch1]\nfanout = 2\n",
            &[],
        );
        assert!(err.is_err());
    }

    #[test]
    fn unknown_override_keys_rejected() {
        // Typo'd key in an in-range section must fail loudly, not
        // silently run with the default.
        let err = SimConfig::from_toml(
            "[cxl]\ndevices = 2\nswitches = 1\n\
             [cxl.switch0]\nfwd_lat = 5.0\n",
            &[],
        );
        assert!(err.is_err(), "typo'd switch key must be rejected");
        let err = SimConfig::from_toml(
            "[cxl]\ndevices = 2\ninterleave_ways = 1\n\
             [cxl.dev1]\nlatency = \"far\"\n",
            &[],
        );
        assert!(err.is_err(), "typo'd device key must be rejected");
    }

    #[test]
    fn mld_windows_expand_per_ld() {
        let cfg = SimConfig::from_toml(
            "[cxl]\ndevices = 2\ninterleave_ways = 1\n\
             [cxl.dev1]\nlds = 2\n",
            &[],
        )
        .unwrap();
        assert_eq!(cfg.cxl.device(1).lds, 2);
        let defs = cfg.cxl.window_defs();
        assert_eq!(defs.len(), 3, "one SLD window + two LD slices");
        assert_eq!(defs[0].targets, vec![0]);
        assert_eq!((defs[1].ld, defs[2].ld), (0, 1));
        assert_eq!(defs[1].size, 2 << 30, "4 GiB MLD splits in half");
        assert_eq!(defs[1].targets, defs[2].targets);
    }

    #[test]
    fn mld_validation_rejects_bad_shapes() {
        // MLD inside a multi-way interleave set.
        let mut c = SimConfig::default();
        c.cxl.devices = 2;
        c.cxl.dev_overrides = vec![
            CxlDevOverride { lds: Some(2), ..Default::default() },
            CxlDevOverride::default(),
        ];
        assert!(c.validate().is_err(), "2-way auto set rejects MLD");
        c.cxl.interleave_ways = 1;
        c.validate().unwrap();

        // lds out of range.
        let mut c = SimConfig::default();
        c.cxl.dev_overrides =
            vec![CxlDevOverride { lds: Some(5), ..Default::default() }];
        assert!(c.validate().is_err());

        // Capacity not splittable into 256 MiB-multiple slices.
        let mut c = SimConfig::default();
        c.cxl.interleave_ways = 1;
        c.cxl.mem_size = 768 << 20;
        c.cxl.dev_overrides =
            vec![CxlDevOverride { lds: Some(2), ..Default::default() }];
        assert!(c.validate().is_err());
    }

    #[test]
    fn fm_event_parsing() {
        let e = FmEventDef::parse("@50us unbind dev0.ld1").unwrap();
        assert_eq!(e.at_ns, 50_000.0);
        assert_eq!(e.op, FmOp::Unbind { ld: LdRef { dev: 0, ld: 1 } });
        let e = FmEventDef::parse("@1.5ms bind dev2.ld0 host3").unwrap();
        assert_eq!(e.at_ns, 1_500_000.0);
        assert_eq!(
            e.op,
            FmOp::Bind { ld: LdRef { dev: 2, ld: 0 }, host: 3 }
        );
        // `dev1` is shorthand for `dev1.ld0`, matching [host.N] lists.
        assert_eq!(
            FmEventDef::parse("@1ns bind dev1 host0").unwrap().ld(),
            LdRef { dev: 1, ld: 0 }
        );
        for bad in [
            "50us unbind dev0.ld1",      // no @
            "@50 unbind dev0.ld1",       // unitless time
            "@50us detach dev0.ld1",     // unknown verb
            "@50us bind dev0.ld1",       // bind without host
            "@50us bind dev0.ld1 h1",    // malformed host
            "@50us unbind dev0.ld1 x",   // trailing token
        ] {
            assert!(FmEventDef::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn fm_schedule_validates_ownership_transitions() {
        let base = "[system]\nhosts = 2\n[cxl]\ninterleave_ways = 1\n\
                    [cxl.dev0]\nlds = 2\n";
        // Legal: unbind then bind elsewhere.
        let cfg = SimConfig::from_toml(
            &format!(
                "{base}[fm]\nevents = [\"@10us unbind dev0.ld1\", \
                 \"@20us bind dev0.ld1 host0\"]\n"
            ),
            &[],
        )
        .unwrap();
        assert_eq!(cfg.fm_events.len(), 2);
        assert_eq!(cfg.fm_events_in_time_order(), vec![0, 1]);

        // Bind of a still-bound LD.
        assert!(SimConfig::from_toml(
            &format!("{base}[fm]\nevents = [\"@10us bind dev0.ld1 host0\"]\n"),
            &[],
        )
        .is_err());
        // Unbind of an LD unbound earlier in the schedule.
        assert!(SimConfig::from_toml(
            &format!(
                "{base}[fm]\nevents = [\"@10us unbind dev0.ld0\", \
                 \"@20us unbind dev0.ld0\"]\n"
            ),
            &[],
        )
        .is_err());
        // Host out of range.
        assert!(SimConfig::from_toml(
            &format!(
                "{base}[fm]\nevents = [\"@10us unbind dev0.ld0\", \
                 \"@20us bind dev0.ld0 host5\"]\n"
            ),
            &[],
        )
        .is_err());
        // Unknown window.
        assert!(SimConfig::from_toml(
            &format!("{base}[fm]\nevents = [\"@10us unbind dev3.ld0\"]\n"),
            &[],
        )
        .is_err());
        // Multi-way windows cannot be re-bound per-LD.
        assert!(SimConfig::from_toml(
            "[cxl]\ndevices = 2\ninterleave_ways = 2\n\
             [fm]\nevents = [\"@10us unbind dev0.ld0\"]\n",
            &[],
        )
        .is_err());
        // The membus baseline has no RC routing windows to hot-remove.
        assert!(SimConfig::from_toml(
            "[system]\nhosts = 2\n\
             [cxl]\ninterleave_ways = 1\nattach = \"membus\"\n\
             [cxl.dev0]\nlds = 2\n\
             [fm]\nevents = [\"@10us unbind dev0.ld1\"]\n",
            &[],
        )
        .is_err());
        // Typo'd [fm] key.
        assert!(SimConfig::from_toml(
            "[fm]\nevent = [\"@10us unbind dev0.ld0\"]\n",
            &[],
        )
        .is_err());
        // Events interleave by time, config order breaking ties.
        let mut c = SimConfig::default();
        c.fm_events = vec![
            FmEventDef::parse("@20us unbind dev0.ld0").unwrap(),
            FmEventDef::parse("@10us bind dev0.ld0 host0").unwrap(),
        ];
        assert_eq!(c.fm_events_in_time_order(), vec![1, 0]);
    }

    #[test]
    fn fm_policy_parses_and_validates() {
        let base = "[system]\nhosts = 2\n[cxl]\ninterleave_ways = 1\n\
                    [cxl.dev0]\nlds = 2\n";
        // Defaults + overridden cadence/hysteresis knobs.
        let cfg = SimConfig::from_toml(
            &format!(
                "{base}[fm]\npolicy = \"capacity_rebalance\"\n\
                 epoch = \"5us\"\nmin_residency = \"15us\"\n\
                 cooldown = \"10us\"\nrefusal_backoff = \"40us\"\n"
            ),
            &[],
        )
        .unwrap();
        let p = cfg.fm_policy.as_ref().unwrap();
        assert_eq!(p.kind, FmPolicyKind::CapacityRebalance);
        assert_eq!(p.epoch_ns, 5_000.0);
        assert_eq!(p.min_residency_ns, 15_000.0);
        assert_eq!(p.cooldown_ns, 10_000.0);
        assert_eq!(p.refusal_backoff_ns, 40_000.0);
        assert!(cfg.fm_dynamic(), "policy selects the hot-plug layout");
        // Bare policy gets the documented defaults.
        let cfg = SimConfig::from_toml(
            &format!("{base}[fm]\npolicy = \"bandwidth_fairness\"\n"),
            &[],
        )
        .unwrap();
        let p = cfg.fm_policy.as_ref().unwrap();
        assert_eq!(p.kind, FmPolicyKind::BandwidthFairness);
        assert_eq!(p.epoch_ns, 10_000.0);

        // Unknown policy name.
        assert!(SimConfig::from_toml(
            &format!("{base}[fm]\npolicy = \"chaos\"\n"),
            &[],
        )
        .is_err());
        // Policy XOR explicit events.
        assert!(SimConfig::from_toml(
            &format!(
                "{base}[fm]\npolicy = \"capacity_rebalance\"\n\
                 events = [\"@10us unbind dev0.ld1\"]\n"
            ),
            &[],
        )
        .is_err());
        // Tuning knobs without a policy are rejected, not dropped.
        assert!(SimConfig::from_toml(
            &format!("{base}[fm]\nepoch = \"5us\"\n"),
            &[],
        )
        .is_err());
        // A single host has nothing to rebalance between.
        assert!(SimConfig::from_toml(
            "[cxl]\ninterleave_ways = 1\n[cxl.dev0]\nlds = 2\n\
             [fm]\npolicy = \"capacity_rebalance\"\n",
            &[],
        )
        .is_err());
        // Same attach/ways constraints as [fm] events.
        assert!(SimConfig::from_toml(
            "[system]\nhosts = 2\n[cxl]\ndevices = 2\n\
             [fm]\npolicy = \"capacity_rebalance\"\n",
            &[],
        )
        .is_err());
        let mut c = SimConfig::default();
        c.hosts = 2;
        c.cxl.interleave_ways = 1;
        c.cxl.attach = CxlAttach::MemBus;
        c.fm_policy =
            Some(FmPolicyConfig::new(FmPolicyKind::CapacityRebalance));
        assert!(c.validate().is_err());
        // Degenerate durations.
        let mut c = SimConfig::default();
        c.hosts = 2;
        c.cxl.interleave_ways = 1;
        let mut p = FmPolicyConfig::new(FmPolicyKind::CapacityRebalance);
        p.epoch_ns = 0.0;
        c.fm_policy = Some(p);
        assert!(c.validate().is_err());
    }

    #[test]
    fn sld_window_defs_match_sets() {
        let mut c = SimConfig::default();
        c.cxl.devices = 4;
        c.validate().unwrap();
        let defs = c.cxl.window_defs();
        assert_eq!(defs.len(), 1);
        assert_eq!(defs[0].targets, vec![0, 1, 2, 3]);
        assert_eq!(defs[0].size, c.cxl.set_size(0));
        assert_eq!(defs[0].ld, 0);
    }

    #[test]
    fn interleave_validation_rejects_bad_shapes() {
        let mut c = SimConfig::default();
        c.cxl.devices = 3;
        c.cxl.interleave_ways = 2; // 3 % 2 != 0
        assert!(c.validate().is_err());

        let mut c = SimConfig::default();
        c.cxl.interleave_granularity = 100; // not pow2
        assert!(c.validate().is_err());

        let mut c = SimConfig::default();
        c.cxl.devices = 7;
        assert!(c.validate().is_err());

        // Mismatched capacities inside one interleave set.
        let mut c = SimConfig::default();
        c.cxl.devices = 2;
        c.cxl.dev_overrides = vec![
            CxlDevOverride::default(),
            CxlDevOverride {
                mem_size: Some(512 << 20),
                ..Default::default()
            },
        ];
        assert!(c.validate().is_err());
        // Same capacities but in separate 1-way sets: fine.
        c.cxl.interleave_ways = 1;
        c.validate().unwrap();
    }
}
