//! Simulation configuration (Table I surface).
//!
//! `SimConfig` is the single schema for the whole machine; it can be
//! loaded from a TOML file, overridden from the CLI (`--set key=value`)
//! and printed in the paper's Table-I format (`bench table1_config`).

use anyhow::{bail, Context, Result};

use crate::util::toml::TomlDoc;
use crate::util::{human_bytes, is_pow2};

/// CPU model selector (paper Table I: In-order, Out-of-Order).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CpuModel {
    /// gem5 "TimingSimpleCPU" analogue: one outstanding memory op.
    InOrder,
    /// gem5 "O3CPU" analogue: ROB/LSQ, multiple outstanding misses.
    OutOfOrder,
}

impl CpuModel {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "inorder" | "timing" => Ok(CpuModel::InOrder),
            "o3" | "ooo" | "out-of-order" => Ok(CpuModel::OutOfOrder),
            _ => bail!("unknown cpu model '{s}' (inorder|o3)"),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            CpuModel::InOrder => "In-order (Timing)",
            CpuModel::OutOfOrder => "Out-of-Order (O3)",
        }
    }
}

/// Where the CXL expander is attached — the paper's core architectural
/// point (Fig. 1). `IoBus` is CXLRAMSim; `MemBus` reproduces the
/// CXL-DMSim / SimCXL shortcut for the E3 ablation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CxlAttach {
    IoBus,
    MemBus,
}

#[derive(Clone, Debug)]
pub struct CacheConfig {
    pub size: u64,
    pub assoc: usize,
    pub line: u64,
    /// Hit latency in CPU cycles.
    pub lat_cycles: u64,
    pub mshrs: usize,
    /// Stride prefetcher at this level (modeled for L2 only).
    pub prefetch: bool,
    /// Prefetch run-ahead distance in lines.
    pub pf_degree: usize,
}

impl CacheConfig {
    pub fn sets(&self) -> usize {
        (self.size / (self.line * self.assoc as u64)) as usize
    }
    fn validate(&self, name: &str) -> Result<()> {
        if !is_pow2(self.line) || self.line < 16 {
            bail!("{name}: line size must be pow2 >= 16");
        }
        if self.size % (self.line * self.assoc as u64) != 0 {
            bail!("{name}: size not divisible by line*assoc");
        }
        if !is_pow2(self.sets() as u64) {
            bail!("{name}: set count must be a power of two");
        }
        if self.mshrs == 0 {
            bail!("{name}: need at least one MSHR");
        }
        Ok(())
    }
}

/// DRAM timing (applies to both system DRAM and the expander's media,
/// with independent values).
#[derive(Clone, Debug)]
pub struct DramConfig {
    pub banks: usize,
    /// Row-hit access latency (ns).
    pub t_cas_ns: f64,
    /// Row activation (ns) added on row miss.
    pub t_rcd_ns: f64,
    /// Precharge (ns) added on row conflict.
    pub t_rp_ns: f64,
    /// Row-buffer size in bytes.
    pub row_bytes: u64,
    /// Peak data bus bandwidth (GB/s) of the channel.
    pub bw_gbps: f64,
}

/// CXL link + protocol parameters (paper §III-B.2: all user-calibratable).
#[derive(Clone, Debug)]
pub struct CxlConfig {
    /// Expander capacity.
    pub mem_size: u64,
    /// M2S/S2M packetization latency at the root complex (ns).
    pub pkt_lat_ns: f64,
    /// De-packetization latency at the endpoint (ns).
    pub depkt_lat_ns: f64,
    /// Link propagation latency one way (ns).
    pub link_lat_ns: f64,
    /// Link bandwidth (GB/s) — x8 CXL 2.0 ~ 32 GB/s raw.
    pub link_bw_gbps: f64,
    /// Flit size in bytes (CXL 2.0: 68B flit carrying 64B payload).
    pub flit_bytes: u64,
    /// Request credits per channel (M2S / S2M).
    pub credits: usize,
    /// Device media timing.
    pub media: DramConfig,
    pub attach: CxlAttach,
}

#[derive(Clone, Debug)]
pub struct SimConfig {
    pub cores: usize,
    pub cpu_model: CpuModel,
    pub freq_ghz: f64,
    /// O3 parameters (ignored by InOrder).
    pub rob_entries: usize,
    pub lsq_entries: usize,
    pub issue_width: usize,
    pub l1: CacheConfig,
    pub l2: CacheConfig,
    pub sys_mem_size: u64,
    pub sys_dram: DramConfig,
    pub membus_lat_ns: f64,
    pub membus_bw_gbps: f64,
    pub iobus_lat_ns: f64,
    pub iobus_bw_gbps: f64,
    pub cxl: CxlConfig,
    pub page_size: u64,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            cores: 4,
            cpu_model: CpuModel::OutOfOrder,
            freq_ghz: 3.0,
            rob_entries: 192,
            lsq_entries: 48,
            issue_width: 4,
            l1: CacheConfig {
                size: 32 << 10,
                assoc: 8,
                line: 64,
                lat_cycles: 4,
                mshrs: 8,
                prefetch: false,
                pf_degree: 0,
            },
            l2: CacheConfig {
                size: 1 << 20,
                assoc: 16,
                line: 64,
                lat_cycles: 30,
                mshrs: 32,
                prefetch: true,
                // Run-ahead 16 lines: covers the 2-stream STREAM kernels'
                // demand rate (deg 8 turns late for copy/scale — see the
                // pf-depth ablation in EXPERIMENTS.md §E2).
                pf_degree: 16,
            },
            sys_mem_size: 2 << 30,
            sys_dram: DramConfig {
                banks: 16,
                t_cas_ns: 14.0,
                t_rcd_ns: 14.0,
                t_rp_ns: 14.0,
                row_bytes: 8192,
                bw_gbps: 25.6,
            },
            membus_lat_ns: 4.0,
            membus_bw_gbps: 51.2,
            iobus_lat_ns: 8.0,
            iobus_bw_gbps: 32.0,
            cxl: CxlConfig {
                mem_size: 4 << 30,
                pkt_lat_ns: 25.0,
                depkt_lat_ns: 25.0,
                link_lat_ns: 20.0,
                link_bw_gbps: 32.0,
                flit_bytes: 68,
                credits: 32,
                media: DramConfig {
                    banks: 16,
                    t_cas_ns: 16.0,
                    t_rcd_ns: 16.0,
                    t_rp_ns: 16.0,
                    row_bytes: 8192,
                    bw_gbps: 19.2,
                },
                attach: CxlAttach::IoBus,
            },
            page_size: 4096,
            seed: 1,
        }
    }
}

impl SimConfig {
    pub fn cycle_ns(&self) -> f64 {
        1.0 / self.freq_ghz
    }

    pub fn validate(&self) -> Result<()> {
        if self.cores == 0 || self.cores > 64 {
            bail!("cores must be 1..=64 (paper evaluates up to 4)");
        }
        self.l1.validate("l1")?;
        self.l2.validate("l2")?;
        if self.l1.line != self.l2.line {
            bail!("l1/l2 line sizes must match");
        }
        if !is_pow2(self.page_size) || self.page_size < self.l1.line {
            bail!("page size must be pow2 >= line size");
        }
        if self.sys_mem_size % self.page_size != 0
            || self.cxl.mem_size % self.page_size != 0
        {
            bail!("memory sizes must be page-aligned");
        }
        if self.cxl.link_bw_gbps <= 0.0 || self.cxl.credits == 0 {
            bail!("cxl link parameters must be positive");
        }
        // CXL 2.0 mailbox capacity fields are in 256 MiB multiples; a
        // smaller expander would IDENTIFY as zero capacity.
        if self.cxl.mem_size % (256 << 20) != 0 || self.cxl.mem_size == 0 {
            bail!("cxl.size must be a non-zero multiple of 256 MiB");
        }
        if self.issue_width == 0 || self.lsq_entries == 0 {
            bail!("o3 parameters must be positive");
        }
        Ok(())
    }

    /// Load from TOML text plus `key=value` overrides.
    pub fn from_toml(text: &str, overrides: &[String]) -> Result<Self> {
        let mut doc = TomlDoc::parse(text).context("parsing config")?;
        for ov in overrides {
            doc.set_override(ov)
                .map_err(|e| anyhow::anyhow!("bad --set '{ov}': {e}"))?;
        }
        Self::from_doc(&doc)
    }

    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let mut c = SimConfig::default();
        let known = |_k: &str| {};
        macro_rules! get {
            ($key:expr, $slot:expr, u64) => {
                if let Some(v) = doc.get($key) {
                    known($key);
                    $slot = v
                        .as_u64()
                        .with_context(|| format!("{} must be int", $key))?;
                }
            };
            ($key:expr, $slot:expr, usize) => {
                if let Some(v) = doc.get($key) {
                    known($key);
                    $slot = v
                        .as_u64()
                        .with_context(|| format!("{} must be int", $key))?
                        as usize;
                }
            };
            ($key:expr, $slot:expr, f64) => {
                if let Some(v) = doc.get($key) {
                    known($key);
                    $slot = v
                        .as_f64()
                        .with_context(|| format!("{} must be number", $key))?;
                }
            };
        }
        get!("system.cores", c.cores, usize);
        get!("system.freq_ghz", c.freq_ghz, f64);
        get!("system.rob", c.rob_entries, usize);
        get!("system.lsq", c.lsq_entries, usize);
        get!("system.issue_width", c.issue_width, usize);
        get!("system.page_size", c.page_size, u64);
        get!("system.seed", c.seed, u64);
        if let Some(v) = doc.get("system.cpu") {
            c.cpu_model = CpuModel::parse(
                v.as_str().context("system.cpu must be string")?,
            )?;
        }
        get!("l1.size", c.l1.size, u64);
        get!("l1.assoc", c.l1.assoc, usize);
        get!("l1.line", c.l1.line, u64);
        get!("l1.lat_cycles", c.l1.lat_cycles, u64);
        get!("l1.mshrs", c.l1.mshrs, usize);
        get!("l2.size", c.l2.size, u64);
        get!("l2.assoc", c.l2.assoc, usize);
        get!("l2.line", c.l2.line, u64);
        get!("l2.lat_cycles", c.l2.lat_cycles, u64);
        get!("l2.mshrs", c.l2.mshrs, usize);
        get!("l2.pf_degree", c.l2.pf_degree, usize);
        if let Some(v) = doc.get("l2.prefetch") {
            c.l2.prefetch =
                v.as_bool().context("l2.prefetch must be bool")?;
        }
        get!("mem.size", c.sys_mem_size, u64);
        get!("mem.banks", c.sys_dram.banks, usize);
        get!("mem.t_cas_ns", c.sys_dram.t_cas_ns, f64);
        get!("mem.t_rcd_ns", c.sys_dram.t_rcd_ns, f64);
        get!("mem.t_rp_ns", c.sys_dram.t_rp_ns, f64);
        get!("mem.bw_gbps", c.sys_dram.bw_gbps, f64);
        get!("bus.mem_lat_ns", c.membus_lat_ns, f64);
        get!("bus.mem_bw_gbps", c.membus_bw_gbps, f64);
        get!("bus.io_lat_ns", c.iobus_lat_ns, f64);
        get!("bus.io_bw_gbps", c.iobus_bw_gbps, f64);
        get!("cxl.size", c.cxl.mem_size, u64);
        get!("cxl.pkt_lat_ns", c.cxl.pkt_lat_ns, f64);
        get!("cxl.depkt_lat_ns", c.cxl.depkt_lat_ns, f64);
        get!("cxl.link_lat_ns", c.cxl.link_lat_ns, f64);
        get!("cxl.link_bw_gbps", c.cxl.link_bw_gbps, f64);
        get!("cxl.flit_bytes", c.cxl.flit_bytes, u64);
        get!("cxl.credits", c.cxl.credits, usize);
        get!("cxl.media_t_cas_ns", c.cxl.media.t_cas_ns, f64);
        get!("cxl.media_t_rcd_ns", c.cxl.media.t_rcd_ns, f64);
        get!("cxl.media_t_rp_ns", c.cxl.media.t_rp_ns, f64);
        get!("cxl.media_bw_gbps", c.cxl.media.bw_gbps, f64);
        if let Some(v) = doc.get("cxl.attach") {
            c.cxl.attach = match v.as_str() {
                Some("iobus") => CxlAttach::IoBus,
                Some("membus") => CxlAttach::MemBus,
                _ => bail!("cxl.attach must be \"iobus\" or \"membus\""),
            };
        }
        c.validate()?;
        Ok(c)
    }

    /// Paper Table I rows, generated from the live schema.
    pub fn table1_rows(&self) -> Vec<(String, String)> {
        vec![
            (
                "CPU Models".into(),
                "In-order, Out-of-Order".into(),
            ),
            (
                "Cores".into(),
                format!("Up to {} cores (x86 ISA)", self.cores),
            ),
            (
                "Cache Coherence".into(),
                "MESI (Two-level, Directory-based)".into(),
            ),
            (
                "System Memory".into(),
                format!(
                    "Configurable (Unbounded) — {}",
                    human_bytes(self.sys_mem_size)
                ),
            ),
            (
                "CXL Memory".into(),
                format!(
                    "Configurable Extension (Unbounded) — {}",
                    human_bytes(self.cxl.mem_size)
                ),
            ),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        SimConfig::default().validate().unwrap();
    }

    #[test]
    fn cache_sets_derived() {
        let c = SimConfig::default();
        assert_eq!(c.l1.sets(), 64);
        assert_eq!(c.l2.sets(), 1024);
    }

    #[test]
    fn from_toml_and_overrides() {
        let cfg = SimConfig::from_toml(
            "[system]\ncores = 2\ncpu = \"inorder\"\n[l2]\nsize = 2 MiB\n",
            &["cxl.attach=\"membus\"".to_string()],
        )
        .unwrap();
        assert_eq!(cfg.cores, 2);
        assert_eq!(cfg.cpu_model, CpuModel::InOrder);
        assert_eq!(cfg.l2.size, 2 << 20);
        assert_eq!(cfg.cxl.attach, CxlAttach::MemBus);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = SimConfig::default();
        c.cores = 0;
        assert!(c.validate().is_err());

        let mut c = SimConfig::default();
        c.l1.line = 48;
        assert!(c.validate().is_err());

        let mut c = SimConfig::default();
        c.l2.line = 128; // mismatch with l1
        assert!(c.validate().is_err());

        assert!(SimConfig::from_toml("[system]\ncpu = \"riscv\"", &[])
            .is_err());
    }

    #[test]
    fn table1_mentions_mesi_and_sizes() {
        let rows = SimConfig::default().table1_rows();
        assert_eq!(rows.len(), 5);
        assert!(rows[2].1.contains("MESI"));
        assert!(rows[4].1.contains("4 GiB"));
    }
}
