//! Access traces: capture, binary (de)serialization and replay.
//!
//! Two formats share the "CXLT" magic, distinguished by version:
//!
//! * **v1** ([`Trace`]) — flat physical line-address stream, packed
//!   (line_addr: i32, is_write: u8) records. Feeds the fast-forward
//!   coordinator's XLA cache-warm artifact.
//! * **v2** ([`EventTrace`]) — the multi-host *memory-event* format: a
//!   VMA preamble (per-core mmap layout + policy specs), functional
//!   init writes, and the full per-(host, core) workload op stream.
//!   Captured from any live run via [`Recorder`] and replayed
//!   bit-deterministically as a workload
//!   (`[workload] kind = "replay"`, see
//!   [`crate::workloads::Replay`]) — same config + same trace ⇒ the
//!   identical event-by-event simulation, which is what lets benches
//!   pin a small serving trace and CI regress on it.
//!
//! v2 wire layout (all little-endian):
//!
//! ```text
//! "CXLT" | ver=2 u32 | n_vmas u32 | n_inits u64 | n_events u64
//! vma:    host u8 | core u8 | start u64 | len u64 | spec_len u16 | spec
//! init:   host u8 | core u8 | va u64 | bits u64
//! event:  op u8 (0=load 1=store 2=work) | host u8 | core u8 | size u8
//!         | arg u64 (va for load/store, cycles for work)
//! ```

use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::cpu::WlOp;
use crate::guestos::{AddressSpace, MemPolicy};
use crate::workloads::{WlStat, Workload};

#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    pub line_addrs: Vec<i32>,
    pub is_write: Vec<i32>,
}

impl Trace {
    pub fn push(&mut self, line_addr: i32, is_write: bool) {
        self.line_addrs.push(line_addr);
        self.is_write.push(is_write as i32);
    }

    pub fn len(&self) -> usize {
        self.line_addrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.line_addrs.is_empty()
    }

    /// Iterate fixed-size windows (last may be short).
    pub fn windows(&self, n: usize) -> impl Iterator<Item = (&[i32], &[i32])> {
        self.line_addrs
            .chunks(n)
            .zip(self.is_write.chunks(n))
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.len() * 5);
        out.extend_from_slice(b"CXLT");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&(self.len() as u64).to_le_bytes());
        for i in 0..self.len() {
            out.extend_from_slice(&self.line_addrs[i].to_le_bytes());
            out.push(self.is_write[i] as u8);
        }
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<Trace> {
        if b.len() < 16 || &b[0..4] != b"CXLT" {
            bail!("not a CXLT trace");
        }
        let ver = u32::from_le_bytes(b[4..8].try_into().unwrap());
        if ver != 1 {
            bail!("unsupported trace version {ver}");
        }
        let n = u64::from_le_bytes(b[8..16].try_into().unwrap()) as usize;
        if b.len() != 16 + n * 5 {
            bail!("trace length mismatch");
        }
        let mut t = Trace::default();
        for i in 0..n {
            let at = 16 + i * 5;
            t.line_addrs.push(i32::from_le_bytes(
                b[at..at + 4].try_into().unwrap(),
            ));
            t.is_write.push(b[at + 4] as i32);
        }
        Ok(t)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &std::path::Path) -> Result<Trace> {
        Trace::from_bytes(
            &std::fs::read(path)
                .with_context(|| format!("reading {}", path.display()))?,
        )
    }
}

// ---- v2: multi-host memory-event traces --------------------------------

/// Operation kind of one [`MemEvent`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceOp {
    Load = 0,
    Store = 1,
    Work = 2,
}

impl TraceOp {
    fn from_u8(b: u8) -> Result<TraceOp> {
        match b {
            0 => Ok(TraceOp::Load),
            1 => Ok(TraceOp::Store),
            2 => Ok(TraceOp::Work),
            other => bail!("bad trace op tag {other}"),
        }
    }
}

/// One workload op as seen at the (host, core) issue boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemEvent {
    pub host: u8,
    pub core: u8,
    pub op: TraceOp,
    /// Access size for loads/stores, 0 for work.
    pub size: u8,
    /// Virtual address (load/store) or cycle count (work).
    pub arg: u64,
}

/// One VMA a workload reserved during `setup`: replay re-mmaps these
/// (same lengths, same order, same policies) so the demand-paging walk
/// lands every page on the same node as the live run.
#[derive(Clone, Debug, PartialEq)]
pub struct VmaRecord {
    pub host: u8,
    pub core: u8,
    /// VA the live mmap returned — replay asserts it gets the same.
    pub start: u64,
    pub len: u64,
    /// `MemPolicy::to_spec` form ("bind:1", "interleave:0=3,1=1", …).
    pub policy: String,
}

/// One functional init write (`Workload::init_data`), replayed so
/// attach-time page faulting and memory contents match the live run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct InitRecord {
    pub host: u8,
    pub core: u8,
    pub va: u64,
    pub bits: u64,
}

/// A captured multi-host memory-event trace (format v2).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EventTrace {
    pub vmas: Vec<VmaRecord>,
    pub inits: Vec<InitRecord>,
    pub events: Vec<MemEvent>,
}

impl EventTrace {
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Hosts with at least one VMA, init record or event.
    pub fn hosts(&self) -> Vec<u8> {
        let mut hs: Vec<u8> = self
            .vmas
            .iter()
            .map(|v| v.host)
            .chain(self.inits.iter().map(|i| i.host))
            .chain(self.events.iter().map(|e| e.host))
            .collect();
        hs.sort_unstable();
        hs.dedup();
        hs
    }

    /// Highest core index recorded for `host`, or `None` if the host
    /// does not appear in the trace.
    pub fn max_core(&self, host: u8) -> Option<u8> {
        self.vmas
            .iter()
            .filter(|v| v.host == host)
            .map(|v| v.core)
            .chain(
                self.inits
                    .iter()
                    .filter(|i| i.host == host)
                    .map(|i| i.core),
            )
            .chain(
                self.events
                    .iter()
                    .filter(|e| e.host == host)
                    .map(|e| e.core),
            )
            .max()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            28 + self.vmas.len() * 32 + self.inits.len() * 18
                + self.events.len() * 12,
        );
        out.extend_from_slice(b"CXLT");
        out.extend_from_slice(&2u32.to_le_bytes());
        out.extend_from_slice(&(self.vmas.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.inits.len() as u64).to_le_bytes());
        out.extend_from_slice(&(self.events.len() as u64).to_le_bytes());
        for v in &self.vmas {
            out.push(v.host);
            out.push(v.core);
            out.extend_from_slice(&v.start.to_le_bytes());
            out.extend_from_slice(&v.len.to_le_bytes());
            let spec = v.policy.as_bytes();
            out.extend_from_slice(&(spec.len() as u16).to_le_bytes());
            out.extend_from_slice(spec);
        }
        for i in &self.inits {
            out.push(i.host);
            out.push(i.core);
            out.extend_from_slice(&i.va.to_le_bytes());
            out.extend_from_slice(&i.bits.to_le_bytes());
        }
        for e in &self.events {
            out.push(e.op as u8);
            out.push(e.host);
            out.push(e.core);
            out.push(e.size);
            out.extend_from_slice(&e.arg.to_le_bytes());
        }
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<EventTrace> {
        if b.len() < 28 || &b[0..4] != b"CXLT" {
            bail!("not a CXLT trace");
        }
        let ver = u32::from_le_bytes(b[4..8].try_into().unwrap());
        if ver != 2 {
            bail!("unsupported event-trace version {ver} (expected 2)");
        }
        let n_vmas =
            u32::from_le_bytes(b[8..12].try_into().unwrap()) as usize;
        let n_inits =
            u64::from_le_bytes(b[12..20].try_into().unwrap()) as usize;
        let n_events =
            u64::from_le_bytes(b[20..28].try_into().unwrap()) as usize;
        let mut t = EventTrace::default();
        let mut at = 28usize;
        let take = |at: &mut usize, n: usize| -> Result<&[u8]> {
            let s = b
                .get(*at..*at + n)
                .context("event trace truncated")?;
            *at += n;
            Ok(s)
        };
        for _ in 0..n_vmas {
            let hc = take(&mut at, 2)?;
            let (host, core) = (hc[0], hc[1]);
            let start =
                u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap());
            let len =
                u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap());
            let spec_len =
                u16::from_le_bytes(take(&mut at, 2)?.try_into().unwrap())
                    as usize;
            let policy =
                std::str::from_utf8(take(&mut at, spec_len)?)
                    .context("vma policy spec is not utf8")?
                    .to_string();
            // Reject specs the replay-side parser cannot rebuild now,
            // not at replay time.
            MemPolicy::parse(&policy).with_context(|| {
                format!("vma record carries unparseable policy '{policy}'")
            })?;
            t.vmas.push(VmaRecord { host, core, start, len, policy });
        }
        for _ in 0..n_inits {
            let hc = take(&mut at, 2)?;
            let (host, core) = (hc[0], hc[1]);
            let va =
                u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap());
            let bits =
                u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap());
            t.inits.push(InitRecord { host, core, va, bits });
        }
        for _ in 0..n_events {
            let head = take(&mut at, 4)?;
            let (op, host, core, size) =
                (TraceOp::from_u8(head[0])?, head[1], head[2], head[3]);
            let arg =
                u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap());
            t.events.push(MemEvent { host, core, op, size, arg });
        }
        if at != b.len() {
            bail!("event trace has {} trailing bytes", b.len() - at);
        }
        Ok(t)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &std::path::Path) -> Result<EventTrace> {
        EventTrace::from_bytes(
            &std::fs::read(path)
                .with_context(|| format!("reading {}", path.display()))?,
        )
    }
}

/// One `(host, core)` wrapper's captured slice. Each [`Recorded`]
/// appends only to its own part, so concurrent capture from several
/// worker threads never interleaves records within a stream.
#[derive(Default)]
struct Part {
    host: u8,
    core: u8,
    vmas: Vec<VmaRecord>,
    inits: Vec<InitRecord>,
    events: Vec<MemEvent>,
}

/// Tees every workload on a machine into one shared [`EventTrace`].
///
/// Wrap each workload with its (host, core) before attaching:
/// `m.attach_workloads_to(h, vec![rec.wrap(h, 0, wl)], &policy)`. The
/// wrapper is transparent — it forwards every trait hook, so a
/// recorded run stays bit-identical to an unrecorded one. Capture is
/// thread-safe (hosts may drain on worker threads under
/// `[sim] threads > 1`): each wrapper owns a private per-(host, core)
/// part and [`Recorder::snapshot`]/[`Recorder::take`] merge the parts
/// in `(host, core)` order — the assembled trace is a function of what
/// each core did, never of which worker thread ran its host first.
/// [`crate::workloads::Replay`] consumes the trace per (host, core)
/// stream, so the grouped merge replays identically.
#[derive(Clone, Default)]
pub struct Recorder {
    parts: Arc<Mutex<Vec<Part>>>,
}

impl Recorder {
    pub fn new() -> Self {
        Recorder::default()
    }

    /// Wrap `inner` so its VMAs, init writes and ops are recorded
    /// under `(host, core)`.
    pub fn wrap(
        &self,
        host: usize,
        core: usize,
        inner: Box<dyn Workload>,
    ) -> Box<dyn Workload> {
        let mut parts = self.parts.lock().unwrap();
        parts.push(Part {
            host: host as u8,
            core: core as u8,
            ..Default::default()
        });
        let idx = parts.len() - 1;
        drop(parts);
        Box::new(Recorded {
            idx,
            inner,
            parts: Arc::clone(&self.parts),
        })
    }

    /// Deterministic merge: parts ordered by `(host, core)` (wrap
    /// order as the tiebreak), each part's records in capture order.
    fn merged(parts: &[Part]) -> EventTrace {
        let mut order: Vec<usize> = (0..parts.len()).collect();
        order.sort_by_key(|&i| (parts[i].host, parts[i].core, i));
        let mut t = EventTrace::default();
        for &i in &order {
            t.vmas.extend(parts[i].vmas.iter().cloned());
            t.inits.extend(parts[i].inits.iter().cloned());
            t.events.extend(parts[i].events.iter().cloned());
        }
        t
    }

    /// The trace captured so far (clone; the run may still be going).
    pub fn snapshot(&self) -> EventTrace {
        Self::merged(&self.parts.lock().unwrap())
    }

    /// Take the captured trace, leaving the recorder empty.
    pub fn take(&self) -> EventTrace {
        let mut parts = self.parts.lock().unwrap();
        let t = Self::merged(&parts);
        for p in parts.iter_mut() {
            p.vmas.clear();
            p.inits.clear();
            p.events.clear();
        }
        t
    }
}

struct Recorded {
    /// This wrapper's slot in the shared part list.
    idx: usize,
    inner: Box<dyn Workload>,
    parts: Arc<Mutex<Vec<Part>>>,
}

impl Workload for Recorded {
    fn name(&self) -> String {
        format!("{}+rec", self.inner.name())
    }

    fn setup(&mut self, asp: &mut AddressSpace, policy: &MemPolicy) {
        let before = asp.vma_spans().len();
        self.inner.setup(asp, policy);
        let init = self.inner.init_data();
        let mut parts = self.parts.lock().unwrap();
        let part = &mut parts[self.idx];
        let (host, core) = (part.host, part.core);
        for (start, len, pol) in asp.vma_spans().into_iter().skip(before) {
            part.vmas.push(VmaRecord {
                host,
                core,
                start,
                len,
                policy: pol.to_spec(),
            });
        }
        for (va, bits) in init {
            part.inits.push(InitRecord { host, core, va, bits });
        }
    }

    fn next_op(&mut self) -> Option<WlOp> {
        let op = self.inner.next_op()?;
        let mut parts = self.parts.lock().unwrap();
        let part = &mut parts[self.idx];
        let (host, core) = (part.host, part.core);
        let ev = match op {
            WlOp::Load { va, size } => MemEvent {
                host,
                core,
                op: TraceOp::Load,
                size: size as u8,
                arg: va,
            },
            WlOp::Store { va, size } => MemEvent {
                host,
                core,
                op: TraceOp::Store,
                size: size as u8,
                arg: va,
            },
            WlOp::Work { cycles } => MemEvent {
                host,
                core,
                op: TraceOp::Work,
                size: 0,
                arg: cycles,
            },
        };
        part.events.push(ev);
        Some(op)
    }

    fn tick_hint(&mut self, tick: u64) {
        self.inner.tick_hint(tick);
    }

    fn extra_stats(&self) -> Vec<(String, WlStat)> {
        self.inner.extra_stats()
    }

    fn bytes_moved(&self) -> u64 {
        self.inner.bytes_moved()
    }

    fn init_data(&self) -> Vec<(u64, u64)> {
        self.inner.init_data()
    }

    fn load_done(&mut self, va: u64, bits: u64) {
        self.inner.load_done(va, bits);
    }

    fn store_value(&mut self, va: u64) -> u64 {
        self.inner.store_value(va)
    }

    fn verify(
        &self,
        asp: &mut AddressSpace,
        alloc: &mut crate::guestos::PageAlloc,
        mem: &crate::mem::PhysMem,
    ) -> Result<(), String> {
        self.inner.verify(asp, alloc, mem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bytes() {
        let mut t = Trace::default();
        for i in 0..1000 {
            t.push(i * 3, i % 7 == 0);
        }
        let b = t.to_bytes();
        assert_eq!(Trace::from_bytes(&b).unwrap(), t);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Trace::from_bytes(b"nope").is_err());
        assert!(Trace::from_bytes(b"CXLT\x02\x00\x00\x00").is_err());
        let mut good = Trace::default();
        good.push(1, false);
        let mut b = good.to_bytes();
        b.pop();
        assert!(Trace::from_bytes(&b).is_err());
    }

    #[test]
    fn windows_chunking() {
        let mut t = Trace::default();
        for i in 0..10 {
            t.push(i, false);
        }
        let w: Vec<_> = t.windows(4).collect();
        assert_eq!(w.len(), 3);
        assert_eq!(w[0].0.len(), 4);
        assert_eq!(w[2].0.len(), 2);
    }

    // ---- v2 ------------------------------------------------------------

    /// Build an EventTrace from shrinkable raw material: each vma is
    /// (start_page, policy_pick), each event is (tag_and_ids, arg).
    fn trace_from_raw(
        vmas: &[(u64, u64)],
        events: &[(u64, u64)],
    ) -> EventTrace {
        const SPECS: [&str; 5] =
            ["local", "local:1", "bind:0,1", "preferred:1", "interleave:0=3,1=1"];
        let mut t = EventTrace::default();
        for (i, &(start, pick)) in vmas.iter().enumerate() {
            t.vmas.push(VmaRecord {
                host: (pick % 3) as u8,
                core: (i % 4) as u8,
                start: 0x7f00_0000_0000 + start * 4096,
                len: (1 + pick % 64) * 4096,
                policy: SPECS[pick as usize % SPECS.len()].to_string(),
            });
            t.inits.push(InitRecord {
                host: (pick % 3) as u8,
                core: (i % 4) as u8,
                va: 0x7f00_0000_0000 + start * 4096,
                bits: pick.wrapping_mul(0x9E37_79B9),
            });
        }
        for &(head, arg) in events {
            let op = match head % 3 {
                0 => TraceOp::Load,
                1 => TraceOp::Store,
                _ => TraceOp::Work,
            };
            t.events.push(MemEvent {
                host: (head / 3 % 3) as u8,
                core: (head / 9 % 4) as u8,
                op,
                size: if op == TraceOp::Work { 0 } else { 8 },
                arg,
            });
        }
        t
    }

    #[test]
    fn v2_roundtrip_property() {
        crate::util::prop::check(
            "event-trace-roundtrip",
            200,
            |r| {
                let nv = r.below(6) as usize;
                let ne = r.below(64) as usize;
                let vmas: Vec<(u64, u64)> = (0..nv)
                    .map(|_| (r.below(1 << 20), r.below(1 << 16)))
                    .collect();
                let events: Vec<(u64, u64)> = (0..ne)
                    .map(|_| (r.below(1 << 30), r.next_u64()))
                    .collect();
                (vmas, events)
            },
            |(vmas, events)| {
                let t = trace_from_raw(vmas, events);
                let b = t.to_bytes();
                let back = EventTrace::from_bytes(&b)
                    .map_err(|e| format!("decode failed: {e}"))?;
                if back != t {
                    return Err("decoded trace differs".into());
                }
                // Bit-identical re-encode, not just structural equality.
                if back.to_bytes() != b {
                    return Err("re-encode differs".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn v2_rejects_garbage() {
        assert!(EventTrace::from_bytes(b"nope").is_err());
        // v1 bytes must not parse as v2 (and vice versa).
        let mut v1 = Trace::default();
        v1.push(1, true);
        assert!(EventTrace::from_bytes(&v1.to_bytes()).is_err());
        let v2 = trace_from_raw(&[(1, 2)], &[(0, 42)]);
        assert!(Trace::from_bytes(&v2.to_bytes()).is_err());
        // Truncation and trailing junk.
        let mut b = v2.to_bytes();
        b.pop();
        assert!(EventTrace::from_bytes(&b).is_err());
        let mut b = v2.to_bytes();
        b.push(0);
        assert!(EventTrace::from_bytes(&b).is_err());
        // Bad op tag.
        let mut b = v2.to_bytes();
        let ev_at = b.len() - 12;
        b[ev_at] = 9;
        assert!(EventTrace::from_bytes(&b).is_err());
        // Unparseable policy spec.
        let mut t = trace_from_raw(&[(1, 0)], &[]);
        t.vmas[0].policy = "martian:7".into();
        assert!(EventTrace::from_bytes(&t.to_bytes()).is_err());
    }

    #[test]
    fn v2_hosts_and_cores() {
        let t = trace_from_raw(&[(0, 0), (1, 4)], &[(3, 1), (26, 2)]);
        // pick=0 → host 0; pick=4 → host 1; head=3 → host 1;
        // head=26 → host 2 core 2.
        assert_eq!(t.hosts(), vec![0, 1, 2]);
        assert_eq!(t.max_core(2), Some(2));
        assert_eq!(t.max_core(7), None);
    }

    #[test]
    fn v2_recorder_captures_vmas_inits_and_ops() {
        use crate::workloads::{Stream, StreamKernel};
        let rec = Recorder::new();
        let inner: Box<dyn Workload> =
            Box::new(Stream::new(StreamKernel::Copy, 64 << 10, 1));
        let mut w = rec.wrap(1, 0, inner);
        let (mut asp, _pa) = crate::workloads::testutil::world();
        w.setup(&mut asp, &MemPolicy::Local { home: 0 });
        let mut n_ops = 0u64;
        while let Some(op) = w.next_op() {
            n_ops += 1;
            // Recorder must hand back the op unchanged.
            match op {
                WlOp::Load { size, .. } | WlOp::Store { size, .. } => {
                    assert_eq!(size, 8)
                }
                WlOp::Work { .. } => {}
            }
            assert!(n_ops < 1_000_000);
        }
        let t = rec.take();
        assert_eq!(t.events.len() as u64, n_ops);
        assert!(!t.vmas.is_empty());
        assert!(t.vmas.iter().all(|v| v.host == 1 && v.core == 0));
        assert!(!t.inits.is_empty());
        // take() drained the buffer.
        assert!(rec.snapshot().is_empty());
    }
}
