//! Access traces: capture, binary (de)serialization and replay.
//!
//! The fast-forward coordinator feeds traces to the XLA cache-warm
//! artifact; benches use saved traces for reproducible inputs. Format:
//! magic "CXLT", version u32, count u64, then per record packed
//! (line_addr: i32, is_write: u8).

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    pub line_addrs: Vec<i32>,
    pub is_write: Vec<i32>,
}

impl Trace {
    pub fn push(&mut self, line_addr: i32, is_write: bool) {
        self.line_addrs.push(line_addr);
        self.is_write.push(is_write as i32);
    }

    pub fn len(&self) -> usize {
        self.line_addrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.line_addrs.is_empty()
    }

    /// Iterate fixed-size windows (last may be short).
    pub fn windows(&self, n: usize) -> impl Iterator<Item = (&[i32], &[i32])> {
        self.line_addrs
            .chunks(n)
            .zip(self.is_write.chunks(n))
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.len() * 5);
        out.extend_from_slice(b"CXLT");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&(self.len() as u64).to_le_bytes());
        for i in 0..self.len() {
            out.extend_from_slice(&self.line_addrs[i].to_le_bytes());
            out.push(self.is_write[i] as u8);
        }
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<Trace> {
        if b.len() < 16 || &b[0..4] != b"CXLT" {
            bail!("not a CXLT trace");
        }
        let ver = u32::from_le_bytes(b[4..8].try_into().unwrap());
        if ver != 1 {
            bail!("unsupported trace version {ver}");
        }
        let n = u64::from_le_bytes(b[8..16].try_into().unwrap()) as usize;
        if b.len() != 16 + n * 5 {
            bail!("trace length mismatch");
        }
        let mut t = Trace::default();
        for i in 0..n {
            let at = 16 + i * 5;
            t.line_addrs.push(i32::from_le_bytes(
                b[at..at + 4].try_into().unwrap(),
            ));
            t.is_write.push(b[at + 4] as i32);
        }
        Ok(t)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &std::path::Path) -> Result<Trace> {
        Trace::from_bytes(
            &std::fs::read(path)
                .with_context(|| format!("reading {}", path.display()))?,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bytes() {
        let mut t = Trace::default();
        for i in 0..1000 {
            t.push(i * 3, i % 7 == 0);
        }
        let b = t.to_bytes();
        assert_eq!(Trace::from_bytes(&b).unwrap(), t);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Trace::from_bytes(b"nope").is_err());
        assert!(Trace::from_bytes(b"CXLT\x02\x00\x00\x00").is_err());
        let mut good = Trace::default();
        good.push(1, false);
        let mut b = good.to_bytes();
        b.pop();
        assert!(Trace::from_bytes(&b).is_err());
    }

    #[test]
    fn windows_chunking() {
        let mut t = Trace::default();
        for i in 0..10 {
            t.push(i, false);
        }
        let w: Vec<_> = t.windows(4).collect();
        assert_eq!(w.len(), 3);
        assert_eq!(w[0].0.len(), 4);
        assert_eq!(w[2].0.len(), 2);
    }
}
