//! Process address spaces with demand paging.
//!
//! Interleaving in Linux happens at *fault time*: a page's node is
//! decided when it is first touched, per the faulting task's mempolicy.
//! We model exactly that: `mmap` only reserves a VA range + policy;
//! `translate` takes the fault on first touch and calls the NUMA page
//! allocator. This is what makes the Fig.-5 interleave-ratio sweeps
//! honest — pages land on DRAM/CXL in the OS-managed ratio, not via a
//! simulator back door.

use crate::util::fxhash::FxHashMap;

use anyhow::{bail, Result};

use super::numa::{MemPolicy, PageAlloc};

#[derive(Clone, Debug)]
struct Vma {
    start: u64,
    len: u64,
    policy: MemPolicy,
    /// Page sequence counter for interleave round-robin within this VMA.
    next_seq: u64,
}

#[derive(Clone, Debug, Default)]
pub struct VmStats {
    pub faults: u64,
    pub pages_node: Vec<u64>,
}

/// One process's virtual address space.
#[derive(Clone, Debug)]
pub struct AddressSpace {
    page: u64,
    vmas: Vec<Vma>,
    table: FxHashMap<u64, u64>, // vpn -> physical page base
    next_mmap: u64,
    pub stats: VmStats,
}

impl AddressSpace {
    pub fn new(page: u64) -> Self {
        AddressSpace {
            page,
            vmas: Vec::new(),
            table: FxHashMap::default(),
            next_mmap: 0x7f00_0000_0000, // canonical-ish mmap base
            stats: VmStats::default(),
        }
    }

    /// Reserve `len` bytes under `policy`; returns the VA.
    pub fn mmap(&mut self, len: u64, policy: MemPolicy) -> u64 {
        let len = len.div_ceil(self.page) * self.page;
        let va = self.next_mmap;
        self.next_mmap += len + self.page; // guard page
        self.vmas.push(Vma { start: va, len, policy, next_seq: 0 });
        va
    }

    /// Translate VA -> PA, faulting the page in on first touch.
    pub fn translate(
        &mut self,
        va: u64,
        alloc: &mut PageAlloc,
    ) -> Result<u64> {
        let vpn = va / self.page;
        if let Some(&base) = self.table.get(&vpn) {
            return Ok(base + va % self.page);
        }
        // Fault: find the VMA.
        let vma = self
            .vmas
            .iter_mut()
            .find(|m| va >= m.start && va < m.start + m.len);
        let Some(vma) = vma else {
            bail!("segfault at {va:#x} (no VMA)");
        };
        let seq = vma.next_seq;
        vma.next_seq += 1;
        let policy = vma.policy.clone();
        let page_base = alloc.alloc_page(&policy, seq)?;
        self.table.insert(vpn, page_base);
        self.stats.faults += 1;
        if let Some(node) = alloc.node_of_addr(page_base) {
            let n = node as usize;
            if self.stats.pages_node.len() <= n {
                self.stats.pages_node.resize(n + 1, 0);
            }
            self.stats.pages_node[n] += 1;
        }
        Ok(page_base + va % self.page)
    }

    /// Fraction of this space's resident pages on `node`.
    pub fn node_share(&self, node: usize) -> f64 {
        let total: u64 = self.stats.pages_node.iter().sum();
        if total == 0 {
            return 0.0;
        }
        self.stats.pages_node.get(node).copied().unwrap_or(0) as f64
            / total as f64
    }

    pub fn resident_pages(&self) -> usize {
        self.table.len()
    }

    /// The reserved VMAs as `(start, len, policy)` triples, in mmap
    /// order. The trace recorder diffs this across a workload's
    /// `setup` to capture the address-space layout a replay run must
    /// rebuild.
    pub fn vma_spans(&self) -> Vec<(u64, u64, MemPolicy)> {
        self.vmas
            .iter()
            .map(|m| (m.start, m.len, m.policy.clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::guestos::numa::NumaNode;

    fn world() -> (AddressSpace, PageAlloc) {
        let mut pa = PageAlloc::new(4096);
        pa.add_node(NumaNode::new(0, 0, 64 << 20, true));
        pa.add_node(NumaNode::new(1, 4 << 30, 64 << 20, false));
        pa.online(0);
        pa.online(1);
        (AddressSpace::new(4096), pa)
    }

    #[test]
    fn demand_paging_faults_once() {
        let (mut asp, mut pa) = world();
        let va = asp.mmap(16 << 10, MemPolicy::Local { home: 0 });
        let p1 = asp.translate(va, &mut pa).unwrap();
        let p2 = asp.translate(va + 8, &mut pa).unwrap();
        assert_eq!(p2 - p1, 8);
        assert_eq!(asp.stats.faults, 1);
        asp.translate(va + 4096, &mut pa).unwrap();
        assert_eq!(asp.stats.faults, 2);
    }

    #[test]
    fn segfault_outside_vma() {
        let (mut asp, mut pa) = world();
        assert!(asp.translate(0xdead_0000, &mut pa).is_err());
    }

    #[test]
    fn interleave_lands_in_ratio() {
        let (mut asp, mut pa) = world();
        let pol = MemPolicy::Interleave { weights: vec![(0, 1), (1, 1)] };
        let va = asp.mmap(4096 * 100, pol);
        for i in 0..100u64 {
            asp.translate(va + i * 4096, &mut pa).unwrap();
        }
        assert_eq!(asp.stats.pages_node, vec![50, 50]);
        assert!((asp.node_share(1) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn separate_vmas_have_separate_cursors() {
        let (mut asp, mut pa) = world();
        let pol = MemPolicy::Interleave { weights: vec![(0, 1), (1, 1)] };
        let a = asp.mmap(4096 * 2, pol.clone());
        let b = asp.mmap(4096 * 2, pol);
        // First page of each VMA starts the round-robin at node 0.
        let pa1 = asp.translate(a, &mut pa).unwrap();
        let pb1 = asp.translate(b, &mut pa).unwrap();
        assert_eq!(pa.node_of_addr(pa1), Some(0));
        assert_eq!(pa.node_of_addr(pb1), Some(0));
    }
}
