//! Guest software stack model.
//!
//! The paper's headline claim is that CXLRAMSim needs **no kernel or
//! driver patches** because the modeled firmware + device surfaces are
//! architecturally correct. We substantiate the same claim structurally:
//! this module consumes only (a) bytes in simulated physical memory
//! (BIOS tables) and (b) MMIO through the [`Platform`] trait (ECAM
//! config space, BAR-mapped CXL register blocks). It never reaches into
//! simulator internals.
//!
//! Boot flow ([`GuestOs::boot`]):
//!   E820 -> ACPI parse (incl. AML) -> NUMA init from SRAT ->
//!   PCIe enumeration -> CXL driver bind (DVSEC walk, mailbox IDENTIFY,
//!   HDM decoder programming) -> `cxl create-region` + online ->
//!   zNUMA node 1 visible to the allocator.

pub mod acpi_parse;
pub mod cxl_driver;
pub mod cxlcli;
pub mod numa;
pub mod pci_scan;
pub mod vm;

use anyhow::{Context, Result};

use crate::bios::layout;
use crate::mem::PhysMem;

pub use acpi_parse::AcpiInfo;
pub use cxl_driver::CxlMemdev;
pub use cxlcli::CxlRegion;
pub use numa::{MemPolicy, NumaNode, PageAlloc};
pub use pci_scan::{MmioAllocator, PciDev};
pub use vm::AddressSpace;

/// MMIO access surface the guest drives (implemented by the machine:
/// routes to ECAM, CXL component/device blocks, host-bridge block).
pub trait Platform {
    fn mmio_read32(&mut self, addr: u64) -> u32;
    fn mmio_write32(&mut self, addr: u64, v: u32);
    fn mmio_read64(&mut self, addr: u64) -> u64;
    fn mmio_write64(&mut self, addr: u64, v: u64);
}

/// Memory-exposure programming model (paper §IV).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ProgModel {
    #[default]
    /// CXL memory as CPU-less NUMA node (zNUMA) — the default.
    Znuma,
    /// Flat mode: CXL capacity merged with system memory.
    Flat,
}

/// A guest-visible memory topology change produced by handling FM
/// events — what the machine needs to mirror into the host-side
/// routing (RC windows) and stats.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemChange {
    /// A hot-added window came online as zNUMA node `node`.
    Onlined { base: u64, size: u64, node: u32 },
    /// A hot-removed window went offline (node emptied and released).
    Offlined { base: u64, size: u64, node: u32 },
    /// The FM asked for this window back but its node still has pages
    /// in use — the guest refused the remove (no-migration model).
    OfflineRefused { base: u64, node: u32 },
}

/// The booted guest's state.
pub struct GuestOs {
    /// Which simulated host this guest runs on (0 in single-host
    /// machines). The driver hands it to the FM-API allocation query so
    /// a pooled MLD presents only this host's logical devices.
    pub host: u16,
    pub acpi: AcpiInfo,
    pub pci_devs: Vec<PciDev>,
    /// Every bound expander, in host-bridge UID order (`mem0`, `mem1`…).
    pub memdevs: Vec<CxlMemdev>,
    /// Hot-plug pool: published windows whose logical devices currently
    /// belong to other hosts (uncommitted; populated only in the
    /// hot-plug window layout — see [`cxl_driver::bind_all`]).
    pub spares: Vec<CxlMemdev>,
    /// One region per interleave-set window, in window order.
    pub regions: Vec<CxlRegion>,
    pub alloc: PageAlloc,
    /// zNUMA node ids onlined for the regions (empty in flat mode).
    pub cxl_nodes: Vec<u32>,
    pub boot_log: Vec<String>,
}

impl GuestOs {
    /// Full boot. `mem` carries the BIOS tables; `p` is the MMIO world;
    /// `host` is this machine's identity on the CXL fabric (0 for
    /// single-host setups).
    pub fn boot(
        p: &mut dyn Platform,
        mem: &PhysMem,
        page_size: u64,
        model: ProgModel,
        host: u16,
    ) -> Result<GuestOs> {
        let mut log = Vec::new();

        // --- firmware tables -------------------------------------------
        let acpi = acpi_parse::parse(mem, layout::RSDP_ADDR & !0xFFFF)
            .context("ACPI parse failed")?;
        log.push(format!(
            "acpi: {} cpus, {} memory affinities, {} CHBS, {} CFMWS",
            acpi.cpu_apic_ids.len(),
            acpi.mem_affinity.len(),
            acpi.chbs.len(),
            acpi.cfmws.len()
        ));

        // --- NUMA init from SRAT ----------------------------------------
        let mut alloc = PageAlloc::new(page_size);
        let mut srat_nodes: Vec<_> = acpi.mem_affinity.clone();
        srat_nodes.sort_by_key(|m| m.domain);
        for m in &srat_nodes {
            let has_cpus = m.domain == 0; // SRAT cpu entries are domain 0
            alloc.add_node(NumaNode::new(m.domain, m.base, m.length, has_cpus));
            if m.enabled && !m.hotplug {
                alloc.online(m.domain);
                log.push(format!(
                    "numa: node {} online ({} MiB)",
                    m.domain,
                    m.length >> 20
                ));
            } else {
                log.push(format!(
                    "numa: node {} deferred (hotplug)",
                    m.domain
                ));
            }
        }

        // --- PCIe enumeration --------------------------------------------
        let (ecam, _b0, b1) = acpi.ecam.context("no MCFG/ECAM")?;
        // BAR window: host bridge _CRS second window, minus the CHBS
        // blocks the BIOS reserved at its base (one per CXL bridge,
        // discovered from their _CRS entries).
        let hb = acpi
            .devices
            .iter()
            .find(|d| d.hid.as_deref() == Some("PNP0A08"))
            .context("no PCIe host bridge in DSDT")?;
        let (mmio_base, mmio_size) =
            *hb.crs.get(1).context("host bridge lacks MMIO window")?;
        let reserved_end = acpi
            .chbs
            .iter()
            .filter(|c| c.base >= mmio_base)
            .map(|c| c.base + c.length)
            .fold(mmio_base + layout::CHBS_SIZE, u64::max);
        let mut bar_alloc = MmioAllocator::new(
            reserved_end,
            mmio_base + mmio_size - reserved_end,
        );
        let pci_devs = pci_scan::enumerate(p, ecam, b1, &mut bar_alloc);
        log.push(format!("pci: {} functions enumerated", pci_devs.len()));

        // --- CXL driver -----------------------------------------------------
        let (memdevs, spares) =
            match cxl_driver::bind_all(p, &acpi, &pci_devs, host) {
                Ok(r) => {
                    for (i, md) in r.bound.iter().enumerate() {
                        let ld = if md.lds > 1 {
                            format!(", LD {}/{}", md.ld, md.lds)
                        } else {
                            String::new()
                        };
                        log.push(format!(
                            "cxl: mem{i} bound at {} — {} MiB, window \
                             {:#x} ({}-way @ {} B, slot {}{ld})",
                            md.bdf,
                            md.capacity >> 20,
                            md.hpa_base,
                            md.window_ways,
                            md.window_granularity,
                            md.position
                        ));
                    }
                    for md in &r.spares {
                        log.push(format!(
                            "cxl: window {:#x} reserved for hot-plug \
                             ({} LD {} is bound to another host)",
                            md.hpa_base, md.bdf, md.ld
                        ));
                    }
                    (r.bound, r.spares)
                }
                Err(e) => {
                    log.push(format!("cxl: no memdev ({e})"));
                    (Vec::new(), Vec::new())
                }
            };

        // --- region creation + onlining ------------------------------------
        // Group memdevs by window: each interleave set becomes one
        // region. Its NUMA domain comes from the SRAT entry covering
        // the window base — the same association Linux derives.
        let mut windows: Vec<u64> = memdevs.iter().map(|m| m.hpa_base).collect();
        windows.sort_unstable();
        windows.dedup();
        let mut regions = Vec::new();
        let mut cxl_nodes = Vec::new();
        for base in windows {
            let group: Vec<&CxlMemdev> =
                memdevs.iter().filter(|m| m.hpa_base == base).collect();
            let domain = acpi
                .mem_affinity
                .iter()
                .find(|m| m.base == base)
                .map(|m| m.domain)
                .context("window has no SRAT domain")?;
            match model {
                ProgModel::Znuma => {
                    let region =
                        cxlcli::cxl_create_region(p, &group, 0, domain)?;
                    let id = cxlcli::online_region(&mut alloc, &region)?;
                    cxl_nodes.push(id);
                    log.push(format!(
                        "cxl-cli: region @{base:#x} ({} memdevs) onlined \
                         as zNUMA node {id}",
                        group.len()
                    ));
                    regions.push(region);
                }
                ProgModel::Flat => {
                    let region =
                        cxlcli::cxl_create_region(p, &group, 0, 0)?;
                    cxlcli::online_flat(&mut alloc, &region)?;
                    log.push(format!(
                        "cxl-cli: region @{base:#x} onlined in flat mode"
                    ));
                    regions.push(region);
                }
            }
        }

        Ok(GuestOs {
            host,
            acpi,
            pci_devs,
            memdevs,
            spares,
            regions,
            alloc,
            cxl_nodes,
            boot_log: log,
        })
    }

    /// The first zNUMA node id, if one was onlined.
    pub fn znuma_node(&self) -> Option<u32> {
        self.cxl_nodes.first().copied()
    }

    // ---- runtime FM events (hot add / remove) ---------------------------

    /// The "interrupt handler" for the CXL event doorbell: poll every
    /// known endpoint's status register for [`EVENT_PENDING`], drain
    /// pending Event-Log records addressed to this host and run the
    /// memory hot-add / hot-remove path for each. Returns the
    /// topology changes for the machine to mirror (RC routing windows,
    /// stats).
    ///
    /// [`EVENT_PENDING`]: crate::cxl::regs::dev::EVENT_PENDING
    pub fn handle_fm_events(
        &mut self,
        p: &mut dyn Platform,
    ) -> Result<Vec<MemChange>> {
        use crate::cxl::mailbox::{
            event, opcode, retcode, EVENT_RECORD_BYTES,
        };
        use crate::cxl::regs::dev;
        let mut blocks: Vec<u64> = self
            .memdevs
            .iter()
            .chain(self.spares.iter())
            .map(|m| m.device_block)
            .collect();
        blocks.sort_unstable();
        blocks.dedup();
        let mut changes = Vec::new();
        for blk in blocks {
            if p.mmio_read64(blk + dev::MEMDEV_STATUS) & dev::EVENT_PENDING
                == 0
            {
                continue;
            }
            let (code, resp) = cxl_driver::mailbox_command(
                p,
                blk,
                opcode::GET_EVENT_RECORDS,
                &[0],
            )?;
            if code != retcode::SUCCESS || resp.len() < 2 {
                continue;
            }
            let n = u16::from_le_bytes(resp[0..2].try_into().unwrap());
            // Handle (and later clear) only the LEADING run of records
            // addressed to this host: CLEAR_EVENT_RECORDS drains from
            // the front, so stopping at the first foreign record is
            // what keeps other hosts' pending events in the log (the
            // contract EventRecord documents). Our synchronous
            // delivery never interleaves hosts, so the prefix is
            // normally the whole log.
            let mut handled: u16 = 0;
            for k in 0..n as usize {
                let o = 2 + k * EVENT_RECORD_BYTES;
                let host =
                    u16::from_le_bytes(resp[o..o + 2].try_into().unwrap());
                let ld = u16::from_le_bytes(
                    resp[o + 2..o + 4].try_into().unwrap(),
                );
                let action = resp[o + 4];
                if host != self.host {
                    break; // another host's record: leave it (and all
                           // behind it) in the log
                }
                handled += 1;
                match action {
                    event::UNBIND_REQUEST => {
                        self.hot_remove(p, blk, ld, &mut changes)?
                    }
                    event::LD_BOUND => {
                        self.hot_add(p, blk, ld, &mut changes)?
                    }
                    event::POLICY_DECISION => {
                        // Informational decision-log record from a
                        // telemetry-driven FM policy: log it like a
                        // kernel would and keep draining.
                        self.boot_log.push(format!(
                            "cxl: fm policy decision — LD {ld} selected \
                             for re-binding"
                        ));
                    }
                    other => self.boot_log.push(format!(
                        "cxl: unknown event action {other} ignored"
                    )),
                }
            }
            if handled > 0 {
                cxl_driver::mailbox_command(
                    p,
                    blk,
                    opcode::CLEAR_EVENT_RECORDS,
                    &handled.to_le_bytes(),
                )?;
            }
        }
        Ok(changes)
    }

    /// Memory hot-remove: the FM wants logical device `ld` (endpoint at
    /// device block `blk`) back. Refuses while the node has pages in
    /// use; otherwise offlines the zNUMA node, uncommits the decoder
    /// pair and moves the memdev into the hot-plug spare pool.
    fn hot_remove(
        &mut self,
        p: &mut dyn Platform,
        blk: u64,
        ld: u16,
        changes: &mut Vec<MemChange>,
    ) -> Result<()> {
        let Some(pos) = self
            .memdevs
            .iter()
            .position(|m| m.device_block == blk && m.ld == ld)
        else {
            self.boot_log.push(format!(
                "cxl: unbind request for LD {ld} we do not hold — ignored"
            ));
            return Ok(());
        };
        let (base, size) =
            (self.memdevs[pos].hpa_base, self.memdevs[pos].hpa_size);
        let node = self
            .alloc
            .node_of_addr(base)
            .context("window has no NUMA node")?;
        match cxlcli::offline_region(&mut self.alloc, node) {
            Err(e) => {
                self.boot_log.push(format!(
                    "cxl: cannot offline node {node} for LD {ld} \
                     hot-remove: {e}"
                ));
                changes.push(MemChange::OfflineRefused { base, node });
                Ok(())
            }
            Ok(()) => {
                cxl_driver::uncommit_memdev_decoders(p, &self.memdevs[pos]);
                self.regions.retain(|r| r.base != base);
                self.cxl_nodes.retain(|&nd| nd != node);
                let md = self.memdevs.remove(pos);
                self.boot_log.push(format!(
                    "cxl: memory hot-remove — {} LD {ld}: node {node} \
                     offlined, {} MiB released to the fabric manager",
                    md.bdf,
                    size >> 20
                ));
                self.spares.push(md);
                changes.push(MemChange::Offlined { base, size, node });
                Ok(())
            }
        }
    }

    /// Memory hot-add: logical device `ld` was just bound to this host.
    /// Commits the spare window's decoder pair, creates the region and
    /// onlines its zNUMA node — the same path boot-time onlining takes.
    fn hot_add(
        &mut self,
        p: &mut dyn Platform,
        blk: u64,
        ld: u16,
        changes: &mut Vec<MemChange>,
    ) -> Result<()> {
        let Some(pos) = self
            .spares
            .iter()
            .position(|m| m.device_block == blk && m.ld == ld)
        else {
            self.boot_log.push(format!(
                "cxl: bind notification for LD {ld} without a spare \
                 window — ignored"
            ));
            return Ok(());
        };
        let md = self.spares[pos].clone();
        cxl_driver::commit_memdev_decoders(p, &md)?;
        let domain = self
            .acpi
            .mem_affinity
            .iter()
            .find(|m| m.base == md.hpa_base)
            .map(|m| m.domain)
            .context("hot-added window has no SRAT domain")?;
        let region = cxlcli::cxl_create_region(p, &[&md], 0, domain)?;
        let node = cxlcli::online_region(&mut self.alloc, &region)?;
        self.boot_log.push(format!(
            "cxl: memory hot-add — {} LD {ld}: window {:#x} onlined as \
             zNUMA node {node} (+{} MiB)",
            md.bdf,
            md.hpa_base,
            md.hpa_size >> 20
        ));
        changes.push(MemChange::Onlined {
            base: md.hpa_base,
            size: md.hpa_size,
            node,
        });
        self.spares.remove(pos);
        self.cxl_nodes.push(node);
        self.regions.push(region);
        self.memdevs.push(md);
        Ok(())
    }
}
