//! Guest software stack model.
//!
//! The paper's headline claim is that CXLRAMSim needs **no kernel or
//! driver patches** because the modeled firmware + device surfaces are
//! architecturally correct. We substantiate the same claim structurally:
//! this module consumes only (a) bytes in simulated physical memory
//! (BIOS tables) and (b) MMIO through the [`Platform`] trait (ECAM
//! config space, BAR-mapped CXL register blocks). It never reaches into
//! simulator internals.
//!
//! Boot flow ([`GuestOs::boot`]):
//!   E820 -> ACPI parse (incl. AML) -> NUMA init from SRAT ->
//!   PCIe enumeration -> CXL driver bind (DVSEC walk, mailbox IDENTIFY,
//!   HDM decoder programming) -> `cxl create-region` + online ->
//!   zNUMA node 1 visible to the allocator.

pub mod acpi_parse;
pub mod cxl_driver;
pub mod cxlcli;
pub mod numa;
pub mod pci_scan;
pub mod vm;

use anyhow::{Context, Result};

use crate::bios::layout;
use crate::mem::PhysMem;

pub use acpi_parse::AcpiInfo;
pub use cxl_driver::CxlMemdev;
pub use cxlcli::CxlRegion;
pub use numa::{MemPolicy, NumaNode, PageAlloc};
pub use pci_scan::{MmioAllocator, PciDev};
pub use vm::AddressSpace;

/// MMIO access surface the guest drives (implemented by the machine:
/// routes to ECAM, CXL component/device blocks, host-bridge block).
pub trait Platform {
    fn mmio_read32(&mut self, addr: u64) -> u32;
    fn mmio_write32(&mut self, addr: u64, v: u32);
    fn mmio_read64(&mut self, addr: u64) -> u64;
    fn mmio_write64(&mut self, addr: u64, v: u64);
}

/// Memory-exposure programming model (paper §IV).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ProgModel {
    #[default]
    /// CXL memory as CPU-less NUMA node (zNUMA) — the default.
    Znuma,
    /// Flat mode: CXL capacity merged with system memory.
    Flat,
}

/// The booted guest's state.
pub struct GuestOs {
    pub acpi: AcpiInfo,
    pub pci_devs: Vec<PciDev>,
    pub memdev: Option<CxlMemdev>,
    pub alloc: PageAlloc,
    pub cxl_node: Option<u32>,
    pub boot_log: Vec<String>,
}

impl GuestOs {
    /// Full boot. `mem` carries the BIOS tables; `p` is the MMIO world.
    pub fn boot(
        p: &mut dyn Platform,
        mem: &PhysMem,
        page_size: u64,
        model: ProgModel,
    ) -> Result<GuestOs> {
        let mut log = Vec::new();

        // --- firmware tables -------------------------------------------
        let acpi = acpi_parse::parse(mem, layout::RSDP_ADDR & !0xFFFF)
            .context("ACPI parse failed")?;
        log.push(format!(
            "acpi: {} cpus, {} memory affinities, {} CHBS, {} CFMWS",
            acpi.cpu_apic_ids.len(),
            acpi.mem_affinity.len(),
            acpi.chbs.len(),
            acpi.cfmws.len()
        ));

        // --- NUMA init from SRAT ----------------------------------------
        let mut alloc = PageAlloc::new(page_size);
        let mut srat_nodes: Vec<_> = acpi.mem_affinity.clone();
        srat_nodes.sort_by_key(|m| m.domain);
        for m in &srat_nodes {
            let has_cpus = m.domain == 0; // SRAT cpu entries are domain 0
            alloc.add_node(NumaNode::new(m.domain, m.base, m.length, has_cpus));
            if m.enabled && !m.hotplug {
                alloc.online(m.domain);
                log.push(format!(
                    "numa: node {} online ({} MiB)",
                    m.domain,
                    m.length >> 20
                ));
            } else {
                log.push(format!(
                    "numa: node {} deferred (hotplug)",
                    m.domain
                ));
            }
        }

        // --- PCIe enumeration --------------------------------------------
        let (ecam, _b0, b1) = acpi.ecam.context("no MCFG/ECAM")?;
        // BAR window: host bridge _CRS second window, minus the CHBS
        // block the BIOS reserved at its base.
        let hb = acpi
            .devices
            .iter()
            .find(|d| d.hid.as_deref() == Some("PNP0A08"))
            .context("no PCIe host bridge in DSDT")?;
        let (mmio_base, mmio_size) =
            *hb.crs.get(1).context("host bridge lacks MMIO window")?;
        let mut bar_alloc = MmioAllocator::new(
            mmio_base + layout::CHBS_SIZE,
            mmio_size - layout::CHBS_SIZE,
        );
        let pci_devs = pci_scan::enumerate(p, ecam, b1, &mut bar_alloc);
        log.push(format!("pci: {} functions enumerated", pci_devs.len()));

        // --- CXL driver -----------------------------------------------------
        let memdev = match cxl_driver::bind(p, &acpi, &pci_devs) {
            Ok(md) => {
                log.push(format!(
                    "cxl: mem0 bound at {} — {} MiB, window {:#x}",
                    md.bdf,
                    md.capacity >> 20,
                    md.hpa_base
                ));
                Some(md)
            }
            Err(e) => {
                log.push(format!("cxl: no memdev ({e})"));
                None
            }
        };

        // --- region creation + onlining ------------------------------------
        let mut cxl_node = None;
        if let Some(md) = &memdev {
            match model {
                ProgModel::Znuma => {
                    let region = cxlcli::cxl_create_region(p, md, 0, 1)?;
                    let id = cxlcli::online_region(&mut alloc, &region)?;
                    cxl_node = Some(id);
                    log.push(format!(
                        "cxl-cli: region onlined as zNUMA node {id}"
                    ));
                }
                ProgModel::Flat => {
                    let region = cxlcli::cxl_create_region(p, md, 0, 0)?;
                    cxlcli::online_flat(&mut alloc, &region)?;
                    log.push("cxl-cli: region onlined in flat mode".into());
                }
            }
        }

        Ok(GuestOs { acpi, pci_devs, memdev, alloc, cxl_node, boot_log: log })
    }

    /// The zNUMA node id, if one was onlined.
    pub fn znuma_node(&self) -> Option<u32> {
        self.cxl_node
    }
}
