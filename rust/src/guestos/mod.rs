//! Guest software stack model.
//!
//! The paper's headline claim is that CXLRAMSim needs **no kernel or
//! driver patches** because the modeled firmware + device surfaces are
//! architecturally correct. We substantiate the same claim structurally:
//! this module consumes only (a) bytes in simulated physical memory
//! (BIOS tables) and (b) MMIO through the [`Platform`] trait (ECAM
//! config space, BAR-mapped CXL register blocks). It never reaches into
//! simulator internals.
//!
//! Boot flow ([`GuestOs::boot`]):
//!   E820 -> ACPI parse (incl. AML) -> NUMA init from SRAT ->
//!   PCIe enumeration -> CXL driver bind (DVSEC walk, mailbox IDENTIFY,
//!   HDM decoder programming) -> `cxl create-region` + online ->
//!   zNUMA node 1 visible to the allocator.

pub mod acpi_parse;
pub mod cxl_driver;
pub mod cxlcli;
pub mod numa;
pub mod pci_scan;
pub mod vm;

use anyhow::{Context, Result};

use crate::bios::layout;
use crate::mem::PhysMem;

pub use acpi_parse::AcpiInfo;
pub use cxl_driver::CxlMemdev;
pub use cxlcli::CxlRegion;
pub use numa::{MemPolicy, NumaNode, PageAlloc};
pub use pci_scan::{MmioAllocator, PciDev};
pub use vm::AddressSpace;

/// MMIO access surface the guest drives (implemented by the machine:
/// routes to ECAM, CXL component/device blocks, host-bridge block).
pub trait Platform {
    fn mmio_read32(&mut self, addr: u64) -> u32;
    fn mmio_write32(&mut self, addr: u64, v: u32);
    fn mmio_read64(&mut self, addr: u64) -> u64;
    fn mmio_write64(&mut self, addr: u64, v: u64);
}

/// Memory-exposure programming model (paper §IV).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ProgModel {
    #[default]
    /// CXL memory as CPU-less NUMA node (zNUMA) — the default.
    Znuma,
    /// Flat mode: CXL capacity merged with system memory.
    Flat,
}

/// The booted guest's state.
pub struct GuestOs {
    /// Which simulated host this guest runs on (0 in single-host
    /// machines). The driver hands it to the FM-API allocation query so
    /// a pooled MLD presents only this host's logical devices.
    pub host: u16,
    pub acpi: AcpiInfo,
    pub pci_devs: Vec<PciDev>,
    /// Every bound expander, in host-bridge UID order (`mem0`, `mem1`…).
    pub memdevs: Vec<CxlMemdev>,
    /// One region per interleave-set window, in window order.
    pub regions: Vec<CxlRegion>,
    pub alloc: PageAlloc,
    /// zNUMA node ids onlined for the regions (empty in flat mode).
    pub cxl_nodes: Vec<u32>,
    pub boot_log: Vec<String>,
}

impl GuestOs {
    /// Full boot. `mem` carries the BIOS tables; `p` is the MMIO world;
    /// `host` is this machine's identity on the CXL fabric (0 for
    /// single-host setups).
    pub fn boot(
        p: &mut dyn Platform,
        mem: &PhysMem,
        page_size: u64,
        model: ProgModel,
        host: u16,
    ) -> Result<GuestOs> {
        let mut log = Vec::new();

        // --- firmware tables -------------------------------------------
        let acpi = acpi_parse::parse(mem, layout::RSDP_ADDR & !0xFFFF)
            .context("ACPI parse failed")?;
        log.push(format!(
            "acpi: {} cpus, {} memory affinities, {} CHBS, {} CFMWS",
            acpi.cpu_apic_ids.len(),
            acpi.mem_affinity.len(),
            acpi.chbs.len(),
            acpi.cfmws.len()
        ));

        // --- NUMA init from SRAT ----------------------------------------
        let mut alloc = PageAlloc::new(page_size);
        let mut srat_nodes: Vec<_> = acpi.mem_affinity.clone();
        srat_nodes.sort_by_key(|m| m.domain);
        for m in &srat_nodes {
            let has_cpus = m.domain == 0; // SRAT cpu entries are domain 0
            alloc.add_node(NumaNode::new(m.domain, m.base, m.length, has_cpus));
            if m.enabled && !m.hotplug {
                alloc.online(m.domain);
                log.push(format!(
                    "numa: node {} online ({} MiB)",
                    m.domain,
                    m.length >> 20
                ));
            } else {
                log.push(format!(
                    "numa: node {} deferred (hotplug)",
                    m.domain
                ));
            }
        }

        // --- PCIe enumeration --------------------------------------------
        let (ecam, _b0, b1) = acpi.ecam.context("no MCFG/ECAM")?;
        // BAR window: host bridge _CRS second window, minus the CHBS
        // blocks the BIOS reserved at its base (one per CXL bridge,
        // discovered from their _CRS entries).
        let hb = acpi
            .devices
            .iter()
            .find(|d| d.hid.as_deref() == Some("PNP0A08"))
            .context("no PCIe host bridge in DSDT")?;
        let (mmio_base, mmio_size) =
            *hb.crs.get(1).context("host bridge lacks MMIO window")?;
        let reserved_end = acpi
            .chbs
            .iter()
            .filter(|c| c.base >= mmio_base)
            .map(|c| c.base + c.length)
            .fold(mmio_base + layout::CHBS_SIZE, u64::max);
        let mut bar_alloc = MmioAllocator::new(
            reserved_end,
            mmio_base + mmio_size - reserved_end,
        );
        let pci_devs = pci_scan::enumerate(p, ecam, b1, &mut bar_alloc);
        log.push(format!("pci: {} functions enumerated", pci_devs.len()));

        // --- CXL driver -----------------------------------------------------
        let memdevs = match cxl_driver::bind_all(p, &acpi, &pci_devs, host) {
            Ok(mds) => {
                for (i, md) in mds.iter().enumerate() {
                    let ld = if md.lds > 1 {
                        format!(", LD {}/{}", md.ld, md.lds)
                    } else {
                        String::new()
                    };
                    log.push(format!(
                        "cxl: mem{i} bound at {} — {} MiB, window {:#x} \
                         ({}-way @ {} B, slot {}{ld})",
                        md.bdf,
                        md.capacity >> 20,
                        md.hpa_base,
                        md.window_ways,
                        md.window_granularity,
                        md.position
                    ));
                }
                mds
            }
            Err(e) => {
                log.push(format!("cxl: no memdev ({e})"));
                Vec::new()
            }
        };

        // --- region creation + onlining ------------------------------------
        // Group memdevs by window: each interleave set becomes one
        // region. Its NUMA domain comes from the SRAT entry covering
        // the window base — the same association Linux derives.
        let mut windows: Vec<u64> = memdevs.iter().map(|m| m.hpa_base).collect();
        windows.sort_unstable();
        windows.dedup();
        let mut regions = Vec::new();
        let mut cxl_nodes = Vec::new();
        for base in windows {
            let group: Vec<&CxlMemdev> =
                memdevs.iter().filter(|m| m.hpa_base == base).collect();
            let domain = acpi
                .mem_affinity
                .iter()
                .find(|m| m.base == base)
                .map(|m| m.domain)
                .context("window has no SRAT domain")?;
            match model {
                ProgModel::Znuma => {
                    let region =
                        cxlcli::cxl_create_region(p, &group, 0, domain)?;
                    let id = cxlcli::online_region(&mut alloc, &region)?;
                    cxl_nodes.push(id);
                    log.push(format!(
                        "cxl-cli: region @{base:#x} ({} memdevs) onlined \
                         as zNUMA node {id}",
                        group.len()
                    ));
                    regions.push(region);
                }
                ProgModel::Flat => {
                    let region =
                        cxlcli::cxl_create_region(p, &group, 0, 0)?;
                    cxlcli::online_flat(&mut alloc, &region)?;
                    log.push(format!(
                        "cxl-cli: region @{base:#x} onlined in flat mode"
                    ));
                    regions.push(region);
                }
            }
        }

        Ok(GuestOs {
            host,
            acpi,
            pci_devs,
            memdevs,
            regions,
            alloc,
            cxl_nodes,
            boot_log: log,
        })
    }

    /// The first zNUMA node id, if one was onlined.
    pub fn znuma_node(&self) -> Option<u32> {
        self.cxl_nodes.first().copied()
    }
}
