//! Guest-side PCIe enumeration through ECAM MMIO.
//!
//! The guest only gets the ECAM base (from MCFG) and the MMIO window
//! (from the host bridge's _CRS); everything else is discovered by
//! config-space probing: vendor-id scan, header type, BAR sizing via the
//! all-ones protocol, BAR assignment from a bump allocator over the
//! window, and bridge secondary-bus walks — the same dance as a real
//! kernel's `pci_scan_root_bus`.

use crate::pcie::config_space::{off, CMD_BUS_MASTER, CMD_MEM_ENABLE};
use crate::pcie::Bdf;

use super::Platform;

#[derive(Clone, Debug)]
pub struct PciBar {
    pub index: usize,
    pub base: u64,
    pub size: u64,
}

#[derive(Clone, Debug)]
pub struct PciDev {
    pub bdf: Bdf,
    pub vendor: u16,
    pub device: u16,
    pub class: [u8; 3], // base, sub, prog-if
    pub is_bridge: bool,
    pub secondary_bus: u8,
    /// Highest bus number reachable below this bridge (type-1 header;
    /// 0 for endpoints). The CXL driver uses [secondary, subordinate]
    /// to place endpoints under their root port across switch levels.
    pub subordinate_bus: u8,
    pub bars: Vec<PciBar>,
}

/// Bump allocator over the MMIO window.
#[derive(Clone, Debug)]
pub struct MmioAllocator {
    cursor: u64,
    end: u64,
}

impl MmioAllocator {
    pub fn new(base: u64, size: u64) -> Self {
        MmioAllocator { cursor: base, end: base + size }
    }

    pub fn alloc(&mut self, size: u64) -> Option<u64> {
        let align = size.max(4096);
        let base = self.cursor.div_ceil(align) * align;
        if base + size > self.end {
            return None;
        }
        self.cursor = base + size;
        Some(base)
    }
}

fn cfg_addr(ecam: u64, bdf: Bdf, reg: usize) -> u64 {
    ecam + bdf.ecam_offset() + reg as u64
}

fn cfg_r32(p: &mut dyn Platform, ecam: u64, bdf: Bdf, reg: usize) -> u32 {
    p.mmio_read32(cfg_addr(ecam, bdf, reg))
}

fn cfg_w32(p: &mut dyn Platform, ecam: u64, bdf: Bdf, reg: usize, v: u32) {
    p.mmio_write32(cfg_addr(ecam, bdf, reg), v);
}

fn cfg_r16(p: &mut dyn Platform, ecam: u64, bdf: Bdf, reg: usize) -> u16 {
    let d = cfg_r32(p, ecam, bdf, reg & !3);
    ((d >> ((reg & 2) * 8)) & 0xFFFF) as u16
}

/// Size and assign the BARs of one function.
fn setup_bars(
    p: &mut dyn Platform,
    ecam: u64,
    bdf: Bdf,
    alloc: &mut MmioAllocator,
) -> Vec<PciBar> {
    let mut bars = Vec::new();
    let mut idx = 0;
    while idx < 6 {
        let reg = off::BAR0 + idx * 4;
        let orig = cfg_r32(p, ecam, bdf, reg);
        cfg_w32(p, ecam, bdf, reg, 0xFFFF_FFFF);
        let mask = cfg_r32(p, ecam, bdf, reg);
        if mask == 0 || mask == 0xFFFF_FFFF {
            cfg_w32(p, ecam, bdf, reg, orig);
            idx += 1;
            continue;
        }
        let is64 = mask & 0b110 == 0b100;
        let size_mask = (mask & 0xFFFF_FFF0) as u64;
        let size = (!size_mask).wrapping_add(1) & 0xFFFF_FFFF;
        if let Some(base) = alloc.alloc(size) {
            cfg_w32(p, ecam, bdf, reg, base as u32 | (mask & 0xF));
            if is64 {
                cfg_w32(p, ecam, bdf, reg + 4, (base >> 32) as u32);
            }
            bars.push(PciBar { index: idx, base, size });
        }
        idx += if is64 { 2 } else { 1 };
    }
    // Enable memory decoding + bus mastering.
    let cmd = cfg_r32(p, ecam, bdf, off::COMMAND & !3);
    cfg_w32(
        p,
        ecam,
        bdf,
        off::COMMAND & !3,
        cmd | (CMD_MEM_ENABLE | CMD_BUS_MASTER) as u32,
    );
    bars
}

/// Enumerate buses `0..=last_bus`, sizing and assigning BARs.
pub fn enumerate(
    p: &mut dyn Platform,
    ecam: u64,
    last_bus: u8,
    alloc: &mut MmioAllocator,
) -> Vec<PciDev> {
    let mut found = Vec::new();
    for bus in 0..=last_bus {
        for dev in 0..32u8 {
            let bdf = Bdf::new(bus, dev, 0);
            let id = cfg_r32(p, ecam, bdf, off::VENDOR_ID);
            if id == 0xFFFF_FFFF {
                continue;
            }
            let vendor = (id & 0xFFFF) as u16;
            let device = (id >> 16) as u16;
            let class_dword = cfg_r32(p, ecam, bdf, 0x08);
            let class = [
                (class_dword >> 24) as u8,
                (class_dword >> 16) as u8,
                (class_dword >> 8) as u8,
            ];
            let hdr = (cfg_r32(p, ecam, bdf, 0x0C) >> 16) as u8 & 0x7F;
            let is_bridge = hdr == 0x01;
            let (secondary_bus, subordinate_bus) = if is_bridge {
                let v = cfg_r32(p, ecam, bdf, off::PRIMARY_BUS);
                (((v >> 8) & 0xFF) as u8, ((v >> 16) & 0xFF) as u8)
            } else {
                (0, 0)
            };
            let bars = if is_bridge {
                Vec::new()
            } else {
                setup_bars(p, ecam, bdf, alloc)
            };
            found.push(PciDev {
                bdf,
                vendor,
                device,
                class,
                is_bridge,
                secondary_bus,
                subordinate_bus,
                bars,
            });
        }
    }
    found
}

/// Guest-side DVSEC walk over config space MMIO (mirrors
/// `pci_find_dvsec_capability`).
pub fn find_dvsec(
    p: &mut dyn Platform,
    ecam: u64,
    bdf: Bdf,
    vendor: u16,
    dvsec_id: u16,
) -> Option<usize> {
    let mut ptr = 0x100usize;
    loop {
        let hdr = cfg_r32(p, ecam, bdf, ptr);
        if hdr == 0 || hdr == 0xFFFF_FFFF {
            return None;
        }
        if hdr & 0xFFFF == 0x0023 {
            let v = cfg_r16(p, ecam, bdf, ptr + 4);
            let id = cfg_r16(p, ecam, bdf, ptr + 8);
            if v == vendor && id == dvsec_id {
                return Some(ptr);
            }
        }
        let next = (hdr >> 20) as usize & 0xFFC;
        if next == 0 {
            return None;
        }
        ptr = next;
    }
}

/// Read a chunk of config space (for DVSEC payload parsing).
pub fn read_cfg_bytes(
    p: &mut dyn Platform,
    ecam: u64,
    bdf: Bdf,
    reg: usize,
    len: usize,
) -> Vec<u8> {
    let mut out = Vec::with_capacity(len);
    let mut r = reg;
    while out.len() < len {
        let d = cfg_r32(p, ecam, bdf, r & !3);
        let b = d.to_le_bytes();
        let start = r & 3;
        for &x in &b[start..] {
            if out.len() == len {
                break;
            }
            out.push(x);
        }
        r = (r & !3) + 4;
    }
    out
}
