//! Guest NUMA topology + page allocator + memory policies.
//!
//! The SRAT gives node 0 (system DRAM, has CPUs) and — once the CXL
//! driver onlines the expander — node 1 (the CPU-less **zNUMA** node).
//! The page allocator hands out physical pages per policy; `numactl`'s
//! `--interleave` / `--membind` / `--preferred` map 1:1 onto
//! [`MemPolicy`], including the *weighted* interleave ratios the paper's
//! Fig. 5 sweeps (e.g. 3:1 DRAM:CXL).

use anyhow::{bail, Result};

/// A memory policy for an allocation context (mirrors Linux mempolicy).
#[derive(Clone, Debug, PartialEq)]
pub enum MemPolicy {
    /// Node-local (default): allocate from `home` until exhausted, then
    /// fall back to any node with free pages.
    Local { home: u32 },
    /// Strict bind: only these nodes, OOM otherwise.
    Bind { nodes: Vec<u32> },
    /// Preferred node with fallback.
    Preferred { node: u32 },
    /// Weighted round-robin page interleave: `(node, weight)` pairs.
    /// `numactl --interleave=0,1` == weights 1:1; HMSDK/SMDK-style
    /// weighted tiering (e.g. 3:1) uses unequal weights.
    Interleave { weights: Vec<(u32, u32)> },
}

impl MemPolicy {
    /// Parse the numactl-ish syntax used by the CLI:
    /// "local", "bind:0", "preferred:1", "interleave:0=3,1=1".
    pub fn parse(s: &str) -> Result<MemPolicy> {
        if s == "local" {
            return Ok(MemPolicy::Local { home: 0 });
        }
        if let Some(rest) = s.strip_prefix("local:") {
            return Ok(MemPolicy::Local { home: rest.trim().parse()? });
        }
        if let Some(rest) = s.strip_prefix("bind:") {
            let nodes = rest
                .split(',')
                .map(|n| n.trim().parse::<u32>())
                .collect::<Result<Vec<_>, _>>()?;
            if nodes.is_empty() {
                bail!("bind needs nodes");
            }
            return Ok(MemPolicy::Bind { nodes });
        }
        if let Some(rest) = s.strip_prefix("preferred:") {
            return Ok(MemPolicy::Preferred { node: rest.trim().parse()? });
        }
        if let Some(rest) = s.strip_prefix("interleave:") {
            let mut weights = Vec::new();
            for part in rest.split(',') {
                let part = part.trim();
                if let Some((n, w)) = part.split_once('=') {
                    weights.push((n.parse()?, w.parse()?));
                } else {
                    weights.push((part.parse()?, 1));
                }
            }
            if weights.is_empty() || weights.iter().any(|&(_, w)| w == 0) {
                bail!("bad interleave weights");
            }
            return Ok(MemPolicy::Interleave { weights });
        }
        bail!("unknown policy '{s}'")
    }

    /// The numactl-ish spec string this policy parses back from
    /// (`parse(p.to_spec()) == p`). Trace files record VMA policies in
    /// this form so replay runs rebuild identical address spaces.
    pub fn to_spec(&self) -> String {
        match self {
            MemPolicy::Local { home: 0 } => "local".into(),
            MemPolicy::Local { home } => format!("local:{home}"),
            MemPolicy::Bind { nodes } => format!(
                "bind:{}",
                nodes
                    .iter()
                    .map(|n| n.to_string())
                    .collect::<Vec<_>>()
                    .join(",")
            ),
            MemPolicy::Preferred { node } => format!("preferred:{node}"),
            MemPolicy::Interleave { weights } => format!(
                "interleave:{}",
                weights
                    .iter()
                    .map(|(n, w)| format!("{n}={w}"))
                    .collect::<Vec<_>>()
                    .join(",")
            ),
        }
    }
}

/// One NUMA node's physical memory.
#[derive(Clone, Debug)]
pub struct NumaNode {
    pub id: u32,
    pub base: u64,
    pub size: u64,
    pub has_cpus: bool,
    pub online: bool,
    next_free: u64,
    free_list: Vec<u64>,
}

impl NumaNode {
    pub fn new(id: u32, base: u64, size: u64, has_cpus: bool) -> Self {
        NumaNode {
            id,
            base,
            size,
            has_cpus,
            online: false,
            next_free: base,
            free_list: Vec::new(),
        }
    }

    pub fn free_pages(&self, page: u64) -> u64 {
        (self.base + self.size - self.next_free) / page
            + self.free_list.len() as u64
    }

    fn alloc(&mut self, page: u64) -> Option<u64> {
        if !self.online {
            return None;
        }
        if let Some(p) = self.free_list.pop() {
            return Some(p);
        }
        if self.next_free + page <= self.base + self.size {
            let p = self.next_free;
            self.next_free += page;
            Some(p)
        } else {
            None
        }
    }

    fn free(&mut self, addr: u64) {
        debug_assert!(addr >= self.base && addr < self.base + self.size);
        self.free_list.push(addr);
    }
}

/// The guest's physical page allocator across nodes.
#[derive(Clone, Debug)]
pub struct PageAlloc {
    pub nodes: Vec<NumaNode>,
    pub page: u64,
    /// Interleave cursor state per policy instance is the caller's; the
    /// allocator tracks per-node allocation counters for stats.
    pub allocated: Vec<u64>,
    /// Pages the policy's chosen node could not supply (exhausted or
    /// offline) that landed on a fallback node instead — the guest-side
    /// memory-pressure signal the FM's `capacity_rebalance` policy
    /// samples (dumped as `sys.numa_fallback_allocs`).
    pub fallback_allocs: u64,
}

impl PageAlloc {
    pub fn new(page: u64) -> Self {
        PageAlloc {
            nodes: Vec::new(),
            page,
            allocated: Vec::new(),
            fallback_allocs: 0,
        }
    }

    pub fn add_node(&mut self, node: NumaNode) {
        assert_eq!(node.id as usize, self.nodes.len(), "ids must be dense");
        self.nodes.push(node);
        self.allocated.push(0);
    }

    pub fn online(&mut self, id: u32) {
        self.nodes[id as usize].online = true;
    }

    /// Take a node offline: no further allocations land on it. The
    /// caller (hot-remove path) is responsible for checking that no
    /// pages are still in use — see `cxlcli::offline_region`.
    pub fn offline(&mut self, id: u32) {
        self.nodes[id as usize].online = false;
    }

    /// Pages currently allocated on node `id`.
    pub fn pages_in_use(&self, id: u32) -> u64 {
        self.allocated.get(id as usize).copied().unwrap_or(0)
    }

    pub fn node_of_addr(&self, addr: u64) -> Option<u32> {
        self.nodes
            .iter()
            .find(|n| addr >= n.base && addr < n.base + n.size)
            .map(|n| n.id)
    }

    fn alloc_on(&mut self, id: u32) -> Option<u64> {
        let p = self.page;
        let got = self.nodes.get_mut(id as usize)?.alloc(p);
        if got.is_some() {
            self.allocated[id as usize] += 1;
        }
        got
    }

    /// Allocate off-policy after `home` could not supply the page.
    /// Scan order is nearest first, like a real NUMA distance table —
    /// CPU (DRAM) nodes before CPU-less zNUMA (CXL) nodes, ids
    /// ascending within each class, the home node excluded (it was
    /// just probed). Two inline passes: this runs once per spilled
    /// page, so no order list is materialized.
    fn alloc_fallback(&mut self, home: u32) -> Option<u64> {
        for want_cpus in [true, false] {
            for id in 0..self.nodes.len() as u32 {
                if id == home
                    || self.nodes[id as usize].has_cpus != want_cpus
                {
                    continue;
                }
                if let Some(p) = self.alloc_on(id) {
                    self.fallback_allocs += 1;
                    return Some(p);
                }
            }
        }
        None
    }

    /// The scan order [`PageAlloc::alloc_fallback`] probes (exposed
    /// for tests: DRAM class first, home excluded).
    #[cfg(test)]
    fn fallback_order(&self, home: u32) -> Vec<u32> {
        let mut ids: Vec<u32> = (0..self.nodes.len() as u32)
            .filter(|&id| id != home)
            .collect();
        ids.sort_by_key(|&id| (!self.nodes[id as usize].has_cpus, id));
        ids
    }

    /// Allocate one page under `policy`; `seq` is the caller's page
    /// sequence number (drives interleave round-robin).
    pub fn alloc_page(
        &mut self,
        policy: &MemPolicy,
        seq: u64,
    ) -> Result<u64> {
        let pick = match policy {
            MemPolicy::Local { home } | MemPolicy::Preferred { node: home } => {
                if let Some(p) = self.alloc_on(*home) {
                    return Ok(p);
                }
                self.alloc_fallback(*home)
            }
            MemPolicy::Bind { nodes } => nodes
                .iter()
                .find_map(|&id| self.alloc_on(id)),
            MemPolicy::Interleave { weights } => {
                let total: u64 =
                    weights.iter().map(|&(_, w)| w as u64).sum();
                let mut slot = seq % total;
                let mut chosen = weights[0].0;
                for &(n, w) in weights {
                    if slot < w as u64 {
                        chosen = n;
                        break;
                    }
                    slot -= w as u64;
                }
                match self.alloc_on(chosen) {
                    Some(p) => return Ok(p),
                    None => self.alloc_fallback(chosen),
                }
            }
        };
        pick.ok_or_else(|| anyhow::anyhow!("out of memory (policy {policy:?})"))
    }

    pub fn free_page(&mut self, addr: u64) {
        if let Some(id) = self.node_of_addr(addr) {
            self.allocated[id as usize] =
                self.allocated[id as usize].saturating_sub(1);
            self.nodes[id as usize].free(addr);
        }
    }

    /// Online node ids of one memory class, id-ascending: `true` for
    /// CPU-carrying DRAM nodes, `false` for CPU-less zNUMA (CXL)
    /// windows.
    pub fn nodes_of_class(&self, has_cpus: bool) -> Vec<u32> {
        self.nodes
            .iter()
            .filter(|n| n.online && n.has_cpus == has_cpus)
            .map(|n| n.id)
            .collect()
    }

    /// Tier placement for a two-tier (hot/cold) workload, derived from
    /// the booted topology rather than hard-coded node ids: the hot
    /// tier strict-binds to the DRAM class, the cold tier to the zNUMA
    /// (CXL) class. On a machine with no online CXL window both tiers
    /// collapse onto DRAM — a serving fleet without an expander still
    /// runs, it just has nowhere cheaper to demote warm KV blocks.
    pub fn tier_policies(&self) -> (MemPolicy, MemPolicy) {
        let dram = self.nodes_of_class(true);
        let cxl = self.nodes_of_class(false);
        let hot = MemPolicy::Bind { nodes: dram.clone() };
        let cold = if cxl.is_empty() {
            MemPolicy::Bind { nodes: dram }
        } else {
            MemPolicy::Bind { nodes: cxl }
        };
        (hot, cold)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> PageAlloc {
        let mut pa = PageAlloc::new(4096);
        pa.add_node(NumaNode::new(0, 0, 1 << 20, true)); // 256 pages
        pa.add_node(NumaNode::new(1, 4 << 30, 1 << 20, false));
        pa.online(0);
        pa
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(
            MemPolicy::parse("local").unwrap(),
            MemPolicy::Local { home: 0 }
        );
        assert_eq!(
            MemPolicy::parse("bind:1").unwrap(),
            MemPolicy::Bind { nodes: vec![1] }
        );
        assert_eq!(
            MemPolicy::parse("interleave:0,1").unwrap(),
            MemPolicy::Interleave { weights: vec![(0, 1), (1, 1)] }
        );
        assert_eq!(
            MemPolicy::parse("interleave:0=3,1=1").unwrap(),
            MemPolicy::Interleave { weights: vec![(0, 3), (1, 1)] }
        );
        assert!(MemPolicy::parse("chaos").is_err());
        assert!(MemPolicy::parse("interleave:0=0").is_err());
    }

    #[test]
    fn policy_spec_round_trips() {
        for p in [
            MemPolicy::Local { home: 0 },
            MemPolicy::Local { home: 2 },
            MemPolicy::Bind { nodes: vec![1] },
            MemPolicy::Bind { nodes: vec![0, 2, 3] },
            MemPolicy::Preferred { node: 1 },
            MemPolicy::Interleave { weights: vec![(0, 3), (1, 1)] },
        ] {
            let spec = p.to_spec();
            assert_eq!(
                MemPolicy::parse(&spec).unwrap(),
                p,
                "spec '{spec}'"
            );
        }
    }

    #[test]
    fn tier_policies_split_by_memory_class() {
        let mut pa = setup();
        pa.online(1);
        let (hot, cold) = pa.tier_policies();
        assert_eq!(hot, MemPolicy::Bind { nodes: vec![0] });
        assert_eq!(cold, MemPolicy::Bind { nodes: vec![1] });
        // Offline CXL window: both tiers collapse onto DRAM.
        pa.offline(1);
        let (hot, cold) = pa.tier_policies();
        assert_eq!(hot, MemPolicy::Bind { nodes: vec![0] });
        assert_eq!(cold, MemPolicy::Bind { nodes: vec![0] });
    }

    #[test]
    fn offline_node_never_allocates() {
        let mut pa = setup();
        let pol = MemPolicy::Bind { nodes: vec![1] };
        assert!(pa.alloc_page(&pol, 0).is_err());
        pa.online(1);
        assert!(pa.alloc_page(&pol, 0).is_ok());
    }

    #[test]
    fn weighted_interleave_ratio_respected() {
        // Bigger nodes so the 3:1 split fits without fallback.
        let mut pa = PageAlloc::new(4096);
        pa.add_node(NumaNode::new(0, 0, 4 << 20, true));
        pa.add_node(NumaNode::new(1, 4 << 30, 4 << 20, false));
        pa.online(0);
        pa.online(1);
        let pol = MemPolicy::Interleave { weights: vec![(0, 3), (1, 1)] };
        for seq in 0..400u64 {
            pa.alloc_page(&pol, seq).unwrap();
        }
        assert_eq!(pa.allocated[0], 300);
        assert_eq!(pa.allocated[1], 100);
    }

    #[test]
    fn local_falls_back_when_exhausted() {
        let mut pa = setup();
        pa.online(1);
        let pol = MemPolicy::Local { home: 0 };
        // Node 0 has 256 pages; allocate 300.
        let mut on1 = 0;
        for seq in 0..300u64 {
            let p = pa.alloc_page(&pol, seq).unwrap();
            if pa.node_of_addr(p) == Some(1) {
                on1 += 1;
            }
        }
        assert_eq!(on1, 44);
    }

    #[test]
    fn fallback_is_nearest_first_and_skips_home() {
        // Three nodes, deliberately ordered so id order and distance
        // order disagree: node 0 is CPU-less (CXL), node 1 has CPUs
        // (DRAM), node 2 is CPU-less (CXL). 4 pages each.
        let mut pa = PageAlloc::new(4096);
        pa.add_node(NumaNode::new(0, 8 << 30, 4 * 4096, false));
        pa.add_node(NumaNode::new(1, 0, 4 * 4096, true));
        pa.add_node(NumaNode::new(2, 12 << 30, 4 * 4096, false));
        for id in 0..3 {
            pa.online(id);
        }
        let pol = MemPolicy::Preferred { node: 2 };
        // Fill the preferred node, then keep allocating: the fallback
        // must land on the DRAM node (1) first even though the far
        // zNUMA node (0) has the lower id, and only then on node 0.
        for seq in 0..4u64 {
            pa.alloc_page(&pol, seq).unwrap();
        }
        assert_eq!(pa.fallback_allocs, 0);
        let p = pa.alloc_page(&pol, 4).unwrap();
        assert_eq!(pa.node_of_addr(p), Some(1), "DRAM before far zNUMA");
        assert_eq!(pa.fallback_allocs, 1);
        for seq in 5..8u64 {
            pa.alloc_page(&pol, seq).unwrap();
        }
        assert_eq!(pa.allocated, vec![0, 4, 4], "node 0 untouched so far");
        let p = pa.alloc_page(&pol, 8).unwrap();
        assert_eq!(pa.node_of_addr(p), Some(0), "far zNUMA is last resort");
        assert_eq!(pa.fallback_allocs, 5);
        // The exhausted home node is skipped by the scan (order lists
        // every other node exactly once, DRAM class first).
        assert_eq!(pa.fallback_order(2), vec![1, 0]);
        assert_eq!(pa.fallback_order(0), vec![1, 2]);
    }

    #[test]
    fn bind_strict_oom() {
        let mut pa = setup();
        let pol = MemPolicy::Bind { nodes: vec![0] };
        for seq in 0..256u64 {
            pa.alloc_page(&pol, seq).unwrap();
        }
        assert!(pa.alloc_page(&pol, 999).is_err());
    }

    #[test]
    fn free_recycles() {
        let mut pa = setup();
        let pol = MemPolicy::Local { home: 0 };
        let p = pa.alloc_page(&pol, 0).unwrap();
        pa.free_page(p);
        // Freed page is reused.
        let q = pa.alloc_page(&pol, 1).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn node_of_addr_maps_ranges() {
        let pa = setup();
        assert_eq!(pa.node_of_addr(0), Some(0));
        assert_eq!(pa.node_of_addr(4 << 30), Some(1));
        assert_eq!(pa.node_of_addr(2 << 30), None);
    }
}
