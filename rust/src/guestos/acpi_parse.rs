//! Guest-side ACPI parsing — the "unmodified kernel" half of the BIOS
//! contract.
//!
//! Reads simulated physical memory only: scans for the RSDP, validates
//! every checksum, follows XSDT -> {FADT->DSDT, MADT, MCFG, SRAT, CEDT},
//! and runs the mini-AML interpreter over the DSDT to build the ACPI
//! namespace (devices with _HID/_UID/_CRS). Mirrors the Linux boot path
//! (`acpi_parse_rsdp` .. `acpi_scan_init`) at reduced scope.

use anyhow::{bail, Context, Result};

use crate::bios::acpi::table_checksum_ok;
use crate::bios::aml;
use crate::mem::PhysMem;

/// A device discovered in the DSDT namespace.
#[derive(Clone, Debug)]
pub struct AcpiDevice {
    pub path: String,
    /// Normalized HID: either the string form ("ACPI0016") or the
    /// decoded EISA form ("PNP0A08").
    pub hid: Option<String>,
    pub uid: Option<u32>,
    /// Memory windows from _CRS.
    pub crs: Vec<(u64, u64)>,
}

/// SRAT-derived memory affinity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemAffinity {
    pub domain: u32,
    pub base: u64,
    pub length: u64,
    pub hotplug: bool,
    pub enabled: bool,
}

/// CEDT structures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChbsInfo {
    pub uid: u32,
    pub cxl_version: u32,
    pub base: u64,
    pub length: u64,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CfmwsInfo {
    pub base_hpa: u64,
    pub window_size: u64,
    pub targets: Vec<u32>,
    /// Interleave granularity in bytes (decoded from HBIG).
    pub granularity: u64,
    /// Interleave arithmetic: 0 = modulo, 1 = XOR.
    pub arith: u8,
    pub restrictions: u16,
}

/// HMAT type-1 access attributes from initiator domain 0.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HmatAttr {
    pub target_domain: u32,
    pub read_lat_ns: f64,
    pub bw_gbps: f64,
}

/// Everything the guest kernel learned from ACPI.
#[derive(Clone, Debug, Default)]
pub struct AcpiInfo {
    pub cpu_apic_ids: Vec<u8>,
    pub ecam: Option<(u64, u8, u8)>, // base, start bus, end bus
    pub mem_affinity: Vec<MemAffinity>,
    pub chbs: Vec<ChbsInfo>,
    pub cfmws: Vec<CfmwsInfo>,
    pub hmat: Vec<HmatAttr>,
    pub devices: Vec<AcpiDevice>,
}

fn read_table(mem: &PhysMem, addr: u64) -> Result<(String, Vec<u8>)> {
    let len = mem.read_u32(addr + 4) as usize;
    if !(36..16 << 20).contains(&len) {
        bail!("implausible table length {len} at {addr:#x}");
    }
    let mut t = vec![0u8; len];
    mem.read(addr, &mut t);
    if !table_checksum_ok(&t) {
        bail!("checksum failure at {addr:#x}");
    }
    Ok((String::from_utf8_lossy(&t[0..4]).into_owned(), t))
}

/// Parse the full ACPI surface starting from the RSDP scan region.
pub fn parse(mem: &PhysMem, rsdp_scan_base: u64) -> Result<AcpiInfo> {
    // RSDP scan: 16-byte aligned over the classic window.
    let mut rsdp_addr = None;
    for off in (0..0x2_0000u64).step_by(16) {
        let mut sig = [0u8; 8];
        mem.read(rsdp_scan_base + off, &mut sig);
        if &sig == b"RSD PTR " {
            rsdp_addr = Some(rsdp_scan_base + off);
            break;
        }
    }
    let rsdp_addr = rsdp_addr.context("RSDP not found")?;
    let mut rsdp = vec![0u8; 36];
    mem.read(rsdp_addr, &mut rsdp);
    if !table_checksum_ok(&rsdp) {
        bail!("RSDP extended checksum bad");
    }
    if rsdp[..20].iter().fold(0u8, |a, b| a.wrapping_add(*b)) != 0 {
        bail!("RSDP v1 checksum bad");
    }
    let xsdt_addr = u64::from_le_bytes(rsdp[24..32].try_into().unwrap());

    let (sig, xsdt) = read_table(mem, xsdt_addr)?;
    if sig != "XSDT" {
        bail!("expected XSDT, found {sig}");
    }

    let mut info = AcpiInfo::default();
    for chunk in xsdt[36..].chunks_exact(8) {
        let addr = u64::from_le_bytes(chunk.try_into().unwrap());
        let (sig, t) = read_table(mem, addr)?;
        match sig.as_str() {
            "APIC" => parse_madt(&t, &mut info),
            "MCFG" => parse_mcfg(&t, &mut info),
            "SRAT" => parse_srat(&t, &mut info),
            "CEDT" => parse_cedt(&t, &mut info),
            "HMAT" => parse_hmat(&t, &mut info),
            "FACP" => {
                let dsdt_addr =
                    u64::from_le_bytes(t[140..148].try_into().unwrap());
                let (dsig, dsdt) = read_table(mem, dsdt_addr)?;
                if dsig != "DSDT" {
                    bail!("FADT points at {dsig}, not DSDT");
                }
                interpret_dsdt(&dsdt[36..], &mut info)?;
            }
            _ => {} // tolerate unknown tables like a real kernel
        }
    }
    Ok(info)
}

fn parse_madt(t: &[u8], info: &mut AcpiInfo) {
    let mut i = 36 + 8;
    while i + 2 <= t.len() {
        let typ = t[i];
        let len = t[i + 1] as usize;
        if len < 2 || i + len > t.len() {
            break;
        }
        if typ == 0 && len >= 8 {
            let flags = u32::from_le_bytes(t[i + 4..i + 8].try_into().unwrap());
            if flags & 1 != 0 {
                info.cpu_apic_ids.push(t[i + 3]);
            }
        }
        i += len;
    }
}

fn parse_mcfg(t: &[u8], info: &mut AcpiInfo) {
    let body = &t[36 + 8..];
    if body.len() >= 16 {
        let base = u64::from_le_bytes(body[0..8].try_into().unwrap());
        info.ecam = Some((base, body[10], body[11]));
    }
}

fn parse_srat(t: &[u8], info: &mut AcpiInfo) {
    let mut i = 36 + 12;
    while i + 2 <= t.len() {
        let typ = t[i];
        let len = t[i + 1] as usize;
        if len < 2 || i + len > t.len() {
            break;
        }
        if typ == 1 && len >= 40 {
            let g32 = |o: usize| {
                u32::from_le_bytes(t[i + o..i + o + 4].try_into().unwrap())
            };
            let g64 = |o: usize| {
                u64::from_le_bytes(t[i + o..i + o + 8].try_into().unwrap())
            };
            let flags = g32(28);
            info.mem_affinity.push(MemAffinity {
                domain: g32(2),
                base: g64(8),
                length: g64(16),
                enabled: flags & 1 != 0,
                hotplug: flags & 2 != 0,
            });
        }
        i += len;
    }
}

fn parse_cedt(t: &[u8], info: &mut AcpiInfo) {
    let mut i = 36;
    while i + 4 <= t.len() {
        let typ = t[i];
        let len = u16::from_le_bytes(t[i + 2..i + 4].try_into().unwrap())
            as usize;
        if len < 4 || i + len > t.len() {
            break;
        }
        let g32 = |o: usize| {
            u32::from_le_bytes(t[i + o..i + o + 4].try_into().unwrap())
        };
        let g64 = |o: usize| {
            u64::from_le_bytes(t[i + o..i + o + 8].try_into().unwrap())
        };
        match typ {
            0 => info.chbs.push(ChbsInfo {
                uid: g32(4),
                cxl_version: g32(8),
                base: g64(16),
                length: g64(24),
            }),
            1 => {
                let eniw = t[i + 24] as usize;
                let niw = 1usize << eniw;
                let mut targets = Vec::with_capacity(niw);
                for k in 0..niw {
                    targets.push(g32(36 + 4 * k));
                }
                let hbig = g32(28);
                info.cfmws.push(CfmwsInfo {
                    base_hpa: g64(8),
                    window_size: g64(16),
                    targets,
                    granularity: 256u64 << hbig,
                    arith: t[i + 25],
                    restrictions: u16::from_le_bytes(
                        t[i + 32..i + 34].try_into().unwrap(),
                    ),
                });
            }
            _ => {}
        }
        i += len;
    }
}

/// HMAT: type-1 System Locality Latency and Bandwidth structures with
/// one initiator (domain 0). Latency (data type 0) and bandwidth (data
/// type 3) rows are merged per target domain.
fn parse_hmat(t: &[u8], info: &mut AcpiInfo) {
    let mut i = 36 + 4;
    while i + 8 <= t.len() {
        let typ = u16::from_le_bytes(t[i..i + 2].try_into().unwrap());
        let len =
            u32::from_le_bytes(t[i + 4..i + 8].try_into().unwrap()) as usize;
        if len < 8 || i + len > t.len() {
            break;
        }
        if typ == 1 && len >= 32 {
            let data_type = t[i + 9];
            let n_init = u32::from_le_bytes(
                t[i + 12..i + 16].try_into().unwrap(),
            ) as usize;
            let n_tgt = u32::from_le_bytes(
                t[i + 16..i + 20].try_into().unwrap(),
            ) as usize;
            let base_unit = u64::from_le_bytes(
                t[i + 24..i + 32].try_into().unwrap(),
            );
            let tgt_list = i + 32 + 4 * n_init;
            let entries = tgt_list + 4 * n_tgt;
            if n_init == 1 && entries + 2 * n_tgt <= i + len {
                for k in 0..n_tgt {
                    let dom = u32::from_le_bytes(
                        t[tgt_list + 4 * k..tgt_list + 4 * k + 4]
                            .try_into()
                            .unwrap(),
                    );
                    let raw = u16::from_le_bytes(
                        t[entries + 2 * k..entries + 2 * k + 2]
                            .try_into()
                            .unwrap(),
                    ) as u64;
                    let attr = match info
                        .hmat
                        .iter_mut()
                        .find(|a| a.target_domain == dom)
                    {
                        Some(a) => a,
                        None => {
                            info.hmat.push(HmatAttr {
                                target_domain: dom,
                                read_lat_ns: 0.0,
                                bw_gbps: 0.0,
                            });
                            info.hmat.last_mut().unwrap()
                        }
                    };
                    match data_type {
                        // Latency entries scale by base unit in ps.
                        0 => {
                            attr.read_lat_ns =
                                (raw * base_unit) as f64 / 1000.0
                        }
                        // Bandwidth entries scale by base unit in MB/s.
                        3 => {
                            attr.bw_gbps =
                                (raw * base_unit) as f64 / 1000.0
                        }
                        _ => {}
                    }
                }
            }
        }
        i += len;
    }
}

// ---- mini-AML interpreter ------------------------------------------------

fn decode_eisa(v: u32) -> String {
    let s = v.swap_bytes();
    let c = |x: u32| ((x & 0x1F) as u8 + 0x40) as char;
    let h = |x: u32| char::from_digit(x & 0xF, 16).unwrap().to_ascii_uppercase();
    format!(
        "{}{}{}{}{}{}{}",
        c(s >> 26),
        c(s >> 21),
        c(s >> 16),
        h(s >> 12),
        h(s >> 8),
        h(s >> 4),
        h(s)
    )
}

struct AmlCursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> AmlCursor<'a> {
    fn name_string(&mut self) -> Result<String> {
        let mut out = String::new();
        if self.b[self.i] == b'\\' {
            out.push('\\');
            self.i += 1;
        }
        match self.b[self.i] {
            0x2E => {
                self.i += 1;
                out.push_str(&self.seg()?);
                out.push('.');
                out.push_str(&self.seg()?);
            }
            0x2F => {
                self.i += 1;
                let n = self.b[self.i] as usize;
                self.i += 1;
                for k in 0..n {
                    if k > 0 {
                        out.push('.');
                    }
                    out.push_str(&self.seg()?);
                }
            }
            _ => out.push_str(&self.seg()?),
        }
        Ok(out)
    }

    fn seg(&mut self) -> Result<String> {
        if self.i + 4 > self.b.len() {
            bail!("truncated name segment");
        }
        let s = String::from_utf8_lossy(&self.b[self.i..self.i + 4])
            .trim_end_matches('_')
            .to_string();
        self.i += 4;
        Ok(s)
    }

    fn data(&mut self) -> Result<aml::AmlData> {
        let op = self.b[self.i];
        self.i += 1;
        match op {
            0x0A => {
                let v = self.b[self.i] as u32;
                self.i += 1;
                Ok(aml::AmlData::DWord(v))
            }
            0x0B => {
                let v = u16::from_le_bytes(
                    self.b[self.i..self.i + 2].try_into().unwrap(),
                ) as u32;
                self.i += 2;
                Ok(aml::AmlData::DWord(v))
            }
            0x0C => {
                let v = u32::from_le_bytes(
                    self.b[self.i..self.i + 4].try_into().unwrap(),
                );
                self.i += 4;
                Ok(aml::AmlData::DWord(v))
            }
            0x0E => {
                let v = u64::from_le_bytes(
                    self.b[self.i..self.i + 8].try_into().unwrap(),
                );
                self.i += 8;
                Ok(aml::AmlData::QWord(v))
            }
            0x0D => {
                let start = self.i;
                while self.b[self.i] != 0 {
                    self.i += 1;
                }
                let s = String::from_utf8_lossy(&self.b[start..self.i])
                    .into_owned();
                self.i += 1;
                Ok(aml::AmlData::Str(s))
            }
            0x11 => {
                let (total, plen) =
                    aml::parse_pkg_length(&self.b[self.i..]);
                let end = self.i + total;
                self.i += plen;
                // BufferSize term: integer constant.
                let size = match self.data()? {
                    aml::AmlData::DWord(v) => v as usize,
                    aml::AmlData::QWord(v) => v as usize,
                    _ => bail!("non-integer buffer size"),
                };
                let have = end - self.i;
                let take = size.min(have);
                let bytes = self.b[self.i..self.i + take].to_vec();
                self.i = end;
                Ok(aml::AmlData::Buffer(bytes))
            }
            other => bail!("unsupported AML data opcode {other:#x}"),
        }
    }
}

fn interpret_dsdt(aml_bytes: &[u8], info: &mut AcpiInfo) -> Result<()> {
    let mut c = AmlCursor { b: aml_bytes, i: 0 };
    walk_termlist(&mut c, aml_bytes.len(), "", info)
}

fn walk_termlist(
    c: &mut AmlCursor,
    end: usize,
    scope: &str,
    info: &mut AcpiInfo,
) -> Result<()> {
    while c.i < end {
        match c.b[c.i] {
            0x10 => {
                // ScopeOp
                c.i += 1;
                let (total, plen) = aml::parse_pkg_length(&c.b[c.i..]);
                let body_end = c.i + total;
                c.i += plen;
                let name = c.name_string()?;
                let inner = join(scope, &name);
                walk_termlist(c, body_end, &inner, info)?;
                c.i = body_end;
            }
            0x5B if c.b.get(c.i + 1) == Some(&0x82) => {
                // DeviceOp
                c.i += 2;
                let (total, plen) = aml::parse_pkg_length(&c.b[c.i..]);
                let body_end = c.i + total;
                c.i += plen;
                let name = c.name_string()?;
                let path = join(scope, &name);
                let mut dev = AcpiDevice {
                    path: path.clone(),
                    hid: None,
                    uid: None,
                    crs: Vec::new(),
                };
                // Children: Names we understand, nested devices recurse.
                walk_device_body(c, body_end, &path, &mut dev, info)?;
                info.devices.push(dev);
                c.i = body_end;
            }
            0x08 => {
                // Stray Name at scope level — skip it properly.
                c.i += 1;
                let _ = c.name_string()?;
                let _ = c.data()?;
            }
            other => bail!("unsupported AML term {other:#x} at {}", c.i),
        }
    }
    Ok(())
}

fn walk_device_body(
    c: &mut AmlCursor,
    end: usize,
    path: &str,
    dev: &mut AcpiDevice,
    info: &mut AcpiInfo,
) -> Result<()> {
    while c.i < end {
        match c.b[c.i] {
            0x08 => {
                c.i += 1;
                let name = c.name_string()?;
                let data = c.data()?;
                match (name.as_str(), &data) {
                    ("_HID", aml::AmlData::Str(s)) => {
                        dev.hid = Some(s.clone())
                    }
                    ("_HID", aml::AmlData::DWord(v)) => {
                        dev.hid = Some(decode_eisa(*v))
                    }
                    ("_UID", aml::AmlData::DWord(v)) => dev.uid = Some(*v),
                    ("_CRS", aml::AmlData::Buffer(b)) => {
                        dev.crs = aml::parse_crs_memory(b)
                    }
                    _ => {}
                }
            }
            0x5B if c.b.get(c.i + 1) == Some(&0x82) => {
                // Nested device.
                c.i += 2;
                let (total, plen) = aml::parse_pkg_length(&c.b[c.i..]);
                let body_end = c.i + total;
                c.i += plen;
                let name = c.name_string()?;
                let p = join(path, &name);
                let mut inner = AcpiDevice {
                    path: p.clone(),
                    hid: None,
                    uid: None,
                    crs: Vec::new(),
                };
                walk_device_body(c, body_end, &p, &mut inner, info)?;
                info.devices.push(inner);
                c.i = body_end;
            }
            other => bail!("unsupported device term {other:#x}"),
        }
    }
    Ok(())
}

fn join(scope: &str, name: &str) -> String {
    if scope.is_empty() {
        name.to_string()
    } else {
        format!("{scope}.{name}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bios;
    use crate::config::SimConfig;

    fn parsed() -> AcpiInfo {
        let cfg = SimConfig::default();
        let mut mem = PhysMem::new();
        bios::build(&cfg, &mut mem);
        parse(&mem, bios::layout::RSDP_ADDR & !0xFFFF).unwrap()
    }

    #[test]
    fn finds_cpus_and_ecam() {
        let info = parsed();
        assert_eq!(info.cpu_apic_ids.len(), 4);
        let (base, b0, b1) = info.ecam.unwrap();
        assert_eq!(base, bios::layout::ECAM_BASE);
        assert_eq!(b0, 0);
        assert_eq!(b1, bios::layout::ECAM_BUSES - 1);
    }

    #[test]
    fn srat_exposes_znuma_domain() {
        let info = parsed();
        assert_eq!(info.mem_affinity.len(), 2);
        let cxl = &info.mem_affinity[1];
        assert_eq!(cxl.domain, 1);
        assert!(cxl.hotplug, "CXL domain must be hot-pluggable");
        assert_eq!(cxl.base, bios::cxl_window_base(2 << 30));
    }

    #[test]
    fn cedt_chbs_and_cfmws_parsed() {
        let info = parsed();
        assert_eq!(info.chbs.len(), 1);
        assert_eq!(info.chbs[0].uid, bios::layout::CHB_UID);
        assert_eq!(info.chbs[0].base, bios::layout::CHBS_BASE);
        assert_eq!(info.cfmws.len(), 1);
        assert_eq!(info.cfmws[0].targets, vec![bios::layout::CHB_UID]);
        assert!(info.cfmws[0].restrictions & (1 << 2) != 0, "volatile");
    }

    #[test]
    fn dsdt_namespace_has_host_bridges() {
        let info = parsed();
        let pc = info
            .devices
            .iter()
            .find(|d| d.hid.as_deref() == Some("PNP0A08"))
            .expect("PCIe host bridge");
        assert_eq!(pc.crs.len(), 2); // ECAM + MMIO windows
        let cxl = info
            .devices
            .iter()
            .find(|d| d.hid.as_deref() == Some("ACPI0016"))
            .expect("CXL host bridge");
        assert_eq!(cxl.uid, Some(bios::layout::CHB_UID));
        assert_eq!(
            cxl.crs,
            vec![(bios::layout::CHBS_BASE, bios::layout::CHBS_SIZE)]
        );
    }

    #[test]
    fn cfmws_carries_interleave_parameters() {
        let info = parsed();
        assert_eq!(info.cfmws[0].granularity, 256);
        assert_eq!(info.cfmws[0].arith, 0);
    }

    #[test]
    fn hmat_ranks_cxl_behind_dram() {
        let info = parsed();
        assert_eq!(info.hmat.len(), 2);
        let dram = info.hmat.iter().find(|a| a.target_domain == 0).unwrap();
        let cxl = info.hmat.iter().find(|a| a.target_domain == 1).unwrap();
        assert!(cxl.read_lat_ns > dram.read_lat_ns);
        assert!(cxl.bw_gbps > 0.0 && dram.bw_gbps > 0.0);
    }

    #[test]
    fn four_device_bios_parses_to_four_bridges() {
        let mut cfg = SimConfig::default();
        cfg.cxl.devices = 4;
        cfg.cxl.mem_size = 512 << 20;
        cfg.cxl.interleave_granularity = 1024;
        let mut mem = PhysMem::new();
        bios::build(&cfg, &mut mem);
        let info = parse(&mem, bios::layout::RSDP_ADDR & !0xFFFF).unwrap();
        assert_eq!(info.chbs.len(), 4);
        assert_eq!(info.cfmws.len(), 1, "one window for the 4-way set");
        assert_eq!(info.cfmws[0].targets.len(), 4);
        assert_eq!(info.cfmws[0].granularity, 1024);
        assert_eq!(info.cfmws[0].window_size, 2 << 30);
        // Four ACPI0016 bridges in the namespace, distinct UIDs + CHBS.
        let bridges: Vec<_> = info
            .devices
            .iter()
            .filter(|d| d.hid.as_deref() == Some("ACPI0016"))
            .collect();
        assert_eq!(bridges.len(), 4);
        for (i, b) in bridges.iter().enumerate() {
            assert_eq!(b.uid, Some(bios::layout::CHB_UID + i as u32));
            assert_eq!(
                b.crs,
                vec![(
                    bios::layout::chbs_base(i),
                    bios::layout::CHBS_SIZE
                )]
            );
        }
        // SRAT: DRAM domain + one zNUMA domain for the set.
        assert_eq!(info.mem_affinity.len(), 2);
    }

    #[test]
    fn eisa_decode_inverts_encode() {
        for id in ["PNP0A08", "PNP0A03", "PNP0C02"] {
            assert_eq!(decode_eisa(bios::aml::eisa_id(id)), id);
        }
    }

    #[test]
    fn corrupted_checksum_rejected() {
        let cfg = SimConfig::default();
        let mut mem = PhysMem::new();
        let info = bios::build(&cfg, &mut mem);
        // Flip a byte in the XSDT region.
        let addr = info.tables_end - 64;
        let v = mem.read_u32(addr);
        mem.write_u32(addr, v ^ 0xFF);
        // Either parse fails or (if we hit padding) succeeds; corrupt a
        // known table instead: MADT is after DSDT+FADT.
        // Brute force: corrupt every table start until parse fails.
        let mut failed = false;
        for off in (0..(info.tables_end - bios::layout::ACPI_POOL)).step_by(8)
        {
            let a = bios::layout::ACPI_POOL + off;
            let orig = mem.read_u32(a);
            mem.write_u32(a, orig ^ 0xA5);
            if parse(&mem, 0xE0000 & !0xFFFF).is_err() {
                failed = true;
                mem.write_u32(a, orig);
                break;
            }
            mem.write_u32(a, orig);
        }
        assert!(failed, "no corruption detected anywhere");
    }
}
