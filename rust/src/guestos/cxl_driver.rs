//! The guest's CXL driver stack (cxl_acpi + cxl_pci + cxl_mem in one).
//!
//! Everything happens through architectural surfaces:
//!   1. CEDT (CHBS/CFMWS) from ACPI tells it where the host-bridge
//!      component registers and the fixed memory windows live.
//!   2. Memdev endpoints are matched by class code 0502xx from the PCI
//!      scan and placed under their root port by walking the bridge
//!      secondary/subordinate bus ranges — one level deep for direct
//!      attach, through upstream/downstream switch bridges otherwise.
//!      DVSECs are walked via config MMIO; the Register Locator DVSEC
//!      yields the BAR-relative component/device blocks.
//!   3. The mailbox (doorbell poll) runs IDENTIFY to learn capacity,
//!      the FM-API Get LD Info to learn the logical-device count, and
//!      the FM-API Get LD Allocations to learn which LDs the fabric
//!      manager bound to *this* host (a pooled MLD parcels its LDs out
//!      to different hosts; unbound LDs default to host 0 so FM-less
//!      bring-up keeps working).
//!   4. Per owned logical device, HDM decoders are programmed +
//!      committed on BOTH the host bridge and the endpoint, mapping one
//!      CFMWS window onto that LD's capacity slice (DPA skip).
//!   5. Runtime re-binding: in the hot-plug window layout (one window
//!      per LD, published to every host) foreign LDs' windows are kept
//!      as uncommitted *spares*; FM Event-Log records later drive
//!      [`commit_memdev_decoders`] (hot-add) and
//!      [`uncommit_memdev_decoders`] (hot-remove) against them — see
//!      `GuestOs::handle_fm_events`.

use anyhow::{bail, Context, Result};

use crate::cxl::mailbox::{opcode, retcode, CAP_MULTIPLE, SHARED, UNBOUND};
use crate::cxl::regs::{comp, dev, dev_block_ids};
use crate::pcie::config_space::{CXL_VENDOR_ID, DVSEC_CXL_DEVICE,
                                DVSEC_REGISTER_LOCATOR};
use crate::pcie::Bdf;

use super::acpi_parse::{AcpiInfo, CfmwsInfo, ChbsInfo};
use super::pci_scan::{self, PciDev};
use super::Platform;

/// What the driver bound and where: one entry per *logical* device (an
/// SLD contributes one, an MLD with `lds = K` contributes up to K —
/// only this host's share — sharing a BDF/mailbox but mapping distinct
/// windows).
#[derive(Clone, Debug)]
pub struct CxlMemdev {
    pub bdf: Bdf,
    pub serial: u64,
    /// Capacity this logical device contributes (the full card for an
    /// SLD, one slice for an MLD LD).
    pub capacity: u64,
    /// Host-physical window the HDM decoders map (the full CFMWS
    /// window; an interleaved device holds every `ways`-th granule).
    pub hpa_base: u64,
    pub hpa_size: u64,
    /// Interleave parameters of the window this device participates in.
    pub window_ways: usize,
    pub window_granularity: u64,
    /// 0 = modulo, 1 = XOR target selection.
    pub window_arith: u8,
    /// This device's slot in the CFMWS target list.
    pub position: usize,
    /// Logical-device index within the endpoint (0 for SLDs).
    pub ld: u16,
    /// Logical devices the endpoint exposes.
    pub lds: u16,
    /// Endpoint HDM decoder slot this binding commits. Equal to `ld`
    /// for private LDs; sharers of a shared LD past the first take
    /// overflow slots beyond `lds` so their commits never collide.
    pub ep_decoder: usize,
    /// The LD is CXL 3.x shared (this host is one of several sharers).
    pub shared: bool,
    pub component_block: u64, // absolute MMIO base (endpoint)
    pub device_block: u64,    // absolute MMIO base (mailbox)
    pub hb_component_block: u64,
    /// Host-bridge HDM decoder index this logical device's window uses
    /// (committed while bound, uncommitted by hot-remove; stable across
    /// re-binds in the hot-plug window layout).
    pub hb_decoder: usize,
    pub hb_uid: u32,
}

/// Run a mailbox command through the device block MMIO (doorbell poll —
/// the same loop user-space CXL-CLI ends up in via the kernel ioctl).
pub fn mailbox_command(
    p: &mut dyn Platform,
    devblk: u64,
    op: u16,
    payload: &[u8],
) -> Result<(u16, Vec<u8>)> {
    if p.mmio_read64(devblk + dev::MB_CTRL) & 1 != 0 {
        bail!("mailbox busy before command");
    }
    for (i, chunk) in payload.chunks(8).enumerate() {
        let mut b = [0u8; 8];
        b[..chunk.len()].copy_from_slice(chunk);
        p.mmio_write64(
            devblk + dev::MB_PAYLOAD + (i * 8) as u64,
            u64::from_le_bytes(b),
        );
    }
    p.mmio_write64(
        devblk + dev::MB_CMD,
        (op as u64) | ((payload.len() as u64) << 16),
    );
    p.mmio_write64(devblk + dev::MB_CTRL, 1);
    let mut spins = 0u32;
    while p.mmio_read64(devblk + dev::MB_CTRL) & 1 != 0 {
        spins += 1;
        if spins > 10_000 {
            bail!("mailbox doorbell stuck");
        }
    }
    let code = ((p.mmio_read64(devblk + dev::MB_STATUS) >> 32) & 0xFFFF) as u16;
    let rlen =
        ((p.mmio_read64(devblk + dev::MB_CMD) >> 16) & 0x1F_FFFF) as usize;
    let mut resp = vec![0u8; rlen];
    for i in 0..rlen.div_ceil(8) {
        let v = p.mmio_read64(devblk + dev::MB_PAYLOAD + (i * 8) as u64);
        let at = i * 8;
        let n = (rlen - at).min(8);
        resp[at..at + n].copy_from_slice(&v.to_le_bytes()[..n]);
    }
    Ok((code, resp))
}

/// Program and commit decoder `idx` of a component block at `blk` to
/// map `[base, base+size)` onto device-physical `[dpa, dpa+size)` with
/// the given interleave encodings (IG: granularity = 256 << ig; IW:
/// ways = 1 << eniw).
#[allow(clippy::too_many_arguments)]
fn commit_decoder(
    p: &mut dyn Platform,
    blk: u64,
    idx: usize,
    base: u64,
    size: u64,
    ig: u8,
    eniw: u8,
    dpa: u64,
) -> Result<()> {
    let dec = blk + comp::HDM_DEC0 + (idx as u64) * comp::HDM_DEC_STRIDE;
    p.mmio_write32(dec + comp::DEC_BASE_LO, base as u32);
    p.mmio_write32(dec + comp::DEC_BASE_HI, (base >> 32) as u32);
    p.mmio_write32(dec + comp::DEC_SIZE_LO, size as u32);
    p.mmio_write32(dec + comp::DEC_SIZE_HI, (size >> 32) as u32);
    p.mmio_write32(dec + comp::DEC_DPA_LO, dpa as u32);
    p.mmio_write32(dec + comp::DEC_DPA_HI, (dpa >> 32) as u32);
    p.mmio_write32(dec + comp::DEC_CTRL, comp::dec_ctrl_commit(ig, eniw));
    let ctrl = p.mmio_read32(dec + comp::DEC_CTRL);
    if ctrl & comp::CTRL_COMMITTED == 0 {
        bail!("HDM decoder {idx} refused commit (ctrl={ctrl:#x})");
    }
    // Global enable (bit 1).
    p.mmio_write32(blk + comp::HDM_GLOBAL_CTRL, 0b10);
    Ok(())
}

/// Uncommit decoder `idx` of the component block at `blk` (clears the
/// commit bit; the committed latch follows).
fn uncommit_decoder(p: &mut dyn Platform, blk: u64, idx: usize) {
    let dec = blk + comp::HDM_DEC0 + (idx as u64) * comp::HDM_DEC_STRIDE;
    p.mmio_write32(dec + comp::DEC_CTRL, 0);
}

/// Hot-add half of runtime re-binding: program + commit the endpoint
/// and host-bridge HDM decoder pair for `md`'s window (leaf before
/// root, as at boot).
pub fn commit_memdev_decoders(
    p: &mut dyn Platform,
    md: &CxlMemdev,
) -> Result<()> {
    let ig = (md.window_granularity.trailing_zeros() - 8) as u8;
    let eniw = md.window_ways.trailing_zeros() as u8;
    let dpa = md.ld as u64 * md.capacity;
    commit_decoder(
        p,
        md.component_block,
        md.ep_decoder,
        md.hpa_base,
        md.hpa_size,
        ig,
        eniw,
        dpa,
    )?;
    commit_decoder(
        p,
        md.hb_component_block,
        md.hb_decoder,
        md.hpa_base,
        md.hpa_size,
        ig,
        eniw,
        0,
    )?;
    Ok(())
}

/// Hot-remove half: uncommit `md`'s decoder pair (root before leaf —
/// upstream routing dies first so nothing can still be steered at the
/// endpoint mid-teardown).
pub fn uncommit_memdev_decoders(p: &mut dyn Platform, md: &CxlMemdev) {
    uncommit_decoder(p, md.hb_component_block, md.hb_decoder);
    uncommit_decoder(p, md.component_block, md.ep_decoder);
}

/// Per-bridge window consumption state: published windows are consumed
/// in CEDT order by this host's logical devices in (endpoint BDF, LD)
/// order; a multi-way window whose target list names this bridge
/// several times (an interleave set behind one switch) is shared by
/// that many endpoints, each taking the next target slot.
struct BridgeCursor {
    /// Index of the window currently being filled.
    window: usize,
    /// Target slots of the current window already claimed.
    slot: usize,
    /// Next free host-bridge HDM decoder.
    decoder: usize,
}

/// What the driver binds and what it holds back: `bound` is one entry
/// per logical device this host owns (decoders committed, ready to
/// become regions); `spares` is the hot-plug pool — windows the
/// firmware published for logical devices currently bound to *other*
/// hosts, kept uncommitted until an FM re-bind event hands them to us.
/// The pool is non-empty only in the hot-plug window layout (see
/// [`bind_all`]).
#[derive(Debug, Default)]
pub struct BindResult {
    pub bound: Vec<CxlMemdev>,
    pub spares: Vec<CxlMemdev>,
}

/// Bind every CXL memdev by walking the PCIe *hierarchy*: the type-1
/// bridges on bus 0 are the CXL root ports; root port `i` (BDF order)
/// pairs with CHBS entry `i` (UID order) — the simulator wires them in
/// that order, mirroring the ACPI namespace association a full _PRT
/// walk would produce. Every class-0502 endpoint whose bus falls in a
/// root port's [secondary, subordinate] range belongs to that bridge,
/// whether direct-attached or behind a switch's upstream/downstream
/// bridges. Each bridge's CFMWS windows (CEDT order) are then consumed
/// by its endpoints in BDF order, one window slot per logical device
/// this host owns.
///
/// **Hot-plug window layout**: when the firmware publishes exactly one
/// 1-way window per logical device under a bridge (`windows == total
/// LDs` — the layout BIOSes emit when a runtime FM schedule exists),
/// window consumption turns *positional*: every LD, owned or not,
/// claims its own window and host-bridge decoder slot, and windows of
/// foreign LDs are recorded as uncommitted spares for later hot-add.
/// Otherwise (the legacy layout) only owned LDs consume windows and a
/// leftover window is a firmware/FM disagreement.
pub fn bind_all(
    p: &mut dyn Platform,
    acpi: &AcpiInfo,
    pci_devs: &[PciDev],
    host: u16,
) -> Result<BindResult> {
    let mut chbs = acpi.chbs.clone();
    chbs.sort_by_key(|c| c.uid);
    if chbs.is_empty() {
        bail!("no CHBS in CEDT — BIOS did not describe a CXL host bridge");
    }
    let mut root_ports: Vec<&PciDev> = pci_devs
        .iter()
        .filter(|d| d.is_bridge && d.bdf.bus == 0)
        .collect();
    root_ports.sort_by_key(|d| d.bdf);
    if root_ports.len() != chbs.len() {
        bail!(
            "{} root ports but {} CXL host bridges",
            root_ports.len(),
            chbs.len()
        );
    }
    let mut eps: Vec<&PciDev> = pci_devs
        .iter()
        .filter(|d| {
            !d.is_bridge && d.class[0] == 0x05 && d.class[1] == 0x02
        })
        .collect();
    eps.sort_by_key(|d| d.bdf);
    if eps.is_empty() {
        bail!("no CXL memory device on the PCIe bus");
    }
    let mut out = BindResult::default();
    let mut claimed = 0usize;
    for (rp, hb) in root_ports.iter().zip(&chbs) {
        let under: Vec<&PciDev> = eps
            .iter()
            .filter(|e| {
                e.bdf.bus >= rp.secondary_bus
                    && e.bdf.bus <= rp.subordinate_bus
            })
            .copied()
            .collect();
        if under.is_empty() {
            bail!(
                "CXL host bridge uid {} has no memdev beneath its root \
                 port {}",
                hb.uid,
                rp.bdf
            );
        }
        claimed += under.len();
        let wins: Vec<&CfmwsInfo> = acpi
            .cfmws
            .iter()
            .filter(|w| w.targets.contains(&hb.uid))
            .collect();
        // Probe first (register blocks, IDENTIFY, LD counts/owners),
        // so the window layout is known before anything commits.
        let probes: Vec<EpProbe> = under
            .iter()
            .map(|ep| probe_endpoint(p, acpi, ep, hb))
            .collect::<Result<_>>()?;
        let total_lds: usize =
            probes.iter().map(|pr| pr.lds as usize).sum();
        let positional = wins.len() == total_lds
            && wins.iter().all(|w| w.targets.len() == 1);
        let mut cursor = BridgeCursor { window: 0, slot: 0, decoder: 0 };
        for pr in &probes {
            bind_endpoint_lds(
                p, pr, hb, &wins, &mut cursor, host, positional, &mut out,
            )?;
        }
        if cursor.window < wins.len() || cursor.slot != 0 {
            bail!(
                "host bridge uid {}: {} window(s) published but the \
                 endpoints' bound LDs consumed only {} (FM binding and \
                 firmware disagree)",
                hb.uid,
                wins.len(),
                cursor.window
            );
        }
    }
    if claimed != eps.len() {
        bail!(
            "{} memdev endpoint(s) not under any CXL root port",
            eps.len() - claimed
        );
    }
    Ok(out)
}

/// Probe results for one endpoint: register-block locations and the
/// mailbox-reported identity, gathered before any decoder commits.
struct EpProbe {
    bdf: Bdf,
    serial: u64,
    capacity: u64,
    lds: u16,
    slice: u64,
    owners: Vec<u16>,
    /// Per-LD sharer-host bitmaps (CXL 3.x shared LDs report owner ==
    /// SHARED and list their sharers here; zero otherwise).
    sharer_maps: Vec<u64>,
    component_block: u64,
    device_block: u64,
}

/// Endpoint HDM decoder slot for (`ld`, `host`): private LDs use slot
/// `ld`; a shared LD's first sharer (lowest host id) also uses slot
/// `ld`, and every further sharer takes one slot from the overflow
/// region past `lds`, in (ld, sharer-rank) order. Every host computes
/// this independently from the Get LD Allocations bitmaps, so sharer
/// commits on the shared endpoint never collide.
fn endpoint_decoder_slot(
    lds: u16,
    owners: &[u16],
    sharer_maps: &[u64],
    ld: u16,
    host: u16,
) -> usize {
    if owners[ld as usize] != SHARED {
        return ld as usize;
    }
    let below = (1u64 << (host as u64 & 63)) - 1;
    let rank = (sharer_maps[ld as usize] & below).count_ones() as usize;
    if rank == 0 {
        return ld as usize;
    }
    let mut slot = lds as usize;
    for j in 0..ld as usize {
        if owners[j] == SHARED {
            slot +=
                (sharer_maps[j].count_ones() as usize).saturating_sub(1);
        }
    }
    slot + rank - 1
}

/// Locate one endpoint's register blocks and interrogate its mailbox:
/// DVSEC walk, IDENTIFY, FM-API Get LD Info + Get LD Allocations.
fn probe_endpoint(
    p: &mut dyn Platform,
    acpi: &AcpiInfo,
    ep: &PciDev,
    chbs: &ChbsInfo,
) -> Result<EpProbe> {
    if chbs.cxl_version == 0 {
        bail!("CXL 1.1 host bridges unsupported (RCD mode)");
    }
    let (ecam, ..) = acpi.ecam.context("no MCFG")?;

    // DVSEC walk: confirm CXL device + register locator.
    let cxl_dvsec =
        pci_scan::find_dvsec(p, ecam, ep.bdf, CXL_VENDOR_ID, DVSEC_CXL_DEVICE)
            .context("endpoint lacks CXL Device DVSEC")?;
    let caps = pci_scan::read_cfg_bytes(p, ecam, ep.bdf, cxl_dvsec + 12, 2);
    let cap = u16::from_le_bytes(caps.try_into().unwrap());
    if cap & (1 << 2) == 0 {
        bail!("device is not mem_capable");
    }
    let rl = pci_scan::find_dvsec(
        p,
        ecam,
        ep.bdf,
        CXL_VENDOR_ID,
        DVSEC_REGISTER_LOCATOR,
    )
    .context("endpoint lacks Register Locator DVSEC")?;
    // Register locator payload: walk entries until both blocks found.
    let payload = pci_scan::read_cfg_bytes(p, ecam, ep.bdf, rl + 12, 24);
    let entries =
        crate::cxl::regs::dvsec_payload::parse_register_locator(&payload);
    let mut comp_off = None;
    let mut dev_off = None;
    for (bar, id, offset) in entries {
        let base = ep
            .bars
            .iter()
            .find(|b| b.index == bar as usize)
            .map(|b| b.base + offset);
        match id {
            x if x == dev_block_ids::COMPONENT => comp_off = base,
            x if x == dev_block_ids::DEVICE => dev_off = base,
            _ => {}
        }
    }
    let component_block =
        comp_off.context("register locator lacks component block")?;
    let device_block =
        dev_off.context("register locator lacks device block")?;

    // Wait for media, then IDENTIFY through the mailbox.
    if p.mmio_read64(device_block + dev::MEMDEV_STATUS) & dev::MEDIA_READY == 0
    {
        bail!("media not ready");
    }
    let (code, ident) =
        mailbox_command(p, device_block, opcode::IDENTIFY_MEMORY_DEVICE, &[])?;
    if code != retcode::SUCCESS {
        bail!("IDENTIFY failed with code {code:#x}");
    }
    let capacity =
        u64::from_le_bytes(ident[16..24].try_into().unwrap()) * CAP_MULTIPLE;
    let serial = u64::from_le_bytes(ident[64..72].try_into().unwrap());
    if capacity == 0 {
        bail!("device reports zero capacity");
    }
    // Logical-device count (FM-API Get LD Info); SLDs report 1 and an
    // UNSUPPORTED return degrades to the SLD path.
    let (code, ldinfo) =
        mailbox_command(p, device_block, opcode::GET_LD_INFO, &[])?;
    let lds = if code == retcode::SUCCESS && ldinfo.len() >= 10 {
        u16::from_le_bytes(ldinfo[8..10].try_into().unwrap()).max(1)
    } else {
        1
    };
    if capacity % lds as u64 != 0 {
        bail!("capacity does not split across {lds} logical devices");
    }
    let slice = capacity / lds as u64;

    // FM-API Get LD Allocations: which host owns each LD. LDs the
    // fabric manager never bound default to host 0 (FM-less operation).
    let (code, alloc) =
        mailbox_command(p, device_block, opcode::GET_LD_ALLOCATIONS, &[])?;
    let owners: Vec<u16> =
        if code == retcode::SUCCESS && alloc.len() >= 2 + 2 * lds as usize {
            (0..lds as usize)
                .map(|k| {
                    u16::from_le_bytes(
                        alloc[2 + 2 * k..4 + 2 * k].try_into().unwrap(),
                    )
                })
                .collect()
        } else {
            vec![UNBOUND; lds as usize]
        };
    // Sharer bitmaps follow the owner array (devices that predate
    // sharing return the short form; all-private then).
    let bm_off = 2 + 2 * lds as usize;
    let sharer_maps: Vec<u64> =
        if code == retcode::SUCCESS && alloc.len() >= bm_off + 8 * lds as usize
        {
            (0..lds as usize)
                .map(|k| {
                    u64::from_le_bytes(
                        alloc[bm_off + 8 * k..bm_off + 8 * k + 8]
                            .try_into()
                            .unwrap(),
                    )
                })
                .collect()
        } else {
            vec![0; lds as usize]
        };
    Ok(EpProbe {
        bdf: ep.bdf,
        serial,
        capacity,
        lds,
        slice,
        owners,
        sharer_maps,
        component_block,
        device_block,
    })
}

/// Walk one probed endpoint's logical devices, consuming the bridge's
/// windows at `cursor`: owned LDs get their endpoint + host-bridge HDM
/// decoder pair committed and become `out.bound` entries; in the
/// positional (hot-plug) layout, foreign LDs still claim their window
/// and decoder slot but stay uncommitted, landing in `out.spares`.
#[allow(clippy::too_many_arguments)]
fn bind_endpoint_lds(
    p: &mut dyn Platform,
    ep: &EpProbe,
    chbs: &ChbsInfo,
    wins: &[&CfmwsInfo],
    cursor: &mut BridgeCursor,
    host: u16,
    positional: bool,
    out: &mut BindResult,
) -> Result<()> {
    let (capacity, lds, slice) = (ep.capacity, ep.lds, ep.slice);
    for ld in 0..lds {
        let owner = ep.owners[ld as usize];
        let shared = owner == SHARED;
        let owned = owner == host
            || (owner == UNBOUND && host == 0)
            || (shared
                && ep.sharer_maps[ld as usize] >> (host as u64 & 63) & 1
                    == 1);
        if !owned && !positional {
            // Legacy layout: another host's logical device is simply
            // not presented to us (its window isn't published here).
            continue;
        }
        let cfmws = wins.get(cursor.window).with_context(|| {
            format!(
                "host bridge uid {} has no CFMWS window left for {} LD {ld}",
                chbs.uid, ep.bdf
            )
        })?;
        // Target slots of this window that name our bridge: one slot
        // per participating endpoint. Direct-attach interleave lists
        // each bridge once; a same-switch set lists this bridge `ways`
        // times and its endpoints claim consecutive slots in BDF order.
        let my_slots: Vec<usize> = cfmws
            .targets
            .iter()
            .enumerate()
            .filter(|(_, &u)| u == chbs.uid)
            .map(|(i, _)| i)
            .collect();
        let position = *my_slots.get(cursor.slot).with_context(|| {
            format!(
                "window {:#x}: all {} slot(s) of bridge uid {} already \
                 claimed",
                cfmws.base_hpa,
                my_slots.len(),
                chbs.uid
            )
        })?;
        let ways = cfmws.targets.len();
        // An N-way window spreads every member across the whole window
        // (each decoder maps the full window with the interleave fields
        // set); a 1-way window maps onto one LD slice via DPA skip.
        let map_size = if ways == 1 {
            cfmws.window_size.min(slice)
        } else {
            cfmws.window_size.min(capacity * ways as u64)
        };
        if !cfmws.granularity.is_power_of_two() || cfmws.granularity < 256 {
            bail!("bad CFMWS granularity {:#x}", cfmws.granularity);
        }

        let md = CxlMemdev {
            bdf: ep.bdf,
            serial: ep.serial,
            capacity: slice,
            hpa_base: cfmws.base_hpa,
            hpa_size: map_size,
            window_ways: ways,
            window_granularity: cfmws.granularity,
            window_arith: cfmws.arith,
            position,
            ld,
            lds,
            ep_decoder: endpoint_decoder_slot(
                lds,
                &ep.owners,
                &ep.sharer_maps,
                ld,
                host,
            ),
            shared,
            component_block: ep.component_block,
            device_block: ep.device_block,
            hb_component_block: chbs.base,
            hb_decoder: cursor.decoder,
            hb_uid: chbs.uid,
        };
        if owned {
            // HDM decoders: endpoint first, then host bridge (commit
            // order matters on real hardware: leaf before root). The
            // endpoint uses decoder `ld`; the bridge its claimed slot.
            commit_memdev_decoders(p, &md)?;
            out.bound.push(md);
        } else {
            // Positional layout: the window and decoder slot stay
            // reserved (uncommitted) for a future FM hot-add.
            out.spares.push(md);
        }
        cursor.decoder += 1;
        cursor.slot += 1;
        if cursor.slot >= my_slots.len() {
            cursor.slot = 0;
            cursor.window += 1;
        }
    }
    Ok(())
}
